"""Compiled codec pipeline (`repro.comm.compiled`) — the jit fast path.

The load-bearing assertions of this PR:

* BYTE EQUALITY — for every registry codec, the compiled
  ``encode_arrays``-based pipeline emits packets byte-identical to the
  eager `WireCodec.encode` (the golden fixtures keep guarding the eager
  side, so compiled == eager == committed bytes), including the MLMC
  dense-fallback variants and the level-specialized RTN bodies;
* batched (vmapped) encodes equal single-row encodes bit-for-bit — the
  invariant that keeps a TCP rank (batch of 1) bitwise comparable to the
  in-process loop (batch of M);
* the fused ``decode_mean`` equals the eager stack-and-mean;
* the Elias-gamma correction stream round-trips and never exceeds its 2d
  worst-case bound;
* RETRACE GUARD — three trainer steps on each wire (packed / device /
  tcp-loopback) lower exactly once: zero new jit lowerings after step 0.
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import jax._src.test_util as jtu

from repro.comm import Packet, make_codec, make_compiled_codec
from repro.comm.codec import gamma_signed_decode, gamma_signed_encode
from repro.core.aggregators import ALL_AGGREGATORS

jax.config.update("jax_platform_name", "cpu")

D = 257            # deliberately not a multiple of 128 or any field count
M = 4
CODEC_KW = dict(k_fraction=0.05, s=4)

#: forced-level sweeps only make sense where explicit probs steer the draw
#: (the per-sample-adaptive families ignore the probs argument)
FORCIBLE = ("mlmc_fixed", "mlmc_float", "mlmc_adaptive_rtn")


def _grad(d=D, seed=0):
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, (d,)) * jnp.exp(-0.02 * jnp.arange(d))


@pytest.fixture(scope="module")
def grad():
    return _grad()


def _pair(name, d=D):
    return (make_codec(name, d, **CODEC_KW),
            make_compiled_codec(name, d, **CODEC_KW))


def _assert_same_encode(eager, comp, v, key, probs=None):
    e = (eager.encode(v, key, probs=probs) if probs is not None
         else eager.encode(v, key))
    c = (comp.encode(v, key, probs=probs) if probs is not None
         else comp.encode(v, key))
    assert e.packet.to_bytes() == c.packet.to_bytes(), \
        (eager.name, e.packet.header, c.packet.header)
    np.testing.assert_array_equal(np.asarray(c.estimate),
                                  np.asarray(e.estimate))
    np.testing.assert_array_equal(comp.decode(e.packet),
                                  eager.decode(e.packet))
    return e


# ---------------------------------------------------------------------------
# byte-equality battery
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_AGGREGATORS)
def test_compiled_bytes_match_eager(name, grad):
    """encode_arrays -> byte framing produces EXACTLY the eager bytes."""
    eager, comp = _pair(name)
    for trial in range(4):
        key = jax.random.fold_in(jax.random.PRNGKey(1), trial)
        _assert_same_encode(eager, comp, grad, key)


@pytest.mark.parametrize("name", FORCIBLE)
def test_compiled_forced_levels(name, grad):
    """Every sampled level — including the dense top-level fallback whose
    payload is the raw residual — stays byte-identical."""
    eager, comp = _pair(name)
    L = eager.compressor.num_levels
    levels = sorted({1, 2, 3, L - 1, L} & set(range(1, L + 1)))
    for lvl in levels:
        probs = jnp.full((L,), 1e-9).at[lvl - 1].set(1.0)
        e = _assert_same_encode(eager, comp, grad, jax.random.PRNGKey(5),
                                probs=probs)
        assert e.packet.header.level == lvl


def test_compiled_mlmc_rtn_levels(grad):
    """The per-sample-adaptive RTN family: sweep keys until several levels
    (ideally including the dense fallback) have been seen."""
    eager, comp = _pair("mlmc_rtn")
    seen = set()
    for t in range(200):
        key = jax.random.PRNGKey(1000 + t)
        lvl = eager.encode(grad, key).packet.header.level
        if lvl in seen:
            continue
        seen.add(lvl)
        _assert_same_encode(eager, comp, grad, key)
        if len(seen) >= 5:
            break
    assert len(seen) >= 3, f"only levels {seen} sampled"


def test_compiled_zero_and_negzero(grad):
    """Exact zeros (sign = 0 side channels) survive the compiled path."""
    v = jnp.asarray(np.array([0.0, -1.5, 0.0, 2.5, -0.0, 1e-8] * 20,
                             np.float32))
    for name in ("signsgd", "qsgd", "natural", "mlmc_fixed", "mlmc_float"):
        eager = make_codec(name, v.shape[0], **CODEC_KW)
        comp = make_compiled_codec(name, v.shape[0], **CODEC_KW)
        _assert_same_encode(eager, comp, v, jax.random.PRNGKey(4))


# ---------------------------------------------------------------------------
# batched encode / fused decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_AGGREGATORS)
def test_batch_rows_match_single_and_eager(name):
    """One vmapped batch encode == M single-row encodes == eager, byte for
    byte — what keeps tcp ranks (M=1) bitwise equal to loopback (M=4)."""
    eager, comp = _pair(name)
    V = jnp.stack([_grad(seed=3 + i) for i in range(M)])
    keys = jax.random.split(jax.random.PRNGKey(9), M)
    pkts = comp.encode_batch(V, keys)
    for m in range(M):
        single = comp.encode(V[m], keys[m]).packet.to_bytes()
        assert pkts[m].to_bytes() == single, (name, m)
        assert single == eager.encode(V[m], keys[m]).packet.to_bytes(), \
            (name, m)
    fused = comp.decode_mean(pkts)
    ref = jnp.mean(jnp.stack([jnp.asarray(eager.decode(p)) for p in pkts]),
                   axis=0)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(ref))


def test_explicit_probs_batch_matches_single():
    """The stateful EMA family's explicit-prob packets: batched encode with
    per-worker Lemma-3.4 rows equals the per-row encode (the multihost
    parity surface)."""
    for name in ("mlmc_adaptive_topk", "mlmc_adaptive_stopk",
                 "mlmc_adaptive_rtn"):
        eager, comp = _pair(name)
        L = eager.compressor.num_levels
        V = jnp.stack([_grad(seed=13 + i) for i in range(M)])
        keys = jax.random.split(jax.random.PRNGKey(17), M)
        probs = jax.nn.softmax(
            jax.random.normal(jax.random.PRNGKey(23), (M, L)))
        pkts = comp.encode_batch(V, keys, probs=probs)
        for m in range(M):
            ref = eager.encode(V[m], keys[m], probs=probs[m])
            assert pkts[m].to_bytes() == ref.packet.to_bytes(), (name, m)
            assert pkts[m].header.flags & 2   # FLAG_EXPLICIT_PROB shipped


# ---------------------------------------------------------------------------
# Elias-gamma correction stream
# ---------------------------------------------------------------------------


def test_gamma_stream_roundtrip_and_bound():
    rs = np.random.RandomState(0)
    for _ in range(120):
        d = int(rs.randint(1, 1500))
        dens = float(rs.choice([0.0, 0.01, 0.25, 0.5, 1.0]))
        corr = rs.choice([-1, 0, 1], size=d,
                         p=[dens / 2, 1 - dens, dens / 2])
        words, nbits, n = gamma_signed_encode(corr)
        assert n == int(np.count_nonzero(corr))
        # worst case: sum_i (2 floor(log2 g_i) + 2) <= 2 sum_i g_i <= 2d
        assert nbits <= 2 * d
        assert words.size == -(-nbits // 32)
        np.testing.assert_array_equal(gamma_signed_decode(words, nbits, d),
                                      corr)


def test_gamma_stream_rejects_corruption_loudly():
    """A corrupt-but-frame-valid gamma stream (bit flips survive
    `Packet.from_bytes`'s geometry checks) must raise a descriptive
    ValueError — rank 0's TCP server decodes these, and PR 3's contract is
    loud rejection, never a raw IndexError."""
    d = 64
    # unary run that never terminates
    with pytest.raises(ValueError, match="never terminates"):
        gamma_signed_decode(np.zeros((1,), np.uint32), 5, d)
    # truncated final record: gamma(3) needs 3 bits + sign, give it 3
    corr = np.zeros((d,), np.int64)
    corr[2] = 1
    words, nbits, _ = gamma_signed_encode(corr)
    with pytest.raises(ValueError, match="stream has"):
        gamma_signed_decode(words, nbits - 1, d)
    # gap overruns the plane
    with pytest.raises(ValueError, match="dim-1 plane"):
        gamma_signed_decode(words, nbits, 1)


def test_gamma_stream_shrinks_the_rtn_packet(grad):
    """The entropy-coded corr stream must never exceed the flat 2-bit plane
    it replaced, and the measured bits reconcile with the
    corr_bits-aware ledger."""
    from repro.core import bits as bitcost

    eager, _ = _pair("mlmc_rtn")
    for t in range(60):
        res = eager.encode(grad, jax.random.PRNGKey(400 + t))
        h = res.packet.header
        if not 1 < h.level < eager.compressor.num_levels:
            continue
        corr = res.packet.streams[1]
        assert corr.width == 1
        assert corr.used_bits <= 2 * D
        lo, hi = eager.reconcile_bounds(res.packet)
        assert lo <= eager.measured_bits(res.packet) <= hi
        booked = bitcost.rtn_mlmc_bits(D, h.level, corr_bits=corr.used_bits,
                                       num_levels=8)
        flat = float(bitcost.rtn_mlmc_bits(D, h.level, num_levels=8))
        assert booked <= flat
        return
    pytest.skip("no mid-level draw in 60 keys")


# ---------------------------------------------------------------------------
# hypothesis: odd dims round-trip
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # pragma: no cover - dev extra not installed
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(name=st.sampled_from(ALL_AGGREGATORS),
           dim=st.sampled_from([1, 2, 3, 31, 63, 127, 130, 255, 419]),
           seed=st.integers(0, 2**16))
    def test_compiled_roundtrip_odd_dims(name, dim, seed):
        """Byte equality + lossless round-trip at awkward dims (1, primes,
        just-past-word-boundary sizes) — the padding/slicing edge cases of
        the fixed-shape buffers."""
        eager = make_codec(name, dim, **CODEC_KW)
        comp = make_compiled_codec(name, dim, **CODEC_KW)
        v = _grad(d=dim, seed=seed)
        key = jax.random.PRNGKey(seed + 1)
        e = eager.encode(v, key)
        c = comp.encode(v, key)
        assert e.packet.to_bytes() == c.packet.to_bytes()
        wire = Packet.from_bytes(c.packet.to_bytes())
        np.testing.assert_array_equal(comp.decode(wire),
                                      np.asarray(c.estimate))


# ---------------------------------------------------------------------------
# retrace guard: 3 trainer steps per wire, zero lowerings after step 0
# ---------------------------------------------------------------------------

_RG = dict(d=48, b=4, world=3, seed=11)


def _rg_trainer(wire, transport=None, method="mlmc_topk"):
    from repro.optim import sgd
    from repro.train import Trainer

    d = _RG["d"]
    params = {"w": jnp.zeros((d,)), "b": jnp.zeros(())}

    def loss_fn(p, batch):
        return jnp.mean((batch["x"] @ p["w"] + p["b"] - batch["y"]) ** 2)

    return Trainer(loss_fn, params, num_workers=_RG["world"], method=method,
                   optimizer=sgd(0.1), k_fraction=0.25, wire=wire,
                   transport=transport)


def _rg_batches():
    d, b, world = _RG["d"], _RG["b"], _RG["world"]
    key = jax.random.PRNGKey(7)
    wkey, key = jax.random.split(key)
    w_true = jax.random.normal(wkey, (d,))
    while True:
        key, kx = jax.random.split(key)
        x = jax.random.normal(kx, (world, b, d))
        yield {"x": x, "y": x @ w_true}


@pytest.mark.parametrize("wire", ["packed", "device"])
def test_no_retrace_after_first_step(wire):
    """Steady-state steps must not lower a single new jit: the compiled
    pipeline's caches are keyed on static shapes only."""
    trainer = _rg_trainer(wire)
    data = _rg_batches()
    trainer.fit(data, steps=1, seed=_RG["seed"])          # warmup/compile
    with jtu.count_jit_and_pmap_lowerings() as count:
        trainer.fit(data, steps=2, seed=_RG["seed"] + 1)
    assert count[0] == 0, f"{wire}: {count[0]} new lowerings after step 0"


def test_no_retrace_tcp_loopback():
    """Same guard over a real in-process TCP star: rank 0 + worker threads
    each run 1 warmup step, then 2 counted steps with ZERO new lowerings
    anywhere in the process."""
    import socket

    from repro.comm.multihost import TcpStarTransport

    try:
        probe = socket.socket()
        probe.bind(("127.0.0.1", 0))
        probe.close()
    except OSError:
        pytest.skip("localhost sockets unavailable")

    world = _RG["world"]
    server = TcpStarTransport.listen(port=0, world=world, timeout=15.0)
    tps = {0: server}

    def join(r):
        tps[r] = TcpStarTransport.connect("127.0.0.1", server.port, rank=r,
                                          world=world, timeout=15.0)

    joiners = [threading.Thread(target=join, args=(r,))
               for r in range(1, world)]
    for t in joiners:
        t.start()
    server.accept_workers()
    for t in joiners:
        t.join()

    trainers = {r: _rg_trainer("packed", transport=tps[r])
                for r in range(world)}
    streams = {r: _rg_batches() for r in range(world)}
    errors = []

    def run(r, steps, seed):
        try:
            trainers[r].fit(streams[r], steps=steps, seed=seed)
        except Exception as exc:            # pragma: no cover - diagnostics
            errors.append((r, exc))

    def round_of_steps(steps, seed):
        threads = [threading.Thread(target=run, args=(r, steps, seed))
                   for r in range(1, world)]
        for t in threads:
            t.start()
        run(0, steps, seed)
        for t in threads:
            t.join()

    try:
        round_of_steps(1, _RG["seed"])                    # warmup/compile
        assert not errors, errors
        with jtu.count_jit_and_pmap_lowerings() as count:
            round_of_steps(2, _RG["seed"] + 1)
        assert not errors, errors
        assert count[0] == 0, \
            f"tcp: {count[0]} new lowerings after step 0"
    finally:
        for tp in tps.values():
            tp.close()


# ---------------------------------------------------------------------------
# compiled aggregator == eager aggregator (same bytes -> same training)
# ---------------------------------------------------------------------------


def test_per_codec_compiled_default_table():
    """``compiled=None`` routes each (codec, direction) to its
    measured-faster pipeline (BENCH_wire.json "codec_us"): the EF21 family
    stays fully eager, the mlmc_topk family gets a `HybridCodec` (compiled
    encode, eager decode), and the explicit flag still overrides in both
    directions."""
    from repro.comm import packed_aggregator
    from repro.comm.aggregate import _is_compiled
    from repro.comm.compiled import (
        COMPILED_DECODE_OFF,
        COMPILED_DEFAULT_OFF,
        COMPILED_ENCODE_OFF,
        CompiledCodec,
        HybridCodec,
        default_compiled,
    )
    from repro.core.aggregators import ALL_AGGREGATORS, make_aggregator

    assert COMPILED_ENCODE_OFF == {"ef21", "ef21_sgdm"}
    assert COMPILED_DECODE_OFF == {"ef21", "ef21_sgdm", "mlmc_topk",
                                   "mlmc_topk_static", "mlmc_stopk"}
    assert COMPILED_DEFAULT_OFF == {"ef21", "ef21_sgdm"}
    for name in ALL_AGGREGATORS:
        assert default_compiled(name, "encode") == \
            (name not in COMPILED_ENCODE_OFF)
        assert default_compiled(name, "decode") == \
            (name not in COMPILED_DECODE_OFF)
        assert default_compiled(name) == (name not in COMPILED_DEFAULT_OFF)

    def codec_of(agg):
        return agg.fn.codec if hasattr(agg.fn, "codec") else agg.codec

    for name, want in (("ef21", False), ("ef21_sgdm", False),
                       ("signsgd_ef", True), ("mlmc_topk", True),
                       ("mlmc_adaptive_topk", True)):
        agg = packed_aggregator(name, D, **CODEC_KW)
        assert _is_compiled(codec_of(agg)) == want, name
        forced = packed_aggregator(name, D, **CODEC_KW, compiled=not want)
        assert _is_compiled(codec_of(forced)) == (not want), name
        # an explicit flag always yields a single-pipeline codec
        assert not isinstance(codec_of(forced), HybridCodec), name

    # the split defaults surface as a hybrid: compiled encode half, eager
    # decode half, and NO decode_device (the TCP drain path must decode
    # eagerly per arriving frame)
    hyb = codec_of(packed_aggregator("mlmc_topk", D, **CODEC_KW))
    assert isinstance(hyb, HybridCodec)
    assert hasattr(hyb, "encode_batch")
    assert isinstance(hyb.enc, CompiledCodec)
    assert not isinstance(hyb.dec, CompiledCodec)
    assert not hasattr(hyb, "decode_device")
    # fully-on codecs stay plain compiled instances
    assert not isinstance(
        codec_of(packed_aggregator("qsgd", D, **CODEC_KW)), HybridCodec)

    # the table threads through make_aggregator (what Trainer uses)
    via_make = make_aggregator("ef21", D, **CODEC_KW, wire="packed")
    assert not _is_compiled(codec_of(via_make))
    via_make = make_aggregator("ef21", D, **CODEC_KW, wire="packed",
                               compiled=True)
    assert _is_compiled(codec_of(via_make))


def test_hybrid_default_equals_forced_pipelines():
    """The default (hybrid) mlmc_topk packed aggregator reproduces both
    forced pipelines bit-for-bit: same direction, same measured bits."""
    from repro.comm import packed_aggregator

    V = jnp.stack([_grad(seed=61 + i) for i in range(M)])
    for name in ("mlmc_topk", "mlmc_adaptive_topk", "ef21"):
        default = packed_aggregator(name, D, **CODEC_KW)
        eager = packed_aggregator(name, D, **CODEC_KW, compiled=False)
        st_d, st_e = default.init(M, D), eager.init(M, D)
        for t in range(2):
            key = jax.random.fold_in(jax.random.PRNGKey(5), t)
            od = default.step(st_d, V, key)
            oe = eager.step(st_e, V, key)
            st_d, st_e = od.state, oe.state
            np.testing.assert_array_equal(np.asarray(od.direction),
                                          np.asarray(oe.direction),
                                          err_msg=f"{name} step {t}")
            assert float(od.bits) == float(oe.bits), (name, t)


def test_packed_aggregator_compiled_equals_eager():
    """`packed_aggregator(compiled=True)` must reproduce the eager-codec
    aggregation bit-for-bit: direction AND measured bits."""
    from repro.comm import packed_aggregator

    V = jnp.stack([_grad(seed=31 + i) for i in range(M)])
    for name in ("mlmc_topk", "mlmc_topk_static", "qsgd", "ef21",
                 "mlmc_adaptive_topk", "signsgd"):
        fast = packed_aggregator(name, D, **CODEC_KW, compiled=True)
        slow = packed_aggregator(name, D, **CODEC_KW, compiled=False)
        st_f, st_s = fast.init(M, D), slow.init(M, D)
        for t in range(3):
            key = jax.random.fold_in(jax.random.PRNGKey(3), t)
            of = fast.step(st_f, V, key)
            os_ = slow.step(st_s, V, key)
            st_f, st_s = of.state, os_.state
            np.testing.assert_array_equal(np.asarray(of.direction),
                                          np.asarray(os_.direction),
                                          err_msg=f"{name} step {t}")
            assert float(of.bits) == float(os_.bits), (name, t)
