"""Multi-host TCP wire tests.

Fast tier: frame protocol, rendezvous/handshake failure paths, and the
socket star driven by threads inside one process (real localhost sockets,
no subprocess cost) — including full Trainer parity against the loopback
and abstract paths.

Slow tier: the real thing — ``multiprocessing`` *spawn* ranks, each with
its own fresh JAX runtime, training over localhost TCP and matching the
in-process paths bit-for-bit with *measured* (not simulated) stats.
"""

import socket
import struct
import threading

import numpy as np
import pytest

from repro.comm.multihost import (
    FRAME_HEADER_BYTES,
    HELLO_TOKEN,
    PAYLOAD,
    TcpStarTransport,
    WELCOME,
    is_multihost_transport,
    parse_coordinator,
    pick_free_port,
    recv_frame,
    send_frame,
)
from repro.comm.transport import LoopbackTransport, make_transport


def _sockets_available() -> bool:
    try:
        with socket.socket() as s:
            s.bind(("127.0.0.1", 0))
        return True
    except OSError:               # pragma: no cover - sandboxed environments
        return False


needs_sockets = pytest.mark.skipif(not _sockets_available(),
                                   reason="localhost sockets unavailable")

#: toy problem shared by the thread- and spawn-based parity tests (the
#: spawn children re-import this module, so keep everything module-level)
_TOY = dict(d=48, b=4, world=3, steps=4, seed=11, data_seed=7)


def _toy_trainer(transport, wire, method="mlmc_topk", **kw):
    import jax.numpy as jnp

    from repro.optim import sgd
    from repro.train import Trainer

    d = _TOY["d"]
    params = {"w": jnp.zeros((d,)), "b": jnp.zeros(())}

    def loss_fn(p, batch):
        return jnp.mean((batch["x"] @ p["w"] + p["b"] - batch["y"]) ** 2)

    return Trainer(loss_fn, params, num_workers=_TOY["world"],
                   method=method, optimizer=sgd(0.1), k_fraction=0.25,
                   wire=wire, transport=transport, **kw)


def _toy_batches():
    """The same deterministic global (world, b, d) stream on every rank."""
    import jax

    d, b, world = _TOY["d"], _TOY["b"], _TOY["world"]
    key = jax.random.PRNGKey(_TOY["data_seed"])
    wkey, key = jax.random.split(key)
    w_true = jax.random.normal(wkey, (d,))
    while True:
        key, kx = jax.random.split(key)
        x = jax.random.normal(kx, (world, b, d))
        yield {"x": x, "y": x @ w_true}


def _connect_world(world, timeout=15.0):
    """listen + thread-connect all worker ranks; returns {rank: transport}."""
    server = TcpStarTransport.listen(port=0, world=world, timeout=timeout)
    tps = {0: server}

    def join(r):
        tps[r] = TcpStarTransport.connect("127.0.0.1", server.port, rank=r,
                                          world=world, timeout=timeout)

    threads = [threading.Thread(target=join, args=(r,))
               for r in range(1, world)]
    for t in threads:
        t.start()
    server.accept_workers()
    for t in threads:
        t.join()
    return tps


# ---------------------------------------------------------------------------
# frame protocol
# ---------------------------------------------------------------------------


def test_frame_roundtrip_and_torn_frames():
    a, b = socket.socketpair()
    try:
        n = send_frame(a, PAYLOAD, 3, 8, b"hello bytes")
        assert n == FRAME_HEADER_BYTES + 11
        ftype, rank, world, payload = recv_frame(b)
        assert (ftype, rank, world, payload) == (PAYLOAD, 3, 8,
                                                 b"hello bytes")
        # a torn frame (peer dies mid-payload) must raise, not hang or
        # silently return short bytes
        hdr = struct.pack("<4sBBHI", b"RCMH", PAYLOAD, 1, 2, 100)
        a.sendall(hdr + b"only-part")
        a.close()
        with pytest.raises(ConnectionError, match="mid-frame"):
            recv_frame(b)
    finally:
        b.close()


def test_frame_bad_magic_and_unexpected_type():
    a, b = socket.socketpair()
    try:
        a.sendall(b"XXXX" + bytes(FRAME_HEADER_BYTES - 4))
        with pytest.raises(ConnectionError, match="bad frame magic"):
            recv_frame(b)
        send_frame(a, WELCOME, 0, 2)
        with pytest.raises(ConnectionError, match="expected frame type"):
            recv_frame(b, expect=PAYLOAD)
    finally:
        a.close()
        b.close()


def test_parse_coordinator():
    assert parse_coordinator("10.0.0.1:3000") == ("10.0.0.1", 3000)
    with pytest.raises(ValueError, match="host:port"):
        parse_coordinator("3000")
    with pytest.raises(ValueError, match="host:port"):
        parse_coordinator("host:")


# ---------------------------------------------------------------------------
# the socket star (threads, real localhost sockets)
# ---------------------------------------------------------------------------


@needs_sockets
def test_tcp_star_exchange_and_broadcast():
    world = 3
    tps = _connect_world(world)
    payloads = {0: b"rank0-payload", 1: b"w1" * 40, 2: b"w2" * 77}
    got = {}

    def worker_round(r):
        assert tps[r].exchange([payloads[r]]) == []
        got[r] = tps[r].broadcast_payload(None)

    threads = [threading.Thread(target=worker_round, args=(r,))
               for r in range(1, world)]
    for t in threads:
        t.start()
    delivered = tps[0].exchange([payloads[0]])
    assert delivered == [payloads[0], payloads[1], payloads[2]]  # rank order
    blob = b"direction" * 20
    assert tps[0].broadcast_payload(blob) == blob
    for t in threads:
        t.join()
    assert got[1] == blob and got[2] == blob

    st = tps[0].stats
    # bytes_up books payload bytes for ALL ranks (loopback semantics);
    # bytes_down books only the world-1 REAL socket sends, frame headers
    # included — rank 0's in-process copy of the direction never crosses
    # the wire and must not inflate downlink ratios
    assert st.rounds == 1
    assert st.bytes_up == sum(len(p) for p in payloads.values())
    assert st.bytes_down == (FRAME_HEADER_BYTES + len(blob)) * (world - 1)
    assert st.wire_bytes == sum(
        FRAME_HEADER_BYTES + len(payloads[r]) for r in (1, 2)) + \
        2 * (FRAME_HEADER_BYTES + len(blob))
    assert st.wall_time_s > 0 and st.sim_time_s == 0
    w1 = tps[1].stats
    assert w1.bytes_up == len(payloads[1])
    assert w1.bytes_down == len(blob)
    assert w1.wire_bytes == FRAME_HEADER_BYTES + len(payloads[1]) + \
        FRAME_HEADER_BYTES + len(blob)
    assert is_multihost_transport(tps[0])
    assert not is_multihost_transport(LoopbackTransport())
    for t in tps.values():
        t.close()


@needs_sockets
def test_tcp_handshake_rejects_world_mismatch():
    server = TcpStarTransport.listen(port=0, world=2, timeout=15)
    errors = {}

    def bad_then_good():
        try:
            TcpStarTransport.connect("127.0.0.1", server.port, rank=1,
                                     world=5, timeout=5)
        except ConnectionError as e:
            errors["bad"] = str(e)
        # the server must survive the refusal and accept a correct HELLO
        errors["good"] = TcpStarTransport.connect(
            "127.0.0.1", server.port, rank=1, world=2, timeout=10)

    t = threading.Thread(target=bad_then_good)
    t.start()
    server.accept_workers()
    t.join()
    assert "world mismatch" in errors["bad"]
    errors["good"].close()
    server.close()


@needs_sockets
def test_tcp_rendezvous_timeout():
    server = TcpStarTransport.listen(port=0, world=2, timeout=0.3)
    with pytest.raises(TimeoutError, match="rendezvous timed out"):
        server.accept_workers()


@needs_sockets
def test_tcp_rendezvous_survives_silent_peer():
    """A peer that connects but never HELLOs (port scanner, health check)
    gets a short grace and is refused — it must neither crash the
    rendezvous with a raw socket.timeout nor eat the whole deadline: a
    real worker arriving behind it still joins."""
    server = TcpStarTransport.listen(port=0, world=2, timeout=8.0)
    silent = socket.create_connection(("127.0.0.1", server.port))
    joined = {}

    def join():
        joined["w"] = TcpStarTransport.connect(
            "127.0.0.1", server.port, rank=1, world=2, timeout=8.0)

    t = threading.Thread(target=join)
    t.start()
    try:
        server.accept_workers()          # drops the probe, admits the worker
        t.join()
        assert 1 in server._conns
    finally:
        silent.close()
        joined["w"].close()
        server.close()


def test_tcp_transport_argument_errors():
    with pytest.raises(ValueError, match="worker rank"):
        TcpStarTransport.connect("127.0.0.1", 1, rank=0, world=2)
    with pytest.raises(ValueError, match="world must be"):
        TcpStarTransport.listen(world=1)
    with pytest.raises(TypeError, match="no simulated CostModel"):
        from repro.comm.topology import CostModel
        make_transport("tcp", cost=CostModel(), rank=0, world=2)
    with pytest.raises(ValueError, match="port 0"):
        make_transport("tcp", rank=0, world=2, coordinator="127.0.0.1:0")
    t = LoopbackTransport()
    with pytest.raises(ValueError):      # multihost seam is explicit
        from repro.comm import MultihostPackedAggregate, make_codec
        MultihostPackedAggregate(make_codec("dense", 8), t)


@needs_sockets
def test_tcp_exchange_requires_one_payload_per_rank():
    tps = _connect_world(2)
    with pytest.raises(ValueError, match="exactly one payload"):
        tps[0].exchange([b"a", b"b"])
    with pytest.raises(RuntimeError, match="broadcast_payload"):
        tps[0].broadcast(100, 2)
    for t in tps.values():
        t.close()


# ---------------------------------------------------------------------------
# aggregation + Trainer parity (threads)
# ---------------------------------------------------------------------------


@needs_sockets
def test_multihost_aggregate_matches_loopback_bitwise():
    import jax

    from repro.comm import PackedAggregate, make_codec
    from repro.core.aggregators import make_aggregator

    d, world = 129, 3
    rng = jax.random.PRNGKey(5)
    grads = jax.random.normal(jax.random.PRNGKey(1), (world, d))
    ref = PackedAggregate(make_codec("mlmc_topk", d, k_fraction=0.1, s=4))
    out_ref = ref(grads, rng)

    tps = _connect_world(world)
    outs = {}

    def run_rank(r):
        agg = make_aggregator("mlmc_topk", d, k_fraction=0.1, s=4,
                              wire="packed", transport=tps[r])
        outs[r] = agg(grads[r:r + 1], rng, None)

    threads = [threading.Thread(target=run_rank, args=(r,))
               for r in range(1, world)]
    for t in threads:
        t.start()
    run_rank(0)
    for t in threads:
        t.join()

    for r in range(world):
        assert np.array_equal(np.asarray(outs[r].direction),
                              np.asarray(out_ref.direction)), f"rank {r}"
        assert float(outs[r].bits) == float(out_ref.bits)
    # identical traffic books identical payload bytes on both transports
    assert tps[0].stats.bytes_up == ref.transport.stats.bytes_up
    # downlink is MEASURED and honest: only the world-1 real socket sends
    # of the direction blob (16-byte RCD1 header + 4*dim payload + frame
    # header each); loopback still models a bare 4*dim update per worker.
    # The documented relation: tcp books (world-1)/world of loopback's
    # payload volume, plus per-send blob+frame headers.
    blob = 16 + 4 * d
    assert tps[0].stats.bytes_down == (FRAME_HEADER_BYTES + blob) * (world - 1)
    assert ref.transport.stats.bytes_down == 4 * d * world
    per_send_overhead = FRAME_HEADER_BYTES + 16
    assert tps[0].stats.bytes_down == \
        (world - 1) * ref.transport.stats.bytes_down // world + \
        (world - 1) * per_send_overhead
    for t in tps.values():
        t.close()


@needs_sockets
@pytest.mark.parametrize("method", ["ef21", "ef21_sgdm",
                                    "mlmc_adaptive_topk"])
def test_multihost_stateful_matches_loopback_bitwise(method):
    """The stateful aggregators over tcp: rank 0 replicates every worker's
    decoded EF21 innovation into its (M, d) mirror (resp. each rank keeps
    its own EMA ladder row) and the per-step directions and measured bits
    equal the in-process loopback run BIT-FOR-BIT across multiple steps of
    evolving state — the ROADMAP follow-up this PR closes."""
    import jax

    from repro.core.aggregators import make_aggregator

    d, world, steps = 129, 3, 4
    grads = jax.random.normal(jax.random.PRNGKey(1), (world, d))
    kw = dict(k_fraction=0.1, s=4)

    ref = make_aggregator(method, d, **kw, wire="packed")
    st = ref.init(world, d)
    ref_outs = []
    for t in range(steps):
        o = ref.step(st, grads, jax.random.fold_in(jax.random.PRNGKey(5), t))
        st = o.state
        ref_outs.append(o)

    tps = _connect_world(world)
    outs = {}

    def run_rank(r):
        agg = make_aggregator(method, d, **kw, wire="packed",
                              transport=tps[r])
        state = agg.init(world, d)
        res = []
        for t in range(steps):
            o = agg.step(state, grads[r:r + 1],
                         jax.random.fold_in(jax.random.PRNGKey(5), t))
            state = o.state
            res.append(o)
        outs[r] = (res, state)

    threads = [threading.Thread(target=run_rank, args=(r,))
               for r in range(1, world)]
    for t in threads:
        t.start()
    run_rank(0)
    for t in threads:
        t.join()

    for r in range(world):
        res, state = outs[r]
        for t in range(steps):
            assert np.array_equal(np.asarray(res[t].direction),
                                  np.asarray(ref_outs[t].direction)), (r, t)
            assert float(res[t].bits) == float(ref_outs[t].bits), (r, t)
        assert int(state.step) == steps
    if method.startswith("ef21"):
        # server-side innovation replication: rank 0's FULL worker mirror
        # equals the loopback state bitwise; a worker rank owns its row
        srv_state = outs[0][1]
        assert np.array_equal(np.asarray(srv_state.g_workers),
                              np.asarray(st.g_workers))
        w1_state = outs[1][1]
        assert np.array_equal(np.asarray(w1_state.g_workers[1]),
                              np.asarray(st.g_workers[1]))
    assert tps[0].stats.bytes_up == ref.fn.transport.stats.bytes_up
    for t in tps.values():
        t.close()


@needs_sockets
@pytest.mark.parametrize("downlink", ["topk", "qsgd"])
def test_multihost_downlink_matches_loopback_bitwise(downlink):
    """Compressed downlink over tcp: rank 0 ships the DIANA-encoded
    direction on the DIRECTION_ENC frame, every rank decodes and updates
    its mirrored shift, and across multiple steps of evolving shift the
    directions, bits, and shift mirrors equal the in-process loopback run
    BIT-FOR-BIT — while booking strictly fewer downlink bytes than the
    raw f32 broadcast."""
    import jax

    from repro.core.aggregators import make_aggregator

    d, world, steps = 129, 3, 4
    grads = jax.random.normal(jax.random.PRNGKey(1), (world, d))
    kw = dict(k_fraction=0.1, s=4, downlink=downlink, wire="packed")

    ref = make_aggregator("mlmc_topk", d, **kw)
    st = ref.init(world, d)
    ref_outs = []
    for t in range(steps):
        o = ref.step(st, grads, jax.random.fold_in(jax.random.PRNGKey(5), t))
        st = o.state
        ref_outs.append(o)
    assert bool(np.any(np.asarray(st.shift) != 0.0))

    tps = _connect_world(world)
    outs = {}

    def run_rank(r):
        agg = make_aggregator("mlmc_topk", d, transport=tps[r], **kw)
        state = agg.init(world, d)
        res = []
        for t in range(steps):
            o = agg.step(state, grads[r:r + 1],
                         jax.random.fold_in(jax.random.PRNGKey(5), t))
            state = o.state
            res.append(o)
        outs[r] = (res, state)

    threads = [threading.Thread(target=run_rank, args=(r,))
               for r in range(1, world)]
    for t in threads:
        t.start()
    run_rank(0)
    for t in threads:
        t.join()

    for r in range(world):
        res, state = outs[r]
        for t in range(steps):
            assert np.array_equal(np.asarray(res[t].direction),
                                  np.asarray(ref_outs[t].direction)), (r, t)
            assert float(res[t].bits) == float(ref_outs[t].bits), (r, t)
        # every rank's shift mirror equals loopback's bitwise
        assert np.array_equal(np.asarray(state.shift), np.asarray(st.shift)), r
        assert int(state.step) == steps
    # honest compression: tcp downlink bytes strictly below the raw f32
    # broadcast's would-be booking under the same world-1 send accounting
    raw_down = (FRAME_HEADER_BYTES + 16 + 4 * d) * (world - 1) * steps
    assert 0 < tps[0].stats.bytes_down < raw_down
    assert tps[0].stats.bytes_up == ref.fn.transport.stats.bytes_up
    for t in tps.values():
        t.close()


@needs_sockets
def test_server_fanin_interleaves_slow_rank():
    """Fan-in concurrency regression (ROADMAP follow-up): rank 0 drains
    uplinks through a selectors reactor, so a slow rank 1 no longer
    serializes ranks 2..M — their frames complete FIRST even though the
    old code read rank-by-rank in rank order."""
    import time as _time

    world = 4
    tps = _connect_world(world)
    delay = 0.5

    def worker_round(r):
        if r == 1:
            _time.sleep(delay)      # the straggler
        tps[r].exchange([bytes([r]) * 64])
        tps[r].broadcast_payload(None)

    threads = [threading.Thread(target=worker_round, args=(r,))
               for r in range(1, world)]
    t0 = _time.monotonic()
    for t in threads:
        t.start()
    delivered = tps[0].exchange([b"rank0" * 8])
    elapsed = _time.monotonic() - t0
    tps[0].broadcast_payload(b"done")
    for t in threads:
        t.join()

    assert delivered[1] == bytes([1]) * 64 and delivered[3] == bytes([3]) * 64
    # the fast ranks' frames completed before the straggler's
    order = tps[0].last_arrival_order
    assert set(order) == {1, 2, 3}
    assert order[-1] == 1, f"straggler should arrive last, got {order}"
    assert set(order[:2]) == {2, 3}, order
    # and the round still only costs ~the straggler's delay
    assert elapsed < delay + 2.0
    for t in tps.values():
        t.close()


@needs_sockets
def test_multihost_trainer_matches_loopback_and_abstract():
    """The acceptance check, fast tier: a threaded 3-rank TCP world trains
    the toy problem and every rank's params equal the loopback-packed run
    BIT-FOR-BIT, with measured bytes matching loopback.  Against the
    abstract wire the repo's own guarantee is allclose, not bitwise (the
    fully-jitted abstract step fuses the mean differently — see
    test_packed_aggregator_matches_abstract), and tcp inherits exactly
    that bound because it IS the packed path."""
    ref_packed = _toy_trainer(None, "packed")          # loopback
    hist_ref = ref_packed.fit(_toy_batches(), steps=_TOY["steps"],
                              seed=_TOY["seed"])
    ref_abstract = _toy_trainer(None, "abstract")
    ref_abstract.fit(_toy_batches(), steps=_TOY["steps"], seed=_TOY["seed"])

    world = _TOY["world"]
    tps = _connect_world(world)
    results = {}

    def run_rank(r):
        tr = _toy_trainer(tps[r], "packed")
        hist = tr.fit(_toy_batches(), steps=_TOY["steps"], seed=_TOY["seed"])
        results[r] = (np.asarray(tr.flat_params), hist.bits[-1], hist.loss)

    threads = [threading.Thread(target=run_rank, args=(r,))
               for r in range(1, world)]
    for t in threads:
        t.start()
    run_rank(0)
    for t in threads:
        t.join()

    want = np.asarray(ref_packed.flat_params)
    np.testing.assert_allclose(want, np.asarray(ref_abstract.flat_params),
                               rtol=1e-5, atol=1e-6)
    for r in range(world):
        got, bits, losses = results[r]
        assert np.array_equal(got, want), f"rank {r} params diverged"
        assert bits == hist_ref.bits[-1]
        # loss telemetry is the GLOBAL mean on every rank (f64-allreduced,
        # so allclose to — not bitwise with — the in-process f32 mean)
        assert losses == results[0][2], f"rank {r} loss curve diverged"
        np.testing.assert_allclose(losses, hist_ref.loss, rtol=1e-6)
    assert tps[0].stats.bytes_up == ref_packed.transport.stats.bytes_up
    assert tps[0].stats.wall_time_s > 0
    assert tps[0].stats.sim_time_s == 0
    for t in tps.values():
        t.close()


# ---------------------------------------------------------------------------
# the real thing: spawned OS processes (slow tier)
# ---------------------------------------------------------------------------


def _tcp_rank_main(rank, port, q, method="mlmc_topk"):
    """Entry point of one spawned rank (own process, fresh JAX runtime)."""
    try:
        from repro.comm import make_transport as mk

        transport = mk("tcp", rank=rank, world=_TOY["world"],
                       coordinator=f"127.0.0.1:{port}", timeout=120.0)
        tr = _toy_trainer(transport, "packed", method)
        hist = tr.fit(_toy_batches(), steps=_TOY["steps"], seed=_TOY["seed"])
        st = transport.stats
        q.put((rank, np.asarray(tr.flat_params).tobytes(), hist.bits[-1],
               st.bytes_up, st.wall_time_s, st.sim_time_s, hist.loss[-1],
               None))
        transport.close()
    except Exception as e:        # pragma: no cover - surfaced by the parent
        q.put((rank, None, 0.0, 0, 0.0, 0.0, 0.0, repr(e)))


@pytest.mark.slow
@needs_sockets
@pytest.mark.parametrize("method", ["mlmc_topk", "ef21",
                                    "mlmc_adaptive_topk"])
def test_tcp_spawned_processes_train_in_parity(method):
    """2+ OS processes (multiprocessing spawn) train over localhost TCP:
    every rank's final params match the in-process loopback run
    bit-for-bit, the server's measured bytes_up matches loopback, and the
    clock is measured wall time (sim_time stays 0).  Covers a stateless
    method AND the stateful families (EF21 server-side innovation
    replication; the adaptive EMA ladder) — the 3-rank spawn half of the
    stateful cross-wire parity matrix."""
    import multiprocessing as mp

    ref = _toy_trainer(None, "packed", method)
    hist_ref = ref.fit(_toy_batches(), steps=_TOY["steps"],
                       seed=_TOY["seed"])
    want = np.asarray(ref.flat_params).tobytes()

    ctx = mp.get_context("spawn")
    port = pick_free_port()
    q = ctx.Queue()
    procs = [ctx.Process(target=_tcp_rank_main, args=(r, port, q, method))
             for r in range(_TOY["world"])]
    for p in procs:
        p.start()
    try:
        results = {}
        for _ in range(_TOY["world"]):
            (rank, params, bits, bytes_up, wall, sim, loss,
             err) = q.get(timeout=300)
            assert err is None, f"rank {rank} failed: {err}"
            results[rank] = (params, bits, bytes_up, wall, sim, loss)
        for p in procs:
            p.join(timeout=60)
    finally:
        for p in procs:
            if p.is_alive():      # pragma: no cover - cleanup on failure
                p.terminate()

    assert set(results) == set(range(_TOY["world"]))
    for rank, (params, bits, bytes_up, wall, sim, loss) in results.items():
        assert params == want, f"rank {rank} params diverged from loopback"
        assert bits == hist_ref.bits[-1]
        assert wall > 0 and sim == 0, "tcp stats must be measured, not modeled"
        assert loss == results[0][5], f"rank {rank} loss telemetry diverged"
        np.testing.assert_allclose(loss, hist_ref.loss[-1], rtol=1e-6)
    # the server saw every rank's payload: measured == loopback accounting
    assert results[0][2] == ref.transport.stats.bytes_up


def test_launch_world_rejects_reserved_flags_in_any_form():
    from repro.launch.multihost import launch_world

    for bad in (["--rank", "1"], ["--rank=1"], ["--world=4"],
                ["--steps", "2", "--wire=packed"]):
        with pytest.raises(ValueError, match="set by the launcher"):
            launch_world(2, bad)


# ---------------------------------------------------------------------------
# STATE frame: rank-0 checkpoints capture every rank's CommState rows
# ---------------------------------------------------------------------------


def test_comm_state_row_roundtrip_and_errors():
    import struct

    from repro.comm.aggregate import (
        _STATE_FMT,
        _STATE_MAGIC,
        _STATE2_HEADER_BYTES,
        fold_comm_state_rows,
        pack_comm_state_row,
        unpack_comm_state_row,
    )
    from repro.core.aggregators import make_aggregator

    d, world = 32, 3
    agg = make_aggregator("ef21_sgdm", d, k_fraction=0.25, wire="packed")
    st = agg.init(world, d)
    # give rank 1 a distinctive momentum row, then round-trip it (the
    # ladder stays the family's empty (0, 0) placeholder)
    st = st._replace(
        momentum=st.momentum.at[1].set(np.arange(d, dtype=np.float32)))
    raw = pack_comm_state_row(st, 1)
    r, ladder, momentum, shift = unpack_comm_state_row(raw)
    assert (r, ladder.size, shift.size) == (1, 0, 0)
    assert np.array_equal(momentum, np.asarray(st.momentum[1]))
    # folding rank 1's row into a FRESH state reproduces it bitwise
    fresh = fold_comm_state_rows(agg.init(world, d), [raw])
    assert np.array_equal(np.asarray(fresh.momentum[1]),
                          np.asarray(st.momentum[1]))
    # same round-trip for the adaptive family's EMA ladder row
    adaptive = make_aggregator("mlmc_adaptive_topk", d, k_fraction=0.25,
                               wire="packed")
    ast = adaptive.init(world, d)
    ast = ast._replace(ladder_ema=ast.ladder_ema.at[1].add(0.5))
    r, ladder, momentum, shift = unpack_comm_state_row(
        pack_comm_state_row(ast, 1))
    assert (r, momentum.size, shift.size) == (1, 0, 0)
    assert np.array_equal(ladder, np.asarray(ast.ladder_ema[1]))
    afresh = fold_comm_state_rows(
        adaptive.init(world, d), [pack_comm_state_row(ast, 1)])
    assert np.array_equal(np.asarray(afresh.ladder_ema[1]),
                          np.asarray(ast.ladder_ema[1]))
    # downlink shift mirrors ride the RCS2 row; the fold validates them
    # against rank 0's copy (every rank must hold the identical shift)
    dl = make_aggregator("mlmc_topk", d, k_fraction=0.25, wire="packed",
                         downlink="topk")
    import jax.numpy as jnp

    dst = dl.init(world, d)._replace(
        shift=jnp.asarray(np.linspace(-1.0, 1.0, d), jnp.float32))
    r, ladder, momentum, shift = unpack_comm_state_row(
        pack_comm_state_row(dst, 2))
    assert (r, ladder.size, momentum.size) == (2, 0, 0)
    assert np.array_equal(shift, np.asarray(dst.shift))
    folded = fold_comm_state_rows(dst, [pack_comm_state_row(dst, 2)])
    assert np.array_equal(np.asarray(folded.shift), np.asarray(dst.shift))
    diverged = dst._replace(shift=dst.shift.at[0].add(1.0))
    with pytest.raises(ValueError, match="diverged"):
        fold_comm_state_rows(dst, [pack_comm_state_row(diverged, 2)])
    # rows for a method with no client-side state are empty but valid
    stateless = make_aggregator("mlmc_topk", d, k_fraction=0.25,
                                wire="packed").init(world, d)
    empty = pack_comm_state_row(stateless, 2)
    assert len(empty) == _STATE2_HEADER_BYTES
    r, ladder, momentum, shift = unpack_comm_state_row(empty)
    assert (r, ladder.size, momentum.size, shift.size) == (2, 0, 0, 0)
    # legacy RCS1 rows (pre-downlink checkpoints) still read back
    mom = np.asarray(st.momentum[1], np.float32)
    legacy = struct.pack(_STATE_FMT, _STATE_MAGIC, 1, 0, mom.size) + \
        mom.tobytes()
    r, ladder, momentum, shift = unpack_comm_state_row(legacy)
    assert (r, ladder.size, shift.size) == (1, 0, 0)
    assert np.array_equal(momentum, mom)
    with pytest.raises(ValueError, match="truncated STATE row"):
        unpack_comm_state_row(raw[:4])
    with pytest.raises(ValueError, match="bad STATE magic"):
        unpack_comm_state_row(b"XXXX" + raw[4:])
    with pytest.raises(ValueError, match="expected"):
        unpack_comm_state_row(raw + b"extra")
    # a row whose width doesn't fit the target state is rejected
    wrong = make_aggregator("ef21_sgdm", d + 1, k_fraction=0.25,
                            wire="packed").init(world, d + 1)
    with pytest.raises(ValueError, match="does not fit"):
        fold_comm_state_rows(wrong, [raw])


@needs_sockets
def test_gather_state_rank_ordered():
    """The STATE-frame collective: rank 0 receives [own, rank1, .., rankN]
    in rank order regardless of arrival order; workers get []."""
    world = 3
    tps = _connect_world(world)
    rows = {r: f"row-{r}".encode() * (r + 1) for r in range(world)}
    out = {}

    def worker(r):
        out[r] = tps[r].gather_state(rows[r])

    threads = [threading.Thread(target=worker, args=(r,))
               for r in range(1, world)]
    for t in threads:
        t.start()
    got = tps[0].gather_state(rows[0])
    for t in threads:
        t.join()
    assert got == [rows[0], rows[1], rows[2]]
    assert out[1] == [] and out[2] == []
    # checkpoint plumbing is booked as wire bytes, not gradient payload
    assert tps[0].stats.wire_bytes == sum(
        FRAME_HEADER_BYTES + len(rows[r]) for r in (1, 2))
    assert tps[0].stats.bytes_up == 0 and tps[0].stats.rounds == 0
    for t in tps.values():
        t.close()


@needs_sockets
@pytest.mark.parametrize("method", ["ef21_sgdm", "mlmc_adaptive_topk"])
def test_sync_comm_state_completes_rank0_state(method):
    """After training over tcp, each rank holds only ITS OWN client-side
    rows (EMA ladder / SGDM momentum).  `Trainer.sync_comm_state` gathers
    them over the STATE frame: rank 0's folded CommState must equal the
    loopback run's full state BITWISE — the checkpoint-completeness gap
    this PR closes."""
    ref = _toy_trainer(None, "packed", method)
    ref.fit(_toy_batches(), steps=_TOY["steps"], seed=_TOY["seed"])

    world = _TOY["world"]
    tps = _connect_world(world)
    states = {}

    def run_rank(r):
        tr = _toy_trainer(tps[r], "packed", method)
        tr.fit(_toy_batches(), steps=_TOY["steps"], seed=_TOY["seed"])
        states[r] = tr.sync_comm_state()

    threads = [threading.Thread(target=run_rank, args=(r,))
               for r in range(1, world)]
    for t in threads:
        t.start()
    run_rank(0)
    for t in threads:
        t.join()

    want = ref.comm_state
    got = states[0]
    assert np.array_equal(np.asarray(got.ladder_ema),
                          np.asarray(want.ladder_ema))
    assert np.array_equal(np.asarray(got.momentum),
                          np.asarray(want.momentum))
    if method.startswith("ef21"):
        assert np.array_equal(np.asarray(got.g_workers),
                              np.asarray(want.g_workers))
    # a worker's state is unchanged by the gather (it only ships its row)
    if method == "mlmc_adaptive_topk":
        assert np.array_equal(np.asarray(states[1].ladder_ema[1]),
                              np.asarray(want.ladder_ema[1]))
    for t in tps.values():
        t.close()


def _tcp_ckpt_rank_main(rank, port, q, method, ckpt_path):
    """Spawned rank: phase-A training + STATE-frame sync + rank-0 save."""
    try:
        from repro.comm import make_transport as mk

        transport = mk("tcp", rank=rank, world=_TOY["world"],
                       coordinator=f"127.0.0.1:{port}", timeout=120.0)
        tr = _toy_trainer(transport, "packed", method)
        tr.fit(_toy_batches(), steps=_TOY["steps"], seed=_TOY["seed"])
        tr.sync_comm_state()
        if rank == 0:
            tr.save_checkpoint(ckpt_path)
        transport.close()
        q.put((rank, None))
    except Exception as e:        # pragma: no cover - surfaced by the parent
        q.put((rank, repr(e)))


@pytest.mark.slow
@needs_sockets
@pytest.mark.parametrize("method", ["ef21_sgdm", "mlmc_adaptive_topk"])
def test_tcp_checkpoint_restores_and_continues_bitwise(method, tmp_path):
    """The acceptance check: a 3-rank SPAWNED tcp world trains phase A,
    syncs CommState over the STATE frame, and rank 0 checkpoints; a fresh
    in-process trainer restores that bundle and continues phase B,
    matching an uninterrupted loopback run BIT-FOR-BIT.  Without the
    gathered worker rows the restored EMA ladder / momentum would re-seed
    and the continuation would diverge."""
    import itertools
    import multiprocessing as mp

    steps, seed = _TOY["steps"], _TOY["seed"]
    ref = _toy_trainer(None, "packed", method)
    stream = _toy_batches()
    ref.fit(stream, steps=steps, seed=seed)
    phase_a_ladder = np.asarray(ref.comm_state.ladder_ema).copy()
    phase_a_momentum = np.asarray(ref.comm_state.momentum).copy()
    ref.fit(stream, steps=steps, seed=seed + 1)      # phase B, same stream
    want = np.asarray(ref.flat_params).tobytes()

    ckpt = str(tmp_path / "world.npz")
    ctx = mp.get_context("spawn")
    port = pick_free_port()
    q = ctx.Queue()
    procs = [ctx.Process(target=_tcp_ckpt_rank_main,
                         args=(r, port, q, method, ckpt))
             for r in range(_TOY["world"])]
    for p in procs:
        p.start()
    try:
        for _ in range(_TOY["world"]):
            rank, err = q.get(timeout=300)
            assert err is None, f"rank {rank} failed: {err}"
        for p in procs:
            p.join(timeout=60)
    finally:
        for p in procs:
            if p.is_alive():      # pragma: no cover - cleanup on failure
                p.terminate()

    resumed = _toy_trainer(None, "packed", method)
    resumed.load_checkpoint(ckpt)
    # the restored CommState holds EVERY rank's rows, bitwise
    assert np.array_equal(np.asarray(resumed.comm_state.ladder_ema),
                          phase_a_ladder)
    assert np.array_equal(np.asarray(resumed.comm_state.momentum),
                          phase_a_momentum)
    cont = _toy_batches()
    resumed.fit(itertools.islice(cont, steps, None), steps=steps,
                seed=seed + 1)
    assert np.asarray(resumed.flat_params).tobytes() == want


def _tcp_downlink_rank_main(rank, port, q, ckpt_path):
    """Spawned rank: compressed-downlink phase-A training + STATE sync +
    rank-0 save; reports final params so the parent checks cross-rank
    parity."""
    try:
        from repro.comm import make_transport as mk

        transport = mk("tcp", rank=rank, world=_TOY["world"],
                       coordinator=f"127.0.0.1:{port}", timeout=120.0)
        tr = _toy_trainer(transport, "packed", downlink="topk")
        tr.fit(_toy_batches(), steps=_TOY["steps"], seed=_TOY["seed"])
        tr.sync_comm_state()
        if rank == 0:
            tr.save_checkpoint(ckpt_path)
        params = np.asarray(tr.flat_params).tobytes()
        shift = np.asarray(tr.comm_state.shift).tobytes()
        down = transport.stats.bytes_down
        transport.close()
        q.put((rank, None, params, shift, down))
    except Exception as e:        # pragma: no cover - surfaced by the parent
        q.put((rank, repr(e), None, None, 0))


@pytest.mark.slow
@needs_sockets
def test_tcp_downlink_checkpoint_restores_and_continues_bitwise(tmp_path):
    """The compressed-downlink acceptance check: a 3-rank SPAWNED tcp
    world trains with the DIANA-shift downlink, every rank's params AND
    shift mirror equal the loopback run bit-for-bit, rank 0's checkpoint
    carries the shift via the STATE frame, and a restored trainer
    continues phase B matching an uninterrupted loopback run exactly.
    The tcp downlink also books measurably fewer bytes than the raw f32
    broadcast would."""
    import itertools
    import multiprocessing as mp

    steps, seed, world = _TOY["steps"], _TOY["seed"], _TOY["world"]
    ref = _toy_trainer(None, "packed", downlink="topk")
    stream = _toy_batches()
    ref.fit(stream, steps=steps, seed=seed)
    phase_a_params = np.asarray(ref.flat_params).tobytes()
    phase_a_shift = np.asarray(ref.comm_state.shift).copy()
    assert bool(np.any(phase_a_shift != 0.0))
    ref.fit(stream, steps=steps, seed=seed + 1)      # phase B, same stream
    want = np.asarray(ref.flat_params).tobytes()

    ckpt = str(tmp_path / "downlink.npz")
    ctx = mp.get_context("spawn")
    port = pick_free_port()
    q = ctx.Queue()
    procs = [ctx.Process(target=_tcp_downlink_rank_main,
                         args=(r, port, q, ckpt))
             for r in range(world)]
    for p in procs:
        p.start()
    try:
        downs = {}
        for _ in range(world):
            rank, err, params, shift, down = q.get(timeout=300)
            assert err is None, f"rank {rank} failed: {err}"
            assert params == phase_a_params, f"rank {rank} params diverged"
            assert shift == phase_a_shift.tobytes(), \
                f"rank {rank} shift mirror diverged"
            downs[rank] = down
        for p in procs:
            p.join(timeout=60)
    finally:
        for p in procs:
            if p.is_alive():      # pragma: no cover - cleanup on failure
                p.terminate()

    # honest, compressed downlink booking on the real wire
    from repro.comm.multihost import FRAME_HEADER_BYTES

    raw_down = (FRAME_HEADER_BYTES + 16 + 4 * _TOY["d"]) * (world - 1) * steps
    assert 0 < downs[0] < raw_down

    resumed = _toy_trainer(None, "packed", downlink="topk")
    resumed.load_checkpoint(ckpt)
    assert np.array_equal(np.asarray(resumed.comm_state.shift),
                          phase_a_shift)
    cont = _toy_batches()
    resumed.fit(itertools.islice(cont, steps, None), steps=steps,
                seed=seed + 1)
    assert np.asarray(resumed.flat_params).tobytes() == want
