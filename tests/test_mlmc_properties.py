"""Property-based tests of the MLMC estimator — the paper's lemmas as
executable invariants (hypothesis-driven where the space is continuous).

Key trick: Lemma 3.2's unbiasedness can be checked EXACTLY (no Monte Carlo):
``E[g~] = sum_l p_l (base + residual_l / p_l) = base + sum_l residual_l = v``
by the telescoping property, for ANY valid level distribution.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -r "
                         "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    FixedPointMultilevel,
    FloatingPointMultilevel,
    RTNMultilevel,
    STopKMultilevel,
    adaptive_probs,
    mlmc_estimate,
    mlmc_second_moment,
    optimal_second_moment,
)

jax.config.update("jax_platform_name", "cpu")


def _families(d):
    return [STopKMultilevel(d=d, s=1), STopKMultilevel(d=d, s=4),
            FixedPointMultilevel(num_bits=12),
            FloatingPointMultilevel(num_bits=12), RTNMultilevel(num_bits=6)]


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-100.0, 100.0), min_size=8, max_size=48),
       st.integers(0, 2**31 - 1))
def test_lemma_3_2_exact_unbiasedness(vals, seed):
    """sum_l p_l * estimate_l == v exactly, for arbitrary vectors and for
    both adaptive (Alg. 3) and static (Alg. 2) level distributions."""
    v = jnp.asarray(vals, jnp.float32)
    if not bool(jnp.all(jnp.isfinite(v))):
        return
    for comp in _families(v.shape[0]):
        for probs in (None, adaptive_probs(comp, v)):
            p = comp.static_probs() if probs is None else probs
            p = p / jnp.sum(p)
            mean = np.asarray(comp.base(v), np.float64).copy()
            for l in range(1, comp.num_levels + 1):
                resid = np.asarray(comp.residual(v, l))
                if float(p[l - 1]) == 0.0:
                    # Lemma 3.4's optimum zeroes p_l exactly when Delta_l = 0;
                    # such levels carry no mass AND no residual.
                    np.testing.assert_allclose(resid, 0.0, atol=1e-6)
                    continue
                mean += float(p[l - 1]) * (resid / float(p[l - 1]))
            np.testing.assert_allclose(mean, np.asarray(v),
                                       atol=5e-4 * (1 + float(jnp.max(jnp.abs(v)))))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_lemma_3_2_monte_carlo(seed):
    """MC sanity: the sampled estimator's mean converges to v."""
    key = jax.random.PRNGKey(seed % 1000)
    v = jax.random.normal(key, (32,)) * jnp.exp(
        -0.2 * jnp.arange(32, dtype=jnp.float32))
    comp = STopKMultilevel(d=32, s=4)
    keys = jax.random.split(jax.random.PRNGKey(seed % 997), 2000)
    est = jax.vmap(
        lambda k: mlmc_estimate(comp, v, k, adaptive=True).estimate)(keys)
    rel = float(jnp.linalg.norm(est.mean(0) - v) / jnp.linalg.norm(v))
    assert rel < 0.15


def test_second_moment_closed_form_matches_mc():
    """E||g~||^2 == sum_l Delta_l^2/p_l (Eq. 48) — MC cross-check."""
    v = jax.random.normal(jax.random.PRNGKey(0), (24,))
    comp = STopKMultilevel(d=24, s=3)
    probs = adaptive_probs(comp, v)
    keys = jax.random.split(jax.random.PRNGKey(1), 4000)
    sq = jax.vmap(lambda k: jnp.sum(
        mlmc_estimate(comp, v, k, adaptive=True).estimate ** 2))(keys)
    closed = float(mlmc_second_moment(comp, v, probs))
    assert abs(float(sq.mean()) - closed) / closed < 0.1


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_lemma_3_4_optimality(seed):
    """The adaptive distribution minimizes sum_l Delta_l^2 / p_l: any other
    random distribution gives a second moment >= the optimum (Eq. 54)."""
    key = jax.random.PRNGKey(seed % 4096)
    k1, k2 = jax.random.split(key)
    v = jax.random.normal(k1, (40,)) * jnp.exp(
        -0.1 * jnp.arange(40, dtype=jnp.float32))
    comp = STopKMultilevel(d=40, s=5)
    opt = float(optimal_second_moment(comp, v))
    # check the closed form too
    np.testing.assert_allclose(
        opt, float(mlmc_second_moment(comp, v, adaptive_probs(comp, v))),
        rtol=1e-4)
    other = jax.random.dirichlet(k2, jnp.ones((comp.num_levels,)))
    alt = float(mlmc_second_moment(comp, v, other))
    assert alt >= opt - 1e-4 * opt


def test_lemma_3_4_stopk_reduction():
    """For s-Top-k: p_l ∝ sqrt(alpha_l - alpha_{l-1}) (the Lemma 3.4
    reduction via Eq. 59)."""
    v = jax.random.normal(jax.random.PRNGKey(5), (48,))
    comp = STopKMultilevel(d=48, s=6)
    p = np.asarray(adaptive_probs(comp, v))
    alphas = np.concatenate([[0.0], np.asarray(comp.alphas(v))])
    want = np.sqrt(np.maximum(np.diff(alphas), 0))
    want = want / want.sum()
    np.testing.assert_allclose(p, want, atol=1e-5)


def test_lemma_3_3_fixed_point_optimal_probs():
    """p_l = 2^-l/(1-2^-L): verify it beats perturbations on the worst-case
    objective sum_l 2^-2l / p_l (the Lemma's optimization problem)."""
    L = 12
    comp = FixedPointMultilevel(num_bits=L)
    p_star = np.asarray(comp.static_probs())
    np.testing.assert_allclose(p_star.sum(), 1.0, rtol=1e-6)
    obj = lambda p: float(np.sum(4.0 ** -np.arange(1, L + 1) / p))
    base = obj(p_star)
    rng = np.random.default_rng(0)
    for _ in range(25):
        q = p_star * np.exp(0.3 * rng.standard_normal(L))
        q = q / q.sum()
        assert obj(q) >= base - 1e-9


def test_lemma_3_6_variance_scaling():
    """Under exponential decay |v_j| = e^{-rj/2}, the adaptive MLMC s-Top-k
    compression variance is O(1/(r s)) * ||v||^2 — check the 4/(rs)-1 form
    (Eq. 75) and that it beats Rand-k's (d/s - 1) factor when 1/r < d."""
    d, s = 4096, 32
    # the paper's approximation holds in the r*s <= 1 regime (App. E:
    # "we consider s such that s * r_{t,i} <= 1") with r*d >> 1
    for r in [0.005, 0.01, 0.03]:
        assert r * s <= 1.0 and r * d > 1.0
        v = jnp.exp(-r / 2 * jnp.arange(d, dtype=jnp.float32))
        comp = STopKMultilevel(d=d, s=s)
        var = float(optimal_second_moment(comp, v) - jnp.sum(v * v))
        norm2 = float(jnp.sum(v * v))
        predicted = (4.0 / (r * s) - 1.0) * norm2
        assert var <= predicted * 1.2 + 1e-6, (r, var, predicted)
        randk_var = (d / s - 1.0) * norm2
        assert var < randk_var
    # outside the approximation regime the Rand-k dominance still holds
    v = jnp.exp(-0.05 * jnp.arange(d, dtype=jnp.float32))
    comp = STopKMultilevel(d=d, s=s)
    var = float(optimal_second_moment(comp, v) - jnp.sum(v * v))
    assert var < (d / s - 1.0) * float(jnp.sum(v * v))


def test_payload_bits_accounting():
    from repro.core import bits as bc

    d = 10000
    assert bc.fixed_point_mlmc_bits(d) == 2 * d + 64 + 6
    assert bc.floating_point_mlmc_bits(d) == pytest.approx(
        13 * d + np.log2(52))
    assert bc.dense_bits(d, 64) == 64 * d
    assert bc.compression_ratio(bc.fixed_point_mlmc_bits(d), d, 64) == (
        pytest.approx(32, rel=0.01))  # the paper's x32 headline
