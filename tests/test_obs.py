"""`repro.obs` — the comm-stack telemetry subsystem.

Pins the subsystem's design constraints:

* recording units (spans / counters / histograms / MLMC estimator
  telemetry) and their thread-safety + boundedness;
* exporters: JSONL round-trip, Chrome trace-event JSON (per-rank
  process tracks), Prometheus text, and the checked-in append-only
  trace-event schema;
* statistical fidelity — the level-draw histogram recorded from real
  packed-wire rounds matches the theoretical ``p_l`` ladder
  (Lemma 3.3) within sampling error;
* ZERO cost when disabled: the disabled path adds no jit lowerings to
  the PR-5 retrace-guard harness, and the ENABLED path (sample_every=1,
  so every expensive estimator metric fires) adds none either — all
  recording is host-side Python.
"""

import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import jax._src.test_util as jtu

from repro.obs import export
from repro.obs.metrics import (
    DEFAULT_LATENCY_BUCKETS,
    Histogram,
    MetricsRegistry,
    MLMCTelemetry,
)
from repro.obs.trace import _NULL_SPAN, SpanRecorder, Telemetry
from repro.obs import trace as obs

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(autouse=True)
def _restore_active_telemetry():
    """Never leak an installed bundle into other test modules."""
    yield
    obs.install(None)


# ---------------------------------------------------------------------------
# recording units
# ---------------------------------------------------------------------------


def test_disabled_telemetry_is_inert():
    tel = Telemetry(enabled=False)
    assert tel.span("x") is _NULL_SPAN      # one shared null context manager
    with tel.span("x", codec="topk"):
        pass
    tel.instant("i", v=1)
    tel.count("c", 2.0)
    tel.observe("h", 0.5)
    tel.gauge("g", 3.0)
    assert not tel.should_sample("k") and not tel.should_sample("k")
    assert tel.trace.events() == []
    assert tel.metrics.snapshot() == []
    # the module default is a disabled singleton; install(None) restores it
    assert obs.active() is obs._DISABLED
    assert not obs.enabled()
    installed = obs.install(Telemetry())
    assert obs.active() is installed and obs.enabled()
    obs.install(None)
    assert obs.active() is obs._DISABLED


def test_span_recorder_event_shapes():
    rec = SpanRecorder(pid=3)
    with rec.span("comm/encode", codec="topk"):
        pass
    import time
    rec.complete("comm/decode", time.perf_counter(), cat="comm", n=2)
    rec.instant("wire/frame_arrival", rank=1)
    rec.counter("wire_bytes", 128.0)
    evs = rec.events()
    assert [e["ph"] for e in evs] == ["X", "X", "i", "C"]
    span = evs[0]
    assert span["name"] == "comm/encode" and span["pid"] == 3
    assert span["dur"] >= 0 and span["args"] == {"codec": "topk"}
    assert evs[2]["s"] == "t" and evs[2]["args"] == {"rank": 1}
    assert evs[3]["args"] == {"value": 128.0}
    # everything is JSON-serializable as recorded
    json.dumps(evs)
    assert export.validate_events(evs) == []
    rec.clear()
    assert rec.events() == [] and rec.dropped == 0


def test_span_recorder_bounded_buffer_counts_drops():
    rec = SpanRecorder(max_events=3)
    for i in range(5):
        rec.instant(f"e{i}")
    assert len(rec.events()) == 3 and rec.dropped == 2


def test_span_recorder_thread_ids_are_stable_and_distinct():
    rec = SpanRecorder()
    main_tid = rec._tid()
    assert rec._tid() == main_tid
    seen = {}
    gate = threading.Barrier(3)    # concurrent threads: no ident reuse

    def worker(k):
        seen[k] = rec._tid()
        gate.wait()

    ts = [threading.Thread(target=worker, args=(k,)) for k in range(3)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(set(seen.values()) | {main_tid}) == 4


def test_metrics_registry_labels_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("wire_bytes_up", transport="tcp").add(10)
    reg.counter("wire_bytes_up", transport="tcp").add(5)
    reg.counter("wire_bytes_up", transport="loopback").add(1)
    reg.gauge("train_loss").set(0.25)
    reg.histogram("lat", buckets=(0.1, 1.0)).observe(0.5)
    snap = {(m["kind"], m["name"], tuple(sorted(m["labels"].items()))): m
            for m in reg.snapshot()}
    assert snap[("counter", "wire_bytes_up",
                 (("transport", "tcp"),))]["value"] == 15
    assert snap[("counter", "wire_bytes_up",
                 (("transport", "loopback"),))]["value"] == 1
    assert snap[("gauge", "train_loss", ())]["value"] == 0.25
    h = snap[("histogram", "lat", ())]
    assert h["counts"] == [0, 1, 0] and h["sum"] == 0.5 and h["count"] == 1


def test_histogram_bucketing_and_mean():
    h = Histogram(bounds=(1.0, 10.0))
    for v in (0.5, 1.0, 5.0, 100.0):
        h.observe(v)
    # bisect_left: the bound itself lands in ITS bucket (le semantics)
    assert h.counts == [2, 1, 1]
    assert h.mean == pytest.approx((0.5 + 1.0 + 5.0 + 100.0) / 4)
    assert Histogram().bounds == DEFAULT_LATENCY_BUCKETS


def test_mlmc_telemetry_draws_ladders_innovations_bias():
    t = MLMCTelemetry(maxlen=4)
    for lvl in (1, 1, 1, 2):
        t.record_draw("m", lvl, 0.5)
    t.record_expected("m", [2.0, 1.0, 1.0])        # normalized on record
    assert t.level_histogram("m") == {1: 0.75, 2: 0.25}
    assert t.draw_count("m") == 4
    np.testing.assert_allclose(t.expected_probs("m"), [0.5, 0.25, 0.25])
    assert t.level_histogram("other") == {} and t.draw_count("other") == 0
    assert t.expected_probs("other") is None

    for step in range(6):                          # maxlen=4 bounds it
        t.record_ladder("m", 1, [1.0, float(step)], step=step)
        t.record_innovation("e", [0.1 * step], step=step)
    traj = t.ladder_trajectory("m", 1)
    assert len(traj) == 4 and traj[-1][0] == 5
    assert len(t.innovation_trajectory("e")) == 4

    assert t.bias_proxy("m") is None
    g = np.arange(8.0)
    t.record_bias("m", g, g)
    assert t.bias_proxy("m") == pytest.approx(0.0, abs=1e-12)
    t.record_bias("m", g + 2.0, g)                 # mean dir drifts off dense
    assert t.bias_proxy("m") > 0

    s = t.summary()
    json.dumps(s)                                  # JSON-able roll-up
    assert s["m"]["level_histogram"] == {"1": 0.75, "2": 0.25}
    assert s["m"]["draws"] == 4
    assert s["m"]["ladder_last"]["1"]["points"] == 4
    assert s["e"]["innovation_last"]["step"] == 5
    assert "bias_proxy" in s["m"]


def test_should_sample_period_per_key():
    tel = Telemetry(sample_every=3)
    hits = [tel.should_sample("a") for _ in range(7)]
    assert hits == [True, False, False, True, False, False, True]
    assert tel.should_sample("b")                  # keys tick independently


# ---------------------------------------------------------------------------
# exporters + schema
# ---------------------------------------------------------------------------


def _small_telemetry() -> Telemetry:
    tel = Telemetry(rank=2)
    with tel.span("comm/encode", codec="topk"):
        pass
    tel.instant("train/log", cat="train", loss=1.0)
    tel.count("wire_bytes_up", 64, transport="tcp")
    tel.observe("codec_encode_s", 0.02, codec="topk")
    tel.mlmc.record_draw("mlmc_topk", 1, 0.5)
    return tel


def test_jsonl_roundtrip_and_summary_event(tmp_path):
    tel = _small_telemetry()
    path = tmp_path / "t.jsonl"
    n = export.write_jsonl(path, tel)
    back = export.read_jsonl(path)
    assert len(back) == n == len(tel.trace.events()) + 1
    assert export.validate_events(back) == []
    summary = back[-1]
    assert summary["ph"] == "M" and summary["name"] == "repro_summary"
    assert summary["pid"] == 2
    kinds = {m["name"] for m in summary["args"]["metrics"]}
    assert {"wire_bytes_up", "codec_encode_s"} <= kinds
    assert summary["args"]["mlmc"]["mlmc_topk"]["draws"] == 1
    (tmp_path / "bad.jsonl").write_text('{"ph": "X"}\nnot json\n')
    with pytest.raises(ValueError, match="bad.jsonl:2"):
        export.read_jsonl(tmp_path / "bad.jsonl")


def test_merge_events_sorts_by_ts():
    a = [{"ph": "i", "name": "a", "ts": 5.0, "pid": 0, "tid": 0}]
    b = [{"ph": "i", "name": "b", "ts": 1.0, "pid": 1, "tid": 0},
         {"ph": "i", "name": "c", "ts": 9.0, "pid": 1, "tid": 0}]
    assert [e["name"] for e in export.merge_events(a, b)] == ["b", "a", "c"]


def test_chrome_trace_has_one_named_track_per_rank():
    events = [{"ph": "X", "name": "s", "ts": 1.0, "dur": 2.0,
               "pid": p, "tid": 0} for p in (0, 2)]
    doc = export.chrome_trace(events, process_names={0: "rank 0 (server)"})
    assert doc["displayTimeUnit"] == "ms"
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    names = {e["pid"]: e["args"]["name"] for e in meta
             if e["name"] == "process_name"}
    assert names == {0: "rank 0 (server)", 2: "rank 2"}
    sort = {e["pid"]: e["args"]["sort_index"] for e in meta
            if e["name"] == "process_sort_index"}
    assert sort == {0: 0, 2: 2}
    assert doc["traceEvents"][-len(events):] == events


def test_prometheus_text_format():
    tel = _small_telemetry()
    text = export.prometheus_text(tel)
    assert '# TYPE repro_wire_bytes_up counter' in text
    assert 'repro_wire_bytes_up{transport="tcp"} 64' in text
    assert '# TYPE repro_codec_encode_s histogram' in text
    assert 'le="+Inf"' in text
    assert 'repro_codec_encode_s_count{codec="topk"} 1' in text
    # cumulative buckets are monotone
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith("repro_codec_encode_s_bucket")]
    assert cums == sorted(cums) and cums[-1] == 1


def test_schema_validation_catches_violations():
    ok = {"ph": "X", "name": "s", "ts": 1.0, "dur": 2.0, "pid": 0, "tid": 0}
    assert export.validate_events([ok]) == []
    bad = [{"ph": "Z", "name": "s", "ts": 1.0, "pid": 0, "tid": 0},
           {"ph": "X", "ts": 1.0, "pid": 0, "tid": 0},
           {"ph": "X", "name": "s", "ts": "late", "pid": 0, "tid": 0}]
    errors = export.validate_events(bad)
    assert len(errors) == 3
    assert any("not in" in e for e in errors)          # bad ph enum
    assert any("missing required field 'name'" in e for e in errors)
    assert any("expected number" in e for e in errors)


def test_checked_in_schema_is_the_wire_surface():
    """The schema file is append-only, like the golden packets: the core
    required fields and phase codes must never disappear."""
    schema = export.load_schema()
    assert set(schema["required"]) == {"ph", "name", "ts", "pid", "tid"}
    assert {"X", "i", "C", "M"} <= set(schema["properties"]["ph"]["enum"])


def test_downlink_and_bucket_spans_recorded_and_valid():
    """PR 7's comm-stack spans — ``wire/downlink_encode`` from the
    DIANA-shift server encode and ``wire/bucket_encode`` from the
    backward-pass streamer — must come out of the REAL code paths with
    their documented args and validate against the checked-in schema."""
    from repro.comm.plan import GradBucketStreamer, WirePlan
    from repro.comm.aggregate import _make_packed_codec
    from repro.core.aggregators import make_aggregator

    tel = obs.install(Telemetry(sample_every=1))
    dim, m = 96, 2
    rng = jax.random.PRNGKey(0)
    grads = jax.random.normal(rng, (m, dim), jnp.float32)

    ag = make_aggregator("mlmc_topk", dim, k_fraction=0.1, wire="packed",
                         downlink="topk")
    ag(grads, rng, ag.init(m, dim))

    plan = WirePlan("mlmc_topk", dim, 48,
                    lambda size: _make_packed_codec(
                        "mlmc_topk", size, None, dict(k_fraction=0.1)))
    streamer = GradBucketStreamer(plan, m, [0], [dim])
    streamer.begin(rng)
    for w in range(m):
        streamer.push(0, jnp.float32(w), grads[w])
    streamer.finish(grads)

    events = export.telemetry_events(tel)
    assert export.validate_events(events) == []
    by_name = {}
    for ev in events:
        by_name.setdefault(ev["name"], []).append(ev)
    (down,) = by_name["wire/downlink_encode"]
    assert down["ph"] == "X" and down["args"]["codec"] == "topk"
    assert down["args"]["nbytes"] > 0
    buckets = by_name["wire/bucket_encode"]
    assert len(buckets) == m * plan.num_buckets
    assert {(e["args"]["bucket"], e["args"]["worker"]) for e in buckets} \
        == {(b, w) for b in range(plan.num_buckets) for w in range(m)}
    assert all(e["args"]["codec"] == "mlmc_topk" for e in buckets)


def test_elastic_membership_events_recorded_and_valid(tmp_path):
    """PR 10's elastic-star events — ``wire/member_join`` /
    ``wire/member_leave`` from `Membership` transitions and
    ``wire/partial_round`` + the participation histogram from a deadline
    round — must come out of the real book-keeping code paths with their
    documented args, validate against the checked-in schema, and survive
    the Perfetto conversion."""
    from repro.comm.aggregate import _record_partial_round
    from repro.comm.elastic import Membership

    tel = obs.install(Telemetry(sample_every=1))
    mem = Membership(3)
    mem.mark_left(2, 4, "recv failed: peer reset")
    mem.mark_left(2, 5, "late")          # idempotent: no second event
    mem.mark_joined(2, 7, rejoin=True)

    class _Tp:
        rank = 0
        last_round = 7
    mask = np.array([1, 1, 0], np.uint8)
    _record_partial_round(tel, _Tp(), mask)
    _record_partial_round(tel, _Tp(), np.ones(3, np.uint8))  # full: no event

    events = export.telemetry_events(tel)
    assert export.validate_events(events) == []
    by_name = {}
    for ev in events:
        by_name.setdefault(ev["name"], []).append(ev)
    (leave,) = by_name["wire/member_leave"]
    assert leave["ph"] == "i" and leave["args"] == {
        "rank": 2, "round": 4, "reason": "recv failed: peer reset"}
    (join,) = by_name["wire/member_join"]
    assert join["args"] == {"rank": 2, "round": 7, "rejoin": True,
                            "rejoins": 1}
    (partial,) = by_name["wire/partial_round"]
    assert partial["args"] == {"round": 7, "n_arrived": 2, "world": 3,
                               "participants": [0, 1]}
    h = tel.metrics.histogram("wire_participation", transport="tcp")
    assert h.n == 2 and h.total == 5.0      # one 2-of-3 + one 3-of-3 round

    # round-trips: JSONL back in validates, Perfetto wraps every event
    p = tmp_path / "elastic.jsonl"
    export.write_jsonl(p, events)
    assert export.validate_events(export.read_jsonl(p)) == []
    n = export.write_chrome_trace(tmp_path / "elastic.json", events)
    assert n >= len(events)


def test_export_cli_merges_validates_and_converts(tmp_path):
    tels = []
    for rank in (0, 1):
        tel = Telemetry(rank=rank)
        with tel.span("comm/encode"):
            pass
        tel.count("wire_bytes_up", 10 * (rank + 1), transport="tcp")
        p = tmp_path / f"r{rank}.jsonl"
        export.write_jsonl(p, tel)
        tels.append(p)
    merged = tmp_path / "m.jsonl"
    perfetto = tmp_path / "m.json"
    prom = tmp_path / "m.prom"
    export.main([str(tels[0]), str(tels[1]), "--jsonl", str(merged),
                 "--perfetto", str(perfetto), "--prometheus", str(prom),
                 "--validate"])
    events = export.read_jsonl(merged)
    assert {e["pid"] for e in events} == {0, 1}
    doc = json.loads(perfetto.read_text())
    assert {e["pid"] for e in doc["traceEvents"]} == {0, 1}
    assert "repro_wire_bytes_up" in prom.read_text()
    # a schema violation makes the CLI exit nonzero
    (tmp_path / "bad.jsonl").write_text('{"ph": "Z", "ts": 0}\n')
    with pytest.raises(SystemExit, match="schema violations"):
        export.main([str(tmp_path / "bad.jsonl"), "--validate"])


# ---------------------------------------------------------------------------
# statistical fidelity: empirical level draws vs the p_l ladder
# ---------------------------------------------------------------------------


def test_level_draws_match_theoretical_ladder():
    """Real packed-wire rounds with telemetry installed: the recorded
    level-draw histogram must match `compressor.static_probs()` (the
    Lemma-3.3 ladder, auto-recorded as expected_probs) within sampling
    error, and every draw must be booked (M per round).  Uses the
    static-ladder family — the per-sample-adaptive ones draw from the
    Lemma-3.4 distribution instead, which is exactly what this telemetry
    exists to make visible."""
    from repro.comm import packed_aggregator

    tel = obs.install(Telemetry(sample_every=1))
    d, m, rounds = 64, 4, 120
    agg = packed_aggregator("mlmc_topk_static", d, k_fraction=0.1, s=4)
    st = agg.init(m, d)
    V = jnp.stack([jax.random.normal(jax.random.PRNGKey(40 + i), (d,))
                   for i in range(m)])
    for t in range(rounds):
        st = agg.step(st, V, jax.random.fold_in(jax.random.PRNGKey(9), t)).state
    n = rounds * m
    assert tel.mlmc.draw_count("mlmc_topk_static") == n
    expected = tel.mlmc.expected_probs("mlmc_topk_static")
    np.testing.assert_allclose(
        expected, np.asarray(agg.fn.codec.compressor.static_probs()),
        rtol=1e-6)
    hist = tel.mlmc.level_histogram("mlmc_topk_static")
    for lvl, p in enumerate(expected, start=1):
        tol = 5 * np.sqrt(p * (1 - p) / n) + 1e-3     # 5 sigma + slack
        assert abs(hist.get(lvl, 0.0) - p) < tol, \
            f"level {lvl}: {hist.get(lvl, 0.0):.3f} vs p_l {p:.3f}"


# ---------------------------------------------------------------------------
# retrace guard: telemetry must never add a jit lowering
# ---------------------------------------------------------------------------

_RG = dict(d=48, b=4, world=3, seed=11)


def _rg_trainer(telemetry=None):
    from repro.optim import sgd
    from repro.train import Trainer

    d = _RG["d"]
    params = {"w": jnp.zeros((d,)), "b": jnp.zeros(())}

    def loss_fn(p, batch):
        return jnp.mean((batch["x"] @ p["w"] + p["b"] - batch["y"]) ** 2)

    return Trainer(loss_fn, params, num_workers=_RG["world"],
                   method="mlmc_adaptive_topk", optimizer=sgd(0.1),
                   k_fraction=0.25, wire="packed", telemetry=telemetry)


def _rg_batches():
    d, b, world = _RG["d"], _RG["b"], _RG["world"]
    key = jax.random.PRNGKey(7)
    wkey, key = jax.random.split(key)
    w_true = jax.random.normal(wkey, (d,))
    while True:
        key, kx = jax.random.split(key)
        x = jax.random.normal(kx, (world, b, d))
        yield {"x": x, "y": x @ w_true}


@pytest.mark.parametrize("enabled", [False, True],
                         ids=["disabled", "enabled"])
def test_telemetry_adds_no_jit_lowerings(enabled):
    """The PR-5 retrace harness with telemetry off AND on (sample_every=1,
    so the sampled estimator metrics — ladder rows, bias proxy — fire on
    every counted step): zero new lowerings after step 0 either way.  The
    sampled jnp reductions lower once at warmup and then hit the cache."""
    tel = Telemetry(sample_every=1) if enabled else None
    trainer = _rg_trainer(tel)
    data = _rg_batches()
    trainer.fit(data, steps=1, seed=_RG["seed"])          # warmup/compile
    with jtu.count_jit_and_pmap_lowerings() as count:
        trainer.fit(data, steps=2, seed=_RG["seed"] + 1)
    assert count[0] == 0, \
        f"telemetry {'on' if enabled else 'off'}: {count[0]} new lowerings"
    if enabled:
        assert tel.mlmc.draw_count("mlmc_adaptive_topk") == 3 * _RG["world"]
        assert len(tel.mlmc.ladder_trajectory("mlmc_adaptive_topk", 0)) == 3


@pytest.mark.slow
def test_enabled_telemetry_overhead_within_budget():
    """Steady-state step-time overhead of ENABLED telemetry at the default
    sampling period stays within the ISSUE's 5% budget (median over many
    steps; generous absolute slack absorbs CI timer noise)."""
    import time

    def steady_median(tel):
        trainer = _rg_trainer(tel)
        data = _rg_batches()
        trainer.fit(data, steps=3, seed=_RG["seed"])      # warmup
        times = []
        for _ in range(40):
            t0 = time.perf_counter()
            trainer.fit(data, steps=1, seed=_RG["seed"] + 1)
            times.append(time.perf_counter() - t0)
        return float(np.median(times))

    off = steady_median(None)
    on = steady_median(Telemetry())
    assert on <= off * 1.05 + 2e-4, \
        f"telemetry overhead {on / off - 1:+.1%} (off={off*1e3:.2f}ms, " \
        f"on={on*1e3:.2f}ms)"
