"""The stateful compression pipeline: first-class CommState on every wire.

Load-bearing assertions:

* the unified `Aggregator` protocol — ``init(M, d) -> CommState``,
  ``step(state, grads, rng) -> AggregateOut`` — holds for EVERY registry
  name on every substrate, with a stable treedef (stateless families carry
  the empty state);
* cross-wire parity matrix for the stateful aggregators: EF21, EF21-SGDM
  and `mlmc_adaptive_topk` produce identical directions on abstract vs
  packed vs device over multiple steps of evolving state (EF21's device
  wire is bitwise; the adaptive family is bitwise at ``value_bits=32`` and
  within bf16 value rounding at the default 16);
* the EMA family's semantics: ``ema_rho = 1`` reproduces the stateless
  per-sample Lemma-3.4 estimator exactly; the estimator stays unbiased for
  any rho (Lemma 3.2 holds for ANY non-zero level distribution);
* checkpoint round-trip: params + opt_state + CommState restore to a
  bitwise-identical continuation (the former ``ef_state``-dropping bug);
* EF21 bits reconcile: the abstract booking equals the honest
  `bits.ef21_bits` ledger, which the packed codec measures tightly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bits as bitcost
from repro.core.aggregators import (
    ALL_AGGREGATORS,
    STATEFUL_AGGREGATORS,
    make_aggregator,
)
from repro.core.types import CommState, empty_comm_state

jax.config.update("jax_platform_name", "cpu")

D, M = 193, 3
KW = dict(k_fraction=0.05, s=4)


def _grads(seed=7):
    return jax.random.normal(jax.random.PRNGKey(seed), (M, D)) \
        * jnp.exp(-0.05 * jnp.arange(D))


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_AGGREGATORS)
def test_protocol_state_treedef_stable(name):
    """init always yields a CommState; step returns one with the SAME
    treedef and leaf shapes (jit-compatible threading for every family)."""
    agg = make_aggregator(name, D, **KW)
    state = agg.init(M, D)
    assert isinstance(state, CommState)
    out = agg.step(state, _grads(), jax.random.PRNGKey(0))
    assert isinstance(out.state, CommState)
    assert jax.tree_util.tree_structure(out.state) == \
        jax.tree_util.tree_structure(state)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(out.state)):
        assert a.shape == b.shape and a.dtype == b.dtype
    assert agg.stateful == (name in STATEFUL_AGGREGATORS)


@pytest.mark.parametrize("name", ["dense", "mlmc_topk", "qsgd"])
def test_stateless_state_passes_through(name):
    agg = make_aggregator(name, D, **KW)
    state = agg.init(M, D)
    out = agg.step(state, _grads(), jax.random.PRNGKey(1))
    assert out.state is state          # identity pass-through
    # and the empty state holds no data
    assert sum(l.size for l in jax.tree_util.tree_leaves(empty_comm_state())
               if l.ndim > 0) == 0


@pytest.mark.parametrize("name", STATEFUL_AGGREGATORS)
def test_stateful_state_evolves(name):
    agg = make_aggregator(name, D, **KW)
    state = agg.init(M, D)
    out = agg.step(state, _grads(), jax.random.PRNGKey(2))
    assert int(out.state.step) == int(state.step) + 1
    moving = (out.state.ladder_ema if name.startswith("mlmc_adaptive")
              else out.state.g_workers)
    assert float(jnp.sum(jnp.abs(moving))) > 0


# ---------------------------------------------------------------------------
# adaptive EMA semantics
# ---------------------------------------------------------------------------


def test_adaptive_rho_one_recovers_per_sample_lemma34():
    """ema_rho = 1: the EMA ladder IS the fresh ladder every step, so the
    stateful family reproduces the stateless adaptive estimator exactly."""
    g = _grads(3)
    a_stateless = make_aggregator("mlmc_topk", D, **KW)
    a_ema = make_aggregator("mlmc_adaptive_topk", D, **KW, ema_rho=1.0)
    state = a_ema.init(M, D)
    for step in range(3):
        rng = jax.random.fold_in(jax.random.PRNGKey(5), step)
        o_ref = a_stateless(g, rng)
        o_ema = a_ema.step(state, g, rng)
        state = o_ema.state
        np.testing.assert_array_equal(np.asarray(o_ema.direction),
                                      np.asarray(o_ref.direction))


@pytest.mark.parametrize("name", ["mlmc_adaptive_topk", "mlmc_adaptive_rtn"])
def test_adaptive_unbiased_mc(name):
    """Lemma 3.2: the estimator is conditionally unbiased for ANY level
    distribution — including the EMA-smoothed one (state held fixed)."""
    g = _grads(11)
    target = np.asarray(g.mean(0))
    agg = make_aggregator(name, D, **KW, ema_rho=0.25)
    # advance the state once so the EMA differs from the fresh ladder
    state = agg.step(agg.init(M, D), g, jax.random.PRNGKey(0)).state
    keys = jax.random.split(jax.random.PRNGKey(7), 600)
    outs = jax.vmap(lambda k: agg.step(state, g, k).direction)(keys)
    est = np.asarray(outs.mean(0))
    rel = np.linalg.norm(est - target) / np.linalg.norm(target)
    assert rel < 0.25, (name, rel)


def test_adaptive_ema_smooths_ladder():
    """rho < 1 after step 0: the EMA ladder is a strict blend of old and
    fresh ladders, not a copy of either."""
    from repro.core.adaptive import ladder_ema_update

    ema = jnp.asarray([1.0, 0.0, 0.0])
    fresh = jnp.asarray([0.0, 1.0, 0.0])
    out0 = ladder_ema_update(ema, fresh, 0.25, 0)     # cold start: fresh
    np.testing.assert_array_equal(np.asarray(out0), np.asarray(fresh))
    out1 = ladder_ema_update(ema, fresh, 0.25, 1)
    np.testing.assert_allclose(np.asarray(out1), [0.75, 0.25, 0.0],
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# cross-wire parity matrix (fast, single-device half; the 8-device mesh
# half lives in distributed_worker.py behind the `slow` marker)
# ---------------------------------------------------------------------------


def _run_steps(agg, g, steps=3, seed=9):
    state = agg.init(M, D)
    outs = []
    for t in range(steps):
        o = agg.step(state, g, jax.random.fold_in(jax.random.PRNGKey(seed),
                                                  t))
        state = o.state
        outs.append(o)
    return outs


@pytest.mark.parametrize("name", ["ef21", "ef21_sgdm", "mlmc_adaptive_topk",
                                  "mlmc_adaptive_rtn"])
def test_stateful_packed_matches_abstract(name):
    g = _grads()
    ref = _run_steps(make_aggregator(name, D, **KW), g)
    pkd = _run_steps(make_aggregator(name, D, **KW, wire="packed"), g)
    for t, (a, p) in enumerate(zip(ref, pkd)):
        np.testing.assert_allclose(np.asarray(p.direction),
                                   np.asarray(a.direction),
                                   rtol=1e-6, atol=1e-7,
                                   err_msg=f"{name} step {t}")


@pytest.mark.parametrize("name", ["ef21", "ef21_sgdm"])
def test_ef21_device_matches_abstract_bitwise(name):
    """The EF21 device codec ships raw f32 values + exact positions, so the
    jitted device direction AND the threaded state equal the abstract ones
    elementwise over multiple steps of compounding state."""
    g = _grads()
    a_abs = make_aggregator(name, D, **KW)
    a_dev = make_aggregator(name, D, **KW, wire="device")
    st_a, st_d = a_abs.init(M, D), a_dev.init(M, D)
    for t in range(3):
        rng = jax.random.fold_in(jax.random.PRNGKey(13), t)
        oa = jax.jit(a_abs.fn)(g, rng, st_a)
        od = jax.jit(a_dev.fn)(g, rng, st_d)
        st_a, st_d = oa.state, od.state
        np.testing.assert_array_equal(np.asarray(od.direction),
                                      np.asarray(oa.direction),
                                      err_msg=f"{name} step {t}")
        np.testing.assert_array_equal(np.asarray(od.state.g_workers),
                                      np.asarray(oa.state.g_workers),
                                      err_msg=f"{name} state step {t}")


def test_adaptive_device_f32_matches_abstract_bitwise():
    """At value_bits=32 the adaptive device wire replays the abstract f32
    math exactly: directions and EMA ladders are IEEE-equal under jit."""
    from repro.comm.device_wire import device_aggregator

    g = _grads()
    a_abs = make_aggregator("mlmc_adaptive_topk", D, **KW)
    a_dev = device_aggregator("mlmc_adaptive_topk", D, **KW,
                              topk_value_bits=32)
    st_a, st_d = a_abs.init(M, D), a_dev.init(M, D)
    for t in range(4):
        rng = jax.random.fold_in(jax.random.PRNGKey(17), t)
        oa = jax.jit(a_abs.fn)(g, rng, st_a)
        od = jax.jit(a_dev.fn)(g, rng, st_d)
        st_a, st_d = oa.state, od.state
        np.testing.assert_array_equal(np.asarray(od.direction),
                                      np.asarray(oa.direction))
        np.testing.assert_array_equal(np.asarray(od.state.ladder_ema),
                                      np.asarray(oa.state.ladder_ema))


def test_adaptive_device_bf16_is_value_rounding_only():
    """Default bf16 values: the ladders (and hence levels) still match the
    abstract substrate exactly — only the shipped VALUES round."""
    g = _grads()
    a_abs = make_aggregator("mlmc_adaptive_topk", D, **KW)
    a_dev = make_aggregator("mlmc_adaptive_topk", D, **KW, wire="device")
    st_a, st_d = a_abs.init(M, D), a_dev.init(M, D)
    for t in range(3):
        rng = jax.random.fold_in(jax.random.PRNGKey(19), t)
        oa = jax.jit(a_abs.fn)(g, rng, st_a)
        od = jax.jit(a_dev.fn)(g, rng, st_d)
        st_a, st_d = oa.state, od.state
        np.testing.assert_array_equal(np.asarray(od.state.ladder_ema),
                                      np.asarray(oa.state.ladder_ema))
        np.testing.assert_allclose(np.asarray(od.direction),
                                   np.asarray(oa.direction),
                                   rtol=3e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# EF21 bits reconcile (the honest ledger, abstract == nominal == measured)
# ---------------------------------------------------------------------------


def test_ef21_bits_reconcile_with_ledger():
    from repro.comm import make_codec

    k = max(1, round(KW["k_fraction"] * D))
    agg = make_aggregator("ef21", D, **KW)
    out = agg.step(agg.init(M, D), _grads(), jax.random.PRNGKey(0))
    assert float(out.bits) == M * bitcost.ef21_bits(D, k)

    codec = make_codec("ef21", D, **KW)
    assert codec.nominal_bits() == bitcost.ef21_bits(D, k)
    pkt = codec.encode(_grads()[0], None).packet
    lo, hi = codec.reconcile_bounds(pkt)
    assert lo <= codec.measured_bits(pkt) <= hi
    # tightened bound: only index-stream word padding above nominal
    assert hi - lo <= 32.0 * k


def test_packed_ef21_measures_close_to_abstract_booking():
    """The packed EF21 measurement sits within the documented per-packet
    slack of the abstract booking (serialization framing excluded)."""
    g = _grads()
    k = max(1, round(KW["k_fraction"] * D))
    a_abs = make_aggregator("ef21", D, **KW)
    a_pkd = make_aggregator("ef21", D, **KW, wire="packed")
    oa = a_abs.step(a_abs.init(M, D), g, jax.random.PRNGKey(0))
    op = a_pkd.step(a_pkd.init(M, D), g, jax.random.PRNGKey(0))
    booked, measured = float(oa.bits), float(op.bits)
    assert booked <= measured <= booked + M * 32.0 * k


# ---------------------------------------------------------------------------
# checkpoint round-trip (the ef_state-dropping bugfix)
# ---------------------------------------------------------------------------


def _toy_trainer(method):
    from repro.optim import sgd
    from repro.train import Trainer

    d = 48
    params = {"w": jnp.zeros((d,)), "b": jnp.zeros(())}

    def loss_fn(p, batch):
        return jnp.mean((batch["x"] @ p["w"] + p["b"] - batch["y"]) ** 2)

    return Trainer(loss_fn, params, num_workers=2, method=method,
                   optimizer=sgd(0.1), k_fraction=0.25)


def _toy_batches(n, seed=21):
    key = jax.random.PRNGKey(seed)
    w_true = jax.random.normal(jax.random.PRNGKey(1), (48,))
    out = []
    for _ in range(n):
        key, kx = jax.random.split(key)
        x = jax.random.normal(kx, (2, 4, 48))
        out.append({"x": x, "y": x @ w_true})
    return out


@pytest.mark.parametrize("method", ["ef21", "ef21_sgdm",
                                    "mlmc_adaptive_topk"])
def test_checkpoint_roundtrip_restores_comm_state(method, tmp_path):
    """Save at step 3, restore into a FRESH trainer, continue 2 steps: the
    final params/state/bits equal the uninterrupted 5-step run bitwise.
    Before CommState was checkpointed, the restored EF21 run restarted
    from zero innovation and diverged immediately."""
    batches = _toy_batches(5)

    ref = _toy_trainer(method)
    ref.fit(iter(batches), steps=5, seed=31)

    a = _toy_trainer(method)
    a.fit(iter(batches[:3]), steps=3, seed=31)
    a.save_checkpoint(tmp_path / "ck")

    b = _toy_trainer(method)
    meta = b.load_checkpoint(tmp_path / "ck")
    assert meta["method"] == method
    # the restored state is REAL (the former bug zeroed it)
    moving = (b.comm_state.ladder_ema if method.startswith("mlmc_adaptive")
              else b.comm_state.g_workers)
    assert float(jnp.sum(jnp.abs(moving))) > 0
    assert int(b.comm_state.step) == 3
    # resume the rng chain where the uninterrupted run stands after 3 steps
    rng = jax.random.PRNGKey(31)
    for _ in range(3):
        rng, _ = jax.random.split(rng)
    for batch in batches[3:]:
        rng, sub = jax.random.split(rng)
        (b.flat_params, b.opt_state, b.comm_state, _,
         bits) = b._step(b.flat_params, b.opt_state, b.comm_state, batch,
                         sub)
        b.total_bits += float(bits)
    np.testing.assert_array_equal(np.asarray(b.flat_params),
                                  np.asarray(ref.flat_params))
    for got, want in zip(jax.tree_util.tree_leaves(b.comm_state),
                         jax.tree_util.tree_leaves(ref.comm_state)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert b.total_bits == ref.total_bits


def test_checkpoint_without_comm_state_raises_loudly(tmp_path):
    """Restoring a stateful template from a bundle that never saved the
    comm state must fail loudly, not silently zero the state."""
    from repro import checkpoint

    tr = _toy_trainer("ef21")
    checkpoint.save(tmp_path / "old", {"params": tr.params,
                                       "opt_state": tr.opt_state,
                                       "comm_state": ()})
    with pytest.raises(KeyError):
        tr.load_checkpoint(tmp_path / "old")
