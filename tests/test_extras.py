"""Extended-feature tests: natural compression / SignSGD baselines,
vocab-parallel sampling, LR schedules + clipping."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.natural import NaturalCompression, SignSGD
from repro.models.layers import vocab_parallel_sample
from repro.optim import sgd
from repro.optim.schedules import scheduled, warmup_cosine, with_global_clip
from repro.sharding.ctx import unsharded


def test_natural_compression_unbiased():
    v = jax.random.normal(jax.random.PRNGKey(0), (128,))
    comp = NaturalCompression()
    keys = jax.random.split(jax.random.PRNGKey(1), 4000)
    est = jax.vmap(lambda k: comp.compress(v, rng=k))(keys).mean(0)
    rel = float(jnp.linalg.norm(est - v) / jnp.linalg.norm(v))
    assert rel < 0.05
    # outputs are exact powers of two (in magnitude)
    one = comp.compress(v, rng=keys[0])
    m, _ = jnp.frexp(jnp.where(one == 0, 1.0, one))
    assert bool(jnp.all(jnp.isin(jnp.abs(m), jnp.asarray([0.5, 1.0])) |
                        (one == 0)))


def test_natural_compression_bounded_variance():
    """omega = 1/8 for natural compression: E||C(v)-v||^2 <= ||v||^2 / 8."""
    v = jax.random.normal(jax.random.PRNGKey(2), (256,))
    comp = NaturalCompression()
    keys = jax.random.split(jax.random.PRNGKey(3), 2000)
    errs = jax.vmap(lambda k: jnp.sum((comp.compress(v, rng=k) - v) ** 2))(keys)
    assert float(errs.mean()) <= float(jnp.sum(v * v)) / 8 * 1.1


def test_signsgd():
    v = jnp.asarray([3.0, -1.0, 0.5, -0.5])
    out = SignSGD().compress(v)
    np.testing.assert_allclose(np.asarray(jnp.sign(out)),
                               np.asarray(jnp.sign(v)))
    np.testing.assert_allclose(float(jnp.abs(out).max()),
                               float(jnp.mean(jnp.abs(v))), rtol=1e-6)


def test_new_aggregators():
    from repro.core.aggregators import make_aggregator

    g = jax.random.normal(jax.random.PRNGKey(4), (4, 64))
    for name in ("natural", "signsgd", "signsgd_ef"):
        agg = make_aggregator(name, 64)
        state = agg.init(4, 64) if agg.init else None
        out = agg(g, jax.random.PRNGKey(5), state)
        assert out.direction.shape == (64,)
        assert np.isfinite(np.asarray(out.direction)).all()


def test_vocab_parallel_sample():
    """Unsharded: gumbel sampling matches categorical frequencies and at
    temperature->0 converges to argmax."""
    logits = jnp.log(jnp.asarray([[0.7, 0.2, 0.1, 1e-9]]))
    keys = jax.random.split(jax.random.PRNGKey(6), 3000)
    toks = jax.vmap(lambda k: vocab_parallel_sample(logits, unsharded(), k))(
        keys)[:, 0]
    freq = np.bincount(np.asarray(toks), minlength=4) / toks.shape[0]
    np.testing.assert_allclose(freq[:3], [0.7, 0.2, 0.1], atol=0.05)
    cold = vocab_parallel_sample(logits, unsharded(), keys[0],
                                 temperature=1e-4)
    assert int(cold[0]) == 0


def test_warmup_cosine_schedule():
    sch = warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(sch(0)) < 0.2
    np.testing.assert_allclose(float(sch(10)), 1.0, rtol=0.1)
    assert float(sch(99)) < 0.2
    assert float(sch(99)) >= 0.1 - 1e-6  # min_frac floor


def test_scheduled_optimizer_descends():
    opt = scheduled(lambda lr: sgd(lr), warmup_cosine(0.2, 5, 60))
    params = {"x": jnp.asarray([4.0, -3.0])}
    state = opt.init(params)
    for _ in range(60):
        grads = jax.tree.map(lambda p: 2 * p, params)
        params, state = opt.apply(grads, state, params)
    assert float(jnp.linalg.norm(params["x"])) < 0.5
    assert int(state["step"]) == 60


def test_global_clip():
    opt = with_global_clip(sgd(1.0), max_norm=1.0)
    params = {"x": jnp.zeros(4)}
    state = opt.init(params)
    big = {"x": jnp.full((4,), 100.0)}
    params, _ = opt.apply(big, state, params)
    np.testing.assert_allclose(float(jnp.linalg.norm(params["x"])), 1.0,
                               rtol=1e-5)
