"""Golden-packet regression battery: the byte wire format is a compatibility
surface.

One committed snapshot (`tests/golden_packets/<name>.bin`) of an encoded
`Packet` per registry aggregator (EF21 variants snapshot their innovation
codec).  The test re-encodes the same deterministic gradient with the same
keys and asserts `to_bytes()` is BYTE-identical to the snapshot: any change
to the header struct, stream layout, bit-packing order, codec math, or the
PRNG replay breaks decode for packets already on the wire and must be a
deliberate, versioned decision.

Deliberate wire changes on record:

* PR 4 — ``ef21``/``ef21_sgdm`` moved off the Top-k baseline codec onto the
  dedicated `EF21InnovationCodec` (new codec id 14): positions now pack at
  the honest ceil(log2 d) bits the `bits.ef21_bits` ledger books, so those
  two fixtures were regenerated.  The ``mlmc_adaptive_*`` fixtures are new
  (codec ids 15-17).  Every pre-existing non-EF21 fixture is byte-identical.

Regenerate (only when intentionally changing the wire format):

    PYTHONPATH=src python tests/test_golden_packets.py --regen
"""

import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro.comm import Packet, make_codec
from repro.comm.packets import CODEC_IDS
from repro.core.aggregators import ALL_AGGREGATORS

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden_packets"

#: deterministic fixture inputs (MUST never change: part of the snapshots)
GOLDEN_DIM = 257
GOLDEN_CODEC_KW = dict(k_fraction=0.05, s=4)
GOLDEN_GRAD_SEED = 20250728
GOLDEN_KEY_SEED = 42

#: frozen copy of the wire codec-id table at snapshot time.  CODEC_IDS is
#: append-only: every entry here must stay EXACTLY as-is forever; new codecs
#: may only take ids above the frozen range.
FROZEN_CODEC_IDS = {
    "dense": 0, "topk": 1, "randk": 2, "qsgd": 3, "rtn": 4, "fixed2": 5,
    "natural": 6, "signsgd": 7, "mlmc_topk": 8, "mlmc_topk_static": 9,
    "mlmc_stopk": 10, "mlmc_fixed": 11, "mlmc_float": 12, "mlmc_rtn": 13,
}


def golden_grad() -> jax.Array:
    key = jax.random.PRNGKey(GOLDEN_GRAD_SEED)
    return jax.random.normal(key, (GOLDEN_DIM,)) * jnp.exp(
        -0.02 * jnp.arange(GOLDEN_DIM))


def encode_golden(name: str) -> bytes:
    """Deterministic encode for one registry name (key folds in the name's
    position in ALL_AGGREGATORS, which is itself append-only)."""
    codec = make_codec(name, GOLDEN_DIM, **GOLDEN_CODEC_KW)
    key = jax.random.fold_in(jax.random.PRNGKey(GOLDEN_KEY_SEED),
                             ALL_AGGREGATORS.index(name))
    return codec.encode(golden_grad(), key).packet.to_bytes()


@pytest.mark.parametrize("name", ALL_AGGREGATORS)
def test_golden_packet_bytes(name):
    path = GOLDEN_DIR / f"{name}.bin"
    assert path.exists(), \
        f"missing golden fixture {path}; run tests/test_golden_packets.py --regen"
    got = encode_golden(name)
    want = path.read_bytes()
    assert got == want, (
        f"{name}: encoded packet ({len(got)}B) differs from the committed "
        f"snapshot ({len(want)}B) — the wire format changed. If intentional, "
        "bump the packet version and regenerate the fixtures.")


@pytest.mark.parametrize("name", ALL_AGGREGATORS)
def test_golden_packet_still_decodes(name):
    """The committed bytes must parse and decode to a dim-sized estimate."""
    pkt = Packet.from_bytes((GOLDEN_DIR / f"{name}.bin").read_bytes())
    codec = make_codec(name, GOLDEN_DIM, **GOLDEN_CODEC_KW)
    est = codec.decode(pkt)
    assert est.shape == (GOLDEN_DIM,)


#: frozen copy of the tcp star's frame-type table and wire magics at the
#: PR-7 snapshot.  Both are append-only compatibility surfaces: existing
#: numbers/magics must never change; new frame types take the next free
#: number, new blob formats take a fresh 4-byte magic.
FROZEN_FRAME_TYPES = {
    "HELLO": 1, "WELCOME": 2, "GOODBYE": 3, "PAYLOAD": 4, "DIRECTION": 5,
    "SCALAR": 6, "SCALAR_MEAN": 7, "STATE": 8, "DIRECTION_ENC": 9,
    "PING": 10, "PONG": 11, "LEAVE": 12, "REJOIN": 13,
}
FROZEN_WIRE_MAGICS = {
    "direction_enc": b"RCD2", "state_row_v1": b"RCS1", "state_row_v2": b"RCS2",
    "bucket_container": b"RCBW",
    "partial_direction": b"RCD3", "seq_container": b"RCSQ",
}

#: deterministic downlink-fixture inputs (immutable: part of the snapshot)
GOLDEN_DOWNLINK = ("topk", "qsgd")
GOLDEN_SHIFT_SCALE = 0.125
GOLDEN_STATE_RANK = 2


def golden_shift() -> jax.Array:
    return GOLDEN_SHIFT_SCALE * jnp.sin(jnp.arange(GOLDEN_DIM, dtype=jnp.float32))


def encode_golden_downlink(name: str) -> bytes:
    """Deterministic RCD2 blob: the server-side half of one downlink round
    (encode `direction - shift`, frame it) with pinned inputs."""
    from repro.comm.aggregate import Downlink, pack_encoded_direction

    codec = make_codec(name, GOLDEN_DIM, **GOLDEN_CODEC_KW)
    down = Downlink(codec, alpha=0.5)
    key = down.key(jax.random.PRNGKey(GOLDEN_KEY_SEED))
    pkt, _, _ = down.encode(golden_grad(), golden_shift(), key)
    return pack_encoded_direction(pkt.to_bytes(), GOLDEN_DIM, 1234.5)


def encode_golden_state_row() -> bytes:
    """Deterministic RCS2 row: a shift-bearing CommState gathered from
    GOLDEN_STATE_RANK (ladder/momentum empty — the downlink-only shape)."""
    from repro.comm.aggregate import pack_comm_state_row
    from repro.core.types import empty_comm_state

    state = empty_comm_state(GOLDEN_DIM)._replace(shift=golden_shift())
    return pack_comm_state_row(state, GOLDEN_STATE_RANK)


@pytest.mark.parametrize("name", GOLDEN_DOWNLINK)
def test_golden_downlink_blob_bytes(name):
    path = GOLDEN_DIR / f"downlink_{name}.bin"
    assert path.exists(), \
        f"missing golden fixture {path}; run tests/test_golden_packets.py --regen"
    assert encode_golden_downlink(name) == path.read_bytes(), (
        f"downlink_{name}: RCD2 blob differs from the committed snapshot — "
        "the downlink wire format changed. If intentional, add a new magic "
        "next to RCD2 and regenerate.")


@pytest.mark.parametrize("name", GOLDEN_DOWNLINK)
def test_golden_downlink_blob_roundtrips(name):
    """The committed blob must unpack, decode, and advance the shift the
    same way on any receiver: direction~ and new shift are pure f32 ops on
    the decoded delta, so equality of decode(pkt) is the whole contract."""
    from repro.comm.aggregate import Downlink, unpack_encoded_direction

    raw = (GOLDEN_DIR / f"downlink_{name}.bin").read_bytes()
    pkt_bytes, bits = unpack_encoded_direction(raw, GOLDEN_DIM)
    assert bits == 1234.5
    codec = make_codec(name, GOLDEN_DIM, **GOLDEN_CODEC_KW)
    delta_hat = Downlink(codec).decode(Packet.from_bytes(pkt_bytes))
    assert delta_hat.shape == (GOLDEN_DIM,)
    assert bool(jnp.all(jnp.isfinite(delta_hat)))


def test_golden_state_row_bytes():
    path = GOLDEN_DIR / "state_row_shift.bin"
    assert path.exists(), \
        f"missing golden fixture {path}; run tests/test_golden_packets.py --regen"
    assert encode_golden_state_row() == path.read_bytes(), (
        "state_row_shift: RCS2 row differs from the committed snapshot — "
        "the checkpoint-gather format changed. If intentional, add RCS3 and "
        "regenerate.")


def test_golden_state_row_roundtrips():
    import numpy as np

    from repro.comm.aggregate import unpack_comm_state_row

    raw = (GOLDEN_DIR / "state_row_shift.bin").read_bytes()
    rank, ladder, momentum, shift = unpack_comm_state_row(raw)
    assert rank == GOLDEN_STATE_RANK
    assert ladder.size == 0 and momentum.size == 0
    assert np.array_equal(shift, np.asarray(golden_shift(), np.float32))


def test_frame_types_and_magics_append_only():
    """tcp frame-type numbers and 4-byte blob magics are frozen: peers on
    the old protocol must keep parsing every committed frame forever."""
    from repro.comm import aggregate, multihost, packets, plan

    for name, num in FROZEN_FRAME_TYPES.items():
        assert getattr(multihost, name) == num, \
            f"frame type {name} changed from {num}"
    assert aggregate._DIRE_MAGIC == FROZEN_WIRE_MAGICS["direction_enc"]
    assert aggregate._STATE_MAGIC == FROZEN_WIRE_MAGICS["state_row_v1"]
    assert aggregate._STATE2_MAGIC == FROZEN_WIRE_MAGICS["state_row_v2"]
    assert plan._BUCKETS_MAGIC == FROZEN_WIRE_MAGICS["bucket_container"]
    assert aggregate._DIRP_MAGIC == FROZEN_WIRE_MAGICS["partial_direction"]
    assert packets.SEQ_MAGIC == FROZEN_WIRE_MAGICS["seq_container"]
    magics = list(FROZEN_WIRE_MAGICS.values())
    assert len(magics) == len(set(magics)), "duplicate wire magics"


def test_codec_ids_append_only():
    """Wire codec ids are a compatibility surface: frozen entries immutable,
    new entries only above the frozen range, ids unique."""
    for name, cid in FROZEN_CODEC_IDS.items():
        assert CODEC_IDS.get(name) == cid, \
            f"CODEC_IDS[{name!r}] changed from {cid} to {CODEC_IDS.get(name)}"
    ids = list(CODEC_IDS.values())
    assert len(ids) == len(set(ids)), "duplicate codec ids"
    frozen_max = max(FROZEN_CODEC_IDS.values())
    for name, cid in CODEC_IDS.items():
        if name not in FROZEN_CODEC_IDS:
            assert cid > frozen_max, \
                f"new codec {name!r} must take an id above {frozen_max}"


#: frozen copy of `repro.comm.policy.POLICY_PRESETS` at the PR-8 snapshot.
#: The table is append-only config surface: a run launched with a preset
#: name must mean the same resolved policy forever — existing entries
#: must never change; new presets take new names.
FROZEN_POLICY_PRESETS = {
    "dense_small_tensors": {"size<=2048": "dense", "*": "mlmc_topk"},
    "dense_embed_norm": {"*embed*": "dense", "*norm*": "dense",
                         "*": "mlmc_topk"},
    "uniform_mlmc_topk": {"*": "mlmc_topk"},
    "uniform_dense": {"*": "dense"},
}

#: deterministic policy-container fixture: a two-stream split of the
#: golden gradient (dense head, qsgd tail) shipped as one RCBW container.
#: The pinned hash is the exact fingerprint this policy sends in the tcp
#: HELLO — if it drifts, old and new ranks refuse each other's handshake.
GOLDEN_POLICY_SEGMENTS = (("dense", 0, 64), ("qsgd", 64, GOLDEN_DIM))
GOLDEN_POLICY_HASH = "5249744e1ea53308"


def golden_policy():
    from repro.comm.policy import ResolvedPolicy, Segment

    return ResolvedPolicy(GOLDEN_DIM, tuple(
        Segment(f"{codec}@{start}", codec, start, stop)
        for codec, start, stop in GOLDEN_POLICY_SEGMENTS))


def encode_golden_policy_container() -> bytes:
    """Deterministic RCBW multi-stream container: worker 0's per-segment
    packets under the policy draw keys ``fold_in(key0, segment_index)``."""
    from repro.comm.packets import pack_bucket_payload
    from repro.comm.plan import policy_packed_aggregator

    ag = policy_packed_aggregator(golden_policy(), GOLDEN_DIM,
                                  codec_kw=dict(GOLDEN_CODEC_KW))
    plan = ag.fn.plan
    keys = jax.random.split(jax.random.PRNGKey(GOLDEN_KEY_SEED), 1)
    packets = plan.encode_round(golden_grad()[None, :], keys)
    return pack_bucket_payload([packets[b][0].to_bytes()
                                for b in range(plan.num_buckets)])


def test_policy_presets_append_only():
    from repro.comm.policy import POLICY_PRESETS

    for name, rules in FROZEN_POLICY_PRESETS.items():
        assert POLICY_PRESETS.get(name) == rules, \
            f"POLICY_PRESETS[{name!r}] changed meaning"
    # and rule ORDER is part of the meaning (first match wins)
    for name in FROZEN_POLICY_PRESETS:
        assert list(POLICY_PRESETS[name]) == \
            list(FROZEN_POLICY_PRESETS[name]), \
            f"POLICY_PRESETS[{name!r}] rule order changed"


def test_golden_policy_hash_pinned():
    assert golden_policy().hash == GOLDEN_POLICY_HASH, (
        "the policy fingerprint derivation changed — ranks running the "
        "committed policy would now refuse old peers at the tcp HELLO. "
        "If intentional, version the HELLO token and re-pin.")


def test_golden_policy_container_bytes():
    path = GOLDEN_DIR / "policy_container.bin"
    assert path.exists(), \
        f"missing golden fixture {path}; run tests/test_golden_packets.py --regen"
    assert encode_golden_policy_container() == path.read_bytes(), (
        "policy_container: RCBW multi-stream container differs from the "
        "committed snapshot — the policy wire changed. If intentional, add "
        "a new container magic next to RCBW and regenerate.")


def test_golden_policy_container_roundtrips():
    """The committed container splits into one self-describing `Packet`
    per segment, each decoding to its segment's size — and the decoded
    concatenation covers the golden gradient's full dimension."""
    from repro.comm.packets import unpack_bucket_payload

    raw = (GOLDEN_DIR / "policy_container.bin").read_bytes()
    parts = unpack_bucket_payload(raw)
    assert len(parts) == len(GOLDEN_POLICY_SEGMENTS)
    total = 0
    for part, (codec_name, start, stop) in zip(parts,
                                               GOLDEN_POLICY_SEGMENTS):
        pkt = Packet.from_bytes(part)
        codec = make_codec(codec_name, stop - start, **GOLDEN_CODEC_KW)
        est = codec.decode(pkt)
        assert est.shape == (stop - start,)
        total += stop - start
    assert total == GOLDEN_DIM


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # pragma: no cover - dev extra not installed
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=120, deadline=None)
    @given(name=st.sampled_from(ALL_AGGREGATORS), cut=st.floats(0.0, 1.0))
    def test_truncated_golden_packet_raises(name, cut):
        """A torn frame (any strict prefix of real wire bytes) must raise a
        descriptive ValueError from `Packet.from_bytes` — a TCP transport
        will see exactly these buffers on a mid-frame disconnect."""
        raw = (GOLDEN_DIR / f"{name}.bin").read_bytes()
        n = min(int(cut * len(raw)), len(raw) - 1)
        with pytest.raises(ValueError,
                           match="truncated|corrupt|trailing|magic"):
            Packet.from_bytes(raw[:n])

    @settings(max_examples=120, deadline=None)
    @given(name=st.sampled_from(ALL_AGGREGATORS),
           pos=st.integers(0, 11), val=st.integers(0, 255))
    def test_corrupt_golden_header_never_parses_silently(name, pos, val):
        """Flipping a byte in the magic/id/version/geometry region either
        raises ValueError or yields a packet that still declares a valid
        structure — never an out-of-bounds buffer read or a silent hang."""
        raw = bytearray((GOLDEN_DIR / f"{name}.bin").read_bytes())
        raw[pos] = val ^ raw[pos]
        try:
            pkt = Packet.from_bytes(bytes(raw))
        except ValueError:
            return                    # loudly rejected: the desired outcome
        for s in pkt.streams:         # accepted: geometry must be coherent
            assert 1 <= s.width <= 32
            assert s.words.size * 32 >= s.used_bits


def _regen():
    GOLDEN_DIR.mkdir(exist_ok=True)
    for name in ALL_AGGREGATORS:
        raw = encode_golden(name)
        (GOLDEN_DIR / f"{name}.bin").write_bytes(raw)
        print(f"wrote golden_packets/{name}.bin ({len(raw)} bytes)")
    for name in GOLDEN_DOWNLINK:
        raw = encode_golden_downlink(name)
        (GOLDEN_DIR / f"downlink_{name}.bin").write_bytes(raw)
        print(f"wrote golden_packets/downlink_{name}.bin ({len(raw)} bytes)")
    raw = encode_golden_state_row()
    (GOLDEN_DIR / "state_row_shift.bin").write_bytes(raw)
    print(f"wrote golden_packets/state_row_shift.bin ({len(raw)} bytes)")
    raw = encode_golden_policy_container()
    (GOLDEN_DIR / "policy_container.bin").write_bytes(raw)
    print(f"wrote golden_packets/policy_container.bin ({len(raw)} bytes)")
    print(f"golden policy hash: {golden_policy().hash}")


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
