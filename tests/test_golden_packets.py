"""Golden-packet regression battery: the byte wire format is a compatibility
surface.

One committed snapshot (`tests/golden_packets/<name>.bin`) of an encoded
`Packet` per registry aggregator (EF21 variants snapshot their innovation
codec).  The test re-encodes the same deterministic gradient with the same
keys and asserts `to_bytes()` is BYTE-identical to the snapshot: any change
to the header struct, stream layout, bit-packing order, codec math, or the
PRNG replay breaks decode for packets already on the wire and must be a
deliberate, versioned decision.

Deliberate wire changes on record:

* PR 4 — ``ef21``/``ef21_sgdm`` moved off the Top-k baseline codec onto the
  dedicated `EF21InnovationCodec` (new codec id 14): positions now pack at
  the honest ceil(log2 d) bits the `bits.ef21_bits` ledger books, so those
  two fixtures were regenerated.  The ``mlmc_adaptive_*`` fixtures are new
  (codec ids 15-17).  Every pre-existing non-EF21 fixture is byte-identical.

Regenerate (only when intentionally changing the wire format):

    PYTHONPATH=src python tests/test_golden_packets.py --regen
"""

import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro.comm import Packet, make_codec
from repro.comm.packets import CODEC_IDS
from repro.core.aggregators import ALL_AGGREGATORS

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden_packets"

#: deterministic fixture inputs (MUST never change: part of the snapshots)
GOLDEN_DIM = 257
GOLDEN_CODEC_KW = dict(k_fraction=0.05, s=4)
GOLDEN_GRAD_SEED = 20250728
GOLDEN_KEY_SEED = 42

#: frozen copy of the wire codec-id table at snapshot time.  CODEC_IDS is
#: append-only: every entry here must stay EXACTLY as-is forever; new codecs
#: may only take ids above the frozen range.
FROZEN_CODEC_IDS = {
    "dense": 0, "topk": 1, "randk": 2, "qsgd": 3, "rtn": 4, "fixed2": 5,
    "natural": 6, "signsgd": 7, "mlmc_topk": 8, "mlmc_topk_static": 9,
    "mlmc_stopk": 10, "mlmc_fixed": 11, "mlmc_float": 12, "mlmc_rtn": 13,
}


def golden_grad() -> jax.Array:
    key = jax.random.PRNGKey(GOLDEN_GRAD_SEED)
    return jax.random.normal(key, (GOLDEN_DIM,)) * jnp.exp(
        -0.02 * jnp.arange(GOLDEN_DIM))


def encode_golden(name: str) -> bytes:
    """Deterministic encode for one registry name (key folds in the name's
    position in ALL_AGGREGATORS, which is itself append-only)."""
    codec = make_codec(name, GOLDEN_DIM, **GOLDEN_CODEC_KW)
    key = jax.random.fold_in(jax.random.PRNGKey(GOLDEN_KEY_SEED),
                             ALL_AGGREGATORS.index(name))
    return codec.encode(golden_grad(), key).packet.to_bytes()


@pytest.mark.parametrize("name", ALL_AGGREGATORS)
def test_golden_packet_bytes(name):
    path = GOLDEN_DIR / f"{name}.bin"
    assert path.exists(), \
        f"missing golden fixture {path}; run tests/test_golden_packets.py --regen"
    got = encode_golden(name)
    want = path.read_bytes()
    assert got == want, (
        f"{name}: encoded packet ({len(got)}B) differs from the committed "
        f"snapshot ({len(want)}B) — the wire format changed. If intentional, "
        "bump the packet version and regenerate the fixtures.")


@pytest.mark.parametrize("name", ALL_AGGREGATORS)
def test_golden_packet_still_decodes(name):
    """The committed bytes must parse and decode to a dim-sized estimate."""
    pkt = Packet.from_bytes((GOLDEN_DIR / f"{name}.bin").read_bytes())
    codec = make_codec(name, GOLDEN_DIM, **GOLDEN_CODEC_KW)
    est = codec.decode(pkt)
    assert est.shape == (GOLDEN_DIM,)


def test_codec_ids_append_only():
    """Wire codec ids are a compatibility surface: frozen entries immutable,
    new entries only above the frozen range, ids unique."""
    for name, cid in FROZEN_CODEC_IDS.items():
        assert CODEC_IDS.get(name) == cid, \
            f"CODEC_IDS[{name!r}] changed from {cid} to {CODEC_IDS.get(name)}"
    ids = list(CODEC_IDS.values())
    assert len(ids) == len(set(ids)), "duplicate codec ids"
    frozen_max = max(FROZEN_CODEC_IDS.values())
    for name, cid in CODEC_IDS.items():
        if name not in FROZEN_CODEC_IDS:
            assert cid > frozen_max, \
                f"new codec {name!r} must take an id above {frozen_max}"


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # pragma: no cover - dev extra not installed
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=120, deadline=None)
    @given(name=st.sampled_from(ALL_AGGREGATORS), cut=st.floats(0.0, 1.0))
    def test_truncated_golden_packet_raises(name, cut):
        """A torn frame (any strict prefix of real wire bytes) must raise a
        descriptive ValueError from `Packet.from_bytes` — a TCP transport
        will see exactly these buffers on a mid-frame disconnect."""
        raw = (GOLDEN_DIR / f"{name}.bin").read_bytes()
        n = min(int(cut * len(raw)), len(raw) - 1)
        with pytest.raises(ValueError,
                           match="truncated|corrupt|trailing|magic"):
            Packet.from_bytes(raw[:n])

    @settings(max_examples=120, deadline=None)
    @given(name=st.sampled_from(ALL_AGGREGATORS),
           pos=st.integers(0, 11), val=st.integers(0, 255))
    def test_corrupt_golden_header_never_parses_silently(name, pos, val):
        """Flipping a byte in the magic/id/version/geometry region either
        raises ValueError or yields a packet that still declares a valid
        structure — never an out-of-bounds buffer read or a silent hang."""
        raw = bytearray((GOLDEN_DIR / f"{name}.bin").read_bytes())
        raw[pos] = val ^ raw[pos]
        try:
            pkt = Packet.from_bytes(bytes(raw))
        except ValueError:
            return                    # loudly rejected: the desired outcome
        for s in pkt.streams:         # accepted: geometry must be coherent
            assert 1 <= s.width <= 32
            assert s.words.size * 32 >= s.used_bits


def _regen():
    GOLDEN_DIR.mkdir(exist_ok=True)
    for name in ALL_AGGREGATORS:
        raw = encode_golden(name)
        (GOLDEN_DIR / f"{name}.bin").write_bytes(raw)
        print(f"wrote golden_packets/{name}.bin ({len(raw)} bytes)")


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
