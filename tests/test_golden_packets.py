"""Golden-packet regression battery: the byte wire format is a compatibility
surface.

One committed snapshot (`tests/golden_packets/<name>.bin`) of an encoded
`Packet` per registry aggregator (all 17 names — EF21 variants snapshot
their innovation codec).  The test re-encodes the same deterministic
gradient with the same keys and asserts `to_bytes()` is BYTE-identical to
the snapshot: any change to the header struct, stream layout, bit-packing
order, codec math, or the PRNG replay breaks decode for packets already on
the wire and must be a deliberate, versioned decision.

Regenerate (only when intentionally changing the wire format):

    PYTHONPATH=src python tests/test_golden_packets.py --regen
"""

import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro.comm import Packet, make_codec
from repro.comm.packets import CODEC_IDS
from repro.core.aggregators import ALL_AGGREGATORS

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden_packets"

#: deterministic fixture inputs (MUST never change: part of the snapshots)
GOLDEN_DIM = 257
GOLDEN_CODEC_KW = dict(k_fraction=0.05, s=4)
GOLDEN_GRAD_SEED = 20250728
GOLDEN_KEY_SEED = 42

#: frozen copy of the wire codec-id table at snapshot time.  CODEC_IDS is
#: append-only: every entry here must stay EXACTLY as-is forever; new codecs
#: may only take ids above the frozen range.
FROZEN_CODEC_IDS = {
    "dense": 0, "topk": 1, "randk": 2, "qsgd": 3, "rtn": 4, "fixed2": 5,
    "natural": 6, "signsgd": 7, "mlmc_topk": 8, "mlmc_topk_static": 9,
    "mlmc_stopk": 10, "mlmc_fixed": 11, "mlmc_float": 12, "mlmc_rtn": 13,
}


def golden_grad() -> jax.Array:
    key = jax.random.PRNGKey(GOLDEN_GRAD_SEED)
    return jax.random.normal(key, (GOLDEN_DIM,)) * jnp.exp(
        -0.02 * jnp.arange(GOLDEN_DIM))


def encode_golden(name: str) -> bytes:
    """Deterministic encode for one registry name (key folds in the name's
    position in ALL_AGGREGATORS, which is itself append-only)."""
    codec = make_codec(name, GOLDEN_DIM, **GOLDEN_CODEC_KW)
    key = jax.random.fold_in(jax.random.PRNGKey(GOLDEN_KEY_SEED),
                             ALL_AGGREGATORS.index(name))
    return codec.encode(golden_grad(), key).packet.to_bytes()


@pytest.mark.parametrize("name", ALL_AGGREGATORS)
def test_golden_packet_bytes(name):
    path = GOLDEN_DIR / f"{name}.bin"
    assert path.exists(), \
        f"missing golden fixture {path}; run tests/test_golden_packets.py --regen"
    got = encode_golden(name)
    want = path.read_bytes()
    assert got == want, (
        f"{name}: encoded packet ({len(got)}B) differs from the committed "
        f"snapshot ({len(want)}B) — the wire format changed. If intentional, "
        "bump the packet version and regenerate the fixtures.")


@pytest.mark.parametrize("name", ALL_AGGREGATORS)
def test_golden_packet_still_decodes(name):
    """The committed bytes must parse and decode to a dim-sized estimate."""
    pkt = Packet.from_bytes((GOLDEN_DIR / f"{name}.bin").read_bytes())
    codec = make_codec(name, GOLDEN_DIM, **GOLDEN_CODEC_KW)
    est = codec.decode(pkt)
    assert est.shape == (GOLDEN_DIM,)


def test_codec_ids_append_only():
    """Wire codec ids are a compatibility surface: frozen entries immutable,
    new entries only above the frozen range, ids unique."""
    for name, cid in FROZEN_CODEC_IDS.items():
        assert CODEC_IDS.get(name) == cid, \
            f"CODEC_IDS[{name!r}] changed from {cid} to {CODEC_IDS.get(name)}"
    ids = list(CODEC_IDS.values())
    assert len(ids) == len(set(ids)), "duplicate codec ids"
    frozen_max = max(FROZEN_CODEC_IDS.values())
    for name, cid in CODEC_IDS.items():
        if name not in FROZEN_CODEC_IDS:
            assert cid > frozen_max, \
                f"new codec {name!r} must take an id above {frozen_max}"


def _regen():
    GOLDEN_DIR.mkdir(exist_ok=True)
    for name in ALL_AGGREGATORS:
        raw = encode_golden(name)
        (GOLDEN_DIR / f"{name}.bin").write_bytes(raw)
        print(f"wrote golden_packets/{name}.bin ({len(raw)} bytes)")


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
