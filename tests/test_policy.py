"""Per-leaf `CodecPolicy` battery: rules, resolution, and cross-wire parity.

The policy stack's whole correctness story mirrors the bucket plan's ONE
invariant, lifted to heterogeneous codecs: segment ``b`` of a flat
gradient encodes bitwise identically to a standalone flat codec of the
segment's size under the folded key ``fold_in(worker_key, b)``, on EVERY
substrate.  So the abstract per-segment reference, the packed RCBW
multi-stream container, the device wire's fixed-shape per-segment
round-trip, and the tcp star must all produce the SAME direction bitwise
— and a one-segment policy must be indistinguishable from not passing a
policy at all.
"""

import socket
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.aggregate import _make_packed_codec
from repro.comm.multihost import TcpStarTransport
from repro.comm.plan import WirePlan, policy_packed_aggregator
from repro.comm.policy import (
    POLICY_PRESETS,
    CodecPolicy,
    PolicyRule,
    ResolvedPolicy,
    Segment,
    as_resolved,
    leaf_paths,
    segment_codec_kw,
)
from repro.core.aggregators import filter_codec_kw, make_aggregator

DIM = 300
WORKERS = 3
CODEC_KW = dict(k_fraction=0.1, s=8)

#: a 3-leaf tree whose flat order ("a/embed", "a/w", "norm") exercises
#: path globs, size rules, and adjacent-merge at once
TREE = {"a": {"embed": jnp.zeros((64,)), "w": jnp.zeros((8, 16))},
        "norm": jnp.zeros((4,))}

#: heterogeneous segments over a flat DIM-vector (dense / qsgd / mlmc)
HET = ResolvedPolicy(DIM, (Segment("dense@0", "dense", 0, 64),
                           Segment("qsgd@64", "qsgd", 64, 192),
                           Segment("mlmc_topk@192", "mlmc_topk", 192, DIM)))


def _grads(dim: int = DIM, m: int = WORKERS) -> jax.Array:
    g = jax.random.normal(jax.random.PRNGKey(3), (m, dim), jnp.float32)
    return g * jnp.exp(-5.0 * jnp.arange(dim) / dim)


def _sockets_available() -> bool:
    try:
        with socket.socket() as s:
            s.bind(("127.0.0.1", 0))
        return True
    except OSError:               # pragma: no cover - sandboxed environments
        return False


needs_sockets = pytest.mark.skipif(not _sockets_available(),
                                   reason="localhost sockets unavailable")


# ---------------------------------------------------------------------------
# rules, parsing, resolution
# ---------------------------------------------------------------------------


def test_leaf_paths_flat_order():
    assert leaf_paths(TREE) == [("a/embed", 64), ("a/w", 128), ("norm", 4)]
    assert leaf_paths(jnp.zeros((7,))) == [("flat", 7)]


def test_parse_forms_agree():
    """Preset name, spec string, dict, and rule list all parse to the
    same resolution."""
    want = CodecPolicy.parse({"*embed*": "dense", "*norm*": "dense",
                              "*": "mlmc_topk"}).resolve(TREE)
    for spec in ("dense_embed_norm",
                 "*embed*=dense, *norm*=dense, *=mlmc_topk",
                 [PolicyRule("*embed*", "dense"), PolicyRule("*norm*", "dense"),
                  PolicyRule("*", "mlmc_topk")]):
        assert CodecPolicy.parse(spec).resolve(TREE) == want
    # a CodecPolicy passes through untouched
    pol = CodecPolicy.parse("dense_embed_norm")
    assert CodecPolicy.parse(pol) is pol


def test_parse_rejects_malformed_rules():
    with pytest.raises(ValueError, match="pattern=codec"):
        CodecPolicy.parse("mlmc_topk")       # not a preset, no '='
    with pytest.raises(ValueError, match="at least one rule"):
        CodecPolicy.parse(",")


def test_size_rules_and_first_match_wins():
    pol = CodecPolicy.parse({"size<=64": "dense", "a/*": "qsgd",
                             "*": "mlmc_topk"})
    # a/embed (64) hits the size rule BEFORE the a/* glob; a/w (128)
    # falls through to a/*; norm (4) hits the size rule
    assert [c for _, c, _ in pol.leaf_specs(TREE)] == \
        ["dense", "qsgd", "dense"]
    for pattern, size, want in (("size<64", 64, False), ("size<64", 63, True),
                                ("size>=128", 128, True), ("size>4", 4, False),
                                ("size==4", 4, True)):
        assert PolicyRule(pattern, "dense").matches("x", size) is want


def test_no_match_raises_with_hint():
    with pytest.raises(ValueError, match="catch-all"):
        CodecPolicy.parse({"*embed*": "dense"}).resolve(TREE)


def test_resolve_merges_adjacent_identical_assignments():
    res = CodecPolicy.parse({"a/*": "dense", "*": "mlmc_topk"}).resolve(TREE)
    assert [(s.codec, s.start, s.stop) for s in res.segments] == \
        [("dense", 0, 192), ("mlmc_topk", 192, 196)]
    # differing per-segment params block the merge
    res = CodecPolicy.parse(
        {"a/embed": ("qsgd", {"qsgd_levels": 8}), "a/w": "qsgd",
         "*": "qsgd"}).resolve(TREE)
    assert [(s.codec, s.size) for s in res.segments] == \
        [("qsgd", 64), ("qsgd", 132)]
    assert dict(res.segments[0].params) == {"qsgd_levels": 8}


def test_resolved_policy_validates_tiling():
    with pytest.raises(ValueError, match="tile"):
        ResolvedPolicy(10, (Segment("a", "dense", 0, 4),
                            Segment("b", "dense", 5, 10)))
    with pytest.raises(ValueError, match="dim"):
        ResolvedPolicy(10, (Segment("a", "dense", 0, 4),))


def test_uniform_flag_and_as_resolved():
    assert CodecPolicy.parse("uniform_dense").resolve_flat(DIM).is_uniform
    assert not HET.is_uniform
    assert HET.codecs == ("dense", "qsgd", "mlmc_topk")
    assert as_resolved(None, DIM) is None
    assert as_resolved(HET, DIM) is HET
    with pytest.raises(ValueError, match="dim"):
        as_resolved(HET, DIM + 1)
    assert as_resolved("uniform_dense", 8).segments[0].codec == "dense"


def test_hash_is_stable_and_discriminates():
    assert HET.hash == ResolvedPolicy(DIM, HET.segments).hash
    assert len(HET.hash) == 16
    other = ResolvedPolicy(DIM, (Segment("dense@0", "dense", 0, DIM),))
    assert HET.hash != other.hash
    # params participate in the fingerprint
    a = CodecPolicy.parse({"*": ("qsgd", {"qsgd_levels": 2})}).resolve(TREE)
    b = CodecPolicy.parse({"*": ("qsgd", {"qsgd_levels": 8})}).resolve(TREE)
    assert a.hash != b.hash


def test_subdivide_composes_with_buckets():
    sub = HET.subdivide(100)
    assert [(s.codec, s.start, s.stop) for s in sub.segments] == \
        [("dense", 0, 64), ("qsgd", 64, 164), ("qsgd", 164, 192),
         ("mlmc_topk", 192, 292), ("mlmc_topk", 292, 300)]
    assert sub.dim == DIM                    # still tiles exactly


def test_segment_codec_kw_rescales_s():
    seg = Segment("m", "mlmc_topk", 0, 30, params=(("k_fraction", 0.5),))
    kw = segment_codec_kw(dict(s=30, k_fraction=0.1), seg, DIM)
    assert kw["s"] == 3                      # 30 * 30/300
    assert kw["k_fraction"] == 0.5           # rule params override
    # s<=1 is left alone (not a dim-derived budget)
    assert segment_codec_kw(dict(s=1), seg, DIM)["s"] == 1


# ---------------------------------------------------------------------------
# the degenerate one-segment policy == no policy at all
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire", ["abstract", "packed", "device"])
def test_uniform_policy_is_bitwise_noop(wire):
    grads, rng = _grads(), jax.random.PRNGKey(7)
    plain = make_aggregator("mlmc_topk", DIM, **CODEC_KW, wire=wire)
    pol = make_aggregator("mlmc_topk", DIM, **CODEC_KW, wire=wire,
                          policy={"*": "mlmc_topk"})
    a, b = plain(grads, rng, None), pol(grads, rng, None)
    assert np.array_equal(np.asarray(a.direction), np.asarray(b.direction))
    assert float(a.bits) == float(b.bits)
    # the policy's codec supersedes `name`
    named = make_aggregator("qsgd", DIM, **CODEC_KW, wire=wire,
                            policy="uniform_mlmc_topk")
    c = named(grads, rng, None)
    assert np.array_equal(np.asarray(a.direction), np.asarray(c.direction))


# ---------------------------------------------------------------------------
# cross-wire parity: abstract == packed == device == tcp, bitwise
# ---------------------------------------------------------------------------


def _run_policy(wire, policy=HET, transport=None):
    ag = make_aggregator("mlmc_topk", DIM, **CODEC_KW, wire=wire,
                         policy=policy, transport=transport)
    return ag(_grads(), jax.random.PRNGKey(7), None)


def test_heterogeneous_policy_cross_wire_bitwise():
    outs = {w: _run_policy(w) for w in ("abstract", "packed")}
    a = np.asarray(outs["abstract"].direction)
    assert np.array_equal(a, np.asarray(outs["packed"].direction))
    # the device wire joins the bitwise matrix for the exact codecs
    # (mlmc_topk ships bf16 values on the device wire by default, so its
    # segments are allclose-not-bitwise there)
    exact = ResolvedPolicy(DIM, (Segment("dense@0", "dense", 0, 64),
                                 Segment("qsgd@64", "qsgd", 64, 192),
                                 Segment("rtn@192", "rtn", 192, DIM)))
    exact_outs = {w: _run_policy(w, policy=exact)
                  for w in ("abstract", "packed", "device")}
    e = np.asarray(exact_outs["abstract"].direction)
    for wire in ("packed", "device"):
        assert np.array_equal(e, np.asarray(exact_outs[wire].direction)), wire
    dev = np.asarray(_run_policy("device").direction)
    np.testing.assert_allclose(a, dev, rtol=1e-2, atol=1e-3)
    # bits are per-wire MEASURED quantities (packet headers / static
    # operand sizes differ), but every wire books something positive
    for out in (*outs.values(), *exact_outs.values()):
        assert float(out.bits) > 0


def test_policy_segments_match_standalone_flat_codecs_bitwise():
    """THE invariant, packed realization: each segment's container bytes
    == a standalone flat codec of the segment's size with the folded
    key."""
    grads = _grads()
    rng = jax.random.PRNGKey(7)
    keys = jax.random.split(rng, WORKERS)
    ag = policy_packed_aggregator(HET, DIM, codec_kw=dict(CODEC_KW))
    plan: WirePlan = ag.fn.plan
    packets = plan.encode_round(grads, keys)
    for b, seg in enumerate(HET.segments):
        flat = _make_packed_codec(seg.codec, seg.size, None,
                                  segment_codec_kw(dict(CODEC_KW), seg, DIM))
        for w in range(WORKERS):
            ref = flat.encode(grads[w, seg.start:seg.stop],
                              jax.random.fold_in(keys[w], b)).packet
            assert packets[b][w].to_bytes() == ref.to_bytes(), (seg.name, w)


def test_policy_abstract_matches_per_segment_reference():
    """The abstract wire against a hand-rolled per-segment mean with the
    same kernels and folded keys — the reference the other wires chase."""
    from repro.core.aggregators import _stateless_fn

    grads = _grads()
    rng = jax.random.PRNGKey(7)
    keys = jax.random.split(rng, WORKERS)
    parts, bits = [], 0.0
    for b, seg in enumerate(HET.segments):
        f = _stateless_fn(seg.codec, seg.size,
                          **segment_codec_kw(dict(CODEC_KW), seg, DIM))
        outs = [f(grads[w, seg.start:seg.stop],
                  jax.random.fold_in(keys[w], b)) for w in range(WORKERS)]
        parts.append(np.asarray(jnp.mean(jnp.stack([o[0] for o in outs]),
                                         axis=0)))
        bits += float(sum(o[1] for o in outs))
    out = _run_policy("abstract")
    assert np.array_equal(np.asarray(out.direction), np.concatenate(parts))
    assert float(out.bits) == bits


@needs_sockets
def test_policy_over_tcp_matches_loopback_bitwise():
    """The tcp realization ships ONE RCBW multi-stream container per rank
    and reproduces the in-process direction and bits exactly."""
    ref_ag = make_aggregator("mlmc_topk", DIM, **CODEC_KW, wire="packed",
                             policy=HET)
    ref = ref_ag(_grads(), jax.random.PRNGKey(7), None)
    world = WORKERS
    tps = _connect_world(world)
    grads = _grads()
    outs = {}

    def run_rank(r):
        ag = make_aggregator("mlmc_topk", DIM, **CODEC_KW, wire="packed",
                             policy=HET, transport=tps[r])
        outs[r] = ag(grads[r:r + 1], jax.random.PRNGKey(7), None)

    threads = [threading.Thread(target=run_rank, args=(r,))
               for r in range(1, world)]
    for t in threads:
        t.start()
    run_rank(0)
    for t in threads:
        t.join()
    for r in range(world):
        assert np.array_equal(np.asarray(outs[r].direction),
                              np.asarray(ref.direction)), f"rank {r}"
        assert float(outs[r].bits) == float(ref.bits)
    assert tps[0].stats.bytes_up == ref_ag.fn.transport.stats.bytes_up
    for t in tps.values():
        t.close()


def _connect_world(world, timeout=15.0, policy_hash=None):
    server = TcpStarTransport.listen(port=0, world=world, timeout=timeout,
                                     policy_hash=policy_hash)
    tps = {0: server}

    def join(r):
        tps[r] = TcpStarTransport.connect(
            "127.0.0.1", server.port, rank=r, world=world, timeout=timeout,
            policy_hash=policy_hash)

    threads = [threading.Thread(target=join, args=(r,))
               for r in range(1, world)]
    for t in threads:
        t.start()
    server.accept_workers()
    for t in threads:
        t.join()
    return tps


# ---------------------------------------------------------------------------
# wire accounting: measured bits reconcile with transport bytes
# ---------------------------------------------------------------------------


def test_segment_bits_sum_to_transport_frame_bytes():
    """Per-stream accounting is EXACT: the segments' measured bits sum to
    the aggregate's bits, and the transport's booked uplink bytes equal
    the packets' serialized bytes plus the RCBW container overhead (8-byte
    header + one u32 length prefix per stream, per worker) to the byte."""
    grads = _grads()
    rng = jax.random.PRNGKey(7)
    ag = policy_packed_aggregator(HET, DIM, codec_kw=dict(CODEC_KW))
    plan: WirePlan = ag.fn.plan
    keys = jax.random.split(rng, WORKERS)
    packets = plan.encode_round(grads, keys)
    seg_bits = plan.segment_bits(packets)
    assert sum(seg_bits) == plan.measured_bits(packets)
    out = ag(grads, rng, None)
    assert float(out.bits) == sum(seg_bits)
    n_seg = len(HET.segments)
    packet_bytes = sum(len(packets[b][w].to_bytes())
                       for b in range(n_seg) for w in range(WORKERS))
    overhead = (8 + 4 * n_seg) * WORKERS
    assert ag.fn.transport.stats.bytes_up == packet_bytes + overhead


def test_policy_records_per_segment_telemetry():
    from repro.obs import trace as obs

    tel = obs.install(obs.Telemetry(enabled=True))
    try:
        _run_policy("packed")
    finally:
        obs.install(None)
    rows = {(r["labels"]["segment"], r["labels"]["codec"]): r["value"]
            for r in tel.metrics.snapshot()
            if r["name"] == "wire_segment_bits"}
    assert set(rows) == {(s.name, s.codec) for s in HET.segments}
    assert all(v > 0 for v in rows.values())


# ---------------------------------------------------------------------------
# construction-time guard rails
# ---------------------------------------------------------------------------


def test_stateful_segments_rejected_on_every_wire():
    # an explicit ResolvedPolicy: against a FLAT dim-vector, path/size
    # rules always resolve uniform (one "flat" leaf), so rule dicts
    # cannot express multi-segment policies at the aggregator level
    bad = ResolvedPolicy(DIM, (Segment("ef21@0", "ef21", 0, 64),
                               Segment("m@64", "mlmc_topk", 64, DIM)))
    for wire in ("abstract", "packed", "device"):
        with pytest.raises(ValueError,
                           match="whole flat gradient|stateful"):
            make_aggregator("mlmc_topk", DIM, **CODEC_KW, wire=wire,
                            policy=bad)


def test_codec_kwargs_typeerror_and_filter():
    """Satellite regression: an explicitly passed codec kwarg nobody
    consumes raises, `filter_codec_kw` pre-filters heterogeneous sets,
    and policy codecs count as consumers."""
    with pytest.raises(TypeError, match="qsgd_levels"):
        make_aggregator("dense", DIM, qsgd_levels=8)
    # a policy whose segments include qsgd legitimizes the same kwarg
    make_aggregator("dense", DIM, **CODEC_KW, qsgd_levels=8, policy=HET)
    kw = filter_codec_kw(dict(qsgd_levels=8, rtn_level=4, momentum_beta=None),
                         "qsgd", "dense")
    assert kw == {"qsgd_levels": 8}
    # k_fraction / s stay lenient (every family accepts them)
    assert filter_codec_kw(dict(k_fraction=0.1, s=4), "dense") == \
        {"k_fraction": 0.1, "s": 4}


def test_trainer_accepts_blanket_kwargs_with_policy():
    """The Trainer passes its full knob set for ANY method/policy — the
    filter, not the caller, drops what the selected codecs don't eat."""
    from repro.optim import sgd
    from repro.train import Trainer

    params = {"w": jnp.zeros((48,)), "b": jnp.zeros(())}

    def loss_fn(p, batch):
        return jnp.mean((batch["x"] @ p["w"] + p["b"] - batch["y"]) ** 2)

    for method, policy in (("dense", None),
                           ("mlmc_topk", {"b": "dense", "*": "mlmc_topk"})):
        tr = Trainer(loss_fn, params, num_workers=2, method=method,
                     optimizer=sgd(0.1), k_fraction=0.25, wire="packed",
                     policy=policy)
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 48))
        batch = {"x": x, "y": jnp.zeros((2, 4))}
        hist = tr.fit(iter([batch, batch]), steps=2, seed=0)
        assert np.isfinite(hist.loss).all()
        if policy is not None:
            assert len(tr.policy.segments) == 2


# ---------------------------------------------------------------------------
# jit hygiene: the abstract policy path traces once, no callbacks
# ---------------------------------------------------------------------------


def test_policy_abstract_traces_once():
    ag = make_aggregator("mlmc_topk", DIM, **CODEC_KW, wire="abstract",
                         policy=HET)
    calls = {"n": 0}

    def fn(grads, rng):
        calls["n"] += 1
        out = ag(grads, rng, None)
        return out.direction, out.bits

    jfn = jax.jit(fn)
    for i in range(3):
        d, b = jfn(_grads() + i, jax.random.PRNGKey(i))
        jax.block_until_ready(d)
    assert calls["n"] == 1, "policy abstract path must not retrace"


# ---------------------------------------------------------------------------
# HELLO handshake: policy fingerprints must agree at rendezvous
# ---------------------------------------------------------------------------


@needs_sockets
def test_tcp_handshake_rejects_policy_mismatch():
    server = TcpStarTransport.listen(port=0, world=2, timeout=15,
                                     policy_hash=HET.hash)
    errors = {}

    def bad_then_good():
        other = ResolvedPolicy(DIM, (Segment("dense@0", "dense", 0, DIM),))
        try:
            TcpStarTransport.connect("127.0.0.1", server.port, rank=1,
                                     world=2, timeout=5,
                                     policy_hash=other.hash)
        except ConnectionError as e:
            errors["bad"] = str(e)
        try:
            TcpStarTransport.connect("127.0.0.1", server.port, rank=1,
                                     world=2, timeout=5)     # no policy
        except ConnectionError as e:
            errors["none"] = str(e)
        errors["good"] = TcpStarTransport.connect(
            "127.0.0.1", server.port, rank=1, world=2, timeout=10,
            policy_hash=HET.hash)

    t = threading.Thread(target=bad_then_good)
    t.start()
    server.accept_workers()
    t.join()
    assert "policy mismatch" in errors["bad"]
    assert "policy mismatch" in errors["none"]
    errors["good"].close()
    server.close()
