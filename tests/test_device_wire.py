"""repro.comm.device_wire: jit-native fixed-shape packed packets.

Load-bearing assertions (the fast, single-device half of the cross-wire
parity matrix — the >=4-device mesh half lives in `distributed_worker.py`
behind the `slow` marker):

* device codecs round-trip: ``decode(encode(v))`` equals the abstract
  estimate elementwise (IEEE-equal) for every fixed-shape family; the
  mlmc_topk bf16 value stream is exact vs its own bf16 estimate and within
  bf16 rounding of the f32 abstract estimate;
* ``make_aggregator(wire="device")`` == ``wire="abstract"`` under jit;
* static packet operand bits reconcile with the `repro.core.bits` ledger
  inside each codec's documented bounds;
* the whole path traces with NO host callbacks (jit-native by
  construction, unlike ``wire="packed"``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.device_wire import (
    DEVICE_WIRE_METHODS,
    MLMCTopKDeviceCodec,
    make_device_codec,
)
from repro.core.aggregators import make_aggregator
from repro.train import Trainer

jax.config.update("jax_platform_name", "cpu")

D = 257
CODEC_KW = dict(k_fraction=0.05, s=4, qsgd_levels=2, rtn_level=4)
#: families whose device wire replays the abstract f32 math bit-for-bit;
#: mlmc_topk* ship bf16 values (2/word) and are asserted separately
EXACT_METHODS = ("dense", "qsgd", "rtn", "signsgd", "mlmc_fixed",
                 "mlmc_float")


def _grad(d=D, seed=0):
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, (d,)) * jnp.exp(-0.02 * jnp.arange(d))


@pytest.fixture(scope="module")
def grad():
    return _grad()


# ---------------------------------------------------------------------------
# codec round-trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", EXACT_METHODS)
def test_device_roundtrip_exact(name, grad):
    codec = make_device_codec(name, D, **CODEC_KW)
    roundtrip = jax.jit(lambda v, k: codec.decode(codec.encode(v, k)[0]))
    reference = jax.jit(lambda v, k: codec.encode(v, k)[1])
    for trial in range(4):
        key = jax.random.fold_in(jax.random.PRNGKey(1), trial)
        np.testing.assert_array_equal(
            np.asarray(roundtrip(grad, key)),
            np.asarray(reference(grad, key)), err_msg=f"{name} {trial}")


@pytest.mark.parametrize("name", ("mlmc_topk", "mlmc_topk_static",
                                  "mlmc_stopk"))
def test_device_topk_f32_roundtrip_exact(name, grad):
    """With a 32-bit value stream the segment codec is IEEE-exact."""
    codec = make_device_codec(name, D, **CODEC_KW, topk_value_bits=32)
    roundtrip = jax.jit(lambda v, k: codec.decode(codec.encode(v, k)[0]))
    reference = jax.jit(lambda v, k: codec.encode(v, k)[1])
    for trial in range(4):
        key = jax.random.fold_in(jax.random.PRNGKey(2), trial)
        np.testing.assert_array_equal(
            np.asarray(roundtrip(grad, key)),
            np.asarray(reference(grad, key)), err_msg=f"{name} {trial}")


def test_device_topk_bf16_rounding_only(grad):
    """Default bf16 values: decoded == per-entry bf16 rounding of the
    abstract estimate, nothing more."""
    codec = MLMCTopKDeviceCodec(D, 13, adaptive=True, value_bits=16)
    key = jax.random.PRNGKey(3)
    fn = jax.jit(lambda v, k: codec.encode(v, k) + (codec.decode(
        codec.encode(v, k)[0]),))
    _, est, dec = fn(grad, key)
    est, dec = np.asarray(est), np.asarray(dec)
    want = np.asarray(jnp.asarray(est).astype(jnp.bfloat16)
                      .astype(jnp.float32))
    np.testing.assert_array_equal(dec, want)


def test_device_packet_shapes_static(grad):
    """Fixed-shape contract: packet arrays depend only on the codec config,
    never on the data or the sampled level."""
    for name in DEVICE_WIRE_METHODS:
        codec = make_device_codec(name, D, **CODEC_KW)
        for seed in (0, 1, 2):
            pkt, _ = codec.encode(_grad(seed=seed),
                                  jax.random.PRNGKey(seed))
            assert pkt.words.shape == (codec.words_len,), name
            assert pkt.words.dtype == jnp.uint32
            assert pkt.lane.shape == (4,) and pkt.lane.dtype == jnp.float32


def test_lane_bridges_to_host_header(grad):
    """The device header lane maps onto a host `Header` (the byte-wire
    family): scale/prob/level survive the bridge bit-exactly."""
    from repro.comm.packets import lane_to_header

    codec = make_device_codec("mlmc_fixed", D, **CODEC_KW)
    pkt, _ = codec.encode(grad, jax.random.PRNGKey(5))
    hdr = lane_to_header("mlmc_fixed", D, np.asarray(pkt.lane))
    assert hdr.codec == "mlmc_fixed" and hdr.dim == D
    assert 1 <= hdr.level <= codec.compressor.num_levels
    assert hdr.scale == float(pkt.lane[0]) and hdr.prob == float(pkt.lane[1])


def test_zero_gradient_roundtrip():
    v = jnp.asarray(np.array([0.0, -1.5, 0.0, 2.5, -0.0, 1e-8] * 20,
                             np.float32))
    for name in EXACT_METHODS:
        codec = make_device_codec(name, v.shape[0], **CODEC_KW)
        pkt, est = codec.encode(v, jax.random.PRNGKey(4))
        np.testing.assert_array_equal(np.asarray(codec.decode(pkt)),
                                      np.asarray(est), err_msg=name)


# ---------------------------------------------------------------------------
# ledger reconciliation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", DEVICE_WIRE_METHODS)
def test_device_bits_reconcile(name):
    """Static packet operand bits sit inside the documented bounds around
    the `repro.core.bits` ledger value."""
    codec = make_device_codec(name, D, **CODEC_KW)
    lo, hi = codec.reconcile_bounds()
    measured = codec.operand_bits()
    assert lo <= measured <= hi, (name, measured, (lo, hi))
    # packing must never undercut the ledger's information content by more
    # than the documented header slack
    assert measured >= codec.nominal_bits() - 32.0 * 4


# ---------------------------------------------------------------------------
# aggregator parity + jit-nativeness
# ---------------------------------------------------------------------------


def _jit_direction(agg, g, rng):
    return np.asarray(jax.jit(agg.fn)(g, rng, None).direction)


@pytest.mark.parametrize("name", EXACT_METHODS)
def test_device_aggregator_matches_abstract_exactly(name):
    d, m = 193, 3
    g = jax.random.normal(jax.random.PRNGKey(7), (m, d)) \
        * jnp.exp(-0.05 * jnp.arange(d))
    a_abs = make_aggregator(name, d, k_fraction=0.05, s=4)
    a_dev = make_aggregator(name, d, k_fraction=0.05, s=4, wire="device")
    for step in range(2):
        rng = jax.random.fold_in(jax.random.PRNGKey(8), step)
        np.testing.assert_array_equal(
            _jit_direction(a_dev, g, rng), _jit_direction(a_abs, g, rng),
            err_msg=name)


@pytest.mark.parametrize("name", ("mlmc_topk", "mlmc_topk_static",
                                  "mlmc_stopk"))
def test_device_topk_aggregator_is_bf16_of_abstract(name):
    """The bf16 value stream is the ONLY deviation: the device direction
    equals the mean of the per-worker abstract estimates rounded through
    bf16 — exactly (and is hence within bf16 rounding of the abstract
    direction per worker)."""
    from repro.core.aggregators import mlmc_topk_segment
    from repro.core.mlmc import mlmc_estimate
    from repro.core.topk import STopKMultilevel

    d, m = 193, 3
    g = jax.random.normal(jax.random.PRNGKey(7), (m, d)) \
        * jnp.exp(-0.05 * jnp.arange(d))
    a_dev = make_aggregator(name, d, k_fraction=0.05, s=4, wire="device")
    comp = STopKMultilevel(
        d=d, s=mlmc_topk_segment(name, max(1, round(0.05 * d)), 4))
    adaptive = name != "mlmc_topk_static"

    @jax.jit
    def reference(gg, rng):
        keys = jax.random.split(rng, m)
        ests = jax.vmap(lambda v, k: mlmc_estimate(
            comp, v, k, adaptive=adaptive).estimate)(gg, keys)
        return jnp.mean(ests.astype(jnp.bfloat16).astype(jnp.float32),
                        axis=0)

    rng = jax.random.PRNGKey(9)
    np.testing.assert_array_equal(
        _jit_direction(a_dev, g, rng), np.asarray(reference(g, rng)),
        err_msg=name)


@pytest.mark.parametrize("name", DEVICE_WIRE_METHODS)
def test_device_aggregator_traces_without_callbacks(name):
    """The device wire must be pure device code: no pure_callback /
    io_callback / debug_callback anywhere in the closed jaxpr."""
    d, m = 129, 2
    agg = make_aggregator(name, d, k_fraction=0.05, s=4, wire="device")
    g = jnp.zeros((m, d), jnp.float32)
    jaxpr = jax.make_jaxpr(lambda gg, r: agg.fn(gg, r, None))(
        g, jax.random.PRNGKey(0))

    def prims(jx):
        for eqn in jx.eqns:
            yield str(eqn.primitive)
            for p in eqn.params.values():
                inner = getattr(p, "jaxpr", None)
                if inner is not None:
                    yield from prims(inner)
    assert not [p for p in prims(jaxpr.jaxpr) if "callback" in p], name


def test_device_wire_unsupported_methods_raise():
    # ef21 / ef21_sgdm / mlmc_adaptive_topk got fixed-shape device codecs
    # in the stateful-pipeline refactor, mlmc_float in the sort-free
    # selection PR, and all are tested above; the variable-length
    # families still live on the host byte wire only
    for name in ("topk", "randk", "natural", "mlmc_rtn",
                 "mlmc_adaptive_rtn", "signsgd_ef", "fixed2"):
        with pytest.raises(ValueError):
            make_aggregator(name, 64, wire="device")
    with pytest.raises(ValueError):
        make_aggregator("qsgd", 64, wire="device", transport=object())


def test_device_trainer_end_to_end():
    """Trainer(wire='device'): the WHOLE step stays one jit (unlike the
    host-side packed wire)."""
    from repro.optim import sgd

    d, m, b = 32, 2, 4
    params = {"w": jnp.zeros((d,))}

    def loss_fn(p, batch):
        return jnp.mean((batch @ p["w"] - 1.0) ** 2)

    trainer = Trainer(loss_fn, params, num_workers=m, method="mlmc_fixed",
                      optimizer=sgd(0.1), k_fraction=0.25, wire="device")
    assert trainer.transport is None   # arrays through the mesh, no host hop

    def batches():
        key = jax.random.PRNGKey(9)
        while True:
            key, sub = jax.random.split(key)
            yield jax.random.normal(sub, (m, b, d))

    hist = trainer.fit(batches(), steps=3)
    assert len(hist.loss) == 3 and hist.bits[-1] > 0
    assert np.isfinite(hist.loss[-1])
