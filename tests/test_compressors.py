"""Unit tests for the multilevel compressor families (Def. 3.1 contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FixedPointCompressor,
    FixedPointMultilevel,
    FloatingPointMultilevel,
    QSGD,
    RTNCompressor,
    RTNMultilevel,
    RandK,
    STopKMultilevel,
    TopK,
    magnitude_ranks,
)

FAMILIES = [
    STopKMultilevel(d=96, s=1),
    STopKMultilevel(d=96, s=8),
    STopKMultilevel(d=100, s=7),   # non-divisible tail
    FixedPointMultilevel(num_bits=24),
    FixedPointMultilevel(num_bits=8),
    FloatingPointMultilevel(num_bits=23),
    RTNMultilevel(num_bits=8),
]


def _vec(d=96, seed=0, decay=0.15):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, (d,)) * jnp.exp(-decay * jnp.arange(d))


@pytest.mark.parametrize("comp", FAMILIES, ids=lambda c: f"{type(c).__name__}")
def test_def31_contract(comp):
    """C^L = id, C^0 = base, residual == C^l - C^{l-1}, telescoping."""
    d = getattr(comp, "d", 96)
    v = _vec(d)
    L = comp.num_levels
    np.testing.assert_allclose(np.asarray(comp.compress(v, L)),
                               np.asarray(v), rtol=1e-6, atol=1e-7)
    for l in [1, 2, L // 2 or 1, L]:
        prev = comp.base(v) if l == 1 else comp.compress(v, l - 1)
        np.testing.assert_allclose(
            np.asarray(comp.residual(v, l)),
            np.asarray(comp.compress(v, l) - prev), atol=2e-5)
    total = comp.base(v) + sum(comp.residual(v, l) for l in range(1, L + 1))
    np.testing.assert_allclose(np.asarray(total), np.asarray(v), atol=1e-4)


@pytest.mark.parametrize("comp", FAMILIES, ids=lambda c: f"{type(c).__name__}")
def test_residual_norms_match_residuals(comp):
    d = getattr(comp, "d", 96)
    v = _vec(d, seed=3)
    norms = np.asarray(comp.residual_norms(v))
    want = np.array([float(jnp.linalg.norm(comp.residual(v, l)))
                     for l in range(1, comp.num_levels + 1)])
    np.testing.assert_allclose(norms, want, atol=1e-5)


@pytest.mark.parametrize("comp", FAMILIES, ids=lambda c: f"{type(c).__name__}")
def test_static_probs_valid(comp):
    p = np.asarray(comp.static_probs())
    assert p.shape == (comp.num_levels,)
    assert (p > 0).all()
    np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-5)


def test_stopk_is_topk_ls():
    """s-Top-k at level l == Top-(l*s) (the sort-first definition)."""
    v = _vec(100, seed=5)
    comp = STopKMultilevel(d=100, s=7)
    for l in [1, 3, 10]:
        got = comp.compress(v, l)
        want = TopK(min(l * 7, 100)).compress(v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_stopk_alphas_energy():
    """alpha_l = ||C^l(v)||^2/||v||^2 (Eq. 10) and is increasing to 1."""
    v = _vec(96, seed=7)
    comp = STopKMultilevel(d=96, s=8)
    alphas = np.asarray(comp.alphas(v))
    for l in [1, 4, 12]:
        want = float(jnp.sum(comp.compress(v, l) ** 2) / jnp.sum(v**2))
        np.testing.assert_allclose(alphas[l - 1], want, rtol=1e-5)
    assert (np.diff(alphas) >= -1e-6).all()
    np.testing.assert_allclose(alphas[-1], 1.0, rtol=1e-5)


def test_topk_biased_energy_bound():
    """Eq. 9: ||C(v)-v||^2 <= (1 - k/d)||v||^2."""
    v = _vec(128, seed=1)
    for k in [1, 16, 64, 128]:
        c = TopK(k).compress(v)
        lhs = float(jnp.sum((c - v) ** 2))
        rhs = (1 - k / 128) * float(jnp.sum(v**2))
        assert lhs <= rhs + 1e-6


def test_magnitude_ranks():
    v = jnp.asarray([0.1, -3.0, 2.0, 0.0])
    np.testing.assert_array_equal(np.asarray(magnitude_ranks(v)),
                                  [2, 0, 1, 3])


def test_randk_unbiased_mc():
    v = _vec(64, seed=2)
    comp = RandK(8)
    keys = jax.random.split(jax.random.PRNGKey(0), 4000)
    est = jax.vmap(lambda k: comp.compress(v, rng=k))(keys).mean(0)
    rel = float(jnp.linalg.norm(est - v) / jnp.linalg.norm(v))
    assert rel < 0.1


def test_qsgd_unbiased_mc():
    v = _vec(64, seed=4)
    comp = QSGD(2)
    keys = jax.random.split(jax.random.PRNGKey(1), 4000)
    est = jax.vmap(lambda k: comp.compress(v, rng=k))(keys).mean(0)
    rel = float(jnp.linalg.norm(est - v) / jnp.linalg.norm(v))
    assert rel < 0.05


def test_fixed_point_biased_distortion():
    """F-bit truncation distortion bounded by 2^-F per (normalized) entry."""
    v = _vec(64, seed=6)
    scale = float(jnp.max(jnp.abs(v)))
    for f in [2, 4, 8]:
        c = FixedPointCompressor(f).compress(v)
        assert float(jnp.max(jnp.abs(c - v))) <= 2.0 ** -f * scale + 1e-6


def test_rtn_grid():
    v = _vec(64, seed=8)
    out = RTNCompressor(4).compress(v)
    c = float(jnp.max(jnp.abs(v)))
    delta = 2 * c / (2**4 - 1)
    ratio = np.asarray(out) / delta
    np.testing.assert_allclose(ratio, np.round(ratio), atol=1e-4)
