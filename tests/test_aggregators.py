"""Aggregator registry + EF21/EF21-SGDM behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EF21, TopK
from repro.core.aggregators import ALL_AGGREGATORS, make_aggregator

D, M = 256, 8


def _grads(seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (M, D))


@pytest.mark.parametrize("name", ALL_AGGREGATORS)
def test_aggregator_shapes_and_bits(name):
    agg = make_aggregator(name, D, k_fraction=0.05)
    state = agg.init(M, D) if agg.init else None
    out = agg(_grads(), jax.random.PRNGKey(1), state)
    assert out.direction.shape == (D,)
    assert np.isfinite(np.asarray(out.direction)).all()
    assert float(out.bits) > 0


@pytest.mark.parametrize("name", ["dense", "mlmc_topk", "mlmc_fixed",
                                  "mlmc_float", "randk", "qsgd"])
def test_unbiased_aggregators_mc(name):
    """Unbiased aggregators: E[direction] == mean of worker grads."""
    g = _grads(3)
    target = np.asarray(g.mean(0))
    agg = make_aggregator(name, D, k_fraction=0.05)
    keys = jax.random.split(jax.random.PRNGKey(7), 600)
    outs = jax.vmap(lambda k: agg(g, k, None).direction)(keys)
    est = np.asarray(outs.mean(0))
    rel = np.linalg.norm(est - target) / np.linalg.norm(target)
    assert rel < 0.25, (name, rel)


def test_dense_exact():
    g = _grads(1)
    agg = make_aggregator("dense", D)
    out = agg(g, jax.random.PRNGKey(0), None)
    np.testing.assert_allclose(np.asarray(out.direction),
                               np.asarray(g.mean(0)), rtol=1e-6)


def test_ef21_tracks_gradient():
    """On a CONSTANT gradient, EF21's server state converges to it
    (geometric contraction of the innovation)."""
    ef = EF21(TopK(32), beta=1.0)
    state = ef.init(M, D)
    g = jnp.broadcast_to(jax.random.normal(jax.random.PRNGKey(2), (D,)),
                         (M, D))
    errs = []
    for _ in range(40):
        direction, state, _ = ef.step(state, g)
        errs.append(float(jnp.linalg.norm(direction - g[0])))
    assert errs[-1] < 0.05 * errs[0]
    assert errs[-1] <= errs[0]


def test_ef21_sgdm_momentum_smooths():
    """With beta < 1, the momentum state is an EMA of the gradients."""
    ef = EF21(TopK(D), beta=0.5)  # no compression -> isolate momentum
    state = ef.init(1, D)
    g1 = jnp.ones((1, D))
    _, state, _ = ef.step(state, g1)
    np.testing.assert_allclose(np.asarray(state.momentum), 0.5, rtol=1e-6)
    _, state, _ = ef.step(state, g1)
    np.testing.assert_allclose(np.asarray(state.momentum), 0.75, rtol=1e-6)


def test_mlmc_topk_beats_randk_variance():
    """Lemma 3.6 consequence at aggregator level: on decaying gradients the
    adaptive MLMC estimator has lower MSE than Rand-k at matched budget."""
    decay = jnp.exp(-0.05 * jnp.arange(D))
    g = _grads(5) * decay[None, :]
    target = np.asarray(g.mean(0))
    keys = jax.random.split(jax.random.PRNGKey(11), 400)

    def mse(name):
        agg = make_aggregator(name, D, k_fraction=0.05)
        outs = jax.vmap(lambda k: agg(g, k, None).direction)(keys)
        return float(jnp.mean(jnp.sum((outs - target) ** 2, -1)))

    assert mse("mlmc_topk") < mse("randk")
