"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benchmarks
must see the real single CPU device; multi-device tests spawn subprocesses
with their own --xla_force_host_platform_device_count."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
