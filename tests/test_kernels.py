"""Pallas kernel validation: shape/dtype sweeps, allclose vs ref.py oracles
(interpret=True executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SIZES = [1, 100, 128, 129, 1000, 8192, 65536]
DTYPES = [jnp.float32]  # kernels are f32 (gradients are aggregated in f32)


def _vec(d, seed=0, dtype=jnp.float32):
    k = jax.random.PRNGKey(seed)
    v = jax.random.normal(k, (d,)) * jnp.exp(
        -5.0 * jax.random.uniform(jax.random.fold_in(k, 1), (d,)))
    return v.astype(dtype)


@pytest.mark.parametrize("d", SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("level", [1, 7, 24])
def test_bitplane_residual(d, dtype, level):
    v = _vec(d, seed=d, dtype=dtype)
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30)
    got = ops.bitplane_residual(v, scale, level)
    want = ref.bitplane_residual_ref(v, scale, jnp.int32(level))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-7)


@pytest.mark.parametrize("d", SIZES)
@pytest.mark.parametrize("level", [1, 12])
def test_ternary_bitplane(d, level):
    v = _vec(d, seed=d + 1)
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30)
    got = ops.ternary_bitplane(v, scale, level)
    want = ref.ternary_bitplane_ref(v, scale, jnp.int32(level))
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("L,s", [(1, 128), (7, 128), (64, 256), (300, 64),
                                 (1000, 8)])
def test_segment_sumsq(L, s):
    v2d = jax.random.normal(jax.random.PRNGKey(L * s), (L, s))
    got = ops.segment_sumsq(v2d)
    want = ref.segment_sumsq_ref(v2d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@pytest.mark.parametrize("d", SIZES)
@pytest.mark.parametrize("level", [1, 2, 4, 8])
def test_rtn_quantize(d, level):
    v = _vec(d, seed=d + 2)
    c = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30)
    got = ops.rtn_quantize(v, c, level)
    want = ref.rtn_quantize_ref(v, c, jnp.int32(level))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("d", SIZES + [1 << 20])
def test_exp_histogram(d):
    v = _vec(d, seed=d + 3)
    got = ops.exp_histogram(v)
    want = ref.exp_histogram_ref(v)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(got.sum()) == d


@pytest.mark.parametrize("d", SIZES)
def test_band_select(d):
    v = _vec(d, seed=d + 4)
    lo, hi = jnp.float32(0.01), jnp.float32(0.3)
    got = ops.band_select(v, lo, hi)
    want = ref.band_select_ref(v, lo, hi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("d,k", [(1000, 10), (8192, 100), (1 << 16, 650)])
def test_topk_threshold_covers_k(d, k):
    """The histogram threshold band must contain at least the true top-k."""
    v = _vec(d, seed=d + 5)
    lo, _ = ops.topk_threshold(v, k)
    n_sel = int(jnp.sum(jnp.abs(v) >= lo))
    assert n_sel >= k
    # and the band must include every one of the exact top-k entries
    kth = jnp.sort(jnp.abs(v))[-k]
    assert float(lo) <= float(kth) + 1e-12


def test_kernel_vs_core_compressor():
    """Kernel bit-plane == core FixedPointMultilevel.residual (integration)."""
    from repro.core import FixedPointMultilevel

    v = _vec(4096, seed=9)
    comp = FixedPointMultilevel(num_bits=24)
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30)
    for l in [1, 5, 23]:
        got = ops.bitplane_residual(v, scale, l)
        want = comp.residual(v, jnp.int32(l))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-7)
