"""Pallas kernel validation: shape/dtype sweeps, allclose vs ref.py oracles
(interpret=True executes the kernel bodies on CPU).

The bit-pack section is a property battery over the wire-format kernels of
`repro.kernels.pack` (every width 1..32, odd lengths, all-zero / all-ones
extremes, split-plane widths) — exhaustive parametrized sweeps that always
run, plus randomized `hypothesis` properties when the dev extra is
installed (requirements-dev.txt)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.pack import (
    fields_per_word,
    pack_bits,
    pack_planes,
    packed_words,
    plane_widths,
    unpack_bits,
    unpack_planes,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # pragma: no cover - dev extra not installed
    HAVE_HYPOTHESIS = False

SIZES = [1, 100, 128, 129, 1000, 8192, 65536]
DTYPES = [jnp.float32]  # kernels are f32 (gradients are aggregated in f32)


def _vec(d, seed=0, dtype=jnp.float32):
    k = jax.random.PRNGKey(seed)
    v = jax.random.normal(k, (d,)) * jnp.exp(
        -5.0 * jax.random.uniform(jax.random.fold_in(k, 1), (d,)))
    return v.astype(dtype)


@pytest.mark.parametrize("d", SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("level", [1, 7, 24])
def test_bitplane_residual(d, dtype, level):
    v = _vec(d, seed=d, dtype=dtype)
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30)
    got = ops.bitplane_residual(v, scale, level)
    want = ref.bitplane_residual_ref(v, scale, jnp.int32(level))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-7)


@pytest.mark.parametrize("d", SIZES)
@pytest.mark.parametrize("level", [1, 12])
def test_ternary_bitplane(d, level):
    v = _vec(d, seed=d + 1)
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30)
    got = ops.ternary_bitplane(v, scale, level)
    want = ref.ternary_bitplane_ref(v, scale, jnp.int32(level))
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("L,s", [(1, 128), (7, 128), (64, 256), (300, 64),
                                 (1000, 8)])
def test_segment_sumsq(L, s):
    v2d = jax.random.normal(jax.random.PRNGKey(L * s), (L, s))
    got = ops.segment_sumsq(v2d)
    want = ref.segment_sumsq_ref(v2d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@pytest.mark.parametrize("d", SIZES)
@pytest.mark.parametrize("level", [1, 2, 4, 8])
def test_rtn_quantize(d, level):
    v = _vec(d, seed=d + 2)
    c = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30)
    got = ops.rtn_quantize(v, c, level)
    want = ref.rtn_quantize_ref(v, c, jnp.int32(level))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("d", SIZES + [1 << 20])
def test_exp_histogram(d):
    v = _vec(d, seed=d + 3)
    got = ops.exp_histogram(v)
    want = ref.exp_histogram_ref(v)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(got.sum()) == d


@pytest.mark.parametrize("d", SIZES)
def test_band_select(d):
    v = _vec(d, seed=d + 4)
    lo, hi = jnp.float32(0.01), jnp.float32(0.3)
    got = ops.band_select(v, lo, hi)
    want = ref.band_select_ref(v, lo, hi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("d,k", [(1000, 10), (8192, 100), (1 << 16, 650)])
def test_topk_threshold_covers_k(d, k):
    """The histogram threshold band must contain at least the true top-k."""
    v = _vec(d, seed=d + 5)
    lo, _ = ops.topk_threshold(v, k)
    n_sel = int(jnp.sum(jnp.abs(v) >= lo))
    assert n_sel >= k
    # and the band must include every one of the exact top-k entries
    kth = jnp.sort(jnp.abs(v))[-k]
    assert float(lo) <= float(kth) + 1e-12


# ---------------------------------------------------------------------------
# bit-pack property battery (pack/unpack vs the kernels/ref.py oracle)
# ---------------------------------------------------------------------------

_PACK_LENGTHS = (1, 3, 31, 33, 127, 129, 257, 1000)   # odd + off-tile sizes


def _max_code(width: int) -> int:
    return (1 << min(width, 31)) - 1    # np.uint32 rng cap; width 32 uses 31


def _pack_case(codes: np.ndarray, width: int):
    """One pack/unpack round-trip checked against the pure-jnp oracle."""
    n = codes.shape[0]
    kernel_words = np.asarray(pack_bits(codes, width))
    ref_words = np.asarray(ref.pack_bits_ref(codes, width))
    np.testing.assert_array_equal(kernel_words, ref_words)
    np.testing.assert_array_equal(
        np.asarray(unpack_bits(kernel_words, width, n)), codes)
    np.testing.assert_array_equal(
        np.asarray(ref.unpack_bits_ref(ref_words, width, n)), codes)


@pytest.mark.parametrize("width", range(1, 33))
def test_pack_roundtrip_every_width(width):
    """Pack/unpack == oracle for EVERY field width 1..32 at odd lengths."""
    rng = np.random.default_rng(width)
    for n in _PACK_LENGTHS:
        codes = rng.integers(0, _max_code(width) + 1, size=n,
                             dtype=np.uint32)
        _pack_case(codes, width)


@pytest.mark.parametrize("width", range(1, 33))
def test_pack_extremes_every_width(width):
    """All-zero and all-ones (max code) payloads — the saturation extremes
    where shift/mask bugs hide."""
    for n in (1, 33, 257):
        _pack_case(np.zeros(n, np.uint32), width)
        _pack_case(np.full(n, _max_code(width), np.uint32), width)
        if width == 32:   # true 32-bit all-ones (passthrough path)
            _pack_case(np.full(n, 0xFFFFFFFF, np.uint32), width)


@pytest.mark.parametrize("width", range(1, 33))
def test_pack_planes_roundtrip_every_width(width):
    """Split-plane packing (device-wire index streams): round-trip vs the
    ref oracle, static word count, and effective-bits accounting."""
    rng = np.random.default_rng(100 + width)
    for n in (1, 5, 127, 257):
        codes = rng.integers(0, _max_code(width) + 1, size=n,
                             dtype=np.uint32)
        words = np.asarray(pack_planes(codes, width))
        assert words.shape == (packed_words(n, width),)
        np.testing.assert_array_equal(
            words, np.asarray(ref.pack_planes_ref(codes, width)))
        np.testing.assert_array_equal(
            np.asarray(unpack_planes(words, width, n)), codes)
        np.testing.assert_array_equal(
            np.asarray(ref.unpack_planes_ref(words, width, n)), codes)
    # plane decomposition covers the width exactly, word-aligned
    assert sum(plane_widths(width)) == width
    for w in plane_widths(width):
        assert w == 32 or fields_per_word(w) >= 32 // w > 0


def test_pack_rejects_bad_width():
    for width in (0, 33, -1):
        with pytest.raises(ValueError):
            fields_per_word(width)
        with pytest.raises(ValueError):
            plane_widths(width)


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(width=st.integers(1, 32), n=st.integers(1, 600),
           seed=st.integers(0, 2 ** 31 - 1))
    def test_pack_roundtrip_hypothesis(width, n, seed):
        """Property: unpack(pack(codes)) == codes and kernel == oracle for
        arbitrary (width, length, payload)."""
        rng = np.random.default_rng(seed)
        codes = rng.integers(0, _max_code(width) + 1, size=n,
                             dtype=np.uint32)
        _pack_case(codes, width)

    @settings(max_examples=40, deadline=None)
    @given(width=st.integers(17, 31), n=st.integers(1, 300),
           seed=st.integers(0, 2 ** 31 - 1))
    def test_pack_planes_hypothesis(width, n, seed):
        """Property: split-plane round-trip for the wide-index widths."""
        rng = np.random.default_rng(seed)
        codes = rng.integers(0, _max_code(width) + 1, size=n,
                             dtype=np.uint32)
        words = pack_planes(codes, width)
        np.testing.assert_array_equal(
            np.asarray(unpack_planes(words, width, n)), codes)
else:                           # pragma: no cover - dev extra not installed
    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev)")
    def test_pack_roundtrip_hypothesis():
        pass

    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev)")
    def test_pack_planes_hypothesis():
        pass


def test_kernel_vs_core_compressor():
    """Kernel bit-plane == core FixedPointMultilevel.residual (integration)."""
    from repro.core import FixedPointMultilevel

    v = _vec(4096, seed=9)
    comp = FixedPointMultilevel(num_bits=24)
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30)
    for l in [1, 5, 23]:
        got = ops.bitplane_residual(v, scale, l)
        want = comp.residual(v, jnp.int32(l))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-7)
