"""Subprocess worker for multi-device tests (spawned with
XLA_FLAGS=--xla_force_host_platform_device_count=8).  Exits non-zero on any
failure; prints PASS markers that the parent asserts on."""

import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, "src")

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import ASSIGNED, InputShape, reduce_for_smoke  # noqa: E402
from repro.launch.mesh import ctx_for_mesh, make_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.optim import sgd  # noqa: E402
from repro.sharding import shard_map  # noqa: E402
from repro.sharding.collectives import compressed_allreduce  # noqa: E402
from repro.train import step as step_mod  # noqa: E402


def check_collectives():
    """Mean-exactness (dense) and MC-unbiasedness (mlmc) of the compressed
    collectives on a real 8-device mesh."""
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    ctx = ctx_for_mesh(mesh)
    d = 512
    # per-(pod,data) worker gradient with a deep-learning-like decaying
    # magnitude profile (uniform gradients make the MLMC variance large —
    # Lemma 3.6's regime (1) — and the MC check needs too many samples)
    decay = jnp.exp(-0.02 * jnp.arange(d))
    g = jax.random.normal(jax.random.PRNGKey(0), (2, 2, d)) * decay
    target = np.asarray(g.mean((0, 1)))

    def run(method, key):
        def body(gs, rng):
            flat = gs.reshape(-1)
            out, bits = compressed_allreduce(flat, ctx, rng, method,
                                             k_fraction=0.05)
            return out, bits

        fn = jax.jit(shard_map(
            body, mesh=mesh,
            in_specs=(P("pod", "data", None), P()),
            out_specs=(P(), P()), check_vma=False))
        return fn(g, key)

    out, _ = run("dense", jax.random.PRNGKey(1))
    np.testing.assert_allclose(np.asarray(out), target, rtol=1e-5)
    print("PASS dense_exact")

    for method in ("mlmc_topk", "mlmc_fixed"):
        keys = jax.random.split(jax.random.PRNGKey(2), 300)
        outs = np.stack([np.asarray(run(method, k)[0]) for k in keys[:60]])
        est = outs.mean(0)
        rel = np.linalg.norm(est - target) / np.linalg.norm(target)
        assert rel < 0.3, (method, rel)
        print(f"PASS {method}_unbiased rel={rel:.3f}")


def check_device_wire():
    """Cross-wire parity matrix on a real 8-device mesh: for every method
    with a device branch, wire="device" must equal wire="abstract" EXACTLY
    under jit (mlmc_topk with the bf16_wire flag so both substrates apply
    identical value rounding), its measured bits must reconcile with the
    `repro.core.bits` ledger inside the documented per-codec bounds, and
    the traced program must contain no host callbacks."""
    import math

    # set BEFORE any trace: perf flags are read at trace time.  With the
    # flag on, the abstract mlmc_topk gather also ships bf16 values, making
    # the packed device segment bit-identical.
    os.environ["REPRO_OPT"] = "bf16_wire"

    from repro.comm.device_wire import make_device_codec
    from repro.core import bits as bitcost
    from repro.kernels.pack import packed_words

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    ctx = ctx_for_mesh(mesh)
    d, M = 512, 4
    decay = jnp.exp(-0.02 * jnp.arange(d))
    g = jax.random.normal(jax.random.PRNGKey(0), (2, 2, d)) * decay
    k_fraction = 0.05
    s = max(8, int(round(k_fraction * d)))

    def build(method, wire):
        def body(gs, rng):
            return compressed_allreduce(gs.reshape(-1), ctx, rng, method,
                                        k_fraction=k_fraction, wire=wire)
        return jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P("pod", "data", None), P()),
            out_specs=(P(), P()), check_vma=False))

    def measured_bounds(method):
        """(lo, hi) for the per-worker device operand bits, documented per
        codec (word padding + header-lane slack around the ledger)."""
        if method == "mlmc_topk":
            iw = math.ceil(math.log2(d))
            n = bitcost.topk_mlmc_bits(d, s, value_bits=16, index_bits=iw)
            pad = 32.0 * (packed_words(s, iw) + packed_words(s, 16)) \
                - s * (iw + 16)
            return n - 32.0, n + pad
        if method == "mlmc_fixed":
            n = bitcost.fixed_point_mlmc_bits(d, 24)
            pad = 32.0 * packed_words(d, 2) - 2.0 * d
            return n - 32.0, n + pad
        return make_device_codec(method, d).reconcile_bounds()

    for method in ("mlmc_topk", "mlmc_fixed", "qsgd", "rtn", "signsgd"):
        key = jax.random.PRNGKey(3)
        out_a, _ = build(method, "abstract")(g, key)
        out_d, bits_d = build(method, "device")(g, key)
        np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_a),
                                      err_msg=method)
        per_worker = float(bits_d) / M
        lo, hi = measured_bounds(method)
        assert lo <= per_worker <= hi, (method, per_worker, (lo, hi))
        print(f"PASS device_parity_{method} bits/worker={per_worker:.0f} "
              f"in [{lo:.0f}, {hi:.0f}]")

    # no host callbacks anywhere in the traced device-wire program
    def all_device(gs, rng):
        outs = []
        for i, m in enumerate(("mlmc_topk", "mlmc_fixed", "qsgd", "rtn",
                               "signsgd")):
            outs.append(compressed_allreduce(
                gs.reshape(-1), ctx, jax.random.fold_in(rng, i), m,
                k_fraction=k_fraction, wire="device"))
        return outs

    jaxpr = jax.make_jaxpr(shard_map(
        all_device, mesh=mesh, in_specs=(P("pod", "data", None), P()),
        out_specs=[(P(), P())] * 5, check_vma=False))(g, jax.random.PRNGKey(1))

    def prims(jx):
        for eqn in jx.eqns:
            yield str(eqn.primitive)
            for p in eqn.params.values():
                inner = getattr(p, "jaxpr", None)
                if inner is not None:
                    yield from prims(getattr(inner, "jaxpr", inner))
    bad = [p for p in prims(jaxpr.jaxpr) if "callback" in p]
    assert not bad, f"host callbacks in device wire: {bad}"
    print("PASS device_no_callbacks")

    # end-to-end: a full sharded train step on the device wire
    cfg = dataclasses.replace(
        reduce_for_smoke([c for c in ASSIGNED if c.name == "qwen3-4b"][0]))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 8, 32
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    opt = sgd(1e-2)
    for method in ("mlmc_fixed", "mlmc_topk"):
        fn, _, _ = step_mod.make_train_step(
            model, mesh, opt, shape=InputShape("t", S, B, "train"),
            method=method, remat=False, wire="device")
        _, _, metrics = fn(params, opt.init(params), batch,
                           jax.random.PRNGKey(2))
        assert np.isfinite(float(metrics["loss"])), method
        assert float(metrics["bits"]) > 0, method
    print("PASS device_train_step")


def check_stateful():
    """Stateful pipeline on the 8-device mesh (slow half of the cross-wire
    parity matrix in tests/test_comm_state.py):

    * the stateful mesh collective: `mlmc_adaptive_topk` threads a
      per-shard EMA ladder through shard_map; abstract and device wires
      produce the IDENTICAL direction and identical successor ladders over
      multiple rounds (bf16_wire flag: same value rounding both sides);
    * the in-process stateful aggregators under the 8-device runtime:
      EF21 / EF21-SGDM / mlmc_adaptive_topk match abstract-vs-packed
      (allclose, the repo's packed bound) and abstract-vs-device (bitwise
      for EF21, bitwise ladders for adaptive) over compounding state;
    * a full sharded train step with threaded mesh comm state makes
      progress and increments the state.
    """
    os.environ["REPRO_OPT"] = "bf16_wire"   # set BEFORE any trace

    from repro.core.aggregators import make_aggregator
    from repro.sharding.collectives import stateful_allreduce
    from repro.train.step import init_mesh_comm_state

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    ctx = ctx_for_mesh(mesh)
    d, M = 512, 4
    decay = jnp.exp(-0.02 * jnp.arange(d))
    g = jax.random.normal(jax.random.PRNGKey(0), (2, 2, d)) * decay
    k_fraction = 0.05
    import math as _math
    s = min(max(8, int(round(k_fraction * d))), d)
    L = _math.ceil(d / s)

    def build(wire):
        def body(gs, ladder, step, rng):
            out, bits, nl = stateful_allreduce(
                gs.reshape(-1), ctx, rng, "mlmc_adaptive_topk",
                ladder, step, k_fraction=k_fraction, wire=wire)
            return out, bits, nl
        return jax.jit(shard_map(
            body, mesh=mesh,
            in_specs=(P("pod", "data", None), P(("pod", "data"), None),
                      P(), P()),
            out_specs=(P(), P(), P(("pod", "data"), None)),
            check_vma=False))

    lad_a = jnp.zeros((4, L), jnp.float32)
    lad_d = jnp.zeros((4, L), jnp.float32)
    for t in range(3):
        key = jax.random.fold_in(jax.random.PRNGKey(3), t)
        step = jnp.asarray(t, jnp.int32)
        out_a, _, lad_a = build("abstract")(g, lad_a, step, key)
        out_d, _, lad_d = build("device")(g, lad_d, step, key)
        np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_a),
                                      err_msg=f"round {t}")
        np.testing.assert_array_equal(np.asarray(lad_d), np.asarray(lad_a))
    assert float(jnp.sum(jnp.abs(lad_a))) > 0
    print("PASS stateful_mesh_collective_parity")

    # in-process stateful aggregators under the multi-device runtime
    gm = jax.random.normal(jax.random.PRNGKey(7), (3, 193)) \
        * jnp.exp(-0.05 * jnp.arange(193))
    for name in ("ef21", "ef21_sgdm", "mlmc_adaptive_topk"):
        kw = dict(k_fraction=0.05, s=4)
        a_abs = make_aggregator(name, 193, **kw)
        a_pkd = make_aggregator(name, 193, **kw, wire="packed")
        a_dev = make_aggregator(name, 193, **kw, wire="device")
        st_a, st_p, st_d = (a.init(3, 193) for a in (a_abs, a_pkd, a_dev))
        for t in range(3):
            rng = jax.random.fold_in(jax.random.PRNGKey(8), t)
            # jit both jittable substrates: bitwise parity is a statement
            # about the compiled programs (eager XLA fuses differently)
            oa = jax.jit(a_abs.fn)(gm, rng, st_a)
            op = a_pkd.step(st_p, gm, rng)
            od = jax.jit(a_dev.fn)(gm, rng, st_d)
            st_a, st_p, st_d = oa.state, op.state, od.state
            np.testing.assert_allclose(
                np.asarray(op.direction), np.asarray(oa.direction),
                rtol=1e-6, atol=1e-7, err_msg=f"{name} packed step {t}")
            if name.startswith("ef21"):
                np.testing.assert_array_equal(
                    np.asarray(od.direction), np.asarray(oa.direction),
                    err_msg=f"{name} device step {t}")
            else:
                np.testing.assert_array_equal(
                    np.asarray(od.state.ladder_ema),
                    np.asarray(oa.state.ladder_ema))
        print(f"PASS stateful_wires_{name}")

    # end-to-end: the stateful sharded train step with threaded comm state
    cfg = dataclasses.replace(
        reduce_for_smoke([c for c in ASSIGNED if c.name == "qwen3-4b"][0]))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 8, 32
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    opt = sgd(1e-2)
    fn, _, _ = step_mod.make_train_step(
        model, mesh, opt, shape=InputShape("t", S, B, "train"),
        method="mlmc_adaptive_topk", remat=False)
    comm, specs = init_mesh_comm_state(model, mesh,
                                       method="mlmc_adaptive_topk")
    # the ladder state is PER DEVICE and specced over EVERY mesh axis: a
    # tensor-parallel leaf's gradient slice differs per model shard, so a
    # narrower spec would let one shard's ladder overwrite another's
    # (check_vma=False disables the replication check that would catch it)
    for lad, spec in zip(
            jax.tree_util.tree_leaves(comm["ladders"]),
            jax.tree_util.tree_leaves(specs["ladders"],
                                      is_leaf=lambda x: isinstance(x, P))):
        assert lad.shape[0] == mesh.devices.size, lad.shape
        assert tuple(spec)[0] == tuple(mesh.axis_names), spec
    opt_state = opt.init(params)
    for t in range(2):
        params, opt_state, comm, metrics = fn(
            params, opt_state, comm, batch, jax.random.fold_in(key, 10 + t))
        assert np.isfinite(float(metrics["loss"]))
        assert float(metrics["bits"]) > 0
    assert int(comm["step"]) == 2
    # per-device rows are REAL state: at least one TP-sharded leaf's ladder
    # differs across the model coordinate (rows 2k vs 2k+1 in the raveled
    # (pod, data, model) order) — all rows zero/equal would mean the state
    # collapsed to a single replica
    def model_varies(lad):
        rows = np.asarray(lad).reshape(-1, 2, lad.shape[-1])  # model last
        return bool(np.any(rows[:, 0] != rows[:, 1]))
    assert any(model_varies(l)
               for l in jax.tree_util.tree_leaves(comm["ladders"])), \
        "no ladder varies across the model axis — per-device state lost"
    print("PASS stateful_train_step")


def check_ef21_policy():
    """Mesh EF21 + per-leaf policy battery on the 8-device mesh:

    * `ef21_topk_allreduce` converges on a fixed gradient (mirror -> local
      gradient geometrically, so the direction -> the exact mean) on both
      wires, the server replica stays bitwise synced across shards and
      equal to the mean of the mirrors, and the device wire ships fewer
      bits (bf16-packed innovation values);
    * a full sharded train step with ``method="ef21"`` threads the
      (mirrors, servers) comm state exactly the way the adaptive ladder
      rides — state advances, at least one TP-sharded leaf's mirror varies
      across the model axis;
    * ``policy=`` on `make_train_step` dispatches per-leaf codecs (small
      leaves dense, matmuls mlmc_topk) and rejects stateful assignments.
    """
    from repro.sharding.collectives import ef21_topk_allreduce
    from repro.train.step import init_mesh_comm_state

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    ctx = ctx_for_mesh(mesh)
    d, s = 512, 32
    decay = jnp.exp(-0.02 * jnp.arange(d))
    g = jax.random.normal(jax.random.PRNGKey(0), (2, 2, d)) * decay
    target = np.asarray(g.mean((0, 1)))

    def build(wire):
        def body(gs, mirror, server):
            return ef21_topk_allreduce(gs.reshape(-1), ctx, mirror, server,
                                       s=s, wire=wire)
        return jax.jit(shard_map(
            body, mesh=mesh,
            in_specs=(P("pod", "data", None), P(("pod", "data"), None),
                      P(("pod", "data"), None)),
            out_specs=(P(), P(), P(("pod", "data"), None),
                       P(("pod", "data"), None)),
            check_vma=False))

    bits_by_wire = {}
    for wire in ("abstract", "device"):
        mirror = jnp.zeros((4, d), jnp.float32)
        server = jnp.zeros((4, d), jnp.float32)
        fn = build(wire)
        for _ in range(40):
            out, bits, mirror, server = fn(g, mirror, server)
        rel = np.linalg.norm(np.asarray(out) - target) \
            / np.linalg.norm(target)
        assert rel < 1e-4, (wire, rel)
        srv = np.asarray(server)
        assert np.all(srv == srv[0]), "server replicas desynced"
        assert np.allclose(srv[0], np.asarray(mirror).mean(0),
                           atol=1e-5), "server != mean(mirrors)"
        bits_by_wire[wire] = float(bits)
        print(f"PASS ef21_mesh_{wire} rel={rel:.2e} bits={float(bits):.0f}")
    assert bits_by_wire["device"] < bits_by_wire["abstract"]

    # end-to-end: ef21 train step with threaded (mirrors, servers) state
    cfg = dataclasses.replace(
        reduce_for_smoke([c for c in ASSIGNED if c.name == "qwen3-4b"][0]))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 8, 32
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    opt = sgd(1e-2)
    fn, _, _ = step_mod.make_train_step(
        model, mesh, opt, shape=InputShape("t", S, B, "train"),
        method="ef21", remat=False)
    comm, specs = init_mesh_comm_state(model, mesh, method="ef21")
    for mir, spec in zip(
            jax.tree_util.tree_leaves(comm["mirrors"]),
            jax.tree_util.tree_leaves(specs["mirrors"],
                                      is_leaf=lambda x: isinstance(x, P))):
        assert mir.shape[0] == mesh.devices.size, mir.shape
        assert tuple(spec)[0] == tuple(mesh.axis_names), spec
    opt_state = opt.init(params)
    for t in range(2):
        params, opt_state, comm, metrics = fn(
            params, opt_state, comm, batch, jax.random.fold_in(key, 20 + t))
        assert np.isfinite(float(metrics["loss"]))
        assert float(metrics["bits"]) > 0
    assert int(comm["step"]) == 2

    def model_varies(leaf):
        rows = np.asarray(leaf).reshape(-1, 2, leaf.shape[-1])
        return bool(np.any(rows[:, 0] != rows[:, 1]))
    assert any(model_varies(m)
               for m in jax.tree_util.tree_leaves(comm["mirrors"])), \
        "no mirror varies across the model axis — per-device state lost"
    print("PASS ef21_train_step")

    # per-leaf policy: small tensors dense, matmuls mlmc_topk
    fn, _, _ = step_mod.make_train_step(
        model, mesh, opt, shape=InputShape("t", S, B, "train"),
        method="mlmc_topk", remat=False,
        policy={"size<=2048": "dense", "*": "mlmc_topk"})
    _, _, metrics = fn(params, opt_state, batch, jax.random.PRNGKey(9))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["bits"]) > 0
    try:
        step_mod.make_train_step(
            model, mesh, opt, shape=InputShape("t", S, B, "train"),
            method="mlmc_topk", remat=False, policy={"*": "ef21"})
    except ValueError as e:
        assert "stateless" in str(e), e
    else:
        raise AssertionError("stateful policy assignment must be rejected")
    print("PASS policy_train_step")


def check_train_parity():
    """Sharded dense train loss == unsharded loss for a dense arch."""
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = dataclasses.replace(
        reduce_for_smoke([c for c in ASSIGNED if c.name == "qwen3-4b"][0]))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 8, 32
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    ref_loss, _ = model.loss(params, batch, remat=False)
    opt = sgd(1e-2)
    fn, _, _ = step_mod.make_train_step(
        model, mesh, opt, shape=InputShape("t", S, B, "train"),
        method="dense", remat=False)
    _, _, metrics = fn(params, opt.init(params), batch, jax.random.PRNGKey(2))
    diff = abs(float(ref_loss) - float(metrics["loss"]))
    assert diff < 2e-3, diff
    print(f"PASS train_parity diff={diff:.2e}")


def check_fsdp():
    """FSDP path: loss parity with FSDP sharding enabled."""
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    base = reduce_for_smoke([c for c in ASSIGNED
                             if c.name == "internvl2-76b"][0])
    cfg = dataclasses.replace(base, fsdp=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 8, 16
    key = jax.random.PRNGKey(3)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "vision": 0.1 * jax.random.normal(
                 key, (B, cfg.num_vision_tokens, cfg.d_model))}
    nofsdp = dataclasses.replace(cfg, fsdp=False)
    ref_loss, _ = build_model(nofsdp).loss(params, batch, remat=False)
    opt = sgd(1e-2)
    fn, _, _ = step_mod.make_train_step(
        model, mesh, opt, shape=InputShape("t", S, B, "train"),
        method="mlmc_fixed", remat=False)
    _, _, metrics = fn(params, opt.init(params), batch, jax.random.PRNGKey(4))
    diff = abs(float(ref_loss) - float(metrics["loss"]))
    assert diff < 5e-3, diff
    print(f"PASS fsdp_parity diff={diff:.2e}")


def check_decode_parity():
    """Sharded decode greedy tokens == unsharded decode greedy tokens."""
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = reduce_for_smoke([c for c in ASSIGNED
                            if c.name == "gemma3-27b"][0])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 8, 32
    key = jax.random.PRNGKey(5)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    # unsharded reference
    caches_u, nxt_u, _ = model.prefill(params, {"tokens": tokens}, S + 4)
    tok_u, _ = model.decode_step(params, nxt_u, jnp.int32(S), caches_u)
    # sharded
    pshape = InputShape("p", S + 4, B, "prefill")
    dshape = InputShape("d", S + 4, B, "decode")
    pfn, _, _ = step_mod.make_prefill_step(model, mesh, shape=pshape)
    caches_s, nxt_s = pfn(params, {"tokens": tokens})
    dfn, _, _ = step_mod.make_decode_step(model, mesh, shape=dshape)
    tok_s, _ = dfn(params, nxt_s, jnp.int32(S), caches_s)
    np.testing.assert_array_equal(np.asarray(nxt_u), np.asarray(nxt_s))
    np.testing.assert_array_equal(np.asarray(tok_u), np.asarray(tok_s))
    print("PASS decode_parity")


def check_select_mesh():
    """The sort-free selection primitives on the 8-device mesh:

    * `global_topk_mask` (psum'd byte histograms + gathered tie counts)
      matches the host reference — stable u32-key selection over the
      shard-major concatenation — including cross-shard duplicate
      magnitudes at the threshold;
    * `ef21_topk_allreduce(selection="global")` spends the total budget on
      the globally largest innovations and reproduces the host reference
      direction exactly;
    * `mlmc_fixed_pershard` lifts the shared-scale constraint: per-shard
      lane scales differ, abstract == device bitwise, MC mean unbiased.
    """
    from repro.sharding.collectives import (ef21_topk_allreduce,
                                            global_topk_mask)

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    ctx = ctx_for_mesh(mesh)
    d, k = 512, 37
    decay = jnp.exp(-0.02 * jnp.arange(d))
    g = jax.random.normal(jax.random.PRNGKey(0), (2, 2, d)) * decay
    # force cross-shard ties at the threshold: quantize magnitudes hard
    g_tied = jnp.round(g * 4.0) / 4.0

    def global_ref(gm, kk):
        """Host reference: stable u32-key top-k over the shard-major
        concatenation (M, d) -> per-shard membership masks."""
        u = np.asarray(gm).reshape(-1, d)
        keys = np.abs(u.reshape(-1)).astype(np.float32).view(np.uint32)
        order = np.argsort(~keys, kind="stable")       # desc keys, asc idx
        member = np.zeros(keys.shape[0], bool)
        member[order[:kk]] = True
        return member.reshape(-1, d)

    def run_mask(gm, kk):
        def body(gs, _):
            return global_topk_mask(gs.reshape(-1), kk, ctx)[None, None]

        fn = jax.jit(shard_map(
            body, mesh=mesh,
            in_specs=(P("pod", "data", None), P()),
            out_specs=P("pod", "data", None), check_vma=False))
        return np.asarray(fn(gm, jnp.zeros(()))).reshape(-1, d)

    for label, gm in (("normal", g), ("tied", g_tied)):
        got = run_mask(gm, k)
        want = global_ref(gm, k)
        np.testing.assert_array_equal(got, want, err_msg=label)
        assert got.sum() == k, (label, got.sum())
    print("PASS global_topk_mask")

    s = 24

    def run_ef21_global(gm):
        def body(gs):
            flat = gs.reshape(-1)
            direction, bits, mir, srv = ef21_topk_allreduce(
                flat, ctx, jnp.zeros_like(flat), jnp.zeros_like(flat),
                s=s, selection="global")
            return direction, mir[None, None]

        fn = jax.jit(shard_map(
            body, mesh=mesh,
            in_specs=(P("pod", "data", None),),
            out_specs=(P(), P("pod", "data", None)),
            check_vma=False))
        return fn(gm)

    direction, mirrors = run_ef21_global(g)
    # total budget = s across ALL shards (mirror zero => u = g)
    member = global_ref(g, s)
    u = np.asarray(g).reshape(-1, d)
    want_dir = (u * member).sum(0) / member.shape[0]
    np.testing.assert_allclose(np.asarray(direction), want_dir,
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(mirrors).reshape(-1, d),
                               u * member, rtol=1e-6, atol=1e-7)
    print("PASS ef21_global_selection")

    def run_pershard(method, wire, key):
        def body(gs, rng):
            out, bits = compressed_allreduce(gs.reshape(-1), ctx, rng,
                                             method, wire=wire)
            return out, bits

        fn = jax.jit(shard_map(
            body, mesh=mesh,
            in_specs=(P("pod", "data", None), P()),
            out_specs=(P(), P()), check_vma=False))
        return fn(g, key)

    out_a, _ = run_pershard("mlmc_fixed_pershard", "abstract",
                            jax.random.PRNGKey(3))
    out_d, _ = run_pershard("mlmc_fixed_pershard", "device",
                            jax.random.PRNGKey(3))
    np.testing.assert_array_equal(np.asarray(out_a), np.asarray(out_d))

    # per-shard scales really differ (the constraint the method lifts)
    from repro.comm.device_wire import MLMCFixedDeviceCodec

    codec = MLMCFixedDeviceCodec(d)
    rng = jax.random.PRNGKey(3)
    scales = {
        float(codec.encode(jnp.asarray(g[i, j]),
                           jax.random.fold_in(rng, i * 2 + j))[0].lane[0])
        for i in range(2) for j in range(2)}
    assert len(scales) > 1, scales

    target = np.asarray(g.mean((0, 1)))
    outs = np.stack([
        np.asarray(run_pershard("mlmc_fixed_pershard", "abstract", kk)[0])
        for kk in jax.random.split(jax.random.PRNGKey(5), 60)])
    rel = np.linalg.norm(outs.mean(0) - target) / np.linalg.norm(target)
    assert rel < 0.3, rel
    print(f"PASS mlmc_fixed_pershard rel={rel:.3f}")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    fns = {"collectives": check_collectives, "train": check_train_parity,
           "fsdp": check_fsdp, "decode": check_decode_parity,
           "device_wire": check_device_wire, "stateful": check_stateful,
           "ef21_policy": check_ef21_policy,
           "select_mesh": check_select_mesh}
    if which == "all":
        for f in fns.values():
            f()
    else:
        fns[which]()
    print("WORKER_OK")
