"""repro.kernels.select: the sort-free exact selection pipeline.

The contract under test: every primitive reproduces the canonical order —
descending uint32 bitcast of |v|, ties broken by ascending index — that
the retired global ``argsort(-|v|)`` implied, bit for bit, on BOTH
implementations ("sort" key-sort thresholds and the "histogram" byte-radix
walk).  The reference is a numpy stable argsort over the u32 keys, which
never flushes denormals (unlike the XLA CPU float comparator the legacy
path leaned on — see the module docstring).

`hypothesis` is not available in the container, so the adversarial inputs
are a seeded parametrized pool: duplicate magnitudes, +/- pairs,
denormals, all-zero vectors, odd dims, d=1, and every MLMC level.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src import test_util as jtu

from repro.kernels import select

jax.config.update("jax_platform_name", "cpu")

IMPLS = ("sort", "histogram")


def _make_case(case: str, d: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed + 97 * d)
    v = rng.standard_normal(d).astype(np.float32)
    v *= np.exp(-2.0 * rng.random(d)).astype(np.float32)
    if case == "normal":
        return v
    if case == "dups":
        # heavy magnitude ties (plus exact +/- pairs) at every scale
        q = np.round(v * 4.0) / 4.0
        q[:: 3] *= -1.0
        return q.astype(np.float32)
    if case == "denormal":
        out = v.copy()
        out[:: 4] = np.float32(1e-40) * rng.integers(0, 4, size=len(out[::4]))
        out[1:: 4] = np.float32(-1e-41)
        return out.astype(np.float32)
    if case == "zeros":
        return np.zeros(d, np.float32)
    raise AssertionError(case)


CASES = ("normal", "dups", "denormal", "zeros")
DIMS = (1, 33, 257)


def _ref_order(v: np.ndarray) -> np.ndarray:
    """Canonical order: descending u32 keys of |v|, stable (asc. index)."""
    keys = np.abs(np.asarray(v, np.float32)).view(np.uint32)
    return np.argsort(~keys, kind="stable")


def _ref_ranks(v: np.ndarray) -> np.ndarray:
    order = _ref_order(v)
    ranks = np.empty(len(order), np.int64)
    ranks[order] = np.arange(len(order))
    return ranks


def _bounds(d: int):
    s = max(1, d // 5)
    return sorted({(0, 0), (0, 1), (0, d), (0, min(s, d)),
                   (s, min(2 * s, d)), (max(d - s, 0), d), (d, d)})


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("d", DIMS)
def test_band_mask_matches_reference(impl, case, d):
    v = _make_case(case, d)
    ranks = _ref_ranks(v)
    jv = jnp.asarray(v)
    banded = jax.jit(
        lambda vv, r0, r1: select.band_mask(vv, r0, r1, impl=impl))
    for r0, r1 in _bounds(d):
        want = (ranks >= r0) & (ranks < r1)
        got = np.asarray(select.band_mask(jv, r0, r1, impl=impl))
        np.testing.assert_array_equal(got, want, err_msg=f"{r0}:{r1}")
        # traced bounds take the same path
        got_t = np.asarray(banded(jv, jnp.int32(r0), jnp.int32(r1)))
        np.testing.assert_array_equal(got_t, want, err_msg=f"jit {r0}:{r1}")


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("d", DIMS)
def test_topk_mask_static_and_traced(impl, case, d):
    v = _make_case(case, d, seed=1)
    ranks = _ref_ranks(v)
    jv = jnp.asarray(v)
    traced = jax.jit(lambda vv, kk: select.topk_mask(vv, kk, impl=impl))
    for k in sorted({0, 1, d // 3, d - 1, d}):
        want = ranks < k
        np.testing.assert_array_equal(
            np.asarray(select.topk_mask(jv, k, impl=impl)), want,
            err_msg=f"static k={k}")
        np.testing.assert_array_equal(
            np.asarray(traced(jv, jnp.int32(k))), want,
            err_msg=f"traced k={k}")


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("d", DIMS)
def test_rank_band_indices_rank_order(impl, case, d):
    v = _make_case(case, d, seed=2)
    order = _ref_order(v)
    jv = jnp.asarray(v)
    s = max(1, d // 4)
    for r0 in sorted({0, s, max(d - s // 2, 0), d}):
        idx, valid = select.rank_band_indices(jv, r0, s, impl=impl)
        idx, valid = np.asarray(idx), np.asarray(valid)
        n = int(np.clip(d - r0, 0, s))
        assert valid.sum() == n, (r0, valid)
        np.testing.assert_array_equal(idx[:n], order[r0:r0 + n],
                                      err_msg=f"r0={r0}")


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("d", DIMS)
def test_histogram_threshold_matches_key_sort(case, d):
    v = _make_case(case, d, seed=3)
    keys = select.magnitude_keys(jnp.asarray(v))
    sorted_keys = select.sort_magnitude_keys(keys)
    walk = jax.jit(lambda kk, r: select.histogram_threshold(kk, r))
    # in-range ranks only: callers (`band_mask`) clip to [0, d-1] first
    for rank in sorted({0, min(1, d - 1), d // 2, d - 1}):
        want = int(sorted_keys[rank])
        assert int(walk(keys, jnp.int32(rank))) == want, rank


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("d", DIMS)
def test_sorted_abs_desc_bitwise(case, d):
    v = _make_case(case, d, seed=4)
    got = np.asarray(select.sorted_abs_desc(jnp.asarray(v)))
    want = np.sort(np.abs(v))[::-1]
    np.testing.assert_array_equal(got.view(np.uint32),
                                  want.view(np.uint32))


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("d", DIMS)
def test_matches_legacy_float_argsort_without_denormals(impl, d):
    """On denormal-free inputs (every golden fixture) the canonical order
    IS the legacy stable ``argsort(-|v|)`` order."""
    for case in ("normal", "dups", "zeros"):
        v = _make_case(case, d, seed=5)
        legacy = np.argsort(-np.abs(v), kind="stable")
        for k in (1, max(1, d // 3), d):
            want = np.zeros(d, bool)
            want[legacy[:k]] = True
            got = np.asarray(select.topk_mask(jnp.asarray(v), k, impl=impl))
            np.testing.assert_array_equal(got, want,
                                          err_msg=f"{case} k={k}")


@pytest.mark.parametrize("case", ("normal", "dups"))
def test_every_mlmc_level_band(case):
    """The s-Top-k ladder: compress/residual at EVERY level equal the
    reference rank bands (compress = ranks < l*s, residual = the
    [(l-1)s, ls) band)."""
    from repro.core.topk import STopKMultilevel

    d, s = 37, 5
    v = _make_case(case, d, seed=6)
    ranks = _ref_ranks(v)
    comp = STopKMultilevel(d=d, s=s)
    for level in range(1, comp.num_levels + 1):
        got_c = np.asarray(comp.compress(jnp.asarray(v), level))
        np.testing.assert_array_equal(
            got_c, np.where(ranks < level * s, v, 0.0),
            err_msg=f"compress l={level}")
        got_r = np.asarray(comp.residual(jnp.asarray(v), level))
        np.testing.assert_array_equal(
            got_r, np.where((ranks >= (level - 1) * s) & (ranks < level * s),
                            v, 0.0),
            err_msg=f"residual l={level}")


@pytest.mark.parametrize("impl", IMPLS)
def test_traced_bounds_do_not_retrace(impl):
    """One lowering serves every rank: the pipeline is fixed-shape in the
    traced bounds (the property that keeps the packed/device wires at
    zero steady-state lowerings — see test_compiled_codec.py)."""
    d, s = 64, 8
    v = jnp.asarray(_make_case("normal", d, seed=7))
    band = jax.jit(lambda vv, r0: select.rank_band_indices(
        vv, r0, s, impl=impl))
    band(v, jnp.int32(0))                              # warmup lowering
    with jtu.count_jit_and_pmap_lowerings() as count:
        for r0 in (0, s, 3 * s, d):
            band(v, jnp.int32(r0))
    assert count[0] == 0, count[0]


def test_rank_band_indices_s_larger_than_d():
    """s > d: the fixed (s,) shape pads with invalid slots, never aliases
    real indices into the valid region."""
    d, s = 5, 9
    v = jnp.asarray(_make_case("dups", d, seed=8))
    order = _ref_order(np.asarray(v))
    for impl in IMPLS:
        idx, valid = select.rank_band_indices(v, 0, s, impl=impl)
        assert int(np.asarray(valid).sum()) == d
        np.testing.assert_array_equal(np.asarray(idx)[:d], order)


def test_impl_validation():
    with pytest.raises(ValueError):
        select.band_mask(jnp.ones((4,)), 0, 2, impl="radix")
