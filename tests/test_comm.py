"""repro.comm: wire codecs, bit-pack kernels, transports.

The load-bearing assertions:

* every registry compressor round-trips LOSSLESSLY — ``decode(encode(v))``
  is IEEE-equal to the abstract in-memory estimate, including through full
  byte serialization;
* the measured packet size reconciles with the `repro.core.bits` ledger
  within each codec's documented bounds (word padding, f32-vs-f64 headers,
  the honest mlmc_rtn deviation) — the bit counters are *verified*;
* the Pallas pack/unpack kernels match their pure-JAX `kernels/ref.py`
  oracles bit-for-bit;
* ``wire="packed"`` aggregation equals ``wire="abstract"`` aggregation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    CostModel,
    LoopbackTransport,
    Packet,
    make_codec,
    make_topology,
    make_transport,
    pack_bits,
    simulated_step_time,
    unpack_bits,
)
from repro.comm.codec import MLMCRTNCodec
from repro.core.aggregators import ALL_AGGREGATORS, make_aggregator
from repro.kernels.ref import pack_bits_ref, unpack_bits_ref

jax.config.update("jax_platform_name", "cpu")

D = 257            # deliberately not a multiple of 128 or any field count
CODEC_KW = dict(k_fraction=0.05, s=4)


def _grad(d=D, seed=0):
    key = jax.random.PRNGKey(seed)
    # deep-learning-like decaying magnitude profile (cf. Lemma 3.6)
    return jax.random.normal(key, (d,)) * jnp.exp(-0.02 * jnp.arange(d))


@pytest.fixture(scope="module")
def grad():
    return _grad()


# ---------------------------------------------------------------------------
# codec round-trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_AGGREGATORS)
def test_roundtrip_bit_exact(name, grad):
    """decode(encode(v)) == the abstract estimate, elementwise IEEE-equal."""
    codec = make_codec(name, D, **CODEC_KW)
    for trial in range(6):
        key = jax.random.fold_in(jax.random.PRNGKey(1), trial)
        res = codec.encode(grad, key)
        dec = codec.decode(res.packet)
        np.testing.assert_array_equal(dec, res.estimate,
                                      err_msg=f"{name} trial {trial}")


@pytest.mark.parametrize("name", ALL_AGGREGATORS)
def test_roundtrip_through_bytes(name, grad):
    """Full serialization: bytes -> Packet -> estimate, still exact."""
    codec = make_codec(name, D, **CODEC_KW)
    res = codec.encode(grad, jax.random.PRNGKey(2))
    wire = res.packet.to_bytes()
    assert isinstance(wire, bytes) and len(wire) == res.packet.serialized_bytes
    dec = codec.decode(Packet.from_bytes(wire))
    np.testing.assert_array_equal(dec, res.estimate)


@pytest.mark.parametrize("name", ALL_AGGREGATORS)
def test_bits_reconcile(name, grad):
    """Measured packet bits sit inside the codec's documented bounds around
    the `repro.core.bits` ledger value — counters verified, not asserted."""
    codec = make_codec(name, D, **CODEC_KW)
    for trial in range(6):
        key = jax.random.fold_in(jax.random.PRNGKey(3), trial)
        pkt = codec.encode(grad, key).packet
        measured = codec.measured_bits(pkt)
        lo, hi = codec.reconcile_bounds(pkt)
        assert lo <= measured <= hi, \
            (name, trial, measured, (lo, hi), codec.nominal_bits())
        # padded payload can never undercut the information content
        assert pkt.payload_padded_bits >= pkt.payload_used_bits


def test_zero_and_negzero_gradient_roundtrip():
    """Exact zeros (sign = 0 paths) survive the wire."""
    v = jnp.asarray(np.array([0.0, -1.5, 0.0, 2.5, -0.0, 1e-8] * 20,
                             np.float32))
    for name in ("signsgd", "qsgd", "natural", "mlmc_fixed", "mlmc_float"):
        codec = make_codec(name, v.shape[0], **CODEC_KW)
        res = codec.encode(v, jax.random.PRNGKey(4))
        np.testing.assert_array_equal(codec.decode(res.packet), res.estimate,
                                      err_msg=name)


def test_mlmc_dense_top_level_fallback(grad):
    """A forced top-level draw (C^L = id) ships the dense residual and still
    round-trips exactly."""
    for name in ("mlmc_fixed", "mlmc_float"):
        codec = make_codec(name, D, **CODEC_KW)
        L = codec.compressor.num_levels
        probs = jnp.zeros((L,)).at[L - 1].set(1.0)
        res = codec.encode(grad, jax.random.PRNGKey(5), probs=probs)
        assert res.packet.header.level == L
        assert res.packet.header.flags  # FLAG_DENSE_FALLBACK
        np.testing.assert_array_equal(codec.decode(res.packet), res.estimate)
    # adaptive RTN: a 2-level ladder draws the top level almost surely
    # (Delta_1 = 0 on the 1-cell grid), exercising the fallback organically
    codec = MLMCRTNCodec(D, num_bits=2)
    res = codec.encode(grad, jax.random.PRNGKey(6))
    assert res.packet.header.level == 2
    np.testing.assert_array_equal(codec.decode(res.packet), res.estimate)


def test_mlmc_rtn_all_levels(grad):
    """Force every RTN level (the q/correction two-stream format)."""
    codec = make_codec("mlmc_rtn", D, **CODEC_KW)
    L = codec.compressor.num_levels
    # adaptive draws follow Lemma 3.4; sweep keys until all levels < L seen
    seen = set()
    for trial in range(200):
        res = codec.encode(grad, jax.random.PRNGKey(100 + trial))
        seen.add(res.packet.header.level)
        np.testing.assert_array_equal(codec.decode(res.packet), res.estimate)
        if len(seen) >= 4:
            break
    assert len(seen) >= 2, f"only levels {seen} sampled"


# ---------------------------------------------------------------------------
# pack kernels vs reference oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("width", [1, 2, 3, 5, 8, 10, 12, 16, 17, 32])
def test_pack_kernel_matches_ref(width):
    rng = np.random.default_rng(width)
    for n in (1, 127, 257, 4096):
        codes = rng.integers(0, 2 ** min(width, 31), size=n,
                             dtype=np.uint32)
        kernel_words = np.asarray(pack_bits(codes, width))
        ref_words = np.asarray(pack_bits_ref(codes, width))
        np.testing.assert_array_equal(kernel_words, ref_words)
        np.testing.assert_array_equal(
            np.asarray(unpack_bits(kernel_words, width, n)), codes)
        np.testing.assert_array_equal(
            np.asarray(unpack_bits_ref(ref_words, width, n)), codes)


# ---------------------------------------------------------------------------
# packed aggregation == abstract aggregation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_AGGREGATORS)
def test_packed_aggregator_matches_abstract(name):
    d, m = 193, 3
    g = jax.random.normal(jax.random.PRNGKey(7), (m, d)) \
        * jnp.exp(-0.05 * jnp.arange(d))
    a_abs = make_aggregator(name, d, **CODEC_KW)
    a_pkd = make_aggregator(name, d, **CODEC_KW, wire="packed")
    st_a = a_abs.init(m, d) if a_abs.init else None
    st_p = a_pkd.init(m, d) if a_pkd.init else None
    for step in range(2):
        rng = jax.random.fold_in(jax.random.PRNGKey(8), step)
        out_a = a_abs(g, rng, st_a)
        out_p = a_pkd(g, rng, st_p)
        st_a, st_p = out_a.state, out_p.state
        np.testing.assert_allclose(np.asarray(out_p.direction),
                                   np.asarray(out_a.direction),
                                   rtol=1e-6, atol=1e-7, err_msg=name)
        assert float(out_p.bits) > 0


def test_packed_trainer_end_to_end():
    """Trainer(wire='packed'): jitted grads + byte wire + jitted apply."""
    from repro.optim import sgd
    from repro.train import Trainer

    d, m, b = 32, 2, 4
    params = {"w": jnp.zeros((d,))}

    def loss_fn(p, batch):
        return jnp.mean((batch @ p["w"] - 1.0) ** 2)

    transport = make_transport("parameter_server")
    trainer = Trainer(loss_fn, params, num_workers=m, method="mlmc_topk",
                      optimizer=sgd(0.1), k_fraction=0.25, wire="packed",
                      transport=transport)

    def batches():
        key = jax.random.PRNGKey(9)
        while True:
            key, sub = jax.random.split(key)
            yield jax.random.normal(sub, (m, b, d))

    hist = trainer.fit(batches(), steps=3)
    assert len(hist.loss) == 3 and hist.bits[-1] > 0
    st = transport.stats
    assert st.rounds == 3 and st.bytes_up > 0 and st.sim_time_s > 0
    assert trainer.transport is transport


# ---------------------------------------------------------------------------
# transports and the cost model
# ---------------------------------------------------------------------------


def test_transports_deliver_bytes_unchanged():
    payloads = [bytes([i]) * (10 + i) for i in range(4)]
    for name in ("loopback", "parameter_server", "ring", "hierarchical"):
        t = make_transport(name)
        assert t.exchange(list(payloads)) == payloads
        assert t.stats.rounds == 1
        assert t.stats.bytes_up == sum(len(p) for p in payloads)


def test_cost_model_topologies():
    cost = CostModel(latency_s=1e-3, bandwidth_bps=8e6)  # 1 MB/s, 1ms
    sizes = [1000, 2000, 3000, 4000]
    star, ring = make_topology("star"), make_topology("ring")
    # star: one latency + incast sum -> 1ms + 10ms
    assert star.step_time(sizes, cost) == pytest.approx(11e-3)
    # ring: 3 rounds of the 4000-byte max -> 3 * (1ms + 4ms)
    assert ring.step_time(sizes, cost) == pytest.approx(15e-3)
    assert star.wire_bytes(sizes) == 10000
    assert ring.wire_bytes(sizes) == 30000
    hier = make_topology("hierarchical", pod_size=2)
    assert hier.step_time(sizes, cost) > 0
    # post-hoc helper used by fig1: more workers -> never cheaper on a star
    t4 = simulated_step_time(1e6, 4, "star", cost)
    t8 = simulated_step_time(1e6, 8, "star", cost)
    assert t8 >= t4 * 0.99


def test_broadcast_accounting():
    t = LoopbackTransport()
    t.broadcast(100, workers=5)
    assert t.stats.bytes_down == 500
    # simulated downlink: all W copies serialize through one server egress
    cost = CostModel(latency_s=1e-3, bandwidth_bps=8e6)
    ps = make_transport("parameter_server", cost=cost)
    ps.broadcast(1000, workers=4)
    assert ps.stats.bytes_down == 4000
    assert ps.stats.sim_time_s == pytest.approx(1e-3 + 4000 / 1e6)


def test_broadcast_wire_bytes_parity_across_transports():
    """Regression: `LoopbackTransport.broadcast` used to skip ``wire_bytes``
    while `SimulatedTransport.broadcast` booked it, so identical traffic
    produced incomparable stats across transports.  Every transport must
    book the same payload accounting for the same traffic."""
    payloads = [b"x" * 100, b"y" * 60]
    lb = make_transport("loopback")
    ps = make_transport("parameter_server")   # star: wire == payload sum
    for t in (lb, ps):
        t.exchange(list(payloads))
        t.broadcast(1000, workers=2)
    assert lb.stats.bytes_up == ps.stats.bytes_up == 160
    assert lb.stats.bytes_down == ps.stats.bytes_down == 2000
    assert lb.stats.wire_bytes == ps.stats.wire_bytes == 160 + 2000


def test_make_transport_rejects_unused_kwargs():
    """Regression: the parameter_server/ring/loopback branches silently
    swallowed ``**topo_kw`` (make_transport("ring", pod_size=8) just
    dropped the kwarg)."""
    for name in ("loopback", "parameter_server", "ring"):
        with pytest.raises(TypeError, match="unsupported keyword"):
            make_transport(name, pod_size=8)
    # hierarchical consumes topology kwargs for real...
    t = make_transport("hierarchical", pod_size=8)
    assert t.topology.pod_size == 8
    # ...and still fails loudly on unknown ones
    with pytest.raises(TypeError):
        make_transport("hierarchical", nonsense=1)


def test_packet_from_bytes_rejects_corruption(grad):
    """A network transport sees torn frames: every structural violation
    must raise a descriptive ValueError, never a silently-corrupt packet."""
    raw = make_codec("qsgd", D, **CODEC_KW).encode(
        grad, jax.random.PRNGKey(2)).packet.to_bytes()
    assert Packet.from_bytes(raw)  # the pristine buffer parses

    with pytest.raises(ValueError, match="truncated packet"):
        Packet.from_bytes(raw[:10])                 # inside the header
    with pytest.raises(ValueError, match="truncated packet"):
        Packet.from_bytes(raw[:-1])                 # inside the last stream
    with pytest.raises(ValueError, match="bad packet magic"):
        Packet.from_bytes(b"XXXX" + raw[4:])
    with pytest.raises(ValueError, match="unknown codec id"):
        Packet.from_bytes(raw[:4] + b"\xee" + raw[5:])
    with pytest.raises(ValueError, match="unsupported packet version"):
        Packet.from_bytes(raw[:5] + b"\x09" + raw[6:])
    with pytest.raises(ValueError, match="trailing bytes"):
        Packet.from_bytes(raw + b"\x00")
