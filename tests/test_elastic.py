"""Elastic fault-tolerant tcp star: chaos battery.

Fast tier: the deterministic fault-injection harness itself (seeded
schedules, backoff), membership/Horvitz-Thompson unit math, and the
thread-based socket star under injected faults — read deadlines, clean
shutdown, deadline partial rounds with late-frame discard, torn frames,
kill + mid-run REJOIN, and a seeded unbiasedness run over real sockets.

Slow tier: 4 spawned OS processes training a stateful aggregator under a
deadline; one rank is hard-killed mid-run (RST), the world keeps serving
partial rounds, and the rank REJOINs with its gathered `CommState` row
restored bitwise.
"""

import pickle
import socket
import threading
import time

import numpy as np
import pytest

from repro.comm.elastic import (
    ACTIVE,
    LEFT,
    BackoffSchedule,
    Membership,
    participation_weights,
)
from repro.comm.faultinject import (
    Fault,
    FaultSchedule,
    FaultyTransport,
    InjectedFault,
)
from repro.comm.multihost import (
    ServerShutdown,
    TcpStarTransport,
    TransportError,
    pick_free_port,
)


def _sockets_available() -> bool:
    try:
        with socket.socket() as s:
            s.bind(("127.0.0.1", 0))
        return True
    except OSError:               # pragma: no cover - sandboxed environments
        return False


needs_sockets = pytest.mark.skipif(not _sockets_available(),
                                   reason="localhost sockets unavailable")


def _connect_elastic(world, *, deadline_ms=500.0, heartbeat_s=None,
                     read_timeout_s=None, timeout=15.0):
    """Threaded rendezvous of an ELASTIC world; returns {rank: transport}."""
    server = TcpStarTransport.listen(
        port=0, world=world, timeout=timeout, deadline_ms=deadline_ms,
        heartbeat_s=heartbeat_s, read_timeout_s=read_timeout_s)
    tps = {0: server}

    def join(r):
        tps[r] = TcpStarTransport.connect(
            "127.0.0.1", server.port, rank=r, world=world, timeout=timeout,
            deadline_ms=deadline_ms, heartbeat_s=heartbeat_s,
            read_timeout_s=read_timeout_s)

    threads = [threading.Thread(target=join, args=(r,))
               for r in range(1, world)]
    for t in threads:
        t.start()
    server.accept_workers()
    for t in threads:
        t.join()
    return tps


def _close_all(tps):
    for t in tps.values():
        t.close()


# ---------------------------------------------------------------------------
# deterministic harness units (no sockets)
# ---------------------------------------------------------------------------


def test_backoff_schedule_deterministic():
    a = BackoffSchedule(base_s=0.05, cap_s=0.4, retries=6, seed=3)
    b = BackoffSchedule(base_s=0.05, cap_s=0.4, retries=6, seed=3)
    assert a.delays() == b.delays(), "same seed must replay the same delays"
    assert a.delays() == a.delays(), "delays() must be a pure function"
    assert a.delays() != BackoffSchedule(base_s=0.05, cap_s=0.4, retries=6,
                                         seed=4).delays()
    delays = a.delays()
    assert len(delays) == 6
    for i, d in enumerate(delays):
        full = min(0.4, 0.05 * 2 ** i)
        assert 0.5 * full <= d <= full, f"attempt {i}: {d} outside jitter band"
    # jitter=0 is the exact exponential ramp, capped
    assert BackoffSchedule(base_s=0.1, cap_s=0.4, retries=4, jitter=0.0
                           ).delays() == [0.1, 0.2, 0.4, 0.4]


def test_fault_schedule_seeded_deterministic():
    kw = dict(world=4, rounds=50, p_delay=0.2, p_drop=0.3, delay_s=0.01,
              kills=[(2, 7)])
    a = FaultSchedule.seeded(11, **kw)
    b = FaultSchedule.seeded(11, **kw)
    assert len(a) == len(b) > 0
    for rank in range(4):
        for t in range(50):
            assert [(f.kind, f.seconds) for f in a.at(rank, t)] \
                == [(f.kind, f.seconds) for f in b.at(rank, t)]
    assert len(a) != len(FaultSchedule.seeded(12, **kw)) or any(
        a.at(r, t) != FaultSchedule.seeded(12, **kw).at(r, t)
        for r in range(4) for t in range(50))
    # rank 0 is the aggregation point: never faulted
    assert all(not a.at(0, t) for t in range(50))
    assert [f.kind for f in a.at(2, 7)][-1] == "kill"
    # a drop and a delay never share a slot (drop precedence)
    for rank in range(1, 4):
        for t in range(50):
            kinds = [f.kind for f in a.at(rank, t) if f.kind != "kill"]
            assert len(kinds) <= 1


def test_fault_validation():
    with pytest.raises(ValueError, match="fault kind"):
        Fault(0, "explode")
    with pytest.raises(ValueError, match="round must be >= 0"):
        Fault(-1, "drop")

    class _Rank0:
        rank = 0
    with pytest.raises(ValueError, match="rank 0"):
        FaultyTransport(_Rank0(), FaultSchedule())


def test_participation_weights():
    w = participation_weights([2, 4, 1], [4, 4, 4])
    assert w.tolist() == [2.0, 1.0, 4.0]
    with pytest.raises(ValueError, match="shape"):
        participation_weights([1, 2], [1, 2, 3])
    with pytest.raises(ValueError, match=">= 1 participation"):
        participation_weights([1, 0], [2, 2])


def test_membership_lifecycle_and_weights():
    mem = Membership(3)
    assert mem.active_ranks() == [0, 1, 2]
    # 4 rounds: rank 2 misses rounds 1 and 3
    for t, arrived in enumerate([[0, 1, 2], [0, 1], [0, 1, 2], [0, 1]]):
        mem.record_round(arrived, t)
    assert mem.rounds == 4
    np.testing.assert_allclose(mem.weights([0, 1, 2]), [1.0, 1.0, 2.0])
    mem.mark_left(2, 4, "rst")
    assert not mem.is_active(2) and mem.active_ranks() == [0, 1]
    first = mem.members[2].left_reason
    mem.mark_left(2, 9, "later")          # idempotent: first reason sticks
    assert mem.members[2].left_reason == first
    assert mem.members[2].left_round == 4
    # a round recorded while rank 2 is out touches only the active ranks
    mem.record_round([0, 1], 5)
    assert mem.members[2].rounds_seen == 4
    # rejoin resets the participation frequency to the new incarnation,
    # and the join round itself is never counted against the rejoiner
    mem.mark_joined(2, 6, rejoin=True)
    assert mem.is_active(2) and mem.members[2].rejoins == 1
    assert (mem.members[2].rounds_seen, mem.members[2].rounds_participated) \
        == (0, 0)
    mem.record_round([0, 1], 6)
    assert mem.members[2].rounds_seen == 0
    mem.record_round([0, 1, 2], 7)
    np.testing.assert_allclose(mem.weights([2]), [1.0])
    # rows: REJOIN serves the last gathered CommState row bitwise
    mem.store_row(2, b"row-two")
    assert mem.row(2) == b"row-two" and mem.row(1) is None
    s = pickle.loads(pickle.dumps(mem.summary()))
    assert s["members"][2]["rejoins"] == 1
    assert s["members"][1]["state"] == ACTIVE
    assert LEFT not in {m["state"] for m in s["members"].values()}


# ---------------------------------------------------------------------------
# socket star under faults (fast tier, threads)
# ---------------------------------------------------------------------------


@needs_sockets
def test_worker_read_deadline_names_peer_and_round():
    """A worker whose server goes silent must surface a descriptive
    TransportError after the heartbeat-derived read deadline — never hang
    forever on a dead rank 0."""
    tps = _connect_elastic(2, heartbeat_s=0.1, read_timeout_s=0.4)
    try:
        tps[1].exchange([b"round0"])
        with pytest.raises(TransportError) as ei:
            tps[1].broadcast_payload(None)      # rank 0 never broadcasts
        msg = str(ei.value)
        assert "rank 0" in msg and "round 0" in msg
        assert "direction broadcast" in msg
    finally:
        _close_all(tps)


@needs_sockets
def test_heartbeat_keeps_slow_round_alive():
    """While rank 0's reactor waits on a straggler it PINGs every link, so
    a fast worker with a short read deadline does NOT give up on a round
    that is merely slow."""
    tps = _connect_elastic(3, deadline_ms=5000.0, heartbeat_s=0.05,
                           read_timeout_s=0.25)
    got = {}

    def server():
        out = tps[0].exchange([b"s"])
        got[0] = out
        tps[0].broadcast_payload(b"the-direction")

    def fast():
        tps[1].exchange([b"fast"])
        got[1] = tps[1].broadcast_payload(None)

    def slow():
        time.sleep(0.8)           # >> rank 1's read_timeout_s
        tps[2].exchange([b"slow"])
        got[2] = tps[2].broadcast_payload(None)

    try:
        threads = [threading.Thread(target=f) for f in (fast, slow, server)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        assert got[0] == [b"s", b"fast", b"slow"]
        assert got[1] == got[2] == b"the-direction"
    finally:
        _close_all(tps)


@needs_sockets
def test_server_close_surfaces_clean_shutdown():
    """Rank 0's close() says GOODBYE("shutdown") down every link; a worker
    blocked on the next broadcast gets `ServerShutdown`, not a reset."""
    tps = _connect_elastic(2, heartbeat_s=0.1, read_timeout_s=5.0)
    try:
        tps[0].close()
        with pytest.raises(ServerShutdown, match="clean shutdown"):
            tps[1].broadcast_payload(None)
    finally:
        _close_all(tps)


@needs_sockets
def test_worker_leave_marks_member_left():
    """A worker's clean close() ships LEAVE; the elastic server drops the
    link, marks the rank left, and keeps serving partial rounds."""
    tps = _connect_elastic(3, deadline_ms=200.0, heartbeat_s=0.1)
    try:
        tps[2].close()
        out = {}

        def w1():
            tps[1].exchange([b"one"])
            out[1] = tps[1].broadcast_payload(None)

        t = threading.Thread(target=w1)
        t.start()
        got = tps[0].exchange([b"zero"])
        tps[0].broadcast_payload(b"dir")
        t.join(timeout=30)
        assert got == [b"zero", b"one", None]
        assert tps[0].last_participation == [0, 1]
        m = tps[0].membership.members[2]
        assert m.state == LEFT and "LEAVE" in m.left_reason
        assert out[1] == b"dir"
    finally:
        _close_all(tps)


@needs_sockets
def test_deadline_partial_round_discards_late_frame():
    """A straggler misses the deadline: the round closes without it, its
    LATE round-tagged frame is discarded on sight next round (never
    aggregated into the wrong round), and its fresh uplink lands."""
    tps = _connect_elastic(3, deadline_ms=300.0, heartbeat_s=0.1)
    try:
        results = {}

        def w1():
            tps[1].exchange([b"r1-0"])
            results["b0", 1] = tps[1].broadcast_payload(None)
            tps[1].exchange([b"r1-1"])
            results["b1", 1] = tps[1].broadcast_payload(None)

        def w2():
            time.sleep(0.8)                  # misses round 0's deadline
            tps[2].exchange([b"r2-0"])       # LATE: tagged round 0
            results["b0", 2] = tps[2].broadcast_payload(None)
            tps[2].exchange([b"r2-1"])
            results["b1", 2] = tps[2].broadcast_payload(None)

        threads = [threading.Thread(target=f) for f in (w1, w2)]
        for t in threads:
            t.start()
        out0 = tps[0].exchange([b"r0-0"])
        assert out0 == [b"r0-0", b"r1-0", None]
        assert tps[0].last_participation == [0, 1]
        tps[0].broadcast_payload(b"dir0")
        # round 1, per-call deadline override: rank 2's late round-0 frame
        # is discarded on sight and its fresh (resynced) uplink lands
        out1 = tps[0].exchange([b"r0-1"], deadline_ms=5000.0)
        assert out1 == [b"r0-1", b"r1-1", b"r2-1"]
        assert tps[0].last_participation == [0, 1, 2]
        tps[0].broadcast_payload(b"dir1")
        for t in threads:
            t.join(timeout=30)
        assert results["b0", 1] == results["b0", 2] == b"dir0"
        assert results["b1", 1] == results["b1", 2] == b"dir1"
        mem = tps[0].membership.members
        assert mem[2].rounds_seen == 2 and mem[2].rounds_participated == 1
        assert mem[1].rounds_participated == 2
    finally:
        _close_all(tps)


@needs_sockets
def test_injected_drop_skips_round_and_stays_aligned():
    """The harness's "drop": skip_round advances the round tag without
    sending, so the next uplink still lands in the RIGHT round."""
    tps = _connect_elastic(3, deadline_ms=250.0, heartbeat_s=0.1)
    faulty = FaultyTransport(
        tps[2], FaultSchedule({2: [Fault(0, "drop")]}))
    try:
        done = {}

        def w1():
            for t in range(2):
                tps[1].exchange([b"one%d" % t])
                done["w1", t] = tps[1].broadcast_payload(None)

        def w2():
            for t in range(2):
                assert faulty.exchange([b"two%d" % t]) == []
                done["w2", t] = faulty.broadcast_payload(None)

        threads = [threading.Thread(target=f) for f in (w1, w2)]
        for t in threads:
            t.start()
        assert tps[0].exchange([b"zero0"]) == [b"zero0", b"one0", None]
        tps[0].broadcast_payload(b"d0")
        assert tps[0].exchange([b"zero1"]) == [b"zero1", b"one1", b"two1"]
        tps[0].broadcast_payload(b"d1")
        for t in threads:
            t.join(timeout=30)
        assert done["w2", 0] == b"d0" and done["w2", 1] == b"d1"
        # the dropped rank still BOOKED the round (stats stay per-round)
        assert faulty.stats.rounds == 2
    finally:
        _close_all(tps)


def test_skip_round_guards():
    t = TcpStarTransport(1, 2)                     # not elastic
    with pytest.raises(ValueError, match="elastic"):
        t.skip_round()
    s = TcpStarTransport(0, 2, deadline_ms=100.0)
    with pytest.raises(ValueError, match="worker-side"):
        s.skip_round()


@needs_sockets
def test_torn_frame_drops_rank_and_round_completes():
    """A rank dying mid-write (header promising more bytes than follow,
    then RST) must not poison the reactor: the server drops the link,
    serves the round partial, and marks the rank left."""
    tps = _connect_elastic(3, deadline_ms=400.0, heartbeat_s=0.1)
    faulty = FaultyTransport(tps[2], FaultSchedule({2: [Fault(0, "torn")]}))
    try:
        def w1():
            tps[1].exchange([b"one"])
            tps[1].broadcast_payload(None)

        def w2():
            with pytest.raises(InjectedFault, match="torn"):
                faulty.exchange([b"two"])

        threads = [threading.Thread(target=f) for f in (w1, w2)]
        for t in threads:
            t.start()
        out = tps[0].exchange([b"zero"])
        tps[0].broadcast_payload(b"dir")
        for t in threads:
            t.join(timeout=30)
        assert out == [b"zero", b"one", None]
        assert tps[0].membership.members[2].state == LEFT
    finally:
        _close_all(tps)


@needs_sockets
def test_kill_then_rejoin_restores_row_and_snapshot():
    """The full elastic arc over real sockets: gather a CommState row, RST
    rank 2 mid-run, keep serving partial rounds, then REJOIN under seeded
    backoff — the returned row is bitwise the gathered one, the params
    snapshot comes from rank 0's provider, and the rank participates
    again (with its join round never counted against it)."""
    tps = _connect_elastic(3, deadline_ms=250.0, heartbeat_s=0.1)
    tps[0].snapshot_provider = lambda: b"PARAMS"
    faulty = FaultyTransport(tps[2], FaultSchedule({2: [Fault(1, "kill")]}))
    rounds = 6
    fail = []

    def w1():
        try:
            tps[1].gather_state(b"ROW1")
            t = 0
            while True:
                tps[1].exchange([b"one%d" % t])
                tps[1].broadcast_payload(None)
                t += 1
        except (ServerShutdown, TransportError):
            pass
        except Exception as e:    # pragma: no cover - surfaced via fail
            fail.append(("w1", repr(e)))

    def w2():
        try:
            faulty.gather_state(b"ROW2")
            faulty.exchange([b"two0"])
            faulty.broadcast_payload(None)
            with pytest.raises(InjectedFault, match="killed"):
                faulty.exchange([b"two1"])
            tp, row, snap = TcpStarTransport.rejoin(
                "127.0.0.1", tps[0].port, rank=2, world=3,
                deadline_ms=250.0, heartbeat_s=0.1,
                backoff=BackoffSchedule(base_s=0.05, cap_s=0.5,
                                        retries=12, seed=7))
            sent = 0
            try:
                assert row == b"ROW2", row
                assert snap == b"PARAMS", snap
                # consume the in-flight round's downlink, then rejoin the
                # round loop until the server closes the star
                tp.broadcast_payload(None)
                while True:
                    tp.exchange([b"back%d" % sent])
                    sent += 1
                    tp.broadcast_payload(None)
            except (ServerShutdown, TransportError):
                assert sent >= 1, "rejoiner never shipped an uplink"
            finally:
                tp.close()
        except Exception as e:    # pragma: no cover - surfaced via fail
            fail.append(("w2", repr(e)))

    threads = [threading.Thread(target=f) for f in (w1, w2)]
    for t in threads:
        t.start()
    try:
        rows = tps[0].gather_state(b"ROW0")
        assert rows == [b"ROW0", b"ROW1", b"ROW2"]
        partial, served = 0, 0
        while True:
            out = tps[0].exchange([b"zero%d" % served])
            assert out[1] is not None, f"rank 1 missed round {served}"
            partial += out[2] is None
            tps[0].broadcast_payload(b"dir%d" % served)
            served += 1
            m2 = tps[0].membership.members[2]
            if served >= rounds and m2.rejoins == 1 \
                    and m2.rounds_participated >= 1:
                break
            assert served < 80, "rank 2 never made it back into the world"
    finally:
        tps[0].close()
        for t in threads:
            t.join(timeout=60)
    assert not fail, fail
    assert partial >= 1, "the kill must cost at least one partial round"
    mem = tps[0].membership.members[2]
    assert mem.state == ACTIVE and mem.rejoins == 1
    assert mem.rounds_participated >= 1
    summary = tps[0].membership.summary()
    assert summary["members"][2]["rejoins"] == 1
    _close_all(tps)


@needs_sockets
def test_rejoin_refused_while_old_link_alive_then_backoff_wins():
    """An impostor REJOIN for a rank whose link is healthy is refused;
    the refusal text reaches the caller once the backoff is exhausted."""
    tps = _connect_elastic(2, deadline_ms=200.0, heartbeat_s=0.1)
    try:
        err = {}

        def impostor():
            try:
                TcpStarTransport.rejoin(
                    "127.0.0.1", tps[0].port, rank=1, world=2,
                    deadline_ms=200.0,
                    backoff=BackoffSchedule(base_s=0.01, cap_s=0.02,
                                            retries=2, seed=0))
            except TransportError as e:
                err["msg"] = str(e)

        def w1():
            tps[1].exchange([b"one"])
            tps[1].broadcast_payload(None)

        threads = [threading.Thread(target=f) for f in (impostor, w1)]
        for t in threads:
            t.start()
        # serve a few rounds so the listener polls while rank 1 is healthy
        for t in range(3):
            tps[0].exchange([b"zero"], deadline_ms=150.0)
            if t == 0:
                tps[0].broadcast_payload(b"d")
        for t in threads:
            t.join(timeout=30)
        assert "still connected" in err["msg"]
        assert tps[0].membership.members[1].state == ACTIVE
    finally:
        _close_all(tps)


def test_elastic_validation_errors():
    """deadline_ms composes only with elastic transports, and the elastic
    star composes only with the plain-direction aggregators."""
    from repro.comm import make_transport, packed_aggregator
    from repro.comm.transport import LoopbackTransport

    with pytest.raises(ValueError, match="elastic"):
        packed_aggregator("mlmc_topk", 32, transport=LoopbackTransport(),
                          k_fraction=0.25, deadline_ms=100.0)
    plain = TcpStarTransport(0, 2)
    with pytest.raises(ValueError, match="per-round deadline_ms"):
        plain.exchange([b"x"], deadline_ms=50.0)
    el = TcpStarTransport(0, 2, deadline_ms=100.0)
    with pytest.raises(ValueError, match="downlink"):
        packed_aggregator("mlmc_topk", 32, transport=el, k_fraction=0.25,
                          downlink="topk")
    with pytest.raises(ValueError, match="elastic"):
        packed_aggregator("mlmc_topk", 32, transport=el, k_fraction=0.25,
                          bucket_size=16)
    with pytest.raises(ValueError, match="elastic"):
        packed_aggregator("ef21", 32, transport=el, k_fraction=0.25)
    # the sim transports reject the elastic knobs outright
    with pytest.raises(TypeError, match="deadline_ms"):
        make_transport("loopback", deadline_ms=100.0)


# ---------------------------------------------------------------------------
# statistics: Horvitz-Thompson reweighting over real sockets
# ---------------------------------------------------------------------------


def _run_elastic_rounds(tps, schedule, grads, rounds):
    """Drive `MultihostPackedAggregate` (dense codec) for ``rounds`` over
    an elastic world with ``schedule`` injected on the workers.  Returns
    (per-round directions from rank 0, per-round participation masks)."""
    import jax

    from repro.comm import packed_aggregator

    world = len(tps)
    dirs, masks = [], []
    aggs = {0: packed_aggregator("dense", grads.shape[1], transport=tps[0])}
    for r in range(1, world):
        aggs[r] = packed_aggregator(
            "dense", grads.shape[1],
            transport=FaultyTransport(tps[r], schedule))
    rng = jax.random.PRNGKey(0)
    fail = []

    def worker(r):
        try:
            for t in range(rounds):
                aggs[r](grads[r:r + 1], rng, None)
        except Exception as e:    # pragma: no cover - surfaced below
            fail.append((r, repr(e)))

    threads = [threading.Thread(target=worker, args=(r,))
               for r in range(1, world)]
    for t in threads:
        t.start()
    for t in range(rounds):
        out = aggs[0](grads[0:1], rng, None)
        dirs.append(np.asarray(out.direction, np.float64))
        mask = np.zeros(world, bool)
        mask[tps[0].last_participation] = True
        masks.append(mask)
    for t in threads:
        t.join(timeout=120)
    assert not fail, fail
    return np.stack(dirs), np.stack(masks)


@needs_sockets
def test_deadline_reweighting_is_unbiased():
    """The acceptance statistic: under seeded Bernoulli drops the run-mean
    of the Horvitz-Thompson partial directions converges to the FULL-world
    mean gradient, and beats the naive mean-over-arrivals (recomputed from
    the recorded masks), which drifts toward the always-present ranks."""
    world, d, rounds = 4, 32, 80
    rng = np.random.default_rng(5)
    grads = np.asarray(rng.normal(size=(world, d)) +
                       4.0 * np.arange(world)[:, None], np.float32)
    gbar = grads.astype(np.float64).mean(axis=0)
    sched = FaultSchedule.seeded(21, world=world, rounds=rounds, p_drop=0.35)
    tps = _connect_elastic(world, deadline_ms=60.0, heartbeat_s=0.5)
    try:
        dirs, masks = _run_elastic_rounds(tps, sched, grads, rounds)
    finally:
        _close_all(tps)
    assert masks.all(axis=1).sum() < rounds, "the schedule must drop rounds"
    assert (~masks[:, 0]).sum() == 0, "rank 0 never misses its own deadline"
    ht_err = np.linalg.norm(dirs.mean(axis=0) - gbar)
    naive = np.stack([grads[m].astype(np.float64).mean(axis=0)
                      for m in masks]).mean(axis=0)
    naive_err = np.linalg.norm(naive - gbar)
    scale = np.linalg.norm(gbar)
    assert ht_err < 0.20 * scale, (ht_err, scale)
    assert ht_err < 0.5 * naive_err, \
        f"HT ({ht_err:.3f}) must beat the naive mean ({naive_err:.3f})"


@needs_sockets
def test_zero_fault_elastic_matches_loopback_bitwise():
    """A fault-free elastic run IS the synchronous run: with every rank
    inside the deadline all HT weights are exactly 1, the exact-mean path
    serves every round, and the trained params equal loopback bitwise."""
    import jax.numpy as jnp

    from repro.optim import sgd
    from repro.train import Trainer

    d, world, steps = 48, 3, 4

    def trainer(transport):
        params = {"w": jnp.zeros((d,)), "b": jnp.zeros(())}

        def loss_fn(p, batch):
            return jnp.mean((batch["x"] @ p["w"] + p["b"] - batch["y"]) ** 2)

        return Trainer(loss_fn, params, num_workers=world,
                       method="mlmc_topk", optimizer=sgd(0.1),
                       k_fraction=0.25, wire="packed", transport=transport)

    def batches():
        import jax

        key = jax.random.PRNGKey(7)
        wkey, key = jax.random.split(key)
        w_true = jax.random.normal(wkey, (d,))
        while True:
            key, kx = jax.random.split(key)
            x = jax.random.normal(kx, (world, 4, d))
            yield {"x": x, "y": x @ w_true}

    ref = trainer(None)
    ref.fit(batches(), steps=steps, seed=11)
    want = np.asarray(ref.flat_params).tobytes()

    tps = _connect_elastic(world, deadline_ms=30000.0)
    results = {}

    def run_rank(r):
        tr = trainer(tps[r])
        tr.fit(batches(), steps=steps, seed=11)
        results[r] = np.asarray(tr.flat_params).tobytes()

    threads = [threading.Thread(target=run_rank, args=(r,))
               for r in range(1, world)]
    for t in threads:
        t.start()
    run_rank(0)
    for t in threads:
        t.join(timeout=120)
    _close_all(tps)
    for r in range(world):
        assert results[r] == want, f"rank {r} diverged from loopback"
    mem = tps[0].membership
    assert all(m.rounds_participated == m.rounds_seen
               for m in mem.members.values())


# ---------------------------------------------------------------------------
# the real thing: spawned OS processes (slow tier)
# ---------------------------------------------------------------------------

_SPAWN = dict(world=4, d=48, rounds=14, deadline_ms=400.0, heartbeat_s=0.5,
              kill_round=5, gather_round=3, seed=3)


def _spawn_grads(rank):
    rng = np.random.default_rng(_SPAWN["seed"] + rank)
    return np.asarray(rng.normal(size=(1, _SPAWN["d"])), np.float32)


def _spawn_server_main(port, q):
    try:
        import jax

        from repro.comm import packed_aggregator
        from repro.comm.aggregate import pack_comm_state_row
        from repro.comm.multihost import TcpStarTransport

        s = _SPAWN
        tp = TcpStarTransport.serve(
            port=port, world=s["world"], timeout=120.0,
            deadline_ms=s["deadline_ms"], heartbeat_s=s["heartbeat_s"])
        tp.snapshot_provider = lambda: b"SNAP"
        agg = packed_aggregator("mlmc_adaptive_topk", s["d"], transport=tp,
                                k_fraction=0.25)
        state = agg.init(s["world"], s["d"])
        grads = _spawn_grads(0)
        partial = 0
        for t in range(s["rounds"]):
            if t == s["gather_round"]:
                rows = tp.gather_state(pack_comm_state_row(state, 0))
            out = agg(grads, jax.random.PRNGKey(t), state)
            state = out.state
            partial += len(tp.last_participation) < s["world"]
        summary = tp.membership.summary()
        tp.close()
        q.put(("server", None, dict(partial=partial, summary=summary,
                                    row_lens=[len(r or b"") for r in rows])))
    except Exception as e:        # pragma: no cover - surfaced by the parent
        q.put(("server", repr(e), None))


def _spawn_worker_main(rank, port, q):
    try:
        import jax

        from repro.comm import packed_aggregator
        from repro.comm.aggregate import (fold_comm_state_rows,
                                          pack_comm_state_row)
        from repro.comm.elastic import BackoffSchedule
        from repro.comm.faultinject import (Fault, FaultSchedule,
                                            FaultyTransport, InjectedFault)
        from repro.comm.multihost import (ServerShutdown, TcpStarTransport,
                                          TransportError)

        s = _SPAWN
        tp = TcpStarTransport.connect(
            "127.0.0.1", port, rank=rank, world=s["world"], timeout=120.0,
            deadline_ms=s["deadline_ms"], heartbeat_s=s["heartbeat_s"])
        sched = FaultSchedule()
        if rank == 3:
            sched.add(3, Fault(s["kill_round"], "kill"))
        wrapped = FaultyTransport(tp, sched)
        agg = packed_aggregator("mlmc_adaptive_topk", s["d"],
                                transport=wrapped, k_fraction=0.25)
        state = agg.init(s["world"], s["d"])
        grads = _spawn_grads(rank)
        my_row = None
        report = dict(rounds=0, rejoined=False, row_ok=None, snap=None,
                      post_rejoin_rounds=0)
        t = 0
        try:
            while True:
                if t == s["gather_round"]:
                    my_row = pack_comm_state_row(state, rank)
                    wrapped.gather_state(my_row)
                try:
                    out = agg(grads, jax.random.PRNGKey(t), state)
                except InjectedFault:
                    # hard-killed (RST): walk the seeded backoff back in
                    tp2, row, snap = TcpStarTransport.rejoin(
                        "127.0.0.1", port, rank=rank, world=s["world"],
                        deadline_ms=s["deadline_ms"],
                        heartbeat_s=s["heartbeat_s"],
                        backoff=BackoffSchedule(base_s=0.1, cap_s=1.0,
                                                retries=12, seed=rank))
                    report["rejoined"] = True
                    report["row_ok"] = row == my_row
                    report["snap"] = snap
                    # the served row restores this rank's CommState bitwise
                    state = fold_comm_state_rows(
                        agg.init(s["world"], s["d"]), [row])
                    wrapped = tp2
                    agg = packed_aggregator(
                        "mlmc_adaptive_topk", s["d"], transport=tp2,
                        k_fraction=0.25)
                    tp2.broadcast_payload(None)   # in-flight round's downlink
                    t = tp2.joined_round + 1
                    continue
                state = out.state
                report["rounds"] += 1
                if report["rejoined"]:
                    report["post_rejoin_rounds"] += 1
                t += 1
        except (ServerShutdown, TransportError):
            pass
        q.put((rank, None, report))
    except Exception as e:        # pragma: no cover - surfaced by the parent
        q.put((rank, repr(e), None))


@pytest.mark.slow
@needs_sockets
def test_spawned_kill_rejoin_trains_through_partial_rounds():
    """The acceptance run: 4 OS processes aggregate a stateful method under
    a deadline; rank 3 is RST-killed mid-run, the world keeps serving
    partial rounds, and rank 3 REJOINs — its gathered CommState row comes
    back bitwise, rank 0's snapshot arrives, and it participates again."""
    import multiprocessing as mp

    ctx = mp.get_context("spawn")
    port = pick_free_port()
    q = ctx.Queue()
    procs = [ctx.Process(target=_spawn_server_main, args=(port, q))]
    procs += [ctx.Process(target=_spawn_worker_main, args=(r, port, q))
              for r in range(1, _SPAWN["world"])]
    for p in procs:
        p.start()
    try:
        results = {}
        for _ in range(len(procs)):
            who, err, payload = q.get(timeout=300)
            assert err is None, f"{who} failed: {err}"
            results[who] = payload
        for p in procs:
            p.join(timeout=60)
    finally:
        for p in procs:
            if p.is_alive():      # pragma: no cover - cleanup on failure
                p.terminate()

    srv = results["server"]
    assert srv["partial"] >= 1, "the kill must cost at least one partial round"
    assert len(srv["row_lens"]) == _SPAWN["world"]
    assert all(n > 0 for n in srv["row_lens"]), \
        "every rank's CommState row must land in the gather"
    m3 = srv["summary"]["members"][3]
    assert m3["rejoins"] == 1 and m3["state"] == ACTIVE
    assert m3["rounds_participated"] >= 1
    for r in (1, 2):
        assert not results[r]["rejoined"]
        assert results[r]["rounds"] >= _SPAWN["rounds"] - 1
    r3 = results[3]
    assert r3["rejoined"] and r3["row_ok"] is True
    assert r3["snap"] == b"SNAP"
    assert r3["post_rejoin_rounds"] >= 1, "rank 3 never aggregated again"
