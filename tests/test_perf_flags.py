"""§Perf optimization flags must be EXACT rewrites: decode outputs with
REPRO_OPT flags on == baseline (token-for-token), and the MLMC bf16-wire
variant stays unbiased.  Each flagged test runs in a subprocess so the env
var is set before tracing."""

import pathlib
import subprocess
import sys
import textwrap

import pytest

ROOT = pathlib.Path(__file__).parent.parent

_SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["REPRO_OPT"] = sys.argv[1]
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import ASSIGNED, reduce_for_smoke
    from repro.models import build_model
    cfg = reduce_for_smoke([c for c in ASSIGNED if c.name == sys.argv[2]][0])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                              cfg.vocab_size)
    caches, nxt, enc = model.prefill(params, {"tokens": toks}, 28)
    out = [np.asarray(nxt)]
    tok = nxt
    for i in range(3):
        tok, caches = model.decode_step(params, tok, jnp.int32(24 + i),
                                        caches)
        out.append(np.asarray(tok))
    print("TOKENS", [int(x) for o in out for x in o])
""")


def _decode_tokens(flags: str, arch: str) -> str:
    proc = subprocess.run([sys.executable, "-c", _SCRIPT, flags, arch],
                          cwd=ROOT, capture_output=True, text=True,
                          timeout=540)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return [l for l in proc.stdout.splitlines() if l.startswith("TOKENS")][0]


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma3-27b", "deepseek-v3-671b"])
def test_perf_flags_exact(arch):
    base = _decode_tokens("", arch)
    opt = _decode_tokens("grouped_decode,sparse_moe_gather", arch)
    assert base == opt


def test_bf16_wire_unbiased():
    """bf16 residual values keep the estimator unbiased (just coarser)."""
    import os
    import subprocess

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["REPRO_OPT"] = "bf16_wire"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh, ctx_for_mesh
        from repro.sharding import shard_map
        from repro.sharding.collectives import compressed_allreduce
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        ctx = ctx_for_mesh(mesh)
        d = 512
        decay = jnp.exp(-0.02 * jnp.arange(d))
        g = jax.random.normal(jax.random.PRNGKey(0), (2, 2, d)) * decay
        target = np.asarray(g.mean((0, 1)))
        def body(gs, rng):
            out, bits = compressed_allreduce(gs.reshape(-1), ctx, rng,
                                             "mlmc_topk", k_fraction=0.05)
            return out, bits
        fn = jax.jit(shard_map(body, mesh=mesh,
            in_specs=(P("pod", "data", None), P()),
            out_specs=(P(), P()), check_vma=False))
        outs = np.stack([np.asarray(fn(g, k)[0])
                         for k in jax.random.split(jax.random.PRNGKey(2), 60)])
        rel = np.linalg.norm(outs.mean(0) - target) / np.linalg.norm(target)
        assert rel < 0.3, rel
        print("PASS", rel)
    """)
    proc = subprocess.run([sys.executable, "-c", script], cwd=ROOT,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "PASS" in proc.stdout
