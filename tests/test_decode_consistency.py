"""Decode-vs-forward consistency: teacher-forced decode logits must follow
the same distribution the full forward produces — verified by greedy token
agreement when continuing a prefix.  Strong end-to-end check of the cache
machinery (ring buffers, seq-sharding paths, SSM state carry-over)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, reduce_for_smoke
from repro.models import build_model
from repro.models.layers import vocab_parallel_argmax
from repro.sharding.ctx import unsharded

# one representative per family + the SWA pattern
PICKS = ["gemma3-27b", "mamba2-370m", "recurrentgemma-2b", "qwen3-4b",
         "mixtral-8x22b", "deepseek-v3-671b"]
CFGS = [c for c in ASSIGNED if c.name in PICKS]


@pytest.mark.parametrize("cfg_full", CFGS, ids=lambda c: c.name)
def test_decode_matches_forward(cfg_full):
    cfg = reduce_for_smoke(cfg_full)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S_total, S_prompt = 2, 24, 16
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (B, S_total), 0, cfg.vocab_size)

    # ground truth: greedy next-token at each position from ONE full forward
    h, _, _, _, n_extra = model.hidden_sequence(
        params, {"tokens": tokens}, unsharded())
    lg = model._local_logits(params, h)
    full_greedy = np.asarray(vocab_parallel_argmax(lg, unsharded()))

    # prefill the prompt, then teacher-forced decode of the remaining tokens
    caches, nxt, enc = model.prefill(params, {"tokens": tokens[:, :S_prompt]},
                                     S_total)
    np.testing.assert_array_equal(np.asarray(nxt),
                                  full_greedy[:, S_prompt - 1])
    decode = jax.jit(lambda t, p, c: model.decode_step(params, t, p, c,
                                                       enc_out=enc))
    for i in range(S_prompt, S_total - 1):
        tok, caches = decode(tokens[:, i], jnp.int32(i), caches)
        np.testing.assert_array_equal(np.asarray(tok), full_greedy[:, i],
                                      err_msg=f"{cfg.name} pos {i}")
