"""Per-architecture smoke tests (deliverable f): instantiate a REDUCED
variant of each assigned family, run one forward/train step on CPU, assert
output shapes + no NaNs.  Full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, reduce_for_smoke
from repro.models import build_model

B, S = 2, 32


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision"] = 0.1 * jax.random.normal(
            key, (B, cfg.num_vision_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["source"] = 0.1 * jax.random.normal(
            key, (B, cfg.encoder.max_source_len, cfg.encoder.d_model))
    return batch


@pytest.fixture(scope="module", params=ASSIGNED, ids=lambda c: c.name)
def arch(request):
    cfg = reduce_for_smoke(request.param)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_reduced_constraints(arch):
    cfg, _, _ = arch
    assert cfg.d_model <= 512
    assert cfg.num_layers <= 8  # one pattern repeat + prefix
    if cfg.moe:
        assert cfg.moe.num_experts <= 4


def test_forward_loss_finite(arch):
    cfg, model, params = arch
    loss, metrics = jax.jit(
        lambda p, b: model.loss(p, b, remat=False))(params,
                                                    _batch(cfg, jax.random.PRNGKey(1)))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), cfg.name
    assert bool(jnp.isfinite(metrics["ce"]))


def test_train_step_no_nans(arch):
    """One SGD step; every updated parameter stays finite."""
    cfg, model, params = arch
    batch = _batch(cfg, jax.random.PRNGKey(2))

    @jax.jit
    def step(p):
        g = jax.grad(lambda q: model.loss(q, batch, remat=False)[0])(p)
        return jax.tree.map(
            lambda w, gg: w - 0.01 * gg.astype(w.dtype), p, g)

    new = step(params)
    for leaf in jax.tree.leaves(new):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new)))
    assert moved


def test_prefill_decode_shapes(arch):
    cfg, model, params = arch
    batch = _batch(cfg, jax.random.PRNGKey(3))
    pb = {k: v for k, v in batch.items() if k != "labels"}
    caches, nxt, enc = jax.jit(
        lambda p, b: model.prefill(p, b, S))(params, pb)
    assert nxt.shape == (B,)
    assert nxt.dtype == jnp.int32
    assert int(nxt.min()) >= 0 and int(nxt.max()) < cfg.vocab_size
    tok2, caches2 = jax.jit(
        lambda p, t, c: model.decode_step(p, t, jnp.int32(S), c,
                                          enc_out=enc))(params, nxt, caches)
    assert tok2.shape == (B,)
    # cache pytrees keep structure and shapes
    for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(caches2)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_param_count_formula(arch):
    """ModelConfig.param_count() ≈ actual initialized parameter count."""
    cfg, model, params = arch
    actual = sum(x.size for x in jax.tree.leaves(params))
    approx = cfg.param_count()
    if cfg.mtp_depth:
        # the formula excludes the mtp block; allow the gap
        approx += sum(x.size for x in jax.tree.leaves(params["mtp"]))
    if cfg.family == "vlm":
        pass
    assert 0.5 * actual <= approx <= 2.0 * actual, (cfg.name, actual, approx)
