"""End-to-end system behaviour: the trainer actually trains, MLMC beats the
unbiased strawman on loss-vs-bits, the serving engine generates, and the
checkpointed model restores to identical behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs import get_config, reduce_for_smoke
from repro.data import LMTask, lm_batches
from repro.models import build_model
from repro.optim import sgd
from repro.serve import Engine
from repro.train import Trainer


@pytest.fixture(scope="module")
def small_model():
    cfg = reduce_for_smoke(get_config("paper-scale"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _train(cfg, model, params, method, steps=25, workers=4, seed=0):
    tr = Trainer(lambda p, b: model.loss(p, b, remat=False)[0], params,
                 num_workers=workers, method=method, optimizer=sgd(0.05),
                 k_fraction=0.02)
    data = lm_batches(LMTask(vocab=cfg.vocab_size, seq=32), workers, 2,
                      seed=seed)
    return tr, tr.fit(data, steps=steps, seed=seed)


@pytest.mark.slow
def test_mlmc_training_reduces_loss(small_model):
    cfg, model, params = small_model
    _, hist = _train(cfg, model, params, "mlmc_topk")
    assert hist.loss[-1] < hist.loss[0]
    assert hist.bits[-1] > 0
    # monotone cumulative bits
    assert all(b2 >= b1 for b1, b2 in zip(hist.bits, hist.bits[1:]))


@pytest.mark.slow
def test_bits_ledger_orders_methods(small_model):
    """Per-step bits: mlmc_topk << dense; ef21(topk) << dense."""
    cfg, model, params = small_model
    per_step = {}
    for method in ("dense", "mlmc_topk", "ef21"):
        _, hist = _train(cfg, model, params, method, steps=3)
        per_step[method] = hist.bits[0]
    # mlmc payload = one k_fraction-sized segment (values+indices) per
    # worker: >= 20x below dense at k_fraction = 0.02
    assert per_step["mlmc_topk"] < per_step["dense"] / 20
    assert per_step["ef21"] < per_step["dense"]


@pytest.mark.slow
def test_engine_generates(small_model):
    cfg, model, params = small_model
    eng = Engine(model, params)
    out = eng.generate(
        {"tokens": jnp.ones((2, 8), jnp.int32)}, max_new_tokens=5)
    assert out.tokens.shape == (2, 5)
    assert int(out.tokens.max()) < cfg.vocab_size


@pytest.mark.slow
def test_checkpoint_restores_behaviour(small_model, tmp_path):
    cfg, model, params = small_model
    tr, _ = _train(cfg, model, params, "mlmc_fixed", steps=5)
    checkpoint.save(tmp_path / "m", tr.params, {"steps": 5})
    restored, meta = checkpoint.restore(tmp_path / "m", tr.params)
    assert meta["steps"] == 5
    batch = {"tokens": jnp.ones((1, 16), jnp.int32),
             "labels": jnp.ones((1, 16), jnp.int32)}
    l1 = model.loss(tr.params, batch, remat=False)[0]
    l2 = model.loss(restored, batch, remat=False)[0]
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
