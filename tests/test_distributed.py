"""Multi-device integration tests.  Each test spawns a subprocess with 8
forced host devices (so the main pytest process keeps the real single CPU
device, per the assignment's XLA_FLAGS hygiene rule)."""

import pathlib
import subprocess
import sys

import pytest

WORKER = pathlib.Path(__file__).parent / "distributed_worker.py"
ROOT = pathlib.Path(__file__).parent.parent


def _run(which: str) -> str:
    proc = subprocess.run(
        [sys.executable, str(WORKER), which],
        cwd=ROOT, capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, f"{which} failed:\n{proc.stdout}\n{proc.stderr}"
    return proc.stdout


@pytest.mark.slow
def test_compressed_collectives_on_mesh():
    out = _run("collectives")
    assert "PASS dense_exact" in out
    assert "PASS mlmc_topk_unbiased" in out
    assert "PASS mlmc_fixed_unbiased" in out


@pytest.mark.slow
def test_device_wire_parity_on_mesh():
    """Cross-wire matrix: wire="device" == wire="abstract" exactly on an
    8-device mesh, measured bits reconcile with the core.bits ledger, no
    host callbacks, and a full train step runs on the device wire."""
    out = _run("device_wire")
    for method in ("mlmc_topk", "mlmc_fixed", "qsgd", "rtn", "signsgd"):
        assert f"PASS device_parity_{method}" in out
    assert "PASS device_no_callbacks" in out
    assert "PASS device_train_step" in out


@pytest.mark.slow
def test_stateful_pipeline_on_mesh():
    """The stateful-pipeline battery: mlmc_adaptive_topk's mesh collective
    threads its EMA ladder identically on abstract vs device wires, the
    stateful aggregators (EF21/EF21-SGDM/mlmc_adaptive_topk) hold
    cross-wire parity under the 8-device runtime, and the stateful train
    step runs end-to-end with threaded comm state."""
    out = _run("stateful")
    assert "PASS stateful_mesh_collective_parity" in out
    for name in ("ef21", "ef21_sgdm", "mlmc_adaptive_topk"):
        assert f"PASS stateful_wires_{name}" in out
    assert "PASS stateful_train_step" in out


@pytest.mark.slow
def test_ef21_and_policy_on_mesh():
    """Mesh EF21 (per-shard mirror + server replica threaded like the
    adaptive ladder) and per-leaf `policy=` dispatch on `make_train_step`."""
    out = _run("ef21_policy")
    assert "PASS ef21_mesh_abstract" in out
    assert "PASS ef21_mesh_device" in out
    assert "PASS ef21_train_step" in out
    assert "PASS policy_train_step" in out


@pytest.mark.slow
def test_select_primitives_on_mesh():
    """Sort-free selection on the mesh: `global_topk_mask` (psum'd byte
    histograms, cross-shard tie-break) == the host reference,
    ``ef21_topk_allreduce(selection="global")`` reproduces the global-
    budget direction, and `mlmc_fixed_pershard` holds abstract==device
    parity with genuinely per-shard scales."""
    out = _run("select_mesh")
    assert "PASS global_topk_mask" in out
    assert "PASS ef21_global_selection" in out
    assert "PASS mlmc_fixed_pershard" in out


@pytest.mark.slow
def test_sharded_train_parity():
    assert "PASS train_parity" in _run("train")


@pytest.mark.slow
def test_fsdp_parity():
    assert "PASS fsdp_parity" in _run("fsdp")


@pytest.mark.slow
def test_sharded_decode_parity():
    assert "PASS decode_parity" in _run("decode")
