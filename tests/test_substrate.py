"""Substrate tests: optimizers, checkpointing, data pipeline, bit ledger,
partition rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import checkpoint
from repro.configs import ASSIGNED
from repro.data import LMTask, TeacherTask, lm_batches, teacher_student
from repro.models import build_model
from repro.optim import adamw, momentum_sgd, sgd
from repro.sharding.partition import param_specs, replicate_set


# --- optimizers -------------------------------------------------------------


@pytest.mark.parametrize("opt", [sgd(0.1), momentum_sgd(0.1), adamw(0.1)],
                         ids=["sgd", "momentum", "adamw"])
def test_optimizer_descends_quadratic(opt):
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(60):
        grads = jax.tree.map(lambda p: 2 * p, params)  # d/dx ||x||^2
        params, state = opt.apply(grads, state, params)
    assert float(jnp.linalg.norm(params["x"])) < 0.3


def test_momentum_accumulates():
    opt = momentum_sgd(1.0, beta=0.5)
    params = {"x": jnp.zeros(1)}
    state = opt.init(params)
    g = {"x": jnp.ones(1)}
    params, state = opt.apply(g, state, params)
    np.testing.assert_allclose(np.asarray(params["x"]), -1.0)
    params, state = opt.apply(g, state, params)
    np.testing.assert_allclose(np.asarray(params["x"]), -2.5)  # 1 + 1.5


# --- checkpoint --------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "tup": (jnp.zeros(2), jnp.asarray(3))}
    checkpoint.save(tmp_path / "ck", tree, {"step": 7})
    restored, meta = checkpoint.restore(tmp_path / "ck", tree)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_shape_mismatch(tmp_path):
    checkpoint.save(tmp_path / "ck", {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        checkpoint.restore(tmp_path / "ck", {"a": jnp.zeros(4)})


# --- data --------------------------------------------------------------------


def test_lm_batches_shapes_and_determinism():
    task = LMTask(vocab=64, seq=16)
    it1 = lm_batches(task, num_workers=3, batch_per_worker=2, seed=5)
    it2 = lm_batches(task, num_workers=3, batch_per_worker=2, seed=5)
    b1, b2 = next(it1), next(it2)
    assert b1["tokens"].shape == (3, 2, 16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert int(b1["tokens"].max()) < 64
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["labels"][..., :-1]),
                                  np.asarray(b1["tokens"][..., 1:]))


def test_lm_heterogeneity_differs_across_workers():
    hom = next(lm_batches(LMTask(vocab=64, seq=64, noise=0.0),
                          2, 4, seed=1))
    het = next(lm_batches(LMTask(vocab=64, seq=64, noise=0.0,
                                 heterogeneity=1.0), 2, 4, seed=1))
    # heterogeneous workers follow different recurrences
    assert not np.array_equal(np.asarray(het["tokens"][0]),
                              np.asarray(het["tokens"][1])) or \
        np.array_equal(np.asarray(hom["tokens"][0]),
                       np.asarray(hom["tokens"][0]))


def test_teacher_student_learnable():
    it = teacher_student(TeacherTask(noise=0.0), 1, 64, seed=0)
    b = next(it)
    assert b["x"].shape == (1, 64, 32)
    assert float(jnp.std(b["y"])) > 0


# --- partition rules ----------------------------------------------------------


@pytest.mark.parametrize("cfg", ASSIGNED, ids=lambda c: c.name)
def test_param_specs_divisibility(cfg):
    """Every sharded axis divides the mesh size at tp=16, dp=16 —
    the production-mesh precondition for every assigned arch."""
    model = build_model(cfg)
    abstract = model.abstract_params()
    specs = param_specs(abstract, dp=16, tp=16, fsdp=cfg.fsdp,
                        replicate=replicate_set(cfg, 16))
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(abstract)[0],
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax == "model":
                assert dim % 16 == 0, (path, leaf.shape, spec)
            if ax == "data":
                assert dim % 16 == 0, (path, leaf.shape, spec)


def test_recurrentgemma_attention_replicated():
    cfg = [c for c in ASSIGNED if c.name == "recurrentgemma-2b"][0]
    assert replicate_set(cfg, 16) != frozenset()   # 10 heads % 16 != 0
    model = build_model(cfg)
    specs = param_specs(model.abstract_params(), dp=16, tp=16, fsdp=False,
                        replicate=replicate_set(cfg, 16))
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    wq_specs = [s for p, s in flat
                if any(getattr(e, "key", "") == "wq" for e in p)]
    assert wq_specs and all("model" not in tuple(s) for s in wq_specs)
