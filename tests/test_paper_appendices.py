"""Deeper paper-fidelity tests: App. B's floating-point variance identity,
App. F.4's heterogeneous setting, and Eq. 43's fixed-point second moment."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FixedPointMultilevel, FloatingPointMultilevel
from repro.data import LMTask, lm_batches
from repro.models import build_model
from repro.optim import sgd
from repro.train import Trainer
from benchmarks.common import small_lm_config


def test_app_b_floating_point_variance_identity():
    """App. B Eq. 29-31 (adapted to the f32 ladder): with p_l ∝ 2^-l,
    sum_l resid_l^2 / p_l == (1 - 2^-L) * |base| * (|v| - |base|)
    element-wise, where base = sign(v)·2^E is the transmitted leading term."""
    comp = FloatingPointMultilevel(num_bits=20)
    v = jax.random.normal(jax.random.PRNGKey(0), (64,))
    p = np.asarray(comp.static_probs())
    base = np.asarray(comp.base(v))
    lhs = np.zeros_like(base)
    for l in range(1, comp.num_levels):  # exclude the exact-identity top
        r = np.asarray(comp.residual(v, l))
        lhs += r * r / p[l - 1]
    rhs = (1 - 2.0 ** -comp.num_levels) * np.abs(base) * (
        np.abs(np.asarray(v)) - np.abs(base))
    # the exact-identity top level carries the sub-2^-L tail; tolerance
    # covers its (tiny) contribution
    np.testing.assert_allclose(lhs, rhs, rtol=5e-2, atol=1e-6)


def test_eq_43_fixed_point_second_moment():
    """Eq. 43: with optimal probs, E|e~|^2 = (1-2^-L) * scale * |e| per
    element (the |v|_1 identity of Eq. 44)."""
    comp = FixedPointMultilevel(num_bits=20)
    v = jax.random.uniform(jax.random.PRNGKey(1), (64,), minval=-1.0,
                           maxval=1.0)
    scale = float(jnp.max(jnp.abs(v)))
    p = np.asarray(comp.static_probs())
    lhs = np.zeros((64,))
    for l in range(1, comp.num_levels):
        r = np.asarray(comp.residual(v, l))
        lhs += r * r / p[l - 1]
    rhs = (1 - 2.0 ** -comp.num_levels) * scale * np.abs(np.asarray(v))
    np.testing.assert_allclose(lhs, rhs, rtol=5e-2, atol=1e-5)


def test_heterogeneous_training_converges():
    """App. F.4: MLMC-compressed SGD still trains when workers sample from
    DIFFERENT distributions (bounded-heterogeneity setting)."""
    cfg = small_lm_config(layers=1, d_model=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tr = Trainer(lambda p, b: model.loss(p, b, remat=False)[0], params,
                 num_workers=4, method="mlmc_topk", optimizer=sgd(0.05),
                 k_fraction=0.05)
    task = LMTask(vocab=cfg.vocab_size, seq=32, heterogeneity=1.0)
    hist = tr.fit(lm_batches(task, 4, 4), steps=20)
    assert hist.loss[-1] < hist.loss[0]
    assert np.isfinite(hist.loss[-1])
