"""Bucket-plan battery: `repro.comm.plan` parity, framing, and streaming.

The bucketed wire's whole correctness story is ONE invariant: encoding
bucket ``b`` of a flat gradient through a `WirePlan` is bitwise identical
to encoding that slice through a standalone flat codec of the bucket's
size with the same folded key (``fold_in(worker_key, b)``).  Everything
else — the batched `encode_round`, the backward-pass `GradBucketStreamer`,
the `BucketedPackedAggregate` batch and streamed paths — must reproduce
those same bytes, so tcp-less substrate swaps can never change training.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.aggregate import _make_packed_codec
from repro.comm.packets import Packet
from repro.comm.plan import (
    BucketedPackedAggregate,
    GradBucketStreamer,
    WirePlan,
    bucket_ranges,
    bucketed_packed_aggregator,
    pack_bucket_payload,
    unpack_bucket_payload,
)
from repro.core.aggregators import make_aggregator

DIM = 300
BUCKET = 128          # -> buckets of 128, 128, 44: two shared, one odd
WORKERS = 3
CODEC_KW = dict(k_fraction=0.1, s=4)

#: the stateless packed codecs the bucketed wire supports (the stateful
#: families are rejected by construction — tested below)
PLAN_CODECS = ("mlmc_topk", "mlmc_topk_static", "mlmc_stopk", "qsgd",
               "signsgd", "mlmc_rtn")


def _plan(name: str, dim: int = DIM, bucket: int = BUCKET) -> WirePlan:
    return WirePlan(name, dim, bucket,
                    lambda size: _make_packed_codec(name, size, None,
                                                    dict(CODEC_KW)))


def _grads(dim: int = DIM, m: int = WORKERS) -> jax.Array:
    g = jax.random.normal(jax.random.PRNGKey(3), (m, dim), jnp.float32)
    return g * jnp.exp(-5.0 * jnp.arange(dim) / dim)


def test_bucket_ranges_cover_and_validate():
    assert bucket_ranges(DIM, BUCKET) == ((0, 128), (128, 256), (256, 300))
    assert bucket_ranges(5, 100) == ((0, 5),)
    with pytest.raises(ValueError, match="bucket_size"):
        bucket_ranges(DIM, 0)


@pytest.mark.parametrize("name", PLAN_CODECS)
def test_bucketed_encode_matches_flat_codec_bitwise(name):
    """THE invariant: plan bytes == flat-codec-of-bucket-size bytes."""
    plan = _plan(name)
    grads = _grads()
    keys = jax.random.split(jax.random.PRNGKey(11), WORKERS)
    packets = plan.encode_round(grads, keys)
    for b, (start, stop) in enumerate(plan.ranges):
        flat = _make_packed_codec(name, stop - start, None, dict(CODEC_KW))
        for w in range(WORKERS):
            ref = flat.encode(grads[w, start:stop],
                              jax.random.fold_in(keys[w], b)).packet
            assert packets[b][w].to_bytes() == ref.to_bytes(), \
                (name, b, w)


@pytest.mark.parametrize("name", ("mlmc_topk", "qsgd"))
def test_streamer_matches_batch_encode_bitwise(name):
    """Taps arriving leaf-by-leaf (any order, any interleaving) produce
    the same packets as the one-shot `encode_round`."""
    plan = _plan(name)
    grads = _grads()
    rng = jax.random.PRNGKey(7)
    keys = jax.random.split(rng, WORKERS)
    want = plan.encode_round(grads, keys)

    # a synthetic 4-leaf layout straddling every bucket boundary
    offsets, sizes = [0, 100, 180, 260], [100, 80, 80, 40]
    streamer = GradBucketStreamer(plan, WORKERS, offsets, sizes)
    streamer.begin(rng)
    order = [(leaf, w) for w in range(WORKERS) for leaf in range(4)]
    for leaf, w in reversed(order):        # worst-case arrival order
        off, size = offsets[leaf], sizes[leaf]
        streamer.push(leaf, jnp.float32(w), grads[w, off:off + size])
    got = streamer.finish(grads)
    for b in range(plan.num_buckets):
        for w in range(WORKERS):
            assert got[b][w].to_bytes() == want[b][w].to_bytes(), (b, w)


def test_streamer_backfills_missing_taps():
    """Correctness must not depend on the callbacks firing at all."""
    plan = _plan("mlmc_topk")
    grads = _grads()
    rng = jax.random.PRNGKey(7)
    want = plan.encode_round(grads, jax.random.split(rng, WORKERS))
    streamer = GradBucketStreamer(plan, WORKERS, [0], [DIM])
    streamer.begin(rng)                    # no pushes at all
    got = streamer.finish(grads)
    for b in range(plan.num_buckets):
        for w in range(WORKERS):
            assert got[b][w].to_bytes() == want[b][w].to_bytes(), (b, w)


def test_bucketed_aggregate_batch_equals_streamed():
    plan = _plan("mlmc_topk")
    grads = _grads()
    rng = jax.random.PRNGKey(19)
    agg = BucketedPackedAggregate(_plan("mlmc_topk"))
    batch = agg(grads, rng)
    streamer = GradBucketStreamer(plan, WORKERS, [0], [DIM])
    streamer.begin(rng)
    streamed = BucketedPackedAggregate(plan).step_streamed(
        streamer, grads, rng)
    assert np.array_equal(np.asarray(batch.direction),
                          np.asarray(streamed.direction))
    assert float(batch.bits) == float(streamed.bits)
    assert float(batch.bits) > 0


def test_bucketed_downlink_advances_shift():
    ag = make_aggregator("mlmc_topk", DIM, k_fraction=0.1, wire="packed",
                         bucket_size=BUCKET, downlink="topk")
    state = ag.init(WORKERS, DIM)
    assert state.shift.shape == (DIM,)
    out = ag(_grads(), jax.random.PRNGKey(2), state)
    assert out.state.step == 1
    assert float(jnp.sum(jnp.abs(out.state.shift))) > 0
    assert out.direction.shape == (DIM,)


def test_bucket_payload_roundtrip_and_framing_errors():
    parts = [b"alpha", b"", b"\x00" * 9]
    raw = pack_bucket_payload(parts)
    assert unpack_bucket_payload(raw) == parts
    with pytest.raises(ValueError, match="magic"):
        unpack_bucket_payload(b"XXXX" + raw[4:])
    with pytest.raises(ValueError, match="truncated"):
        unpack_bucket_payload(raw[:3])
    with pytest.raises(ValueError, match="truncated"):
        unpack_bucket_payload(raw[:-2])
    with pytest.raises(ValueError, match="trailing"):
        unpack_bucket_payload(raw + b"!")


def test_plan_shares_codec_across_equal_buckets():
    plan = _plan("mlmc_topk")
    assert plan.codec(0) is plan.codec(1)      # both 128-wide
    assert plan.codec(2) is not plan.codec(0)  # the 44-wide remainder


def test_bucketed_rejects_stateful_families_and_streamed_multihost():
    with pytest.raises(ValueError, match="stateful"):
        bucketed_packed_aggregator("ef21", DIM, bucket_size=BUCKET)
    # multihost construction is now supported (one RCBW container per rank
    # over the tcp star), but the STREAMED tap path stays in-process: the
    # streamer's key fan is per-local-worker, not per-rank
    ag = bucketed_packed_aggregator("mlmc_topk", DIM, bucket_size=BUCKET,
                                    transport=_FakeMultihost())
    with pytest.raises(ValueError, match="in-process"):
        ag.fn.step_streamed(None, _grads(), jax.random.PRNGKey(0))


class _FakeMultihost:
    """Quacks like a `TcpStarTransport` for the streamed-path rejection."""
    world = 3

    def broadcast_payload(self, data):
        raise AssertionError("must be rejected before any traffic")


def test_make_aggregator_routes_and_rejects_bucket_size():
    ag = make_aggregator("mlmc_topk", DIM, k_fraction=0.1, wire="packed",
                         bucket_size=BUCKET)
    out = ag(_grads(), jax.random.PRNGKey(0))
    assert out.direction.shape == (DIM,)
    with pytest.raises(ValueError, match="bucket_size"):
        make_aggregator("mlmc_topk", DIM, k_fraction=0.1, bucket_size=BUCKET)
    with pytest.raises(ValueError, match="bucket_size"):
        make_aggregator("mlmc_topk", DIM, k_fraction=0.1, wire="device",
                        bucket_size=BUCKET)


def test_decode_mean_matches_flat_reference():
    """Per-bucket decode_mean concatenated == decoding every packet with
    the flat bucket codec and averaging by hand."""
    plan = _plan("qsgd")
    grads = _grads()
    keys = jax.random.split(jax.random.PRNGKey(23), WORKERS)
    packets = plan.encode_round(grads, keys)
    direction = np.asarray(plan.decode_mean(packets))
    ref = []
    for b, (start, stop) in enumerate(plan.ranges):
        flat = _make_packed_codec("qsgd", stop - start, None, dict(CODEC_KW))
        if hasattr(flat, "decode_mean"):
            ref.append(np.asarray(flat.decode_mean(packets[b])))
        else:
            ests = [np.asarray(flat.decode(p)) for p in packets[b]]
            ref.append(np.mean(np.stack(ests), axis=0))
    assert np.array_equal(direction, np.concatenate(ref))


def test_bucket_packets_parse_standalone():
    """Every bucket packet is an ordinary self-describing `Packet` — a
    future tcp bucketed wire can ship them as-is."""
    plan = _plan("mlmc_topk")
    packets = plan.encode_round(
        _grads(), jax.random.split(jax.random.PRNGKey(1), WORKERS))
    for pkts in packets:
        for p in pkts:
            rt = Packet.from_bytes(p.to_bytes())
            assert rt.to_bytes() == p.to_bytes()
