"""Pallas TPU kernels: bit-packing of sub-32-bit field streams (the codec
hot loop of :mod:`repro.comm.codec`).

Every wire stream (ternary bit-planes, RTN/fixed-point mantissas, Top-k index
streams) is a vector of small unsigned codes.  Packing ``F = 32 // width``
codes per uint32 word is pure VPU work — shifts and ORs — and the kernel's
job, like `kernels/bitplane.py`, is to do it in ONE pass over (rows, 128)
VMEM tiles.

Layout contract (shared by kernel, wrapper and the `kernels/ref.py` oracle):
word ``w`` packs codes ``[w*F, (w+1)*F)`` at bit offsets ``f * width``.  The
wrapper maps that word-major order to the kernel's planar block layout
``(rows, F*128)`` where columns ``[f*128, (f+1)*128)`` hold field ``f`` of
the row's 128 words — so the kernel only needs static slices.

Fields never straddle word boundaries; the ``32 mod (F*width)`` spare bits
per word are the documented packing overhead the reconciliation tests allow.
Widths > 16 get F = 1 (one code per word, a passthrough).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_ROWS = 256  # (256, F*128) u32 tile; F <= 32 -> at most 4 MiB VMEM


def fields_per_word(width: int) -> int:
    if not 1 <= width <= 32:
        raise ValueError(f"field width must be in [1, 32], got {width}")
    return max(1, 32 // width)


def _pack_kernel(v_ref, out_ref, *, width: int, fields: int):
    v = v_ref[...]
    out = v[:, 0:128]
    for f in range(1, fields):
        out = out | (v[:, f * 128:(f + 1) * 128] << jnp.uint32(f * width))
    out_ref[...] = out


def _unpack_kernel(w_ref, out_ref, *, width: int, fields: int):
    w = w_ref[...]
    mask = jnp.uint32(0xFFFFFFFF if width == 32 else (1 << width) - 1)
    planes = [(w >> jnp.uint32(f * width)) & mask for f in range(fields)]
    out_ref[...] = jnp.concatenate(planes, axis=1)


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def pack_words_2d(v2d: Array, *, width: int, interpret: bool = False) -> Array:
    """v2d: (rows, F*128) uint32 planar codes -> (rows, 128) packed words."""
    fields = fields_per_word(width)
    rows = v2d.shape[0]
    assert v2d.shape[1] == fields * 128, v2d.shape
    br = min(BLOCK_ROWS, rows)
    return pl.pallas_call(
        functools.partial(_pack_kernel, width=width, fields=fields),
        grid=(pl.cdiv(rows, br),),
        in_specs=[pl.BlockSpec((br, fields * 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 128), jnp.uint32),
        interpret=interpret,
    )(v2d)


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def unpack_words_2d(w2d: Array, *, width: int,
                    interpret: bool = False) -> Array:
    """w2d: (rows, 128) packed words -> (rows, F*128) planar codes."""
    fields = fields_per_word(width)
    rows = w2d.shape[0]
    br = min(BLOCK_ROWS, rows)
    return pl.pallas_call(
        functools.partial(_unpack_kernel, width=width, fields=fields),
        grid=(pl.cdiv(rows, br),),
        in_specs=[pl.BlockSpec((br, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, fields * 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, fields * 128), jnp.uint32),
        interpret=interpret,
    )(w2d)


# ---------------------------------------------------------------------------
# 1D wrappers (the public ops; `kernels/__init__.py` re-exports them)
# ---------------------------------------------------------------------------


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _num_words(count: int, width: int) -> int:
    return -(-count // fields_per_word(width))


def pack_bits(codes: Array, width: int) -> Array:
    """Pack unsigned codes of ``width`` bits into uint32 words (word-major:
    word w holds codes [w*F, (w+1)*F)).

    Accepts ``(N,)`` -> ``(ceil(N/F),)`` or a batch ``(B, N)`` ->
    ``(B, ceil(N/F))``.  The batched form folds B into the Pallas row grid —
    ONE kernel launch packs every row, which is how the compiled codec
    pipeline (`repro.comm.compiled`) packs all M workers' streams per step —
    and each row's words are bit-identical to the 1D call on that row."""
    codes = jnp.asarray(codes, jnp.uint32)
    n = codes.shape[-1]
    fields = fields_per_word(width)
    if fields == 1:
        return codes
    n_words = _num_words(n, width)
    rows = max(1, -(-n_words // 128))
    batch = codes.shape[:-1]
    pad = [(0, 0)] * len(batch) + [(0, rows * 128 * fields - n)]
    padded = jnp.pad(codes, pad)
    planar = padded.reshape(*batch, rows, 128, fields) \
                   .swapaxes(-1, -2).reshape(-1, fields * 128)
    words = pack_words_2d(planar, width=width, interpret=_interpret())
    return words.reshape(*batch, rows * 128)[..., :n_words]


def unpack_bits(words: Array, width: int, count: int) -> Array:
    """Inverse of :func:`pack_bits`: ``(W,)`` words -> ``(count,)`` uint32
    codes, or batched ``(B, W)`` -> ``(B, count)`` (one kernel launch)."""
    words = jnp.asarray(words, jnp.uint32)
    fields = fields_per_word(width)
    if fields == 1:
        return words[..., :count]
    n_words = words.shape[-1]
    rows = max(1, -(-n_words // 128))
    batch = words.shape[:-1]
    pad = [(0, 0)] * len(batch) + [(0, rows * 128 - n_words)]
    w2d = jnp.pad(words, pad).reshape(-1, 128)
    planar = unpack_words_2d(w2d, width=width, interpret=_interpret())
    codes = planar.reshape(*batch, rows, fields, 128) \
                  .swapaxes(-1, -2).reshape(*batch, rows * fields * 128)
    return codes[..., :count]


# ---------------------------------------------------------------------------
# split-plane packing (gather-friendly: every stream stays word-aligned)
# ---------------------------------------------------------------------------
#
# `pack_bits` wastes a full word per field once width > 16 (32 // width = 1),
# which is exactly the regime of Top-k index streams: ceil(log2 d) is 17..25
# bits for gradient buckets of 2^17..2^25 entries.  Rather than letting
# fields straddle word boundaries (which would force bit-offset fixup after
# an all-gather concatenates per-shard buffers), a wide field is split into
# bit PLANES that each pack an integral number of fields per word with the
# existing kernels: a 20-bit index becomes a 16-bit low plane (2/word) plus
# a 4-bit high plane (8/word) — 20 effective bits/entry, fixed static word
# counts, and packed buffers from different shards concatenate verbatim.


def plane_widths(width: int) -> tuple[int, ...]:
    """Plane decomposition of a field width: one plane for widths that pack
    natively (<= 16, or 32 = passthrough); 16-bit low + (width-16)-bit high
    planes for 17..31."""
    if not 1 <= width <= 32:
        raise ValueError(f"field width must be in [1, 32], got {width}")
    if width <= 16 or width == 32:
        return (width,)
    return (16, width - 16)


def packed_words(count: int, width: int) -> int:
    """Static uint32 word count of `pack_planes(codes, width)` for ``count``
    fields (the fixed wire shape the device packets are built around)."""
    return sum(_num_words(count, w) for w in plane_widths(width))


def pack_planes(codes: Array, width: int) -> Array:
    """Pack (N,) unsigned ``width``-bit codes into `packed_words(N, width)`
    uint32 words, splitting widths 17..31 into word-aligned bit planes
    (low plane first).  Identical to :func:`pack_bits` for widths <= 16/32."""
    codes = jnp.asarray(codes, jnp.uint32)
    planes = plane_widths(width)
    if len(planes) == 1:
        return pack_bits(codes, width)
    lo_w, hi_w = planes
    lo = codes & jnp.uint32((1 << lo_w) - 1)
    hi = codes >> jnp.uint32(lo_w)
    return jnp.concatenate([pack_bits(lo, lo_w), pack_bits(hi, hi_w)],
                           axis=-1)


def unpack_planes(words: Array, width: int, count: int) -> Array:
    """Inverse of :func:`pack_planes`: (W,) words -> (count,) uint32 codes."""
    words = jnp.asarray(words, jnp.uint32)
    planes = plane_widths(width)
    if len(planes) == 1:
        return unpack_bits(words, width, count)
    lo_w, hi_w = planes
    n_lo = _num_words(count, lo_w)
    lo = unpack_bits(words[..., :n_lo], lo_w, count)
    hi = unpack_bits(words[..., n_lo:], hi_w, count)
    return lo | (hi << jnp.uint32(lo_w))
