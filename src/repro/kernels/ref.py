"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

All references operate on the kernels' native 2D layout (rows, 128) —
the `ops` wrappers handle 1D padding/reshaping symmetrically for both
implementations, so tests compare kernel-vs-ref on identical layouts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

_BELOW_ONE = 1.0 - 2.0 ** -24


def bitplane_residual_ref(v: Array, scale: Array, level: Array) -> Array:
    """Fixed-point level-l MLMC residual: sign(v) * b_l * 2^-l * scale."""
    x = jnp.minimum(jnp.abs(v) / scale, _BELOW_ONE)
    bit = jnp.mod(jnp.floor(jnp.ldexp(x, level)), 2.0)
    return jnp.sign(v) * bit * jnp.ldexp(jnp.ones((), v.dtype), -level) * scale


def ternary_bitplane_ref(v: Array, scale: Array, level: Array) -> Array:
    """{-1,0,+1} int8 bit-plane (what rides the int8 psum collective)."""
    x = jnp.minimum(jnp.abs(v) / scale, _BELOW_ONE)
    bit = jnp.mod(jnp.floor(jnp.ldexp(x, level)), 2.0)
    return (jnp.sign(v) * bit).astype(jnp.int8)


def segment_sumsq_ref(v2d: Array) -> Array:
    """Row-wise sum of squares: (L, s) -> (L,).  (s-Top-k segment energies —
    Delta_l^2 of Lemma 3.4 after the sort.)"""
    return jnp.sum(v2d.astype(jnp.float32) ** 2, axis=-1)


def rtn_quantize_ref(v: Array, c: Array, level: Array) -> Array:
    """RTN on a 2^l-point grid over [-c, c] (Eq. 125)."""
    level = level.astype(jnp.float32)
    cells = 2.0 ** level - 1.0
    delta = 2.0 * c / jnp.maximum(cells, 1.0)
    m = jnp.floor(cells / 2.0)
    return delta * jnp.clip(jnp.round(v / jnp.maximum(delta, 1e-30)), -m, m)


def exp_histogram_ref(v: Array, n_buckets: int = 32) -> Array:
    """Histogram of |v| over power-of-two magnitude buckets relative to
    max|v|: bucket = clamp(floor(log2(max|v| / |v|)), 0, NB-1).  Zero entries
    land in the last bucket.  Used for sort-free approximate rank selection
    (the TPU-native replacement for the global argsort)."""
    vmax = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30)
    av = jnp.abs(v)
    safe = jnp.maximum(av, 1e-30)
    b = jnp.floor(jnp.log2(vmax / safe)).astype(jnp.int32)
    b = jnp.where(av > 0, jnp.clip(b, 0, n_buckets - 1), n_buckets - 1)
    return jnp.zeros((n_buckets,), jnp.int32).at[b.reshape(-1)].add(1)


def band_select_ref(v: Array, lo: Array, hi: Array) -> Array:
    """Keep entries with lo <= |v| < hi, zero elsewhere (threshold-based
    Top-k band extraction; pairs with exp_histogram for rank selection)."""
    av = jnp.abs(v)
    return jnp.where((av >= lo) & (av < hi), v, jnp.zeros((), v.dtype))


def pack_bits_ref(codes: Array, width: int) -> Array:
    """(N,) unsigned codes -> ceil(N/F) uint32 words, F = 32 // width codes
    per word at bit offsets f*width (the wire layout of repro.comm)."""
    codes = jnp.asarray(codes, jnp.uint32)
    fields = max(1, 32 // width)
    if fields == 1:
        return codes
    n = codes.shape[0]
    n_words = -(-n // fields)
    c = jnp.pad(codes, (0, n_words * fields - n)).reshape(n_words, fields)
    shifts = (jnp.arange(fields, dtype=jnp.uint32) * width)[None, :]
    # fields are disjoint, so the sum of shifted codes IS the bitwise OR
    return jnp.sum(c << shifts, axis=1, dtype=jnp.uint32)


def unpack_bits_ref(words: Array, width: int, count: int) -> Array:
    """Inverse of pack_bits_ref: (W,) uint32 words -> (count,) uint32."""
    words = jnp.asarray(words, jnp.uint32)
    fields = max(1, 32 // width)
    if fields == 1:
        return words[:count]
    mask = jnp.uint32((1 << width) - 1)
    shifts = (jnp.arange(fields, dtype=jnp.uint32) * width)[None, :]
    codes = (words[:, None] >> shifts) & mask
    return codes.reshape(-1)[:count]


def pack_planes_ref(codes: Array, width: int) -> Array:
    """Oracle for `pack.pack_planes`: widths 17..31 split into a 16-bit low
    plane + (width-16)-bit high plane, each packed word-aligned."""
    codes = jnp.asarray(codes, jnp.uint32)
    if width <= 16 or width == 32:
        return pack_bits_ref(codes, width)
    lo_w = 16
    lo = codes & jnp.uint32((1 << lo_w) - 1)
    hi = codes >> jnp.uint32(lo_w)
    return jnp.concatenate([pack_bits_ref(lo, lo_w),
                            pack_bits_ref(hi, width - lo_w)])


def unpack_planes_ref(words: Array, width: int, count: int) -> Array:
    """Inverse of pack_planes_ref."""
    words = jnp.asarray(words, jnp.uint32)
    if width <= 16 or width == 32:
        return unpack_bits_ref(words, width, count)
    lo_w, hi_w = 16, width - 16
    n_lo = -(-count // (32 // lo_w))
    lo = unpack_bits_ref(words[:n_lo], lo_w, count)
    hi = unpack_bits_ref(words[n_lo:], hi_w, count)
    return lo | (hi << jnp.uint32(lo_w))
