"""repro.kernels — Pallas TPU kernels for the compression hot-spots the
paper optimizes (bit-plane extraction, segment energies, RTN quantize), the
sort-free histogram/threshold Top-k selection (beyond-paper, TPU-native),
and the wire-codec bit-packing of `repro.comm` (sub-32-bit field streams).

Validated on CPU via interpret=True against the `ref.py` oracles."""

from repro.kernels import select
from repro.kernels.pack import pack_bits, unpack_bits
from repro.kernels.ops import (
    band_select,
    bitplane_residual,
    exp_histogram,
    rtn_quantize,
    segment_sumsq,
    ternary_bitplane,
    topk_threshold,
)

__all__ = ["band_select", "bitplane_residual", "exp_histogram", "pack_bits",
           "rtn_quantize", "segment_sumsq", "select", "ternary_bitplane",
           "topk_threshold", "unpack_bits"]
