"""repro.kernels — Pallas TPU kernels for the compression hot-spots the
paper optimizes (bit-plane extraction, segment energies, RTN quantize) plus
the sort-free histogram/threshold Top-k selection (beyond-paper, TPU-native).

Validated on CPU via interpret=True against the `ref.py` oracles."""

from repro.kernels.ops import (
    band_select,
    bitplane_residual,
    exp_histogram,
    rtn_quantize,
    segment_sumsq,
    ternary_bitplane,
    topk_threshold,
)

__all__ = ["band_select", "bitplane_residual", "exp_histogram",
           "rtn_quantize", "segment_sumsq", "ternary_bitplane",
           "topk_threshold"]
