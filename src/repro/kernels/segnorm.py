"""Pallas TPU kernel: s-Top-k segment energies (Lemma 3.4 hot-spot).

After sorting by magnitude, the adaptive level distribution needs
``Delta_l^2 = sum of v^2 over each length-s segment`` for ALL L = d/s
segments — a strided reduction over the full gradient.  The kernel streams
(rows, s) VMEM tiles and emits one partial row-sum per segment, fused with
the squaring (one HBM pass, no (d,) f32 squared temp).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_SEGMENTS = 256


def _segsum_kernel(v_ref, out_ref):
    v = v_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.sum(v * v, axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def segment_sumsq(v2d: Array, *, interpret: bool = False) -> Array:
    """v2d: (L, s) — sorted-magnitude values reshaped to segments.
    Returns (L,) f32 segment energies."""
    L, s = v2d.shape
    bl = min(BLOCK_SEGMENTS, L)
    grid = (pl.cdiv(L, bl),)
    return pl.pallas_call(
        _segsum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bl, s), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bl,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((L,), jnp.float32),
        interpret=interpret,
    )(v2d)
