"""Pallas TPU kernel: RTN quantization at level l (App. G.2 hot-spot).

Elementwise grid round/clip in one HBM pass; level and clip-scale are
scalar-prefetched so one compiled kernel serves every level of the
multilevel ladder (the MLMC estimator samples l per step)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_ROWS = 256


def _rtn_kernel(c_ref, level_ref, v_ref, out_ref):
    v = v_ref[...]
    c = c_ref[0, 0]
    level = level_ref[0, 0].astype(jnp.float32)
    cells = 2.0 ** level - 1.0
    delta = 2.0 * c / jnp.maximum(cells, 1.0)
    m = jnp.floor(cells / 2.0)
    q = jnp.clip(jnp.round(v / jnp.maximum(delta, 1e-30)), -m, m)
    out_ref[...] = (delta * q).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rtn_quantize_2d(v: Array, c: Array, level: Array, *,
                    interpret: bool = False) -> Array:
    """v: (R, 128); c: () clip scale; level: () int32 -> quantized (R, 128)."""
    rows, lanes = v.shape
    assert lanes == 128
    br = min(BLOCK_ROWS, rows)
    grid = (pl.cdiv(rows, br),)
    return pl.pallas_call(
        _rtn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((br, lanes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), v.dtype),
        interpret=interpret,
    )(c.reshape(1, 1), level.reshape(1, 1), v)
