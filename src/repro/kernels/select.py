"""Sort-free EXACT magnitude-rank selection — the Top-k fast path.

Every Top-k-family codec (Top-k / s-Top-k, the MLMC rank ladder, EF21's
innovation select, the mesh segment gather) needs the set of entries whose
magnitude-rank falls in a band ``[r0, r1)`` — and it needs the *same* set
the historical ``jnp.argsort(-|v|)`` produced, bit for bit, because packet
fixtures, tcp/loopback parity and the psum'd mesh all hash the emitted
stream.  A global argsort is O(d log d) and books ~176 ms at d=557,696 on
the CPU container; this module gets the identical answer without ranking
the whole vector.

Canonical order (the contract everything below implements):

    descending ``uint32`` bitcast of ``|v|``, ties broken by ascending
    index.

For non-negative IEEE floats the bit pattern is monotone in value, so this
is magnitude-descending order — with one documented exception: XLA *CPU*
sort comparators flush denormals to zero (a platform quirk, so the legacy
``argsort(-|v|)`` tie-ordering of denormals-vs-zeros was garbage anyway);
integer key compares never flush, making the canonical order deterministic
across backends.  No golden fixture contains denormals (all are generated
from normal-scale data), so fixture bytes are unchanged.

Pipeline (two streaming passes + small-band exact sort — no global sort):

1. *Histogram pass*: bucket counts over the keys (`histogram_threshold`
   walks four 256-ary byte histograms; `bucket_walk_bounds` walks the
   coarse power-of-two `exp_histogram` Pallas kernel).
2. *Cumulative-count walk*: descending cumulative counts locate the bucket
   containing rank ``r`` and yield the exact threshold key plus the number
   of strictly-greater entries.
3. *Band extraction*: `band_mask` marks ``rank in [r0, r1)`` exactly —
   interior keys strictly between the two thresholds, plus tie-broken
   slices of the threshold keys via a cumsum occurrence index.
   `rank_band_indices` then pulls the ≤s member indices in rank order with
   one masked ``lax.top_k`` (s-sized, not d-sized), and consumers that
   emit ascending-index streams sort just those s indices.

Backend routing: the byte-histogram walk is O(d) per pass but scatter-add
bound, which XLA CPU executes slower (~90 ms) than a single u32 key sort
(~35 ms) or the O(d·k) Top-k custom call (~4 ms at k=11k); on CPU the
traced-rank paths therefore sort the *keys* once (4-5x cheaper than a
float argsort and reusable for the ladder norms) while static-k paths use
``lax.top_k`` directly.  On TPU the histogram walk streams through VMEM
and is the default.  Both implementations are exact and bitwise
interchangeable; `impl=` overrides the routing.

The byte-histogram walk also composes across mesh shards: pass a
``reduce=`` hook (e.g. ``lax.psum``) and the walk selects against GLOBAL
ranks from 4 x 1 KB of summed bucket counts, never gathering values —
see `sharding.collectives.global_topk_mask`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

#: byte-radix passes over uint32 keys, most-significant first
_RADIX_SHIFTS = (24, 16, 8, 0)


def _use_histogram() -> bool:
    """Default impl: histogram walk on TPU, key-sort thresholds on CPU."""
    return jax.default_backend() == "tpu"


def _resolve_impl(impl: str | None) -> str:
    if impl is None:
        return "histogram" if _use_histogram() else "sort"
    if impl not in ("sort", "histogram"):
        raise ValueError(f"impl must be 'sort' or 'histogram', got {impl!r}")
    return impl


def magnitude_keys(v: Array) -> Array:
    """uint32 sort keys of ``|v|``: monotone in magnitude, denormal-safe."""
    return jax.lax.bitcast_convert_type(jnp.abs(v), jnp.uint32)


def sort_magnitude_keys(keys: Array) -> Array:
    """Keys sorted descending.  A u32 sort is ~5x cheaper than the float
    argsort it replaces, and bitcasting the result back to f32 reproduces
    ``jnp.sort(|v|)[::-1]`` bitwise — the ladder-norm workhorse."""
    return jnp.sort(keys)[::-1]


def sorted_abs_desc(v: Array, *, sorted_keys: Array | None = None) -> Array:
    """``|v|`` sorted descending (bitwise == ``jnp.sort(jnp.abs(v))[::-1]``)."""
    if sorted_keys is None:
        sorted_keys = sort_magnitude_keys(magnitude_keys(v))
    return jax.lax.bitcast_convert_type(sorted_keys, jnp.float32)


def threshold_at_rank(sorted_keys: Array, rank: Array) -> Array:
    """Key at descending ``rank`` (clipped to [0, d-1]); traced-rank safe."""
    d = sorted_keys.shape[0]
    r = jnp.clip(jnp.asarray(rank, jnp.int32), 0, d - 1)
    return jax.lax.dynamic_slice(sorted_keys, (r,), (1,))[0]


def histogram_threshold(keys: Array, rank: Array, *, reduce=None) -> Array:
    """Exact key at descending ``rank`` via 4 histogram passes + walks.

    Each pass histograms one byte of the surviving keys into 256 buckets,
    walks the descending cumulative counts to the bucket containing the
    rank, pins that byte, and recurses into the bucket.  O(d) per pass, no
    sort, fixed shapes throughout.

    ``reduce`` (optional) sums each 256-bucket histogram across mesh
    shards (e.g. ``partial(lax.psum, axis_name=...)``); ``rank`` is then a
    GLOBAL rank and the returned threshold is the global one — 4 KB of
    scalars on the interconnect instead of a value gather.
    """
    d = keys.shape[0]
    mask = jnp.ones((d,), jnp.bool_)
    prefix = jnp.uint32(0)
    r_rem = jnp.asarray(rank, jnp.int32)
    for shift in _RADIX_SHIFTS:
        byte = (keys >> shift) & jnp.uint32(0xFF)
        hist = jnp.zeros((256,), jnp.int32).at[byte].add(
            mask.astype(jnp.int32))
        if reduce is not None:
            hist = reduce(hist)
        csum = jnp.cumsum(hist[::-1])[::-1]  # count of byte >= b
        b = jnp.sum((csum >= r_rem + 1).astype(jnp.int32)) - 1
        b = jnp.clip(b, 0, 255)
        n_greater = jnp.where(b < 255, csum[jnp.clip(b + 1, 0, 255)], 0)
        r_rem = r_rem - n_greater
        prefix = prefix | (b.astype(jnp.uint32) << shift)
        mask = mask & (byte == b.astype(jnp.uint32))
    return prefix


def tie_rank_mask(keys: Array, t: Array, r0: Array, r1: Array) -> Array:
    """Entries equal to threshold ``t`` whose canonical rank is in
    ``[r0, r1)``.  Rank of the j-th occurrence (ascending index) of ``t``
    is ``count(keys > t) + j`` — the cumsum occurrence index realizes the
    ascending-index tie-break without any sort."""
    eq = keys == t
    n_gt = jnp.sum((keys > t).astype(jnp.int32))
    pos = jnp.cumsum(eq.astype(jnp.int32)) - 1
    rr = n_gt + pos
    return eq & (rr >= r0) & (rr < r1)


def band_mask(v: Array, r0, r1, *, keys: Array | None = None,
              sorted_keys: Array | None = None,
              impl: str | None = None) -> Array:
    """Exact mask of entries with magnitude-rank in ``[r0, r1)``.

    Bitwise identical to ``(ranks >= r0) & (ranks < r1)`` with
    ``ranks = magnitude_ranks(v)``, for traced or concrete bounds.
    Supplying ``sorted_keys`` (from `sort_magnitude_keys`) makes the
    thresholds two dynamic slices; otherwise the resolved ``impl`` decides
    between one key sort and the histogram walk.
    """
    d = v.shape[0]
    if keys is None:
        keys = magnitude_keys(v)
    r0 = jnp.clip(jnp.asarray(r0, jnp.int32), 0, d)
    r1 = jnp.clip(jnp.asarray(r1, jnp.int32), 0, d)
    if sorted_keys is None and _resolve_impl(impl) == "sort":
        sorted_keys = sort_magnitude_keys(keys)
    if sorted_keys is not None:
        t_hi = threshold_at_rank(sorted_keys, r0)
        t_lo = threshold_at_rank(sorted_keys, r1 - 1)
    else:
        t_hi = histogram_threshold(keys, jnp.clip(r0, 0, d - 1))
        t_lo = histogram_threshold(keys, jnp.clip(r1 - 1, 0, d - 1))
    interior = (keys < t_hi) & (keys > t_lo)
    band = interior | tie_rank_mask(keys, t_hi, r0, r1)
    band = band | tie_rank_mask(keys, t_lo, r0, r1)
    return band


def topk_mask(v: Array, k, *, keys: Array | None = None,
              sorted_keys: Array | None = None,
              impl: str | None = None) -> Array:
    """Mask of the k largest-magnitude entries, canonical tie-break.

    Static integer ``k`` routes through the O(d·k) ``lax.top_k`` custom
    call (whose f32 kernel is stable — verified on adversarial duplicate
    pools — and never flushes denormals, matching the key order); traced
    ``k`` uses the threshold band ``[0, k)``.
    """
    d = v.shape[0]
    if isinstance(k, (int, np.integer)):
        if k <= 0:
            return jnp.zeros((d,), jnp.bool_)
        if k >= d:
            return jnp.ones((d,), jnp.bool_)
        _, idx = jax.lax.top_k(jnp.abs(v), k)
        return jnp.zeros((d,), jnp.bool_).at[idx].set(True)
    return band_mask(v, 0, k, keys=keys, sorted_keys=sorted_keys, impl=impl)


def topk_indices(v: Array, k: int) -> Array:
    """Indices of the k largest magnitudes in RANK order (static k).
    Stable: equal magnitudes come out ascending-index."""
    _, idx = jax.lax.top_k(jnp.abs(v), k)
    return idx


def rank_band_indices(v: Array, r0, s: int, *, keys: Array | None = None,
                      sorted_keys: Array | None = None,
                      impl: str | None = None) -> tuple[Array, Array]:
    """Indices of the rank band ``[r0, r0+s)`` in RANK order, fixed shape.

    Returns ``(idx, valid)`` with ``idx`` of shape (s,): the first
    ``min(s, d - r0)`` entries are the band members in canonical rank
    order, the rest are arbitrary filler masked out by ``valid``.  The
    extraction is one masked ``lax.top_k`` over the exact `band_mask` —
    band members all score ``|v| >= 0`` against filler at ``-1``, and
    ``top_k``'s stability reproduces the canonical in-band tie order.
    """
    d = v.shape[0]
    r0 = jnp.clip(jnp.asarray(r0, jnp.int32), 0, d)
    band = band_mask(v, r0, r0 + s, keys=keys, sorted_keys=sorted_keys,
                     impl=impl)
    score = jnp.where(band, jnp.abs(v), -1.0)
    if s > d:
        score = jnp.pad(score, (0, s - d), constant_values=-2.0)
    _, idx = jax.lax.top_k(score, s)
    valid = jnp.arange(s, dtype=jnp.int32) < jnp.clip(d - r0, 0, s)
    return idx.astype(jnp.int32), valid


def bucket_walk_bounds(v: Array, rank, *, n_buckets: int = 32
                       ) -> tuple[Array, Array]:
    """Coarse two-pass variant: power-of-two `exp_histogram` (Pallas
    kernel) + cumulative-count walk to the bucket containing ``rank``.

    Returns float bounds ``(lo, hi)`` such that the band
    ``lo <= |v| < hi`` contains the entry of that rank plus at most one
    bucket's population of neighbours — the streaming prefilter the exact
    pipeline refines (`band_select` extracts the candidates; the ~s-sized
    band then gets its exact small sort).  Kept kernel-backed for the
    TPU-native route and `kernel_bench.py`.
    """
    from repro.kernels import ops as _ops  # local import: ops pulls Pallas

    counts = _ops.exp_histogram(v, n_buckets)
    cum = jnp.cumsum(counts)
    rank = jnp.asarray(rank, jnp.int32)
    bidx = jnp.argmax(cum >= rank + 1)
    vmax = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30)
    lo = vmax * jnp.exp2(-(bidx + 1).astype(jnp.float32))
    hi = jnp.where(bidx == 0, jnp.asarray(jnp.inf, jnp.float32),
                   vmax * jnp.exp2(-bidx.astype(jnp.float32)))
    return lo, hi
