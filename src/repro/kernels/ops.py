"""jit'd public wrappers around the Pallas kernels.

Handles: 1D -> (rows, 128) padding/reshape, scalar coercion, and automatic
``interpret=True`` on CPU (the container target; real TPUs compile the same
kernels natively).  Every wrapper has a matching oracle in `ref.py` and an
allclose sweep in tests/test_kernels.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import bitplane as _bp
from repro.kernels import histogram as _hist
from repro.kernels import rtn as _rtn
from repro.kernels import segnorm as _sn

Array = jax.Array

LANES = 128


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_2d(v: Array) -> tuple[Array, int]:
    """Pad a flat vector to (rows, 128)."""
    d = v.shape[0]
    rows = max(1, -(-d // LANES))
    pad = rows * LANES - d
    return jnp.pad(v, (0, pad)).reshape(rows, LANES), d


def bitplane_residual(v: Array, scale: Array, level: Array) -> Array:
    """Fixed-point MLMC residual of a flat vector (kernel-backed)."""
    v2d, d = _to_2d(v)
    out = _bp.bitplane_residual_2d(v2d, jnp.asarray(scale, v.dtype),
                                   jnp.asarray(level, jnp.int32),
                                   ternary=False, interpret=_interpret())
    return out.reshape(-1)[:d]


def ternary_bitplane(v: Array, scale: Array, level: Array) -> Array:
    """int8 {-1,0,+1} wire tensor for the int8-psum collective."""
    v2d, d = _to_2d(v)
    out = _bp.bitplane_residual_2d(v2d, jnp.asarray(scale, v.dtype),
                                   jnp.asarray(level, jnp.int32),
                                   ternary=True, interpret=_interpret())
    return out.reshape(-1)[:d]


def segment_sumsq(v2d: Array) -> Array:
    """(L, s) segment energies (call on the sorted-magnitude reshape)."""
    return _sn.segment_sumsq(v2d, interpret=_interpret())


def rtn_quantize(v: Array, c: Array, level: Array) -> Array:
    v2d, d = _to_2d(v)
    out = _rtn.rtn_quantize_2d(v2d, jnp.asarray(c, v.dtype),
                               jnp.asarray(level, jnp.int32),
                               interpret=_interpret())
    return out.reshape(-1)[:d]


def exp_histogram(v: Array, n_buckets: int = 32) -> Array:
    """Power-of-two magnitude histogram of a flat vector.  Padding zeros
    land in the last bucket and are subtracted here; the explicit pad to a
    whole number of (BLOCK_ROWS, 128) tiles keeps Pallas' out-of-bounds
    block content out of the counts."""
    d = v.shape[0]
    tile = _hist.BLOCK_ROWS * LANES
    total = max(tile, -(-d // tile) * tile)
    v2d = jnp.pad(v, (0, total - d)).reshape(-1, LANES)
    vmax = jnp.max(jnp.abs(v2d))
    counts = _hist.exp_histogram(v2d, vmax, n_buckets=n_buckets,
                                 interpret=_interpret())
    return counts.at[n_buckets - 1].add(-(total - d))


def band_select(v: Array, lo: Array, hi: Array) -> Array:
    v2d, d = _to_2d(v)
    out = _hist.band_select(v2d, jnp.asarray(lo, v.dtype),
                            jnp.asarray(hi, v.dtype),
                            interpret=_interpret())
    return out.reshape(-1)[:d]


def topk_threshold(v: Array, k: int, n_buckets: int = 32) -> tuple[Array, Array]:
    """Sort-free approximate Top-k: histogram -> threshold bucket -> band.

    Returns (lo, hi) |value| thresholds such that the band ``|v| >= lo``
    contains at least k entries and at most k + (bucket population) — the
    TPU-native replacement for exact rank selection."""
    counts = exp_histogram(v, n_buckets)
    cum = jnp.cumsum(counts)
    # first bucket index where cumulative count reaches k
    bidx = jnp.argmax(cum >= k)
    vmax = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30)
    lo = vmax * jnp.exp2(-(bidx + 1).astype(jnp.float32))
    hi = jnp.asarray(jnp.inf, v.dtype)
    return lo, hi
