"""Pallas TPU kernel: fixed-point bit-plane extraction (§3.1 hot-spot).

The MLMC fixed-point compressor touches every gradient element once per
step: normalize by the (prefetched) scale, extract bit l, emit either the
f32 residual plane or the {-1,0,+1} int8 wire tensor.  Pure VPU work — the
kernel's job is to do it in ONE HBM pass with (8k, 128) VMEM tiles instead
of the ~5 materialized intermediates of the naive jnp chain.

Layout: inputs are (R, 128) f32 (the `ops` wrapper pads/reshapes 1D);
scale/level ride in SMEM as (1, 1) scalars.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

_BELOW_ONE = 1.0 - 2.0 ** -24
BLOCK_ROWS = 256  # (256, 128) f32 tile = 128 KiB VMEM in + same out


def _bitplane_kernel(scale_ref, level_ref, v_ref, out_ref, *, ternary: bool):
    v = v_ref[...]
    scale = scale_ref[0, 0]
    level = level_ref[0, 0]
    x = jnp.minimum(jnp.abs(v) / scale, _BELOW_ONE)
    bit = jnp.mod(jnp.floor(jnp.ldexp(x, level)), 2.0)
    tern = jnp.sign(v) * bit
    if ternary:
        out_ref[...] = tern.astype(jnp.int8)
    else:
        plane = tern * jnp.ldexp(jnp.ones((), v.dtype), -level) * scale
        out_ref[...] = plane.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ternary", "interpret"))
def bitplane_residual_2d(v: Array, scale: Array, level: Array, *,
                         ternary: bool = False,
                         interpret: bool = False) -> Array:
    """v: (R, 128) f32; scale: () f32; level: () int32.

    Returns the level-l bit-plane residual (f32) or its ternary int8 form."""
    rows, lanes = v.shape
    assert lanes == 128, "kernel layout is (rows, 128)"
    br = min(BLOCK_ROWS, rows)
    grid = (pl.cdiv(rows, br),)
    out_dtype = jnp.int8 if ternary else v.dtype
    return pl.pallas_call(
        functools.partial(_bitplane_kernel, ternary=ternary),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),      # scale (SMEM-ish)
            pl.BlockSpec((1, 1), lambda i: (0, 0)),      # level
            pl.BlockSpec((br, lanes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), out_dtype),
        interpret=interpret,
    )(scale.reshape(1, 1), level.reshape(1, 1), v)
