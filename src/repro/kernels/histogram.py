"""Pallas TPU kernels: sort-free approximate rank selection (beyond-paper).

Exact (s-)Top-k needs a global argsort of the gradient — O(d log d) and
sort-lowering-hostile on TPU.  Production systems select by THRESHOLD
instead: build a histogram of |v| over power-of-two magnitude buckets
(one pass), walk the cumulative counts to find the bucket containing rank
k, then extract the band ``lo <= |v| < hi`` (second pass).  Both passes are
streaming VPU work with (rows, 128) VMEM tiles.

* `exp_histogram`  — accumulates bucket counts across the sequential TPU
  grid (out_ref += partial counts; revisited output blocks are legal on
  TPU's sequential grid and under interpret=True).
* `band_select`    — masks the magnitude band, emitting the candidate
  Top-k / MLMC-residual entries without any sort.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_ROWS = 256
N_BUCKETS = 32


def _hist_kernel(vmax_ref, v_ref, out_ref, *, n_buckets: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v = v_ref[...]
    av = jnp.abs(v)
    vmax = jnp.maximum(vmax_ref[0, 0], 1e-30)
    safe = jnp.maximum(av, 1e-30)
    b = jnp.floor(jnp.log2(vmax / safe)).astype(jnp.int32)
    b = jnp.where(av > 0, jnp.clip(b, 0, n_buckets - 1), n_buckets - 1)
    # one-hot compare-and-sum: (NB,) partial counts for this tile
    buckets = jnp.arange(n_buckets, dtype=jnp.int32)
    counts = jnp.sum(
        (b[None, :, :] == buckets[:, None, None]).astype(jnp.int32),
        axis=(1, 2))
    out_ref[...] += counts


@functools.partial(jax.jit, static_argnames=("n_buckets", "interpret"))
def exp_histogram(v: Array, vmax: Array, *, n_buckets: int = N_BUCKETS,
                  interpret: bool = False) -> Array:
    """v: (R, 128); vmax: () f32.  Returns (n_buckets,) int32 counts of
    floor(log2(vmax/|v|)), zeros in the last bucket."""
    rows, lanes = v.shape
    assert lanes == 128
    br = min(BLOCK_ROWS, rows)
    grid = (pl.cdiv(rows, br),)
    return pl.pallas_call(
        functools.partial(_hist_kernel, n_buckets=n_buckets),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((br, lanes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n_buckets,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_buckets,), jnp.int32),
        interpret=interpret,
    )(vmax.reshape(1, 1), v)


def _band_kernel(lo_ref, hi_ref, v_ref, out_ref):
    v = v_ref[...]
    av = jnp.abs(v)
    keep = (av >= lo_ref[0, 0]) & (av < hi_ref[0, 0])
    out_ref[...] = jnp.where(keep, v, jnp.zeros((), v.dtype))


@functools.partial(jax.jit, static_argnames=("interpret",))
def band_select(v: Array, lo: Array, hi: Array, *,
                interpret: bool = False) -> Array:
    """v: (R, 128) -> entries with lo <= |v| < hi, zeros elsewhere."""
    rows, lanes = v.shape
    assert lanes == 128
    br = min(BLOCK_ROWS, rows)
    grid = (pl.cdiv(rows, br),)
    return pl.pallas_call(
        _band_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((br, lanes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), v.dtype),
        interpret=interpret,
    )(lo.reshape(1, 1), hi.reshape(1, 1), v)
