"""Error-feedback baselines: EF21 (Richtárik et al., 2021) and EF21-SGDM
(Fatkhullin et al., 2023) — the biased-compression state of the art the paper
compares against (§1.1, §4, Figs. 1–5).

EF21 (per worker i, compressor C):
    c_i^t = C(grad_i^t - g_i^t)        # compress the *innovation*
    g_i^{t+1} = g_i^t + c_i^t          # worker-side state
    g^{t+1}  = g^t + mean_i(c_i^t)     # server-side aggregate
    x^{t+1}  = x^t - eta * g^{t+1}

EF21-SGDM adds a client-side momentum estimate of the gradient:
    v_i^t = (1 - beta) * v_i^{t-1} + beta * grad_i^t
and feeds v_i^t (instead of grad_i^t) into the EF21 innovation.

The worker mirrors / server aggregate / momentum live in the first-class
`repro.core.types.CommState` pytree, so the exact same step runs on stacked
worker gradients of shape (M, d) in-process, on the packed byte wire, on the
jit-native device wire, and — with rank 0 replicating every worker's decoded
innovation into its ``g_workers`` mirror — over the multi-host TCP star
(`repro.comm.aggregate.MultihostPackedEF21`).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.types import Array, CommState, Compressor, ef21_comm_state


def ef21_targets(state: CommState, worker_grads: Array,
                 beta: float) -> tuple[Array, Array]:
    """(compression target, new momentum) for one EF21(-SGDM) step.

    ``beta = 1`` is plain EF21 (target = gradient, momentum untouched);
    ``beta < 1`` is EF21-SGDM (target = the updated momentum EMA).  Shared
    by every wire substrate so the innovation math is identical on all of
    them — including the per-rank slice the tcp transport computes."""
    if beta < 1.0:
        mom = (1.0 - beta) * state.momentum + beta * worker_grads
        return mom, mom
    return worker_grads, state.momentum


@dataclasses.dataclass(frozen=True)
class EF21:
    """EF21 / EF21-SGDM step.  ``beta = 1`` recovers plain EF21.

    ``bits_fn`` books the honest per-worker wire cost of one innovation
    message (defaults to the innovation compressor's own ledger entry);
    the registry passes `repro.core.bits.ef21_bits` for the Top-k variants
    so the abstract booking reconciles with the packed wire's measurement.
    """

    compressor: Compressor
    beta: float = 1.0  # momentum coefficient (EF21-SGDM uses beta < 1)
    bits_fn: Callable[[int], float] | None = None

    def init(self, num_workers: int, dim: int) -> CommState:
        return ef21_comm_state(num_workers, dim)

    def step(self, state: CommState,
             worker_grads: Array) -> tuple[Array, CommState, Array]:
        """Returns (descent direction g^{t+1}, new state, bits transmitted)."""
        target, mom = ef21_targets(state, worker_grads, self.beta)
        innovations = target - state.g_workers                  # (M, d)
        c = jax.vmap(lambda u: self.compressor.compress(u))(innovations)
        g_workers = state.g_workers + c
        g_server = state.g_server + jnp.mean(c, axis=0)

        m, d = worker_grads.shape
        per_msg = (self.bits_fn or self.compressor.bits)(d)
        bits = jnp.asarray(m * per_msg, jnp.float32)
        new_state = state._replace(step=state.step + 1, g_workers=g_workers,
                                   g_server=g_server, momentum=mom)
        return g_server, new_state, bits
