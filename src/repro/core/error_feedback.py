"""Error-feedback baselines: EF21 (Richtárik et al., 2021) and EF21-SGDM
(Fatkhullin et al., 2023) — the biased-compression state of the art the paper
compares against (§1.1, §4, Figs. 1–5).

EF21 (per worker i, compressor C):
    c_i^t = C(grad_i^t - g_i^t)        # compress the *innovation*
    g_i^{t+1} = g_i^t + c_i^t          # worker-side state
    g^{t+1}  = g^t + mean_i(c_i^t)     # server-side aggregate
    x^{t+1}  = x^t - eta * g^{t+1}

EF21-SGDM adds a client-side momentum estimate of the gradient:
    v_i^t = (1 - beta) * v_i^{t-1} + beta * grad_i^t
and feeds v_i^t (instead of grad_i^t) into the EF21 innovation.

These operate on *stacked worker gradients* of shape (M, d) so the same code
serves the in-process M-worker simulation used by the CPU benchmarks and the
per-shard path inside shard_map (M = 1 local worker per data shard).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import Array, Compressor


class EF21State(NamedTuple):
    g_workers: Array   # (M, d) worker-side compressed-gradient states g_i
    g_server: Array    # (d,) server aggregate g
    momentum: Array    # (M, d) momentum buffers v_i (zeros when beta == 1)


@dataclasses.dataclass(frozen=True)
class EF21:
    """EF21 / EF21-SGDM step.  ``beta = 1`` recovers plain EF21."""

    compressor: Compressor
    beta: float = 1.0  # momentum coefficient (EF21-SGDM uses beta < 1)

    def init(self, num_workers: int, dim: int) -> EF21State:
        z = jnp.zeros((num_workers, dim), jnp.float32)
        return EF21State(g_workers=z, g_server=jnp.zeros((dim,), jnp.float32),
                         momentum=z)

    def step(self, state: EF21State, worker_grads: Array) -> tuple[Array, EF21State, Array]:
        """Returns (descent direction g^{t+1}, new state, bits transmitted)."""
        if self.beta < 1.0:
            mom = (1.0 - self.beta) * state.momentum + self.beta * worker_grads
            target = mom
        else:
            mom = state.momentum
            target = worker_grads

        innovations = target - state.g_workers                  # (M, d)
        c = jax.vmap(lambda u: self.compressor.compress(u))(innovations)
        g_workers = state.g_workers + c
        g_server = state.g_server + jnp.mean(c, axis=0)

        m = worker_grads.shape[0]
        bits = jnp.asarray(m * self.compressor.bits(worker_grads.shape[1]),
                           jnp.float32)
        return g_server, EF21State(g_workers, g_server, mom), bits
