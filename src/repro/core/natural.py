"""Natural compression (Horváth et al., 2022) and SignSGD (Bernstein et al.,
2018) — two further baselines from the paper's related-work section (§1.1).

* Natural compression rounds each value to one of its two neighbouring
  powers of two, with probabilities making it UNBIASED (ω = 1/8); the wire
  format is sign + 8-bit exponent ≈ 9 bits/entry.
* SignSGD transmits sign(v) scaled by mean|v| — BIASED (the canonical
  1-bit baseline; needs error feedback, works with our EF21 wrapper).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.types import Array, Compressor, PRNGKey

_EPS = 1e-30


@dataclasses.dataclass(frozen=True)
class NaturalCompression(Compressor):
    unbiased: bool = dataclasses.field(default=True, init=False)

    def compress(self, v: Array, *, rng: PRNGKey | None = None) -> Array:
        if rng is None:
            raise ValueError("natural compression is stochastic; rng needed")
        m, e = jnp.frexp(jnp.where(v == 0.0, 1.0, v))   # v = m 2^e, |m|∈[.5,1)
        lo = jnp.ldexp(jnp.sign(m) * 0.5, e)            # 2^(e-1) neighbour
        hi = jnp.ldexp(jnp.sign(m) * 1.0, e)            # 2^e neighbour
        # unbiasedness: P(hi) = (|v| - |lo|) / (|hi| - |lo|) = 2|m| - 1
        p_hi = 2.0 * jnp.abs(m) - 1.0
        take_hi = jax.random.bernoulli(rng, jnp.clip(p_hi, 0.0, 1.0))
        out = jnp.where(take_hi, hi, lo)
        return jnp.where(v == 0.0, 0.0, out)

    def bits(self, d: int) -> float:
        return 9.0 * d  # sign + 8-bit exponent


@dataclasses.dataclass(frozen=True)
class SignSGD(Compressor):
    unbiased: bool = dataclasses.field(default=False, init=False)

    def compress(self, v: Array, *, rng: PRNGKey | None = None) -> Array:
        del rng
        scale = jnp.mean(jnp.abs(v))
        return jnp.sign(v) * scale

    def bits(self, d: int) -> float:
        return float(d) + 32  # 1 bit/entry + the scale header
