"""Bit-wise multilevel compressors: fixed-point (§3.1) and floating-point (App. B).

Fixed point
-----------
After normalizing by the max magnitude (which is transmitted alongside), each
entry ``|e| <= 1`` is viewed as a binary fraction ``sum_j b_j 2^{-j}``
(Eq. 7).  ``C^l`` truncates that sum to the first ``l`` bits.  The level-l
MLMC residual is the single bit-plane ``sign(e) * b_l * 2^{-l}`` — two bits of
information per entry, which is the paper's headline ×32 communication saving
(2d + 64 + log2(L) bits/step vs 64d uncompressed).

Lemma 3.3: the variance-optimal level distribution is ``p_l ∝ 2^{-l}``.

Floating point
--------------
Each entry keeps its own exponent (via frexp); ``C^l`` truncates the mantissa
to ``l`` fractional bits.  The residual is one mantissa bit scaled by the
per-entry exponent: ~13 bits/entry wire cost in the paper's fp64 accounting
(sign + 11-bit exponent + 1 mantissa bit).  Lemma B.1 gives the same
``p_l ∝ 2^{-l}`` optimum.

Precision note (documented deviation): the paper works with 64-bit words
(L = 63 / 52).  This framework computes in float32, whose 24-bit significand
makes bit-planes beyond ~24 identically zero, so the default ladders are
L = 24 (fixed) / 23 (float).  ``C^L = id`` is enforced *exactly* by defining
the top level as the identity and its residual as ``v - C^{L-1}(v)`` — the
telescoping sum, and hence Lemma 3.2's unbiasedness, remains exact.  The
paper's 64-bit wire accounting is preserved in :mod:`repro.core.bits`.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.types import Array, Compressor, MultilevelCompressor, \
    PRNGKey, pin_rounding

_EPS = 1e-30


def _ldexp(x: Array, e: Array) -> Array:
    """x * 2**e with traced integer e (jnp.ldexp handles this)."""
    return jnp.ldexp(x, e)


# ---------------------------------------------------------------------------
# Fixed point
# ---------------------------------------------------------------------------


def _fixed_scale(v: Array) -> Array:
    """Normalizing scale (the transmitted max-magnitude header)."""
    return jnp.maximum(jnp.max(jnp.abs(v)), _EPS)


# largest float32 strictly below 1.0 — clamping here keeps the integer part
# of the fixed-point representation at zero even for the max-magnitude entry
_BELOW_ONE = 1.0 - 2.0 ** -24


def _fixed_trunc(scaled_abs: Array, l: Array) -> Array:
    """floor(x * 2^l) / 2^l for x in [0, 1], jit-safe in traced l."""
    x = jnp.minimum(scaled_abs, _BELOW_ONE)
    return _ldexp(jnp.floor(_ldexp(x, l)), -l)


@dataclasses.dataclass(frozen=True)
class FixedPointMultilevel(MultilevelCompressor):
    """Multilevel fixed-point truncation; level l keeps bits 1..l (Eq. 7)."""

    num_bits: int = 24  # L; paper uses 63 (64-bit words), f32 supports ~24

    @property
    def num_levels(self) -> int:
        return self.num_bits

    def compress(self, v: Array, l: Array | int) -> Array:
        l = jnp.asarray(l, jnp.int32)
        scale = _fixed_scale(v)
        trunc = scale * jnp.sign(v) * _fixed_trunc(jnp.abs(v) / scale, l)
        # top level is the exact identity (Def. 3.1)
        return jnp.where(l >= self.num_levels, v, jnp.where(l <= 0, 0.0, trunc))

    def residual(self, v: Array, l: Array | int) -> Array:
        l = jnp.asarray(l, jnp.int32)
        scale = _fixed_scale(v)
        x = jnp.minimum(jnp.abs(v) / scale, _BELOW_ONE)
        bit = jnp.mod(jnp.floor(_ldexp(x, l)), 2.0)           # b_l ∈ {0,1}
        plane = scale * jnp.sign(v) * _ldexp(bit, -l)         # sign·b_l·2^-l
        # pin_rounding keeps compress()'s product rounded before the
        # subtraction: XLA would otherwise contract the trailing multiply
        # into an FMA under jit, making jitted residuals differ from eager
        # ones by 1 ulp — breaking the byte-wire contract (the compiled
        # codec pipeline ships this residual verbatim on top-level draws)
        top = v - pin_rounding(self.compress(v, self.num_levels - 1))
        return jnp.where(l >= self.num_levels, top, plane)

    def residual_norms(self, v: Array) -> Array:
        ls = jnp.arange(1, self.num_levels + 1, dtype=jnp.int32)
        return jax.vmap(lambda l: jnp.linalg.norm(self.residual(v, l)))(ls)

    def static_probs(self) -> Array:
        """Lemma 3.3: p_l = 2^{-l} / (1 - 2^{-L})."""
        L = self.num_levels
        l = jnp.arange(1, L + 1, dtype=jnp.float32)
        return (2.0 ** -l) / (1.0 - 2.0 ** -float(L))

    def residual_bits(self, d: int) -> float:
        # one information bit + one sign bit per entry (§3.1)
        return 2.0 * d


@dataclasses.dataclass(frozen=True)
class FixedPointCompressor(Compressor):
    """Biased F-bit fixed-point truncation baseline (the paper's
    '2-bit quantization' baseline in Fig. 3 is ``F=2``)."""

    f_bits: int

    def compress(self, v: Array, *, rng: PRNGKey | None = None) -> Array:
        del rng
        return FixedPointMultilevel(num_bits=max(self.f_bits, 2) + 1).compress(
            v, self.f_bits
        )

    def bits(self, d: int) -> float:
        return (self.f_bits + 1.0) * d + 32  # bits + sign, plus scale header


# ---------------------------------------------------------------------------
# Floating point
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FloatingPointMultilevel(MultilevelCompressor):
    """Multilevel floating-point mantissa truncation (App. B).

    frexp gives ``v = m * 2^E`` with ``m in [0.5, 1)``; level l keeps the
    leading bit plus ``l`` fractional mantissa bits.
    """

    num_bits: int = 23  # paper: 52 (fp64 mantissa); f32 mantissa = 23

    @property
    def num_levels(self) -> int:
        return self.num_bits

    def _mantissa_exp(self, v: Array) -> tuple[Array, Array]:
        m, e = jnp.frexp(jnp.where(v == 0.0, 1.0, v))
        m = jnp.where(v == 0.0, 0.0, m)
        return m, e

    def base(self, v: Array) -> Array:
        """``C^0(v) = sign(v) * 2^{E(v)}`` — the always-transmitted
        sign+exponent leading term (App. B; part of the 13 bits/entry)."""
        m, e = self._mantissa_exp(v)
        return _ldexp(jnp.sign(m) * 0.5, e)

    def compress(self, v: Array, l: Array | int) -> Array:
        l = jnp.asarray(l, jnp.int32)
        m, e = self._mantissa_exp(v)
        # truncate |m| in [0.5, 1) to 1 leading + l fractional bits; at l = 0
        # this is exactly the base() leading term sign * 2^E
        tm = jnp.sign(m) * _ldexp(jnp.floor(_ldexp(jnp.abs(m), l + 1)), -(l + 1))
        trunc = _ldexp(tm, e)
        return jnp.where(l >= self.num_levels, v, trunc)

    def residual(self, v: Array, l: Array | int) -> Array:
        l = jnp.asarray(l, jnp.int32)
        m, e = self._mantissa_exp(v)
        bit = jnp.mod(jnp.floor(_ldexp(jnp.abs(m), l + 1)), 2.0)  # m_l ∈ {0,1}
        plane = _ldexp(jnp.sign(m) * bit, e - (l + 1))
        top = v - self.compress(v, self.num_levels - 1)
        return jnp.where(l >= self.num_levels, top, plane)

    def residual_norms(self, v: Array) -> Array:
        ls = jnp.arange(1, self.num_levels + 1, dtype=jnp.int32)
        return jax.vmap(lambda l: jnp.linalg.norm(self.residual(v, l)))(ls)

    def static_probs(self) -> Array:
        """Lemma B.1: p_l = 2^{-l} / (1 - 2^{-L})."""
        L = self.num_levels
        l = jnp.arange(1, L + 1, dtype=jnp.float32)
        return (2.0 ** -l) / (1.0 - 2.0 ** -float(L))

    def residual_bits(self, d: int) -> float:
        # sign + exponent + 1 mantissa bit per entry; fp64 accounting -> 13d
        return 13.0 * d
