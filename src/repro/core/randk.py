"""Rand-k: the canonical *unbiased* sparsifier baseline (§1, §2.2).

Keeps k uniformly-random coordinates scaled by d/k, so ``E[C(v)] = v`` with
variance coefficient ``omega = d/k - 1`` (Eq. 3).  The paper's experiments use
it as the unbiased strawman that MLMC-Top-k dominates (Lemma 3.6:
O(d/s) vs O(1/(r s)) variance under exponentially-decaying gradients).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.types import Array, Compressor, PRNGKey


@dataclasses.dataclass(frozen=True)
class RandK(Compressor):
    k: int
    unbiased: bool = dataclasses.field(default=True, init=False)

    def compress(self, v: Array, *, rng: PRNGKey | None = None) -> Array:
        if rng is None:
            raise ValueError("Rand-k is stochastic; an rng key is required")
        d = v.shape[0]
        # choose k of d without replacement via a random permutation prefix
        perm = jax.random.permutation(rng, d)
        mask = jnp.zeros((d,), bool).at[perm[: self.k]].set(True)
        return jnp.where(mask, v * (d / self.k), 0.0)

    def bits(self, d: int) -> float:
        del d
        return float(self.k) * (32 + 32)

    def omega(self, d: int) -> float:
        return d / self.k - 1.0
