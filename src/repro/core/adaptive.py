"""Adaptive level probabilities — Lemma 3.4 (Alg. 3).

For any multilevel compressor, the variance-minimizing per-sample level
distribution is

    p_l = Delta_l / sum_{l'} Delta_{l'},   Delta_l = ||C^l(v) - C^{l-1}(v)||

obtained by minimizing ``sum_l Delta_l^2 / p_l`` subject to ``sum p_l = 1``
(App. D).  For s-Top-k this reduces to ``p_l ∝ sqrt(alpha_l - alpha_{l-1})``
in terms of the adaptive energy coefficients of Eq. (10); the reduction is
checked in the test-suite rather than special-cased here.

The induced optimal second moment is ``(sum_l Delta_l)^2`` (Eq. 54), i.e. the
squared *L1 norm of the residual-norm ladder* — the quantity Lemma 3.6 bounds
by ``O(1/(r s)) ||v||^2`` under exponentially-decaying gradients.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import Array, MultilevelCompressor

_EPS = 1e-30


def adaptive_probs(compressor: MultilevelCompressor, v: Array) -> Array:
    """Lemma 3.4: ``p_l ∝ Delta_l``, guarded against all-zero gradients."""
    deltas = compressor.residual_norms(v)
    total = jnp.sum(deltas)
    uniform = jnp.full_like(deltas, 1.0 / deltas.shape[0])
    return jnp.where(total > _EPS, deltas / jnp.maximum(total, _EPS), uniform)


def optimal_second_moment(compressor: MultilevelCompressor, v: Array) -> Array:
    """``E||g~||^2`` under the Lemma-3.4 optimum: ``(sum_l Delta_l)^2``."""
    return jnp.sum(compressor.residual_norms(v)) ** 2


def optimal_compression_variance(
    compressor: MultilevelCompressor, v: Array
) -> Array:
    """Eq. (55): ``sigma_comp^2 = (sum_l Delta_l)^2 - ||v||^2``."""
    return optimal_second_moment(compressor, v) - jnp.sum(v * v)
