"""Adaptive level probabilities — Lemma 3.4 (Alg. 3).

For any multilevel compressor, the variance-minimizing per-sample level
distribution is

    p_l = Delta_l / sum_{l'} Delta_{l'},   Delta_l = ||C^l(v) - C^{l-1}(v)||

obtained by minimizing ``sum_l Delta_l^2 / p_l`` subject to ``sum p_l = 1``
(App. D).  For s-Top-k this reduces to ``p_l ∝ sqrt(alpha_l - alpha_{l-1})``
in terms of the adaptive energy coefficients of Eq. (10); the reduction is
checked in the test-suite rather than special-cased here.

The induced optimal second moment is ``(sum_l Delta_l)^2`` (Eq. 54), i.e. the
squared *L1 norm of the residual-norm ladder* — the quantity Lemma 3.6 bounds
by ``O(1/(r s)) ||v||^2`` under exponentially-decaying gradients.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import Array, MultilevelCompressor

_EPS = 1e-30


def adaptive_probs(compressor: MultilevelCompressor, v: Array) -> Array:
    """Lemma 3.4: ``p_l ∝ Delta_l``, guarded against all-zero gradients."""
    deltas = compressor.residual_norms(v)
    total = jnp.sum(deltas)
    uniform = jnp.full_like(deltas, 1.0 / deltas.shape[0])
    return jnp.where(total > _EPS, deltas / jnp.maximum(total, _EPS), uniform)


def probs_from_ladder(ladder: Array) -> Array:
    """Lemma-3.4 probabilities from a residual-norm ladder: ``p_l ∝ Delta_l``
    along the LAST axis, guarded against an all-zero ladder (uniform).

    Works on a single ``(L,)`` ladder or a batched ``(M, L)`` stack of
    per-worker ladders; every wire substrate (abstract / packed / device /
    mesh) calls this same function so the sampled levels agree across
    wires."""
    ladder = jnp.asarray(ladder, jnp.float32)
    total = jnp.sum(ladder, axis=-1, keepdims=True)
    uniform = jnp.full_like(ladder, 1.0 / ladder.shape[-1])
    return jnp.where(total > _EPS, ladder / jnp.maximum(total, _EPS), uniform)


def ladder_ema_update(ema: Array, deltas: Array, rho, step) -> Array:
    """Stateful Alg. 3: EMA of the residual-norm ladder across steps.

    ``ema' = (1 - rho) * ema + rho * Delta(v_t)``, seeded with the fresh
    ladder on the very first step (``step == 0``) so the cold state never
    biases the Lemma-3.4 distribution toward uniform.  ``rho = 1`` recovers
    the per-sample adaptive distribution of the stateless estimator exactly.

    Smoothing the *ladder* (not the probabilities) keeps the estimator
    conditionally unbiased for any resulting distribution (Lemma 3.2 holds
    for ANY non-zero p), while damping step-to-step noise in the sampled
    level — the stateful refinement the `mlmc_adaptive_*` registry family
    runs on every wire."""
    ema = jnp.asarray(ema, jnp.float32)
    fresh = jnp.asarray(deltas, jnp.float32)
    blended = (1.0 - jnp.float32(rho)) * ema + jnp.float32(rho) * fresh
    return jnp.where(jnp.asarray(step) == 0, fresh, blended)


def optimal_second_moment(compressor: MultilevelCompressor, v: Array) -> Array:
    """``E||g~||^2`` under the Lemma-3.4 optimum: ``(sum_l Delta_l)^2``."""
    return jnp.sum(compressor.residual_norms(v)) ** 2


def optimal_compression_variance(
    compressor: MultilevelCompressor, v: Array
) -> Array:
    """Eq. (55): ``sigma_comp^2 = (sum_l Delta_l)^2 - ||v||^2``."""
    return optimal_second_moment(compressor, v) - jnp.sum(v * v)
