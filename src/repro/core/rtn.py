"""Round-to-Nearest (RTN) multilevel compressor (App. G.2, Eq. 125).

``C^l_RTN(v) = delta_l * clip(round(v / delta_l), -m_l, m_l)`` where the grid
spacing ``delta_l = 2c / (2^l - 1)`` covers ``[-c, c]`` with ``2^l - 1`` cells
and ``m_l = floor((2^l - 1) / 2)`` integer slots on each side.  We take
``c`` to be the per-tensor max magnitude (transmitted as a header).

RTN is the paper's example of a *structured* compressor with **no importance
-sampling interpretation** (§3.2): the residual ``C^l - C^{l-1}`` has no
sparse closed form, so it is computed as an explicit difference and the
adaptive Lemma-3.4 distribution is obtained from the L residual norms.
L is small (default 8), so this is cheap.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.types import Array, Compressor, MultilevelCompressor, \
    PRNGKey, pin_rounding

_EPS = 1e-30


def rtn_quantize(v: Array, l: Array | int, c: Array) -> Array:
    """One RTN quantization at level l with clip scale c (jit-safe traced l)."""
    l = jnp.asarray(l, jnp.float32)
    cells = 2.0 ** l - 1.0
    delta = 2.0 * c / jnp.maximum(cells, 1.0)
    m = jnp.floor(cells / 2.0)
    q = jnp.clip(jnp.round(v / jnp.maximum(delta, _EPS)), -m, m)
    return delta * q


@dataclasses.dataclass(frozen=True)
class RTNMultilevel(MultilevelCompressor):
    """Multilevel RTN; level l uses a 2^l-point grid; top level = identity."""

    num_bits: int = 8  # L; level L is the exact identity per Def. 3.1

    @property
    def num_levels(self) -> int:
        return self.num_bits

    def _scale(self, v: Array) -> Array:
        return jnp.maximum(jnp.max(jnp.abs(v)), _EPS)

    def compress(self, v: Array, l: Array | int) -> Array:
        l = jnp.asarray(l, jnp.int32)
        q = rtn_quantize(v, l, self._scale(v))
        return jnp.where(l >= self.num_levels, v, jnp.where(l <= 0, 0.0, q))

    def residual(self, v: Array, l: Array | int) -> Array:
        l = jnp.asarray(l, jnp.int32)
        # pin each grid value's rounding before the subtraction — XLA
        # would otherwise contract `delta*q - delta'*q'` into FMAs under
        # jit and jitted residuals drift 1 ulp off the eager ones the byte
        # wire (and its golden fixtures) are built from
        return pin_rounding(self.compress(v, l)) - \
            pin_rounding(self.compress(v, l - 1))

    def residual_norms(self, v: Array) -> Array:
        ls = jnp.arange(1, self.num_levels + 1, dtype=jnp.int32)

        def one(l: Array) -> Array:
            r = self.residual(v, l)
            # pinned replica of jnp.linalg.norm's sqrt(sum(x*x)): keeps the
            # squares rounded before the reduction so the jitted ladder —
            # and hence every Lemma-3.4 probability shipped in a packet
            # header — is bit-identical to the eager one
            return jnp.sqrt(jnp.sum(pin_rounding(r * r)))

        return jax.vmap(one)(ls)

    def static_probs(self) -> Array:
        # RTN error roughly halves per extra bit -> geometric p_l ∝ 2^{-l}
        L = self.num_levels
        l = jnp.arange(1, L + 1, dtype=jnp.float32)
        return (2.0 ** -l) / (1.0 - 2.0 ** -float(L))

    def residual_bits(self, d: int) -> float:
        # residual lives on the level-l grid: <= 2 bits/entry of new info
        # (one refinement bit + sign), mirroring the fixed-point accounting
        return 2.0 * d


@dataclasses.dataclass(frozen=True)
class RTNCompressor(Compressor):
    """Biased plain-RTN baseline at a fixed level (Fig. 6 comparisons)."""

    level: int

    def compress(self, v: Array, *, rng: PRNGKey | None = None) -> Array:
        del rng
        return rtn_quantize(v, self.level, jnp.maximum(jnp.max(jnp.abs(v)), _EPS))

    def bits(self, d: int) -> float:
        return float(self.level) * d + 32
