"""QSGD (Alistarh et al., 2017): unbiased stochastic quantization baseline.

``C(v)_i = ||v||_2 * sign(v_i) * xi_i / s`` where ``xi_i`` randomly rounds
``s |v_i| / ||v||_2`` to a neighbouring integer so that the estimator is
unbiased.  Used by the paper as the unbiased bit-wise baseline in Fig. 3
("2-bit QSGD" = s = 2 quantization levels + sign).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.types import Array, Compressor, PRNGKey

_EPS = 1e-30


@dataclasses.dataclass(frozen=True)
class QSGD(Compressor):
    #: number of quantization levels s (2-bit QSGD -> s = 2)
    s: int = 2
    unbiased: bool = dataclasses.field(default=True, init=False)

    def compress(self, v: Array, *, rng: PRNGKey | None = None) -> Array:
        if rng is None:
            raise ValueError("QSGD is stochastic; an rng key is required")
        norm = jnp.maximum(jnp.linalg.norm(v), _EPS)
        x = jnp.abs(v) / norm * self.s             # in [0, s]
        lo = jnp.floor(x)
        p_up = x - lo                               # P(round up) — unbiased
        up = jax.random.bernoulli(rng, p_up)
        xi = lo + up.astype(v.dtype)
        return norm * jnp.sign(v) * xi / self.s

    def bits(self, d: int) -> float:
        import math

        # sign + level index per entry, plus the 32-bit norm header
        return d * (1 + math.ceil(math.log2(self.s + 1))) + 32
