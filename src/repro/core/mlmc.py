"""The MLMC estimator — the paper's core contribution (Eq. 5/6, Alg. 2/3).

Given a multilevel compressor ``C^0 = 0, ..., C^L = id`` and non-zero level
probabilities ``p``, the estimator of one stochastic gradient ``v`` is

    g~ = C^0(v) + (1/p_l) * (C^l(v) - C^{l-1}(v)),   l ~ p        (Eq. 6)

which is conditionally unbiased for ANY valid ``p`` (Lemma 3.2).  Alg. 2 uses
a static ``p`` (e.g. Lemma 3.3's ``p_l ∝ 2^{-l}`` for bit-wise compressors);
Alg. 3 recomputes the Lemma-3.4 optimum ``p_l ∝ Delta_l`` per sample.

This module is deliberately tiny and pure — it is the plug-and-play "MLMC
block" of §3: (stochastic gradient, multilevel compressor, level
distribution) -> unbiased estimate.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.adaptive import adaptive_probs
from repro.core.types import (
    Array,
    MLMCEstimate,
    MultilevelCompressor,
    PRNGKey,
    categorical,
)

#: header cost: sampled level index + 32-bit scale header (paper: 64-bit max
#: entry + ceil(log2(L)) bits; we account a 32-bit header + level index).
def header_bits(num_levels: int) -> float:
    return 32.0 + math.ceil(math.log2(max(num_levels, 2)))


def mlmc_estimate(
    compressor: MultilevelCompressor,
    v: Array,
    rng: PRNGKey,
    *,
    probs: Array | None = None,
    adaptive: bool = False,
) -> MLMCEstimate:
    """One MLMC compression of a flat vector ``v`` (Alg. 2 inner block).

    Args:
      compressor: the multilevel family ``C^l``.
      v: flat float vector (the stochastic gradient of one worker).
      rng: PRNG key for the level draw.
      probs: optional explicit level distribution (length L).  Ignored when
        ``adaptive=True``.
      adaptive: use the per-sample Lemma-3.4 optimum (Alg. 3).
    """
    if adaptive:
        probs = adaptive_probs(compressor, v)
    elif probs is None:
        probs = compressor.static_probs()
    probs = probs / jnp.sum(probs)

    idx = categorical(rng, probs)            # 0-based level index
    level = idx + 1                          # paper levels are 1-based
    p_l = jnp.maximum(probs[idx], 1e-30)

    residual = compressor.residual(v, level)
    # Eq. 6: g~ = C^0(v) + residual / p_l   (C^0 is zero for all families
    # except floating-point, whose sign+exponent term is always transmitted)
    estimate = compressor.base(v) + residual / p_l

    bits = jnp.asarray(
        compressor.residual_bits(v.shape[0]) + header_bits(compressor.num_levels),
        jnp.float32,
    )
    return MLMCEstimate(
        estimate=estimate, level=level, prob=p_l, payload_bits=bits, residual=residual
    )


def mlmc_second_moment(
    compressor: MultilevelCompressor, v: Array, probs: Array | None = None
) -> Array:
    """Closed-form ``E||g~||^2 = sum_l Delta_l^2 / p_l`` (App. D, Eq. 48).

    Used by the variance benchmarks/tests to check Lemmas 3.3/3.4/3.6 without
    Monte-Carlo noise.  Valid for zero-``base()`` families (Top-k/s-Top-k,
    fixed-point, RTN); the floating-point family's deterministic sign+exponent
    term shifts the mean, see App. B Eq. 29-33 for its variance.
    """
    deltas = compressor.residual_norms(v)
    if probs is None:
        probs = compressor.static_probs()
    probs = probs / jnp.sum(probs)
    return jnp.sum(deltas**2 / jnp.maximum(probs, 1e-30))


def mlmc_compression_variance(
    compressor: MultilevelCompressor, v: Array, probs: Array | None = None
) -> Array:
    """``sigma_comp^2 = E||g~||^2 - ||v||^2`` (Eq. 55; unbiasedness makes the
    mean of g~ equal v, so this is the excess second moment)."""
    return mlmc_second_moment(compressor, v, probs) - jnp.sum(v * v)
