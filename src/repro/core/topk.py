"""Top-k and s-segmented Top-k compressors (paper §2.2, §3.2).

The paper's ``s-Top-k`` sorts the vector by magnitude, divides the *sorted*
vector into segments of length ``s``, and keeps the ``k`` segments with the
largest norm (App. E: "retains the k non-overlapping segments of length s
with the largest norms of the sorted stochastic gradient vector").  Because
the vector is sorted first, ``s``-Top-``k`` coincides with plain
Top-``(k*s)`` — the segment structure matters for the *multilevel residual*:
the level-``l`` residual ``C^l(v) - C^{l-1}(v)`` is exactly the magnitude
ranks ``[(l-1)s, ls)``, i.e. ONE length-``s`` segment, which is what makes the
MLMC wire payload tiny (§3.2).

Plain Top-k is the ``s = 1`` special case.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.types import Array, Compressor, MultilevelCompressor, PRNGKey
from repro.kernels import select as _select

_INDEX_BITS = 32  # we account indices at 32 bits; `bits.py` also offers log2(d)


def magnitude_ranks(v: Array) -> Array:
    """Rank of each entry by descending |value| (rank 0 = largest).

    Canonical order: descending uint32 keys of |v|, ties ascending index
    (`kernels.select`) — identical to the historical ``argsort(-|v|)`` for
    every non-denormal input, and deterministic where CPU float sort
    comparators flushed denormals.  Materializes a full permutation; the
    hot paths below select through `kernels.select` without it.
    """
    order = jnp.argsort(~_select.magnitude_keys(v))  # stable desc-key order
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(v.shape[0]))
    return ranks


def topk_mask(v: Array, k: Array | int) -> Array:
    """Boolean mask of the k largest-|.| entries (jit-safe in traced k).

    Sort-free: static k routes through the ``lax.top_k`` custom call,
    traced k through the threshold band of `kernels.select.topk_mask`.
    """
    return _select.topk_mask(v, k)


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Biased Top-k baseline: keep the k largest-magnitude entries (Eq. 9)."""

    k: int
    unbiased: bool = dataclasses.field(default=False, init=False)

    def compress(self, v: Array, *, rng: PRNGKey | None = None) -> Array:
        del rng  # deterministic
        return jnp.where(topk_mask(v, self.k), v, 0.0)

    def bits(self, d: int) -> float:
        del d
        return float(self.k) * (32 + _INDEX_BITS)

    def alpha(self, d: int) -> float:
        """Worst-case energy retention coefficient: alpha = k/d (Eq. 4/9)."""
        return self.k / d


@dataclasses.dataclass(frozen=True)
class STopKMultilevel(MultilevelCompressor):
    """Multilevel (s-)Top-k family: ``C^l`` keeps the top ``l*s`` entries.

    L = ceil(d / s) so that ``C^L = id`` (Def. 3.1).  The level-l residual is
    the single segment of magnitude-ranks ``[(l-1)s, ls)``.
    """

    d: int
    s: int = 1
    #: decay ratio of the fallback static level distribution (geometric);
    #: Alg. 3 replaces this with the adaptive Lemma-3.4 optimum.
    static_ratio: float = 0.75

    def __post_init__(self):
        if self.d <= 0 or self.s <= 0:
            raise ValueError(f"need d>0, s>0; got d={self.d}, s={self.s}")

    @property
    def num_levels(self) -> int:
        return math.ceil(self.d / self.s)

    # -- Def. 3.1 interface -------------------------------------------------

    def compress(self, v: Array, l: Array | int) -> Array:
        l = jnp.asarray(l, jnp.int32)
        return jnp.where(_select.topk_mask(v, l * self.s), v, 0.0)

    def residual(self, v: Array, l: Array | int) -> Array:
        l = jnp.asarray(l, jnp.int32)
        seg = _select.band_mask(v, (l - 1) * self.s, l * self.s)
        return jnp.where(seg, v, 0.0)

    def residual_norms(self, v: Array) -> Array:
        """Delta_l = sqrt(sum of |v|^2 over magnitude ranks [(l-1)s, ls)).

        Sorts the uint32 magnitude keys (4-5x cheaper than a float sort;
        the bitcast back is bitwise ``jnp.sort(|v|)[::-1]``)."""
        L = self.num_levels
        sq = _select.sorted_abs_desc(v) ** 2
        pad = L * self.s - self.d
        sq = jnp.pad(sq, (0, pad))
        return jnp.sqrt(jnp.sum(sq.reshape(L, self.s), axis=-1))

    def static_probs(self) -> Array:
        L = self.num_levels
        p = self.static_ratio ** jnp.arange(L, dtype=jnp.float32)
        return p / jnp.sum(p)

    def residual_bits(self, d: int) -> float:
        del d
        # one segment: s values + s (32-bit) positions in the original vector
        return float(self.s) * (32 + _INDEX_BITS)

    # -- extras --------------------------------------------------------------

    def alphas(self, v: Array) -> Array:
        """Adaptive energy coefficients alpha^l_{t,i} of Eq. (10), all levels.

        ``alpha_l = ||C^l(v)||^2 / ||v||^2`` (so Lemma 3.4's reduction
        ``p_l ∝ sqrt(alpha_l - alpha_{l-1})`` holds — tested)."""
        deltas_sq = self.residual_norms(v) ** 2
        total = jnp.sum(deltas_sq)
        return jnp.cumsum(deltas_sq) / jnp.maximum(total, 1e-30)


def stopk_for(v_size: int, k_fraction: float, s: int = 1) -> STopKMultilevel:
    """Convenience: multilevel family sized for a tensor of ``v_size``."""
    del k_fraction  # the MLMC family always spans the full ladder
    return STopKMultilevel(d=v_size, s=s)
