"""repro.core — the paper's contribution: MLMC gradient compression.

Public surface:
  * multilevel compressors: STopKMultilevel, FixedPointMultilevel,
    FloatingPointMultilevel, RTNMultilevel           (Def. 3.1 families)
  * the MLMC block: mlmc_estimate                    (Eq. 6, Alg. 2)
  * adaptive probabilities: adaptive_probs           (Lemma 3.4, Alg. 3)
  * baselines: TopK, RandK, QSGD, RTNCompressor, FixedPointCompressor,
    EF21 (incl. EF21-SGDM via beta < 1)
  * aggregation registry: make_aggregator
  * bit accounting: repro.core.bits
"""

from repro.core.adaptive import (
    adaptive_probs,
    ladder_ema_update,
    optimal_compression_variance,
    optimal_second_moment,
    probs_from_ladder,
)
from repro.core.aggregators import (
    ALL_AGGREGATORS,
    STATEFUL_AGGREGATORS,
    Aggregator,
    make_aggregator,
)
from repro.core.bitwise import (
    FixedPointCompressor,
    FixedPointMultilevel,
    FloatingPointMultilevel,
)
from repro.core.error_feedback import EF21, ef21_targets
from repro.core.mlmc import (
    mlmc_compression_variance,
    mlmc_estimate,
    mlmc_second_moment,
)
from repro.core.qsgd import QSGD
from repro.core.randk import RandK
from repro.core.rtn import RTNCompressor, RTNMultilevel, rtn_quantize
from repro.core.topk import STopKMultilevel, TopK, magnitude_ranks, topk_mask
from repro.core.types import (
    CommState,
    Compressor,
    MLMCEstimate,
    MultilevelCompressor,
    adaptive_comm_state,
    categorical,
    ef21_comm_state,
    empty_comm_state,
)

__all__ = [
    "ALL_AGGREGATORS", "Aggregator", "CommState", "Compressor", "EF21",
    "FixedPointCompressor", "FixedPointMultilevel", "FloatingPointMultilevel",
    "MLMCEstimate", "MultilevelCompressor", "QSGD", "RTNCompressor",
    "RTNMultilevel", "RandK", "STATEFUL_AGGREGATORS", "STopKMultilevel",
    "TopK", "adaptive_comm_state", "adaptive_probs", "categorical",
    "ef21_comm_state", "ef21_targets", "empty_comm_state",
    "ladder_ema_update", "magnitude_ranks", "make_aggregator",
    "mlmc_compression_variance", "mlmc_estimate", "mlmc_second_moment",
    "optimal_compression_variance", "optimal_second_moment",
    "probs_from_ladder", "rtn_quantize", "topk_mask",
]
