"""Gradient aggregation strategies — the distributed-learning surface of the
paper's Algorithms 1–3 and of the baselines it compares against.

An aggregator consumes *stacked per-worker gradients* ``(M, d)`` plus a
first-class `repro.core.types.CommState` and produces the server-side update
direction, the successor state, and the transmitted-bit count.  This single
abstraction backs:

* the in-process M-worker simulation used by CPU benchmarks/examples
  (mathematically identical to M machines — the paper's Figs. 1–6), and
* the per-data-shard path inside `shard_map` (`repro.sharding.collectives`
  realizes the same estimators with actual mesh collectives).

The unified protocol every wire substrate implements identically:

    agg.init(num_workers, dim) -> CommState      # empty for stateless
    agg.step(state, worker_grads, rng) -> AggregateOut(direction, state, bits)

Stateless families return their input state unchanged (or a fresh empty one
when called with ``state=None``); the stateful families — EF21 / EF21-SGDM
(worker innovation mirrors) and the adaptive MLMC `mlmc_adaptive_*` family
(EMA of Lemma-3.4 residual-norm ladders) — thread real state step over step
on the abstract, packed, device, and tcp wires alike.

Registry keys (``make_aggregator``):
  dense | topk | randk | qsgd | rtn | fixed2 |
  mlmc_topk | mlmc_topk_static | mlmc_stopk | mlmc_fixed | mlmc_float |
  mlmc_rtn | mlmc_adaptive_topk | mlmc_adaptive_stopk | mlmc_adaptive_rtn |
  ef21 | ef21_sgdm | natural | signsgd | signsgd_ef
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bits as bitcost
from repro.core.adaptive import ladder_ema_update, probs_from_ladder
from repro.core.bitwise import (
    FixedPointCompressor,
    FixedPointMultilevel,
    FloatingPointMultilevel,
)
from repro.core.error_feedback import EF21
from repro.core.mlmc import mlmc_estimate
from repro.core.qsgd import QSGD
from repro.core.randk import RandK
from repro.core.rtn import RTNCompressor, RTNMultilevel
from repro.core.topk import STopKMultilevel, TopK
from repro.core.types import (
    Array,
    CommState,
    PRNGKey,
    adaptive_comm_state,
    empty_comm_state,
)


class AggregateOut(NamedTuple):
    direction: Array     # (d,) server-side update direction
    state: CommState     # successor comm state (input state for stateless)
    bits: Array          # total bits transmitted this step (all workers)


def _empty_init(num_workers: int, dim: int) -> CommState:
    del num_workers, dim
    return empty_comm_state()


@dataclasses.dataclass(frozen=True)
class Aggregator:
    name: str
    #: fn(worker_grads (M,d), rng, state) -> AggregateOut
    fn: Callable[[Array, PRNGKey, CommState | None], AggregateOut]
    #: init(num_workers, dim) -> CommState (empty for stateless families)
    init: Callable[[int, int], CommState] = _empty_init
    #: True when the state actually evolves (EF21*, mlmc_adaptive_*)
    stateful: bool = False

    def step(self, state: CommState, worker_grads: Array,
             rng: PRNGKey) -> AggregateOut:
        """The unified protocol entry point: state in, AggregateOut out."""
        return self.fn(worker_grads, rng, state)

    def __call__(self, worker_grads: Array, rng: PRNGKey,
                 state: CommState | None = None) -> AggregateOut:
        """``state=None`` is single-shot convenience: stateful families
        substitute a fresh ``init``-state; real training threads the
        returned state."""
        return self.fn(worker_grads, rng, state)


def mlmc_topk_segment(name: str, k: int, s: int) -> int:
    """Segment length of the MLMC (s-)Top-k family for a registry name —
    shared with `repro.comm.codec.make_codec` so the packed wire always
    encodes exactly the segment the abstract aggregator computes.

    For MLMC-Top-k the natural segment is the sparsification budget k
    itself: each residual carries one length-k rank segment, matching the
    paper's per-step budget of "k entries"."""
    if name in ("mlmc_stopk", "mlmc_adaptive_stopk"):
        return s
    return s if s > 1 else max(1, k)


def _per_worker(fn):
    """Lift fn(v, key) -> (vec, bits) over the worker axis and average.
    Stateless: the input CommState passes through unchanged."""

    def agg(worker_grads: Array, rng: PRNGKey, state) -> AggregateOut:
        if state is None:
            state = empty_comm_state()
        m = worker_grads.shape[0]
        keys = jax.random.split(rng, m)
        outs, bits = jax.vmap(fn)(worker_grads, keys)
        return AggregateOut(jnp.mean(outs, axis=0), state, jnp.sum(bits))

    return agg


def _stateless_fn(name: str, dim: int, *, k_fraction: float = 0.01,
                  s: int = 1, rtn_level: int = 4, qsgd_levels: int = 2,
                  fixed_levels: int = 24):
    """The per-worker kernel ``f(v, key) -> (estimate, bits)`` of one
    STATELESS registry family, or None for the stateful families.

    This is the single source of truth shared by the plain abstract
    aggregator (`_per_worker` lifts it over the worker axis) and the
    per-segment policy aggregator (which vmaps it per segment with the
    segment-folded keys) — so a policy segment's math is definitionally
    identical to a standalone flat aggregator of the segment's size."""
    k = max(1, int(round(k_fraction * dim)))

    if name == "dense":
        def f(v, key):
            del key
            return v, jnp.asarray(bitcost.dense_bits(dim), jnp.float32)
        return f

    if name == "topk":  # biased, no correction (may diverge — paper §2.2)
        comp = TopK(k)
        def f(v, key):
            del key
            return comp.compress(v), jnp.asarray(comp.bits(dim), jnp.float32)
        return f

    if name == "randk":
        comp = RandK(k)
        def f(v, key):
            return comp.compress(v, rng=key), jnp.asarray(comp.bits(dim),
                                                          jnp.float32)
        return f

    if name == "qsgd":
        comp = QSGD(qsgd_levels)
        def f(v, key):
            return comp.compress(v, rng=key), jnp.asarray(comp.bits(dim),
                                                          jnp.float32)
        return f

    if name == "rtn":
        comp = RTNCompressor(rtn_level)
        def f(v, key):
            del key
            return comp.compress(v), jnp.asarray(comp.bits(dim), jnp.float32)
        return f

    if name == "fixed2":  # biased 2-bit fixed-point quantization (Fig. 3)
        comp = FixedPointCompressor(2)
        def f(v, key):
            del key
            return comp.compress(v), jnp.asarray(comp.bits(dim), jnp.float32)
        return f

    if name in ("mlmc_topk", "mlmc_stopk", "mlmc_topk_static"):
        comp = STopKMultilevel(d=dim, s=mlmc_topk_segment(name, k, s))
        adaptive = name != "mlmc_topk_static"
        def f(v, key):
            est = mlmc_estimate(comp, v, key, adaptive=adaptive)
            return est.estimate, jnp.asarray(
                bitcost.topk_mlmc_bits(dim, comp.s), jnp.float32)
        return f

    if name == "mlmc_fixed":
        comp = FixedPointMultilevel(num_bits=fixed_levels)
        def f(v, key):
            est = mlmc_estimate(comp, v, key, adaptive=False)  # Lemma 3.3 p
            return est.estimate, jnp.asarray(
                bitcost.fixed_point_mlmc_bits(dim, comp.num_levels),
                jnp.float32)
        return f

    if name == "mlmc_float":
        comp = FloatingPointMultilevel()
        def f(v, key):
            est = mlmc_estimate(comp, v, key, adaptive=False)  # Lemma B.1 p
            return est.estimate, jnp.asarray(
                bitcost.floating_point_mlmc_bits(dim, comp.num_levels),
                jnp.float32)
        return f

    if name == "mlmc_rtn":
        comp = RTNMultilevel()
        def f(v, key):
            est = mlmc_estimate(comp, v, key, adaptive=True)   # Alg. 3
            # honest per-draw wire cost ~(l+2) bits/entry, not the former
            # 2d fixed-point analogy (see bits.rtn_mlmc_bits)
            return est.estimate, jnp.asarray(
                bitcost.rtn_mlmc_bits(dim, est.level, comp.num_levels),
                jnp.float32)
        return f

    if name == "natural":
        from repro.core.natural import NaturalCompression

        comp = NaturalCompression()
        def f(v, key):
            return comp.compress(v, rng=key), jnp.asarray(comp.bits(dim),
                                                          jnp.float32)
        return f

    if name == "signsgd":  # biased, no correction (paper §1.1 baseline)
        from repro.core.natural import SignSGD

        comp = SignSGD()
        def f(v, key):
            del key
            return comp.compress(v), jnp.asarray(comp.bits(dim), jnp.float32)
        return f

    if name in STATEFUL_AGGREGATORS:
        return None
    raise ValueError(f"unknown aggregator {name!r}")


#: which registry families consume each CODEC-SPECIFIC kwarg.  Passing one
#: of these explicitly to a family that ignores it raises TypeError (the
#: same swallowed-kwargs class as the `make_transport` fix): a run
#: configured with e.g. ``qsgd_levels=8`` under ``rtn`` would otherwise
#: silently benchmark the default.  ``k_fraction`` and ``s`` are the
#: universal budget knobs (harmlessly ignored by the non-sparsifying
#: families, passed blanket-style by every cross-codec battery) and stay
#: lenient by design.
CODEC_KW_USERS = {
    "rtn_level": ("rtn",),
    "qsgd_levels": ("qsgd",),
    "fixed_levels": ("mlmc_fixed",),
    "momentum_beta": ("ef21_sgdm",),
    "ema_rho": ("mlmc_adaptive_topk", "mlmc_adaptive_stopk",
                "mlmc_adaptive_rtn"),
}

#: defaults for the checked kwargs (make_aggregator's signature carries
#: None sentinels so explicit-vs-default is detectable)
_CODEC_KW_DEFAULTS = {
    "rtn_level": 4, "qsgd_levels": 2, "fixed_levels": 24,
    "momentum_beta": 0.1, "ema_rho": 0.25,
}


def filter_codec_kw(kw: dict, *names: str) -> dict:
    """Drop the codec-specific entries of ``kw`` that none of ``names``
    consume (None values are dropped too) — callers that configure one
    kwarg set for heterogeneous codec names (the Trainer, benches) use
    this to stay on the right side of the explicit-kwargs check."""
    used = set()
    for n in names:
        if n is None:
            continue
        used.update(key for key, users in CODEC_KW_USERS.items()
                    if n in users)
    return {key: v for key, v in kw.items() if v is not None and
            (key not in CODEC_KW_USERS or key in used)}


def _check_codec_kw(explicit: dict, names) -> None:
    consumers = [n for n in names if n]
    offending = sorted(
        key for key, v in explicit.items()
        if v is not None and not any(n in CODEC_KW_USERS[key]
                                     for n in consumers))
    if offending:
        raise TypeError(
            f"make_aggregator got codec-specific keyword arguments "
            f"{offending} that none of {sorted(set(consumers))} consume "
            f"(see CODEC_KW_USERS); they would be silently ignored")


def _adaptive_mlmc_aggregator(name: str, dim: int, comp, book,
                              ema_rho: float) -> Aggregator:
    """The stateful Alg.-3 family: per-worker EMA residual-norm ladders in
    `CommState.ladder_ema`, Lemma-3.4 level sampling from the updated EMA.

    The identical jnp update (`ladder_ema_update` + `probs_from_ladder`)
    runs on every wire, so the sampled levels — and hence the directions —
    agree across substrates."""
    L = comp.num_levels

    def init(num_workers: int, d: int) -> CommState:
        del d
        return adaptive_comm_state(num_workers, L)

    def agg(worker_grads: Array, rng: PRNGKey, state) -> AggregateOut:
        m = worker_grads.shape[0]
        if state is None:
            state = init(m, dim)
        keys = jax.random.split(rng, m)
        deltas = jax.vmap(comp.residual_norms)(worker_grads)       # (M, L)
        ema = ladder_ema_update(state.ladder_ema, deltas, ema_rho, state.step)
        probs = probs_from_ladder(ema)

        def one(v, key, p):
            est = mlmc_estimate(comp, v, key, probs=p)
            return est.estimate, jnp.asarray(book(est), jnp.float32)

        outs, bits = jax.vmap(one)(worker_grads, keys, probs)
        new_state = state._replace(step=state.step + 1, ladder_ema=ema)
        return AggregateOut(jnp.mean(outs, axis=0), new_state, jnp.sum(bits))

    return Aggregator(name, agg, init=init, stateful=True)


def make_aggregator(
    name: str,
    dim: int,
    *,
    k_fraction: float = 0.01,
    s: int = 1,
    rtn_level: int | None = None,
    qsgd_levels: int | None = None,
    momentum_beta: float | None = None,
    fixed_levels: int | None = None,
    ema_rho: float | None = None,
    wire: str = "abstract",
    transport=None,
    compiled: bool | None = None,
    downlink: str | None = None,
    downlink_alpha: float = 0.5,
    bucket_size: int | None = None,
    policy=None,
) -> Aggregator:
    """Build an aggregator for gradients of flat dimension ``dim``.

    ``wire`` selects the aggregation substrate:

    * ``"abstract"`` (default) — dense in-memory estimates, jit/vmap-able,
      bits *accounted* from `repro.core.bits` formulas.
    * ``"packed"`` — every worker estimate is encoded to a byte-exact
      `repro.comm` packet, shipped through ``transport`` (loopback unless
      given), decoded server-side; bits are *measured* from the packets.
      Host-side Python — for verification and honest telemetry.
    * ``"device"`` — every worker estimate is bit-packed into a fixed-shape
      `repro.comm.device_wire.DevicePacket` and decoded back, entirely
      inside jit (no host callbacks); bits are the measured static packet
      operand sizes.  Supported for the fixed-shape families
      (`DEVICE_WIRE_METHODS`), now including the stateful EF21 variants
      and `mlmc_adaptive_topk`; see device_wire for the two documented
      deviations (bf16 mlmc_topk values, grid-value mlmc_fixed).

    ``ema_rho`` is the ladder-EMA momentum of the stateful
    ``mlmc_adaptive_*`` family (1.0 = per-sample Lemma 3.4).

    ``downlink`` (packed & device wires) names a second codec for the
    server→worker direction: rank 0 encodes ``direction - shift`` against
    a DIANA-style server shift mirrored by every rank (``CommState.shift``,
    updated by ``shift += downlink_alpha * delta_hat``), so the downlink
    payload is compressed instead of raw f32.  ``None`` (default) keeps
    the uplink-only full broadcast.

    ``bucket_size`` (packed wire, loopback only) carves the flat gradient
    into fixed-shape buckets (`repro.comm.plan.WirePlan`) encoded
    independently — the substrate for the trainer's backward-overlap
    streaming (`repro.train.step.grad_tap`).

    ``compiled`` (packed wire only) selects the jit-compiled codec fast
    path (`repro.comm.compiled`) vs the original eager codecs — None
    (default) picks the measured-faster pipeline per codec and DIRECTION
    (`repro.comm.compiled.default_compiled`: fully eager for the EF21
    family, compiled encode + eager decode for the mlmc_topk family via
    `HybridCodec`, fully compiled otherwise).  Byte-identical packets
    either way; the explicit flag exists for verification and A-B wire
    benchmarks (`benchmarks/bench_wire.py`).

    ``policy`` (any wire) is a per-leaf codec policy — a preset name, a
    ``pattern=codec`` spec string, a rule dict, or a `CodecPolicy` /
    `ResolvedPolicy` (`repro.comm.policy`).  A one-segment policy routes
    onto the plain single-codec path above (``name`` is overridden by the
    policy's codec — bit-for-bit the no-policy wire); a multi-segment
    policy aggregates independent (segment, codec) streams with draw keys
    ``fold_in(worker_key, segment_index)``, identical across the
    abstract/packed/device/tcp substrates.  Policy segments support the
    stateless families (the stateful EF21/adaptive state rows are defined
    over the whole flat gradient — use a one-segment policy for those).

    Explicitly passing a codec-specific kwarg that neither ``name`` nor
    the downlink/policy codecs consume raises TypeError (see
    `CODEC_KW_USERS`); ``filter_codec_kw`` pre-filters heterogeneous
    kwarg sets.
    """
    explicit = dict(rtn_level=rtn_level, qsgd_levels=qsgd_levels,
                    momentum_beta=momentum_beta, fixed_levels=fixed_levels,
                    ema_rho=ema_rho)
    from repro.comm.policy import as_resolved, segment_codec_kw

    resolved = as_resolved(policy, dim)
    policy_codecs = () if resolved is None else resolved.codecs
    _check_codec_kw(explicit, (name, downlink, *policy_codecs)
                    if resolved is None or not resolved.is_uniform
                    else (resolved.segments[0].codec, downlink))
    if resolved is not None and resolved.is_uniform:
        # the degenerate one-segment policy IS the single-codec path: pass
        # the ORIGINAL (possibly-sentinel) kwargs through so the recursive
        # call's explicit-kwargs check sees exactly what the caller wrote,
        # overridden by the segment's rule params
        seg = resolved.segments[0]
        merged = dict(k_fraction=k_fraction, s=s)
        merged.update(explicit)
        merged.update(dict(seg.params))
        return make_aggregator(
            seg.codec, dim, wire=wire, transport=transport,
            compiled=compiled, downlink=downlink,
            downlink_alpha=downlink_alpha, bucket_size=bucket_size,
            **merged)

    rtn_level, qsgd_levels, momentum_beta, fixed_levels, ema_rho = (
        _CODEC_KW_DEFAULTS[key] if explicit[key] is None else explicit[key]
        for key in ("rtn_level", "qsgd_levels", "momentum_beta",
                    "fixed_levels", "ema_rho"))

    if resolved is not None:
        base_kw = dict(k_fraction=k_fraction, s=s, rtn_level=rtn_level,
                       qsgd_levels=qsgd_levels, fixed_levels=fixed_levels)
        if wire == "packed":
            from repro.comm import packed_aggregator

            return packed_aggregator(
                name, dim, transport=transport, compiled=compiled,
                downlink=downlink, downlink_alpha=downlink_alpha,
                bucket_size=bucket_size, policy=resolved, **base_kw)
        if wire == "device":
            from repro.comm.device_wire import policy_device_aggregator

            if bucket_size is not None:
                raise ValueError("bucket_size is a packed-wire option")
            return policy_device_aggregator(
                resolved, dim, downlink=downlink,
                downlink_alpha=downlink_alpha, **base_kw)
        if wire != "abstract":
            raise ValueError(f"unknown wire mode {wire!r}")
        if downlink is not None or bucket_size is not None:
            raise ValueError("downlink/bucket_size require a real wire")
        return _policy_abstract_aggregator(resolved, dim, base_kw)

    if wire == "packed":
        from repro.comm import packed_aggregator

        return packed_aggregator(
            name, dim, transport=transport, k_fraction=k_fraction, s=s,
            rtn_level=rtn_level, qsgd_levels=qsgd_levels,
            momentum_beta=momentum_beta, fixed_levels=fixed_levels,
            ema_rho=ema_rho, compiled=compiled, downlink=downlink,
            downlink_alpha=downlink_alpha, bucket_size=bucket_size)
    if wire == "device":
        from repro.comm.device_wire import device_aggregator

        if transport is not None:
            raise ValueError("wire='device' ships arrays through the mesh, "
                             "not a host Transport")
        if bucket_size is not None:
            raise ValueError("bucket_size is a packed-wire option; the "
                             "device wire's operands are already fixed-shape")
        return device_aggregator(
            name, dim, k_fraction=k_fraction, s=s, rtn_level=rtn_level,
            qsgd_levels=qsgd_levels, fixed_levels=fixed_levels,
            momentum_beta=momentum_beta, ema_rho=ema_rho,
            downlink=downlink, downlink_alpha=downlink_alpha)
    if wire != "abstract":
        raise ValueError(f"unknown wire mode {wire!r}")
    if downlink is not None or bucket_size is not None:
        raise ValueError("downlink/bucket_size require a real wire "
                         "(wire='packed' or 'device'); the abstract wire "
                         "has no server→worker payload to compress")
    k = max(1, int(round(k_fraction * dim)))

    f = _stateless_fn(name, dim, k_fraction=k_fraction, s=s,
                      rtn_level=rtn_level, qsgd_levels=qsgd_levels,
                      fixed_levels=fixed_levels)
    if f is not None:
        return Aggregator(name, _per_worker(f))

    if name in ("mlmc_adaptive_topk", "mlmc_adaptive_stopk"):
        comp = STopKMultilevel(d=dim, s=mlmc_topk_segment(name, k, s))
        def book(est):
            del est
            return bitcost.topk_mlmc_bits(dim, comp.s)
        return _adaptive_mlmc_aggregator(name, dim, comp, book, ema_rho)

    if name == "mlmc_adaptive_rtn":
        comp = RTNMultilevel()
        def book(est):
            return bitcost.rtn_mlmc_bits(dim, est.level, comp.num_levels)
        return _adaptive_mlmc_aggregator(name, dim, comp, book, ema_rho)

    if name in ("ef21", "ef21_sgdm", "signsgd_ef"):
        if name == "signsgd_ef":   # sign compression + EF21 correction
            from repro.core.natural import SignSGD

            ef = EF21(SignSGD(), beta=1.0)   # SignSGD.bits is already honest
        else:
            beta = 1.0 if name == "ef21" else momentum_beta
            ef = EF21(TopK(k), beta=beta,
                      bits_fn=lambda d: bitcost.ef21_bits(d, k))

        def agg(worker_grads: Array, rng: PRNGKey, state) -> AggregateOut:
            del rng  # the EF21 compressors (Top-k / sign) are deterministic
            if state is None:
                state = ef.init(worker_grads.shape[0], dim)
            direction, new_state, nbits = ef.step(state, worker_grads)
            return AggregateOut(direction, new_state, nbits)
        return Aggregator(name, agg, init=ef.init, stateful=True)

    raise ValueError(f"unknown aggregator {name!r}")


def _policy_abstract_aggregator(resolved, dim: int, base_kw: dict) -> Aggregator:
    """The abstract-wire realization of a multi-segment policy: the
    per-leaf reference every real wire must match bitwise.

    Per segment ``b``, every worker's slice is compressed by the segment's
    `_stateless_fn` kernel under the draw key ``fold_in(worker_key, b)``
    (`WirePlan.bucket_key` — the identical derivation the packed, device,
    and tcp substrates replay), means are concatenated, bits summed.
    Fully jit/vmap-able; stateless (per-segment-unbiased families stay
    unbiased for the concatenation, per the bucket-plan argument)."""
    from repro.comm.policy import segment_codec_kw

    fns = []
    for seg in resolved.segments:
        f = _stateless_fn(seg.codec, seg.size,
                          **segment_codec_kw(base_kw, seg, dim))
        if f is None:
            raise ValueError(
                f"policy segment {seg.name!r}: the stateful family "
                f"{seg.codec!r} is not supported per-segment — its state "
                "rows are defined over the whole flat gradient (use a "
                "one-segment policy)")
        fns.append(f)

    def agg(worker_grads: Array, rng: PRNGKey, state) -> AggregateOut:
        if state is None:
            state = empty_comm_state()
        m = worker_grads.shape[0]
        keys = jax.random.split(rng, m)
        parts, total = [], jnp.float32(0.0)
        for b, seg in enumerate(resolved.segments):
            bkeys = jax.vmap(lambda kk, _b=b: jax.random.fold_in(kk, _b))(keys)
            outs, bb = jax.vmap(fns[b])(
                worker_grads[:, seg.start:seg.stop], bkeys)
            parts.append(jnp.mean(outs, axis=0))
            total = total + jnp.sum(bb)
        return AggregateOut(jnp.concatenate(parts), state, total)

    return Aggregator("policy", agg)


#: append-only (golden-packet fixture keys fold in the registry position)
ALL_AGGREGATORS = (
    "dense", "topk", "randk", "qsgd", "rtn", "fixed2",
    "mlmc_topk", "mlmc_topk_static", "mlmc_stopk", "mlmc_fixed",
    "mlmc_float", "mlmc_rtn", "ef21", "ef21_sgdm",
    "natural", "signsgd", "signsgd_ef",
    "mlmc_adaptive_topk", "mlmc_adaptive_stopk", "mlmc_adaptive_rtn",
)

#: the families whose CommState actually evolves step over step
STATEFUL_AGGREGATORS = ("ef21", "ef21_sgdm", "signsgd_ef",
                        "mlmc_adaptive_topk", "mlmc_adaptive_stopk",
                        "mlmc_adaptive_rtn")
