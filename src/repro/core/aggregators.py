"""Gradient aggregation strategies — the distributed-learning surface of the
paper's Algorithms 1–3 and of the baselines it compares against.

An aggregator consumes *stacked per-worker gradients* ``(M, d)`` plus a
first-class `repro.core.types.CommState` and produces the server-side update
direction, the successor state, and the transmitted-bit count.  This single
abstraction backs:

* the in-process M-worker simulation used by CPU benchmarks/examples
  (mathematically identical to M machines — the paper's Figs. 1–6), and
* the per-data-shard path inside `shard_map` (`repro.sharding.collectives`
  realizes the same estimators with actual mesh collectives).

The unified protocol every wire substrate implements identically:

    agg.init(num_workers, dim) -> CommState      # empty for stateless
    agg.step(state, worker_grads, rng) -> AggregateOut(direction, state, bits)

Stateless families return their input state unchanged (or a fresh empty one
when called with ``state=None``); the stateful families — EF21 / EF21-SGDM
(worker innovation mirrors) and the adaptive MLMC `mlmc_adaptive_*` family
(EMA of Lemma-3.4 residual-norm ladders) — thread real state step over step
on the abstract, packed, device, and tcp wires alike.

Registry keys (``make_aggregator``):
  dense | topk | randk | qsgd | rtn | fixed2 |
  mlmc_topk | mlmc_topk_static | mlmc_stopk | mlmc_fixed | mlmc_float |
  mlmc_rtn | mlmc_adaptive_topk | mlmc_adaptive_stopk | mlmc_adaptive_rtn |
  ef21 | ef21_sgdm | natural | signsgd | signsgd_ef
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bits as bitcost
from repro.core.adaptive import ladder_ema_update, probs_from_ladder
from repro.core.bitwise import (
    FixedPointCompressor,
    FixedPointMultilevel,
    FloatingPointMultilevel,
)
from repro.core.error_feedback import EF21
from repro.core.mlmc import mlmc_estimate
from repro.core.qsgd import QSGD
from repro.core.randk import RandK
from repro.core.rtn import RTNCompressor, RTNMultilevel
from repro.core.topk import STopKMultilevel, TopK
from repro.core.types import (
    Array,
    CommState,
    PRNGKey,
    adaptive_comm_state,
    empty_comm_state,
)


class AggregateOut(NamedTuple):
    direction: Array     # (d,) server-side update direction
    state: CommState     # successor comm state (input state for stateless)
    bits: Array          # total bits transmitted this step (all workers)


def _empty_init(num_workers: int, dim: int) -> CommState:
    del num_workers, dim
    return empty_comm_state()


@dataclasses.dataclass(frozen=True)
class Aggregator:
    name: str
    #: fn(worker_grads (M,d), rng, state) -> AggregateOut
    fn: Callable[[Array, PRNGKey, CommState | None], AggregateOut]
    #: init(num_workers, dim) -> CommState (empty for stateless families)
    init: Callable[[int, int], CommState] = _empty_init
    #: True when the state actually evolves (EF21*, mlmc_adaptive_*)
    stateful: bool = False

    def step(self, state: CommState, worker_grads: Array,
             rng: PRNGKey) -> AggregateOut:
        """The unified protocol entry point: state in, AggregateOut out."""
        return self.fn(worker_grads, rng, state)

    def __call__(self, worker_grads: Array, rng: PRNGKey,
                 state: CommState | None = None) -> AggregateOut:
        """``state=None`` is single-shot convenience: stateful families
        substitute a fresh ``init``-state; real training threads the
        returned state."""
        return self.fn(worker_grads, rng, state)


def mlmc_topk_segment(name: str, k: int, s: int) -> int:
    """Segment length of the MLMC (s-)Top-k family for a registry name —
    shared with `repro.comm.codec.make_codec` so the packed wire always
    encodes exactly the segment the abstract aggregator computes.

    For MLMC-Top-k the natural segment is the sparsification budget k
    itself: each residual carries one length-k rank segment, matching the
    paper's per-step budget of "k entries"."""
    if name in ("mlmc_stopk", "mlmc_adaptive_stopk"):
        return s
    return s if s > 1 else max(1, k)


def _per_worker(fn):
    """Lift fn(v, key) -> (vec, bits) over the worker axis and average.
    Stateless: the input CommState passes through unchanged."""

    def agg(worker_grads: Array, rng: PRNGKey, state) -> AggregateOut:
        if state is None:
            state = empty_comm_state()
        m = worker_grads.shape[0]
        keys = jax.random.split(rng, m)
        outs, bits = jax.vmap(fn)(worker_grads, keys)
        return AggregateOut(jnp.mean(outs, axis=0), state, jnp.sum(bits))

    return agg


def _adaptive_mlmc_aggregator(name: str, dim: int, comp, book,
                              ema_rho: float) -> Aggregator:
    """The stateful Alg.-3 family: per-worker EMA residual-norm ladders in
    `CommState.ladder_ema`, Lemma-3.4 level sampling from the updated EMA.

    The identical jnp update (`ladder_ema_update` + `probs_from_ladder`)
    runs on every wire, so the sampled levels — and hence the directions —
    agree across substrates."""
    L = comp.num_levels

    def init(num_workers: int, d: int) -> CommState:
        del d
        return adaptive_comm_state(num_workers, L)

    def agg(worker_grads: Array, rng: PRNGKey, state) -> AggregateOut:
        m = worker_grads.shape[0]
        if state is None:
            state = init(m, dim)
        keys = jax.random.split(rng, m)
        deltas = jax.vmap(comp.residual_norms)(worker_grads)       # (M, L)
        ema = ladder_ema_update(state.ladder_ema, deltas, ema_rho, state.step)
        probs = probs_from_ladder(ema)

        def one(v, key, p):
            est = mlmc_estimate(comp, v, key, probs=p)
            return est.estimate, jnp.asarray(book(est), jnp.float32)

        outs, bits = jax.vmap(one)(worker_grads, keys, probs)
        new_state = state._replace(step=state.step + 1, ladder_ema=ema)
        return AggregateOut(jnp.mean(outs, axis=0), new_state, jnp.sum(bits))

    return Aggregator(name, agg, init=init, stateful=True)


def make_aggregator(
    name: str,
    dim: int,
    *,
    k_fraction: float = 0.01,
    s: int = 1,
    rtn_level: int = 4,
    qsgd_levels: int = 2,
    momentum_beta: float = 0.1,
    fixed_levels: int = 24,
    ema_rho: float = 0.25,
    wire: str = "abstract",
    transport=None,
    compiled: bool | None = None,
    downlink: str | None = None,
    downlink_alpha: float = 0.5,
    bucket_size: int | None = None,
) -> Aggregator:
    """Build an aggregator for gradients of flat dimension ``dim``.

    ``wire`` selects the aggregation substrate:

    * ``"abstract"`` (default) — dense in-memory estimates, jit/vmap-able,
      bits *accounted* from `repro.core.bits` formulas.
    * ``"packed"`` — every worker estimate is encoded to a byte-exact
      `repro.comm` packet, shipped through ``transport`` (loopback unless
      given), decoded server-side; bits are *measured* from the packets.
      Host-side Python — for verification and honest telemetry.
    * ``"device"`` — every worker estimate is bit-packed into a fixed-shape
      `repro.comm.device_wire.DevicePacket` and decoded back, entirely
      inside jit (no host callbacks); bits are the measured static packet
      operand sizes.  Supported for the fixed-shape families
      (`DEVICE_WIRE_METHODS`), now including the stateful EF21 variants
      and `mlmc_adaptive_topk`; see device_wire for the two documented
      deviations (bf16 mlmc_topk values, grid-value mlmc_fixed).

    ``ema_rho`` is the ladder-EMA momentum of the stateful
    ``mlmc_adaptive_*`` family (1.0 = per-sample Lemma 3.4).

    ``downlink`` (packed & device wires) names a second codec for the
    server→worker direction: rank 0 encodes ``direction - shift`` against
    a DIANA-style server shift mirrored by every rank (``CommState.shift``,
    updated by ``shift += downlink_alpha * delta_hat``), so the downlink
    payload is compressed instead of raw f32.  ``None`` (default) keeps
    the uplink-only full broadcast.

    ``bucket_size`` (packed wire, loopback only) carves the flat gradient
    into fixed-shape buckets (`repro.comm.plan.WirePlan`) encoded
    independently — the substrate for the trainer's backward-overlap
    streaming (`repro.train.step.grad_tap`).

    ``compiled`` (packed wire only) selects the jit-compiled codec fast
    path (`repro.comm.compiled`) vs the original eager codecs — None
    (default) picks the measured-faster pipeline per codec
    (`repro.comm.compiled.default_compiled`: compiled for everything but
    the EF21 family).  Byte-identical packets either way; the explicit
    flag exists for verification and A-B wire benchmarks
    (`benchmarks/bench_wire.py`).
    """
    if wire == "packed":
        from repro.comm import packed_aggregator

        return packed_aggregator(
            name, dim, transport=transport, k_fraction=k_fraction, s=s,
            rtn_level=rtn_level, qsgd_levels=qsgd_levels,
            momentum_beta=momentum_beta, fixed_levels=fixed_levels,
            ema_rho=ema_rho, compiled=compiled, downlink=downlink,
            downlink_alpha=downlink_alpha, bucket_size=bucket_size)
    if wire == "device":
        from repro.comm.device_wire import device_aggregator

        if transport is not None:
            raise ValueError("wire='device' ships arrays through the mesh, "
                             "not a host Transport")
        if bucket_size is not None:
            raise ValueError("bucket_size is a packed-wire option; the "
                             "device wire's operands are already fixed-shape")
        return device_aggregator(
            name, dim, k_fraction=k_fraction, s=s, rtn_level=rtn_level,
            qsgd_levels=qsgd_levels, fixed_levels=fixed_levels,
            momentum_beta=momentum_beta, ema_rho=ema_rho,
            downlink=downlink, downlink_alpha=downlink_alpha)
    if wire != "abstract":
        raise ValueError(f"unknown wire mode {wire!r}")
    if downlink is not None or bucket_size is not None:
        raise ValueError("downlink/bucket_size require a real wire "
                         "(wire='packed' or 'device'); the abstract wire "
                         "has no server→worker payload to compress")
    k = max(1, int(round(k_fraction * dim)))

    if name == "dense":
        def f(v, key):
            del key
            return v, jnp.asarray(bitcost.dense_bits(dim), jnp.float32)
        return Aggregator(name, _per_worker(f))

    if name == "topk":  # biased, no correction (may diverge — paper §2.2)
        comp = TopK(k)
        def f(v, key):
            del key
            return comp.compress(v), jnp.asarray(comp.bits(dim), jnp.float32)
        return Aggregator(name, _per_worker(f))

    if name == "randk":
        comp = RandK(k)
        def f(v, key):
            return comp.compress(v, rng=key), jnp.asarray(comp.bits(dim), jnp.float32)
        return Aggregator(name, _per_worker(f))

    if name == "qsgd":
        comp = QSGD(qsgd_levels)
        def f(v, key):
            return comp.compress(v, rng=key), jnp.asarray(comp.bits(dim), jnp.float32)
        return Aggregator(name, _per_worker(f))

    if name == "rtn":
        comp = RTNCompressor(rtn_level)
        def f(v, key):
            del key
            return comp.compress(v), jnp.asarray(comp.bits(dim), jnp.float32)
        return Aggregator(name, _per_worker(f))

    if name == "fixed2":  # biased 2-bit fixed-point quantization (Fig. 3)
        comp = FixedPointCompressor(2)
        def f(v, key):
            del key
            return comp.compress(v), jnp.asarray(comp.bits(dim), jnp.float32)
        return Aggregator(name, _per_worker(f))

    if name in ("mlmc_topk", "mlmc_stopk", "mlmc_topk_static"):
        comp = STopKMultilevel(d=dim, s=mlmc_topk_segment(name, k, s))
        adaptive = name != "mlmc_topk_static"
        def f(v, key):
            est = mlmc_estimate(comp, v, key, adaptive=adaptive)
            return est.estimate, jnp.asarray(
                bitcost.topk_mlmc_bits(dim, comp.s), jnp.float32)
        return Aggregator(name, _per_worker(f))

    if name == "mlmc_fixed":
        comp = FixedPointMultilevel(num_bits=fixed_levels)
        def f(v, key):
            est = mlmc_estimate(comp, v, key, adaptive=False)  # Lemma 3.3 p
            return est.estimate, jnp.asarray(
                bitcost.fixed_point_mlmc_bits(dim, comp.num_levels), jnp.float32)
        return Aggregator(name, _per_worker(f))

    if name == "mlmc_float":
        comp = FloatingPointMultilevel()
        def f(v, key):
            est = mlmc_estimate(comp, v, key, adaptive=False)  # Lemma B.1 p
            return est.estimate, jnp.asarray(
                bitcost.floating_point_mlmc_bits(dim, comp.num_levels), jnp.float32)
        return Aggregator(name, _per_worker(f))

    if name == "mlmc_rtn":
        comp = RTNMultilevel()
        def f(v, key):
            est = mlmc_estimate(comp, v, key, adaptive=True)   # Alg. 3
            # honest per-draw wire cost ~(l+2) bits/entry, not the former
            # 2d fixed-point analogy (see bits.rtn_mlmc_bits)
            return est.estimate, jnp.asarray(
                bitcost.rtn_mlmc_bits(dim, est.level, comp.num_levels),
                jnp.float32)
        return Aggregator(name, _per_worker(f))

    if name in ("mlmc_adaptive_topk", "mlmc_adaptive_stopk"):
        comp = STopKMultilevel(d=dim, s=mlmc_topk_segment(name, k, s))
        def book(est):
            del est
            return bitcost.topk_mlmc_bits(dim, comp.s)
        return _adaptive_mlmc_aggregator(name, dim, comp, book, ema_rho)

    if name == "mlmc_adaptive_rtn":
        comp = RTNMultilevel()
        def book(est):
            return bitcost.rtn_mlmc_bits(dim, est.level, comp.num_levels)
        return _adaptive_mlmc_aggregator(name, dim, comp, book, ema_rho)

    if name == "natural":
        from repro.core.natural import NaturalCompression

        comp = NaturalCompression()
        def f(v, key):
            return comp.compress(v, rng=key), jnp.asarray(comp.bits(dim),
                                                          jnp.float32)
        return Aggregator(name, _per_worker(f))

    if name == "signsgd":  # biased, no correction (paper §1.1 baseline)
        from repro.core.natural import SignSGD

        comp = SignSGD()
        def f(v, key):
            del key
            return comp.compress(v), jnp.asarray(comp.bits(dim), jnp.float32)
        return Aggregator(name, _per_worker(f))

    if name in ("ef21", "ef21_sgdm", "signsgd_ef"):
        if name == "signsgd_ef":   # sign compression + EF21 correction
            from repro.core.natural import SignSGD

            ef = EF21(SignSGD(), beta=1.0)   # SignSGD.bits is already honest
        else:
            beta = 1.0 if name == "ef21" else momentum_beta
            ef = EF21(TopK(k), beta=beta,
                      bits_fn=lambda d: bitcost.ef21_bits(d, k))

        def agg(worker_grads: Array, rng: PRNGKey, state) -> AggregateOut:
            del rng  # the EF21 compressors (Top-k / sign) are deterministic
            if state is None:
                state = ef.init(worker_grads.shape[0], dim)
            direction, new_state, nbits = ef.step(state, worker_grads)
            return AggregateOut(direction, new_state, nbits)
        return Aggregator(name, agg, init=ef.init, stateful=True)

    raise ValueError(f"unknown aggregator {name!r}")


#: append-only (golden-packet fixture keys fold in the registry position)
ALL_AGGREGATORS = (
    "dense", "topk", "randk", "qsgd", "rtn", "fixed2",
    "mlmc_topk", "mlmc_topk_static", "mlmc_stopk", "mlmc_fixed",
    "mlmc_float", "mlmc_rtn", "ef21", "ef21_sgdm",
    "natural", "signsgd", "signsgd_ef",
    "mlmc_adaptive_topk", "mlmc_adaptive_stopk", "mlmc_adaptive_rtn",
)

#: the families whose CommState actually evolves step over step
STATEFUL_AGGREGATORS = ("ef21", "ef21_sgdm", "signsgd_ef",
                        "mlmc_adaptive_topk", "mlmc_adaptive_stopk",
                        "mlmc_adaptive_rtn")
