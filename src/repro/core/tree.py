"""Pytree plumbing: apply flat-vector compressors to gradient pytrees.

Two modes, mirroring real deployments:

* ``concat``  — ravel the whole gradient pytree into one flat vector (the
  paper's model: the gradient IS one d-dimensional vector).  Best statistical
  behaviour for rank-based compressors (global top-k across layers).
* ``per_leaf`` — compress each tensor independently (how per-tensor fusion
  buckets behave in production all-reduce stacks).  Each leaf gets its own
  level draw, scale header and compressor family sized to its length.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core.types import Array, PRNGKey

PyTree = Any


def tree_ravel(tree: PyTree) -> tuple[Array, Callable[[Array], PyTree]]:
    flat, unravel = ravel_pytree(tree)
    return flat.astype(jnp.float32), unravel


def tree_size(tree: PyTree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def map_flat_leaves(
    fn: Callable[[Array, PRNGKey], tuple[Array, Array]],
    tree: PyTree,
    rng: PRNGKey,
) -> tuple[PyTree, Array]:
    """Apply ``fn(flat_leaf, key) -> (flat_out, bits)`` to every leaf.

    Returns the reassembled pytree and the summed bit cost."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(rng, len(leaves))
    outs, bits = [], jnp.zeros((), jnp.float32)
    for leaf, key in zip(leaves, keys):
        flat = leaf.reshape(-1).astype(jnp.float32)
        out, b = fn(flat, key)
        outs.append(out.reshape(leaf.shape).astype(leaf.dtype))
        bits = bits + b
    return jax.tree_util.tree_unflatten(treedef, outs), bits


def tree_compress_concat(
    fn: Callable[[Array, PRNGKey], tuple[Array, Array]],
    tree: PyTree,
    rng: PRNGKey,
) -> tuple[PyTree, Array]:
    """Ravel the whole pytree, compress once, unravel."""
    flat, unravel = tree_ravel(tree)
    out, bits = fn(flat, rng)
    return unravel(out), bits
