"""Communication-cost accounting (bits on the wire), per the paper's own
formulas, plus the realized-on-TPU byte counts used by the roofline analysis.

The paper counts an idealized point-to-point wire format:

* uncompressed fp64 vector: ``64 d``                                  (§3.1)
* fixed-point MLMC:  ``2 d + 64 + ceil(log2(63))``                    (§3.1)
* floating-point MLMC: ``13 d + log2(52)``                            (App. B)
* Top-k MLMC residual: one entry;  s-Top-k: one length-s segment      (§3.2)

On a TPU mesh there is no parameter server; "worker→server" traffic becomes
the per-chip bytes of the gradient collective.  `realized_*` helpers mirror
what `repro.sharding.collectives` actually lowers to, and are cross-checked
against the HLO parse in `repro.launch.roofline`.
"""

from __future__ import annotations

import math


def dense_bits(d: int, word_bits: int = 32) -> float:
    """Alg. 1 baseline: one uncompressed gradient."""
    return float(word_bits) * d


def fixed_point_mlmc_bits(d: int, num_levels: int = 63, header_bits: int = 64) -> float:
    """§3.1: 2 bits/entry + max-entry header + level index."""
    return 2.0 * d + header_bits + math.ceil(math.log2(num_levels))


def floating_point_mlmc_bits(d: int, num_levels: int = 52) -> float:
    """App. B: 13 bits/entry (sign + 11-bit exponent + 1 mantissa bit)."""
    return 13.0 * d + math.log2(num_levels)


def topk_mlmc_bits(d: int, s: int = 1, value_bits: int = 32,
                   index_bits: int | None = None) -> float:
    """§3.2: one length-s segment — s values + s positions + level index.

    The paper counts "s numbers"; we additionally account the positions
    (``index_bits`` defaults to ceil(log2 d)) to keep the ledger honest."""
    if index_bits is None:
        index_bits = math.ceil(math.log2(max(d, 2)))
    num_levels = math.ceil(d / s)
    return s * (value_bits + index_bits) + math.ceil(math.log2(num_levels))


def rtn_mlmc_bits(d: int, level, num_levels: int = 8,
                  header_bits: int = 64, corr_bits=None):
    """Honest adaptive MLMC-RTN wire cost for a SAMPLED level (App. G.2).

    The RTN residual ``C^l - C^{l-1}`` has no sparse/bit-plane closed form
    (§3.2: no importance-sampling interpretation), so the wire ships the
    level-l grid codes (``max(l, 1)`` bits/entry) plus, for ``l > 1``, a
    {-1,0,+1} refinement correction; the top level (``C^L = id``) ships
    the dense f32 residual.  This replaces the former 2d
    fixed-point-analogy entry, which was optimistic for every ``l > 1`` —
    the deviation `repro.comm.codec.MLMCRTNCodec` measured.

    ``corr_bits`` books the correction stream: ``None`` charges the flat
    2-bit plane (the closed-form upper bound the abstract aggregator uses
    and the `mlmc_adaptive_rtn` wire still ships); a number books the
    MEASURED Elias-gamma stream of the entropy-coded ``mlmc_rtn`` wire
    (`repro.comm.codec.gamma_signed_encode`, <= 2d by construction) —
    only valid for a concrete ``level``.

    ``level`` may be a traced jnp scalar (the adaptive Alg. 3 draw) when
    ``corr_bits`` is None; the result is then a traced f32 scalar.  Wrap
    in ``float()`` for a concrete level."""
    hdr = header_bits + math.ceil(math.log2(max(num_levels, 2)))
    if corr_bits is not None:
        lvl = int(level)
        if lvl >= num_levels:
            return 32.0 * d + hdr
        return float(max(lvl, 1)) * d + \
            (float(corr_bits) if lvl > 1 else 0.0) + hdr
    import jax.numpy as jnp

    lvl = jnp.asarray(level, jnp.float32)
    per_entry = jnp.where(
        lvl >= num_levels, 32.0,
        jnp.maximum(lvl, 1.0) + jnp.where(lvl > 1.0, 2.0, 0.0))
    return per_entry * d + hdr


def rtn_mlmc_expected_bits(d: int, num_levels: int = 8,
                           header_bits: int = 64) -> float:
    """Expectation of :func:`rtn_mlmc_bits` under the family's static
    Lemma-3.3 distribution ``p_l ∝ 2^{-l}`` (the reference point the packet
    reconciliation centres on when no level has been sampled yet)."""
    z = sum(2.0 ** -l for l in range(1, num_levels + 1))
    return sum(
        (2.0 ** -l / z) * float(rtn_mlmc_bits(d, l, num_levels, header_bits))
        for l in range(1, num_levels + 1))


def ef21_bits(d: int, k: int, value_bits: int = 32) -> float:
    """Honest EF21 / EF21-SGDM wire cost for ONE Top-k innovation message:
    k values + k positions at ``ceil(log2 d)`` bits.

    The former accounting (`TopK.bits`) charged 32-bit positions — the
    wire codec (`repro.comm.codec.EF21InnovationCodec`) ships the honest
    ceil(log2 d)-bit positions, and this entry reconciles with it tightly
    (word padding only), the same move PR 2 made for `rtn_mlmc_bits`."""
    return float(k) * (value_bits + math.ceil(math.log2(max(d, 2))))


def topk_bits(k: int, d: int, value_bits: int = 32) -> float:
    """Biased Top-k: k values + k indices."""
    return k * (value_bits + math.ceil(math.log2(max(d, 2))))


def randk_bits(k: int, d: int, value_bits: int = 32) -> float:
    return topk_bits(k, d, value_bits)


def qsgd_bits(d: int, s: int = 2) -> float:
    return d * (1 + math.ceil(math.log2(s + 1))) + 32


def rtn_bits(d: int, level: int) -> float:
    return float(level) * d + 32


def compression_ratio(method_bits: float, d: int, word_bits: int = 32) -> float:
    return dense_bits(d, word_bits) / max(method_bits, 1.0)


# --- realized TPU collective payloads (per data-parallel step, per chip) ----


def realized_dense_allreduce_bytes(d: int, dtype_bytes: int = 4) -> float:
    """Ring all-reduce moves ~2x the shard bytes per chip; we report the
    operand size (what the HLO parser counts) for consistency."""
    return float(d) * dtype_bytes


def realized_mlmc_topk_allgather_bytes(k: int, workers: int,
                                       value_bytes: int = 4,
                                       index_bytes: int = 4) -> float:
    """all_gather of (values, indices) of the k-entry residual across M
    workers: each chip contributes k entries and receives M*k."""
    return float(k) * workers * (value_bytes + index_bytes)


def realized_mlmc_fixedpoint_psum_bytes(d: int) -> float:
    """int8 psum of the ternary bit-plane residual: 1 byte/entry operand
    (vs 4 for f32) — exact for <= 127 workers."""
    return float(d)
