"""Core type definitions for the MLMC compression framework.

The paper (Zukerman, Hamoud & Levy, ICML 2025) defines (Def. 3.1) a
*multilevel compressor* as a family ``C^l : R^d -> R^d`` for ``l in [L]``
where the highest level is the identity (``C^L(v) = v``) and, by convention,
``C^0(v) = 0``.  The MLMC estimator (Eq. 6) telescopes over this family:

    g~ = C^0(v) + (1/p_l) (C^l(v) - C^{l-1}(v)),   l ~ p

and is conditionally unbiased (Lemma 3.2) for *any* non-zero level
distribution p.  Everything in :mod:`repro.core` is written against the
interface below so that the MLMC machinery is plug-and-play, exactly as the
paper advertises.

Design notes (JAX):

* All compressor methods are pure functions of ``(v, l)`` and jit-safe with a
  *traced* level ``l`` (levels select bit-planes / rank-ranges, never shapes).
* Compressed values are represented **densely** (same shape as ``v``, zeros
  outside the support).  The *wire format* (what would actually cross the
  interconnect) is accounted separately in :mod:`repro.core.bits` and realised
  by the compressed collectives in :mod:`repro.sharding.collectives`.
* Compressors operate on flat ``float32`` vectors; pytree plumbing lives in
  :mod:`repro.core.tree`.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PRNGKey = jax.Array


def _register_barrier_batching() -> None:
    """`jax.lax.optimization_barrier` has no vmap batching rule in the
    pinned jax (0.4.x); the barrier is operand-wise identity, so batching
    is a pass-through.  Registered once at import (idempotent)."""
    from jax._src.lax import lax as _lax
    from jax.interpreters import batching

    prim = getattr(_lax, "optimization_barrier_p", None)
    if prim is None or prim in batching.primitive_batchers:
        return

    def rule(args, dims, **params):
        outs = prim.bind(*args)
        if not isinstance(outs, (list, tuple)):
            outs = [outs]
        return outs, dims

    batching.primitive_batchers[prim] = rule


_register_barrier_batching()


def opt_barrier(x: Array) -> Array:
    """Identity that XLA may not constant-fold or move computations across.
    Used by the compiled codec pipeline to keep a STATIC level from being
    folded into the grid math (a constant divisor lets XLA rewrite the
    division as a reciprocal multiply, 1 ulp off the eager delta).

    NOTE: this does NOT stop FMA contraction on the CPU backend — a
    multiply feeding an add/subtract still fuses straight through the
    barrier.  Use :func:`pin_rounding` for that."""
    return jax.lax.optimization_barrier(x)


def pin_rounding(x: Array) -> Array:
    """Pin the f32 rounding of ``x`` before it meets an add/subtract.

    XLA CPU contracts ``v - x*y`` into an FMA under jit (keeping the
    product's excess precision), so jitted results drift 1 ulp off the
    eager op-by-op ones — breaking the byte-exact wire contract the codecs
    and golden fixtures rely on.  `opt_barrier`, double bitcasts, and the
    fast-math XLA flags all fail to stop the contraction on the pinned
    jax; a data-dependent select does: contraction cannot reach through a
    ``select``, and ``x == x`` is not foldable.  Value-preserving for every
    input including NaN (the false branch ``x + 1`` is NaN exactly when
    taken)."""
    return jnp.where(x == x, x, x + 1)


class Compressor(abc.ABC):
    """A (possibly biased) single-level compressor ``C : R^d -> R^d``.

    Biased compressors satisfy Eq. (4): ``E||C(v) - v||^2 <= (1-alpha)||v||^2``
    with ``0 < alpha <= 1``.  Unbiased compressors satisfy Eq. (3):
    ``E[C(v)] = v`` and ``E||C(v) - v||^2 <= omega ||v||^2``.
    """

    #: True if ``E[C(v)] = v`` holds by construction.
    unbiased: bool = False

    @abc.abstractmethod
    def compress(self, v: Array, *, rng: PRNGKey | None = None) -> Array:
        """Return the (densely represented) compressed vector."""

    @abc.abstractmethod
    def bits(self, d: int) -> float:
        """Idealized wire cost in bits for one compressed length-``d`` vector."""

    def __call__(self, v: Array, *, rng: PRNGKey | None = None) -> Array:
        return self.compress(v, rng=rng)


class MultilevelCompressor(abc.ABC):
    """A family ``C^0 = 0, C^1, ..., C^L = id`` per Definition 3.1.

    Subclasses must make ``compress``/``residual`` jit-safe in the level
    argument ``l`` (an int32 scalar, possibly traced), because the MLMC
    estimator samples ``l`` at every step.
    """

    @property
    @abc.abstractmethod
    def num_levels(self) -> int:
        """L — number of levels (levels are 1-indexed; level L = identity)."""

    @abc.abstractmethod
    def compress(self, v: Array, l: Array | int) -> Array:
        """``C^l(v)``, densely represented.  ``C^0 = 0`` must hold."""

    @abc.abstractmethod
    def residual(self, v: Array, l: Array | int) -> Array:
        """``C^l(v) - C^{l-1}(v)`` — the MLMC payload.

        Subclasses override with the *efficient* form where one exists
        (single rank-range for (s-)Top-k, single bit-plane for fixed point);
        the contract is checked against ``compress`` in the test-suite.
        """

    @abc.abstractmethod
    def residual_norms(self, v: Array) -> Array:
        """``(L,)`` vector of ``Delta_l = ||C^l(v) - C^{l-1}(v)||``.

        This powers the adaptive level distribution of Lemma 3.4
        (``p_l ∝ Delta_l``).  Implementations must compute all L norms in one
        pass (never L separate compressions).
        """

    @abc.abstractmethod
    def static_probs(self) -> Array:
        """A fixed, input-independent level distribution ``(L,)``.

        For bit-wise compressors this is the Lemma 3.3 / B.1 optimum
        (``p_l ∝ 2^{-l}``); for rank-based compressors it is a sensible
        default (the adaptive Alg. 3 path is preferred there).
        """

    @abc.abstractmethod
    def residual_bits(self, d: int) -> float:
        """Idealized wire cost in bits of ONE residual for a length-d vector
        (excluding the level index / scale header; see :mod:`.bits`)."""

    # --- provided ----------------------------------------------------------

    def base(self, v: Array) -> Array:
        """``C^0(v)`` — the deterministic part transmitted alongside every
        residual.  Zero for most families; the floating-point compressor
        transmits sign+exponent every step (App. B counts them in the 13
        bits/entry), so there ``C^0(v) = sign(v) * 2^{E(v)}``.  The MLMC
        estimator is ``base(v) + residual(v, l) / p_l`` (Eq. 6)."""
        return jnp.zeros_like(v)

    def identity_level(self) -> int:
        return self.num_levels

    def check_identity(self, v: Array) -> Array:
        """``C^L(v)`` — used by tests to assert Def 3.1's top-level identity."""
        return self.compress(v, self.num_levels)


class CommState(NamedTuple):
    """First-class aggregator/compressor state — ONE pytree that every wire
    substrate (abstract / packed / device / tcp) threads through
    ``Aggregator.step`` and the checkpointer persists next to
    params/opt_state.

    A single fixed treedef serves every registry family: stateless
    aggregators carry an *empty* state (zero-sized leaves, no data), EF21 /
    EF21-SGDM populate the worker mirrors, and the adaptive MLMC family
    populates the EMA residual-norm ladders.  Keeping one structure (rather
    than per-family state classes) is what lets the trainer, the mesh step,
    and the checkpointer stay generic over the aggregation method.
    """

    step: Array         # ()     int32 — aggregation rounds taken
    g_workers: Array    # (M, d) EF21 worker-side mirrors g_i;  (0, 0) unused
    g_server: Array     # (d,)   EF21 server aggregate g;       (0,)   unused
    momentum: Array     # (M, d) EF21-SGDM momentum v_i;        (0, 0) unused
    ladder_ema: Array   # (M, L) adaptive-MLMC EMA of residual-norm
    #                            ladders (Lemma 3.4);           (0, 0) unused
    shift: Array        # (d,)   DIANA-style downlink server shift h
    #                            (mirrored by every rank);      (0,)   unused


def empty_comm_state(shift_dim: int = 0) -> CommState:
    """The stateless aggregators' state: same treedef, zero-sized leaves.

    ``shift_dim`` sizes the downlink server-shift mirror: 0 (the default)
    for uplink-only runs, ``d`` when the server→worker direction is itself
    compressed against a DIANA-style shift (see `repro.comm.aggregate`)."""
    z2 = jnp.zeros((0, 0), jnp.float32)
    return CommState(step=jnp.zeros((), jnp.int32), g_workers=z2,
                     g_server=jnp.zeros((0,), jnp.float32), momentum=z2,
                     ladder_ema=z2,
                     shift=jnp.zeros((shift_dim,), jnp.float32))


def ef21_comm_state(num_workers: int, dim: int,
                    shift_dim: int = 0) -> CommState:
    """Zero-innovation EF21 start: g_i = g = v_i = 0 (Richtárik et al.)."""
    z = jnp.zeros((num_workers, dim), jnp.float32)
    return empty_comm_state(shift_dim)._replace(
        g_workers=z, g_server=jnp.zeros((dim,), jnp.float32), momentum=z)


def adaptive_comm_state(num_workers: int, num_levels: int,
                        shift_dim: int = 0) -> CommState:
    """Cold-start adaptive MLMC: the EMA ladder seeds from the first step's
    fresh residual norms (see `repro.core.adaptive.ladder_ema_update`)."""
    return empty_comm_state(shift_dim)._replace(
        ladder_ema=jnp.zeros((num_workers, num_levels), jnp.float32))


@dataclasses.dataclass(frozen=True)
class MLMCEstimate:
    """Result of one MLMC compression of one tensor (see core/mlmc.py)."""

    estimate: Array          # g~ — dense unbiased estimate (Eq. 6)
    level: Array             # sampled l (int32 scalar)
    prob: Array              # p_l of the sampled level (f32 scalar)
    payload_bits: Array      # idealized bits that would cross the wire
    residual: Array          # raw residual C^l - C^{l-1} (dense), pre-scaling


LevelProbFn = Callable[[MultilevelCompressor, Array], Array]


def categorical(rng: PRNGKey, probs: Array) -> Array:
    """Sample an index from a (possibly unnormalized) probability vector."""
    probs = probs / jnp.sum(probs)
    return jax.random.categorical(rng, jnp.log(probs + 1e-30))
