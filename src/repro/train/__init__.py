from repro.train.loop import History, Trainer

__all__ = ["History", "Trainer"]
