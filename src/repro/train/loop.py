"""In-process M-worker trainer — the paper's experimental harness (§5).

Simulates M machines by splitting each global batch into M worker shards and
running the full Alg. 1 / Alg. 2 / Alg. 3 / EF21(-SGDM) pipeline over the
stacked per-worker gradients.  Mathematically identical to M real machines
(the server sees exactly the same aggregate), which is how the CPU container
reproduces Figures 1-6.  The gradient is raveled to ONE flat d-vector per
worker, matching the paper's model of the gradient as a d-dimensional
object.

Aggregator state is a first-class `repro.core.types.CommState`: the trainer
threads ONE pytree through every step on every wire (abstract / packed /
device / tcp) and checkpoints it alongside params and optimizer state
(`save_checkpoint` / `load_checkpoint`) so stateful runs — EF21's innovation
mirrors, the adaptive-MLMC EMA ladders — resume exactly where they stopped.

For the mesh-collective realization of the same algorithms see
`repro.train.step` (used by the dry-run and real-device tests)."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core.aggregators import (
    Aggregator,
    filter_codec_kw,
    make_aggregator,
)
from repro.core.types import CommState
from repro.obs import trace as obs
from repro.optim.optimizers import Optimizer, sgd

PyTree = Any


@dataclasses.dataclass
class History:
    steps: list[int] = dataclasses.field(default_factory=list)
    loss: list[float] = dataclasses.field(default_factory=list)
    bits: list[float] = dataclasses.field(default_factory=list)  # cumulative
    eval_loss: list[float] = dataclasses.field(default_factory=list)


class Trainer:
    """MLMC-compressed distributed SGD over simulated workers.

    Args:
      loss_fn: (params_pytree, batch) -> scalar loss.  The batch's leading
        axis is the per-worker batch (the trainer adds the worker axis).
      params: initial parameter pytree.
      num_workers: M.
      method: aggregator registry key (see repro.core.aggregators).
      optimizer: from repro.optim (default SGD, as in the paper).
      ema_rho: ladder-EMA momentum of the stateful `mlmc_adaptive_*` family.
      wire: aggregation substrate — "abstract" (in-memory estimates),
        "packed" (byte packets through a Transport, encoded/decoded by the
        COMPILED codec pipeline of repro.comm.compiled: byte-identical to
        the eager codecs with the compression math fully jitted), or
        "device" (jit-native fixed-shape packed packets,
        repro.comm.device_wire; the whole step stays jitted like the
        abstract path).
      wire_compiled: packed wire only — None (default) picks the
        measured-faster pipeline per codec AND direction
        (`repro.comm.compiled.default_compiled`; a compiled-encode /
        eager-decode mix ships as a `HybridCodec`); True forces the
        jit-compiled fast path, False the eager codecs (byte-identical
        either way; A-B wire benchmarks).
      downlink: packed/device wires — registry name of a SECOND codec for
        the server→worker direction (DIANA-style shift compression; see
        `repro.comm.aggregate.Downlink`).  None keeps the raw f32
        broadcast.  downlink_alpha is the shift learning rate.
      bucket_size: packed wire — carve the flat gradient into fixed-shape
        buckets (`repro.comm.plan.WirePlan`).  In-process, each bucket is
        encoded DURING the backward pass the moment its last param leaf's
        gradient lands (`repro.train.step.grad_tap`), overlapping
        encode/serialize with the remaining compute; on a multihost
        transport the buckets ship batched as one RCBW container per rank
        (the backward-overlap taps stay in-process).  None keeps the
        one-flat-packet fast path.
      policy: a per-leaf codec policy — a preset name, a
        ``pattern=codec`` spec string, a rule dict, a `CodecPolicy`, or a
        pre-resolved `ResolvedPolicy` (`repro.comm.policy`).  Resolved
        against the PARAMS pytree (path globs see real leaf names), it
        splits the flat gradient into named (segment, codec) streams that
        every wire encodes independently; ``method`` is superseded.  A
        one-segment policy is bit-for-bit the plain single-codec path.
      telemetry: a `repro.obs.Telemetry` bundle to record per-step spans,
        wire metrics, and MLMC estimator telemetry into.  Installed
        process-wide (`repro.obs.install`) so the comm stack's
        instrumentation sees it; None leaves the currently active bundle
        (a disabled no-op by default) in place.
    """

    def __init__(self, loss_fn: Callable, params: PyTree, *,
                 num_workers: int = 4, method: str = "mlmc_topk",
                 optimizer: Optimizer | None = None,
                 k_fraction: float = 0.01, s: int = 0,
                 momentum_beta: float = 0.1, qsgd_levels: int = 2,
                 rtn_level: int = 4, ema_rho: float = 0.25,
                 wire: str = "abstract", transport=None,
                 wire_compiled: bool | None = None,
                 downlink: str | None = None, downlink_alpha: float = 0.5,
                 bucket_size: int | None = None, policy=None,
                 telemetry: obs.Telemetry | None = None):
        if telemetry is not None:
            obs.install(telemetry)
        self.loss_fn = loss_fn
        self.m = num_workers
        flat, self.unravel = ravel_pytree(params)
        self.dim = flat.size
        self.flat_params = flat.astype(jnp.float32)
        self.optimizer = optimizer or sgd(0.05)
        self.wire = wire
        self.bucket_size = bucket_size
        self.policy = None
        if policy is not None:
            from repro.comm.policy import CodecPolicy, ResolvedPolicy

            # resolve against the REAL param tree so path globs see the
            # leaf names the user wrote rules for
            self.policy = (policy if isinstance(policy, ResolvedPolicy)
                           else CodecPolicy.parse(policy).resolve(params))
        # one blanket kwarg set serves heterogeneous codec names: keep only
        # the entries some selected codec consumes (make_aggregator raises
        # on explicitly-passed kwargs its codec would silently ignore)
        consumers = ((method,) if self.policy is None
                     else self.policy.codecs) + (downlink,)
        codec_kw = filter_codec_kw(
            dict(momentum_beta=momentum_beta, qsgd_levels=qsgd_levels,
                 rtn_level=rtn_level, ema_rho=ema_rho), *consumers)
        self.agg: Aggregator = make_aggregator(
            method, self.dim, k_fraction=k_fraction,
            s=s or max(1, int(round(k_fraction * self.dim))),
            wire=wire, transport=transport, compiled=wire_compiled,
            downlink=downlink, downlink_alpha=downlink_alpha,
            bucket_size=bucket_size, policy=self.policy, **codec_kw)
        self.opt_state = self.optimizer.init(self.flat_params)
        #: first-class aggregator state — empty for stateless methods,
        #: threaded through every step and checkpointed with params
        self.comm_state: CommState = self.agg.init(self.m, self.dim)
        self.total_bits = 0.0
        self.method = method
        if self.rank is not None and self.transport.world != self.m:
            raise ValueError(
                f"multihost transport world={self.transport.world} but "
                f"num_workers={self.m}; pass the GLOBAL worker count (every "
                "rank sees the same (M, b, ...) batch stream and computes "
                "its own shard)")
        if self.rank == 0 and getattr(self.transport, "elastic", False):
            # elastic star: a mid-run REJOINer's own params are stale by
            # however many rounds it missed — serve it the live flat
            # params during the rejoin handshake (DIRECTION frame)
            import numpy as np

            self.transport.snapshot_provider = lambda: np.asarray(
                self.flat_params, np.float32).tobytes()
        if wire == "packed" and bucket_size is not None and self.rank is None:
            # in-process bucketed wire: backward-overlap streaming taps
            self._step = self._build_bucketed_step()
        elif wire == "packed":
            # multihost bucketed runs ship batched RCBW containers through
            # the plain packed step (the streamed taps are in-process only)
            self._step = self._build_packed_step()
        else:
            self._step = self._build_step()

    @property
    def transport(self):
        """The packed-wire transport (None in abstract mode)."""
        return getattr(self.agg.fn, "transport", None)

    @property
    def rank(self):
        """This process's rank on a multihost transport, else None."""
        from repro.comm.multihost import is_multihost_transport

        tp = self.transport
        return tp.rank if is_multihost_transport(tp) else None

    def _grad_fn(self):
        loss_fn, unravel = self.loss_fn, self.unravel

        @jax.jit
        def grads_of(flat_params, batch):
            def worker_loss(p_flat, wb):
                return loss_fn(unravel(p_flat), wb)

            # stacked per-worker (loss, grad): batch leaves are (M, b, ...)
            return jax.vmap(
                jax.value_and_grad(worker_loss), in_axes=(None, 0)
            )(flat_params, batch)

        return grads_of

    def _build_step(self):
        agg, opt, grads_of = self.agg, self.optimizer, self._grad_fn()

        @jax.jit
        def step(flat_params, opt_state, comm_state, batch, rng):
            losses, grads = grads_of(flat_params, batch)
            out = agg.step(comm_state, grads, rng)
            new_flat, new_opt = opt.apply(out.direction, opt_state,
                                          flat_params)
            return (new_flat, new_opt, out.state, jnp.mean(losses), out.bits)

        return step

    def _build_packed_step(self):
        """Packed wire: jitted grads + the COMPILED codec pipeline
        (`repro.comm.compiled`: one vmapped jitted encode, one device_get,
        byte framing, one fused decode+mean) + jitted apply — only the
        serialization itself stays on the host.  The apply donates the old
        params/optimizer buffers, so XLA recycles their storage for the new
        ones instead of allocating fresh arrays every step.

        On a multihost transport every rank runs this same step over the
        same global (M, b, ...) batch stream but slices out ITS OWN worker
        shard before the gradient — each worker's gradient is computed in
        its own OS process, and only the aggregated direction (broadcast by
        rank 0) feeds the optimizer, keeping params identical across
        ranks.  Stateful methods keep rank-local CommState rows (rank 0
        additionally mirrors every worker's EF21 innovation state)."""
        agg, opt, grads_of = self.agg, self.optimizer, self._grad_fn()
        # donate (opt_state, flat_params): fit() rebinds both to the
        # returned successors every step, so the old buffers are dead
        apply_jit = jax.jit(opt.apply, donate_argnums=(1, 2))
        rank, tp = self.rank, self.transport

        def step(flat_params, opt_state, comm_state, batch, rng):
            if rank is not None:
                batch = jax.tree.map(lambda x: x[rank:rank + 1], batch)
            losses, grads = grads_of(flat_params, batch)
            out = agg.step(comm_state, grads, rng)
            new_flat, new_opt = apply_jit(out.direction, opt_state,
                                          flat_params)
            loss = jnp.mean(losses)
            if rank is not None:
                # telemetry parity: every rank reports the GLOBAL mean loss
                # (f64 reduction on the server — allclose to, not bitwise
                # with, the in-process f32 jnp.mean)
                loss = tp.allreduce_scalar(float(loss))
            return (new_flat, new_opt, out.state, loss, out.bits)

        return step

    def _build_bucketed_step(self):
        """Bucketed packed wire with comm/compute overlap: every param leaf
        is wrapped in a `grad_tap` whose backward streams the leaf's
        cotangent to a `GradBucketStreamer`, which encodes each wire bucket
        the moment its last leaf lands — so the per-bucket encodes run
        CONCURRENTLY with the rest of the backward pass instead of strictly
        after it.  Bytes are identical to the non-streamed bucketed path
        (and per bucket to a flat codec of the bucket's size): the taps are
        value-preserving identities, and `GradBucketStreamer.finish`
        backfills any bucket the callbacks missed from the returned grads,
        so correctness never depends on the overlap actually firing."""
        from repro.comm.plan import GradBucketStreamer
        from repro.train.step import leaf_layout, tap_params

        opt, bucketed = self.optimizer, self.agg.fn
        offsets, sizes = leaf_layout(self.params)
        streamer = GradBucketStreamer(bucketed.plan, self.m, offsets, sizes)
        self._streamer = streamer     # stable sink: one instance, no retrace
        loss_fn, unravel, m = self.loss_fn, self.unravel, self.m

        @jax.jit
        def grads_of(flat_params, batch):
            def worker_loss(p_flat, wid, wb):
                return loss_fn(
                    tap_params(p_flat, wid, streamer.push, unravel), wb)

            wids = jnp.arange(m, dtype=jnp.float32)
            return jax.vmap(jax.value_and_grad(worker_loss),
                            in_axes=(None, 0, 0))(flat_params, wids, batch)

        apply_jit = jax.jit(opt.apply, donate_argnums=(1, 2))

        def step(flat_params, opt_state, comm_state, batch, rng):
            streamer.begin(rng)   # same rng the aggregator keys derive from
            losses, grads = grads_of(flat_params, batch)
            out = bucketed.step_streamed(streamer, grads, rng, comm_state)
            new_flat, new_opt = apply_jit(out.direction, opt_state,
                                          flat_params)
            return (new_flat, new_opt, out.state, jnp.mean(losses), out.bits)

        return step

    def fit(self, batches: Iterator, *, steps: int, seed: int = 0,
            eval_fn: Callable | None = None, eval_every: int = 0,
            log_every: int = 0) -> History:
        """batches yields pytrees whose leaves are (M, b, ...)."""
        hist = History()
        rng = jax.random.PRNGKey(seed)
        tel = obs.active()
        window_t0, window_step = time.perf_counter(), 0
        for t in range(steps):
            rng, sub = jax.random.split(rng)
            batch = next(batches)
            t0 = time.perf_counter()
            (self.flat_params, self.opt_state, self.comm_state, loss,
             bits) = self._step(self.flat_params, self.opt_state,
                                self.comm_state, batch, sub)
            self.total_bits += float(bits)
            if tel.enabled:
                tel.trace.complete("train/step", t0, cat="train", step=t,
                                   method=self.method)
                tel.observe("train_step_s", time.perf_counter() - t0,
                            method=self.method)
                tel.count("train_bits", float(bits), method=self.method)
            hist.steps.append(t)
            hist.loss.append(float(loss))
            hist.bits.append(self.total_bits)
            if eval_fn and eval_every and (t + 1) % eval_every == 0:
                hist.eval_loss.append(float(eval_fn(self.params)))
            if log_every and (t + 1) % log_every == 0:
                now = time.perf_counter()
                steps_per_s = (t + 1 - window_step) / max(now - window_t0,
                                                          1e-9)
                window_t0, window_step = now, t + 1
                self._log_step(tel, t + 1, float(loss), float(bits),
                               steps_per_s)
        return hist

    def _log_step(self, tel, step: int, loss: float, bits: float,
                  steps_per_s: float) -> None:
        """The structured telemetry log line (loss, bits/step, wire bytes,
        steps/s) — emitted through `repro.obs` AND printed in the familiar
        human-readable console form."""
        tp = self.transport
        wire_bytes = tp.stats.wire_bytes if tp is not None else 0
        tel.instant("train/log", cat="train", step=step, loss=loss,
                    bits_per_step=bits, total_gbits=self.total_bits / 1e9,
                    wire_bytes=wire_bytes, steps_per_s=steps_per_s)
        if tel.enabled:
            tel.gauge("train_loss", loss, method=self.method)
            tel.gauge("train_steps_per_s", steps_per_s, method=self.method)
        wire = f" wire={wire_bytes/1e6:.2f}MB" if tp is not None else ""
        print(f"  step {step:4d} loss={loss:.4f} "
              f"Gbits={self.total_bits/1e9:.3f}"
              f"{wire} steps/s={steps_per_s:.2f}", flush=True)

    @property
    def params(self) -> PyTree:
        return self.unravel(self.flat_params)

    # ---- checkpointing -----------------------------------------------------

    def sync_comm_state(self) -> CommState:
        """Multihost checkpoint collective: gather every rank's client-side
        `CommState` rows (adaptive EMA ladder, EF21-SGDM momentum) to rank 0
        over the STATE frame and fold them into rank 0's state, so the
        rank-0 checkpoint captures the WHOLE world's client state.  EVERY
        rank must call this at the same point between rounds (workers ship
        their row and return their state unchanged).  A no-op on
        non-multihost transports — safe to call unconditionally before
        `save_checkpoint`."""
        rank = self.rank
        if rank is None:
            return self.comm_state
        from repro.comm.aggregate import (
            fold_comm_state_rows,
            pack_comm_state_row,
        )

        rows = self.transport.gather_state(
            pack_comm_state_row(self.comm_state, rank))
        if rank == 0:
            self.comm_state = fold_comm_state_rows(self.comm_state, rows)
        return self.comm_state

    def save_checkpoint(self, path, metadata: dict | None = None) -> None:
        """Persist params + opt_state + CommState in one bundle, so
        stateful runs (EF21 mirrors, adaptive EMA ladders) resume exactly
        — previously the comm state was silently dropped.  On a multihost
        transport, call `sync_comm_state` (on every rank) first so the
        rank-0 bundle includes the other ranks' client-side rows."""
        from repro import checkpoint

        meta = dict(metadata or {})
        meta.setdefault("method", self.method)
        meta["total_bits"] = self.total_bits
        checkpoint.save_training(path, params=self.params,
                                 opt_state=self.opt_state,
                                 comm_state=self.comm_state, metadata=meta)

    def load_checkpoint(self, path) -> dict:
        """Restore a `save_checkpoint` bundle into this trainer (shapes and
        method must match); returns the checkpoint metadata."""
        from repro import checkpoint

        params, opt_state, comm_state, meta = checkpoint.restore_training(
            path, params=self.params, opt_state=self.opt_state,
            comm_state=self.comm_state)
        flat, _ = ravel_pytree(params)
        self.flat_params = flat.astype(jnp.float32)
        self.opt_state = opt_state
        self.comm_state = comm_state
        self.total_bits = float(meta.get("total_bits", self.total_bits))
        return meta
