"""Distributed step builders: ONE shard_map over the production mesh per
step function (train / prefill / decode), with the paper's MLMC compression
applied to the gradient aggregation path.

Aggregation semantics (paper Alg. 2/3 mapped to the mesh):

* non-FSDP params are replicated over the data axes; each data shard
  computes a local gradient = one of the paper's M machines.  The chosen
  `method` ("dense" | "mlmc_topk" | "mlmc_fixed") reduces them.
* FSDP params are sharded over ``data``; autodiff's reduce-scatter has
  already summed their gradient over ``data`` (native FSDP behaviour), so
  only the expensive cross-pod hop remains — compression applies on the
  ``pod`` axis.  This matches production practice: compress the slow link.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models.attention import AttnCache, MLACache
from repro.models.model import Model, _fsdp_axes_cached
from repro.models.rglru import RGLRUCache
from repro.models.ssm import SSDCache
from repro.optim.optimizers import Optimizer
from repro.sharding import shard_map
from repro.sharding.collectives import compressed_allreduce
from repro.sharding.ctx import ShardCtx
from repro.sharding.partition import param_specs as build_param_specs

PyTree = Any


# ---------------------------------------------------------------------------
# spec helpers
# ---------------------------------------------------------------------------


def batch_axes(global_batch: int, ctx: ShardCtx):
    """Mesh axes carrying the batch dim: all data axes when divisible,
    replicated otherwise (tiny-batch decode, e.g. long_500k B=1)."""
    if global_batch % ctx.dp_total == 0 and global_batch >= ctx.dp_total:
        return tuple(a for a in (ctx.pod_axis, ctx.data_axis) if a)
    return None


def batch_pspec(global_batch: int, ctx: ShardCtx, extra_dims: int = 1) -> P:
    b = batch_axes(global_batch, ctx)
    return P(b, *([None] * extra_dims))


def make_batch_specs(cfg: ModelConfig, shape: InputShape, ctx: ShardCtx,
                     kind: str) -> dict:
    """PartitionSpecs for the batch dict fed to loss/prefill."""
    specs = {"tokens": batch_pspec(shape.global_batch, ctx, 1)}
    if kind == "train":
        specs["labels"] = batch_pspec(shape.global_batch, ctx, 1)
    if cfg.family == "vlm":
        specs["vision"] = batch_pspec(shape.global_batch, ctx, 2)
    if cfg.family == "audio":
        specs["source"] = batch_pspec(shape.global_batch, ctx, 2)
    return specs


def cache_specs(cfg: ModelConfig, ctx: ShardCtx, global_batch: int) -> PyTree:
    """PartitionSpec pytree mirroring Model.init_caches output."""
    b = batch_axes(global_batch, ctx)
    m = ctx.model_axis  # one name, or the fused (data, model) serve group

    def one(spec):
        if spec.mixer in ("attn", "swa"):
            return AttnCache(k=P(b, m, None, None),
                             v=P(b, m, None, None), pos=P(m))
        if spec.mixer == "mla":
            return MLACache(ckv=P(b, m, None),
                            krope=P(b, m, None), pos=P(m))
        if spec.mixer == "ssd":
            return SSDCache(state=P(b, m, None, None),
                            conv_x=P(b, None, m),
                            conv_b=P(b, None, None), conv_c=P(b, None, None))
        if spec.mixer == "rglru":
            return RGLRUCache(h=P(b, m), conv=P(b, None, m))
        raise ValueError(spec.mixer)

    def stack(s: P) -> P:
        return P(None, *tuple(s))

    prefix = tuple(one(s) for s in cfg.prefix)
    blocks = tuple(jax.tree.map(stack, one(s), is_leaf=lambda x: isinstance(x, P))
                   for s in cfg.pattern)
    return {"prefix": prefix, "blocks": blocks}


def model_param_specs(model: Model, ctx: ShardCtx) -> PyTree:
    from repro.sharding.partition import replicate_set

    return build_param_specs(model.abstract_params(), dp=ctx.dp, tp=ctx.tp,
                             fsdp=model.cfg.fsdp,
                             model_axis=ctx.model_axis or "model",
                             replicate=replicate_set(model.cfg, ctx.tp))


# ---------------------------------------------------------------------------
# gradient aggregation (the paper's algorithms on the mesh)
# ---------------------------------------------------------------------------


def aggregate_gradients(grads: PyTree, ctx: ShardCtx, rng, cfg: ModelConfig,
                        method: str, k_fraction: float,
                        wire: str = "abstract"):
    """Per-leaf compressed mean over the data axes.  Returns (grads, bits).

    ``wire="device"`` routes every leaf's collective through the bit-packed
    `repro.comm.device_wire` operands (see `repro.sharding.collectives`)."""
    fsdp_map = (_fsdp_axes_cached(cfg, ctx.dp, ctx.tp)
                if cfg.fsdp and ctx.dp > 1 else
                jax.tree.map(lambda _: -1, grads))
    pod_ctx = dataclasses.replace(ctx, data_axis=None, dp=1)

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    ax_leaves = jax.tree_util.tree_leaves(fsdp_map)
    keys = jax.random.split(rng, len(leaves))
    outs = []
    bits = jnp.zeros((), jnp.float32)
    for leaf, ax, key in zip(leaves, ax_leaves, keys):
        flat = leaf.reshape(-1).astype(jnp.float32)
        if ax >= 0:
            # FSDP leaf: already summed over `data` by the reduce-scatter
            # transpose of the forward all-gather -> normalize, then
            # compress only the cross-pod hop.
            flat = flat / ctx.dp
            out, b = compressed_allreduce(flat, pod_ctx, key, method,
                                          k_fraction=k_fraction, wire=wire)
        else:
            out, b = compressed_allreduce(flat, ctx, key, method,
                                          k_fraction=k_fraction, wire=wire)
        outs.append(out.reshape(leaf.shape))
        bits = bits + b
    return jax.tree_util.tree_unflatten(treedef, outs), bits


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def make_train_step(model: Model, mesh, optimizer: Optimizer, *,
                    shape: InputShape, method: str = "mlmc_topk",
                    k_fraction: float = 0.001, remat: bool = True,
                    wire: str = "abstract"):
    """Returns (jitted_fn, in_specs, out_specs).  fn(params, opt_state,
    batch, rng) -> (params, opt_state, metrics).

    ``wire``: collective substrate for the gradient aggregation —
    ``"abstract"`` (raw operands) or ``"device"`` (bit-packed operands)."""
    from repro.launch.mesh import ctx_for_mesh

    ctx = ctx_for_mesh(mesh)
    cfg = model.cfg
    p_specs = model_param_specs(model, ctx)
    o_specs = optimizer.state_specs(p_specs)
    b_specs = make_batch_specs(cfg, shape, ctx, "train")
    m_specs = {"loss": P(), "bits": P(), "ce": P(), "aux": P()}

    def local_step(params, opt_state, batch, rng):
        def loss_fn(p):
            return model.loss(p, batch, ctx, remat=remat)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        grads, bits = aggregate_gradients(grads, ctx, rng, cfg, method,
                                          k_fraction, wire)
        new_params, new_opt = optimizer.apply(grads, opt_state, params)
        out_metrics = {
            "loss": ctx.pmean_data(loss),
            "bits": bits,
            "ce": ctx.pmean_data(metrics["ce"]),
            "aux": ctx.pmean_data(metrics["aux"]),
        }
        return new_params, new_opt, out_metrics

    fn = shard_map(
        local_step, mesh=mesh,
        in_specs=(p_specs, o_specs, b_specs, P()),
        out_specs=(p_specs, o_specs, m_specs),
        check_vma=False,
    )
    return jax.jit(fn), (p_specs, o_specs, b_specs, P()), (p_specs, o_specs,
                                                           m_specs)


def make_prefill_step(model: Model, mesh, *, shape: InputShape):
    """fn(params, batch) -> (caches, next_token[, enc_out])."""
    from repro import perf
    from repro.launch.mesh import ctx_for_mesh, serve_ctx_for_mesh

    if perf.enabled("serve_no_fsdp") and model.cfg.fsdp:
        model = Model(dataclasses.replace(model.cfg, fsdp=False))
    ctx = (serve_ctx_for_mesh(mesh) if perf.enabled("serve_tp_all")
           else ctx_for_mesh(mesh))
    cfg = model.cfg
    p_specs = model_param_specs(model, ctx)
    b_specs = make_batch_specs(cfg, shape, ctx, "prefill")
    c_specs = cache_specs(cfg, ctx, shape.global_batch)
    tok_spec = P(batch_axes(shape.global_batch, ctx))
    enc_spec = (batch_pspec(shape.global_batch, ctx, 2)
                if cfg.is_encdec else None)

    def local_step(params, batch):
        caches, nxt, enc_out = model.prefill(params, batch, shape.seq_len, ctx)
        if cfg.is_encdec:
            return caches, nxt, enc_out
        return caches, nxt

    out_specs = ((c_specs, tok_spec, enc_spec) if cfg.is_encdec
                 else (c_specs, tok_spec))
    fn = shard_map(local_step, mesh=mesh, in_specs=(p_specs, b_specs),
                   out_specs=out_specs, check_vma=False)
    return jax.jit(fn), (p_specs, b_specs), out_specs


def make_decode_step(model: Model, mesh, *, shape: InputShape):
    """fn(params, token, pos, caches[, enc_out]) -> (next_token, caches)."""
    from repro import perf
    from repro.launch.mesh import ctx_for_mesh, serve_ctx_for_mesh

    if perf.enabled("serve_no_fsdp") and model.cfg.fsdp:
        model = Model(dataclasses.replace(model.cfg, fsdp=False))
    ctx = (serve_ctx_for_mesh(mesh) if perf.enabled("serve_tp_all")
           else ctx_for_mesh(mesh))
    cfg = model.cfg
    p_specs = model_param_specs(model, ctx)
    c_specs = cache_specs(cfg, ctx, shape.global_batch)
    tok_spec = P(batch_axes(shape.global_batch, ctx))
    enc_spec = (batch_pspec(shape.global_batch, ctx, 2)
                if cfg.is_encdec else None)

    if cfg.is_encdec:
        def local_step(params, token, pos, caches, enc_out):
            return model.decode_step(params, token, pos, caches, ctx, enc_out)
        in_specs = (p_specs, tok_spec, P(), c_specs, enc_spec)
    else:
        def local_step(params, token, pos, caches):
            return model.decode_step(params, token, pos, caches, ctx)
        in_specs = (p_specs, tok_spec, P(), c_specs)

    fn = shard_map(local_step, mesh=mesh, in_specs=in_specs,
                   out_specs=(tok_spec, c_specs), check_vma=False)
    return jax.jit(fn), in_specs, (tok_spec, c_specs)
