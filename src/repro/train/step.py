"""Distributed step builders: ONE shard_map over the production mesh per
step function (train / prefill / decode), with the paper's MLMC compression
applied to the gradient aggregation path.

Aggregation semantics (paper Alg. 2/3 mapped to the mesh):

* non-FSDP params are replicated over the data axes; each data shard
  computes a local gradient = one of the paper's M machines.  The chosen
  `method` ("dense" | "mlmc_topk" | "mlmc_fixed") reduces them.
* FSDP params are sharded over ``data``; autodiff's reduce-scatter has
  already summed their gradient over ``data`` (native FSDP behaviour), so
  only the expensive cross-pod hop remains — compression applies on the
  ``pod`` axis.  This matches production practice: compress the slow link.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models.attention import AttnCache, MLACache
from repro.models.model import Model, _fsdp_axes_cached
from repro.models.rglru import RGLRUCache
from repro.models.ssm import SSDCache
from repro.optim.optimizers import Optimizer
from repro.sharding import shard_map
from repro.sharding.collectives import (
    EF_MESH_METHODS,
    STATEFUL_MESH_METHODS,
    adaptive_ladder_len,
    adaptive_segment_len,
    compressed_allreduce,
    ef21_topk_allreduce,
    stateful_allreduce,
)
from repro.sharding.ctx import ShardCtx
from repro.sharding.partition import param_specs as build_param_specs

PyTree = Any


# ---------------------------------------------------------------------------
# spec helpers
# ---------------------------------------------------------------------------


def batch_axes(global_batch: int, ctx: ShardCtx):
    """Mesh axes carrying the batch dim: all data axes when divisible,
    replicated otherwise (tiny-batch decode, e.g. long_500k B=1)."""
    if global_batch % ctx.dp_total == 0 and global_batch >= ctx.dp_total:
        return tuple(a for a in (ctx.pod_axis, ctx.data_axis) if a)
    return None


def batch_pspec(global_batch: int, ctx: ShardCtx, extra_dims: int = 1) -> P:
    b = batch_axes(global_batch, ctx)
    return P(b, *([None] * extra_dims))


def make_batch_specs(cfg: ModelConfig, shape: InputShape, ctx: ShardCtx,
                     kind: str) -> dict:
    """PartitionSpecs for the batch dict fed to loss/prefill."""
    specs = {"tokens": batch_pspec(shape.global_batch, ctx, 1)}
    if kind == "train":
        specs["labels"] = batch_pspec(shape.global_batch, ctx, 1)
    if cfg.family == "vlm":
        specs["vision"] = batch_pspec(shape.global_batch, ctx, 2)
    if cfg.family == "audio":
        specs["source"] = batch_pspec(shape.global_batch, ctx, 2)
    return specs


def cache_specs(cfg: ModelConfig, ctx: ShardCtx, global_batch: int) -> PyTree:
    """PartitionSpec pytree mirroring Model.init_caches output."""
    b = batch_axes(global_batch, ctx)
    m = ctx.model_axis  # one name, or the fused (data, model) serve group

    def one(spec):
        if spec.mixer in ("attn", "swa"):
            return AttnCache(k=P(b, m, None, None),
                             v=P(b, m, None, None), pos=P(m))
        if spec.mixer == "mla":
            return MLACache(ckv=P(b, m, None),
                            krope=P(b, m, None), pos=P(m))
        if spec.mixer == "ssd":
            return SSDCache(state=P(b, m, None, None),
                            conv_x=P(b, None, m),
                            conv_b=P(b, None, None), conv_c=P(b, None, None))
        if spec.mixer == "rglru":
            return RGLRUCache(h=P(b, m), conv=P(b, None, m))
        raise ValueError(spec.mixer)

    def stack(s: P) -> P:
        return P(None, *tuple(s))

    prefix = tuple(one(s) for s in cfg.prefix)
    blocks = tuple(jax.tree.map(stack, one(s), is_leaf=lambda x: isinstance(x, P))
                   for s in cfg.pattern)
    return {"prefix": prefix, "blocks": blocks}


def model_param_specs(model: Model, ctx: ShardCtx) -> PyTree:
    from repro.sharding.partition import replicate_set

    return build_param_specs(model.abstract_params(), dp=ctx.dp, tp=ctx.tp,
                             fsdp=model.cfg.fsdp,
                             model_axis=ctx.model_axis or "model",
                             replicate=replicate_set(model.cfg, ctx.tp))


# ---------------------------------------------------------------------------
# gradient aggregation (the paper's algorithms on the mesh)
# ---------------------------------------------------------------------------


def aggregate_gradients(grads: PyTree, ctx: ShardCtx, rng, cfg: ModelConfig,
                        method: str, k_fraction: float,
                        wire: str = "abstract", comm: PyTree | None = None,
                        ema_rho: float = 0.25,
                        leaf_methods: list | None = None):
    """Per-leaf compressed mean over the data axes.

    Returns ``(grads, bits)`` for the stateless methods, or
    ``(grads, bits, new_comm)`` when ``comm`` is given — the mesh
    realization of the trainer's `CommState`: ``comm["step"]`` is the round
    counter and either ``comm["ladders"]`` mirrors the grads pytree with
    one per-shard EMA residual-norm ladder per leaf (the stateful
    `mlmc_adaptive_*` family) or — for the error-feedback family —
    ``comm["mirrors"]`` / ``comm["servers"]`` carry each shard's dense
    EF21 mirror and server replica per leaf (see `init_mesh_comm_state`).

    ``leaf_methods`` (stateless only) is the mesh realization of a
    per-leaf `repro.comm.policy.CodecPolicy`: a ``(codec, params)`` list
    in flat leaf order — each leaf's collective dispatches through its own
    codec instead of the global ``method``.

    ``wire="device"`` routes every leaf's collective through the bit-packed
    `repro.comm.device_wire` operands (see `repro.sharding.collectives`)."""
    fsdp_map = (_fsdp_axes_cached(cfg, ctx.dp, ctx.tp)
                if cfg.fsdp and ctx.dp > 1 else
                jax.tree.map(lambda _: -1, grads))
    pod_ctx = dataclasses.replace(ctx, data_axis=None, dp=1)
    ef_mode = comm is not None and "mirrors" in comm

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    ax_leaves = jax.tree_util.tree_leaves(fsdp_map)
    if ef_mode:
        state_a = jax.tree_util.tree_leaves(comm["mirrors"])
        state_b = jax.tree_util.tree_leaves(comm["servers"])
    elif comm is not None:
        state_a = jax.tree_util.tree_leaves(comm["ladders"])
        state_b = [None] * len(leaves)
    else:
        state_a = state_b = [None] * len(leaves)
    if leaf_methods is not None and len(leaf_methods) != len(leaves):
        raise ValueError(
            f"leaf_methods has {len(leaf_methods)} entries for "
            f"{len(leaves)} gradient leaves")
    keys = jax.random.split(rng, len(leaves))
    outs, new_a, new_b = [], [], []
    bits = jnp.zeros((), jnp.float32)
    for i, (leaf, ax, key, sa, sb) in enumerate(
            zip(leaves, ax_leaves, keys, state_a, state_b)):
        flat = leaf.reshape(-1).astype(jnp.float32)
        leaf_ctx = ctx
        if ax >= 0:
            # FSDP leaf: already summed over `data` by the reduce-scatter
            # transpose of the forward all-gather -> normalize, then
            # compress only the cross-pod hop.
            flat = flat / ctx.dp
            leaf_ctx = pod_ctx
        if ef_mode:
            s = adaptive_segment_len(flat.shape[0], k_fraction)
            out, b, na, nb = ef21_topk_allreduce(flat, leaf_ctx, sa, sb,
                                                 s=s, wire=wire)
            new_a.append(na)
            new_b.append(nb)
        elif comm is not None:
            out, b, nl = stateful_allreduce(
                flat, leaf_ctx, key, method, sa, comm["step"],
                k_fraction=k_fraction, ema_rho=ema_rho, wire=wire)
            new_a.append(nl)
        else:
            leaf_method, leaf_kw = ((method, {}) if leaf_methods is None
                                    else leaf_methods[i])
            out, b = compressed_allreduce(
                flat, leaf_ctx, key, leaf_method,
                **{"k_fraction": k_fraction, "wire": wire, **leaf_kw})
        outs.append(out.reshape(leaf.shape))
        bits = bits + b
    grads_out = jax.tree_util.tree_unflatten(treedef, outs)
    if comm is None:
        return grads_out, bits
    if ef_mode:
        sub = jax.tree_util.tree_structure(comm["mirrors"])
        new_comm = {"step": comm["step"] + 1,
                    "mirrors": jax.tree_util.tree_unflatten(sub, new_a),
                    "servers": jax.tree_util.tree_unflatten(sub, new_b)}
    else:
        new_comm = {"step": comm["step"] + 1,
                    "ladders": jax.tree_util.tree_unflatten(
                        jax.tree_util.tree_structure(comm["ladders"]),
                        new_a)}
    return grads_out, bits, new_comm


# ---------------------------------------------------------------------------
# mesh comm state (the CommState realization for the sharded train step)
# ---------------------------------------------------------------------------


def _local_leaf_size(shape, spec: P, mesh) -> int:
    """Flat size of one param leaf's PER-SHARD slice under `spec`."""
    names = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    size = 1
    for dim, name in zip(shape, names):
        div = 1
        if name:
            for n in (name if isinstance(name, tuple) else (name,)):
                div *= mesh.shape[n]
        size *= dim // div
    return size


def init_mesh_comm_state(model: Model, mesh, *, method: str,
                         k_fraction: float = 0.001, min_segment: int = 8):
    """Build the sharded train step's comm state for a stateful method.

    Returns ``(comm_state, comm_specs)``: ``comm_state["step"]`` is the
    round counter and ``comm_state["ladders"]`` mirrors the param pytree
    with one zeroed EMA residual-norm ladder PER LEAF **PER DEVICE** —
    shape ``(num_devices, L_leaf)`` sharded over EVERY mesh axis.  The
    leading dim spans all axes (not just the data axes) because a leaf's
    local gradient slice — and hence its ladder — also varies along the
    model axis for tensor-parallel leaves and along the data axis for
    FSDP-sharded leaves; a narrower spec would let shard_map (replication
    unchecked under ``check_vma=False``) overwrite one shard's ladder with
    another's.  Leaves that are replicated along an axis simply carry
    identical rows there — redundant but exact.

    The error-feedback family (`EF_MESH_METHODS`, e.g. ``ef21``) threads
    dense per-shard state instead: ``comm_state["mirrors"]`` /
    ``comm_state["servers"]`` mirror the param pytree with one zeroed
    ``(num_devices, d_local)`` row pair per leaf — each shard's EF21
    mirror ``g_i`` and its replica of the server aggregate (see
    `repro.sharding.collectives.ef21_topk_allreduce`).

    For a stateless method returns ``(None, None)``."""
    if method not in STATEFUL_MESH_METHODS:
        return None, None
    from repro.launch.mesh import ctx_for_mesh

    ctx = ctx_for_mesh(mesh)
    p_abs = model.abstract_params()
    p_specs = model_param_specs(model, ctx)
    all_axes = tuple(mesh.axis_names)
    num_devices = int(mesh.devices.size)

    leaves, treedef = jax.tree_util.tree_flatten(p_abs)
    spec_leaves = jax.tree_util.tree_leaves(
        p_specs, is_leaf=lambda x: isinstance(x, P))
    state_leaves, state_specs = [], []
    for leaf, spec in zip(leaves, spec_leaves):
        d_local = _local_leaf_size(leaf.shape, spec, mesh)
        if method in EF_MESH_METHODS:
            rows = d_local
        else:
            rows = adaptive_ladder_len(d_local, k_fraction, min_segment)
        state_leaves.append(jnp.zeros((num_devices, rows), jnp.float32))
        state_specs.append(P(all_axes, None))
    state = jax.tree_util.tree_unflatten(treedef, state_leaves)
    specs = jax.tree_util.tree_unflatten(treedef, state_specs)
    if method in EF_MESH_METHODS:
        comm = {"step": jnp.zeros((), jnp.int32), "mirrors": state,
                "servers": jax.tree.map(jnp.zeros_like, state)}
        comm_specs = {"step": P(), "mirrors": specs, "servers": specs}
    else:
        comm = {"step": jnp.zeros((), jnp.int32), "ladders": state}
        comm_specs = {"step": P(), "ladders": specs}
    return comm, comm_specs


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def make_train_step(model: Model, mesh, optimizer: Optimizer, *,
                    shape: InputShape, method: str = "mlmc_topk",
                    k_fraction: float = 0.001, remat: bool = True,
                    wire: str = "abstract", ema_rho: float = 0.25,
                    policy=None):
    """Returns (jitted_fn, in_specs, out_specs).

    Stateless methods: fn(params, opt_state, batch, rng) ->
    (params, opt_state, metrics) — unchanged.

    Stateful methods (`STATEFUL_MESH_METHODS`): fn(params, opt_state,
    comm_state, batch, rng) -> (params, opt_state, comm_state, metrics),
    with ``comm_state`` built by `init_mesh_comm_state` — the mesh
    realization of the trainer's first-class CommState (per-shard EMA
    residual-norm ladders for ``mlmc_adaptive_topk``; dense mirror +
    server-replica pairs for ``ef21``).

    ``policy``: a per-leaf `repro.comm.policy.CodecPolicy` (or anything
    `CodecPolicy.parse` accepts) — each param leaf's collective dispatches
    through the codec its rule assigns instead of the global ``method``
    (``method`` is ignored).  Stateless codecs only: the policy's rules
    must not name a `STATEFUL_MESH_METHODS` member.

    ``wire``: collective substrate for the gradient aggregation —
    ``"abstract"`` (raw operands) or ``"device"`` (bit-packed operands)."""
    from repro.launch.mesh import ctx_for_mesh

    ctx = ctx_for_mesh(mesh)
    cfg = model.cfg
    leaf_methods = None
    if policy is not None:
        from repro.comm.policy import CodecPolicy
        from repro.sharding.collectives import AGG_METHODS

        if method in STATEFUL_MESH_METHODS:
            raise ValueError(
                f"policy= cannot combine with stateful method {method!r}; "
                "pass a stateless base method (it is superseded per leaf)")
        specs = CodecPolicy.parse(policy).leaf_specs(model.abstract_params())
        for path, codec, params in specs:
            if codec not in AGG_METHODS:
                raise ValueError(
                    f"policy assigns leaf {path!r} codec {codec!r}, not a "
                    f"mesh collective (one of {AGG_METHODS})")
            if codec in STATEFUL_MESH_METHODS:
                raise ValueError(
                    f"policy assigns leaf {path!r} the stateful collective "
                    f"{codec!r} — per-leaf policies are stateless-only on "
                    "the mesh wire")
        leaf_methods = [(codec, params) for _, codec, params in specs]
    p_specs = model_param_specs(model, ctx)
    o_specs = optimizer.state_specs(p_specs)
    b_specs = make_batch_specs(cfg, shape, ctx, "train")
    m_specs = {"loss": P(), "bits": P(), "ce": P(), "aux": P()}
    stateful = method in STATEFUL_MESH_METHODS

    def grads_and_metrics(params, batch):
        def loss_fn(p):
            return model.loss(p, batch, ctx, remat=remat)

        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def out_metrics(loss, metrics, bits):
        return {
            "loss": ctx.pmean_data(loss),
            "bits": bits,
            "ce": ctx.pmean_data(metrics["ce"]),
            "aux": ctx.pmean_data(metrics["aux"]),
        }

    if stateful:
        _, c_specs = init_mesh_comm_state(model, mesh, method=method,
                                          k_fraction=k_fraction)

        def local_step(params, opt_state, comm, batch, rng):
            (loss, metrics), grads = grads_and_metrics(params, batch)
            grads, bits, new_comm = aggregate_gradients(
                grads, ctx, rng, cfg, method, k_fraction, wire, comm=comm,
                ema_rho=ema_rho)
            new_params, new_opt = optimizer.apply(grads, opt_state, params)
            return (new_params, new_opt, new_comm,
                    out_metrics(loss, metrics, bits))

        in_specs = (p_specs, o_specs, c_specs, b_specs, P())
        out_specs = (p_specs, o_specs, c_specs, m_specs)
    else:
        def local_step(params, opt_state, batch, rng):
            (loss, metrics), grads = grads_and_metrics(params, batch)
            grads, bits = aggregate_gradients(grads, ctx, rng, cfg, method,
                                              k_fraction, wire,
                                              leaf_methods=leaf_methods)
            new_params, new_opt = optimizer.apply(grads, opt_state, params)
            return new_params, new_opt, out_metrics(loss, metrics, bits)

        in_specs = (p_specs, o_specs, b_specs, P())
        out_specs = (p_specs, o_specs, m_specs)

    fn = shard_map(local_step, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return jax.jit(fn), in_specs, out_specs


# ---------------------------------------------------------------------------
# backward-pass gradient taps (bucketed comm/compute overlap)
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def grad_tap(x, worker_id, sink, tag: int):
    """Identity on ``x`` whose BACKWARD streams the cotangent to the host.

    Wrapping each param leaf in ``grad_tap(leaf, wid, sink, leaf_idx)``
    inside the loss makes autodiff call ``sink(tag, wid, cotangent)`` via
    `jax.debug.callback` the moment that leaf's gradient materializes —
    i.e. DURING the backward pass, while later (earlier-layer) segments
    are still computing.  The `GradBucketStreamer` (`repro.comm.plan`)
    uses this to encode each wire bucket as soon as its last leaf lands,
    overlapping the 0.16-1.1 s encode with the rest of backward.

    Contract:

    * ``worker_id`` must be a FLOAT scalar (an int operand would need a
      float0 cotangent from the bwd rule); under ``vmap`` over workers the
      debug callback unrolls per batch element, so the sink sees one call
      per (worker, leaf).
    * ``sink`` and ``tag`` are nondiff/static — keep ``sink`` a stable
      object across steps or every step retraces.
    * The tap never changes values: primal and cotangent pass through
      untouched, so tapped gradients stay bitwise identical to untapped
      ones and correctness never depends on the callback firing (the
      streamer backfills from the returned grads)."""
    del sink, tag
    return x


def _grad_tap_fwd(x, worker_id, sink, tag):
    del sink, tag
    return x, worker_id


def _grad_tap_bwd(sink, tag, worker_id, ct):
    jax.debug.callback(lambda w, c: sink(tag, w, c), worker_id, ct)
    return ct, jnp.zeros_like(worker_id)


grad_tap.defvjp(_grad_tap_fwd, _grad_tap_bwd)


def tap_params(p_flat, worker_id, sink, unravel):
    """Unravel ``p_flat`` and wrap every leaf in a `grad_tap` (tag = flat
    leaf index, matching the streamer's leaf-offset table)."""
    leaves, treedef = jax.tree_util.tree_flatten(unravel(p_flat))
    tapped = [grad_tap(leaf, worker_id, sink, i)
              for i, leaf in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, tapped)


def leaf_layout(params) -> tuple[list[int], list[int]]:
    """(offsets, sizes) of each leaf inside ``ravel_pytree(params)`` —
    tree-flatten order, the same order `tap_params` tags leaves in."""
    offsets, sizes, off = [], [], 0
    for leaf in jax.tree_util.tree_leaves(params):
        n = int(jnp.size(leaf))
        offsets.append(off)
        sizes.append(n)
        off += n
    return offsets, sizes


def make_prefill_step(model: Model, mesh, *, shape: InputShape):
    """fn(params, batch) -> (caches, next_token[, enc_out])."""
    from repro import perf
    from repro.launch.mesh import ctx_for_mesh, serve_ctx_for_mesh

    if perf.enabled("serve_no_fsdp") and model.cfg.fsdp:
        model = Model(dataclasses.replace(model.cfg, fsdp=False))
    ctx = (serve_ctx_for_mesh(mesh) if perf.enabled("serve_tp_all")
           else ctx_for_mesh(mesh))
    cfg = model.cfg
    p_specs = model_param_specs(model, ctx)
    b_specs = make_batch_specs(cfg, shape, ctx, "prefill")
    c_specs = cache_specs(cfg, ctx, shape.global_batch)
    tok_spec = P(batch_axes(shape.global_batch, ctx))
    enc_spec = (batch_pspec(shape.global_batch, ctx, 2)
                if cfg.is_encdec else None)

    def local_step(params, batch):
        caches, nxt, enc_out = model.prefill(params, batch, shape.seq_len, ctx)
        if cfg.is_encdec:
            return caches, nxt, enc_out
        return caches, nxt

    out_specs = ((c_specs, tok_spec, enc_spec) if cfg.is_encdec
                 else (c_specs, tok_spec))
    fn = shard_map(local_step, mesh=mesh, in_specs=(p_specs, b_specs),
                   out_specs=out_specs, check_vma=False)
    return jax.jit(fn), (p_specs, b_specs), out_specs


def make_decode_step(model: Model, mesh, *, shape: InputShape):
    """fn(params, token, pos, caches[, enc_out]) -> (next_token, caches)."""
    from repro import perf
    from repro.launch.mesh import ctx_for_mesh, serve_ctx_for_mesh

    if perf.enabled("serve_no_fsdp") and model.cfg.fsdp:
        model = Model(dataclasses.replace(model.cfg, fsdp=False))
    ctx = (serve_ctx_for_mesh(mesh) if perf.enabled("serve_tp_all")
           else ctx_for_mesh(mesh))
    cfg = model.cfg
    p_specs = model_param_specs(model, ctx)
    c_specs = cache_specs(cfg, ctx, shape.global_batch)
    tok_spec = P(batch_axes(shape.global_batch, ctx))
    enc_spec = (batch_pspec(shape.global_batch, ctx, 2)
                if cfg.is_encdec else None)

    if cfg.is_encdec:
        def local_step(params, token, pos, caches, enc_out):
            return model.decode_step(params, token, pos, caches, ctx, enc_out)
        in_specs = (p_specs, tok_spec, P(), c_specs, enc_spec)
    else:
        def local_step(params, token, pos, caches):
            return model.decode_step(params, token, pos, caches, ctx)
        in_specs = (p_specs, tok_spec, P(), c_specs)

    fn = shard_map(local_step, mesh=mesh, in_specs=in_specs,
                   out_specs=(tok_spec, c_specs), check_vma=False)
    return jax.jit(fn), in_specs, (tok_spec, c_specs)
