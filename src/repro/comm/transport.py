"""Transports — how encoded packets move from M workers to the server.

A `Transport` takes one aggregation round's worth of serialized packets
(real ``bytes``, produced by `Packet.to_bytes`) and delivers them to the
aggregation point, accumulating byte counts and simulated wall-clock from
the :mod:`repro.comm.topology` cost model.  The in-process implementations
are deliberately simple — the subsystem's value is that *actual bytes* flow
through a pluggable seam (cf. Hivemind-style pluggable compression
transports), so a real network backend only has to implement `exchange`.

* ``loopback``          — zero-cost in-process delivery (tests, parity runs)
* ``parameter_server``  — star topology with incast accounting
* ``ring``              — all-gather ring accounting
* ``tcp``               — real multi-host socket star with *measured* bytes
  and wall-clock (:mod:`repro.comm.multihost`)
"""

from __future__ import annotations

import dataclasses
import time
from typing import Protocol, runtime_checkable

from repro.comm.topology import CostModel, Topology, make_topology
from repro.obs import trace as obs


@dataclasses.dataclass
class TransportStats:
    rounds: int = 0
    bytes_up: int = 0          # worker -> server payload bytes
    bytes_down: int = 0        # server -> worker broadcast bytes
    wire_bytes: int = 0        # bytes crossing any link (topology-dependent)
    sim_time_s: float = 0.0    # alpha-beta modeled clock (in-process only)
    wall_time_s: float = 0.0   # measured clock (real transports, e.g. tcp)

    def observe(self, sizes: list[int], topology: Topology,
                cost: CostModel) -> None:
        self.rounds += 1
        self.bytes_up += sum(sizes)
        self.wire_bytes += topology.wire_bytes(sizes)
        self.sim_time_s += topology.step_time(sizes, cost)


@runtime_checkable
class Transport(Protocol):
    stats: TransportStats

    def exchange(self, payloads: list[bytes],
                 on_payload=None) -> list[bytes]:
        """Deliver every worker's serialized packet to the server.

        ``on_payload(index, payload)`` — when given — is invoked at the
        aggregation point for each delivered payload AS IT BECOMES
        AVAILABLE (in-process transports: immediately, in order; the tcp
        star: as each rank's uplink frame completes), so the server can
        parse/stage/decode one message while still waiting on the others."""
        ...

    def broadcast(self, nbytes: int, workers: int) -> None:
        """Account a server -> workers broadcast of ``nbytes`` per worker
        (a byte count, not a payload — the model update itself never needs
        to be materialized just to be priced)."""
        ...


@dataclasses.dataclass
class LoopbackTransport:
    """In-process delivery; counts bytes, charges no time."""

    stats: TransportStats = dataclasses.field(default_factory=TransportStats)

    def exchange(self, payloads: list[bytes],
                 on_payload=None) -> list[bytes]:
        tel = obs.active()
        t0 = time.perf_counter() if tel.enabled else 0.0
        total = sum(len(p) for p in payloads)
        self.stats.rounds += 1
        self.stats.bytes_up += total
        self.stats.wire_bytes += total
        if on_payload is not None:
            for i, pay in enumerate(payloads):
                on_payload(i, pay)
        if tel.enabled:
            tel.trace.complete("wire/exchange", t0, cat="wire",
                               nbytes=total, transport="loopback")
            tel.count("wire_bytes_up", total, transport="loopback")
        return list(payloads)

    def broadcast(self, nbytes: int, workers: int) -> None:
        total = nbytes * workers
        self.stats.bytes_down += total
        self.stats.wire_bytes += total
        obs.active().count("wire_bytes_down", total, transport="loopback")


@dataclasses.dataclass
class SimulatedTransport:
    """Topology-priced in-process delivery (parameter_server / ring)."""

    topology: Topology
    cost: CostModel = dataclasses.field(default_factory=CostModel)
    stats: TransportStats = dataclasses.field(default_factory=TransportStats)

    def exchange(self, payloads: list[bytes],
                 on_payload=None) -> list[bytes]:
        tel = obs.active()
        t0 = time.perf_counter() if tel.enabled else 0.0
        sizes = [len(p) for p in payloads]
        self.stats.observe(sizes, self.topology, self.cost)
        if on_payload is not None:
            for i, pay in enumerate(payloads):
                on_payload(i, pay)
        if tel.enabled:
            name = type(self.topology).__name__
            tel.trace.complete("wire/exchange", t0, cat="wire",
                               nbytes=sum(sizes), transport=name)
            tel.count("wire_bytes_up", sum(sizes), transport=name)
        return list(payloads)

    def broadcast(self, nbytes: int, workers: int) -> None:
        total = nbytes * workers
        self.stats.bytes_down += total
        self.stats.wire_bytes += total
        # mirror the uplink incast: all W copies leave one server egress NIC
        self.stats.sim_time_s += self.cost.xfer_time(total, messages=1)
        obs.active().count("wire_bytes_down", total,
                           transport=type(self.topology).__name__)


def _reject_unused(name: str, kw: dict) -> None:
    if kw:
        raise TypeError(
            f"make_transport({name!r}) got unsupported keyword arguments "
            f"{sorted(kw)}; only 'hierarchical' takes topology kwargs "
            "(pod_size, cross_pod_slowdown) and 'tcp' takes "
            "rank/world/coordinator/timeout/policy_hash plus the elastic "
            "knobs (deadline_ms, heartbeat_s, read_timeout_s)")


def make_transport(name: str = "loopback", *,
                   cost: CostModel | None = None, **topo_kw) -> Transport:
    if name == "loopback":
        _reject_unused(name, topo_kw)
        return LoopbackTransport()
    if name in ("parameter_server", "star"):
        _reject_unused(name, topo_kw)
        return SimulatedTransport(make_topology("star"),
                                  cost or CostModel())
    if name == "ring":
        _reject_unused(name, topo_kw)
        return SimulatedTransport(make_topology("ring"), cost or CostModel())
    if name == "hierarchical":
        return SimulatedTransport(make_topology("hierarchical", **topo_kw),
                                  cost or CostModel())
    if name == "tcp":
        if cost is not None:
            raise TypeError("the tcp transport measures bytes and wall-clock"
                            " — it takes no simulated CostModel")
        from repro.comm.multihost import make_tcp_transport

        return make_tcp_transport(**topo_kw)
    raise ValueError(f"unknown transport {name!r}")
