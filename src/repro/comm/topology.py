"""Communication topologies + the alpha-beta cost model.

The paper's experiments count bits; a deployment cares about *time*.  The
standard alpha-beta model charges ``alpha`` seconds of latency per message
plus ``bytes * 8 / bandwidth`` of serialization per link.  Each topology
turns one aggregation round's worker payload sizes into (a) total bytes on
the wire and (b) simulated wall-clock, so benchmarks can report seconds per
step instead of raw bits (`benchmarks/fig1_communication_efficiency.py`).

Topologies:

* ``star``  — parameter server (the paper's Alg. 1/2 picture): all M uplinks
  land on one ingress NIC, so serialization time is the SUM of payloads
  (incast), one latency hop.
* ``ring``  — all-gather ring: M-1 rounds, each forwarding the largest
  in-flight packet; every payload traverses M-1 links.
* ``hierarchical`` — pods of ``pod_size`` workers star-aggregate locally,
  then pod leaders star-aggregate across the slow link (the `ShardCtx`
  pod/data split).
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Alpha-beta link model.  Defaults: 50us latency, 10 Gbit/s links."""

    latency_s: float = 50e-6
    bandwidth_bps: float = 10e9

    def xfer_time(self, nbytes: float, messages: int = 1) -> float:
        return messages * self.latency_s + 8.0 * nbytes / self.bandwidth_bps


@dataclasses.dataclass(frozen=True)
class Topology:
    name: str

    def wire_bytes(self, sizes: list[int]) -> int:
        """Total bytes crossing any link during one aggregation round."""
        raise NotImplementedError

    def step_time(self, sizes: list[int], cost: CostModel) -> float:
        """Simulated wall-clock of one aggregation round."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class StarTopology(Topology):
    name: str = "star"

    def wire_bytes(self, sizes):
        return sum(sizes)

    def step_time(self, sizes, cost):
        # uplinks are parallel but share the server ingress: incast sum
        return cost.xfer_time(sum(sizes), messages=1)


@dataclasses.dataclass(frozen=True)
class RingTopology(Topology):
    name: str = "ring"

    def wire_bytes(self, sizes):
        return (max(len(sizes) - 1, 0)) * sum(sizes)

    def step_time(self, sizes, cost):
        rounds = max(len(sizes) - 1, 0)
        # per round every link is busy; the slowest carries the max packet
        return rounds * cost.xfer_time(max(sizes, default=0), messages=1)


@dataclasses.dataclass(frozen=True)
class HierarchicalTopology(Topology):
    name: str = "hierarchical"
    pod_size: int = 4
    #: cross-pod links are typically the slow hop (DC spine vs rack)
    cross_pod_slowdown: float = 4.0

    def _pods(self, sizes):
        return [sizes[i:i + self.pod_size]
                for i in range(0, len(sizes), self.pod_size)]

    def wire_bytes(self, sizes):
        pods = self._pods(sizes)
        # in-pod uplinks + one aggregated (max-size) packet per pod leader
        return sum(sizes) + sum(max(p, default=0) for p in pods)

    def step_time(self, sizes, cost):
        pods = self._pods(sizes)
        local = max((cost.xfer_time(sum(p)) for p in pods), default=0.0)
        slow = CostModel(cost.latency_s * self.cross_pod_slowdown,
                         cost.bandwidth_bps / self.cross_pod_slowdown)
        cross = slow.xfer_time(sum(max(p, default=0) for p in pods))
        return local + cross


TOPOLOGIES = {
    "star": StarTopology,
    "ring": RingTopology,
    "hierarchical": HierarchicalTopology,
}


def make_topology(name: str, **kw) -> Topology:
    if name not in TOPOLOGIES:
        raise ValueError(f"unknown topology {name!r}; have {sorted(TOPOLOGIES)}")
    return TOPOLOGIES[name](**kw)


def simulated_step_time(total_bits: float, workers: int, topology: str = "star",
                        cost: CostModel | None = None) -> float:
    """Post-hoc estimate for benchmarks that only recorded a bit total:
    split the step's bits evenly over M workers and price one round."""
    cost = cost or CostModel()
    per_worker = math.ceil(total_bits / 8.0 / max(workers, 1))
    return make_topology(topology).step_time([per_worker] * workers, cost)
