"""Wire packets — the byte-level container every codec emits.

A `Packet` is a small fixed `Header` plus one or more bit-packed `Stream`s
(uint32 word buffers).  `to_bytes()`/`from_bytes()` give the *actual* network
representation, so the transports in :mod:`repro.comm.transport` ship real
byte strings, and tests can reconcile ``len(payload) * 8`` against the
idealized ledger in :mod:`repro.core.bits` instead of trusting it.

Two bit-accounting views coexist deliberately:

* ``used_bits``   — ``width * count`` per stream: the information content the
  paper's formulas count.
* ``padded_bits`` — ``32 * n_words``: what the uint32 buffers actually hold
  (fields never straddle word boundaries; ``32 // width`` fields per word).

The serialized byte stream adds a fixed struct overhead
(`HEADER_STRUCT_BYTES` + `STREAM_STRUCT_BYTES` per stream) on top — that is
the "documented header padding" the reconciliation tests allow for.

Float headers (scale / norm / p_l) are stored as raw float32 bit patterns so
decode is bit-exact.
"""

from __future__ import annotations

import dataclasses
import struct

import numpy as np

MAGIC = b"RCW1"
#: magic + codec_id/version/flags/n_streams + dim/level/nnz + scale/prob
_HEADER_FMT = "<4sBBBBIIIff"
HEADER_STRUCT_BYTES = struct.calcsize(_HEADER_FMT)   # 28
_STREAM_FMT = "<BBHII"                               # width, _, _, count, words
STREAM_STRUCT_BYTES = struct.calcsize(_STREAM_FMT)   # 12

#: stable codec ids for the wire (order is append-only)
CODEC_IDS = {
    "dense": 0, "topk": 1, "randk": 2, "qsgd": 3, "rtn": 4, "fixed2": 5,
    "natural": 6, "signsgd": 7, "mlmc_topk": 8, "mlmc_topk_static": 9,
    "mlmc_stopk": 10, "mlmc_fixed": 11, "mlmc_float": 12, "mlmc_rtn": 13,
    # PR 4 (appended): the EF21 innovation wire (honest ceil(log2 d)-bit
    # positions) and the stateful EMA-adaptive MLMC family
    "ef21": 14, "mlmc_adaptive_topk": 15, "mlmc_adaptive_stopk": 16,
    "mlmc_adaptive_rtn": 17,
}
_ID_TO_CODEC = {i: n for n, i in CODEC_IDS.items()}

#: header flag: the MLMC draw hit the top level — payload is the dense f32
#: residual (Def. 3.1's C^L = id has no compact plane/segment form)
FLAG_DENSE_FALLBACK = 1
#: header flag: p_l is shipped in the header rather than derived from the
#: family's static distribution (adaptive draws, or an explicit `probs`
#: override at encode time)
FLAG_EXPLICIT_PROB = 2


@dataclasses.dataclass(frozen=True)
class Stream:
    """One bit-packed field stream: ``count`` fields of ``width`` bits each,
    packed ``32 // width`` to a word (width > 16 occupies a full word)."""

    name: str
    words: np.ndarray          # uint32
    width: int
    count: int

    def __post_init__(self):
        assert self.words.dtype == np.uint32, self.words.dtype

    @property
    def used_bits(self) -> int:
        return self.width * self.count

    @property
    def padded_bits(self) -> int:
        return 32 * int(self.words.size)


@dataclasses.dataclass(frozen=True)
class Header:
    codec: str
    dim: int
    level: int = 0        # sampled MLMC level; 0 for single-level codecs
    nnz: int = 0          # entries in a sparse payload
    scale: float = 0.0    # f32 scale / norm header (bit pattern preserved)
    prob: float = 0.0     # f32 p_l (adaptive families ship it; else derived)
    flags: int = 0


@dataclasses.dataclass(frozen=True)
class Packet:
    header: Header
    streams: tuple[Stream, ...]

    # ---- bit accounting ----------------------------------------------------

    @property
    def payload_used_bits(self) -> int:
        return sum(s.used_bits for s in self.streams)

    @property
    def payload_padded_bits(self) -> int:
        return sum(s.padded_bits for s in self.streams)

    @property
    def payload_bytes(self) -> int:
        return sum(int(s.words.nbytes) for s in self.streams)

    @property
    def serialized_bytes(self) -> int:
        return (HEADER_STRUCT_BYTES
                + STREAM_STRUCT_BYTES * len(self.streams)
                + self.payload_bytes)

    # ---- bytes on the wire -------------------------------------------------
    # NOTE: stream names are debugging labels only and are NOT serialized —
    # codecs address streams positionally (`packet.streams[i]`), which works
    # identically on both sides of the wire.

    def to_bytes(self) -> bytes:
        h = self.header
        out = [struct.pack(_HEADER_FMT, MAGIC, CODEC_IDS[h.codec], 1,
                           h.flags, len(self.streams), h.dim, h.level, h.nnz,
                           np.float32(h.scale), np.float32(h.prob))]
        for s in self.streams:
            out.append(struct.pack(_STREAM_FMT, s.width, 0, 0, s.count,
                                   s.words.size))
            out.append(s.words.tobytes())
        return b"".join(out)

    @classmethod
    def from_bytes(cls, raw: bytes) -> "Packet":
        """Parse wire bytes, validating structure as it goes: a network
        transport sees torn frames and stray peers, so truncation, bad
        magic, unknown codec ids, inconsistent stream geometry, and
        trailing garbage all raise a descriptive `ValueError` instead of
        yielding a silently-corrupt packet."""
        if len(raw) < HEADER_STRUCT_BYTES:
            raise ValueError(
                f"truncated packet: {len(raw)} bytes < the "
                f"{HEADER_STRUCT_BYTES}-byte header")
        magic, codec_id, version, flags, n_streams, dim, level, nnz, scale, \
            prob = struct.unpack_from(_HEADER_FMT, raw, 0)
        if magic != MAGIC:
            raise ValueError(f"bad packet magic {magic!r} (want {MAGIC!r})")
        if version != 1:
            raise ValueError(f"unsupported packet version {version}")
        if codec_id not in _ID_TO_CODEC:
            raise ValueError(f"unknown codec id {codec_id}; have "
                             f"{sorted(_ID_TO_CODEC)}")
        off = HEADER_STRUCT_BYTES
        streams = []
        #: stream names are positional per codec (see codec.py stream orders)
        for i in range(n_streams):
            if len(raw) < off + STREAM_STRUCT_BYTES:
                raise ValueError(
                    f"truncated packet: stream {i}/{n_streams} header needs "
                    f"bytes [{off}, {off + STREAM_STRUCT_BYTES}) of "
                    f"{len(raw)}")
            width, _, _, count, n_words = struct.unpack_from(_STREAM_FMT,
                                                             raw, off)
            off += STREAM_STRUCT_BYTES
            if not 1 <= width <= 32:
                raise ValueError(
                    f"corrupt packet: stream {i} field width {width} "
                    "outside [1, 32]")
            min_words = -(-count // max(1, 32 // width))
            if n_words < min_words:
                raise ValueError(
                    f"corrupt packet: stream {i} declares {count} "
                    f"{width}-bit fields but only {n_words} words "
                    f"(needs >= {min_words})")
            if len(raw) < off + 4 * n_words:
                raise ValueError(
                    f"truncated packet: stream {i} wants {n_words} words "
                    f"ending at byte {off + 4 * n_words}, buffer has "
                    f"{len(raw)}")
            words = np.frombuffer(raw, np.uint32, n_words, off).copy()
            off += 4 * n_words
            streams.append(Stream(f"s{i}", words, width, count))
        if off != len(raw):
            raise ValueError(f"corrupt packet: {len(raw) - off} trailing "
                             f"bytes after the last stream")
        header = Header(_ID_TO_CODEC[codec_id], dim, level, nnz,
                        float(np.float32(scale)), float(np.float32(prob)),
                        flags)
        return cls(header, tuple(streams))


# ---------------------------------------------------------------------------
# multi-stream uplink container (RCBW)
# ---------------------------------------------------------------------------
#
# One worker's per-bucket / per-policy-segment packets in a single transport
# payload: magic, stream count, then (u32 length | packet bytes) each.  The
# bucketed plan (`repro.comm.plan`) and the per-leaf policy wire ship one of
# these per rank per round — in-process and on the tcp star's PAYLOAD frame
# alike (rank 0 dispatches on the magic).

BUCKETS_MAGIC = b"RCBW"
_BUCKETS_FMT = "<4sI"
BUCKETS_HEADER_BYTES = struct.calcsize(_BUCKETS_FMT)    # 8


def pack_bucket_payload(parts: list[bytes]) -> bytes:
    out = [struct.pack(_BUCKETS_FMT, BUCKETS_MAGIC, len(parts))]
    for p in parts:
        out.append(struct.pack("<I", len(p)))
        out.append(p)
    return b"".join(out)


def unpack_bucket_payload(raw: bytes) -> list[bytes]:
    if len(raw) < BUCKETS_HEADER_BYTES:
        raise ValueError(f"truncated bucket payload: {len(raw)} bytes")
    magic, count = struct.unpack_from(_BUCKETS_FMT, raw, 0)
    if magic != BUCKETS_MAGIC:
        raise ValueError(f"bad bucket-payload magic {magic!r}")
    parts, off = [], BUCKETS_HEADER_BYTES
    for _ in range(count):
        if off + 4 > len(raw):
            raise ValueError("truncated bucket payload: missing length")
        (n,) = struct.unpack_from("<I", raw, off)
        off += 4
        if off + n > len(raw):
            raise ValueError("truncated bucket payload: short packet")
        parts.append(raw[off:off + n])
        off += n
    if off != len(raw):
        raise ValueError(f"trailing garbage in bucket payload: "
                         f"{len(raw) - off} bytes")
    return parts


# ---------------------------------------------------------------------------
# round-tagged uplink container (RCSQ)
# ---------------------------------------------------------------------------
#
# The elastic tcp star (`repro.comm.multihost` with ``deadline_ms``) wraps
# every worker PAYLOAD/SCALAR body in this 8-byte container so rank 0 can
# tell a live round's frame from a straggler's late one: a deadline round
# closes without the slow uplinks, and whenever those bytes eventually land
# (or never do — a dropped send leaves no frame at all) the server discards
# anything tagged with an already-served round on sight instead of
# mistaking it for the current round's contribution.

SEQ_MAGIC = b"RCSQ"
_SEQ_FMT = "<4sI"
SEQ_HEADER_BYTES = struct.calcsize(_SEQ_FMT)    # 8


def pack_seq_payload(seq: int, inner: bytes) -> bytes:
    """Tag one uplink body with its round index."""
    if seq < 0:
        raise ValueError(f"round tag must be >= 0, got {seq}")
    return struct.pack(_SEQ_FMT, SEQ_MAGIC, seq) + inner


def unpack_seq_payload(raw: bytes) -> tuple[int, bytes]:
    """Inverse of `pack_seq_payload` -> (round, inner bytes)."""
    if len(raw) < SEQ_HEADER_BYTES:
        raise ValueError(f"truncated round-tagged payload: {len(raw)} bytes")
    magic, seq = struct.unpack_from(_SEQ_FMT, raw, 0)
    if magic != SEQ_MAGIC:
        raise ValueError(f"bad round-tag magic {magic!r}")
    return seq, raw[SEQ_HEADER_BYTES:]


# ---------------------------------------------------------------------------
# device header lane
# ---------------------------------------------------------------------------
#
# The jit-native device wire (`repro.comm.device_wire`) cannot carry a Python
# `Header`; its packets ship a small fixed float32 LANE next to the packed
# uint32 payload.  Slot order is part of the wire format (append-only, like
# CODEC_IDS).  Levels/counts ride as exact f32 integers (< 2^24).

#: header-lane slot indices (append-only)
LANE_SCALE, LANE_PROB, LANE_LEVEL, LANE_META = 0, 1, 2, 3
HEADER_LANE_LEN = 4

#: extended lane slots used by the COMPILED byte-wire pipeline
#: (`repro.comm.compiled`): the jitted `encode_arrays` returns one fixed
#: (EXT_LANE_LEN,) f32 lane per packet carrying every `Header` field, so the
#: host builds the byte header from a single fetched row without touching
#: the payload.  Slots 0-3 are identical to the device lane (append-only);
#: nnz/flags ride as exact f32 integers (< 2^24, like level).  This lane is
#: host-internal — it never crosses a network; the serialized byte header
#: (`Packet.to_bytes`) remains the wire format.
LANE_NNZ, LANE_FLAGS = 4, 5
EXT_LANE_LEN = 6


def header_lane(*, scale=0.0, prob=1.0, level=0, meta=0.0):
    """Build the fixed (HEADER_LANE_LEN,) f32 header lane of a DevicePacket.

    jit-traceable: any argument may be a traced jnp scalar."""
    import jax.numpy as jnp

    return jnp.stack([
        jnp.asarray(scale, jnp.float32),
        jnp.asarray(prob, jnp.float32),
        jnp.asarray(level, jnp.float32),
        jnp.asarray(meta, jnp.float32),
    ])


def lane_to_header(codec: str, dim: int, lane: np.ndarray, *,
                   nnz: int = 0, flags: int = 0) -> Header:
    """Host-side bridge: a device header lane as a byte-wire `Header` (used
    by tests and telemetry to cross-check the two packet families)."""
    lane = np.asarray(lane, np.float32)
    return Header(codec, dim, level=int(lane[LANE_LEVEL]), nnz=nnz,
                  scale=float(lane[LANE_SCALE]), prob=float(lane[LANE_PROB]),
                  flags=flags)


def ext_lane(*, scale=0.0, prob=1.0, level=0, meta=0.0, nnz=0, flags=0):
    """Build the fixed (EXT_LANE_LEN,) f32 extended lane of the compiled
    codec pipeline.  jit-traceable: any argument may be a traced scalar."""
    import jax.numpy as jnp

    return jnp.stack([
        jnp.asarray(scale, jnp.float32),
        jnp.asarray(prob, jnp.float32),
        jnp.asarray(level, jnp.float32),
        jnp.asarray(meta, jnp.float32),
        jnp.asarray(nnz, jnp.float32),
        jnp.asarray(flags, jnp.float32),
    ])


def ext_lane_to_header(codec: str, dim: int, lane: np.ndarray) -> Header:
    """One fetched extended-lane row -> the byte-wire `Header` (float slots
    keep their exact f32 bit patterns; int slots are exact f32 integers)."""
    lane = np.asarray(lane, np.float32)
    return Header(codec, dim, level=int(lane[LANE_LEVEL]),
                  nnz=int(lane[LANE_NNZ]), scale=float(lane[LANE_SCALE]),
                  prob=float(lane[LANE_PROB]), flags=int(lane[LANE_FLAGS]))


def f32_stream(name: str, values: np.ndarray) -> Stream:
    """Raw float32 values as a width-32 stream (bit patterns preserved)."""
    v = np.ascontiguousarray(np.asarray(values, np.float32))
    return Stream(name, v.view(np.uint32).reshape(-1), 32, int(v.size))


def f32_from_stream(s: Stream) -> np.ndarray:
    return s.words.view(np.float32)[: s.count]
