"""Wire codecs — ``encode(gradient) -> Packet`` / ``decode(Packet) -> array``
for every compressor family in the `make_aggregator` registry.

Until this module existed the repo only *accounted* bits
(`repro.core.bits`, `AggregateOut.bits`); nothing ever produced the bytes.
Each codec here re-runs the family's own jnp compression math (same ops,
same PRNG keys), extracts the structured payload (indices, bit-planes,
quantization codes), bit-packs it with the Pallas kernels in
:mod:`repro.comm.pack_kernels`, and can reconstruct the in-memory estimate
**value-exactly** from the packet alone.  That turns the bit ledger from an
assertion into a measurement: `reconcile_bounds` states, per codec, exactly
how far the measured packet may sit from the `repro.core.bits` formula and
why (word padding, f32-vs-f64 headers, ...).

Exactness contract: ``decode(encode(v, rng).packet)`` equals
``encode(v, rng).estimate`` elementwise (IEEE-equal; ±0 may collapse).  The
decode path replays the *same float32 operations in the same order* as the
in-memory compressor, so every multiply/divide rounds identically.

Documented deviations surfaced by measuring instead of asserting:

* `natural` — float32 exponents span [-148, 129]: 9 bits, not the 8 the
  9d ledger assumes -> measured ~ 10d/9d of nominal.
* `mlmc_float` — conversely f32 needs only a 9-bit exponent where the
  paper's fp64 accounting charges 11 -> measured ~ 12d vs the 13d ledger.
* `mlmc_rtn` — the level-l RTN residual has NO compact closed form (§3.2:
  no importance-sampling interpretation).  The honest wire format ships the
  level-l codes (l bits/entry) plus a {-1,0,+1} refinement correction
  (2 bits/entry).  The ledger now books exactly that
  (`bits.rtn_mlmc_bits`, ~(l+2) bits/entry per draw) — the former 2d
  "fixed-point analogy" entry this codec's measurements exposed is gone.
* MLMC top-level draws (l = L) — ``C^L = id`` has no plane/segment form, so
  the dense f32 residual ships (probability ~2^-L under Lemma 3.3).
"""

from __future__ import annotations

import abc
import bisect
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.pack_kernels import fields_per_word, pack_bits, unpack_bits
from repro.comm.packets import (
    FLAG_DENSE_FALLBACK,
    FLAG_EXPLICIT_PROB,
    Header,
    Packet,
    Stream,
    f32_from_stream,
    f32_stream,
)
from repro.core import bits as bitcost
from repro.core.bitwise import (
    _BELOW_ONE,
    FixedPointMultilevel,
    FloatingPointMultilevel,
    _fixed_scale,
)
from repro.core.mlmc import mlmc_estimate
from repro.core.rtn import RTNMultilevel
from repro.core.topk import STopKMultilevel, topk_mask
from repro.kernels import select
from repro.core.types import Array, PRNGKey

_EPS = 1e-30


def _np32(x) -> np.ndarray:
    return np.asarray(x, np.float32)


def _pack_stream(name: str, codes: np.ndarray, width: int) -> Stream:
    codes = np.asarray(codes, np.uint32)
    words = np.asarray(pack_bits(jnp.asarray(codes), width), np.uint32)
    return Stream(name, words, width, int(codes.size))


def _unpack_stream(s: Stream) -> np.ndarray:
    return np.asarray(unpack_bits(jnp.asarray(s.words), s.width, s.count))


def _padding_bits(count: int, width: int) -> int:
    """Exact word-padding overhead of one packed stream."""
    f = fields_per_word(width)
    return (-(-count // f)) * 32 - count * width


def _index_bits(d: int) -> int:
    return math.ceil(math.log2(max(d, 2)))


@dataclasses.dataclass(frozen=True)
class EncodeResult:
    packet: Packet
    estimate: np.ndarray   # the abstract in-memory estimate (f32, dense)


class WireCodec(abc.ABC):
    """One compressor family as a byte-exact wire format."""

    name: str
    dim: int

    @abc.abstractmethod
    def encode(self, v: Array, rng: PRNGKey | None) -> EncodeResult:
        """Compress ``v`` exactly as the abstract aggregator would and emit
        the packet plus the reference estimate."""

    @abc.abstractmethod
    def decode(self, packet: Packet) -> np.ndarray:
        """Reconstruct the dense estimate from the packet alone."""

    @abc.abstractmethod
    def nominal_bits(self) -> float:
        """The `repro.core.bits` ledger value the aggregator reports."""

    def header_bits(self, packet: Packet) -> float:
        """Idealized header content (scale/prob/level) in bits."""
        return 0.0

    def measured_bits(self, packet: Packet) -> float:
        """What actually sits in the packet: padded payload + header."""
        return packet.payload_padded_bits + self.header_bits(packet)

    def reconcile_bounds(self, packet: Packet) -> tuple[float, float]:
        """(lo, hi) range the measured bits must fall in around
        `nominal_bits`, with the derivation documented per codec."""
        n = self.nominal_bits()
        return n, n

    def roundtrip(self, v: Array, rng: PRNGKey | None = None) -> EncodeResult:
        return self.encode(v, rng)


# ---------------------------------------------------------------------------
# single-level baselines
# ---------------------------------------------------------------------------


class DenseCodec(WireCodec):
    """Alg. 1 baseline: the raw f32 vector."""

    def __init__(self, dim: int):
        self.name, self.dim = "dense", dim

    def encode(self, v, rng):
        est = _np32(v)
        pkt = Packet(Header("dense", self.dim), (f32_stream("values", est),))
        return EncodeResult(pkt, est)

    def decode(self, packet):
        return f32_from_stream(packet.streams[0]).copy()

    def nominal_bits(self):
        return bitcost.dense_bits(self.dim)


class _SparseCodec(WireCodec):
    """Shared index+value wire format: nnz positions + f32 values.

    ``index_width`` mirrors each family's own ledger: the Top-k/Rand-k
    baselines account 32-bit indices (`core.topk._INDEX_BITS`), the MLMC
    segment codec accounts ceil(log2 d) (`bits.topk_mlmc_bits`).
    """

    index_width: int

    def _sparse_packet(self, name: str, idx: np.ndarray, vals: np.ndarray,
                       header: Header) -> Packet:
        return Packet(header, (
            _pack_stream("indices", idx, self.index_width),
            f32_stream("values", vals),
        ))

    def _scatter(self, packet: Packet) -> np.ndarray:
        idx = _unpack_stream(packet.streams[0])[: packet.header.nnz]
        vals = f32_from_stream(packet.streams[1])[: packet.header.nnz]
        out = np.zeros((packet.header.dim,), np.float32)
        out[idx.astype(np.int64)] = vals
        return out


class TopKCodec(_SparseCodec):
    def __init__(self, dim: int, k: int):
        self.name, self.dim, self.k = "topk", dim, k
        self.index_width = 32   # TopK.bits accounts 32-bit positions

    def encode(self, v, rng):
        del rng
        v = jnp.asarray(v, jnp.float32)
        mask = topk_mask(v, self.k)
        est = _np32(jnp.where(mask, v, 0.0))
        idx = np.flatnonzero(np.asarray(mask))
        pkt = self._sparse_packet(
            "topk", idx, est[idx], Header("topk", self.dim, nnz=idx.size))
        return EncodeResult(pkt, est)

    def decode(self, packet):
        return self._scatter(packet)

    def nominal_bits(self):
        return float(self.k) * (32 + 32)

    def reconcile_bounds(self, packet):
        n = self.nominal_bits()
        # both streams are width-32: padding is exactly 0
        return n, n


class EF21InnovationCodec(TopKCodec):
    """The EF21 / EF21-SGDM innovation message ``c_i = Top-k(target - g_i)``
    with HONEST positions: ``ceil(log2 d)`` bits per index instead of the
    Top-k baseline's 32 (the `bits.ef21_bits` ledger entry).

    The abstract `EF21.step` books exactly `bits.ef21_bits(d, k)` per
    worker, so measured-vs-booked reconciliation is tight — word padding of
    the index stream is the only slack (the same move PR 2 made for
    `mlmc_rtn`)."""

    def __init__(self, dim: int, k: int):
        super().__init__(dim, k)
        self.name = "ef21"
        self.index_width = _index_bits(dim)

    def encode(self, v, rng):
        res = super().encode(v, rng)
        hdr = dataclasses.replace(res.packet.header, codec="ef21")
        return EncodeResult(Packet(hdr, res.packet.streams), res.estimate)

    def nominal_bits(self):
        return bitcost.ef21_bits(self.dim, self.k)

    def reconcile_bounds(self, packet):
        n = self.nominal_bits()
        # Top-k of an innovation always carries exactly k entries; only the
        # ceil(log2 d)-bit index stream can pad out to a word boundary
        return n, n + _padding_bits(self.k, self.index_width)


class RandKCodec(_SparseCodec):
    def __init__(self, dim: int, k: int):
        self.name, self.dim, self.k = "randk", dim, k
        self.index_width = 32

    def encode(self, v, rng):
        if rng is None:
            raise ValueError("Rand-k is stochastic; an rng key is required")
        v = jnp.asarray(v, jnp.float32)
        # same key -> same permutation the in-memory RandK.compress draws
        perm = jax.random.permutation(rng, self.dim)
        idx = np.sort(np.asarray(perm[: self.k]))
        mask = jnp.zeros((self.dim,), bool).at[perm[: self.k]].set(True)
        est = _np32(jnp.where(mask, v * (self.dim / self.k), 0.0))
        pkt = self._sparse_packet(
            "randk", idx, est[idx], Header("randk", self.dim, nnz=idx.size))
        return EncodeResult(pkt, est)

    def decode(self, packet):
        return self._scatter(packet)

    def nominal_bits(self):
        return float(self.k) * (32 + 32)


class QSGDCodec(WireCodec):
    """Norm header + per-entry (sign | level-index) codes."""

    def __init__(self, dim: int, s: int):
        self.name, self.dim, self.s = "qsgd", dim, s
        self.level_width = math.ceil(math.log2(s + 1))
        self.width = 1 + self.level_width

    def encode(self, v, rng):
        if rng is None:
            raise ValueError("QSGD is stochastic; an rng key is required")
        v = jnp.asarray(v, jnp.float32)
        # replay QSGD.compress exactly (same ops, same key -> same rounding)
        norm = jnp.maximum(jnp.linalg.norm(v), _EPS)
        x = jnp.abs(v) / norm * self.s
        lo = jnp.floor(x)
        up = jax.random.bernoulli(rng, x - lo)
        xi = lo + up.astype(v.dtype)
        est = _np32(norm * jnp.sign(v) * xi / self.s)
        codes = (np.asarray(xi, np.uint32) << 1) | \
            (np.asarray(v) < 0).astype(np.uint32)
        hdr = Header("qsgd", self.dim, scale=float(_np32(norm)))
        pkt = Packet(hdr, (_pack_stream("codes", codes, self.width),))
        return EncodeResult(pkt, est)

    def decode(self, packet):
        codes = _unpack_stream(packet.streams[0])[: packet.header.dim]
        xi = _np32(codes >> 1)
        sgn = np.where(codes & 1, np.float32(-1.0), np.float32(1.0))
        norm = np.float32(packet.header.scale)
        # same association order as `norm * sign(v) * xi / s`
        return ((norm * sgn) * xi / np.float32(self.s)).astype(np.float32)

    def nominal_bits(self):
        return bitcost.qsgd_bits(self.dim, self.s)

    def header_bits(self, packet):
        return 32.0   # the norm

    def reconcile_bounds(self, packet):
        n = self.nominal_bits()   # d*(1 + ceil(log2(s+1))) + 32
        # only word padding of the single (1+level_width)-bit stream
        return n, n + _padding_bits(self.dim, self.width)


# ---------------------------------------------------------------------------
# Elias-gamma entropy coding of sparse signed ternary planes
# ---------------------------------------------------------------------------
#
# The `mlmc_rtn` refinement correction is a {-1, 0, +1} plane whose nonzeros
# mark entries that re-quantize across a coarse-grid cell boundary.  Shipping
# it flat costs 2 bits/entry; gamma-coding the GAPS between nonzeros (plus
# one sign bit each) costs sum_i (2*floor(log2 g_i) + 2) <= 2d bits in the
# worst case and far less on sparse planes — the measured size is what the
# ledger books (`bits.rtn_mlmc_bits(..., corr_bits=...)`).
#
# Record format, bit order LSB-first within each uint32 word (the same
# "field f at bit offset f" layout as every width-1 stream):
#     gamma(gap)   = u zeros, then the (u+1)-bit binary of gap MSB-first
#                    (gap >= 1, u = floor(log2 gap))
#     sign bit     = 1 for a -1 correction, 0 for +1


def gamma_signed_encode(corr: np.ndarray) -> tuple[np.ndarray, int, int]:
    """{-1,0,+1} plane -> (uint32 words, total bits, nonzero count)."""
    corr = np.asarray(corr)
    nz = np.flatnonzero(corr)
    n = int(nz.size)
    if n == 0:
        return np.zeros((0,), np.uint32), 0, 0
    gaps = np.diff(nz.astype(np.int64), prepend=np.int64(-1))  # >= 1
    u = (np.frexp(gaps.astype(np.float64))[1] - 1).astype(np.int64)
    rec_len = 2 * u + 2
    starts = np.concatenate([[0], np.cumsum(rec_len)[:-1]])
    total = int(rec_len.sum())
    rec = np.repeat(np.arange(n), rec_len)
    within = np.arange(total) - starts[rec]
    g, uu = gaps[rec], u[rec]
    neg = (corr[nz] < 0).astype(np.int64)[rec]
    shift = np.maximum(2 * uu - within, 0)
    bits = np.where(within < uu, 0,
                    np.where(within <= 2 * uu, (g >> shift) & 1, neg))
    pad = (-total) % 32
    bits32 = np.concatenate([bits, np.zeros((pad,), np.int64)])
    words = (bits32.reshape(-1, 32).astype(np.uint32)
             << np.arange(32, dtype=np.uint32)).sum(axis=1, dtype=np.uint64)
    return words.astype(np.uint32), total, n


def gamma_signed_decode(words: np.ndarray, nbits: int,
                        d: int) -> np.ndarray:
    """Inverse of :func:`gamma_signed_encode` -> int32 plane of length d.

    Gamma records self-delimit, so finding the record BOUNDARIES is
    inherently sequential — but that phase is a cheap pointer walk over
    the '1' positions (a few int ops per nonzero); extracting the gap
    values, signs, and output positions is fully vectorized (one ragged
    gather + ``np.add.reduceat``).  A corrupt-but-frame-valid stream (a
    bit flip survives `Packet.from_bytes`'s geometry checks) raises a
    descriptive ValueError — this decoder runs on rank 0's TCP server
    path, which must reject bad input loudly, never die on an
    IndexError."""
    out = np.zeros((d,), np.int32)
    if nbits == 0:
        return out
    w = np.asarray(words, np.uint32)
    bits = ((w[:, None] >> np.arange(32, dtype=np.uint32)) & 1) \
        .reshape(-1)[:nbits].astype(np.int64)
    ones = np.flatnonzero(bits).tolist()
    # phase 1 (sequential): record starts -> (p1, u) per record; the
    # pointer advances monotonically, so each jump is one C-level bisect
    p1s, us = [], []
    pos, j, n_ones = 0, 0, len(ones)
    while pos < nbits:
        j = bisect.bisect_left(ones, pos, j)
        if j >= n_ones:
            raise ValueError(
                "corrupt gamma stream: unary run starting at bit "
                f"{pos} never terminates within the {nbits}-bit stream")
        p1 = ones[j]
        u = p1 - pos
        if p1 + u + 1 >= nbits:
            raise ValueError(
                f"corrupt gamma stream: record at bit {pos} wants bits "
                f"up to {p1 + u + 1}, stream has {nbits}")
        p1s.append(p1)
        us.append(u)
        pos = p1 + u + 2
    # phase 2 (vectorized): gaps = the (u+1)-bit binaries, MSB-first
    p1a = np.asarray(p1s, np.int64)
    ua = np.asarray(us, np.int64)
    lens = ua + 1
    starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
    rec = np.repeat(np.arange(lens.size), lens)
    within = np.arange(int(lens.sum())) - starts[rec]
    weighted = bits[p1a[rec] + within] << (ua[rec] - within)
    gaps = np.add.reduceat(weighted, starts)
    targets = np.cumsum(gaps) - 1
    if targets[-1] >= d:
        raise ValueError(
            f"corrupt gamma stream: gaps land on entry {targets[-1]} "
            f"of a dim-{d} plane")
    out[targets] = np.where(bits[p1a + ua + 1], -1, 1)
    return out


def _rtn_grid(level: int, c: np.float32) -> tuple[np.float32, np.float32]:
    """RTN grid spacing and clip bound, replaying `rtn_quantize`'s f32
    arithmetic bit-for-bit (shared by RTNCodec and MLMCRTNCodec — the two
    decoders MUST agree with the in-memory compressor and each other)."""
    cells = np.float32(2.0) ** np.float32(level) - np.float32(1.0)
    delta = np.float32(2.0) * c / np.maximum(cells, np.float32(1.0))
    m = np.floor(cells / np.float32(2.0))
    return delta, m


class RTNCodec(WireCodec):
    """Biased plain RTN at a fixed level: scale header + l-bit grid codes."""

    def __init__(self, dim: int, level: int):
        self.name, self.dim, self.level = "rtn", dim, level

    def encode(self, v, rng):
        del rng
        v = jnp.asarray(v, jnp.float32)
        c = jnp.maximum(jnp.max(jnp.abs(v)), _EPS)
        l = jnp.asarray(self.level, jnp.float32)
        cells = 2.0 ** l - 1.0
        delta = 2.0 * c / jnp.maximum(cells, 1.0)
        m = jnp.floor(cells / 2.0)
        q = jnp.clip(jnp.round(v / jnp.maximum(delta, _EPS)), -m, m)
        est = _np32(delta * q)
        codes = (np.asarray(q) + np.asarray(m)).astype(np.uint32)
        hdr = Header("rtn", self.dim, level=self.level,
                     scale=float(_np32(c)))
        pkt = Packet(hdr, (_pack_stream("codes", codes, self.level),))
        return EncodeResult(pkt, est)

    def decode(self, packet):
        delta, m = _rtn_grid(packet.header.level,
                             np.float32(packet.header.scale))
        codes = _unpack_stream(packet.streams[0])[: packet.header.dim]
        q = _np32(codes) - _np32(m)
        return (delta * q).astype(np.float32)

    def nominal_bits(self):
        return bitcost.rtn_bits(self.dim, self.level)

    def header_bits(self, packet):
        return 32.0

    def reconcile_bounds(self, packet):
        n = self.nominal_bits()   # level*d + 32
        return n, n + _padding_bits(self.dim, self.level)


class FixedPointCodec(WireCodec):
    """Biased F-bit fixed-point truncation (the Fig. 3 'fixed2' baseline):
    scale header + per-entry (mantissa | sign) codes of F+1 bits."""

    def __init__(self, dim: int, f_bits: int):
        self.name, self.dim, self.f = "fixed2", dim, f_bits
        self.width = f_bits + 1

    def encode(self, v, rng):
        del rng
        v = jnp.asarray(v, jnp.float32)
        scale = _fixed_scale(v)
        x = jnp.minimum(jnp.abs(v) / scale, _BELOW_ONE)
        mant = jnp.floor(jnp.ldexp(x, self.f))            # in [0, 2^f)
        trunc = jnp.ldexp(mant, -self.f)
        est = _np32(scale * jnp.sign(v) * trunc)
        codes = (np.asarray(mant, np.uint32) << 1) | \
            (np.asarray(v) < 0).astype(np.uint32)
        hdr = Header("fixed2", self.dim, scale=float(_np32(scale)))
        pkt = Packet(hdr, (_pack_stream("codes", codes, self.width),))
        return EncodeResult(pkt, est)

    def decode(self, packet):
        codes = _unpack_stream(packet.streams[0])[: packet.header.dim]
        trunc = np.ldexp(_np32(codes >> 1), -self.f).astype(np.float32)
        sgn = np.where(codes & 1, np.float32(-1.0), np.float32(1.0))
        scale = np.float32(packet.header.scale)
        return ((scale * sgn) * trunc).astype(np.float32)

    def nominal_bits(self):
        return (self.f + 1.0) * self.dim + 32

    def header_bits(self, packet):
        return 32.0

    def reconcile_bounds(self, packet):
        n = self.nominal_bits()
        return n, n + _padding_bits(self.dim, self.width)


class SignSGDCodec(WireCodec):
    """1 bit/entry + scale header; exact zeros (sign(v) = 0) ride a side
    index stream so the round-trip stays lossless (gradients rarely hold
    exact zeros, so the ledger's d + 32 is met on typical payloads)."""

    def __init__(self, dim: int):
        self.name, self.dim = "signsgd", dim

    def encode(self, v, rng):
        del rng
        v = jnp.asarray(v, jnp.float32)
        scale = jnp.mean(jnp.abs(v))
        est = _np32(jnp.sign(v) * scale)
        vn = np.asarray(v)
        bits = (vn > 0).astype(np.uint32)
        zeros = np.flatnonzero(vn == 0).astype(np.uint32)
        hdr = Header("signsgd", self.dim, nnz=int(zeros.size),
                     scale=float(_np32(scale)))
        pkt = Packet(hdr, (_pack_stream("signs", bits, 1),
                           _pack_stream("zeros", zeros, 32)))
        return EncodeResult(pkt, est)

    def decode(self, packet):
        d = packet.header.dim
        bits = _unpack_stream(packet.streams[0])[:d]
        sgn = np.where(bits, np.float32(1.0), np.float32(-1.0))
        zeros = _unpack_stream(packet.streams[1])[: packet.header.nnz]
        sgn[zeros.astype(np.int64)] = np.float32(0.0)
        return (sgn * np.float32(packet.header.scale)).astype(np.float32)

    def nominal_bits(self):
        return bitcost.dense_bits(self.dim, 1) + 32   # d + 32

    def header_bits(self, packet):
        return 32.0

    def reconcile_bounds(self, packet):
        n = self.nominal_bits()
        # + word padding of the sign plane + 32 per exact-zero exception
        return n, n + _padding_bits(self.dim, 1) + 32.0 * packet.header.nnz


class NaturalCodec(WireCodec):
    """Sign + exponent per entry.  f32 frexp exponents span [-148, 129], so
    the honest width is 1 + 9 bits — the 9d ledger assumes an 8-bit exponent
    and is ~10% optimistic for float32 payloads (documented deviation)."""

    _EXP_OFFSET = 151   # frexp exponent + offset in [1, 281); 0 = exact zero
    WIDTH = 10

    def __init__(self, dim: int):
        self.name, self.dim = "natural", dim

    def encode(self, v, rng):
        if rng is None:
            raise ValueError("natural compression is stochastic; rng needed")
        v = jnp.asarray(v, jnp.float32)
        # replay NaturalCompression.compress (same ops, same key)
        m, e = jnp.frexp(jnp.where(v == 0.0, 1.0, v))
        lo = jnp.ldexp(jnp.sign(m) * 0.5, e)
        hi = jnp.ldexp(jnp.sign(m) * 1.0, e)
        p_hi = 2.0 * jnp.abs(m) - 1.0
        take_hi = jax.random.bernoulli(rng, jnp.clip(p_hi, 0.0, 1.0))
        est = _np32(jnp.where(v == 0.0, 0.0, jnp.where(take_hi, hi, lo)))
        # the emitted value is +-2^(e2): recover its own frexp exponent
        m2, e2 = np.frexp(np.where(est == 0.0, np.float32(1.0), est))
        ecode = np.where(est == 0.0, 0,
                         e2 + self._EXP_OFFSET).astype(np.uint32)
        codes = (ecode << 1) | (est < 0).astype(np.uint32)
        pkt = Packet(Header("natural", self.dim),
                     (_pack_stream("codes", codes, self.WIDTH),))
        return EncodeResult(pkt, est)

    def decode(self, packet):
        codes = _unpack_stream(packet.streams[0])[: packet.header.dim]
        ecode = (codes >> 1).astype(np.int64)
        sgn = np.where(codes & 1, np.float32(-0.5), np.float32(0.5))
        out = np.ldexp(sgn, ecode - self._EXP_OFFSET).astype(np.float32)
        return np.where(ecode == 0, np.float32(0.0), out)

    def nominal_bits(self):
        return 9.0 * self.dim

    def reconcile_bounds(self, packet):
        n = self.nominal_bits()
        # documented: +1 bit/entry (9-bit f32 exponent range) + word padding
        return n, n + self.dim + _padding_bits(self.dim, self.WIDTH)


# ---------------------------------------------------------------------------
# MLMC families
# ---------------------------------------------------------------------------


def _static_prob(compressor, level: int) -> np.float32:
    """Replay mlmc_estimate's normalization to recover p_l decode-side."""
    probs = compressor.static_probs()
    probs = probs / jnp.sum(probs)
    return _np32(jnp.maximum(probs[level - 1], 1e-30))


class _MLMCCodecBase(WireCodec):
    """Shared MLMC plumbing: run the real `mlmc_estimate` (same jnp ops the
    abstract aggregator uses), ship level (+ p_l when adaptive), and let the
    subclass pack / unpack the level-l residual."""

    compressor = None
    adaptive = False

    def _estimate(self, v, rng, probs=None):
        return mlmc_estimate(self.compressor, jnp.asarray(v, jnp.float32),
                             rng, probs=probs, adaptive=self.adaptive)

    def _prob_for(self, packet: Packet) -> np.float32:
        if self.adaptive or (packet.header.flags & FLAG_EXPLICIT_PROB):
            return np.float32(packet.header.prob)
        return _static_prob(self.compressor, packet.header.level)

    def _prob_flag(self, probs) -> int:
        return FLAG_EXPLICIT_PROB if (probs is not None and
                                      not self.adaptive) else 0

    def level_header_bits(self) -> float:
        return math.ceil(math.log2(max(self.compressor.num_levels, 2)))


class MLMCTopKCodec(_MLMCCodecBase):
    """(s-)Top-k MLMC: one magnitude-rank segment of <= s entries — values
    at 32 bits, positions at ceil(log2 d) bits, exactly the
    `bits.topk_mlmc_bits` ledger."""

    def __init__(self, dim: int, s: int, *, adaptive: bool = True,
                 name: str = "mlmc_topk"):
        self.name, self.dim, self.adaptive = name, dim, adaptive
        self.compressor = STopKMultilevel(d=dim, s=s)
        self.index_width = _index_bits(dim)

    def encode(self, v, rng, probs=None):
        v = jnp.asarray(v, jnp.float32)
        est = self._estimate(v, rng, probs)
        level = int(est.level)
        s = self.compressor.s
        mask = np.asarray(select.band_mask(v, (level - 1) * s, level * s))
        idx = np.flatnonzero(mask)
        residual = np.asarray(est.residual)
        hdr = Header(self.name, self.dim, level=level,
                     nnz=int(idx.size), prob=float(_np32(est.prob)),
                     flags=self._prob_flag(probs))
        pkt = Packet(hdr, (
            _pack_stream("indices", idx, self.index_width),
            f32_stream("values", residual[idx]),
        ))
        return EncodeResult(pkt, _np32(est.estimate))

    def decode(self, packet):
        h = packet.header
        idx = _unpack_stream(packet.streams[0])[: h.nnz]
        vals = f32_from_stream(packet.streams[1])[: h.nnz]
        residual = np.zeros((h.dim,), np.float32)
        residual[idx.astype(np.int64)] = vals
        return (residual / self._prob_for(packet)).astype(np.float32)

    def nominal_bits(self):
        return bitcost.topk_mlmc_bits(self.dim, self.compressor.s)

    def _explicit_prob(self, packet):
        return self.adaptive or bool(packet.header.flags & FLAG_EXPLICIT_PROB)

    def header_bits(self, packet):
        # level index (+ p_l whenever it actually ships: the adaptive Alg. 3
        # variant and the stateful EMA family's explicit-prob packets)
        return self.level_header_bits() + \
            (32.0 if self._explicit_prob(packet) else 0.0)

    def reconcile_bounds(self, packet):
        n = self.nominal_bits()   # s*(32 + ceil(log2 d)) + ceil(log2 L)
        s = self.compressor.s
        pad = _padding_bits(s, self.index_width)
        # last segment may carry fewer than s entries (d mod s), and a
        # shipped p_l adds 32 bits on top of the ledger header
        short = (s - packet.header.nnz) * (32 + self.index_width)
        return n - short, n + pad + \
            (32.0 if self._explicit_prob(packet) else 0.0)


class MLMCFixedCodec(_MLMCCodecBase):
    """§3.1 fixed point: 32-bit max-magnitude header + level index + one
    ternary bit-plane at 2 bits/entry.  Top-level draws (C^L = id) ship the
    dense f32 residual under FLAG_DENSE_FALLBACK."""

    def __init__(self, dim: int, num_bits: int = 24):
        self.name, self.dim = "mlmc_fixed", dim
        self.compressor = FixedPointMultilevel(num_bits=num_bits)
        self.adaptive = False

    def encode(self, v, rng, probs=None):
        v = jnp.asarray(v, jnp.float32)
        est = self._estimate(v, rng, probs)
        level = int(est.level)
        scale = _fixed_scale(v)
        residual = np.asarray(est.residual)
        if level >= self.compressor.num_levels:
            hdr = Header("mlmc_fixed", self.dim, level=level,
                         scale=float(_np32(scale)), prob=float(_np32(est.prob)),
                         flags=FLAG_DENSE_FALLBACK | self._prob_flag(probs))
            pkt = Packet(hdr, (f32_stream("residual", residual),))
            return EncodeResult(pkt, _np32(est.estimate))
        tern = np.sign(residual).astype(np.int64)        # {-1, 0, +1}
        hdr = Header("mlmc_fixed", self.dim, level=level,
                     scale=float(_np32(scale)), prob=float(_np32(est.prob)),
                     flags=self._prob_flag(probs))
        pkt = Packet(hdr, (_pack_stream("plane", (tern + 1).astype(np.uint32),
                                        2),))
        return EncodeResult(pkt, _np32(est.estimate))

    def decode(self, packet):
        h = packet.header
        p = self._prob_for(packet)
        if h.flags & FLAG_DENSE_FALLBACK:
            residual = f32_from_stream(packet.streams[0])[: h.dim].copy()
        else:
            tern = _np32(_unpack_stream(packet.streams[0])[: h.dim]) \
                - np.float32(1.0)
            # same order as `scale * sign(v) * ldexp(bit, -l)`
            residual = ((np.float32(h.scale) * tern)
                        * np.float32(np.ldexp(1.0, -h.level)))
        return (residual / p).astype(np.float32)

    def nominal_bits(self):
        return bitcost.fixed_point_mlmc_bits(self.dim,
                                             self.compressor.num_levels)

    def header_bits(self, packet):
        return 32.0 + self.level_header_bits()

    def reconcile_bounds(self, packet):
        n = self.nominal_bits()   # 2d + 64 + ceil(log2 L)
        if packet.header.flags & FLAG_DENSE_FALLBACK:
            # dense C^L residual: 32d instead of 2d (probability ~2^-L)
            return n, n + 30.0 * self.dim
        # our scale header is f32 (32 bits) where the paper charges 64
        return n - 32.0, n + _padding_bits(self.dim, 2)


class MLMCFloatCodec(_MLMCCodecBase):
    """App. B floating point: always-transmitted sign+exponent plane
    (2 + 9 bits/entry in f32) plus a 1-bit mantissa plane."""

    _EXP_OFFSET = 150

    def __init__(self, dim: int, num_bits: int = 23):
        self.name, self.dim = "mlmc_float", dim
        self.compressor = FloatingPointMultilevel(num_bits=num_bits)
        self.adaptive = False

    def encode(self, v, rng, probs=None):
        v = jnp.asarray(v, jnp.float32)
        est = self._estimate(v, rng, probs)
        level = int(est.level)
        m, e = self.compressor._mantissa_exp(v)
        sgn = np.asarray(jnp.sign(m), np.int64)            # {-1, 0, +1}
        ecode = (np.asarray(e, np.int64) + self._EXP_OFFSET).astype(np.uint32)
        base_codes = (ecode << 2) | (sgn + 1).astype(np.uint32)
        streams = [_pack_stream("base", base_codes, 11)]
        if level >= self.compressor.num_levels:
            flags = FLAG_DENSE_FALLBACK | self._prob_flag(probs)
            streams.append(f32_stream("residual", np.asarray(est.residual)))
        else:
            flags = self._prob_flag(probs)
            bit = np.asarray(
                jnp.mod(jnp.floor(jnp.ldexp(jnp.abs(m), level + 1)), 2.0),
                np.uint32)
            streams.append(_pack_stream("plane", bit, 1))
        hdr = Header("mlmc_float", self.dim, level=level,
                     prob=float(_np32(est.prob)), flags=flags)
        return EncodeResult(Packet(hdr, tuple(streams)), _np32(est.estimate))

    def decode(self, packet):
        h = packet.header
        base_codes = _unpack_stream(packet.streams[0])[: h.dim]
        sgn = _np32(base_codes & 3) - np.float32(1.0)
        e = (base_codes >> 2).astype(np.int64) - self._EXP_OFFSET
        base = np.ldexp(sgn * np.float32(0.5), e).astype(np.float32)
        if h.flags & FLAG_DENSE_FALLBACK:
            residual = f32_from_stream(packet.streams[1])[: h.dim].copy()
        else:
            bit = _np32(_unpack_stream(packet.streams[1])[: h.dim])
            residual = np.ldexp(sgn * bit,
                                e - (h.level + 1)).astype(np.float32)
        p = self._prob_for(packet)
        return (base + residual / p).astype(np.float32)

    def nominal_bits(self):
        return bitcost.floating_point_mlmc_bits(self.dim,
                                                self.compressor.num_levels)

    def header_bits(self, packet):
        return self.level_header_bits()

    def reconcile_bounds(self, packet):
        n = self.nominal_bits()   # 13d + log2(L)
        if packet.header.flags & FLAG_DENSE_FALLBACK:
            return n - 2.0 * self.dim, n + 32.0 * self.dim
        # f32 exponents need 9 bits, not the fp64 ledger's 11: measured sits
        # ~1 bit/entry BELOW nominal, plus word padding on both planes
        pad = _padding_bits(self.dim, 11) + _padding_bits(self.dim, 1)
        return n - 2.0 * self.dim, n + pad


class MLMCRTNCodec(_MLMCCodecBase):
    """Adaptive MLMC-RTN (Alg. 3, App. G.2).  The residual C^l - C^{l-1}
    has no sparse/bit-plane form, so the honest wire format is the level-l
    grid codes (l bits/entry) plus a {-1,0,+1} correction that turns the
    decoder's re-quantization of C^l onto the coarse grid into the true
    C^{l-1}.

    The ``mlmc_rtn`` wire (codec id 13) ENTROPY-CODES that correction:
    nonzeros are Elias-gamma gap + sign records (`gamma_signed_encode`),
    so the stream measures its actual information content (<= 2d bits
    worst-case, typically well under the flat plane) and the ledger books
    the measured size (`bits.rtn_mlmc_bits(..., corr_bits=...)`) — its
    golden fixture was deliberately regenerated for this PR.  The stateful
    ``mlmc_adaptive_rtn`` wire (codec id 17) keeps the flat 2-bit plane:
    wire formats are append-only, and its fixture stays byte-identical
    until its own versioned change."""

    def __init__(self, dim: int, num_bits: int = 8, *, adaptive: bool = True,
                 name: str = "mlmc_rtn"):
        # adaptive=False is the stateful EMA family (`mlmc_adaptive_rtn`):
        # the caller supplies the Lemma-3.4 probabilities per encode (they
        # come from the CommState ladder) and they ship in the header under
        # FLAG_EXPLICIT_PROB.
        self.name, self.dim = name, dim
        self.compressor = RTNMultilevel(num_bits=num_bits)
        self.adaptive = adaptive
        #: gamma-coded correction stream (the PR-5 wire evolution) — only
        #: the mlmc_rtn format; see the class docstring
        self.entropy_corr = name == "mlmc_rtn"

    def encode(self, v, rng, probs=None):
        v = jnp.asarray(v, jnp.float32)
        est = self._estimate(v, rng, probs)
        level = int(est.level)
        c = np.float32(jnp.maximum(jnp.max(jnp.abs(v)), _EPS))
        hdr_kw = dict(level=level, scale=float(c),
                      prob=float(_np32(est.prob)))
        if level >= self.compressor.num_levels:
            hdr = Header(self.name, self.dim,
                         flags=FLAG_DENSE_FALLBACK | self._prob_flag(probs),
                         **hdr_kw)
            pkt = Packet(hdr, (f32_stream("residual",
                                          np.asarray(est.residual)),))
            return EncodeResult(pkt, _np32(est.estimate))

        q_l, m_l = self._codes(v, level, c)
        streams = [_pack_stream("q", (q_l + m_l).astype(np.uint32),
                                max(level, 1))]
        nnz = 0
        if level > 1:
            q_prev, m_prev = self._codes(v, level - 1, c)
            q_hat = self._requant(self._values(q_l, level, c), level - 1, c)
            corr = q_prev - q_hat
            assert np.abs(corr).max(initial=0) <= 1, \
                "RTN refinement correction left {-1,0,1} (delta_l < " \
                "delta_{l-1}/2 should make this impossible)"
            if self.entropy_corr:
                words, nbits, nnz = gamma_signed_encode(corr)
                streams.append(Stream("corr", words, 1, nbits))
            else:
                streams.append(_pack_stream("corr",
                                            (corr + 1).astype(np.uint32), 2))
        hdr = Header(self.name, self.dim, nnz=nnz,
                     flags=self._prob_flag(probs), **hdr_kw)
        return EncodeResult(Packet(hdr, tuple(streams)), _np32(est.estimate))

    # -- grid helpers built on the shared `_rtn_grid` -----------------------

    @staticmethod
    def _codes(v, level: int, c: np.float32):
        delta, m = _rtn_grid(level, c)
        vn = np.asarray(v, np.float32)
        q = np.clip(np.round(vn / np.maximum(delta, np.float32(_EPS))),
                    -m, m)
        return q.astype(np.int64), np.int64(m)

    @staticmethod
    def _values(q: np.ndarray, level: int, c: np.float32) -> np.ndarray:
        delta, _ = _rtn_grid(level, c)
        return (delta * _np32(q)).astype(np.float32)

    @staticmethod
    def _requant(values: np.ndarray, level: int, c: np.float32):
        delta, m = _rtn_grid(level, c)
        q = np.clip(np.round(values / np.maximum(delta, np.float32(_EPS))),
                    -m, m)
        return q.astype(np.int64)

    def decode(self, packet):
        h = packet.header
        p = np.float32(h.prob)
        if h.flags & FLAG_DENSE_FALLBACK:
            residual = f32_from_stream(packet.streams[0])[: h.dim].copy()
            return (residual / p).astype(np.float32)
        c = np.float32(h.scale)
        _, m_l = _rtn_grid(h.level, c)
        q_l = _np32(_unpack_stream(packet.streams[0])[: h.dim]) - _np32(m_l)
        vals_l = self._values(q_l.astype(np.int64), h.level, c)
        if h.level <= 1:
            residual = vals_l - np.float32(0.0)
        else:
            s = packet.streams[1]
            if self.entropy_corr:
                corr = gamma_signed_decode(s.words, s.count, h.dim) \
                    .astype(np.int64)
            else:
                corr = (_unpack_stream(s)[: h.dim].astype(np.int64) - 1)
            q_prev = self._requant(vals_l, h.level - 1, c) + corr
            residual = vals_l - self._values(q_prev, h.level - 1, c)
        return (residual / p).astype(np.float32)

    def nominal_bits(self):
        # expectation of the honest per-level cost under the static
        # Lemma-3.3 distribution (the aggregator books the per-draw value)
        return bitcost.rtn_mlmc_expected_bits(self.dim,
                                              self.compressor.num_levels)

    def nominal_bits_for(self, level: int, corr_bits=None) -> float:
        """The honest per-draw ledger value for one sampled level; pass the
        MEASURED gamma-stream size as ``corr_bits`` to book the
        entropy-coded wire exactly."""
        return float(bitcost.rtn_mlmc_bits(self.dim, level,
                                           self.compressor.num_levels,
                                           corr_bits=corr_bits))

    def header_bits(self, packet):
        return 64.0 + self.level_header_bits()   # scale + p_l + level

    def reconcile_bounds(self, packet):
        level = packet.header.level
        if packet.header.flags & FLAG_DENSE_FALLBACK:
            # honest formula already charges 32d; only header slack remains
            n = self.nominal_bits_for(level)
            return n - 32.0, n + 32.0
        corr_bits = None
        pad = _padding_bits(self.dim, max(level, 1))
        if level > 1:
            corr = packet.streams[1]
            if self.entropy_corr:
                # book the measured gamma stream: bounds stay tight around
                # the data-dependent size instead of absorbing a 2d gap
                corr_bits = float(corr.used_bits)
                pad += corr.padded_bits - corr.used_bits
            else:
                pad += _padding_bits(self.dim, 2)
        n = self.nominal_bits_for(level, corr_bits=corr_bits)
        return n - 32.0, n + pad + 32.0


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def make_codec(name: str, dim: int, *, k_fraction: float = 0.01, s: int = 1,
               rtn_level: int = 4, qsgd_levels: int = 2,
               fixed_levels: int = 24) -> WireCodec:
    """Build the wire codec matching ``make_aggregator(name, dim, ...)``.

    For the EF21 family the *innovation* compressor's codec is returned
    (that is what crosses the wire each step — see `PackedEF21`)."""
    k = max(1, int(round(k_fraction * dim)))
    if name == "dense":
        return DenseCodec(dim)
    if name == "topk":
        return TopKCodec(dim, k)
    if name in ("ef21", "ef21_sgdm"):
        return EF21InnovationCodec(dim, k)
    if name == "randk":
        return RandKCodec(dim, k)
    if name == "qsgd":
        return QSGDCodec(dim, qsgd_levels)
    if name == "rtn":
        return RTNCodec(dim, rtn_level)
    if name == "fixed2":
        return FixedPointCodec(dim, 2)
    if name in ("signsgd", "signsgd_ef"):
        return SignSGDCodec(dim)
    if name == "natural":
        return NaturalCodec(dim)
    if name in ("mlmc_topk", "mlmc_topk_static", "mlmc_stopk",
                "mlmc_adaptive_topk", "mlmc_adaptive_stopk"):
        from repro.core.aggregators import mlmc_topk_segment

        # the stateful EMA family carries its Lemma-3.4 probabilities in
        # CommState and passes them explicitly at encode time, so its codec
        # is adaptive=False (FLAG_EXPLICIT_PROB ships p_l in the header)
        return MLMCTopKCodec(dim, mlmc_topk_segment(name, k, s),
                             adaptive=name in ("mlmc_topk", "mlmc_stopk"),
                             name=name)
    if name == "mlmc_fixed":
        return MLMCFixedCodec(dim, fixed_levels)
    if name == "mlmc_float":
        return MLMCFloatCodec(dim)
    if name == "mlmc_rtn":
        return MLMCRTNCodec(dim)
    if name == "mlmc_adaptive_rtn":
        return MLMCRTNCodec(dim, adaptive=False, name=name)
    raise ValueError(f"no wire codec for {name!r}")
