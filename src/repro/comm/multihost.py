"""Multi-host packed wire — a TCP socket star for the byte packets.

Every transport before this one ran in-process with a simulated alpha-beta
clock.  `TcpStarTransport` moves the *actual* `Packet.to_bytes()` payloads
between OS processes: rank 0 is the aggregation point (the paper's parameter
server), ranks 1..W-1 connect to it over TCP, and every uplink/downlink is a
length-prefixed frame whose bytes and wall-clock are **measured**, never
modeled.  `TransportStats.sim_time_s` stays 0 on this transport;
`wall_time_s` holds real `perf_counter` deltas.

Frame protocol (all little-endian, append-only like the packet header):

    <4s  B      B     H      I>        then `length` payload bytes
    RCMH type   rank  world  length

* ``HELLO``     worker -> server on connect; payload is the protocol token,
  server validates (rank, world, token) and replies ``WELCOME`` or
  ``GOODBYE`` + reason.
* ``PAYLOAD``   worker -> server, one serialized `Packet` per round.
* ``DIRECTION`` server -> workers, the aggregated direction blob
  (see `repro.comm.aggregate`).
* ``DIRECTION_ENC`` server -> workers, the COMPRESSED direction blob: a
  16-byte RCD2 header followed by one serialized `Packet` the downlink
  codec decodes against the rank's mirrored DIANA shift
  (`repro.comm.aggregate.pack_encoded_direction`).
* ``STATE``     worker -> server, one rank's client-side `CommState` rows
  (`repro.comm.aggregate.pack_comm_state_row`), gathered by
  `gather_state` at checkpoint time so a rank-0 checkpoint captures
  every rank's EMA ladder / momentum / downlink-shift rows.
* ``PING``/``PONG``  heartbeats.  The server pings every link while its
  reactor waits; a worker answers PONG and resets its read deadline, so a
  long server compute (first-round jit) never looks like a dead peer —
  and a genuinely dead rank 0 turns a forever-block into a descriptive
  `TransportError` after ``read_timeout_s``.
* ``LEAVE``     worker -> server on clean close (an elastic server marks
  the rank left instead of dying on a bare reset).
* ``REJOIN``    worker -> server mid-run (elastic mode): same payload as
  HELLO; the server validates it, replies WELCOME (carrying the round in
  flight), a STATE frame with the rank's last stored `CommState` row, and
  a DIRECTION frame with the current-params snapshot from
  ``snapshot_provider`` — then the rank is a full member again.

Elastic mode (``deadline_ms`` not None) relaxes the fixed-healthy-world
assumption end to end: `exchange` on rank 0 closes each round
``deadline_ms`` after it starts and serves whoever arrived (the partial
round is reweighted unbiasedly in `repro.comm.aggregate` — see
`repro.comm.elastic`); dead links mark the rank left instead of raising;
the listener keeps accepting REJOINs mid-run; and every worker
PAYLOAD/SCALAR body rides a `repro.comm.packets.pack_seq_payload` round
tag so a straggler's late frame is discarded on sight, never mistaken for
the current round.  With ``deadline_ms=None`` the transport behaves
exactly as before (fixed world, any dead link raises).

Stats semantics (cross-transport comparability is the point):

* ``bytes_up`` counts *payload* bytes.  On rank 0 — the aggregation
  point, the vantage the in-process transports model — it covers all
  ``world`` ranks including rank 0's loopback contribution, so identical
  uplink traffic books identical numbers on `LoopbackTransport` and
  here; worker ranks see only their own link and book only that.
* ``bytes_down`` on rank 0 books only the ``world - 1`` REAL socket
  sends of each broadcast, frame headers included — rank 0's in-process
  loopback copy never crosses a wire and is no longer counted (it used
  to be booked as ``payload * world``, silently inflating every
  compressed-downlink ratio by ``world/(world-1)``).  A worker books its
  own received payload.  `LoopbackTransport` keeps its modeled
  ``payload * world`` accounting, so the documented cross-transport
  relation is ``tcp_down == (world-1)/world * loopback_down`` plus the
  per-send frame-header bytes (regression-tested in
  ``tests/test_multihost.py``).
* ``wire_bytes`` counts what actually crossed a socket on this process
  (frame headers included): the honest per-link measurement.

One rank hosts exactly one worker; `repro.launch.multihost` spawns a
localhost world, `--transport tcp` in `repro.launch.train` joins one rank.
"""

from __future__ import annotations

import contextlib
import selectors
import socket
import struct
import time

from repro.comm.elastic import BackoffSchedule, Membership
from repro.comm.packets import pack_seq_payload, unpack_seq_payload
from repro.comm.transport import TransportStats
from repro.obs import trace as obs

FRAME_MAGIC = b"RCMH"
_FRAME_FMT = "<4sBBHI"                 # magic, type, rank, world, payload len
FRAME_HEADER_BYTES = struct.calcsize(_FRAME_FMT)   # 12

#: frame types (append-only)
HELLO, WELCOME, GOODBYE, PAYLOAD, DIRECTION = 1, 2, 3, 4, 5
SCALAR, SCALAR_MEAN = 6, 7     # loss-telemetry allreduce (8-byte f64)
STATE = 8                      # checkpoint gather of client CommState rows
DIRECTION_ENC = 9              # compressed (DIANA-shift) direction blob
PING, PONG = 10, 11            # heartbeats (server pings, worker answers)
LEAVE = 12                     # worker -> server: clean departure
REJOIN = 13                    # worker -> server: mid-run re-entry (elastic)

#: server heartbeat period and the worker read deadline derived from it:
#: a worker treats rank 0 as dead after this many silent heartbeat periods
#: (generous — a slow first-round jit on the server must never trip it,
#: and the server only pings while its reactor is actually waiting)
_DEFAULT_HEARTBEAT_S = 5.0
_READ_TIMEOUT_BEATS = 36


class TransportError(ConnectionError):
    """A peer died, timed out, or desynced mid-run.  Subclasses
    `ConnectionError` so pre-elastic callers keep working."""


class ServerShutdown(TransportError):
    """Rank 0 closed the star cleanly (GOODBYE "shutdown") — a normal end
    of run, not a fault.  Workers catch this to exit gracefully."""

#: a real worker HELLOs immediately after connecting; give a stray peer
#: (port scanner, health check) at most this long before refusing it
_HELLO_GRACE_S = 2.0

#: handshake token — bump the suffix on any incompatible protocol change.
#: The HELLO payload is the token, optionally followed by ``|`` and the
#: rank's codec-policy fingerprint (`repro.comm.policy.ResolvedPolicy.hash`):
#: ranks running different per-leaf policies would desync mid-run (their
#: RCBW containers disagree segment by segment), so the server refuses the
#: handshake instead.  Old payloads (bare token) parse as "no policy".
HELLO_TOKEN = b"repro-multihost-v1"

MAX_WORLD = 255            # rank rides in a uint8 frame field
_MAX_FRAME_PAYLOAD = 1 << 31


def pick_free_port(host: str = "127.0.0.1") -> int:
    """Bind port 0, read the kernel's choice, release it (launcher helper)."""
    with contextlib.closing(socket.socket()) as s:
        s.bind((host, 0))
        return s.getsockname()[1]


def parse_coordinator(spec: str) -> tuple[str, int]:
    """``"host:port"`` -> (host, port)."""
    host, _, port = spec.rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(f"coordinator must be 'host:port', got {spec!r}")
    return host, int(port)


def is_multihost_transport(transport) -> bool:
    """True for transports whose ranks live in different OS processes (they
    carry a rank/world identity and a real payload broadcast)."""
    return (getattr(transport, "world", 0) or 0) > 0 \
        and hasattr(transport, "broadcast_payload")


def _steady_state(sock: socket.socket) -> None:
    """Post-handshake socket mode: the rendezvous timeout must NOT govern
    training rounds (a slow jit or straggler rank is healthy, not dead) —
    block indefinitely and let TCP keepalive surface dead peers."""
    sock.settimeout(None)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_KEEPALIVE, 1)


# ---------------------------------------------------------------------------
# frame I/O
# ---------------------------------------------------------------------------


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError(
                f"peer closed mid-frame: got {len(buf)} of {n} bytes")
        buf.extend(chunk)
    return bytes(buf)


def send_frame(sock: socket.socket, ftype: int, rank: int, world: int,
               payload: bytes = b"") -> int:
    """Send one frame; returns the bytes that crossed the socket."""
    sock.sendall(struct.pack(_FRAME_FMT, FRAME_MAGIC, ftype, rank, world,
                             len(payload)) + payload)
    return FRAME_HEADER_BYTES + len(payload)


def recv_frame(sock: socket.socket,
               expect: int | None = None) -> tuple[int, int, int, bytes]:
    """Receive one frame -> (type, rank, world, payload).  Raises
    `ConnectionError` on torn frames, bad magic, or an unexpected type."""
    hdr = _recv_exact(sock, FRAME_HEADER_BYTES)
    magic, ftype, rank, world, length = struct.unpack(_FRAME_FMT, hdr)
    if magic != FRAME_MAGIC:
        raise ConnectionError(f"bad frame magic {magic!r} (want "
                              f"{FRAME_MAGIC!r}) — not a multihost peer?")
    if length > _MAX_FRAME_PAYLOAD:
        raise ConnectionError(f"frame length {length} exceeds the "
                              f"{_MAX_FRAME_PAYLOAD}-byte cap")
    payload = _recv_exact(sock, length) if length else b""
    if expect is not None and ftype != expect:
        if ftype == GOODBYE:
            raise ConnectionError(
                f"peer said goodbye: {payload.decode(errors='replace')}")
        raise ConnectionError(f"expected frame type {expect}, got {ftype}")
    return ftype, rank, world, payload


class _FrameBuffer:
    """Per-connection receive buffer for the server's selectors reactor.

    Frames are reassembled incrementally from whatever bytes the socket had
    ready, so a slow rank mid-frame never blocks the ranks behind it — and
    bytes that belong to the NEXT frame (a worker may pipeline its SCALAR
    loss frame right behind its PAYLOAD) stay buffered for the next read."""

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> None:
        self._buf.extend(data)

    def next_frame(self) -> tuple[int, int, int, bytes] | None:
        """Pop one complete frame -> (type, rank, world, payload), or None
        if the buffer does not hold a full frame yet.  Raises
        `ConnectionError` on bad magic / oversized frames (same contract as
        `recv_frame`)."""
        if len(self._buf) < FRAME_HEADER_BYTES:
            return None
        magic, ftype, rank, world, length = struct.unpack_from(
            _FRAME_FMT, self._buf, 0)
        if magic != FRAME_MAGIC:
            raise ConnectionError(f"bad frame magic {magic!r} (want "
                                  f"{FRAME_MAGIC!r}) — not a multihost peer?")
        if length > _MAX_FRAME_PAYLOAD:
            raise ConnectionError(f"frame length {length} exceeds the "
                                  f"{_MAX_FRAME_PAYLOAD}-byte cap")
        end = FRAME_HEADER_BYTES + length
        if len(self._buf) < end:
            return None
        payload = bytes(self._buf[FRAME_HEADER_BYTES:end])
        del self._buf[:end]
        return ftype, rank, world, payload


# ---------------------------------------------------------------------------
# the transport
# ---------------------------------------------------------------------------


class TcpStarTransport:
    """Socket star over ``world`` OS processes; rank 0 aggregates.

    Build with `serve` (rank 0) or `connect` (ranks 1..W-1) — or via
    ``make_transport("tcp", rank=..., world=..., coordinator="host:port")``.
    Implements the `Transport` seam with multihost semantics: `exchange`
    takes THIS rank's single payload and returns all ``world`` payloads on
    rank 0 (rank-ordered) and ``[]`` on workers; `broadcast_payload` ships
    the direction blob down every link.
    """

    def __init__(self, rank: int, world: int, *,
                 heartbeat_s: float | None = None,
                 read_timeout_s: float | None = None,
                 deadline_ms: float | None = None):
        self.rank = rank
        self.world = world
        self.stats = TransportStats()
        self._policy_hash = b""      # codec-policy fingerprint (HELLO check)
        self._conns: dict[int, socket.socket] = {}   # server: rank -> socket
        self._bufs: dict[int, _FrameBuffer] = {}     # server: rank -> buffer
        self._sock: socket.socket | None = None      # worker: server link
        self._listener: socket.socket | None = None
        self._timeout: float = 60.0
        self.port: int | None = None
        #: rank order in which the last `exchange` round's uplink frames
        #: COMPLETED on the server (fan-in observability; regression surface
        #: for the selectors reactor — a slow rank lands last, not first)
        self.last_arrival_order: list[int] = []
        # per-round fan-in timing (server): round start + completion lags,
        # feeding the straggler timeline in `repro.obs`
        self._round_t0 = 0.0
        self._round_lags: list[float] = []
        # ---- elastic layer ----
        self.heartbeat_s = (_DEFAULT_HEARTBEAT_S if heartbeat_s is None
                            else float(heartbeat_s))
        self.read_timeout_s = (
            _READ_TIMEOUT_BEATS * self.heartbeat_s
            if read_timeout_s is None else float(read_timeout_s))
        self.deadline_ms = deadline_ms
        #: server-side membership/participation ledger (None on workers)
        self.membership: Membership | None = (
            Membership(world) if rank == 0 else None)
        #: rank 0 hook: ``() -> bytes`` serving the current flat params to a
        #: REJOINing rank (its own copy is stale by however many rounds it
        #: missed); the trainer installs it
        self.snapshot_provider = None
        #: ranks whose uplink made the last served round (server; elastic
        #: deadline rounds may close without the slow ones)
        self.last_participation: list[int] = list(range(world))
        self._round = -1          # server: index of the round in flight
        self._seq = 0             # worker: round tag for the next uplink
        #: highest round already SERVED per uplink frame type — an elastic
        #: server discards any later copy of those rounds on sight (a
        #: straggler's late frame, or a non-participant's unread scalar)
        self._served = {PAYLOAD: -1, SCALAR: -1}
        self._last_ping = time.perf_counter()
        self.joined_round: int | None = None   # set on a REJOINed worker

    @property
    def elastic(self) -> bool:
        """True when this transport runs the deadline/membership layer."""
        return self.deadline_ms is not None

    # ---- construction ------------------------------------------------------

    @classmethod
    def listen(cls, host: str = "127.0.0.1", port: int = 0, *, world: int,
               timeout: float = 60.0, policy_hash: str | None = None,
               heartbeat_s: float | None = None,
               read_timeout_s: float | None = None,
               deadline_ms: float | None = None) -> "TcpStarTransport":
        """Rank 0, step 1: bind ``host:port`` (0 = ephemeral; the kernel's
        choice lands in ``.port``) without blocking.  Call
        `accept_workers` to run the rendezvous.  ``policy_hash`` is this
        rank's codec-policy fingerprint — workers whose HELLO carries a
        different one are refused (fail fast at rendezvous, not desync
        mid-run).  ``deadline_ms`` turns on elastic mode (see module doc);
        pass the same value on every rank."""
        if not 2 <= world <= MAX_WORLD:
            raise ValueError(f"world must be in [2, {MAX_WORLD}], got {world}")
        t = cls(0, world, heartbeat_s=heartbeat_s,
                read_timeout_s=read_timeout_s, deadline_ms=deadline_ms)
        t._policy_hash = (policy_hash or "").encode()
        t._listener = socket.create_server((host, port))
        t.port = t._listener.getsockname()[1]
        t._timeout = timeout
        return t

    def accept_workers(self) -> "TcpStarTransport":
        """Rank 0, step 2: accept HELLOs until all ``world - 1`` workers
        have joined.  Bad handshakes are refused with a GOODBYE and do not
        kill the server; returns self for chaining."""
        srv, timeout = self._listener, self._timeout
        deadline = time.monotonic() + timeout

        def timed_out():
            self.close()
            raise TimeoutError(
                f"rendezvous timed out after {timeout}s with "
                f"{len(self._conns)}/{self.world - 1} workers connected")

        while len(self._conns) < self.world - 1:
            remaining = deadline - time.monotonic()
            if remaining <= 0:    # settimeout(0) would mean non-blocking
                timed_out()
            srv.settimeout(remaining)
            try:
                conn, _ = srv.accept()
            except (socket.timeout, TimeoutError):
                timed_out()
            # a stray/silent peer gets a short grace, never the whole
            # deadline — real workers' HELLOs must still fit in it
            conn.settimeout(
                max(0.1, min(_HELLO_GRACE_S, deadline - time.monotonic())))
            try:
                _, rank, w, token = recv_frame(conn, expect=HELLO)
            except (ConnectionError, socket.timeout, TimeoutError, OSError):
                conn.close()
                continue
            conn.settimeout(timeout)     # GOODBYE/WELCOME writes below
            reason = None
            tok, _, peer_policy = token.partition(b"|")
            if tok != HELLO_TOKEN:
                reason = f"protocol token mismatch (server {HELLO_TOKEN!r})"
            elif peer_policy != self._policy_hash:
                reason = ("policy mismatch: server "
                          f"{self._policy_hash.decode() or '<none>'}, worker "
                          f"{peer_policy.decode(errors='replace') or '<none>'}")
            elif w != self.world:
                reason = f"world mismatch: server {self.world}, worker {w}"
            elif not 1 <= rank < self.world:
                reason = f"rank {rank} out of range [1, {self.world})"
            elif rank in self._conns:
                reason = f"rank {rank} already connected"
            if reason is not None:
                with contextlib.suppress(OSError):
                    send_frame(conn, GOODBYE, 0, self.world, reason.encode())
                conn.close()
                continue
            send_frame(conn, WELCOME, 0, self.world)
            _steady_state(conn)
            self._conns[rank] = conn
            self._bufs[rank] = _FrameBuffer()
        return self

    @classmethod
    def serve(cls, host: str = "127.0.0.1", port: int = 0, *, world: int,
              timeout: float = 60.0, policy_hash: str | None = None,
              heartbeat_s: float | None = None,
              read_timeout_s: float | None = None,
              deadline_ms: float | None = None) -> "TcpStarTransport":
        """Rank 0: `listen` + `accept_workers` in one blocking call (the
        ``make_transport("tcp", rank=0, ...)`` path, where the port is
        fixed up front and every worker retries until it is up)."""
        return cls.listen(host, port, world=world, timeout=timeout,
                          policy_hash=policy_hash, heartbeat_s=heartbeat_s,
                          read_timeout_s=read_timeout_s,
                          deadline_ms=deadline_ms).accept_workers()

    @classmethod
    def connect(cls, host: str, port: int, *, rank: int, world: int,
                timeout: float = 60.0, policy_hash: str | None = None,
                heartbeat_s: float | None = None,
                read_timeout_s: float | None = None,
                deadline_ms: float | None = None) -> "TcpStarTransport":
        """Ranks 1..W-1: dial the coordinator (retrying until ``timeout`` so
        workers may start before the server) and handshake.
        ``policy_hash`` rides the HELLO payload behind a ``|`` separator;
        a server running a different policy refuses the handshake."""
        if not 2 <= world <= MAX_WORLD:
            raise ValueError(f"world must be in [2, {MAX_WORLD}], got {world}")
        if not 1 <= rank < world:
            raise ValueError(f"worker rank must be in [1, {world}), got {rank}")
        deadline = time.monotonic() + timeout
        while True:
            try:
                sock = socket.create_connection((host, port), timeout=1.0)
                break
            except OSError as e:
                if time.monotonic() >= deadline:
                    raise ConnectionError(
                        f"could not reach coordinator {host}:{port} within "
                        f"{timeout}s: {e}") from e
                time.sleep(0.05)
        sock.settimeout(timeout)
        hello = HELLO_TOKEN + (b"|" + policy_hash.encode()
                               if policy_hash else b"")
        try:
            send_frame(sock, HELLO, rank, world, hello)
            _, _, w, _ = recv_frame(sock, expect=WELCOME)
        except Exception:
            sock.close()
            raise
        if w != world:
            sock.close()
            raise ConnectionError(f"server runs world={w}, we expect {world}")
        _steady_state(sock)
        t = cls(rank, world, heartbeat_s=heartbeat_s,
                read_timeout_s=read_timeout_s, deadline_ms=deadline_ms)
        t._policy_hash = (policy_hash or "").encode()
        t._sock = sock
        return t

    @classmethod
    def rejoin(cls, host: str, port: int, *, rank: int, world: int,
               deadline_ms: float, timeout: float = 60.0,
               policy_hash: str | None = None,
               backoff: BackoffSchedule | None = None,
               heartbeat_s: float | None = None,
               read_timeout_s: float | None = None,
               ) -> tuple["TcpStarTransport", bytes, bytes]:
        """Re-enter a RUNNING elastic world after this rank died mid-run.

        Walks ``backoff`` (seeded capped exponential; one immediate attempt
        plus one per delay) until the server's listener accepts the REJOIN
        — early attempts are typically refused with "rank N is still
        connected" until the server notices the old link is dead, which is
        exactly what the backoff is for.

        Returns ``(transport, state_row, params_snapshot)``: the rank's
        last gathered `CommState` row (b"" if none was ever gathered) to
        feed `repro.comm.aggregate.fold_comm_state_rows`, and the server's
        current flat params (b"" when rank 0 installed no
        ``snapshot_provider``).  The transport's ``joined_round`` is the
        round that was in flight when the server accepted us; our first
        uplink is tagged ``joined_round + 1``, and the caller must consume
        the in-flight round's downlink (``broadcast_payload(None)``) before
        entering its normal round loop."""
        if backoff is None:
            backoff = BackoffSchedule()
        last_err: Exception | None = None
        for delay in [0.0, *backoff.delays()]:
            if delay > 0:
                time.sleep(delay)
            try:
                return cls._rejoin_once(
                    host, port, rank=rank, world=world,
                    deadline_ms=deadline_ms, timeout=timeout,
                    policy_hash=policy_hash, heartbeat_s=heartbeat_s,
                    read_timeout_s=read_timeout_s)
            except (ConnectionError, TimeoutError, OSError) as e:
                last_err = e
        raise TransportError(
            f"rank {rank} could not rejoin {host}:{port} after "
            f"{backoff.retries + 1} attempts: {last_err}") from last_err

    @classmethod
    def _rejoin_once(cls, host: str, port: int, *, rank: int, world: int,
                     deadline_ms: float, timeout: float,
                     policy_hash: str | None,
                     heartbeat_s: float | None,
                     read_timeout_s: float | None,
                     ) -> tuple["TcpStarTransport", bytes, bytes]:
        if not 1 <= rank < world:
            raise ValueError(f"worker rank must be in [1, {world}), got {rank}")
        sock = socket.create_connection((host, port), timeout=1.0)
        try:
            sock.settimeout(timeout)
            token = HELLO_TOKEN + (b"|" + policy_hash.encode()
                                   if policy_hash else b"")
            send_frame(sock, REJOIN, rank, world, token)

            def read(*want: int) -> bytes:
                # the server's heartbeat tick may interleave PINGs with the
                # handshake frames once our conn is registered
                while True:
                    ftype, _, w, data = recv_frame(sock)
                    if ftype == PING:
                        with contextlib.suppress(OSError):
                            send_frame(sock, PONG, rank, world)
                        continue
                    if ftype == GOODBYE:
                        raise ConnectionError(
                            f"server refused the rejoin: "
                            f"{data.decode(errors='replace')}")
                    if ftype not in want:
                        raise ConnectionError(
                            f"rejoin handshake expected frame type {want}, "
                            f"got {ftype}")
                    if w != world:
                        raise ConnectionError(
                            f"server runs world={w}, we expect {world}")
                    return data

            welcome = read(WELCOME)
            joined_round = struct.unpack("<I", welcome[:4])[0] \
                if len(welcome) >= 4 else 0
            row = read(STATE)
            snapshot = read(DIRECTION)
        except Exception:
            sock.close()
            raise
        _steady_state(sock)
        t = cls(rank, world, heartbeat_s=heartbeat_s,
                read_timeout_s=read_timeout_s, deadline_ms=deadline_ms)
        t._policy_hash = (policy_hash or "").encode()
        t._sock = sock
        t._seq = joined_round + 1
        t.joined_round = joined_round
        return t, row, snapshot

    # ---- Transport seam ----------------------------------------------------

    @property
    def is_server(self) -> bool:
        return self.rank == 0

    def _filter_control(self, r: int, frame) -> tuple | None:
        """Classify one popped frame from rank ``r``.  Returns None when the
        frame was consumed here (a PONG heartbeat answer, or an elastic
        frame tagged with an already-served round — a straggler's late
        uplink); otherwise ``(ftype, sender, data, seq)`` with any RCSQ
        round tag stripped (``seq`` is -1 when the frame carries none).
        Raises `TransportError` on LEAVE (the caller decides whether that
        is fatal) and on a round tag from the future (seq desync)."""
        ftype, sender, _, data = frame
        if ftype == PONG:
            return None
        if ftype == LEAVE:
            reason = data.decode(errors="replace") if data else ""
            raise TransportError(
                f"rank {r} left the world (LEAVE"
                + (f": {reason}" if reason else "") + ")")
        seq = -1
        if self.elastic and ftype in (PAYLOAD, SCALAR):
            seq, data = unpack_seq_payload(data)
            if seq <= self._served[ftype]:
                return None
            ceiling = self._round if ftype == PAYLOAD else \
                self._served[ftype] + 1
            if seq > max(ceiling, self._round):
                raise TransportError(
                    f"rank {r} sent a round-{seq} frame during round "
                    f"{self._round} — round-tag desync")
        return ftype, sender, data, seq

    def _buffered_frame_from(self, r: int,
                             expect: int) -> tuple[int, int, bytes, int]:
        """Server: pop the next meaningful frame from rank ``r``'s buffer,
        blocking on its socket only when the buffer is empty (heartbeat
        answers and stale elastic frames are consumed silently).  Returns
        ``(type, sender, payload, seq)``."""
        buf = self._bufs[r]
        conn = self._conns[r]
        while True:
            frame = buf.next_frame()
            while frame is None:
                if self.elastic:
                    conn.settimeout(self.read_timeout_s)
                try:
                    data = conn.recv(1 << 16)
                except (socket.timeout, TimeoutError) as e:
                    raise TransportError(
                        f"rank {r} sent nothing for {self.read_timeout_s:.1f}s"
                        f" while rank 0 waited for frame type {expect} "
                        f"(round {self._round})") from e
                if not data:
                    raise TransportError(f"rank {r} closed its uplink")
                buf.feed(data)
                frame = buf.next_frame()
            got = self._filter_control(r, frame)
            if got is None:
                continue
            ftype, sender, payload, seq = got
            if ftype != expect:
                if ftype == GOODBYE:
                    raise TransportError(
                        f"peer said goodbye: "
                        f"{payload.decode(errors='replace')}")
                raise TransportError(f"expected frame type {expect}, got "
                                     f"{ftype} from rank {r}")
            if sender != r:
                raise TransportError(
                    f"link for rank {r} delivered a frame from rank {sender}")
            return ftype, sender, payload, seq

    def exchange(self, payloads: list[bytes], on_payload=None,
                 deadline_ms: float | None = None) -> list[bytes]:
        """Ship THIS rank's payload.  Rank 0 returns all ``world`` payloads
        in rank order; workers return ``[]`` (the aggregate comes back via
        `broadcast_payload`).

        The server drains uplinks through a `selectors` reactor: frames
        from all workers interleave as their bytes arrive, so one slow or
        large rank no longer serializes the ranks behind it (the former
        rank-by-rank drain blocked on rank 1 before reading rank 2's
        already-delivered frame).

        ``on_payload(rank, payload)`` is invoked on the server the moment
        each rank's frame COMPLETES (rank 0's own payload first), while the
        reactor is still waiting on the remaining uplinks — the aggregation
        layer uses it to parse, stage, and dispatch the decode of each
        packet during network wait instead of after the full drain.

        Elastic mode: ``deadline_ms`` (per-call override of the
        transport-level default) closes the round that many ms after it
        starts; ranks that missed it stay ``None`` in the returned list and
        land in ``last_participation``, a dead link marks the rank left
        instead of raising, and the listener accepts REJOINs while the
        reactor waits."""
        if len(payloads) != 1:
            raise ValueError(
                "multihost exchange ships exactly one payload per rank per "
                f"round (one rank hosts one worker); got {len(payloads)}")
        t0 = time.perf_counter()
        self.stats.rounds += 1
        local = payloads[0]
        tel = obs.active()
        if self.is_server:
            if deadline_ms is None:
                deadline_ms = self.deadline_ms
            elif not self.elastic:
                raise ValueError(
                    "a per-round deadline_ms needs an elastic transport "
                    "(construct every rank with deadline_ms=... so worker "
                    "frames carry round tags)")
            self._round += 1
            return self._serve_exchange(local, on_payload, deadline_ms,
                                        t0, tel)
        seq = self._seq
        self._seq += 1
        wire = pack_seq_payload(seq, local) if self.elastic else local
        if self._sock is None:
            raise TransportError(
                f"rank {self.rank} has no link to rank 0 (transport closed) "
                f"— cannot ship round {seq}")
        try:
            sent = send_frame(self._sock, PAYLOAD, self.rank, self.world,
                              wire)
        except OSError as e:
            raise TransportError(
                f"rank {self.rank} could not ship its round-{seq} payload "
                f"to rank 0: {e}") from e
        self.stats.bytes_up += len(local)
        self.stats.wire_bytes += sent
        self.stats.wall_time_s += time.perf_counter() - t0
        if tel.enabled:
            tel.trace.complete("wire/exchange", t0, cat="wire",
                               pid=self.rank, nbytes=len(local))
            tel.count("wire_bytes_up", sent, transport="tcp",
                      link=f"rank{self.rank}")
        return []

    def _serve_exchange(self, local: bytes, on_payload,
                        deadline_ms: float | None, t0: float,
                        tel) -> list[bytes]:
        out: list[bytes | None] = [local] + [None] * (self.world - 1)
        self.last_arrival_order = []
        self._round_t0 = t0
        self._round_lags = []
        if on_payload is not None:
            on_payload(0, local)
        self._poll_rejoin()    # a rejoiner queued since last round
        pending = set(self._conns)
        # frames already sitting in the buffers (pipelined last round)
        for r in sorted(pending):
            try:
                if self._pop_buffered_payload(out, r, on_payload):
                    pending.discard(r)
            except ConnectionError as e:
                if not self.elastic:
                    raise
                self._drop_link(r, str(e))
                pending.discard(r)
        deadline = None if deadline_ms is None \
            else t0 + float(deadline_ms) / 1000.0
        with selectors.DefaultSelector() as sel:
            if self.elastic and self._listener is not None:
                sel.register(self._listener, selectors.EVENT_READ, -1)
            for r in pending:
                sel.register(self._conns[r], selectors.EVENT_READ, r)
            while pending:
                timeout = self.heartbeat_s
                if deadline is not None:
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        break
                    timeout = min(timeout, remaining)
                events = sel.select(timeout)
                self._maybe_ping()
                for key, _ in events:
                    if key.data == -1:
                        self._poll_rejoin()
                        continue
                    r = key.data
                    try:
                        data = key.fileobj.recv(1 << 16)
                        if not data:
                            raise TransportError(
                                f"rank {r} closed its uplink mid-round")
                        self._bufs[r].feed(data)
                        done = self._pop_buffered_payload(out, r, on_payload)
                    except ConnectionError as e:
                        if not self.elastic:
                            raise
                        self._drop_link(r, str(e))
                        pending.discard(r)
                        with contextlib.suppress(KeyError, ValueError):
                            sel.unregister(key.fileobj)
                        continue
                    if done:
                        pending.discard(r)
                        sel.unregister(key.fileobj)
        # pending ranks missed the deadline: they stay connected, their
        # late round-tagged frames are discarded on sight
        arrived = [r for r in range(self.world) if out[r] is not None]
        self.last_participation = arrived
        if self.elastic:
            self._served[PAYLOAD] = self._round
            self.membership.record_round(arrived, self._round)
        self.stats.bytes_up += sum(len(p) for p in out if p is not None)
        self.stats.wall_time_s += time.perf_counter() - t0
        if tel.enabled:
            # fan-in straggler skew: first to last uplink completion
            lags = self._round_lags
            tel.trace.complete(
                "wire/exchange", t0, cat="wire", pid=0,
                nbytes=sum(len(p) for p in out if p is not None),
                arrival_order=list(self.last_arrival_order),
                n_arrived=len(arrived),
                fanin_skew_s=(max(lags) - min(lags)) if lags else 0.0)
            if lags:
                tel.observe("wire_fanin_skew_s", max(lags) - min(lags),
                            transport="tcp")
        return out

    def _pop_buffered_payload(self, out: list, r: int, on_payload) -> bool:
        """Pop frames from rank ``r``'s buffer until its round payload
        completes (True) or the buffer runs dry (False)."""
        buf = self._bufs.get(r)
        while buf is not None:
            frame = buf.next_frame()
            if frame is None:
                return False
            got = self._filter_control(r, frame)
            if got is None:
                continue
            ftype, sender, data, seq = got
            if ftype != PAYLOAD:
                if ftype == GOODBYE:
                    raise TransportError(
                        f"peer said goodbye: {data.decode(errors='replace')}")
                raise TransportError(f"expected frame type {PAYLOAD}, got "
                                     f"{ftype} from rank {r}")
            if sender != r:
                raise TransportError(
                    f"link for rank {r} delivered a frame from rank {sender}")
            if seq not in (-1, self._round):
                raise TransportError(
                    f"rank {r} shipped a round-{seq} payload during round "
                    f"{self._round} — round-tag desync")
            self._finish_payload(out, r, data, on_payload)
            return True
        return False

    def _finish_payload(self, out: list, r: int, data: bytes,
                        on_payload=None) -> None:
        out[r] = data
        self.last_arrival_order.append(r)
        self.stats.wire_bytes += FRAME_HEADER_BYTES + len(data)
        tel = obs.active()
        if tel.enabled:
            # one instant per completed uplink: the straggler timeline
            lag = time.perf_counter() - self._round_t0
            self._round_lags.append(lag)
            tel.instant("wire/frame_arrival", cat="wire", pid=0,
                        rank=r, nbytes=len(data),
                        arrival_index=len(self.last_arrival_order) - 1,
                        lag_s=lag)
            tel.observe("wire_arrival_lag_s", lag, link=f"rank{r}")
            tel.count("wire_bytes_up", FRAME_HEADER_BYTES + len(data),
                      transport="tcp", link=f"rank{r}")
        if on_payload is not None:
            on_payload(r, data)

    # ---- elastic plumbing --------------------------------------------------

    @property
    def last_round(self) -> int:
        """Index of the round most recently entered (server: the round in
        flight; worker: the round of its last uplink)."""
        return self._round if self.is_server else self._seq - 1

    def skip_round(self) -> None:
        """Worker: advance the round tag WITHOUT sending this round's
        uplink (the fault harness's "drop" — the server serves the round
        from whoever arrived; this rank still receives the broadcast)."""
        if self.is_server:
            raise ValueError("skip_round is a worker-side operation")
        if not self.elastic:
            raise ValueError("skip_round needs an elastic (deadline_ms) "
                             "transport — a fixed world would deadlock")
        self._seq += 1
        self.stats.rounds += 1

    def _maybe_ping(self) -> None:
        """Server: heartbeat every link at most once per ``heartbeat_s``
        (called while the reactor waits).  Send failures are left for the
        read path to surface — a ping is advisory, not a probe."""
        now = time.perf_counter()
        if now - self._last_ping < self.heartbeat_s:
            return
        self._last_ping = now
        for conn in list(self._conns.values()):
            with contextlib.suppress(OSError):
                send_frame(conn, PING, 0, self.world)

    def _drop_link(self, r: int, reason: str) -> None:
        """Server, elastic mode: rank ``r``'s link is gone — close it and
        mark the rank left (it may REJOIN later)."""
        conn = self._conns.pop(r, None)
        self._bufs.pop(r, None)
        if conn is not None:
            with contextlib.suppress(OSError):
                conn.close()
        if self.membership is not None:
            self.membership.mark_left(r, self._round, reason)

    def _poll_rejoin(self) -> None:
        """Server, elastic mode: accept at most one queued REJOIN without
        blocking (called from the reactor when the listener is readable,
        and once per round so fully-pipelined uplinks never starve a
        waiting rejoiner)."""
        if not self.elastic or self._listener is None:
            return
        self._listener.settimeout(0.0)
        try:
            conn, _ = self._listener.accept()
        except (BlockingIOError, OSError):
            return
        self._handshake_rejoin(conn)

    def _handshake_rejoin(self, conn: socket.socket) -> None:
        conn.settimeout(_HELLO_GRACE_S)
        try:
            ftype, rank, w, token = recv_frame(conn)
        except (ConnectionError, socket.timeout, TimeoutError, OSError):
            conn.close()
            return
        tok, _, peer_policy = token.partition(b"|")
        reason = None
        if ftype == HELLO:
            reason = ("rendezvous is over: use a REJOIN frame to re-enter "
                      "a running world")
        elif ftype != REJOIN:
            reason = f"expected REJOIN, got frame type {ftype}"
        elif tok != HELLO_TOKEN:
            reason = f"protocol token mismatch (server {HELLO_TOKEN!r})"
        elif peer_policy != self._policy_hash:
            reason = ("policy mismatch: server "
                      f"{self._policy_hash.decode() or '<none>'}, worker "
                      f"{peer_policy.decode(errors='replace') or '<none>'}")
        elif w != self.world:
            reason = f"world mismatch: server {self.world}, worker {w}"
        elif not 1 <= rank < self.world:
            reason = f"rank {rank} out of range [1, {self.world})"
        elif rank in self._conns:
            reason = f"rank {rank} is still connected"
        if reason is not None:
            with contextlib.suppress(OSError):
                send_frame(conn, GOODBYE, 0, self.world, reason.encode())
            conn.close()
            return
        row = (self.membership.row(rank) if self.membership else None) or b""
        snapshot = b""
        if self.snapshot_provider is not None:
            snapshot = self.snapshot_provider() or b""
        try:
            send_frame(conn, WELCOME, 0, self.world,
                       struct.pack("<I", max(self._round, 0)))
            send_frame(conn, STATE, 0, self.world, row)
            send_frame(conn, DIRECTION, 0, self.world, snapshot)
        except OSError:
            conn.close()
            return
        _steady_state(conn)
        self._conns[rank] = conn
        self._bufs[rank] = _FrameBuffer()
        if self.membership is not None:
            self.membership.mark_joined(rank, self._round, rejoin=True)

    def _recv_steady(self, waiting_for: str,
                     expect=None) -> tuple[int, int, int, bytes]:
        """Worker: receive one meaningful frame under the heartbeat-derived
        read deadline.  PINGs are answered (and reset the deadline), a
        GOODBYE("shutdown") raises `ServerShutdown`, silence past
        ``read_timeout_s`` or a broken link raises a `TransportError`
        naming the peer and round instead of blocking forever."""
        sock = self._sock
        round_ = self._seq - 1
        where = (f"rank {self.rank} waited for {waiting_for} "
                 f"(round {round_})")
        if sock is None:
            raise TransportError(f"no link to rank 0 while {where} "
                                 "(transport closed)")
        deadline = time.monotonic() + self.read_timeout_s
        while True:
            sock.settimeout(max(0.001, deadline - time.monotonic()))
            try:
                ftype, sender, w, payload = recv_frame(sock)
            except (socket.timeout, TimeoutError) as e:
                raise TransportError(
                    f"rank 0 sent nothing for {self.read_timeout_s:.1f}s "
                    f"while {where} — treating the server as dead") from e
            except TransportError:
                raise
            except (ConnectionError, OSError) as e:
                raise TransportError(
                    f"link to rank 0 broke while {where}: {e}") from e
            if ftype == PING:
                with contextlib.suppress(OSError):
                    send_frame(sock, PONG, self.rank, self.world)
                deadline = time.monotonic() + self.read_timeout_s
                continue
            if ftype == GOODBYE:
                reason = payload.decode(errors="replace")
                if reason == "shutdown":
                    raise ServerShutdown(
                        f"rank 0 closed the star (clean shutdown) while "
                        f"{where}")
                raise TransportError(f"peer said goodbye: {reason}")
            if expect is not None and ftype != expect:
                raise TransportError(
                    f"expected frame type {expect}, got {ftype} while "
                    f"{where}")
            return ftype, sender, w, payload

    def broadcast_payload(self, data: bytes | None, *,
                          encoded: bool = False) -> bytes:
        """Rank 0 passes the direction blob and sends it down every link;
        workers pass ``None`` and receive it.  Returns the blob on every
        rank.  ``encoded=True`` ships the blob on the ``DIRECTION_ENC``
        frame (a compressed RCD2 direction the receiver decodes against
        its DIANA shift — see `repro.comm.aggregate`); workers accept
        either frame type and dispatch on the blob's magic.

        ``bytes_down`` books only the ``world - 1`` REAL socket sends
        (frame headers included) on rank 0 — its own in-process loopback
        copy never crosses a wire; a worker books its received payload.
        ``wire_bytes`` counts socket bytes on this process as always.

        Elastic mode RCSQ-wraps the blob with the round it serves, and a
        receiving worker RESYNCS its own round tag (``_seq = round + 1``).
        This is the protocol's self-healing half: a worker that missed
        rounds (slow compile, long GC pause, rejoin) would otherwise fall
        permanently behind the server's round counter and have every
        later uplink discarded as stale."""
        t0 = time.perf_counter()
        tel = obs.active()
        ftype = DIRECTION_ENC if encoded else DIRECTION
        if self.is_server:
            if data is None:
                raise ValueError("rank 0 must provide the broadcast payload")
            wire = pack_seq_payload(max(self._round, 0), data) \
                if self.elastic else data
            sent = 0
            for r in sorted(self._conns):
                try:
                    sent += send_frame(self._conns[r], ftype, 0, self.world,
                                       wire)
                except OSError as e:
                    if not self.elastic:
                        raise
                    self._drop_link(r, f"downlink send failed in round "
                                       f"{self._round}: {e}")
            self.stats.wire_bytes += sent
            self.stats.bytes_down += sent
            self.stats.wall_time_s += time.perf_counter() - t0
            if tel.enabled:
                tel.trace.complete("wire/broadcast", t0, cat="wire", pid=0,
                                   nbytes=sent, encoded=encoded)
                tel.count("wire_bytes_down", sent, transport="tcp",
                          link="all")
            return data
        got, _, _, data = self._recv_steady("the direction broadcast")
        if got not in (DIRECTION, DIRECTION_ENC):
            raise TransportError(f"expected a direction frame "
                                 f"({DIRECTION}/{DIRECTION_ENC}), got {got}")
        if self.elastic:
            round_, data = unpack_seq_payload(data)
            self._seq = round_ + 1     # resync: see docstring
        self.stats.bytes_down += len(data)
        self.stats.wire_bytes += FRAME_HEADER_BYTES + len(data)
        self.stats.wall_time_s += time.perf_counter() - t0
        if tel.enabled:
            tel.trace.complete("wire/broadcast", t0, cat="wire",
                               pid=self.rank, nbytes=len(data),
                               encoded=got == DIRECTION_ENC)
            tel.count("wire_bytes_down", FRAME_HEADER_BYTES + len(data),
                      transport="tcp", link=f"rank{self.rank}")
        return data

    def broadcast(self, nbytes: int, workers: int) -> None:
        raise RuntimeError(
            "TcpStarTransport measures real downlinks — use "
            "broadcast_payload(data), not the accounting-only broadcast()")

    def allreduce_scalar(self, value: float) -> float:
        """Mean of one float across all ranks (loss telemetry: every rank
        reports the same global number, like the in-process trainer).  The
        24-byte frames are booked in ``wire_bytes``/``wall_time_s`` only —
        they are telemetry, not gradient payload.

        Elastic mode: the server waits only for the ranks whose uplink made
        the last round (``last_participation``) and means over them; ranks
        that missed the deadline still RECEIVE the mean (theirs is the
        participants' mean — the best global number that exists)."""
        t0 = time.perf_counter()
        if self.is_server:
            round_ = self._round
            total, n = float(value), 1
            sources = sorted(set(self.last_participation)
                             & set(self._conns)) if self.elastic \
                else sorted(self._conns)
            for r in sources:
                # through the shared buffers: a worker may have pipelined
                # this SCALAR right behind its PAYLOAD frame
                try:
                    _, _, data, seq = self._buffered_frame_from(r, SCALAR)
                except ConnectionError as e:
                    if not self.elastic:
                        raise
                    self._drop_link(
                        r, f"lost during the round-{round_} loss "
                           f"allreduce: {e}")
                    continue
                if seq not in (-1, round_):
                    raise TransportError(
                        f"rank {r} sent a round-{seq} loss during round "
                        f"{round_} — round-tag desync")
                total += struct.unpack("<d", data)[0]
                n += 1
                self.stats.wire_bytes += FRAME_HEADER_BYTES + len(data)
            if self.elastic:
                self._served[SCALAR] = round_
            mean = total / (n if self.elastic else self.world)
            out = struct.pack("<d", mean)
            for r in sorted(self._conns):
                try:
                    self.stats.wire_bytes += send_frame(
                        self._conns[r], SCALAR_MEAN, 0, self.world, out)
                except OSError as e:
                    if not self.elastic:
                        raise
                    self._drop_link(r, f"loss-mean send failed in round "
                                       f"{round_}: {e}")
        else:
            body = struct.pack("<d", float(value))
            if self.elastic:
                body = pack_seq_payload(self._seq - 1, body)
            if self._sock is None:
                raise TransportError(
                    f"rank {self.rank} has no link to rank 0 (transport "
                    "closed) — cannot allreduce")
            try:
                self.stats.wire_bytes += send_frame(
                    self._sock, SCALAR, self.rank, self.world, body)
            except OSError as e:
                raise TransportError(
                    f"rank {self.rank} could not ship its loss to rank 0: "
                    f"{e}") from e
            _, _, _, data = self._recv_steady("the loss mean",
                                              expect=SCALAR_MEAN)
            self.stats.wire_bytes += FRAME_HEADER_BYTES + 8
            mean = struct.unpack("<d", data)[0]
        self.stats.wall_time_s += time.perf_counter() - t0
        return mean

    def gather_state(self, payload: bytes) -> list[bytes]:
        """Checkpoint-time gather: every rank ships one STATE frame (its
        client-side `CommState` rows); rank 0 returns all ``world``
        payloads in rank order, workers return ``[]``.  Runs between
        training rounds over the same buffered links as the SCALAR frames
        (a worker may have pipelined frames ahead of it), so it needs no
        barrier of its own.  Booked in ``wire_bytes`` only — checkpoint
        plumbing, not gradient payload.

        The server also stores each rank's row in `Membership`, so a rank
        that later dies REJOINs with its `CommState` restored bitwise from
        the last gather.  In elastic mode a dead rank's slot comes back
        ``None`` (`fold_comm_state_rows` skips it)."""
        t0 = time.perf_counter()
        if self.is_server:
            out: list[bytes | None] = [payload] + [None] * (self.world - 1)
            if self.membership is not None:
                self.membership.store_row(0, payload)
            for r in sorted(self._conns):
                try:
                    _, _, data, _ = self._buffered_frame_from(r, STATE)
                except ConnectionError as e:
                    if not self.elastic:
                        raise
                    self._drop_link(
                        r, f"lost during the round-{self._round} state "
                           f"gather: {e}")
                    continue
                out[r] = data
                if self.membership is not None and data:
                    self.membership.store_row(r, data)
                self.stats.wire_bytes += FRAME_HEADER_BYTES + len(data)
            self.stats.wall_time_s += time.perf_counter() - t0
            return out
        if self._sock is None:
            raise TransportError(
                f"rank {self.rank} has no link to rank 0 (transport "
                "closed) — cannot gather state")
        self.stats.wire_bytes += send_frame(
            self._sock, STATE, self.rank, self.world, payload)
        self.stats.wall_time_s += time.perf_counter() - t0
        return []

    # ---- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        """Tear down the star.  Rank 0 tells every worker GOODBYE
        ("shutdown") first, so a worker blocked on a recv surfaces a clean
        `ServerShutdown` instead of a bare reset; a worker announces LEAVE
        so an elastic server marks it left instead of dying on EOF."""
        for conn in self._conns.values():
            with contextlib.suppress(OSError):
                send_frame(conn, GOODBYE, 0, self.world, b"shutdown")
            with contextlib.suppress(OSError):
                conn.close()
        self._conns.clear()
        self._bufs.clear()
        if self._sock is not None and not self.is_server:
            with contextlib.suppress(OSError):
                send_frame(self._sock, LEAVE, self.rank, self.world, b"done")
        for s in (self._sock, self._listener):
            if s is not None:
                with contextlib.suppress(OSError):
                    s.close()
        self._sock = self._listener = None

    def __enter__(self) -> "TcpStarTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def make_tcp_transport(*, rank: int, world: int,
                       coordinator: str = "127.0.0.1:37737",
                       timeout: float = 60.0,
                       policy_hash: str | None = None,
                       heartbeat_s: float | None = None,
                       read_timeout_s: float | None = None,
                       deadline_ms: float | None = None) -> TcpStarTransport:
    """The ``make_transport("tcp", ...)`` branch: rank 0 serves at
    ``coordinator``, every other rank dials it.  ``policy_hash`` (the
    rank's `ResolvedPolicy.hash`) rides the HELLO handshake so policy
    mismatches fail at rendezvous.  ``deadline_ms`` turns on elastic mode
    (partial deadline rounds, REJOIN, fault tolerance — see the module
    doc); pass the same value on EVERY rank so worker frames carry the
    round tags the server's staleness filter needs."""
    host, port = parse_coordinator(coordinator)
    if rank == 0:
        if port == 0:
            raise ValueError("coordinator port 0 only works single-process; "
                             "pick a concrete port every rank can dial "
                             "(repro.launch.multihost does this for you)")
        return TcpStarTransport.serve(host, port, world=world, timeout=timeout,
                                      policy_hash=policy_hash,
                                      heartbeat_s=heartbeat_s,
                                      read_timeout_s=read_timeout_s,
                                      deadline_ms=deadline_ms)
    return TcpStarTransport.connect(host, port, rank=rank, world=world,
                                    timeout=timeout, policy_hash=policy_hash,
                                    heartbeat_s=heartbeat_s,
                                    read_timeout_s=read_timeout_s,
                                    deadline_ms=deadline_ms)
