"""Multi-host packed wire — a TCP socket star for the byte packets.

Every transport before this one ran in-process with a simulated alpha-beta
clock.  `TcpStarTransport` moves the *actual* `Packet.to_bytes()` payloads
between OS processes: rank 0 is the aggregation point (the paper's parameter
server), ranks 1..W-1 connect to it over TCP, and every uplink/downlink is a
length-prefixed frame whose bytes and wall-clock are **measured**, never
modeled.  `TransportStats.sim_time_s` stays 0 on this transport;
`wall_time_s` holds real `perf_counter` deltas.

Frame protocol (all little-endian, append-only like the packet header):

    <4s  B      B     H      I>        then `length` payload bytes
    RCMH type   rank  world  length

* ``HELLO``     worker -> server on connect; payload is the protocol token,
  server validates (rank, world, token) and replies ``WELCOME`` or
  ``GOODBYE`` + reason.
* ``PAYLOAD``   worker -> server, one serialized `Packet` per round.
* ``DIRECTION`` server -> workers, the aggregated direction blob
  (see `repro.comm.aggregate`).
* ``DIRECTION_ENC`` server -> workers, the COMPRESSED direction blob: a
  16-byte RCD2 header followed by one serialized `Packet` the downlink
  codec decodes against the rank's mirrored DIANA shift
  (`repro.comm.aggregate.pack_encoded_direction`).
* ``STATE``     worker -> server, one rank's client-side `CommState` rows
  (`repro.comm.aggregate.pack_comm_state_row`), gathered by
  `gather_state` at checkpoint time so a rank-0 checkpoint captures
  every rank's EMA ladder / momentum / downlink-shift rows.

Stats semantics (cross-transport comparability is the point):

* ``bytes_up`` counts *payload* bytes.  On rank 0 — the aggregation
  point, the vantage the in-process transports model — it covers all
  ``world`` ranks including rank 0's loopback contribution, so identical
  uplink traffic books identical numbers on `LoopbackTransport` and
  here; worker ranks see only their own link and book only that.
* ``bytes_down`` on rank 0 books only the ``world - 1`` REAL socket
  sends of each broadcast, frame headers included — rank 0's in-process
  loopback copy never crosses a wire and is no longer counted (it used
  to be booked as ``payload * world``, silently inflating every
  compressed-downlink ratio by ``world/(world-1)``).  A worker books its
  own received payload.  `LoopbackTransport` keeps its modeled
  ``payload * world`` accounting, so the documented cross-transport
  relation is ``tcp_down == (world-1)/world * loopback_down`` plus the
  per-send frame-header bytes (regression-tested in
  ``tests/test_multihost.py``).
* ``wire_bytes`` counts what actually crossed a socket on this process
  (frame headers included): the honest per-link measurement.

One rank hosts exactly one worker; `repro.launch.multihost` spawns a
localhost world, `--transport tcp` in `repro.launch.train` joins one rank.
"""

from __future__ import annotations

import contextlib
import selectors
import socket
import struct
import time

from repro.comm.transport import TransportStats
from repro.obs import trace as obs

FRAME_MAGIC = b"RCMH"
_FRAME_FMT = "<4sBBHI"                 # magic, type, rank, world, payload len
FRAME_HEADER_BYTES = struct.calcsize(_FRAME_FMT)   # 12

#: frame types (append-only)
HELLO, WELCOME, GOODBYE, PAYLOAD, DIRECTION = 1, 2, 3, 4, 5
SCALAR, SCALAR_MEAN = 6, 7     # loss-telemetry allreduce (8-byte f64)
STATE = 8                      # checkpoint gather of client CommState rows
DIRECTION_ENC = 9              # compressed (DIANA-shift) direction blob

#: a real worker HELLOs immediately after connecting; give a stray peer
#: (port scanner, health check) at most this long before refusing it
_HELLO_GRACE_S = 2.0

#: handshake token — bump the suffix on any incompatible protocol change.
#: The HELLO payload is the token, optionally followed by ``|`` and the
#: rank's codec-policy fingerprint (`repro.comm.policy.ResolvedPolicy.hash`):
#: ranks running different per-leaf policies would desync mid-run (their
#: RCBW containers disagree segment by segment), so the server refuses the
#: handshake instead.  Old payloads (bare token) parse as "no policy".
HELLO_TOKEN = b"repro-multihost-v1"

MAX_WORLD = 255            # rank rides in a uint8 frame field
_MAX_FRAME_PAYLOAD = 1 << 31


def pick_free_port(host: str = "127.0.0.1") -> int:
    """Bind port 0, read the kernel's choice, release it (launcher helper)."""
    with contextlib.closing(socket.socket()) as s:
        s.bind((host, 0))
        return s.getsockname()[1]


def parse_coordinator(spec: str) -> tuple[str, int]:
    """``"host:port"`` -> (host, port)."""
    host, _, port = spec.rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(f"coordinator must be 'host:port', got {spec!r}")
    return host, int(port)


def is_multihost_transport(transport) -> bool:
    """True for transports whose ranks live in different OS processes (they
    carry a rank/world identity and a real payload broadcast)."""
    return (getattr(transport, "world", 0) or 0) > 0 \
        and hasattr(transport, "broadcast_payload")


def _steady_state(sock: socket.socket) -> None:
    """Post-handshake socket mode: the rendezvous timeout must NOT govern
    training rounds (a slow jit or straggler rank is healthy, not dead) —
    block indefinitely and let TCP keepalive surface dead peers."""
    sock.settimeout(None)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_KEEPALIVE, 1)


# ---------------------------------------------------------------------------
# frame I/O
# ---------------------------------------------------------------------------


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError(
                f"peer closed mid-frame: got {len(buf)} of {n} bytes")
        buf.extend(chunk)
    return bytes(buf)


def send_frame(sock: socket.socket, ftype: int, rank: int, world: int,
               payload: bytes = b"") -> int:
    """Send one frame; returns the bytes that crossed the socket."""
    sock.sendall(struct.pack(_FRAME_FMT, FRAME_MAGIC, ftype, rank, world,
                             len(payload)) + payload)
    return FRAME_HEADER_BYTES + len(payload)


def recv_frame(sock: socket.socket,
               expect: int | None = None) -> tuple[int, int, int, bytes]:
    """Receive one frame -> (type, rank, world, payload).  Raises
    `ConnectionError` on torn frames, bad magic, or an unexpected type."""
    hdr = _recv_exact(sock, FRAME_HEADER_BYTES)
    magic, ftype, rank, world, length = struct.unpack(_FRAME_FMT, hdr)
    if magic != FRAME_MAGIC:
        raise ConnectionError(f"bad frame magic {magic!r} (want "
                              f"{FRAME_MAGIC!r}) — not a multihost peer?")
    if length > _MAX_FRAME_PAYLOAD:
        raise ConnectionError(f"frame length {length} exceeds the "
                              f"{_MAX_FRAME_PAYLOAD}-byte cap")
    payload = _recv_exact(sock, length) if length else b""
    if expect is not None and ftype != expect:
        if ftype == GOODBYE:
            raise ConnectionError(
                f"peer said goodbye: {payload.decode(errors='replace')}")
        raise ConnectionError(f"expected frame type {expect}, got {ftype}")
    return ftype, rank, world, payload


class _FrameBuffer:
    """Per-connection receive buffer for the server's selectors reactor.

    Frames are reassembled incrementally from whatever bytes the socket had
    ready, so a slow rank mid-frame never blocks the ranks behind it — and
    bytes that belong to the NEXT frame (a worker may pipeline its SCALAR
    loss frame right behind its PAYLOAD) stay buffered for the next read."""

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> None:
        self._buf.extend(data)

    def next_frame(self) -> tuple[int, int, int, bytes] | None:
        """Pop one complete frame -> (type, rank, world, payload), or None
        if the buffer does not hold a full frame yet.  Raises
        `ConnectionError` on bad magic / oversized frames (same contract as
        `recv_frame`)."""
        if len(self._buf) < FRAME_HEADER_BYTES:
            return None
        magic, ftype, rank, world, length = struct.unpack_from(
            _FRAME_FMT, self._buf, 0)
        if magic != FRAME_MAGIC:
            raise ConnectionError(f"bad frame magic {magic!r} (want "
                                  f"{FRAME_MAGIC!r}) — not a multihost peer?")
        if length > _MAX_FRAME_PAYLOAD:
            raise ConnectionError(f"frame length {length} exceeds the "
                                  f"{_MAX_FRAME_PAYLOAD}-byte cap")
        end = FRAME_HEADER_BYTES + length
        if len(self._buf) < end:
            return None
        payload = bytes(self._buf[FRAME_HEADER_BYTES:end])
        del self._buf[:end]
        return ftype, rank, world, payload


# ---------------------------------------------------------------------------
# the transport
# ---------------------------------------------------------------------------


class TcpStarTransport:
    """Socket star over ``world`` OS processes; rank 0 aggregates.

    Build with `serve` (rank 0) or `connect` (ranks 1..W-1) — or via
    ``make_transport("tcp", rank=..., world=..., coordinator="host:port")``.
    Implements the `Transport` seam with multihost semantics: `exchange`
    takes THIS rank's single payload and returns all ``world`` payloads on
    rank 0 (rank-ordered) and ``[]`` on workers; `broadcast_payload` ships
    the direction blob down every link.
    """

    def __init__(self, rank: int, world: int):
        self.rank = rank
        self.world = world
        self.stats = TransportStats()
        self._policy_hash = b""      # codec-policy fingerprint (HELLO check)
        self._conns: dict[int, socket.socket] = {}   # server: rank -> socket
        self._bufs: dict[int, _FrameBuffer] = {}     # server: rank -> buffer
        self._sock: socket.socket | None = None      # worker: server link
        self._listener: socket.socket | None = None
        self._timeout: float = 60.0
        self.port: int | None = None
        #: rank order in which the last `exchange` round's uplink frames
        #: COMPLETED on the server (fan-in observability; regression surface
        #: for the selectors reactor — a slow rank lands last, not first)
        self.last_arrival_order: list[int] = []
        # per-round fan-in timing (server): round start + completion lags,
        # feeding the straggler timeline in `repro.obs`
        self._round_t0 = 0.0
        self._round_lags: list[float] = []

    # ---- construction ------------------------------------------------------

    @classmethod
    def listen(cls, host: str = "127.0.0.1", port: int = 0, *, world: int,
               timeout: float = 60.0,
               policy_hash: str | None = None) -> "TcpStarTransport":
        """Rank 0, step 1: bind ``host:port`` (0 = ephemeral; the kernel's
        choice lands in ``.port``) without blocking.  Call
        `accept_workers` to run the rendezvous.  ``policy_hash`` is this
        rank's codec-policy fingerprint — workers whose HELLO carries a
        different one are refused (fail fast at rendezvous, not desync
        mid-run)."""
        if not 2 <= world <= MAX_WORLD:
            raise ValueError(f"world must be in [2, {MAX_WORLD}], got {world}")
        t = cls(0, world)
        t._policy_hash = (policy_hash or "").encode()
        t._listener = socket.create_server((host, port))
        t.port = t._listener.getsockname()[1]
        t._timeout = timeout
        return t

    def accept_workers(self) -> "TcpStarTransport":
        """Rank 0, step 2: accept HELLOs until all ``world - 1`` workers
        have joined.  Bad handshakes are refused with a GOODBYE and do not
        kill the server; returns self for chaining."""
        srv, timeout = self._listener, self._timeout
        deadline = time.monotonic() + timeout

        def timed_out():
            self.close()
            raise TimeoutError(
                f"rendezvous timed out after {timeout}s with "
                f"{len(self._conns)}/{self.world - 1} workers connected")

        while len(self._conns) < self.world - 1:
            remaining = deadline - time.monotonic()
            if remaining <= 0:    # settimeout(0) would mean non-blocking
                timed_out()
            srv.settimeout(remaining)
            try:
                conn, _ = srv.accept()
            except (socket.timeout, TimeoutError):
                timed_out()
            # a stray/silent peer gets a short grace, never the whole
            # deadline — real workers' HELLOs must still fit in it
            conn.settimeout(
                max(0.1, min(_HELLO_GRACE_S, deadline - time.monotonic())))
            try:
                _, rank, w, token = recv_frame(conn, expect=HELLO)
            except (ConnectionError, socket.timeout, TimeoutError, OSError):
                conn.close()
                continue
            conn.settimeout(timeout)     # GOODBYE/WELCOME writes below
            reason = None
            tok, _, peer_policy = token.partition(b"|")
            if tok != HELLO_TOKEN:
                reason = f"protocol token mismatch (server {HELLO_TOKEN!r})"
            elif peer_policy != self._policy_hash:
                reason = ("policy mismatch: server "
                          f"{self._policy_hash.decode() or '<none>'}, worker "
                          f"{peer_policy.decode(errors='replace') or '<none>'}")
            elif w != self.world:
                reason = f"world mismatch: server {self.world}, worker {w}"
            elif not 1 <= rank < self.world:
                reason = f"rank {rank} out of range [1, {self.world})"
            elif rank in self._conns:
                reason = f"rank {rank} already connected"
            if reason is not None:
                with contextlib.suppress(OSError):
                    send_frame(conn, GOODBYE, 0, self.world, reason.encode())
                conn.close()
                continue
            send_frame(conn, WELCOME, 0, self.world)
            _steady_state(conn)
            self._conns[rank] = conn
            self._bufs[rank] = _FrameBuffer()
        return self

    @classmethod
    def serve(cls, host: str = "127.0.0.1", port: int = 0, *, world: int,
              timeout: float = 60.0,
              policy_hash: str | None = None) -> "TcpStarTransport":
        """Rank 0: `listen` + `accept_workers` in one blocking call (the
        ``make_transport("tcp", rank=0, ...)`` path, where the port is
        fixed up front and every worker retries until it is up)."""
        return cls.listen(host, port, world=world, timeout=timeout,
                          policy_hash=policy_hash).accept_workers()

    @classmethod
    def connect(cls, host: str, port: int, *, rank: int, world: int,
                timeout: float = 60.0,
                policy_hash: str | None = None) -> "TcpStarTransport":
        """Ranks 1..W-1: dial the coordinator (retrying until ``timeout`` so
        workers may start before the server) and handshake.
        ``policy_hash`` rides the HELLO payload behind a ``|`` separator;
        a server running a different policy refuses the handshake."""
        if not 2 <= world <= MAX_WORLD:
            raise ValueError(f"world must be in [2, {MAX_WORLD}], got {world}")
        if not 1 <= rank < world:
            raise ValueError(f"worker rank must be in [1, {world}), got {rank}")
        deadline = time.monotonic() + timeout
        while True:
            try:
                sock = socket.create_connection((host, port), timeout=1.0)
                break
            except OSError as e:
                if time.monotonic() >= deadline:
                    raise ConnectionError(
                        f"could not reach coordinator {host}:{port} within "
                        f"{timeout}s: {e}") from e
                time.sleep(0.05)
        sock.settimeout(timeout)
        hello = HELLO_TOKEN + (b"|" + policy_hash.encode()
                               if policy_hash else b"")
        try:
            send_frame(sock, HELLO, rank, world, hello)
            _, _, w, _ = recv_frame(sock, expect=WELCOME)
        except Exception:
            sock.close()
            raise
        if w != world:
            sock.close()
            raise ConnectionError(f"server runs world={w}, we expect {world}")
        _steady_state(sock)
        t = cls(rank, world)
        t._policy_hash = (policy_hash or "").encode()
        t._sock = sock
        return t

    # ---- Transport seam ----------------------------------------------------

    @property
    def is_server(self) -> bool:
        return self.rank == 0

    def _buffered_frame_from(self, r: int,
                             expect: int) -> tuple[int, int, int, bytes]:
        """Server: pop the next complete frame from rank ``r``'s buffer,
        blocking on its socket only when the buffer is empty."""
        buf = self._bufs[r]
        frame = buf.next_frame()
        while frame is None:
            data = self._conns[r].recv(1 << 16)
            if not data:
                raise ConnectionError(f"rank {r} closed its uplink")
            buf.feed(data)
            frame = buf.next_frame()
        ftype, sender, _, payload = frame
        if ftype != expect:
            if ftype == GOODBYE:
                raise ConnectionError(
                    f"peer said goodbye: {payload.decode(errors='replace')}")
            raise ConnectionError(f"expected frame type {expect}, got "
                                  f"{ftype} from rank {r}")
        if sender != r:
            raise ConnectionError(
                f"link for rank {r} delivered a frame from rank {sender}")
        return frame

    def exchange(self, payloads: list[bytes],
                 on_payload=None) -> list[bytes]:
        """Ship THIS rank's payload.  Rank 0 returns all ``world`` payloads
        in rank order; workers return ``[]`` (the aggregate comes back via
        `broadcast_payload`).

        The server drains uplinks through a `selectors` reactor: frames
        from all workers interleave as their bytes arrive, so one slow or
        large rank no longer serializes the ranks behind it (the former
        rank-by-rank drain blocked on rank 1 before reading rank 2's
        already-delivered frame).

        ``on_payload(rank, payload)`` is invoked on the server the moment
        each rank's frame COMPLETES (rank 0's own payload first), while the
        reactor is still waiting on the remaining uplinks — the aggregation
        layer uses it to parse, stage, and dispatch the decode of each
        packet during network wait instead of after the full drain."""
        if len(payloads) != 1:
            raise ValueError(
                "multihost exchange ships exactly one payload per rank per "
                f"round (one rank hosts one worker); got {len(payloads)}")
        t0 = time.perf_counter()
        self.stats.rounds += 1
        local = payloads[0]
        tel = obs.active()
        if self.is_server:
            out: list[bytes | None] = [local] + [None] * (self.world - 1)
            self.last_arrival_order = []
            self._round_t0 = t0
            self._round_lags = []
            if on_payload is not None:
                on_payload(0, local)
            pending = set(self._conns)
            # frames already sitting in the buffers (pipelined last round)
            for r in sorted(pending):
                frame = self._bufs[r].next_frame()
                if frame is not None:
                    self._finish_payload(out, r, frame, on_payload)
                    pending.discard(r)
            with selectors.DefaultSelector() as sel:
                for r in pending:
                    sel.register(self._conns[r], selectors.EVENT_READ, r)
                while pending:
                    for key, _ in sel.select():
                        r = key.data
                        data = key.fileobj.recv(1 << 16)
                        if not data:
                            raise ConnectionError(
                                f"rank {r} closed its uplink mid-round")
                        self._bufs[r].feed(data)
                        frame = self._bufs[r].next_frame()
                        if frame is not None:
                            self._finish_payload(out, r, frame, on_payload)
                            pending.discard(r)
                            sel.unregister(key.fileobj)
            self.stats.bytes_up += sum(len(p) for p in out)
            self.stats.wall_time_s += time.perf_counter() - t0
            if tel.enabled:
                # fan-in straggler skew: first to last uplink completion
                lags = self._round_lags
                tel.trace.complete(
                    "wire/exchange", t0, cat="wire", pid=0,
                    nbytes=sum(len(p) for p in out),
                    arrival_order=list(self.last_arrival_order),
                    fanin_skew_s=(max(lags) - min(lags)) if lags else 0.0)
                if lags:
                    tel.observe("wire_fanin_skew_s", max(lags) - min(lags),
                                transport="tcp")
            return out
        sent = send_frame(self._sock, PAYLOAD, self.rank, self.world, local)
        self.stats.bytes_up += len(local)
        self.stats.wire_bytes += sent
        self.stats.wall_time_s += time.perf_counter() - t0
        if tel.enabled:
            tel.trace.complete("wire/exchange", t0, cat="wire",
                               pid=self.rank, nbytes=len(local))
            tel.count("wire_bytes_up", sent, transport="tcp",
                      link=f"rank{self.rank}")
        return []

    def _finish_payload(self, out: list, r: int, frame,
                        on_payload=None) -> None:
        ftype, sender, _, data = frame
        if ftype != PAYLOAD:
            if ftype == GOODBYE:
                raise ConnectionError(
                    f"peer said goodbye: {data.decode(errors='replace')}")
            raise ConnectionError(f"expected frame type {PAYLOAD}, got "
                                  f"{ftype} from rank {r}")
        if sender != r:
            raise ConnectionError(
                f"link for rank {r} delivered a frame from rank {sender}")
        out[r] = data
        self.last_arrival_order.append(r)
        self.stats.wire_bytes += FRAME_HEADER_BYTES + len(data)
        tel = obs.active()
        if tel.enabled:
            # one instant per completed uplink: the straggler timeline
            lag = time.perf_counter() - self._round_t0
            self._round_lags.append(lag)
            tel.instant("wire/frame_arrival", cat="wire", pid=0,
                        rank=r, nbytes=len(data),
                        arrival_index=len(self.last_arrival_order) - 1,
                        lag_s=lag)
            tel.observe("wire_arrival_lag_s", lag, link=f"rank{r}")
            tel.count("wire_bytes_up", FRAME_HEADER_BYTES + len(data),
                      transport="tcp", link=f"rank{r}")
        if on_payload is not None:
            on_payload(r, data)

    def broadcast_payload(self, data: bytes | None, *,
                          encoded: bool = False) -> bytes:
        """Rank 0 passes the direction blob and sends it down every link;
        workers pass ``None`` and receive it.  Returns the blob on every
        rank.  ``encoded=True`` ships the blob on the ``DIRECTION_ENC``
        frame (a compressed RCD2 direction the receiver decodes against
        its DIANA shift — see `repro.comm.aggregate`); workers accept
        either frame type and dispatch on the blob's magic.

        ``bytes_down`` books only the ``world - 1`` REAL socket sends
        (frame headers included) on rank 0 — its own in-process loopback
        copy never crosses a wire; a worker books its received payload.
        ``wire_bytes`` counts socket bytes on this process as always."""
        t0 = time.perf_counter()
        tel = obs.active()
        ftype = DIRECTION_ENC if encoded else DIRECTION
        if self.is_server:
            if data is None:
                raise ValueError("rank 0 must provide the broadcast payload")
            sent = 0
            for r in sorted(self._conns):
                sent += send_frame(self._conns[r], ftype, 0, self.world, data)
            self.stats.wire_bytes += sent
            self.stats.bytes_down += sent
            self.stats.wall_time_s += time.perf_counter() - t0
            if tel.enabled:
                tel.trace.complete("wire/broadcast", t0, cat="wire", pid=0,
                                   nbytes=sent, encoded=encoded)
                tel.count("wire_bytes_down", sent, transport="tcp",
                          link="all")
            return data
        got, _, _, data = recv_frame(self._sock)
        if got not in (DIRECTION, DIRECTION_ENC):
            if got == GOODBYE:
                raise ConnectionError(
                    f"peer said goodbye: {data.decode(errors='replace')}")
            raise ConnectionError(f"expected a direction frame "
                                  f"({DIRECTION}/{DIRECTION_ENC}), got {got}")
        self.stats.bytes_down += len(data)
        self.stats.wire_bytes += FRAME_HEADER_BYTES + len(data)
        self.stats.wall_time_s += time.perf_counter() - t0
        if tel.enabled:
            tel.trace.complete("wire/broadcast", t0, cat="wire",
                               pid=self.rank, nbytes=len(data),
                               encoded=got == DIRECTION_ENC)
            tel.count("wire_bytes_down", FRAME_HEADER_BYTES + len(data),
                      transport="tcp", link=f"rank{self.rank}")
        return data

    def broadcast(self, nbytes: int, workers: int) -> None:
        raise RuntimeError(
            "TcpStarTransport measures real downlinks — use "
            "broadcast_payload(data), not the accounting-only broadcast()")

    def allreduce_scalar(self, value: float) -> float:
        """Mean of one float across all ranks (loss telemetry: every rank
        reports the same global number, like the in-process trainer).  The
        24-byte frames are booked in ``wire_bytes``/``wall_time_s`` only —
        they are telemetry, not gradient payload."""
        t0 = time.perf_counter()
        if self.is_server:
            total = float(value)
            for r in sorted(self._conns):
                # through the shared buffers: a worker may have pipelined
                # this SCALAR right behind its PAYLOAD frame
                _, _, _, data = self._buffered_frame_from(r, SCALAR)
                total += struct.unpack("<d", data)[0]
                self.stats.wire_bytes += FRAME_HEADER_BYTES + 8
            mean = total / self.world
            out = struct.pack("<d", mean)
            for r in sorted(self._conns):
                self.stats.wire_bytes += send_frame(
                    self._conns[r], SCALAR_MEAN, 0, self.world, out)
        else:
            self.stats.wire_bytes += send_frame(
                self._sock, SCALAR, self.rank, self.world,
                struct.pack("<d", float(value)))
            _, _, _, data = recv_frame(self._sock, expect=SCALAR_MEAN)
            self.stats.wire_bytes += FRAME_HEADER_BYTES + 8
            mean = struct.unpack("<d", data)[0]
        self.stats.wall_time_s += time.perf_counter() - t0
        return mean

    def gather_state(self, payload: bytes) -> list[bytes]:
        """Checkpoint-time gather: every rank ships one STATE frame (its
        client-side `CommState` rows); rank 0 returns all ``world``
        payloads in rank order, workers return ``[]``.  Runs between
        training rounds over the same buffered links as the SCALAR frames
        (a worker may have pipelined frames ahead of it), so it needs no
        barrier of its own.  Booked in ``wire_bytes`` only — checkpoint
        plumbing, not gradient payload."""
        t0 = time.perf_counter()
        if self.is_server:
            out: list[bytes | None] = [payload] + [None] * (self.world - 1)
            for r in sorted(self._conns):
                _, _, _, data = self._buffered_frame_from(r, STATE)
                out[r] = data
                self.stats.wire_bytes += FRAME_HEADER_BYTES + len(data)
            self.stats.wall_time_s += time.perf_counter() - t0
            return out
        self.stats.wire_bytes += send_frame(
            self._sock, STATE, self.rank, self.world, payload)
        self.stats.wall_time_s += time.perf_counter() - t0
        return []

    # ---- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        for conn in self._conns.values():
            with contextlib.suppress(OSError):
                conn.close()
        self._conns.clear()
        self._bufs.clear()
        for s in (self._sock, self._listener):
            if s is not None:
                with contextlib.suppress(OSError):
                    s.close()
        self._sock = self._listener = None

    def __enter__(self) -> "TcpStarTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def make_tcp_transport(*, rank: int, world: int,
                       coordinator: str = "127.0.0.1:37737",
                       timeout: float = 60.0,
                       policy_hash: str | None = None) -> TcpStarTransport:
    """The ``make_transport("tcp", ...)`` branch: rank 0 serves at
    ``coordinator``, every other rank dials it.  ``policy_hash`` (the
    rank's `ResolvedPolicy.hash`) rides the HELLO handshake so policy
    mismatches fail at rendezvous."""
    host, port = parse_coordinator(coordinator)
    if rank == 0:
        if port == 0:
            raise ValueError("coordinator port 0 only works single-process; "
                             "pick a concrete port every rank can dial "
                             "(repro.launch.multihost does this for you)")
        return TcpStarTransport.serve(host, port, world=world, timeout=timeout,
                                      policy_hash=policy_hash)
    return TcpStarTransport.connect(host, port, rank=rank, world=world,
                                    timeout=timeout, policy_hash=policy_hash)
