"""Deterministic fault injection for the multihost tcp star.

Chaos testing needs faults that are *reproducible*: a seeded schedule says
exactly which rank misbehaves at which round and how, so a failing run
replays bit-for-bit.  `FaultyTransport` wraps one WORKER rank's
`TcpStarTransport` and applies the schedule at each `exchange` call:

* ``delay``  — sleep ``seconds`` before sending the uplink (a straggler; a
  deadline server serves the round without it and discards the late,
  round-tagged frame on sight).
* ``drop``   — skip this round's uplink entirely (`skip_round` advances the
  round tag without sending; TCP never loses frames on its own, so a
  "dropped" frame is one that was never sent).
* ``torn``   — write a frame header promising more bytes than follow, then
  hard-close the socket (a peer dying mid-write).
* ``kill``   — hard-close the socket with ``SO_LINGER(1, 0)`` so the peer
  sees an RST, not a tidy FIN (a machine vanishing).  ``torn``/``kill``
  raise `InjectedFault` in the wrapped rank, which then typically walks
  `TcpStarTransport.rejoin`.

Rank 0 is the aggregation point and stays fault-free — the star has no
server failover; that is what the ROADMAP's decentralized follow-ups are
for.  Everything else delegates to the inner transport untouched, so the
wrapper composes with `is_multihost_transport`, the packed aggregators,
and per-link `TransportStats`.
"""

from __future__ import annotations

import dataclasses
import socket
import struct
import time
from typing import Iterable, Sequence

KINDS = ("delay", "drop", "torn", "kill")


class InjectedFault(RuntimeError):
    """Raised in the wrapped rank when a torn/kill fault fires."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled misbehavior: ``kind`` at ``round`` (``seconds`` is the
    delay length; ignored for the other kinds)."""

    round: int
    kind: str
    seconds: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"fault kind must be one of {KINDS}, "
                             f"got {self.kind!r}")
        if self.round < 0:
            raise ValueError(f"fault round must be >= 0, got {self.round}")


class FaultSchedule:
    """Per-rank fault timetable, keyed ``(rank, round)``.

    Build explicitly (``{rank: [Fault(...), ...]}``) or via `seeded`, which
    draws i.i.d. per-(rank, round) delays/drops from ``random.Random(seed)``
    — same seed, same faults, every run."""

    def __init__(self, by_rank: dict[int, Sequence[Fault]] | None = None):
        self._faults: dict[tuple[int, int], list[Fault]] = {}
        for rank, faults in (by_rank or {}).items():
            for f in faults:
                self._faults.setdefault((rank, f.round), []).append(f)

    def at(self, rank: int, round_: int) -> tuple[Fault, ...]:
        return tuple(self._faults.get((rank, round_), ()))

    def add(self, rank: int, fault: Fault) -> None:
        self._faults.setdefault((rank, fault.round), []).append(fault)

    def __len__(self) -> int:
        return sum(len(v) for v in self._faults.values())

    @classmethod
    def seeded(cls, seed: int, *, world: int, rounds: int,
               p_delay: float = 0.0, p_drop: float = 0.0,
               delay_s: float = 0.02,
               kills: Iterable[tuple[int, int]] = ()) -> "FaultSchedule":
        """Bernoulli delays/drops for every worker rank and round (rank 0
        is never faulted), plus explicit ``kills`` as (rank, round) pairs.
        A drop takes precedence over a delay drawn for the same slot."""
        import random
        rnd = random.Random(seed)
        sched = cls()
        for rank in range(1, world):
            for t in range(rounds):
                # draw both every slot so the stream stays aligned across
                # parameter choices with the same seed
                u_drop, u_delay = rnd.random(), rnd.random()
                if u_drop < p_drop:
                    sched.add(rank, Fault(t, "drop"))
                elif u_delay < p_delay:
                    sched.add(rank, Fault(t, "delay", delay_s))
        for rank, t in kills:
            sched.add(rank, Fault(t, "kill"))
        return sched


class FaultyTransport:
    """Wrap one worker's `TcpStarTransport`, applying ``schedule`` at each
    `exchange`.  Every other attribute (broadcast, allreduce, stats, rank,
    world, ...) delegates to the inner transport."""

    def __init__(self, inner, schedule: FaultSchedule):
        if getattr(inner, "rank", 0) == 0:
            raise ValueError("FaultyTransport wraps worker ranks; rank 0 is "
                             "the fault-free aggregation point")
        self._inner = inner
        self._schedule = schedule
        self._next_round = 0

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def exchange(self, payloads, on_payload=None, deadline_ms=None):
        round_ = self._next_round
        self._next_round += 1
        for f in self._schedule.at(self._inner.rank, round_):
            if f.kind == "delay":
                time.sleep(f.seconds)
            elif f.kind == "drop":
                self._inner.skip_round()
                return []
            elif f.kind == "torn":
                self._tear()
                raise InjectedFault(
                    f"rank {self._inner.rank} torn frame at round {round_}")
            else:   # kill
                self._kill()
                raise InjectedFault(
                    f"rank {self._inner.rank} killed at round {round_}")
        return self._inner.exchange(payloads, on_payload=on_payload,
                                    deadline_ms=deadline_ms)

    def _kill(self) -> None:
        """RST the uplink (SO_LINGER 0): the server sees an abrupt reset,
        never a clean FIN/LEAVE."""
        sock = self._inner._sock
        if sock is None:
            return
        try:
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                            struct.pack("ii", 1, 0))
        except OSError:
            pass
        try:
            sock.close()
        except OSError:
            pass
        self._inner._sock = None

    def _tear(self) -> None:
        """Write a frame header that promises more payload than follows,
        then die — the server's reassembly buffer must survive it."""
        from repro.comm import multihost as mh
        sock = self._inner._sock
        if sock is not None:
            try:
                sock.sendall(struct.pack(
                    mh._FRAME_FMT, mh.FRAME_MAGIC, mh.PAYLOAD,
                    self._inner.rank, self._inner.world, 4096) + b"\x00" * 64)
            except OSError:
                pass
        self._kill()

    def close(self) -> None:
        self._inner.close()
