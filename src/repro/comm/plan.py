"""Per-bucket wire plans — fixed-shape gradient buckets with shared codecs.

Every wire used to compress the WHOLE gradient as one flat d-vector after
the full backward finished, so the measured 0.16-1.1 s encode at d≈0.5-1.9M
(`BENCH_wire.json` codec_us) serialized strictly after compute.  A
`WirePlan` carves the flat dimension into fixed-shape buckets (the classic
DDP bucket trick) so that:

* each bucket can be encoded AS ITS BACKWARD SEGMENT COMPLETES — the
  `grad_tap` custom-vjp hook in `repro.train.step` streams per-leaf
  cotangents to a `GradBucketStreamer` during the backward pass, and the
  streamer dispatches each bucket's encode the moment its last leaf lands,
  overlapping encode/serialize with the remaining compute;
* equal-size buckets SHARE one codec instance: the plan's per-size cache
  delegates to the process-wide per-(codec, dim) LRU behind
  `repro.comm.compiled.make_compiled_codec`, so the packed and device
  wires (and every plan over the same bucket size) reuse the same jitted
  encode/decode programs instead of compiling one program per bucket.

Estimator semantics: each bucket is an INDEPENDENT compression of its
slice — for the MLMC families that means an independent Lemma-3.2 level
draw per bucket (key = ``fold_in(worker_key, bucket_index)``), which stays
unbiased per bucket and therefore unbiased for the concatenation.  The
bucketed bytes are bitwise identical to encoding each slice through a
standalone flat codec of the bucket's size with the same key (the
bucket-plan parity battery in ``tests/test_plan.py``).
"""

from __future__ import annotations

import queue
import struct
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.packets import (
    BUCKETS_HEADER_BYTES,
    BUCKETS_MAGIC,
    Packet,
    pack_bucket_payload,
    unpack_bucket_payload,
)
from repro.comm.transport import LoopbackTransport
from repro.obs import trace as obs

Array = jax.Array

#: the RCBW container now lives in `repro.comm.packets` (it is a wire
#: format, shared with the policy streams); these aliases keep the
#: historical import surface of this module working
_BUCKETS_MAGIC = BUCKETS_MAGIC
_BUCKETS_HEADER_BYTES = BUCKETS_HEADER_BYTES


def bucket_ranges(dim: int, bucket_size: int) -> tuple[tuple[int, int], ...]:
    """Carve ``[0, dim)`` into contiguous buckets of ``bucket_size`` (the
    last bucket takes the remainder)."""
    if bucket_size < 1:
        raise ValueError(f"bucket_size must be >= 1, got {bucket_size}")
    return tuple((s, min(s + bucket_size, dim))
                 for s in range(0, dim, bucket_size))


class WirePlan:
    """The per-bucket codec plan shared by the packed and device wires.

    ``factory(size) -> codec`` builds one codec for a bucket size —
    `repro.comm.aggregate._make_packed_codec` for the byte wire,
    `repro.comm.device_wire.make_device_codec` for the device wire.  The
    plan calls it once per DISTINCT size (all full buckets share one
    instance, and the compiled pipeline's process-wide LRU shares the
    jitted programs across plans and wires on top of that)."""

    def __init__(self, name: str, dim: int, bucket_size: int | None, factory,
                 *, segments=None):
        self.name = name
        self.dim = dim
        self.segments = tuple(segments) if segments is not None else None
        if self.segments is not None:
            self.bucket_size = 0
            self.ranges = tuple((s.start, s.stop) for s in self.segments)
        else:
            self.bucket_size = int(bucket_size)
            self.ranges = bucket_ranges(dim, self.bucket_size)
        self.num_buckets = len(self.ranges)
        self._factory = factory
        self._by_size: dict = {}

    @classmethod
    def from_policy(cls, resolved, factory, *, name: str = "policy"):
        """A plan whose buckets ARE a `ResolvedPolicy`'s segments — the
        policy-driven multi-stream realization.  ``factory(seg) -> codec``
        builds one codec per DISTINCT (codec, params, size) triple (shared
        across same-shaped segments, and the compiled LRU shares the
        jitted programs under that)."""
        return cls(name, resolved.dim, None, factory,
                   segments=resolved.segments)

    def codec(self, b: int):
        if self.segments is not None:
            seg = self.segments[b]
            key = (seg.codec, seg.params, seg.size)
            if key not in self._by_size:
                self._by_size[key] = self._factory(seg)
            return self._by_size[key]
        start, stop = self.ranges[b]
        size = stop - start
        if size not in self._by_size:
            self._by_size[size] = self._factory(size)
        return self._by_size[size]

    def segment_label(self, b: int) -> str:
        """Telemetry label for bucket ``b`` — the policy segment's name,
        or the positional bucket index for uniform plans."""
        if self.segments is not None:
            return self.segments[b].name
        return f"bucket{b}"

    def codec_name(self, b: int) -> str:
        return self.segments[b].codec if self.segments is not None \
            else self.name

    def bucket_key(self, worker_key, b: int):
        """The bucket's draw key: an independent MLMC level draw per
        bucket, deterministically derived so every substrate (batched,
        streamed, flat-slice reference) replays the identical draw."""
        return jax.random.fold_in(worker_key, b)

    def encode_bucket(self, v: Array, worker_key, b: int) -> Packet:
        """Encode ONE worker's bucket ``b`` of the flat gradient ``v``
        (or of the bucket slice itself when ``v`` is already sliced)."""
        start, stop = self.ranges[b]
        sl = v if v.shape[0] == stop - start else v[start:stop]
        return self.codec(b).encode(sl, self.bucket_key(worker_key, b)).packet

    def encode_round(self, worker_grads: Array, keys) -> list[list[Packet]]:
        """All workers, all buckets -> ``packets[b][w]`` (one vmapped
        encode per bucket on the compiled pipeline)."""
        out = []
        for b, (start, stop) in enumerate(self.ranges):
            codec = self.codec(b)
            bkeys = jax.vmap(lambda k, _b=b: jax.random.fold_in(k, _b))(keys)
            if hasattr(codec, "encode_batch"):
                out.append(codec.encode_batch(worker_grads[:, start:stop],
                                              bkeys))
            else:
                out.append([codec.encode(worker_grads[i, start:stop],
                                         bkeys[i]).packet
                            for i in range(worker_grads.shape[0])])
        return out

    def decode_mean(self, bucket_packets: list[list[Packet]]) -> Array:
        """Mean of the decoded estimates, concatenated across buckets."""
        parts = []
        for b, pkts in enumerate(bucket_packets):
            codec = self.codec(b)
            if hasattr(codec, "decode_mean"):
                parts.append(codec.decode_mean(pkts))
            else:
                parts.append(jnp.mean(jnp.stack(
                    [jnp.asarray(codec.decode(p)) for p in pkts]), axis=0))
        return jnp.concatenate(parts)

    def measured_bits(self, bucket_packets: list[list[Packet]]) -> float:
        return float(sum(self.codec(b).measured_bits(p)
                         for b, pkts in enumerate(bucket_packets)
                         for p in pkts))

    def segment_bits(self, bucket_packets: list[list[Packet]]) -> list[float]:
        """Per-bucket measured bits, aligned with ``ranges`` — the policy
        wire's per-stream byte accounting."""
        return [float(sum(self.codec(b).measured_bits(p) for p in pkts))
                for b, pkts in enumerate(bucket_packets)]

    def record_segments(self, tel, bucket_packets) -> None:
        """Per-segment telemetry: one byte counter per (segment, codec)
        stream plus the MLMC level draws of each stream's packets."""
        from repro.comm.aggregate import _record_mlmc_draws

        for b, pkts in enumerate(bucket_packets):
            codec = self.codec(b)
            tel.count("wire_segment_bits",
                      float(sum(codec.measured_bits(p) for p in pkts)),
                      segment=self.segment_label(b), codec=self.codec_name(b))
            _record_mlmc_draws(tel, codec, pkts)


class GradBucketStreamer:
    """Assembles per-worker flat gradients from backward-pass taps and
    encodes each bucket THE MOMENT its last leaf cotangent lands.

    The `grad_tap` hook (`repro.train.step`) fires one host callback per
    (worker, leaf) during the backward pass; `push` only enqueues (the
    XLA thread must not stall), and a dedicated encoder thread fills the
    per-worker flat buffers, tracks per-bucket completion, and dispatches
    the plan's encode for every completed bucket while the rest of the
    backward still runs.  `finish` drains the queue, fills any bucket the
    taps never delivered from the returned gradients (correctness never
    depends on the callbacks firing), and returns ``packets[b][w]``."""

    def __init__(self, plan: WirePlan, num_workers: int,
                 leaf_offsets: list[int], leaf_sizes: list[int]):
        self.plan = plan
        self.m = num_workers
        self.offsets = list(leaf_offsets)
        self.sizes = list(leaf_sizes)
        self._q: queue.Queue = queue.Queue()
        self._round = 0
        self._lock = threading.Lock()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="bucket-encoder")
        self._thread.start()

    def begin(self, rng) -> None:
        """Reset for one aggregation round; must see the SAME per-step rng
        the aggregator receives (keys replay the non-streamed path)."""
        with self._lock:
            self._round += 1
            self._keys = jax.random.split(rng, self.m)
            self._bufs = [np.zeros((self.plan.dim,), np.float32)
                          for _ in range(self.m)]
            self._remaining = [[stop - start
                                for start, stop in self.plan.ranges]
                               for _ in range(self.m)]
            self._packets: list[list[Packet | None]] = \
                [[None] * self.plan.num_buckets for _ in range(self.m)]

    def push(self, leaf_idx: int, wid, ct) -> None:
        """The tap callback: runs on the XLA execution thread — enqueue
        and return.  It must not touch the values (`int(wid)` /
        `np.asarray(ct)` block on the CPU client's thread pool, which is
        busy running the computation that is waiting for this callback:
        deadlock); the encoder thread does every host conversion."""
        self._q.put((self._round, leaf_idx, wid, ct))

    def _run(self) -> None:
        while True:
            item = self._q.get()
            try:
                self._consume(*item)
            except Exception:        # pragma: no cover - keep draining
                pass
            finally:
                self._q.task_done()

    def _consume(self, rnd: int, leaf_idx: int, w, ct) -> None:
        # host conversions happen HERE, off the XLA thread — waiting for
        # the value is harmless on this thread, fatal on the callback's
        w = int(w)
        ct = np.asarray(ct)
        with self._lock:
            if rnd != self._round or not 0 <= w < self.m:
                return                     # stale round / foreign tap
            off, size = self.offsets[int(leaf_idx)], self.sizes[int(leaf_idx)]
            self._bufs[w][off:off + size] = np.ravel(ct)
            tel = obs.active()
            for b, (start, stop) in enumerate(self.plan.ranges):
                overlap = min(stop, off + size) - max(start, off)
                if overlap <= 0 or self._packets[w][b] is not None:
                    continue
                self._remaining[w][b] -= overlap
                if self._remaining[w][b] == 0:
                    t0 = time.perf_counter() if tel.enabled else 0.0
                    self._packets[w][b] = self.plan.encode_bucket(
                        jnp.asarray(self._bufs[w][start:stop]),
                        self._keys[w], b)
                    if tel.enabled:
                        tel.trace.complete(
                            "wire/bucket_encode", t0, cat="wire", bucket=b,
                            worker=w, codec=self.plan.name, nbytes=stop - start)

    def finish(self, worker_grads: Array) -> list[list[Packet]]:
        """Drain the tap queue, backfill buckets the taps missed from the
        returned gradients, and return ``packets[b][w]``."""
        self._q.join()
        with self._lock:
            grads_np = None
            for w in range(self.m):
                for b in range(self.plan.num_buckets):
                    if self._packets[w][b] is None:
                        if grads_np is None:
                            grads_np = np.asarray(worker_grads)
                        self._packets[w][b] = self.plan.encode_bucket(
                            jnp.asarray(grads_np[w]), self._keys[w], b)
            return [[self._packets[w][b] for w in range(self.m)]
                    for b in range(self.plan.num_buckets)]


class BucketedPackedAggregate:
    """The bucketed realization of `PackedAggregate`: every worker's
    gradient ships as ``num_buckets`` independent packets (one container
    payload per worker), decoded and meaned per bucket, concatenated into
    the direction.  Stateless uplink; composes with a `Downlink`
    (DIANA-shift compressed direction) exactly like the flat aggregator.

    The trainer's streamed path (`step_streamed`) consumes a
    `GradBucketStreamer` whose per-bucket encodes already ran DURING the
    backward pass; `__call__` is the self-contained batch path (same
    bytes — the parity battery covers both)."""

    def __init__(self, plan: WirePlan, transport=None, downlink=None):
        self.plan = plan
        self.dim = plan.dim
        self.transport = transport or LoopbackTransport()
        self.downlink = downlink

    def init(self, num_workers: int, dim: int):
        from repro.core.types import empty_comm_state

        del num_workers
        return empty_comm_state(dim if self.downlink is not None else 0)

    def __call__(self, worker_grads: Array, rng, state=None):
        from repro.comm.multihost import is_multihost_transport

        tel = obs.active()
        if is_multihost_transport(self.transport):
            from repro.comm.aggregate import _require_one_worker

            _require_one_worker(worker_grads)
            tp = self.transport
            # same per-step key fan as the flat multihost wire: every rank
            # derives split(rng, world) and encodes with ITS OWN row, so
            # the container bytes match the in-process worker order
            keys = jax.random.split(rng, tp.world)[tp.rank:tp.rank + 1]
            t0 = time.perf_counter() if tel.enabled else 0.0
            bucket_packets = self.plan.encode_round(worker_grads, keys)
            if tel.enabled:
                tel.trace.complete("comm/encode", t0, pid=tp.rank,
                                   codec=self.plan.name, impl="bucketed",
                                   buckets=self.plan.num_buckets)
            return self._finish_multihost(bucket_packets, rng, state)
        m = worker_grads.shape[0]
        keys = jax.random.split(rng, m)
        t0 = time.perf_counter() if tel.enabled else 0.0
        bucket_packets = self.plan.encode_round(worker_grads, keys)
        if tel.enabled:
            tel.trace.complete("comm/encode", t0, codec=self.plan.name,
                               impl="bucketed", buckets=self.plan.num_buckets)
        return self._finish(bucket_packets, rng, state, m)

    def step_streamed(self, streamer: GradBucketStreamer,
                      worker_grads: Array, rng, state=None):
        from repro.comm.multihost import is_multihost_transport

        if is_multihost_transport(self.transport):
            raise ValueError(
                "streamed bucketed taps are in-process only (the streamer's "
                "key fan is per-local-worker); the batch path ships RCBW "
                "containers over the tcp star — call the aggregator itself")
        bucket_packets = streamer.finish(worker_grads)
        return self._finish(bucket_packets, rng, state,
                            worker_grads.shape[0])

    def _finish_multihost(self, bucket_packets, rng, state):
        from repro.comm.aggregate import _serve_round
        from repro.core.aggregators import AggregateOut

        tp = self.transport
        if state is None:
            state = self.init(tp.world, self.dim)
        payload = pack_bucket_payload(
            [bucket_packets[b][0].to_bytes()
             for b in range(self.plan.num_buckets)])
        dl = self.downlink
        direction, bits, shift = _serve_round(
            tp, None, payload, downlink=dl,
            shift=state.shift if dl is not None else None,
            key=dl.key(rng) if dl is not None else None, plan=self.plan)
        if dl is not None:
            state = state._replace(step=state.step + 1, shift=shift)
        return AggregateOut(direction, state, jnp.asarray(bits, jnp.float32))

    def _finish(self, bucket_packets, rng, state, m):
        from repro.comm.aggregate import _downlink_round
        from repro.core.aggregators import AggregateOut

        if state is None:
            state = self.init(m, self.dim)
        payloads = [pack_bucket_payload(
            [bucket_packets[b][w].to_bytes()
             for b in range(self.plan.num_buckets)]) for w in range(m)]
        delivered = self.transport.exchange(payloads)
        arrived: list[list[Packet]] = [[] for _ in self.plan.ranges]
        for raw in delivered:
            for b, part in enumerate(unpack_bucket_payload(raw)):
                arrived[b].append(Packet.from_bytes(part))
        tel = obs.active()
        t0 = time.perf_counter() if tel.enabled else 0.0
        direction = self.plan.decode_mean(arrived)
        if tel.enabled:
            tel.trace.complete("comm/decode_mean", t0, codec=self.plan.name,
                               impl="bucketed")
        bits = self.plan.measured_bits(arrived)
        if tel.enabled:
            self.plan.record_segments(tel, arrived)
        if self.downlink is not None:
            direction, state, dbits = _downlink_round(
                self.downlink, direction, state, rng, self.transport, m)
            state = state._replace(step=state.step + 1)
            bits += dbits
        else:
            self.transport.broadcast(4 * self.dim, m)
        return AggregateOut(direction, state, jnp.asarray(bits, jnp.float32))


def bucketed_packed_aggregator(name: str, dim: int, *, bucket_size: int,
                               transport=None, compiled=None, downlink=None,
                               codec_kw=None):
    """The ``bucket_size=`` branch of `packed_aggregator`.  Works on both
    the in-process transports and the tcp star: a multihost rank packs its
    per-bucket packets into ONE RCBW container per round, rank 0 unpacks
    every rank's container and decodes + means per bucket."""
    from repro.comm.aggregate import _make_packed_codec
    from repro.core.aggregators import Aggregator

    if name in ("ef21", "ef21_sgdm", "signsgd_ef", "mlmc_adaptive_topk",
                "mlmc_adaptive_stopk", "mlmc_adaptive_rtn"):
        raise ValueError(
            f"bucketed streaming does not support the stateful family "
            f"{name!r} yet — its per-worker state rows are defined over "
            "the whole flat gradient")
    kw = dict(codec_kw or {})

    def factory(size):
        skw = dict(kw)
        # dim-derived knobs must scale with the bucket, or every bucket
        # ships the FULL gradient's budget: the MLMC segment length ``s``
        # defaults to round(k_fraction * dim) in the Trainer, and keeping
        # it flat-sized made 9 buckets cost ~7x the flat packet's bits
        if skw.get("s", 0) > 1:
            skw["s"] = max(1, int(round(skw["s"] * size / dim)))
        return _make_packed_codec(name, size, compiled, skw)

    plan = WirePlan(name, dim, bucket_size, factory)
    ag = BucketedPackedAggregate(plan, transport, downlink=downlink)
    if downlink is not None:
        return Aggregator(name, ag, init=ag.init, stateful=True)
    return Aggregator(name, ag)


def policy_packed_aggregator(resolved, dim: int, *, transport=None,
                             compiled=None, downlink=None, codec_kw=None,
                             bucket_size: int | None = None):
    """The ``policy=`` branch of `packed_aggregator`: each policy segment
    streams through its own codec, and every worker's per-segment packets
    ship as ONE RCBW multi-stream container per round (in-process and over
    the tcp star alike).  ``bucket_size`` composes: segments subdivide into
    at-most-``bucket_size`` buckets so policy streams still overlap
    encode with the backward pass."""
    from repro.comm.aggregate import _make_packed_codec
    from repro.comm.policy import segment_codec_kw
    from repro.core.aggregators import Aggregator, STATEFUL_AGGREGATORS

    kw = dict(codec_kw or {})
    bad = sorted({s.codec for s in resolved.segments
                  if s.codec in STATEFUL_AGGREGATORS})
    if bad:
        raise ValueError(
            f"policy segments name stateful families {bad}: their "
            "per-worker CommState rows are defined over the whole flat "
            "gradient — use a one-segment policy for those")
    if bucket_size is not None:
        resolved = resolved.subdivide(bucket_size)

    def factory(seg):
        return _make_packed_codec(seg.codec, seg.size, compiled,
                                  segment_codec_kw(kw, seg, dim))

    plan = WirePlan.from_policy(resolved, factory)
    ag = BucketedPackedAggregate(plan, transport, downlink=downlink)
    if downlink is not None:
        return Aggregator("policy", ag, init=ag.init, stateful=True)
    return Aggregator("policy", ag)
