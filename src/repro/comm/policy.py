"""Per-leaf codec policies — rule-based (leaf path/size) -> codec maps.

Every wire used to compress the WHOLE gradient as one flat f32 vector
under ONE globally chosen codec.  Real models want heterogeneous
treatment: embeddings and layernorms dense (tiny, precision-critical),
the big matmuls under ``mlmc_topk`` (the bias/variance trade-off is
tensor-dependent — "On Biased Compression for Distributed Learning").
A `CodecPolicy` is an ordered list of first-match-wins rules mapping a
pytree leaf's path (fnmatch glob) or flat size (``size<=N`` forms) to a
codec name plus optional per-segment parameter overrides:

    policy = CodecPolicy.parse({"*embed*": "dense",
                                "*norm*":  "dense",
                                "*":       "mlmc_topk"})
    resolved = policy.resolve(params)        # -> ResolvedPolicy

``resolve`` flattens the tree in `ravel_pytree` leaf order, assigns every
leaf its codec, and merges ADJACENT leaves with identical assignments
into contiguous `Segment`\\s of the flat gradient — the named leaf-group
streams every substrate then encodes independently.  Estimator semantics
are exactly the bucket plan's: each segment is an independent compression
of its slice with draw key ``fold_in(worker_key, segment_index)``, so a
per-segment-unbiased family stays unbiased for the concatenation, and the
bytes are bitwise identical to a standalone flat codec of the segment's
size on every wire (abstract == packed == device == tcp — the parity
battery in ``tests/test_policy.py``).

A single-segment policy (``{"*": codec}``) is the DEGENERATE case:
`make_aggregator` routes it onto the plain single-codec path, bit-for-bit
identical to not passing a policy at all (golden fixtures unchanged).

``ResolvedPolicy.hash`` is the canonical fingerprint of (dim, segments,
codecs, params); the tcp HELLO handshake carries it so ranks running
different policies fail fast at rendezvous instead of desyncing mid-run.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import hashlib
import re

import jax

__all__ = [
    "CodecPolicy", "PolicyRule", "ResolvedPolicy", "Segment",
    "POLICY_PRESETS", "leaf_paths", "segment_codec_kw",
]

#: named presets (append-only: frozen by tests/test_golden_packets.py —
#: existing entries must never change meaning; add new names instead)
POLICY_PRESETS: dict[str, dict] = {
    # small tensors (embeddings rows, norms, biases) ship dense; the big
    # matmuls carry the MLMC estimator.  The 2048 threshold is the paper
    # configs' layernorm/bias scale — matmul leaves are orders larger.
    "dense_small_tensors": {"size<=2048": "dense", "*": "mlmc_topk"},
    # the path-glob flavour of the same idea, for trees with named leaves
    "dense_embed_norm": {"*embed*": "dense", "*norm*": "dense",
                         "*": "mlmc_topk"},
    # the degenerate one-segment policies, for config symmetry
    "uniform_mlmc_topk": {"*": "mlmc_topk"},
    "uniform_dense": {"*": "dense"},
}

_SIZE_RULE = re.compile(r"^size(<=|>=|<|>|==)(\d+)$")


@dataclasses.dataclass(frozen=True)
class PolicyRule:
    """One first-match-wins rule: glob ``pattern`` on the leaf path, or a
    ``size<=N``-style predicate on the leaf's flat element count."""

    pattern: str
    codec: str
    params: tuple = ()          # sorted ((key, value), ...) overrides

    def matches(self, path: str, size: int) -> bool:
        m = _SIZE_RULE.match(self.pattern)
        if m:
            op, n = m.group(1), int(m.group(2))
            return {"<=": size <= n, ">=": size >= n, "<": size < n,
                    ">": size > n, "==": size == n}[op]
        return fnmatch.fnmatchcase(path, self.pattern)


@dataclasses.dataclass(frozen=True)
class Segment:
    """A contiguous ``[start, stop)`` slice of the flat gradient that one
    codec owns.  ``name`` labels telemetry and error messages."""

    name: str
    codec: str
    start: int
    stop: int
    params: tuple = ()

    @property
    def size(self) -> int:
        return self.stop - self.start


def _leaf_path(key_path) -> str:
    """``a/0/w``-style path string for one `tree_flatten_with_path` key."""
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:                                   # pragma: no cover - exotic key
            parts.append(str(k))
    return "/".join(parts) or "flat"


def leaf_paths(tree) -> list[tuple[str, int]]:
    """``(path, size)`` per leaf, in `ravel_pytree` (= `tree_flatten`)
    leaf order — the order every wire's flat vector concatenates."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_leaf_path(kp), int(getattr(leaf, "size", 1) or 1))
            for kp, leaf in flat]


def _freeze_params(params) -> tuple:
    return tuple(sorted((str(k), params[k]) for k in params or {}))


class CodecPolicy:
    """An ordered rule list; see the module docstring for semantics."""

    def __init__(self, rules):
        self.rules = tuple(rules)
        if not self.rules:
            raise ValueError("CodecPolicy needs at least one rule")

    # -- construction -------------------------------------------------------

    @classmethod
    def parse(cls, spec) -> "CodecPolicy":
        """Accepts a preset name, a ``pattern=codec,pattern=codec`` string,
        a ``{pattern: codec | (codec, params)}`` dict (insertion order =
        match order), a rule sequence, or a `CodecPolicy` (returned as-is).
        """
        if isinstance(spec, CodecPolicy):
            return spec
        if isinstance(spec, str):
            if spec in POLICY_PRESETS:
                return cls.parse(POLICY_PRESETS[spec])
            rules = []
            for part in spec.split(","):
                part = part.strip()
                if not part:
                    continue
                pattern, sep, codec = part.rpartition("=")
                if not sep or not pattern or not codec:
                    raise ValueError(
                        f"bad policy rule {part!r}: want 'pattern=codec' "
                        f"(or a preset name from {sorted(POLICY_PRESETS)})")
                rules.append(PolicyRule(pattern.strip(), codec.strip()))
            return cls(rules)
        if isinstance(spec, dict):
            rules = []
            for pattern, target in spec.items():
                if isinstance(target, str):
                    rules.append(PolicyRule(pattern, target))
                else:
                    codec, params = target
                    rules.append(PolicyRule(pattern, codec,
                                            _freeze_params(params)))
            return cls(rules)
        return cls(spec)

    # -- matching -----------------------------------------------------------

    def match(self, path: str, size: int) -> PolicyRule:
        for rule in self.rules:
            if rule.matches(path, size):
                return rule
        raise ValueError(
            f"policy has no rule matching leaf {path!r} (size {size}); "
            "add a catch-all '*' rule")

    def leaf_specs(self, tree) -> list[tuple[str, str, dict]]:
        """Per-leaf ``(path, codec, params)`` in flat leaf order — the
        mesh wire's per-leaf method map."""
        return [(path, r.codec, dict(r.params))
                for path, size in leaf_paths(tree)
                for r in (self.match(path, size),)]

    # -- resolution ---------------------------------------------------------

    def resolve(self, tree) -> "ResolvedPolicy":
        """Assign every leaf, then merge ADJACENT identical assignments
        into contiguous flat-gradient segments."""
        segments: list[Segment] = []
        off = 0
        for path, size in leaf_paths(tree):
            rule = self.match(path, size)
            prev = segments[-1] if segments else None
            if prev is not None and (prev.codec, prev.params) == \
                    (rule.codec, rule.params):
                segments[-1] = dataclasses.replace(prev, stop=off + size)
            else:
                segments.append(Segment(f"{rule.codec}@{off}", rule.codec,
                                        off, off + size, rule.params))
            off += size
        return ResolvedPolicy(off, tuple(segments))

    def resolve_flat(self, dim: int) -> "ResolvedPolicy":
        """Resolve against an anonymous flat ``(dim,)`` vector (path
        ``"flat"``) — benches and wire-level tests without a real tree."""
        import numpy as np

        return self.resolve(np.zeros((dim,), np.float32))


@dataclasses.dataclass(frozen=True)
class ResolvedPolicy:
    """A policy applied to one concrete tree: named (segment, codec)
    streams covering ``[0, dim)`` exactly."""

    dim: int
    segments: tuple

    def __post_init__(self):
        off = 0
        for seg in self.segments:
            if seg.start != off or seg.stop <= seg.start:
                raise ValueError(f"segments must tile [0, dim): {seg}")
            off = seg.stop
        if off != self.dim:
            raise ValueError(
                f"segments cover [0, {off}) but dim is {self.dim}")

    @property
    def is_uniform(self) -> bool:
        """True when this is the degenerate one-codec policy — routed
        onto the plain single-codec path, bit-for-bit unchanged."""
        return len(self.segments) == 1

    @property
    def codecs(self) -> tuple:
        return tuple(dict.fromkeys(s.codec for s in self.segments))

    def canonical(self) -> str:
        parts = [f"dim={self.dim}"]
        for s in self.segments:
            kv = ";".join(f"{k}={v!r}" for k, v in s.params)
            parts.append(f"{s.start}:{s.stop}:{s.codec}:{kv}")
        return "|".join(parts)

    @property
    def hash(self) -> str:
        """Canonical fingerprint for the tcp HELLO handshake: ranks with
        differing policies must fail fast at rendezvous."""
        return hashlib.sha256(self.canonical().encode()).hexdigest()[:16]

    def subdivide(self, bucket_size: int) -> "ResolvedPolicy":
        """Split every segment into buckets of at most ``bucket_size`` —
        policy streams composed with the comm/compute-overlap plan."""
        from repro.comm.plan import bucket_ranges

        out = []
        for seg in self.segments:
            for lo, hi in bucket_ranges(seg.size, bucket_size):
                out.append(dataclasses.replace(
                    seg, name=f"{seg.name}+{lo}", start=seg.start + lo,
                    stop=seg.start + hi))
        return ResolvedPolicy(self.dim, tuple(out))


def segment_codec_kw(base_kw: dict, seg: Segment, dim: int) -> dict:
    """The codec kwargs for one segment: the aggregator-level defaults,
    overridden by the segment's rule params, with the dim-derived MLMC
    segment length ``s`` rescaled to the segment (the same rule as the
    bucket plan: a flat-sized ``s`` would ship the full gradient's budget
    per segment)."""
    kw = dict(base_kw)
    if kw.get("s", 0) > 1:
        kw["s"] = max(1, int(round(kw["s"] * seg.size / dim)))
    kw.update(dict(seg.params))
    return kw


def as_resolved(policy, dim: int):
    """Normalize a user-supplied policy argument (None | preset name |
    spec string | dict | `CodecPolicy` | `ResolvedPolicy`) to a
    `ResolvedPolicy` over a flat ``dim``-vector, or None."""
    if policy is None:
        return None
    if isinstance(policy, ResolvedPolicy):
        if policy.dim != dim:
            raise ValueError(
                f"policy resolved for dim {policy.dim}, aggregator dim {dim}")
        return policy
    return CodecPolicy.parse(policy).resolve_flat(dim)
