"""Bit-packing for the wire codecs — the comm-facing seam.

The Pallas implementation lives in :mod:`repro.kernels.pack` (kernels are a
lower layer than the wire; `repro.comm` depends on `repro.kernels`, never
the reverse).  Codec code imports packing through this module so the wire
subsystem has a single place to swap or instrument its packing backend.
"""

from repro.kernels.pack import (
    BLOCK_ROWS,
    fields_per_word,
    pack_bits,
    pack_words_2d,
    unpack_bits,
    unpack_words_2d,
)

__all__ = ["BLOCK_ROWS", "fields_per_word", "pack_bits", "pack_words_2d",
           "unpack_bits", "unpack_words_2d"]
