"""Compiled codec pipeline — the jit-compiled fast path of the byte wire.

`repro.comm.codec` runs every compressor eagerly: one XLA dispatch per jnp
op, a host round-trip per `np.asarray`, and a fresh Python `Packet` build
per worker.  That is fine for verification but pays a large host tax per
step (the BENCH_adaptive gap the wire benchmarks track).  This module
compiles the SAME math into fixed-shape jitted functions, so the only host
work per step is one `jax.device_get` of the packed uint32 buffers and the
byte framing:

* ``encode_arrays(v, rng[, probs]) -> (lane, word_buffers)`` — one jitted,
  fixed-shape function per (codec, dim) pair that replays the eager codec's
  float32 ops **in the same order** and bit-packs every stream on device
  with the Pallas kernels of :mod:`repro.kernels.pack`.  The fixed
  ``(EXT_LANE_LEN,)`` f32 lane (reusing the `device_wire` header-lane
  layout, extended with nnz/flags slots) carries every `Header` field;
  variable-length streams come back as max-size buffers the host slices to
  their actual word counts.  The resulting `Packet` is **byte-identical**
  to `WireCodec.encode`'s — locked down by the golden fixtures and the
  byte-equality battery in ``tests/test_compiled_codec.py``.
* ``decode_arrays(lane, word_buffers) -> estimate`` — the jitted inverse,
  consuming zero-copy staged buffers.
* ``encode_batch`` — all M workers through ONE vmapped encode (the Pallas
  packers see a single batched launch via the 2D `pack_bits` path) and one
  `device_get`; ``decode_mean`` fuses unpack + scatter + the M-worker mean
  into one jit with **persistent donated staging buffers**: after the first
  step the host path allocates nothing (buffers are reused and donated to
  XLA, which recycles their device storage for the outputs).

`mlmc_rtn` / `mlmc_adaptive_rtn` are the one family whose stream WIDTH
depends on the sampled level, so their pipeline is two-stage: a small
jitted level draw, then a level-specialized jitted body (jit's cache holds
the <= `num_levels` variants).  The `mlmc_rtn` Elias-gamma correction
stream is entropy-coded on the host (same numpy helper as the eager codec,
so bytes trivially agree); see `repro.comm.codec.MLMCRTNCodec`.

Exactness contract: for every registry codec, ``compiled.encode(v, rng)``
returns a packet whose ``to_bytes()`` equals the eager codec's, and
``decode`` / the ``EncodeResult.estimate`` are elementwise equal.  vmapped
batch rows equal single-row encodes bit-for-bit (regression-tested), which
is what keeps a TCP rank (batch of 1) bitwise comparable to the in-process
loop (batch of M).
"""

from __future__ import annotations

import dataclasses
import functools
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.codec import (
    _EPS,
    EncodeResult,
    MLMCRTNCodec,
    WireCodec,
    gamma_signed_decode,
    gamma_signed_encode,
    make_codec,
)
from repro.comm.packets import (
    EXT_LANE_LEN,
    FLAG_DENSE_FALLBACK,
    FLAG_EXPLICIT_PROB,
    LANE_FLAGS,
    LANE_LEVEL,
    LANE_NNZ,
    LANE_PROB,
    LANE_SCALE,
    Header,
    Packet,
    Stream,
    ext_lane,
    ext_lane_to_header,
)
from repro.core.adaptive import _EPS as _ADAPTIVE_EPS
from repro.core.bitwise import _BELOW_ONE, _fixed_scale
from repro.core.types import categorical, opt_barrier, pin_rounding
from repro.kernels.pack import fields_per_word, pack_bits, unpack_bits
from repro.obs import trace as obs

Array = jax.Array


def _n_words(count: int, width: int) -> int:
    return -(-count // fields_per_word(width))


def rtn_grid(lvl, c):
    """The RTN grid (delta, m) as traced jnp f32 ops — the jnp replay of
    `repro.comm.codec._rtn_grid`, shared by every compiled RTN en/decoder
    so the byte-exactness-critical formula exists exactly once here.
    ``lvl`` must be a traced (un-foldable) scalar; see `opt_barrier`."""
    cells = jnp.float32(2.0) ** lvl - 1.0
    delta = jnp.float32(2.0) * c / jnp.maximum(cells, 1.0)
    return delta, jnp.floor(cells / 2.0)


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """Static layout of one (possible) packet stream: the jitted encode
    emits a fixed ``(M, words(max_count, width))`` uint32 buffer for it;
    the host slices each row to the actual count's word length."""

    name: str
    width: int
    max_count: int
    f32: bool = False      # payload is raw f32 bit patterns (width 32)
    rare: bool = False     # fetched from device only when a packet needs it

    @property
    def max_words(self) -> int:
        return _n_words(self.max_count, self.width)


class CompiledCodec:
    """Base wrapper: jitted encode/decode around an eager `WireCodec`.

    Subclasses define ``plan`` (every stream the family can emit),
    ``_row_encode`` (the traced per-worker math, emitting pre-pack code /
    value arrays zero-padded to ``max_count``), ``_streams_for`` /
    ``_counts`` (host: which plan streams a given header selects and their
    field counts), and ``_row_decode`` (the traced inverse).

    Bit accounting (`nominal_bits` / `measured_bits` / `reconcile_bounds` /
    `header_bits`) delegates to the eager codec — the packets are the same
    bytes, so the ledger reconciliation is shared."""

    def __init__(self, eager: WireCodec):
        self.eager = eager
        self.name, self.dim = eager.name, eager.dim
        self._enc_cache: dict = {}
        self._dec_cache: dict = {}
        self._stage: dict = {}
        self._inflight: dict = {}
        #: `make_compiled_codec` hands the SAME instance to every caller
        #: with matching params; the lock makes stage -> dispatch atomic
        #: so threaded aggregators (tests run rank workers in threads)
        #: cannot interleave writes to the shared staging buffers
        self._stage_lock = threading.Lock()

    # ---- per-family surface (overridden) -----------------------------------

    plan: tuple[StreamPlan, ...] = ()

    def _row_encode(self, v, key, probs):
        """(d,) f32 + key [+ (L,) probs] -> (lane, payload, estimate) where
        ``payload[i]`` is plan[i]'s pre-pack array (uint32 codes, or f32
        values when ``plan[i].f32``), zero-filled beyond the actual count."""
        raise NotImplementedError

    def _streams_for(self, header: Header) -> tuple[int, ...]:
        """Plan indices present in a packet with this header, in order."""
        return tuple(range(len(self.plan)))

    def _decode_sel_for(self, header: Header) -> tuple[int, ...]:
        """Plan indices the DECODER needs — may be a subset of the packet's
        streams (`CompiledSignSGD` skips an empty exact-zero side channel,
        and with it a d-sized scatter)."""
        return self._streams_for(header)

    def _counts(self, header: Header) -> tuple[int, ...]:
        """Actual field count of each selected stream."""
        raise NotImplementedError

    def _row_decode(self, lane, bufs, sel: tuple[int, ...]):
        """lane + word buffers (plan order per ``sel``) -> (d,) estimate."""
        raise NotImplementedError

    # ---- compiled encode ---------------------------------------------------

    def _pack_payload(self, payload):
        out = []
        for p, arr in zip(self.plan, payload):
            if p.f32:
                out.append(jax.lax.bitcast_convert_type(
                    arr.astype(jnp.float32), jnp.uint32))
            else:
                out.append(pack_bits(arr.astype(jnp.uint32), p.width))
        return tuple(out)

    def _encode_fn(self, with_probs: bool):
        if with_probs not in self._enc_cache:
            if with_probs:
                def run(V, K, probs):
                    lanes, payload, est = jax.vmap(self._row_encode)(
                        V, K, probs)
                    return lanes, self._pack_payload(payload), est
            else:
                def run(V, K):
                    lanes, payload, est = jax.vmap(
                        lambda v, k: self._row_encode(v, k, None))(V, K)
                    return lanes, self._pack_payload(payload), est
            self._enc_cache[with_probs] = jax.jit(run)
        return self._enc_cache[with_probs]

    def _dispatch_single(self, v: Array, rng, probs):
        V = jnp.asarray(v, jnp.float32)[None]
        K = (jnp.asarray(rng)[None] if rng is not None
             else jnp.zeros((1, 2), jnp.uint32))
        if probs is not None:
            return self._encode_fn(True)(
                V, K, jnp.asarray(probs, jnp.float32)[None])
        return self._encode_fn(False)(V, K)

    def encode_arrays(self, v: Array, rng, probs=None):
        """The core primitive: one jitted fixed-shape encode of a single
        gradient -> ``(header_lane, word_buffers)`` (plus the estimate,
        kept on device)."""
        lanes, bufs, est = self._dispatch_single(v, rng, probs)
        return lanes[0], tuple(b[0] for b in bufs), est[0]

    def _finish_packet(self, lane_row: np.ndarray, buf_rows,
                       rare_rows) -> Packet:
        """Host: one fetched lane row + buffer rows -> the byte `Packet`."""
        header = ext_lane_to_header(self.name, self.dim, lane_row)
        sel = self._streams_for(header)
        counts = self._counts(header)
        streams = []
        for i, count in zip(sel, counts):
            p = self.plan[i]
            row = rare_rows(i) if p.rare else buf_rows(i)
            streams.append(Stream(p.name, row[: _n_words(count, p.width)],
                                  p.width, count))
        return Packet(header, tuple(streams))

    def _fetch_rare(self, i: int, m: int, bufs, V) -> np.ndarray:
        """Fetch one rare-stream row on demand (dense MLMC fallbacks).
        Subclasses may derive the row from the gradient itself instead of
        a device buffer (`CompiledSignSGD`'s exact-zero side channel)."""
        del V
        return np.asarray(bufs[i][m])

    def encode_batch(self, worker_grads: Array, keys: Array = None,
                     probs=None) -> list[Packet]:
        """All M workers through one vmapped jitted encode + ONE device_get
        (rare streams — dense MLMC fallbacks, exact-zero side channels —
        are fetched per affected row only)."""
        tel = obs.active()
        t0 = time.perf_counter() if tel.enabled else 0.0
        if keys is None:   # deterministic codecs (top-k innovations)
            keys = jnp.zeros((worker_grads.shape[0], 2), jnp.uint32)
        if probs is not None:
            lanes, bufs, _ = self._encode_fn(True)(worker_grads, keys, probs)
        else:
            lanes, bufs, _ = self._encode_fn(False)(worker_grads, keys)
        hot = [i for i, p in enumerate(self.plan) if not p.rare]
        if tel.enabled:
            tel.trace.complete("codec/encode_dispatch", t0, cat="codec",
                               codec=self.name)
            t0 = time.perf_counter()
        fetched = jax.device_get((lanes, [bufs[i] for i in hot]))
        lanes_np, hot_np = fetched
        hot_map = dict(zip(hot, hot_np))
        if tel.enabled:
            tel.trace.complete("codec/device_get", t0, cat="codec",
                               codec=self.name)
            t0 = time.perf_counter()
        packets = []
        for m in range(lanes_np.shape[0]):
            packets.append(self._finish_packet(
                lanes_np[m],
                lambda i, m=m: hot_map[i][m],
                lambda i, m=m: self._fetch_rare(i, m, bufs, worker_grads)))
        if tel.enabled:
            tel.trace.complete("codec/frame_packets", t0, cat="codec",
                               codec=self.name, packets=len(packets))
        return packets

    def encode(self, v: Array, rng, probs=None) -> EncodeResult:
        """Eager-compatible single encode: byte-identical packet + the
        in-memory estimate (fetched for `EncodeResult` parity)."""
        lanes, bufs, est = self._dispatch_single(v, rng, probs)
        lane_np = jax.device_get(lanes)[0]
        V = jnp.asarray(v, jnp.float32)[None]
        pkt = self._finish_packet(lane_np,
                                  lambda i: np.asarray(bufs[i][0]),
                                  lambda i: self._fetch_rare(i, 0, bufs, V))
        return EncodeResult(pkt, np.asarray(est[0]))

    # ---- compiled decode ---------------------------------------------------

    def _decode_fn(self, sel: tuple[int, ...], mean: bool):
        key = (sel, mean)
        if key not in self._dec_cache:
            def run(lanes, *bufs):
                out = jax.vmap(
                    lambda lane, *b: self._row_decode(lane, b, sel))(
                        lanes, *bufs)
                return jnp.mean(out, axis=0) if mean else out
            # donate the staged word buffers: XLA recycles their device
            # storage for the decoded estimates (nothing else reads them).
            # On the CPU backend host-committed staging can never donate —
            # skip it there instead of warning every call.
            donate = () if jax.default_backend() == "cpu" else \
                tuple(range(1, 1 + len(sel)))
            self._dec_cache[key] = jax.jit(run, donate_argnums=donate)
        return self._dec_cache[key]

    def _lane_from_header(self, h: Header) -> np.ndarray:
        lane = np.zeros((EXT_LANE_LEN,), np.float32)
        lane[LANE_SCALE] = np.float32(h.scale)
        lane[LANE_PROB] = np.float32(h.prob)
        lane[LANE_LEVEL] = h.level
        lane[LANE_NNZ] = h.nnz
        lane[LANE_FLAGS] = h.flags
        return lane

    def _stage_buffers(self, m: int, sel: tuple[int, ...]):
        """Persistent numpy staging: reused every step, so the steady-state
        host path performs pure row copies (no allocation).  jax may
        zero-copy these aligned buffers on CPU, so the previous in-flight
        decode reading them must complete before they are overwritten —
        `_guard_inflight` enforces that (a no-op once the result has been
        consumed, which every training step's device_get forces)."""
        key = (m, sel)
        if key not in self._stage:
            self._stage[key] = (
                np.zeros((m, EXT_LANE_LEN), np.float32),
                [np.zeros((m, self.plan[i].max_words), np.uint32)
                 for i in sel],
            )
        prev = self._inflight.pop(key, None)
        if prev is not None:
            prev.block_until_ready()
        return self._stage[key]

    def _stage_packets(self, packets: list[Packet], sel: tuple[int, ...]):
        lanes, bufs = self._stage_buffers(len(packets), sel)
        for mrow, pkt in enumerate(packets):
            lanes[mrow] = self._lane_from_header(pkt.header)
            for b, s in zip(bufs, pkt.streams):
                b[mrow, : s.words.size] = s.words
                # stale bytes beyond the actual word count are fine: every
                # decoder masks fields past the lane's count/nnz
        return lanes, bufs

    def decode_arrays(self, lane, bufs, sel: tuple[int, ...] | None = None):
        """The jitted fixed-shape decode of one staged packet."""
        sel = sel if sel is not None else tuple(range(len(self.plan)))
        fn = self._decode_fn(sel, mean=False)
        return fn(jnp.asarray(lane)[None], *(jnp.asarray(b)[None]
                                             for b in bufs))[0]

    def decode_device(self, packet: Packet) -> Array:
        """Dispatch one packet's jitted decode (async).  Uses FRESH staging
        so back-to-back dispatches never alias: jax zero-copies aligned
        numpy buffers on CPU, and the tcp server decodes uplinks as they
        arrive without waiting on the previous dispatch."""
        tel = obs.active()
        t0 = time.perf_counter() if tel.enabled else 0.0
        sel = self._decode_sel_for(packet.header)
        lanes = self._lane_from_header(packet.header)[None]
        bufs = []
        for i, s in zip(sel, packet.streams):
            b = np.zeros((1, self.plan[i].max_words), np.uint32)
            b[0, : s.words.size] = s.words
            bufs.append(b)
        out = self._decode_fn(sel, mean=False)(lanes, *bufs)[0]
        if tel.enabled:
            tel.trace.complete("codec/decode_dispatch", t0, cat="codec",
                               codec=self.name)
        return out

    def decode(self, packet: Packet) -> np.ndarray:
        """Eager-compatible decode (numpy out), via the jitted path."""
        return np.asarray(self.decode_device(packet))

    def decode_mean(self, packets: list[Packet]) -> Array:
        """Fused decode + M-worker mean: one jit, persistent donated
        staging.  Mixed stream variants (e.g. one worker's MLMC draw hit
        the dense fallback) fall back to per-packet decodes + the same
        mean, which keeps the result elementwise identical."""
        tel = obs.active()
        t0 = time.perf_counter() if tel.enabled else 0.0
        sels = {self._decode_sel_for(p.header) for p in packets}
        if len(sels) != 1:
            rows = jnp.stack([self.decode_device(p) for p in packets])
            out = jnp.mean(rows, axis=0)
        else:
            sel = sels.pop()
            with self._stage_lock:
                lanes, bufs = self._stage_packets(packets, sel)
                out = self._decode_fn(sel, mean=True)(lanes, *bufs)
                self._inflight[(len(packets), sel)] = out
        if tel.enabled:
            tel.trace.complete("codec/decode_mean", t0, cat="codec",
                               codec=self.name, packets=len(packets))
        return out

    def decode_stack(self, packets: list[Packet]) -> Array:
        """All packets' estimates as one (M, d) device array (one jit when
        the packets share a stream variant) — the EF21 server fold needs
        every worker's innovation, not just their mean."""
        tel = obs.active()
        t0 = time.perf_counter() if tel.enabled else 0.0
        sels = {self._decode_sel_for(p.header) for p in packets}
        if len(sels) != 1:
            out = jnp.stack([self.decode_device(p) for p in packets])
        else:
            sel = sels.pop()
            with self._stage_lock:
                lanes, bufs = self._stage_packets(packets, sel)
                out = self._decode_fn(sel, mean=False)(lanes, *bufs)
                self._inflight[(len(packets), sel)] = out
        if tel.enabled:
            tel.trace.complete("codec/decode_stack", t0, cat="codec",
                               codec=self.name, packets=len(packets))
        return out

    # ---- shared bit accounting (the packets are the same bytes) ------------

    def nominal_bits(self) -> float:
        return self.eager.nominal_bits()

    def header_bits(self, packet: Packet) -> float:
        return self.eager.header_bits(packet)

    def measured_bits(self, packet: Packet) -> float:
        return self.eager.measured_bits(packet)

    def reconcile_bounds(self, packet: Packet):
        return self.eager.reconcile_bounds(packet)

    @property
    def compressor(self):
        return self.eager.compressor


# ---------------------------------------------------------------------------
# single-level baselines
# ---------------------------------------------------------------------------


class CompiledDense(CompiledCodec):
    def __init__(self, eager):
        super().__init__(eager)
        self.plan = (StreamPlan("values", 32, self.dim, f32=True),)

    def _row_encode(self, v, key, probs):
        del key, probs
        est = jnp.asarray(v, jnp.float32)
        return ext_lane(prob=0.0), (est,), est

    def _counts(self, header):
        return (self.dim,)

    def _row_decode(self, lane, bufs, sel):
        return jax.lax.bitcast_convert_type(bufs[0], jnp.float32)


class CompiledSparse(CompiledCodec):
    """topk / randk / ef21: nnz == k positions + f32 values."""

    def __init__(self, eager):
        super().__init__(eager)
        self.k = eager.k
        self.index_width = eager.index_width
        self.plan = (StreamPlan("indices", self.index_width, self.k),
                     StreamPlan("values", 32, self.k, f32=True))

    def _sparse_payload(self, est, mask):
        idx = jnp.nonzero(mask, size=self.k, fill_value=0)[0]
        return idx.astype(jnp.uint32), est[idx]

    def _counts(self, header):
        return (header.nnz, header.nnz)

    def _row_decode(self, lane, bufs, sel):
        nnz = lane[LANE_NNZ].astype(jnp.int32)
        idx = unpack_bits(bufs[0], self.index_width, self.k)
        vals = jax.lax.bitcast_convert_type(bufs[1], jnp.float32)
        valid = jnp.arange(self.k) < nnz
        out = jnp.zeros((self.dim,), jnp.float32)
        return out.at[jnp.where(valid, idx, 0)].add(
            jnp.where(valid, vals, 0.0))


class CompiledTopK(CompiledSparse):
    def _row_encode(self, v, key, probs):
        del key, probs
        from repro.kernels import select

        v = jnp.asarray(v, jnp.float32)
        if self.k >= self.dim:
            idx = jnp.arange(self.dim, dtype=jnp.int32)
        else:
            # stable top_k indices, re-sorted ascending: the same bytes the
            # eager flatnonzero(mask) emits, without the global argsort
            idx = jnp.sort(select.topk_indices(v, self.k))
        vals = v[idx]
        est = jnp.zeros((self.dim,), jnp.float32).at[idx].set(vals)
        return ext_lane(prob=0.0, nnz=self.k), (idx.astype(jnp.uint32),
                                                vals), est


class CompiledRandK(CompiledSparse):
    def _row_encode(self, v, key, probs):
        del probs
        v = jnp.asarray(v, jnp.float32)
        perm = jax.random.permutation(key, self.dim)
        mask = jnp.zeros((self.dim,), bool).at[perm[: self.k]].set(True)
        est = jnp.where(mask, v * (self.dim / self.k), 0.0)
        idx = jnp.sort(perm[: self.k])
        return (ext_lane(prob=0.0, nnz=self.k),
                (idx.astype(jnp.uint32), est[idx]), est)


class CompiledQSGD(CompiledCodec):
    def __init__(self, eager):
        super().__init__(eager)
        self.s = eager.s
        self.width = eager.width
        self.plan = (StreamPlan("codes", self.width, self.dim),)

    def _row_encode(self, v, key, probs):
        del probs
        v = jnp.asarray(v, jnp.float32)
        # pinned replica of the eager jnp.linalg.norm (sqrt(sum(x*x))): the
        # squares stay rounded before the reduction, so the jitted norm —
        # and the scale header built from it — matches the eager bytes
        norm = jnp.maximum(jnp.sqrt(jnp.sum(pin_rounding(v * v))), _EPS)
        x = jnp.abs(v) / norm * self.s
        lo = jnp.floor(x)
        up = jax.random.bernoulli(key, x - lo)
        xi = lo + up.astype(v.dtype)
        est = norm * jnp.sign(v) * xi / self.s
        codes = (xi.astype(jnp.uint32) << 1) | (v < 0).astype(jnp.uint32)
        return ext_lane(scale=norm, prob=0.0), (codes,), est

    def _counts(self, header):
        return (self.dim,)

    def _row_decode(self, lane, bufs, sel):
        codes = unpack_bits(bufs[0], self.width, self.dim)
        xi = (codes >> 1).astype(jnp.float32)
        sgn = jnp.where((codes & 1) != 0, jnp.float32(-1.0), jnp.float32(1.0))
        norm = lane[LANE_SCALE]
        return (norm * sgn) * xi / jnp.float32(self.s)


class CompiledRTN(CompiledCodec):
    def __init__(self, eager):
        super().__init__(eager)
        self.level = eager.level
        self.plan = (StreamPlan("codes", self.level, self.dim),)

    def _grid(self, c):
        # barrier: a constant-folded level lets XLA rewrite the division as
        # a reciprocal multiply (1 ulp off the eager delta); keeping the
        # level un-foldable preserves the real division the bytes encode
        return rtn_grid(opt_barrier(jnp.asarray(self.level, jnp.float32)),
                        c)

    def _row_encode(self, v, key, probs):
        del key, probs
        v = jnp.asarray(v, jnp.float32)
        c = jnp.maximum(jnp.max(jnp.abs(v)), _EPS)
        delta, m = self._grid(c)
        q = jnp.clip(jnp.round(v / jnp.maximum(delta, _EPS)), -m, m)
        est = delta * q
        codes = (q + m).astype(jnp.uint32)
        return ext_lane(scale=c, prob=0.0, level=self.level), (codes,), est

    def _counts(self, header):
        return (self.dim,)

    def _row_decode(self, lane, bufs, sel):
        delta, m = self._grid(lane[LANE_SCALE])
        codes = unpack_bits(bufs[0], self.level, self.dim)
        return delta * (codes.astype(jnp.float32) - m)


class CompiledFixedPoint(CompiledCodec):
    def __init__(self, eager):
        super().__init__(eager)
        self.f = eager.f
        self.width = eager.width
        self.plan = (StreamPlan("codes", self.width, self.dim),)

    def _row_encode(self, v, key, probs):
        del key, probs
        v = jnp.asarray(v, jnp.float32)
        scale = _fixed_scale(v)
        x = jnp.minimum(jnp.abs(v) / scale, _BELOW_ONE)
        mant = jnp.floor(jnp.ldexp(x, self.f))
        trunc = jnp.ldexp(mant, -self.f)
        est = scale * jnp.sign(v) * trunc
        codes = (mant.astype(jnp.uint32) << 1) | (v < 0).astype(jnp.uint32)
        return ext_lane(scale=scale, prob=0.0), (codes,), est

    def _counts(self, header):
        return (self.dim,)

    def _row_decode(self, lane, bufs, sel):
        codes = unpack_bits(bufs[0], self.width, self.dim)
        trunc = jnp.ldexp((codes >> 1).astype(jnp.float32), -self.f)
        sgn = jnp.where((codes & 1) != 0, jnp.float32(-1.0), jnp.float32(1.0))
        return (lane[LANE_SCALE] * sgn) * trunc


class CompiledSignSGD(CompiledCodec):
    """Sign plane in jit; the exact-zero side channel is computed on the
    HOST in the rare nnz > 0 case only — materializing the positions on
    device costs a d-sized scatter (~35 ms at d=560k on the CPU backend)
    for a stream that is empty on every real gradient."""

    def __init__(self, eager):
        super().__init__(eager)
        self.plan = (StreamPlan("signs", 1, self.dim),
                     StreamPlan("zeros", 32, self.dim, rare=True))

    def _row_encode(self, v, key, probs):
        del key, probs
        v = jnp.asarray(v, jnp.float32)
        scale = jnp.mean(jnp.abs(v))
        est = jnp.sign(v) * scale
        bits = (v > 0).astype(jnp.uint32)
        nnz = jnp.sum((v == 0.0).astype(jnp.int32))
        lane = ext_lane(scale=scale, prob=0.0, nnz=nnz)
        # the zeros stream is NOT part of the payload: `_fetch_rare`
        # derives it from the gradient row when a packet actually needs it
        return lane, (bits,), est

    def _fetch_rare(self, i, m, bufs, V):
        assert self.plan[i].name == "zeros"
        return np.flatnonzero(
            np.asarray(V[m]) == 0.0).astype(np.uint32)

    def _counts(self, header):
        return (self.dim, header.nnz)

    def _decode_sel_for(self, header):
        # nnz == 0 (every real gradient): no zeros stream, no d-scatter
        return (0,) if header.nnz == 0 else (0, 1)

    def _row_decode(self, lane, bufs, sel):
        bits = unpack_bits(bufs[0], 1, self.dim)
        sgn = jnp.where(bits != 0, jnp.float32(1.0), jnp.float32(-1.0))
        if len(sel) > 1:
            nnz = lane[LANE_NNZ].astype(jnp.int32)
            zeros = unpack_bits(bufs[1], 32, self.dim)
            valid = jnp.arange(self.dim) < nnz
            # invalid slots scatter out of range and are dropped under jit
            sgn = sgn.at[jnp.where(valid, zeros, self.dim)].set(
                0.0, mode="drop")
        return sgn * lane[LANE_SCALE]


class CompiledNatural(CompiledCodec):
    def __init__(self, eager):
        super().__init__(eager)
        self._offset = eager._EXP_OFFSET
        self.plan = (StreamPlan("codes", eager.WIDTH, self.dim),)

    def _row_encode(self, v, key, probs):
        del probs
        v = jnp.asarray(v, jnp.float32)
        m, e = jnp.frexp(jnp.where(v == 0.0, 1.0, v))
        lo = jnp.ldexp(jnp.sign(m) * 0.5, e)
        hi = jnp.ldexp(jnp.sign(m) * 1.0, e)
        p_hi = 2.0 * jnp.abs(m) - 1.0
        take_hi = jax.random.bernoulli(key, jnp.clip(p_hi, 0.0, 1.0))
        est = jnp.where(v == 0.0, 0.0, jnp.where(take_hi, hi, lo))
        m2, e2 = jnp.frexp(jnp.where(est == 0.0, 1.0, est))
        del m2
        ecode = jnp.where(est == 0.0, 0, e2 + self._offset).astype(jnp.uint32)
        codes = (ecode << 1) | (est < 0).astype(jnp.uint32)
        return ext_lane(prob=0.0), (codes,), est

    def _counts(self, header):
        return (self.dim,)

    def _row_decode(self, lane, bufs, sel):
        codes = unpack_bits(bufs[0], self.plan[0].width, self.dim)
        ecode = (codes >> 1).astype(jnp.int32)
        sgn = jnp.where((codes & 1) != 0, jnp.float32(-0.5), jnp.float32(0.5))
        out = jnp.ldexp(sgn, ecode - self._offset)
        return jnp.where(ecode == 0, jnp.float32(0.0), out)


# ---------------------------------------------------------------------------
# MLMC families
# ---------------------------------------------------------------------------


class _CompiledMLMCBase(CompiledCodec):
    """Shared MLMC lane plumbing: resolve the decode-side p_l exactly as
    the eager `_MLMCCodecBase._prob_for` does — the shipped header prob
    when FLAG_EXPLICIT_PROB (or an always-adaptive family) says so, the
    family's static Lemma-3.3 distribution at the lane's level otherwise.
    One implementation, so a change to the resolution (clamp constant,
    normalization) cannot diverge the MLMC families."""

    #: the per-sample-adaptive families always trust the header prob
    adaptive = False

    def _prob_for(self, lane):
        if self.adaptive:
            return lane[LANE_PROB]
        explicit = lane[LANE_FLAGS].astype(jnp.int32) & FLAG_EXPLICIT_PROB
        probs = self.comp.static_probs()
        probs = probs / jnp.sum(probs)
        level = lane[LANE_LEVEL].astype(jnp.int32)
        static = jnp.maximum(probs[level - 1], 1e-30)
        return jnp.where(explicit != 0, lane[LANE_PROB], static)


class CompiledMLMCTopK(_CompiledMLMCBase):
    """Fused (s-)Top-k MLMC encode, sort-free: ONE uint32 key sort (4-5x
    cheaper than the float argsort it replaced) feeds both the Lemma-3.4
    residual-norm ladder (the bitcast back is sort(|v|) descending,
    bitwise) and the threshold band of the drawn rank segment; the segment
    members come out of a masked s-sized ``lax.top_k``, never a global
    rank vector.  Bitwise identical to the argsort path: every downstream
    f32 op replays on the same values in the same order."""

    def __init__(self, eager):
        super().__init__(eager)
        self.adaptive = eager.adaptive
        self.comp = eager.compressor
        self.s = self.comp.s
        self.index_width = eager.index_width
        self.plan = (StreamPlan("indices", self.index_width, self.s),
                     StreamPlan("values", 32, self.s, f32=True))

    def _row_encode(self, v, key, probs):
        from repro.kernels import select

        comp, d, s, L = self.comp, self.dim, self.s, self.comp.num_levels
        v = jnp.asarray(v, jnp.float32)
        keys = select.magnitude_keys(v)
        sorted_keys = None
        explicit = 0
        if self.adaptive:
            # the one u32 key sort feeds both the Lemma-3.4 ladder and the
            # band thresholds of the drawn segment
            sorted_keys = select.sort_magnitude_keys(keys)
            sorted_abs = select.sorted_abs_desc(v, sorted_keys=sorted_keys)
            sq = jnp.pad(pin_rounding(sorted_abs * sorted_abs),
                         (0, L * s - d))
            deltas = jnp.sqrt(jnp.sum(sq.reshape(L, s), axis=-1))
            total = jnp.sum(deltas)
            uniform = jnp.full_like(deltas, 1.0 / L)
            probs = jnp.where(total > 1e-30,
                              deltas / jnp.maximum(total, 1e-30), uniform)
        elif probs is None:
            probs = comp.static_probs()
        else:
            explicit = FLAG_EXPLICIT_PROB
        probs = probs / jnp.sum(probs)
        idx0 = categorical(key, probs)
        level = idx0 + 1
        p_l = jnp.maximum(probs[idx0], 1e-30)

        seg, in_use = select.rank_band_indices(v, idx0 * s, s, keys=keys,
                                               sorted_keys=sorted_keys)
        nnz = jnp.clip(d - idx0 * s, 0, s)
        idx = jnp.sort(jnp.where(in_use, seg, d))  # pad sentinel d sorts last
        vals = jnp.where(in_use, v[jnp.clip(idx, 0, d - 1)], 0.0)
        idx = jnp.where(in_use, idx, 0)
        est = jnp.zeros((d,), jnp.float32).at[
            jnp.where(in_use, idx, d)].add(vals / p_l, mode="drop")
        lane = ext_lane(prob=p_l, level=level, nnz=nnz, flags=explicit)
        return lane, (idx.astype(jnp.uint32), vals), est

    def _counts(self, header):
        return (header.nnz, header.nnz)

    def _row_decode(self, lane, bufs, sel):
        nnz = lane[LANE_NNZ].astype(jnp.int32)
        idx = unpack_bits(bufs[0], self.index_width, self.s)
        vals = jax.lax.bitcast_convert_type(bufs[1], jnp.float32)
        valid = jnp.arange(self.s) < nnz
        residual = jnp.zeros((self.dim,), jnp.float32).at[
            jnp.where(valid, idx, self.dim)].add(
                jnp.where(valid, vals, 0.0), mode="drop")
        return residual / self._prob_for(lane)


class CompiledMLMCFixed(_CompiledMLMCBase):
    def __init__(self, eager):
        super().__init__(eager)
        self.comp = eager.compressor
        self.plan = (StreamPlan("plane", 2, self.dim),
                     StreamPlan("residual", 32, self.dim, f32=True,
                                rare=True))

    def _row_encode(self, v, key, probs):
        from repro.core.mlmc import mlmc_estimate

        v = jnp.asarray(v, jnp.float32)
        est = mlmc_estimate(self.comp, v, key, probs=probs, adaptive=False)
        scale = _fixed_scale(v)
        residual = est.residual
        tern = jnp.sign(residual)
        plane = (tern + 1.0).astype(jnp.uint32)
        L = self.comp.num_levels
        explicit = FLAG_EXPLICIT_PROB if probs is not None else 0
        flags = jnp.where(est.level >= L,
                          FLAG_DENSE_FALLBACK | explicit, explicit)
        lane = ext_lane(scale=scale, prob=est.prob, level=est.level,
                        flags=flags)
        return lane, (plane, residual), est.estimate

    def _streams_for(self, header):
        return (1,) if header.flags & FLAG_DENSE_FALLBACK else (0,)

    def _counts(self, header):
        return (self.dim,)

    def _row_decode(self, lane, bufs, sel):
        p = self._prob_for(lane)
        if sel == (1,):
            residual = jax.lax.bitcast_convert_type(bufs[0], jnp.float32)
        else:
            tern = unpack_bits(bufs[0], 2, self.dim).astype(jnp.float32) - 1.0
            level = lane[LANE_LEVEL].astype(jnp.int32)
            residual = (lane[LANE_SCALE] * tern) * \
                jnp.ldexp(jnp.float32(1.0), -level)
        return residual / p


class CompiledMLMCFloat(_CompiledMLMCBase):
    def __init__(self, eager):
        super().__init__(eager)
        self.comp = eager.compressor
        self._offset = eager._EXP_OFFSET
        self.plan = (StreamPlan("base", 11, self.dim),
                     StreamPlan("plane", 1, self.dim),
                     StreamPlan("residual", 32, self.dim, f32=True,
                                rare=True))

    def _row_encode(self, v, key, probs):
        from repro.core.mlmc import mlmc_estimate

        v = jnp.asarray(v, jnp.float32)
        est = mlmc_estimate(self.comp, v, key, probs=probs, adaptive=False)
        m, e = self.comp._mantissa_exp(v)
        sgn = jnp.sign(m)
        ecode = (e + self._offset).astype(jnp.uint32)
        base_codes = (ecode << 2) | (sgn + 1.0).astype(jnp.uint32)
        bit = jnp.mod(jnp.floor(jnp.ldexp(jnp.abs(m), est.level + 1)),
                      2.0).astype(jnp.uint32)
        L = self.comp.num_levels
        explicit = FLAG_EXPLICIT_PROB if probs is not None else 0
        flags = jnp.where(est.level >= L,
                          FLAG_DENSE_FALLBACK | explicit, explicit)
        lane = ext_lane(prob=est.prob, level=est.level, flags=flags)
        return lane, (base_codes, bit, est.residual), est.estimate

    def _streams_for(self, header):
        return (0, 2) if header.flags & FLAG_DENSE_FALLBACK else (0, 1)

    def _counts(self, header):
        return (self.dim, self.dim)

    def _row_decode(self, lane, bufs, sel):
        base_codes = unpack_bits(bufs[0], 11, self.dim)
        sgn = (base_codes & 3).astype(jnp.float32) - 1.0
        e = (base_codes >> 2).astype(jnp.int32) - self._offset
        base = jnp.ldexp(sgn * jnp.float32(0.5), e)
        level = lane[LANE_LEVEL].astype(jnp.int32)
        if sel == (0, 2):
            residual = jax.lax.bitcast_convert_type(bufs[1], jnp.float32)
        else:
            bit = unpack_bits(bufs[1], 1, self.dim).astype(jnp.float32)
            residual = jnp.ldexp(sgn * bit, e - (level + 1))
        return base + residual / self._prob_for(lane)


class CompiledMLMCRTN:
    """Two-stage compiled MLMC-RTN: the stream WIDTH is the sampled level,
    so jit specializes per level (a <= `num_levels`-entry cache).  Stage A
    draws the level; stage B packs the level-l grid codes on device; the
    Elias-gamma correction stream of the ``mlmc_rtn`` wire format is
    entropy-coded on the host with the SAME numpy helper as the eager
    codec, so bytes agree by construction.

    Stage A's O(d*L) work — the adaptive Lemma-3.4 ladder (eight
    `compress(l) - compress(l-1)` norms) and the max-|v| scale — is JITTED
    with the levels UNROLLED as barrier-protected static scalars: the
    former eager stage A existed because `residual_norms`'s vmap over a
    *batched* level drifts 1 ulp under whole-graph jit (XLA specializes
    the batched grid math differently), but an unrolled ladder whose
    static levels pass through `opt_barrier` (so the per-level grid
    division cannot constant-fold into a reciprocal multiply) replays the
    eager bytes exactly — verified over the randomized battery in
    ``tests/test_compiled_codec.py`` and the golden fixtures.  Only the
    O(L)-element tail (normalize, categorical, p_l pick) stays eager:
    fusing it into the same jit re-drifts the p_l header byte."""

    def __init__(self, eager: MLMCRTNCodec):
        self.eager = eager
        self.name, self.dim = eager.name, eager.dim
        self.comp = eager.compressor
        self.adaptive = eager.adaptive
        self._body_cache: dict = {}
        self._dec_cache: dict = {}
        self._stage_a = None

    @property
    def compressor(self):
        return self.comp

    # ---- stage A: the level draw (jitted ladder, see class docstring) -----

    def _stage_a_fn(self):
        """Jitted (ladder, scale) for the Lemma-3.4 draw: the unrolled
        residual-norm ladder (adaptive only — a zero-row placeholder
        otherwise) and the RTN clip scale c, in ONE jit dispatch."""
        if self._stage_a is None:
            comp, adaptive = self.comp, self.adaptive
            L = comp.num_levels

            def stage_a(v):
                v = jnp.asarray(v, jnp.float32)
                if adaptive:
                    norms = []
                    for l in range(1, L + 1):
                        lt = opt_barrier(jnp.asarray(l, jnp.int32))
                        r = comp.residual(v, lt)
                        norms.append(jnp.sqrt(jnp.sum(pin_rounding(r * r))))
                    ladder = jnp.stack(norms)
                else:
                    ladder = jnp.zeros((L,), jnp.float32)
                return ladder, jnp.maximum(jnp.max(jnp.abs(v)), _EPS)

            self._stage_a = jax.jit(stage_a)
        return self._stage_a

    def _draw_row(self, v, key, probs):
        ladder, c = self._stage_a_fn()(v)
        if self.adaptive:
            # the eager tail of core.adaptive.adaptive_probs, applied to
            # the jitted ladder (same ops, same order)
            total = jnp.sum(ladder)
            uniform = jnp.full_like(ladder, 1.0 / ladder.shape[0])
            probs = jnp.where(total > _ADAPTIVE_EPS,
                              ladder / jnp.maximum(total, _ADAPTIVE_EPS),
                              uniform)
        elif probs is None:
            probs = self.comp.static_probs()
        probs = probs / jnp.sum(probs)
        idx = categorical(key, probs)
        p_l = jnp.maximum(probs[idx], 1e-30)
        return int(idx) + 1, p_l, c

    # ---- stage B: level-specialized encode body ---------------------------

    @staticmethod
    def _traced_level(level: int):
        """Static wire level as an un-foldable traced f32 scalar: constant
        folding would let XLA turn the grid division into a reciprocal
        multiply, 1 ulp off the eager delta the bytes encode."""
        return opt_barrier(jnp.asarray(level, jnp.float32))

    def _body_fn(self, level: int):
        if level not in self._body_cache:
            comp, d, L = self.comp, self.dim, self.comp.num_levels

            def codes_at(v, lvl, c):
                delta, m = rtn_grid(lvl, c)
                q = jnp.clip(jnp.round(v / jnp.maximum(delta, _EPS)), -m, m)
                return q, m, delta

            def body(v, p_l, c):
                v = jnp.asarray(v, jnp.float32)
                lvl_t = self._traced_level(level)
                residual = comp.residual(v, lvl_t.astype(jnp.int32))
                estimate = comp.base(v) + residual / p_l
                if level >= L:
                    return (jax.lax.bitcast_convert_type(
                        residual.astype(jnp.float32), jnp.uint32),
                        jnp.zeros((d,), jnp.int32), estimate)
                q_l, m_l, delta_l = codes_at(v, lvl_t, c)
                qwords = pack_bits((q_l + m_l).astype(jnp.uint32),
                                   max(level, 1))
                corr = jnp.zeros((d,), jnp.int32)
                if level > 1:
                    vals_l = delta_l * q_l
                    prev_t = self._traced_level(level - 1)
                    q_prev, _, _ = codes_at(v, prev_t, c)
                    q_hat, _, _ = codes_at(vals_l, prev_t, c)
                    corr = (q_prev - q_hat).astype(jnp.int32)
                return qwords, corr, estimate

            self._body_cache[level] = jax.jit(body)
        return self._body_cache[level]

    # ---- public surface ----------------------------------------------------

    def encode(self, v, rng, probs=None) -> EncodeResult:
        level, p_l, c = self._draw_row(v, rng, probs)
        pkt, est = self._finish_row(v, level, p_l, c, probs is not None)
        return EncodeResult(pkt, np.asarray(est))

    def _finish_row(self, v, level: int, p_l, c, explicit_probs: bool):
        L = self.comp.num_levels
        qwords, corr, est = self._body_fn(level)(v, p_l, c)
        flags = FLAG_EXPLICIT_PROB if (explicit_probs and
                                       not self.adaptive) else 0
        hdr_kw = dict(level=level, scale=float(np.float32(c)),
                      prob=float(np.float32(p_l)))
        if level >= L:
            hdr = Header(self.name, self.dim,
                         flags=FLAG_DENSE_FALLBACK | flags, **hdr_kw)
            return Packet(hdr, (Stream("residual", np.asarray(qwords), 32,
                                       self.dim),)), est
        streams = [Stream("q", np.asarray(qwords), max(level, 1), self.dim)]
        nnz = 0
        if level > 1:
            corr_np = np.asarray(corr)
            if self.eager.entropy_corr:
                words, nbits, nnz = gamma_signed_encode(corr_np)
                streams.append(Stream("corr", words, 1, nbits))
            else:
                streams.append(Stream(
                    "corr",
                    np.asarray(pack_bits(
                        jnp.asarray(corr_np + 1, jnp.uint32), 2)),
                    2, self.dim))
        hdr = Header(self.name, self.dim, nnz=nnz, flags=flags, **hdr_kw)
        return Packet(hdr, tuple(streams)), est

    def encode_batch(self, worker_grads, keys, probs=None) -> list[Packet]:
        V = jnp.asarray(worker_grads, jnp.float32)
        out = []
        for m in range(V.shape[0]):
            p_row = probs[m] if probs is not None else None
            level, p_l, c = self._draw_row(V[m], keys[m], p_row)
            out.append(self._finish_row(V[m], level, p_l, c,
                                        probs is not None)[0])
        return out

    def _decode_fn(self, level: int):
        if level not in self._dec_cache:
            d, L = self.dim, self.comp.num_levels

            def dec(qwords, corr, p, c):
                if level >= L:
                    residual = jax.lax.bitcast_convert_type(qwords,
                                                            jnp.float32)
                    return residual / p
                delta_l, m_l = rtn_grid(self._traced_level(level), c)
                q_l = unpack_bits(qwords, max(level, 1),
                                  d).astype(jnp.float32) - m_l
                vals_l = pin_rounding(delta_l * q_l)
                if level <= 1:
                    residual = vals_l - jnp.float32(0.0)
                else:
                    delta_p, m_p = rtn_grid(self._traced_level(level - 1), c)
                    q_hat = jnp.clip(jnp.round(
                        vals_l / jnp.maximum(delta_p, _EPS)), -m_p, m_p)
                    q_prev = q_hat + corr.astype(jnp.float32)
                    residual = vals_l - pin_rounding(delta_p * q_prev)
                return residual / p

            self._dec_cache[level] = jax.jit(dec)
        return self._dec_cache[level]

    def _corr_plane(self, packet: Packet) -> np.ndarray:
        s = packet.streams[1]
        if self.eager.entropy_corr:
            return gamma_signed_decode(s.words, s.count, self.dim)
        plain = np.asarray(unpack_bits(jnp.asarray(s.words), 2, self.dim))
        return plain.astype(np.int32) - 1

    def decode_device(self, packet: Packet):
        h = packet.header
        level = h.level
        corr = np.zeros((self.dim,), np.int32)
        if not (h.flags & FLAG_DENSE_FALLBACK) and level > 1:
            corr = self._corr_plane(packet)
        qwords = packet.streams[0].words
        if h.flags & FLAG_DENSE_FALLBACK:
            level = max(level, self.comp.num_levels)
        return self._decode_fn(level)(qwords, corr, np.float32(h.prob),
                                      np.float32(h.scale))

    def decode(self, packet: Packet) -> np.ndarray:
        return np.asarray(self.decode_device(packet))

    def decode_mean(self, packets: list[Packet]):
        rows = jnp.stack([self.decode_device(p) for p in packets])
        return jnp.mean(rows, axis=0)

    def decode_stack(self, packets: list[Packet]):
        return jnp.stack([self.decode_device(p) for p in packets])

    # ---- shared bit accounting --------------------------------------------

    def nominal_bits(self):
        return self.eager.nominal_bits()

    def header_bits(self, packet):
        return self.eager.header_bits(packet)

    def measured_bits(self, packet):
        return self.eager.measured_bits(packet)

    def reconcile_bounds(self, packet):
        return self.eager.reconcile_bounds(packet)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_BY_EAGER = {
    "DenseCodec": CompiledDense,
    "TopKCodec": CompiledTopK,
    "EF21InnovationCodec": CompiledTopK,
    "RandKCodec": CompiledRandK,
    "QSGDCodec": CompiledQSGD,
    "RTNCodec": CompiledRTN,
    "FixedPointCodec": CompiledFixedPoint,
    "SignSGDCodec": CompiledSignSGD,
    "NaturalCodec": CompiledNatural,
    "MLMCTopKCodec": CompiledMLMCTopK,
    "MLMCFixedCodec": CompiledMLMCFixed,
    "MLMCFloatCodec": CompiledMLMCFloat,
    "MLMCRTNCodec": CompiledMLMCRTN,
}


#: Per-DIRECTION latency defaults (``BENCH_wire.json`` "codec_us",
#: d=557,696, CPU).  Encode and decode regress independently, so the two
#: directions carry separate tables and `_make_packed_codec` mixes
#: pipelines per direction through `HybridCodec` when they disagree.  The
#: bytes are identical either way, so these are purely latency defaults;
#: an explicit ``compiled=True/False`` always wins.
#:
#: Encode: with the sort-free selection path the compiled encode now wins
#: for every stochastic codec (mlmc_topk 51ms vs 146ms eager, mlmc_rtn
#: 11ms vs 122ms).  The EF21 innovation encode stays eager: deterministic
#: top-k has no per-level jit work to amortize the staging round-trip
#: (8.7ms compiled vs 7.7ms eager).
COMPILED_ENCODE_OFF = frozenset({"ef21", "ef21_sgdm"})

#: Decode: the sparse-segment families pay the compiled path's host
#: staging copy without enough scatter work to amortize it — mlmc_topk
#: 1.39ms compiled vs 1.15ms eager; ef21 1.59ms vs 0.67ms.  The dense
#: unpack codecs (qsgd 2.3ms vs 8.1ms, mlmc_rtn 3.0ms vs 8.8ms) keep the
#: compiled decode.
COMPILED_DECODE_OFF = frozenset({"ef21", "ef21_sgdm", "mlmc_topk",
                                 "mlmc_topk_static", "mlmc_stopk"})

#: Legacy whole-pipeline table: names eager in BOTH directions.
COMPILED_DEFAULT_OFF = COMPILED_ENCODE_OFF & COMPILED_DECODE_OFF


def default_compiled(name: str, direction: str | None = None) -> bool:
    """The measured-faster pipeline for a registry name: True = compiled.

    ``direction`` selects the per-direction table ("encode" / "decode");
    ``None`` keeps the legacy whole-pipeline answer (False only when BOTH
    directions default eager)."""
    if direction == "encode":
        return name not in COMPILED_ENCODE_OFF
    if direction == "decode":
        return name not in COMPILED_DECODE_OFF
    if direction is not None:
        raise ValueError(f"unknown direction {direction!r}")
    return name not in COMPILED_DEFAULT_OFF


def compile_codec(eager: WireCodec):
    """Wrap an eager `WireCodec` in its compiled pipeline."""
    cls = _BY_EAGER.get(type(eager).__name__)
    if cls is None:
        raise ValueError(f"no compiled pipeline for {type(eager).__name__}")
    return cls(eager)


@functools.lru_cache(maxsize=32)
def _cached(name: str, dim: int, kw: tuple):
    return compile_codec(make_codec(name, dim, **dict(kw)))


def make_compiled_codec(name: str, dim: int, **kw):
    """`make_codec` + `compile_codec`, cached per (codec, dim, params) so
    repeated aggregator builds (benchmarks, tests) reuse compiled jits.

    The cache is bounded (LRU, 32 entries) because each instance pins its
    jit executables and persistent staging buffers: long sweeps over many
    (codec, dim) combinations evict cold instances instead of growing for
    the process lifetime (an aggregator keeps its own reference, so
    eviction never invalidates a live wire)."""
    return _cached(name, dim, tuple(sorted(kw.items())))


class HybridCodec:
    """Per-direction pipeline mix behind one codec-shaped object: compiled
    encode with eager decode (or the reverse), byte-identical bytes either
    way.  `default_compiled` measures the two directions independently and
    some codecs win on exactly one — the sort-free compiled mlmc_topk
    encode is ~3x the eager one, but its staged decode pays a host buffer
    copy the tiny eager segment scatter does not.

    The encode half drives ``encode`` (and ``encode_batch`` when it has
    one — its presence is what routes the aggregators' vmapped batch
    path).  The decode half drives the SINGLE-packet ``decode`` — the op
    the TCP per-frame drain and the downlink pay per rank.  The M-packet
    ``decode_mean`` / ``decode_stack`` prefer a fused implementation from
    EITHER half (measured: the fused unpack+scatter+mean over persistent
    staging buffers beats M eager decodes even when one eager decode beats
    one compiled decode — 3.1 ms vs 11.1 ms for mlmc_topk, M=4,
    d=557,696) and fall back to the eager per-packet loop.
    ``decode_device`` is exposed only when the decode half has it, so the
    TCP drain path (`repro.comm.aggregate._drain_decoding`) sees the
    truth.  Bit accounting and ``compressor`` delegate to the decode half
    (both halves share the eager ledger)."""

    def __init__(self, enc, dec):
        if enc.name != dec.name or enc.dim != dec.dim:
            raise ValueError("hybrid halves must wrap the same codec")
        self.enc, self.dec = enc, dec
        self.name, self.dim = enc.name, enc.dim
        if hasattr(enc, "encode_batch"):
            self.encode_batch = enc.encode_batch
        if hasattr(dec, "decode_device"):
            self.decode_device = dec.decode_device

    def encode(self, v, rng, probs=None):
        if probs is None:
            return self.enc.encode(v, rng)
        return self.enc.encode(v, rng, probs=probs)

    def decode(self, packet):
        return self.dec.decode(packet)

    def _fused(self, op: str):
        for half in (self.dec, self.enc):
            if hasattr(half, op):
                return getattr(half, op)
        return None

    def decode_mean(self, packets):
        fused = self._fused("decode_mean")
        if fused is not None:
            return fused(packets)
        return jnp.mean(self.decode_stack(packets), axis=0)

    def decode_stack(self, packets):
        fused = self._fused("decode_stack")
        if fused is not None:
            return fused(packets)
        return jnp.stack([jnp.asarray(self.dec.decode(p))
                          for p in packets])

    def nominal_bits(self):
        return self.dec.nominal_bits()

    def header_bits(self, packet):
        return self.dec.header_bits(packet)

    def measured_bits(self, packet):
        return self.dec.measured_bits(packet)

    def reconcile_bounds(self, packet):
        return self.dec.reconcile_bounds(packet)

    @property
    def compressor(self):
        return getattr(self.dec, "compressor", None)


@functools.lru_cache(maxsize=32)
def _cached_hybrid(name: str, dim: int, encode_compiled: bool, kw: tuple):
    comp = _cached(name, dim, kw)
    eager = comp.eager
    return HybridCodec(comp if encode_compiled else eager,
                       eager if encode_compiled else comp)


def make_hybrid_codec(name: str, dim: int, *, encode_compiled: bool = True,
                      **kw):
    """A cached `HybridCodec`: the compiled pipeline on one direction and
    that same instance's underlying eager codec on the other (so jit
    executables and the bit ledger are shared with `make_compiled_codec`
    for the same (codec, dim, params))."""
    return _cached_hybrid(name, dim, bool(encode_compiled),
                          tuple(sorted(kw.items())))
