"""Elastic membership for the multihost tcp star.

The star (`repro.comm.multihost.TcpStarTransport`) was built for a fixed,
healthy world: every rank arrives at rendezvous, answers every round, and
survives the whole run.  This module is the state rank 0 keeps when that
assumption is dropped (``deadline_ms`` on the transport turns it on):

* `Membership` — per-rank lifecycle (active / left, join and leave rounds,
  stored `CommState` STATE rows for mid-run REJOIN) plus the per-rank
  participation counts that make deadline partial aggregation *unbiased*.
* `BackoffSchedule` — the seeded, capped exponential backoff a worker walks
  while trying to reconnect (deterministic per seed, so chaos tests can
  assert the exact delays).

Unbiasedness (the MLMC connection): a deadline round aggregates only the
uplinks that arrived in time.  The naive mean over arrivals is biased
whenever participation is asymmetric — rank 0 never misses its own
deadline, so the aggregate drifts toward the fast ranks' data.  Instead the
server computes a Horvitz-Thompson estimate: each arrived row is weighted
by the inverse of that rank's *empirical participation frequency*
``p_r = participated_r / rounds_r`` (counted since the rank last joined,
current round included), and the weighted sum is divided by the full world
size::

    direction = (1 / world) * sum_{r in arrived} row_r / p_r

Taking expectations over which ranks arrive, ``E[direction] =
(1/world) * sum_r p_r * E[row_r] / p_r`` — the full-world mean, exactly the
same two-level trick the paper's MLMC estimator uses to cancel compression
bias.  On a full round every ``p_r`` is 1 and the server falls back to the
bitwise-identical plain ``mean``, so a zero-fault elastic run stays
bit-for-bit equal to the loopback transport.
"""

from __future__ import annotations

import dataclasses
import random

import numpy as np

from repro.obs import trace as obs

ACTIVE = "active"
LEFT = "left"


@dataclasses.dataclass(frozen=True)
class BackoffSchedule:
    """Seeded capped exponential backoff for worker reconnects.

    ``delays()`` is deterministic per seed: attempt ``i`` waits
    ``min(cap_s, base_s * 2**i)`` scaled by a jitter factor drawn from
    ``random.Random(seed)`` in ``[1 - jitter, 1]``.  `TcpStarTransport.rejoin`
    makes one immediate attempt, then one per delay (``retries + 1`` total).
    """

    base_s: float = 0.05
    cap_s: float = 2.0
    retries: int = 8
    seed: int = 0
    jitter: float = 0.5

    def delays(self) -> list[float]:
        rnd = random.Random(self.seed)
        out = []
        for i in range(self.retries):
            full = min(self.cap_s, self.base_s * (2.0 ** i))
            out.append(full * (1.0 - self.jitter * rnd.random()))
        return out


def participation_weights(counts, seen) -> np.ndarray:
    """Horvitz-Thompson weights ``seen / counts`` (inverse empirical
    participation frequency) as float64.  ``counts[i]`` is how many of the
    ``seen[i]`` deadline rounds rank i's uplink arrived in; every entry must
    have participated at least once (callers weight *arrived* rows only)."""
    counts = np.asarray(counts, np.float64)
    seen = np.asarray(seen, np.float64)
    if counts.shape != seen.shape:
        raise ValueError(f"counts shape {counts.shape} != seen {seen.shape}")
    if np.any(counts <= 0):
        raise ValueError("every weighted rank needs >= 1 participation "
                         f"(counts {counts.tolist()})")
    return seen / counts


@dataclasses.dataclass
class Member:
    """One rank's lifecycle entry on the server."""

    rank: int
    state: str = ACTIVE
    joined_round: int = -1       # round in flight when the rank (re)joined
    left_round: int | None = None
    left_reason: str = ""
    rejoins: int = 0
    #: deadline rounds this rank was active for / arrived in, counted since
    #: its last (re)join — the empirical participation frequency behind the
    #: Horvitz-Thompson weights resets when a rank re-enters the world
    rounds_seen: int = 0
    rounds_participated: int = 0


class Membership:
    """Rank 0's view of who is in the world (elastic tcp star).

    Tracks per-rank lifecycle, stores the last STATE row each rank shipped
    (served back on REJOIN so the worker restores its `CommState`
    bitwise), counts participation for the Horvitz-Thompson deadline
    weights, and books ``wire/member_join`` / ``wire/member_leave``
    telemetry on every transition."""

    def __init__(self, world: int):
        self.world = world
        self.members = {r: Member(r, joined_round=-1) for r in range(world)}
        self.rows: dict[int, bytes] = {}
        #: deadline rounds recorded so far (`record_round` calls)
        self.rounds = 0

    # ---- lifecycle ---------------------------------------------------------

    def is_active(self, rank: int) -> bool:
        return self.members[rank].state == ACTIVE

    def active_ranks(self) -> list[int]:
        return [r for r, m in sorted(self.members.items())
                if m.state == ACTIVE]

    def mark_left(self, rank: int, round_: int, reason: str = "") -> None:
        m = self.members[rank]
        if m.state == LEFT:
            return
        m.state = LEFT
        m.left_round = round_
        m.left_reason = reason
        tel = obs.active()
        if tel.enabled:
            tel.instant("wire/member_leave", cat="wire", pid=0,
                        rank=rank, round=round_, reason=reason)

    def mark_joined(self, rank: int, round_: int, *,
                    rejoin: bool = False) -> None:
        m = self.members[rank]
        m.state = ACTIVE
        m.joined_round = round_
        m.left_round = None
        m.left_reason = ""
        if rejoin:
            m.rejoins += 1
            # the participation frequency describes the CURRENT incarnation
            m.rounds_seen = 0
            m.rounds_participated = 0
        tel = obs.active()
        if tel.enabled:
            tel.instant("wire/member_join", cat="wire", pid=0,
                        rank=rank, round=round_, rejoin=bool(rejoin),
                        rejoins=m.rejoins)

    # ---- deadline accounting ----------------------------------------------

    def record_round(self, participants, round_: int) -> None:
        """Book one served deadline round: every active rank (except one
        that joined DURING this round and could not have sent yet) saw it;
        ``participants`` arrived in time."""
        self.rounds += 1
        arrived = set(participants)
        for r, m in self.members.items():
            if m.state != ACTIVE or m.joined_round >= round_ >= 0:
                continue
            m.rounds_seen += 1
            if r in arrived:
                m.rounds_participated += 1

    def weights(self, participants) -> np.ndarray:
        """Horvitz-Thompson weight per *arrived* rank (see module doc)."""
        return participation_weights(
            [self.members[r].rounds_participated for r in participants],
            [self.members[r].rounds_seen for r in participants])

    # ---- CommState rows ----------------------------------------------------

    def store_row(self, rank: int, row: bytes) -> None:
        self.rows[rank] = row

    def row(self, rank: int) -> bytes | None:
        return self.rows.get(rank)

    # ---- introspection -----------------------------------------------------

    def summary(self) -> dict:
        """Picklable snapshot for tests / benches / logs."""
        return {
            "world": self.world,
            "rounds": self.rounds,
            "members": {
                r: {
                    "state": m.state,
                    "joined_round": m.joined_round,
                    "left_round": m.left_round,
                    "left_reason": m.left_reason,
                    "rejoins": m.rejoins,
                    "rounds_seen": m.rounds_seen,
                    "rounds_participated": m.rounds_participated,
                }
                for r, m in sorted(self.members.items())
            },
        }
