"""Jit-native device wire — fixed-shape packed packets for the mesh
collectives.

The `repro.comm.codec` wire is byte-exact but host-side: `Packet` holds
numpy buffers and Python `bytes`, so the fast jitted mesh path
(`repro.sharding.collectives`) could not use it and kept moving *unpacked*
f32/int32 operands.  This module closes that gap with a `DevicePacket`: a
pytree of two fixed-shape jnp arrays —

* ``words`` — a static-width uint32 buffer holding the bit-packed payload
  (packed with the Pallas kernels of :mod:`repro.kernels.pack`), and
* ``lane``  — the small f32 header lane of :mod:`repro.comm.packets`
  (scale / p_l / level as exact f32 values).

Everything here traces under ``jax.jit`` + ``shard_map`` with **no host
callbacks**: a packet can be all-gathered across the data axes as a plain
array operand, so compression, bit-packing and communication all run
on-device.  `repro.sharding.collectives` uses the codecs below for its
``wire="device"`` branch, and `device_aggregator` exposes the same path for
the in-process M-worker simulation (``make_aggregator(..., wire="device")``).

Exactness contract (mirrors `repro.comm.codec`): ``decode(packet)`` replays
the abstract compressor's float32 operations in the same order, so the
device direction equals the abstract direction elementwise.  Two documented
deviations:

* `mlmc_topk` ships residual values in **bf16** (2 per word) by default —
  identical to the abstract collective under the ``bf16_wire`` perf flag,
  and within bf16 rounding of the f32 abstract path otherwise
  (``value_bits=32`` restores exact f32 parity at 2x the value words);
* `mlmc_fixed` always ships the level-l ternary plane, i.e. it is the
  24-bit-grid-unbiased variant of the mesh collective (constraint (b) in
  `repro.sharding.collectives`): a top-level draw (probability ~2^-24)
  decodes to the grid value rather than the exact dense residual.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.comm.packets import (
    HEADER_LANE_LEN,
    LANE_LEVEL,
    LANE_PROB,
    LANE_SCALE,
    header_lane,
)
from repro.core import bits as bitcost
from repro.core.bitwise import (_BELOW_ONE, _fixed_scale,
                                FixedPointMultilevel,
                                FloatingPointMultilevel)
from repro.core.topk import STopKMultilevel
from repro.core.types import categorical
from repro.kernels.pack import pack_planes, packed_words, unpack_planes

Array = jax.Array

_EPS = 1e-30


class DevicePacket(NamedTuple):
    """One fixed-shape on-device packet: packed payload + f32 header lane.

    A NamedTuple so it is a pytree: vmap-able per worker, gather-able per
    mesh axis, and passable through jit boundaries unchanged."""

    words: Array   # uint32 (codec.words_len,)
    lane: Array    # float32 (HEADER_LANE_LEN,)


def _index_bits(d: int) -> int:
    return math.ceil(math.log2(max(d, 2)))


# ---------------------------------------------------------------------------
# value-stream packing (bf16 2-per-word / raw f32 words)
# ---------------------------------------------------------------------------


def pack_values(vals: Array, value_bits: int) -> Array:
    """f32 values -> uint32 words: bf16 bit patterns packed 2/word when
    ``value_bits == 16``, raw f32 bit patterns (1/word) when 32."""
    if value_bits == 16:
        u16 = jax.lax.bitcast_convert_type(vals.astype(jnp.bfloat16),
                                           jnp.uint16)
        return pack_planes(u16.astype(jnp.uint32), 16)
    return jax.lax.bitcast_convert_type(vals.astype(jnp.float32), jnp.uint32)


def unpack_values(words: Array, count: int, value_bits: int) -> Array:
    """Inverse of :func:`pack_values`; always returns f32."""
    if value_bits == 16:
        codes = unpack_planes(words, 16, count).astype(jnp.uint16)
        return jax.lax.bitcast_convert_type(codes, jnp.bfloat16) \
                  .astype(jnp.float32)
    return jax.lax.bitcast_convert_type(words[:count], jnp.float32)


def value_words(count: int, value_bits: int) -> int:
    return packed_words(count, 16) if value_bits == 16 else count


# ---------------------------------------------------------------------------
# stream helpers shared with the collectives
# ---------------------------------------------------------------------------


def topk_segment_words(d: int, s: int, value_bits: int = 16) -> int:
    """Static word count of one packed (s-)Top-k residual segment."""
    return packed_words(s, _index_bits(d)) + value_words(s, value_bits)


def rank_segment(v: Array, idx0: Array, s: int, *, pad_idx: int,
                 sorted_keys: Array | None = None) -> tuple[Array, Array]:
    """Sort-free MLMC (s-)Top-k level segment (`kernels.select` pipeline).

    Returns ``(seg_idx, valid)``: the original positions of magnitude
    ranks ``[idx0*s, (idx0+1)*s)`` in rank order (entries beyond ``d``
    filled with ``pad_idx``), and the in-range mask.  Bitwise identical to
    slicing a global ``argsort(-|v|)``, but extracted from the exact
    threshold band with one masked s-sized ``lax.top_k``; pass
    ``sorted_keys`` (from `select.sort_magnitude_keys`) to share the key
    sort with a ladder computation.  ``pad_idx`` is ``d - 1`` on the
    device wire (the packed index must stay in range, values are masked
    instead) and ``d`` on the compiled byte pipeline (an out-of-range
    sentinel that sorts after every real position)."""
    from repro.kernels import select

    seg_idx, valid = select.rank_band_indices(v, idx0 * s, s,
                                              sorted_keys=sorted_keys)
    return jnp.where(valid, seg_idx, pad_idx), valid


def pack_topk_segment(seg_vals: Array, seg_idx: Array, d: int,
                      value_bits: int = 16) -> Array:
    """One MLMC Top-k segment (s values + s positions) as packed words:
    indices at ceil(log2 d) bits (split planes above 16), values per
    :func:`pack_values`."""
    iwords = pack_planes(seg_idx.astype(jnp.uint32), _index_bits(d))
    return jnp.concatenate([iwords, pack_values(seg_vals, value_bits)])


def unpack_topk_segment(words: Array, d: int, s: int,
                        value_bits: int = 16) -> tuple[Array, Array]:
    """Inverse of :func:`pack_topk_segment` -> (vals f32, idx int32)."""
    n_idx = packed_words(s, _index_bits(d))
    idx = unpack_planes(words[:n_idx], _index_bits(d), s).astype(jnp.int32)
    vals = unpack_values(words[n_idx:], s, value_bits)
    return vals, idx


def ternary_words(d: int) -> int:
    """Static word count of one packed {-1,0,+1} plane (2 bits/entry)."""
    return packed_words(d, 2)


def pack_ternary(tern: Array) -> Array:
    """{-1,0,+1} plane -> 2-bit codes (tern+1) packed 16/word."""
    codes = (tern.astype(jnp.int32) + 1).astype(jnp.uint32)
    return pack_planes(codes, 2)


def unpack_ternary(words: Array, d: int) -> Array:
    """Inverse of :func:`pack_ternary` -> int32 in {-1,0,+1}."""
    return unpack_planes(words, 2, d).astype(jnp.int32) - 1


# ---------------------------------------------------------------------------
# device codecs
# ---------------------------------------------------------------------------


class DeviceCodec:
    """One compressor family as a jit-traceable fixed-shape wire format.

    ``encode(v, rng) -> (DevicePacket, estimate)`` replays the abstract
    compressor (same jnp ops, same PRNG draws) and additionally emits the
    packed packet; ``decode(packet)`` reconstructs the dense estimate from
    the packet alone.  ``operand_bits()`` is the static per-packet collective
    operand size (what actually crosses the mesh), reconciled against the
    `repro.core.bits` ledger by ``reconcile_bounds()``."""

    name: str
    dim: int
    words_len: int

    def encode(self, v: Array, rng) -> tuple[DevicePacket, Array]:
        raise NotImplementedError

    def decode(self, packet: DevicePacket) -> Array:
        raise NotImplementedError

    def operand_bits(self) -> float:
        """Bits per packet on the wire: packed words + the header lane."""
        return 32.0 * (self.words_len + HEADER_LANE_LEN)

    def nominal_bits(self) -> float:
        """The `repro.core.bits` ledger value for one worker message."""
        raise NotImplementedError

    def reconcile_bounds(self) -> tuple[float, float]:
        """Static (lo, hi) range `operand_bits()` must fall in around
        `nominal_bits()`; the derivation is documented per codec."""
        n = self.nominal_bits()
        return n, n

    # shared bound pieces ----------------------------------------------------

    def _lane_slack(self, ledger_header_bits: float) -> float:
        """Lane bits beyond what the ledger already charges for headers."""
        return 32.0 * HEADER_LANE_LEN - ledger_header_bits

    def _padding(self, count: int, width: int) -> float:
        return 32.0 * packed_words(count, width) - float(count * width)


class DenseDeviceCodec(DeviceCodec):
    """Alg. 1 baseline: raw f32 bit patterns (completeness / parity oracle)."""

    def __init__(self, dim: int):
        self.name, self.dim = "dense", dim
        self.words_len = dim

    def encode(self, v, rng):
        del rng
        est = jnp.asarray(v, jnp.float32)
        words = jax.lax.bitcast_convert_type(est, jnp.uint32)
        return DevicePacket(words, header_lane()), est

    def decode(self, packet):
        return jax.lax.bitcast_convert_type(packet.words, jnp.float32)

    def nominal_bits(self):
        return bitcost.dense_bits(self.dim)

    def reconcile_bounds(self):
        n = self.nominal_bits()
        return n, n + self._lane_slack(0.0)


class QSGDDeviceCodec(DeviceCodec):
    """Norm in the lane + per-entry (level-index | sign) codes."""

    def __init__(self, dim: int, s: int):
        self.name, self.dim, self.s = "qsgd", dim, s
        self.width = 1 + math.ceil(math.log2(s + 1))
        self.words_len = packed_words(dim, self.width)

    def encode(self, v, rng):
        if rng is None:
            raise ValueError("QSGD is stochastic; an rng key is required")
        v = jnp.asarray(v, jnp.float32)
        # replay QSGD.compress exactly (same ops, same key -> same rounding)
        norm = jnp.maximum(jnp.linalg.norm(v), _EPS)
        x = jnp.abs(v) / norm * self.s
        lo = jnp.floor(x)
        up = jax.random.bernoulli(rng, x - lo)
        xi = lo + up.astype(v.dtype)
        est = norm * jnp.sign(v) * xi / self.s
        codes = (xi.astype(jnp.uint32) << 1) | (v < 0).astype(jnp.uint32)
        return DevicePacket(pack_planes(codes, self.width),
                            header_lane(scale=norm)), est

    def decode(self, packet):
        codes = unpack_planes(packet.words, self.width, self.dim)
        xi = (codes >> 1).astype(jnp.float32)
        sgn = jnp.where((codes & 1) != 0, jnp.float32(-1.0), jnp.float32(1.0))
        norm = packet.lane[LANE_SCALE]
        # same association order as `norm * sign(v) * xi / s`
        return norm * sgn * xi / self.s

    def nominal_bits(self):
        return bitcost.qsgd_bits(self.dim, self.s)

    def reconcile_bounds(self):
        n = self.nominal_bits()   # d*width + 32 (norm header)
        return n, n + self._padding(self.dim, self.width) + \
            self._lane_slack(32.0)


class RTNDeviceCodec(DeviceCodec):
    """Clip scale in the lane + l-bit grid codes (plain biased RTN)."""

    def __init__(self, dim: int, level: int):
        self.name, self.dim, self.level = "rtn", dim, level
        self.words_len = packed_words(dim, level)

    def _grid(self, c):
        l = jnp.asarray(self.level, jnp.float32)
        cells = 2.0 ** l - 1.0
        delta = 2.0 * c / jnp.maximum(cells, 1.0)
        return delta, jnp.floor(cells / 2.0)

    def encode(self, v, rng):
        del rng
        v = jnp.asarray(v, jnp.float32)
        c = jnp.maximum(jnp.max(jnp.abs(v)), _EPS)
        delta, m = self._grid(c)
        q = jnp.clip(jnp.round(v / jnp.maximum(delta, _EPS)), -m, m)
        est = delta * q
        codes = (q + m).astype(jnp.uint32)
        return DevicePacket(pack_planes(codes, self.level),
                            header_lane(scale=c)), est

    def decode(self, packet):
        delta, m = self._grid(packet.lane[LANE_SCALE])
        codes = unpack_planes(packet.words, self.level, self.dim)
        return delta * (codes.astype(jnp.float32) - m)

    def nominal_bits(self):
        return bitcost.rtn_bits(self.dim, self.level)

    def reconcile_bounds(self):
        n = self.nominal_bits()   # level*d + 32
        return n, n + self._padding(self.dim, self.level) + \
            self._lane_slack(32.0)


class SignSGDDeviceCodec(DeviceCodec):
    """Mean-|v| scale in the lane + one {-1,0,+1} plane.

    The fixed-shape wire has no room for the byte-codec's variable-length
    exact-zero side stream, so signs ship at 2 bits/entry (the zero mask
    rides inline) — documented as +d over the d + 32 ledger."""

    def __init__(self, dim: int):
        self.name, self.dim = "signsgd", dim
        self.words_len = ternary_words(dim)

    def encode(self, v, rng):
        del rng
        v = jnp.asarray(v, jnp.float32)
        scale = jnp.mean(jnp.abs(v))
        sgn = jnp.sign(v)
        est = sgn * scale
        return DevicePacket(pack_ternary(sgn), header_lane(scale=scale)), est

    def decode(self, packet):
        sgn = unpack_ternary(packet.words, self.dim).astype(jnp.float32)
        return sgn * packet.lane[LANE_SCALE]

    def nominal_bits(self):
        return bitcost.dense_bits(self.dim, 1) + 32   # d + 32

    def reconcile_bounds(self):
        n = self.nominal_bits()
        # documented: +1 bit/entry (inline zero mask) + padding + lane slack
        return n, n + self.dim + self._padding(self.dim, 2) + \
            self._lane_slack(32.0)


class MLMCFixedDeviceCodec(DeviceCodec):
    """§3.1 fixed point: shared-scale ternary level-l plane at 2 bits/entry.

    Replays the Lemma-3.3 level draw of the abstract aggregator (same
    `categorical` call, same key) and ships ``sign(v) * b_l``; the estimate
    is the bit-plane residual / p_l at EVERY level, i.e. unbiased w.r.t. the
    ``num_levels``-bit fixed-point grid value of the gradient (the same
    constraint (b) the int8-psum mesh collective documents)."""

    def __init__(self, dim: int, num_levels: int = 24):
        self.name, self.dim = "mlmc_fixed", dim
        self.compressor = FixedPointMultilevel(num_bits=num_levels)
        self.words_len = ternary_words(dim)

    def encode(self, v, rng):
        v = jnp.asarray(v, jnp.float32)
        probs = self.compressor.static_probs()
        probs = probs / jnp.sum(probs)
        idx = categorical(rng, probs)
        level = idx + 1
        p_l = jnp.maximum(probs[idx], 1e-30)
        scale = _fixed_scale(v)
        x = jnp.minimum(jnp.abs(v) / scale, _BELOW_ONE)
        bit = jnp.mod(jnp.floor(jnp.ldexp(x, level)), 2.0)
        # same op order as FixedPointMultilevel.residual's plane branch
        plane = scale * jnp.sign(v) * jnp.ldexp(bit, -level)
        est = plane / p_l
        pkt = DevicePacket(pack_ternary(jnp.sign(v) * bit),
                           header_lane(scale=scale, prob=p_l, level=level))
        return pkt, est

    def decode(self, packet):
        tern = unpack_ternary(packet.words, self.dim).astype(jnp.float32)
        scale = packet.lane[LANE_SCALE]
        level = packet.lane[LANE_LEVEL].astype(jnp.int32)
        plane = (scale * tern) * jnp.ldexp(jnp.float32(1.0), -level)
        return plane / packet.lane[LANE_PROB]

    def nominal_bits(self):
        return bitcost.fixed_point_mlmc_bits(self.dim,
                                             self.compressor.num_levels)

    def reconcile_bounds(self):
        n = self.nominal_bits()   # 2d + 64 + ceil(log2 L)
        hdr = 64.0 + math.ceil(math.log2(self.compressor.num_levels))
        return n - hdr, n + self._padding(self.dim, 2) + \
            self._lane_slack(hdr)


class MLMCFloatDeviceCodec(DeviceCodec):
    """App. B floating point on the device wire: a packed sign+exponent
    plane (11 bits/entry via `kernels.pack.pack_planes`) plus the 1-bit
    level-l mantissa plane; scale-free (the exponent rides per entry).

    Like `MLMCFixedDeviceCodec`, the fixed-shape wire cannot ship the
    byte codec's variable-length dense top-level fallback, so the plane is
    transmitted at EVERY level: the estimator is unbiased w.r.t. the
    ``num_bits``-bit mantissa grid value of the gradient (the same
    grid-unbiased deviation the fixed-point device codec documents)."""

    _EXP_OFFSET = 150   # frexp exponents of f32 (incl. denormals) + 150 >= 0

    def __init__(self, dim: int, num_bits: int = 23):
        self.name, self.dim = "mlmc_float", dim
        self.compressor = FloatingPointMultilevel(num_bits=num_bits)
        self.words_len = packed_words(dim, 11) + packed_words(dim, 1)

    def encode(self, v, rng):
        v = jnp.asarray(v, jnp.float32)
        probs = self.compressor.static_probs()
        probs = probs / jnp.sum(probs)
        idx = categorical(rng, probs)
        level = idx + 1
        p_l = jnp.maximum(probs[idx], 1e-30)
        m, e = self.compressor._mantissa_exp(v)
        sgn = jnp.sign(m)
        ecode = (e + self._EXP_OFFSET).astype(jnp.uint32)
        base_codes = (ecode << 2) | (sgn + 1.0).astype(jnp.uint32)
        bit = jnp.mod(jnp.floor(jnp.ldexp(jnp.abs(m), level + 1)), 2.0)
        # same op order the decode replays (and the byte codec uses)
        base = jnp.ldexp(sgn * jnp.float32(0.5), e)
        plane = jnp.ldexp(sgn * bit, e - (level + 1))
        est = base + plane / p_l
        words = jnp.concatenate([pack_planes(base_codes, 11),
                                 pack_planes(bit.astype(jnp.uint32), 1)])
        pkt = DevicePacket(words, header_lane(prob=p_l, level=level))
        return pkt, est

    def decode(self, packet):
        n_base = packed_words(self.dim, 11)
        base_codes = unpack_planes(packet.words[:n_base], 11, self.dim)
        sgn = (base_codes & 3).astype(jnp.float32) - jnp.float32(1.0)
        e = (base_codes >> 2).astype(jnp.int32) - self._EXP_OFFSET
        bit = unpack_planes(packet.words[n_base:], 1,
                            self.dim).astype(jnp.float32)
        level = packet.lane[LANE_LEVEL].astype(jnp.int32)
        base = jnp.ldexp(sgn * jnp.float32(0.5), e)
        plane = jnp.ldexp(sgn * bit, e - (level + 1))
        return base + plane / packet.lane[LANE_PROB]

    def nominal_bits(self):
        return bitcost.floating_point_mlmc_bits(self.dim,
                                                self.compressor.num_levels)

    def reconcile_bounds(self):
        n = self.nominal_bits()   # 13d + log2(L): fp64 ledger (11-bit exp)
        hdr = 32.0 + math.ceil(math.log2(self.compressor.num_levels))
        # f32 exponents need 9 bits, not the ledger's 11 -> measured sits
        # ~2 bits/entry below nominal, plus word padding on both planes
        return n - 2.0 * self.dim - hdr, \
            n + self._padding(self.dim, 11) + self._padding(self.dim, 1) + \
            self._lane_slack(hdr)


class MLMCTopKDeviceCodec(DeviceCodec):
    """(s-)Top-k MLMC: one magnitude-rank segment, positions packed at
    ceil(log2 d) bits and values in bf16 (2/word) by default.

    Level/p_l are drawn through the real `mlmc_estimate` (identical
    categorical call), so against the abstract aggregator the decoded
    direction is exact for ``value_bits=32`` and within bf16 rounding of
    the residual values for the default ``value_bits=16``."""

    def __init__(self, dim: int, s: int, *, adaptive: bool = True,
                 value_bits: int = 16, name: str = "mlmc_topk"):
        if value_bits not in (16, 32):
            raise ValueError(f"value_bits must be 16 or 32, got {value_bits}")
        self.name, self.dim, self.adaptive = name, dim, adaptive
        self.value_bits = value_bits
        self.compressor = STopKMultilevel(d=dim, s=min(s, dim))
        self.words_len = topk_segment_words(dim, self.compressor.s, value_bits)

    def encode(self, v, rng, probs=None):
        """``probs`` (the stateful `mlmc_adaptive_*` family) carries the
        CommState-derived Lemma-3.4 distribution; its sampled ``p_l``/level
        ride the f32 header lane, so the stateful device path stays
        jit-native with no host callbacks."""
        from repro.core.mlmc import mlmc_estimate

        v = jnp.asarray(v, jnp.float32)
        d, s = self.dim, self.compressor.s
        est = mlmc_estimate(self.compressor, v, rng, probs=probs,
                            adaptive=self.adaptive and probs is None)
        idx0 = est.level - 1
        seg_idx, valid = rank_segment(v, idx0, s, pad_idx=d - 1)
        seg_vals = jnp.where(valid, v[seg_idx] / est.prob, 0.0)
        pkt = DevicePacket(
            pack_topk_segment(seg_vals, seg_idx, d, self.value_bits),
            header_lane(prob=est.prob, level=est.level))
        return pkt, est.estimate

    def decode(self, packet):
        vals, idx = unpack_topk_segment(packet.words, self.dim,
                                        self.compressor.s, self.value_bits)
        return jnp.zeros((self.dim,), jnp.float32).at[idx].add(vals)

    def nominal_bits(self):
        return bitcost.topk_mlmc_bits(self.dim, self.compressor.s,
                                      value_bits=self.value_bits)

    def reconcile_bounds(self):
        s = self.compressor.s
        n = self.nominal_bits()   # s*(vb + ceil(log2 d)) + ceil(log2 L)
        hdr = math.ceil(math.log2(max(self.compressor.num_levels, 2)))
        pad = self._padding(s, _index_bits(self.dim)) + \
            (32.0 * value_words(s, self.value_bits) - s * self.value_bits)
        return n - hdr, n + pad + self._lane_slack(float(hdr))


class EF21TopKDeviceCodec(DeviceCodec):
    """The EF21 / EF21-SGDM Top-k innovation as a fixed-shape packet.

    Top-k of an innovation always carries EXACTLY k entries, so — unlike
    the general sparse baselines — it has a static wire form: k positions
    at ceil(log2 d) bits (split planes) + k raw f32 values.  Values ship as
    full f32 bit patterns, so the device EF21 direction is BITWISE equal to
    the abstract one (no bf16 deviation: error feedback compounds state
    step over step, and an exact mirror keeps every substrate identical)."""

    def __init__(self, dim: int, k: int, name: str = "ef21"):
        self.name, self.dim = name, dim
        self.k = max(1, min(k, dim))
        self.words_len = topk_segment_words(dim, self.k, 32)

    def encode(self, u, rng):
        del rng   # Top-k is deterministic
        from repro.kernels import select

        u = jnp.asarray(u, jnp.float32)
        # stable top_k == the first k rows of the old global argsort
        idx = select.topk_indices(u, self.k)
        vals = u[idx]
        est = jnp.zeros((self.dim,), jnp.float32).at[idx].set(vals)
        words = pack_topk_segment(vals, idx, self.dim, 32)
        return DevicePacket(words, header_lane()), est

    def decode(self, packet):
        vals, idx = unpack_topk_segment(packet.words, self.dim, self.k, 32)
        return jnp.zeros((self.dim,), jnp.float32).at[idx].set(vals)

    def nominal_bits(self):
        return bitcost.ef21_bits(self.dim, self.k)

    def reconcile_bounds(self):
        n = self.nominal_bits()   # k*(32 + ceil(log2 d)), headerless ledger
        return n, n + self._padding(self.k, _index_bits(self.dim)) + \
            self._lane_slack(0.0)


# ---------------------------------------------------------------------------
# registry + jit-native aggregator
# ---------------------------------------------------------------------------


def make_device_codec(name: str, dim: int, *, k_fraction: float = 0.01,
                      s: int = 1, rtn_level: int = 4, qsgd_levels: int = 2,
                      fixed_levels: int = 24,
                      topk_value_bits: int = 16) -> DeviceCodec:
    """Build the device-wire codec matching ``make_aggregator(name, dim)``.

    Only families with a fixed-shape packed form are registered; the
    variable-length codecs (topk/randk/natural/mlmc_rtn) stay on the host
    byte wire (``wire="packed"``)."""
    k = max(1, int(round(k_fraction * dim)))
    if name == "dense":
        return DenseDeviceCodec(dim)
    if name == "qsgd":
        return QSGDDeviceCodec(dim, qsgd_levels)
    if name == "rtn":
        return RTNDeviceCodec(dim, rtn_level)
    if name == "signsgd":
        return SignSGDDeviceCodec(dim)
    if name == "mlmc_fixed":
        return MLMCFixedDeviceCodec(dim, fixed_levels)
    if name == "mlmc_float":
        return MLMCFloatDeviceCodec(dim)
    if name in ("mlmc_topk", "mlmc_topk_static", "mlmc_stopk",
                "mlmc_adaptive_topk", "mlmc_adaptive_stopk"):
        from repro.core.aggregators import mlmc_topk_segment

        # the stateful EMA family (mlmc_adaptive_*) receives its Lemma-3.4
        # probabilities explicitly at encode time (adaptive=False)
        return MLMCTopKDeviceCodec(
            dim, mlmc_topk_segment(name, k, s),
            adaptive=name in ("mlmc_topk", "mlmc_stopk"),
            value_bits=topk_value_bits, name=name)
    if name in ("ef21", "ef21_sgdm"):
        return EF21TopKDeviceCodec(dim, k, name=name)
    raise ValueError(f"no device-wire codec for {name!r}")


DEVICE_WIRE_METHODS = ("dense", "qsgd", "rtn", "signsgd", "mlmc_fixed",
                       "mlmc_float", "mlmc_topk", "mlmc_topk_static",
                       "mlmc_stopk", "mlmc_adaptive_topk",
                       "mlmc_adaptive_stopk", "ef21", "ef21_sgdm")


def device_aggregator(name: str, dim: int, *, momentum_beta: float = 0.1,
                      ema_rho: float = 0.25, downlink: str | None = None,
                      downlink_alpha: float = 0.5, **codec_kw):
    """The ``wire="device"`` branch of `make_aggregator`: every worker
    gradient is encoded to a fixed-shape `DevicePacket`, "shipped" as plain
    arrays, decoded, and averaged — all inside one jit, with bits accounted
    from the static packet operand size.

    Stateful families thread a real `CommState` through the jit exactly
    like the abstract substrate: EF21/EF21-SGDM keep their worker mirrors,
    and `mlmc_adaptive_*` keeps the EMA residual-norm ladders, whose
    sampled p_l/level ride the packets' f32 header lane (no host
    callbacks anywhere).

    ``downlink`` names a second device codec for the server→worker
    direction: the mean is encoded as ``direction - shift`` against a
    DIANA-style server shift in ``CommState.shift`` (updated by
    ``shift += downlink_alpha * delta_hat``), entirely inside the jit;
    bits then include the downlink packet's operand size.  Supported for
    the stateless families only — EF21's direction IS the server mirror g
    (already an innovation stream), and the adaptive family's ladder rows
    stay whole-gradient."""
    from repro.core.adaptive import ladder_ema_update, probs_from_ladder
    from repro.core.aggregators import AggregateOut, Aggregator
    from repro.core.error_feedback import ef21_targets
    from repro.core.types import adaptive_comm_state, ef21_comm_state, \
        empty_comm_state

    codec = make_device_codec(name, dim, **codec_kw)
    if downlink is not None and name in ("ef21", "ef21_sgdm",
                                         "mlmc_adaptive_topk",
                                         "mlmc_adaptive_stopk"):
        raise ValueError(f"downlink compression does not compose with the "
                         f"stateful device family {name!r}")

    if name in ("ef21", "ef21_sgdm"):
        beta = 1.0 if name == "ef21" else momentum_beta

        def init(num_workers, d):
            return ef21_comm_state(num_workers, d)

        def agg(worker_grads, rng, state):
            del rng   # Top-k innovations are deterministic
            m = worker_grads.shape[0]
            if state is None:
                state = init(m, dim)
            target, mom = ef21_targets(state, worker_grads, beta)
            innovations = target - state.g_workers

            def one(u):
                packet, _ = codec.encode(u, None)
                return codec.decode(packet)

            c = jax.vmap(one)(innovations)
            g_workers = state.g_workers + c
            g_server = state.g_server + jnp.mean(c, axis=0)
            bits = jnp.asarray(m * codec.operand_bits(), jnp.float32)
            new_state = state._replace(step=state.step + 1,
                                       g_workers=g_workers,
                                       g_server=g_server, momentum=mom)
            return AggregateOut(g_server, new_state, bits)

        return Aggregator(name, agg, init=init, stateful=True)

    if name in ("mlmc_adaptive_topk", "mlmc_adaptive_stopk"):
        comp = codec.compressor

        def init(num_workers, d):
            del d
            return adaptive_comm_state(num_workers, comp.num_levels)

        def agg(worker_grads, rng, state):
            m = worker_grads.shape[0]
            if state is None:
                state = init(m, dim)
            keys = jax.random.split(rng, m)
            deltas = jax.vmap(comp.residual_norms)(worker_grads)
            ema = ladder_ema_update(state.ladder_ema, deltas, ema_rho,
                                    state.step)
            probs = probs_from_ladder(ema)

            def one(v, key, p):
                packet, _ = codec.encode(v, key, probs=p)
                return codec.decode(packet)

            decoded = jax.vmap(one)(worker_grads, keys, probs)
            bits = jnp.asarray(m * codec.operand_bits(), jnp.float32)
            new_state = state._replace(step=state.step + 1, ladder_ema=ema)
            return AggregateOut(jnp.mean(decoded, axis=0), new_state, bits)

        return Aggregator(name, agg, init=init, stateful=True)

    down_codec = (make_device_codec(downlink, dim, **codec_kw)
                  if downlink is not None else None)

    def init(num_workers, d):
        del num_workers
        return empty_comm_state(d if down_codec is not None else 0)

    def agg(worker_grads, rng, state):
        if state is None:
            state = init(worker_grads.shape[0], dim)
        m = worker_grads.shape[0]
        keys = jax.random.split(rng, m)

        def one(v, key):
            packet, _ = codec.encode(v, key)
            return codec.decode(packet)

        decoded = jax.vmap(one)(worker_grads, keys)
        bits = jnp.asarray(m * codec.operand_bits(), jnp.float32)
        direction = jnp.mean(decoded, axis=0)
        if down_codec is None:
            return AggregateOut(direction, state, bits)
        # DIANA-shift downlink: encode the mean's innovation vs the
        # mirrored server shift; every rank decodes the same packet, so
        # the same fold keeps all mirrors identical (_DOWNLINK_FOLD
        # matches the packed wire's key derivation).
        from repro.comm.aggregate import _DOWNLINK_FOLD

        dkey = jax.random.fold_in(rng, _DOWNLINK_FOLD)
        dpkt, _ = down_codec.encode(direction - state.shift, dkey)
        delta_hat = down_codec.decode(dpkt)
        new_state = state._replace(
            step=state.step + 1,
            shift=state.shift + downlink_alpha * delta_hat)
        bits = bits + jnp.asarray(down_codec.operand_bits(), jnp.float32)
        return AggregateOut(state.shift + delta_hat, new_state, bits)

    if down_codec is not None:
        return Aggregator(name, agg, init=init, stateful=True)
    return Aggregator(name, agg)


def policy_device_aggregator(resolved, dim: int, *,
                             downlink: str | None = None,
                             downlink_alpha: float = 0.5, **codec_kw):
    """The device-wire realization of a multi-segment `ResolvedPolicy`
    (`repro.comm.policy`): per segment, every worker's slice round-trips
    through the segment's fixed-shape `DeviceCodec` under the draw key
    ``fold_in(worker_key, segment_index)`` — the identical derivation the
    abstract, packed, and tcp substrates replay — and the per-segment
    means concatenate into the direction, all inside one jit.  Bits are
    the static per-segment operand sizes.  Stateless segment families
    only (the stateful state rows are whole-gradient)."""
    from repro.comm.policy import segment_codec_kw
    from repro.core.aggregators import (AggregateOut, Aggregator,
                                        STATEFUL_AGGREGATORS)
    from repro.core.types import empty_comm_state

    if resolved.dim != dim:
        raise ValueError(f"policy resolved for dim {resolved.dim}, "
                         f"aggregator dim {dim}")
    bad = sorted({s.codec for s in resolved.segments
                  if s.codec in STATEFUL_AGGREGATORS})
    if bad:
        raise ValueError(
            f"policy segments name stateful families {bad}: their "
            "per-worker CommState rows are defined over the whole flat "
            "gradient — use a one-segment policy for those")
    codecs = [make_device_codec(seg.codec, seg.size,
                                **segment_codec_kw(codec_kw, seg, dim))
              for seg in resolved.segments]
    down_codec = (make_device_codec(downlink, dim, **codec_kw)
                  if downlink is not None else None)

    def init(num_workers, d):
        del num_workers
        return empty_comm_state(d if down_codec is not None else 0)

    def agg(worker_grads, rng, state):
        if state is None:
            state = init(worker_grads.shape[0], dim)
        m = worker_grads.shape[0]
        keys = jax.random.split(rng, m)
        parts = []
        for b, seg in enumerate(resolved.segments):

            def one(v, key, _codec=codecs[b], _b=b):
                packet, _ = _codec.encode(v, jax.random.fold_in(key, _b))
                return _codec.decode(packet)

            decoded = jax.vmap(one)(worker_grads[:, seg.start:seg.stop],
                                    keys)
            parts.append(jnp.mean(decoded, axis=0))
        direction = jnp.concatenate(parts)
        bits = jnp.asarray(m * sum(c.operand_bits() for c in codecs),
                           jnp.float32)
        if down_codec is None:
            return AggregateOut(direction, state, bits)
        from repro.comm.aggregate import _DOWNLINK_FOLD

        dkey = jax.random.fold_in(rng, _DOWNLINK_FOLD)
        dpkt, _ = down_codec.encode(direction - state.shift, dkey)
        delta_hat = down_codec.decode(dpkt)
        new_state = state._replace(
            step=state.step + 1,
            shift=state.shift + downlink_alpha * delta_hat)
        bits = bits + jnp.asarray(down_codec.operand_bits(), jnp.float32)
        return AggregateOut(state.shift + delta_hat, new_state, bits)

    if down_codec is not None:
        return Aggregator("policy", agg, init=init, stateful=True)
    return Aggregator("policy", agg)
