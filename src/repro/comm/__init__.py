"""repro.comm — the wire: codecs, packets, bit-pack kernels, transports.

Turns every compressor family of `repro.core` into a byte-exact wire format
(`make_codec`), ships the resulting packets through pluggable transports
with an alpha-beta cost model (`make_transport`), and exposes the
packed-wire aggregation path behind ``make_aggregator(..., wire="packed")``.
"""

from repro.comm.aggregate import PackedAggregate, PackedEF21, packed_aggregator
from repro.comm.codec import EncodeResult, WireCodec, make_codec
from repro.comm.packets import Header, Packet, Stream
from repro.kernels.pack import pack_bits, unpack_bits
from repro.comm.topology import (
    CostModel,
    make_topology,
    simulated_step_time,
)
from repro.comm.transport import (
    LoopbackTransport,
    SimulatedTransport,
    Transport,
    TransportStats,
    make_transport,
)

__all__ = [
    "CostModel", "EncodeResult", "Header", "LoopbackTransport",
    "PackedAggregate", "PackedEF21", "Packet", "SimulatedTransport",
    "Stream", "Transport", "TransportStats", "WireCodec", "make_codec",
    "make_topology", "make_transport", "pack_bits", "packed_aggregator",
    "simulated_step_time", "unpack_bits",
]
