"""repro.comm — the wire: codecs, packets, bit-pack kernels, transports.

Turns every compressor family of `repro.core` into a byte-exact wire format
(`make_codec`), ships the resulting packets through pluggable transports
with an alpha-beta cost model (`make_transport`), and exposes the
packed-wire aggregation path behind ``make_aggregator(..., wire="packed")``.

`device_wire` is the jit-native sibling: fixed-shape `DevicePacket`s
(static uint32 word buffers + a small f32 header lane, no Python bytes)
that the mesh collectives gather directly — ``wire="device"`` in
`make_aggregator` and `repro.sharding.collectives.compressed_allreduce`.

`multihost` is the real-network realization: a TCP socket star
(``make_transport("tcp", rank=..., world=..., coordinator=...)``) that
moves the packet bytes between OS processes and *measures* per-link bytes
and wall-clock instead of simulating them.

`policy` layers per-leaf heterogeneity over all of the above: a
`CodecPolicy` maps pytree leaf paths/sizes to codec names, resolving to
named (segment, codec) streams that every substrate — abstract, packed,
device, tcp — encodes independently (``make_aggregator(...,
policy=...)``); a one-segment policy degenerates bit-for-bit to the
single-codec path.
"""

from repro.comm.aggregate import (
    MultihostPackedAdaptive,
    MultihostPackedAggregate,
    MultihostPackedEF21,
    PackedAdaptiveMLMC,
    PackedAggregate,
    PackedEF21,
    packed_aggregator,
)
from repro.comm.codec import EncodeResult, WireCodec, make_codec
from repro.comm.compiled import (
    CompiledCodec,
    compile_codec,
    make_compiled_codec,
)
from repro.comm.elastic import BackoffSchedule, Membership, \
    participation_weights
from repro.comm.faultinject import Fault, FaultSchedule, FaultyTransport, \
    InjectedFault
from repro.comm.multihost import ServerShutdown, TcpStarTransport, \
    TransportError, is_multihost_transport
from repro.comm.device_wire import (
    DEVICE_WIRE_METHODS,
    DeviceCodec,
    DevicePacket,
    device_aggregator,
    make_device_codec,
)
from repro.comm.packets import Header, Packet, Stream, header_lane
from repro.comm.policy import (
    POLICY_PRESETS,
    CodecPolicy,
    PolicyRule,
    ResolvedPolicy,
    Segment,
)
from repro.kernels.pack import pack_bits, pack_planes, unpack_bits, \
    unpack_planes
from repro.comm.topology import (
    CostModel,
    make_topology,
    simulated_step_time,
)
from repro.comm.transport import (
    LoopbackTransport,
    SimulatedTransport,
    Transport,
    TransportStats,
    make_transport,
)

__all__ = [
    "BackoffSchedule",
    "CodecPolicy", "CompiledCodec", "CostModel", "DEVICE_WIRE_METHODS",
    "DeviceCodec", "DevicePacket", "EncodeResult", "Fault",
    "FaultSchedule", "FaultyTransport", "Header", "InjectedFault",
    "LoopbackTransport", "Membership", "MultihostPackedAdaptive",
    "MultihostPackedAggregate", "MultihostPackedEF21",
    "POLICY_PRESETS", "PackedAdaptiveMLMC",
    "PackedAggregate", "PackedEF21", "Packet", "PolicyRule",
    "ResolvedPolicy", "Segment", "ServerShutdown",
    "SimulatedTransport", "Stream", "TcpStarTransport", "Transport",
    "TransportError",
    "TransportStats", "WireCodec", "compile_codec", "device_aggregator",
    "header_lane", "is_multihost_transport", "make_codec",
    "make_compiled_codec", "make_device_codec",
    "make_topology", "make_transport", "pack_bits", "pack_planes",
    "packed_aggregator", "participation_weights", "simulated_step_time",
    "unpack_bits", "unpack_planes",
]
