"""Packed-wire aggregation: the paper's M-worker step with *real bytes*.

`make_aggregator(name, dim, wire="packed")` routes here: each worker's
gradient is encoded to a `Packet`, serialized, shipped through a `Transport`,
deserialized and decoded server-side, and the direction is the mean of the
*decoded* estimates.  Because every codec round-trip is value-exact, the
direction matches the abstract (`wire="abstract"`) path — now with measured
wire bits instead of asserted ones in `AggregateOut.bits`.

This path is host-side Python (serialization is inherently un-jittable);
it exists for verification and for honest telemetry, while the jitted
abstract path remains the fast default.  `PackedEF21` does the same for the
stateful EF21/EF21-SGDM baselines, whose wire message is the compressed
*innovation* per worker.

`MultihostPackedAggregate` is the distributed realization: when the
transport is a real multi-host one (`repro.comm.multihost`), each OS
process encodes only its own rank's gradient, rank 0 decodes + means, and
the direction comes back over the wire — same math, same bytes, real
sockets.
"""

from __future__ import annotations

import struct

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.codec import WireCodec, make_codec
from repro.comm.multihost import is_multihost_transport
from repro.comm.packets import Packet
from repro.comm.transport import LoopbackTransport, Transport

Array = jax.Array


class PackedAggregate:
    """Stateless packed-wire aggregator: encode -> ship -> decode -> mean."""

    def __init__(self, codec: WireCodec, transport: Transport | None = None):
        self.codec = codec
        self.transport = transport or LoopbackTransport()

    def __call__(self, worker_grads: Array, rng, state=None):
        from repro.core.aggregators import AggregateOut

        del state
        m = worker_grads.shape[0]
        keys = jax.random.split(rng, m)
        encoded = [self.codec.encode(worker_grads[i], keys[i])
                   for i in range(m)]
        raw = [e.packet.to_bytes() for e in encoded]
        delivered = self.transport.exchange(raw)
        packets = [Packet.from_bytes(b) for b in delivered]
        decoded = [self.codec.decode(p) for p in packets]
        direction = jnp.mean(jnp.stack([jnp.asarray(d) for d in decoded]),
                             axis=0)
        bits = float(sum(self.codec.measured_bits(p) for p in packets))
        # account the dense model-update broadcast on the downlink
        self.transport.broadcast(4 * self.codec.dim, m)
        return AggregateOut(direction, None, jnp.asarray(bits, jnp.float32))


# ---------------------------------------------------------------------------
# multihost: rank-local encode, server-side decode, direction re-broadcast
# ---------------------------------------------------------------------------

#: the DIRECTION frame payload: magic, dim, measured bits, then dim f32
_DIR_MAGIC = b"RCD1"
_DIR_FMT = "<4sId"
_DIR_HEADER_BYTES = struct.calcsize(_DIR_FMT)    # 16


def pack_direction(direction: np.ndarray, bits: float) -> bytes:
    v = np.ascontiguousarray(np.asarray(direction), np.float32)
    return struct.pack(_DIR_FMT, _DIR_MAGIC, v.size, float(bits)) + v.tobytes()


def unpack_direction(raw: bytes, dim: int) -> tuple[np.ndarray, float]:
    if len(raw) < _DIR_HEADER_BYTES:
        raise ValueError(f"truncated direction blob: {len(raw)} bytes")
    magic, d, bits = struct.unpack_from(_DIR_FMT, raw, 0)
    if magic != _DIR_MAGIC:
        raise ValueError(f"bad direction magic {magic!r}")
    if d != dim or len(raw) != _DIR_HEADER_BYTES + 4 * d:
        raise ValueError(f"direction blob for dim {d} / {len(raw)} bytes, "
                         f"expected dim {dim}")
    return np.frombuffer(raw, np.float32, d, _DIR_HEADER_BYTES), bits


class MultihostPackedAggregate:
    """The socket-star realization of `PackedAggregate`: each OS process
    encodes ITS OWN worker's gradient, ships it to rank 0, and rank 0
    decodes all ``world`` packets, means them, and re-broadcasts the
    direction — no rank ever loops over the others' gradients.

    Bit-for-bit parity with the in-process loop: every rank draws the same
    per-step ``jax.random.split(rng, world)`` key fan and uses its own row,
    the server means the decoded estimates in rank order (exactly the
    worker order of `PackedAggregate`), and the direction crosses the wire
    as raw f32 bit patterns."""

    def __init__(self, codec: WireCodec, transport):
        if not is_multihost_transport(transport):
            raise ValueError("MultihostPackedAggregate needs a multihost "
                             "transport (rank/world + broadcast_payload)")
        self.codec = codec
        self.transport = transport

    def __call__(self, worker_grads: Array, rng, state=None):
        from repro.core.aggregators import AggregateOut

        del state
        tp = self.transport
        if worker_grads.shape[0] != 1:
            raise ValueError(
                "a multihost rank hosts exactly one worker; got a stack of "
                f"{worker_grads.shape[0]} gradients (slice the global batch "
                "to this rank's shard)")
        keys = jax.random.split(rng, tp.world)
        enc = self.codec.encode(worker_grads[0], keys[tp.rank])
        delivered = tp.exchange([enc.packet.to_bytes()])
        if tp.rank == 0:
            packets = [Packet.from_bytes(b) for b in delivered]
            decoded = [self.codec.decode(p) for p in packets]
            direction = jnp.mean(jnp.stack([jnp.asarray(d) for d in decoded]),
                                 axis=0)
            bits = float(sum(self.codec.measured_bits(p) for p in packets))
            tp.broadcast_payload(pack_direction(np.asarray(direction), bits))
        else:
            vec, bits = unpack_direction(tp.broadcast_payload(None),
                                         self.codec.dim)
            direction = jnp.asarray(vec)
        return AggregateOut(direction, None, jnp.asarray(bits, jnp.float32))


class PackedEF21:
    """EF21 / EF21-SGDM with the per-worker innovation on a packed wire.

    Replays `repro.core.error_feedback.EF21.step` with an
    encode -> ship -> decode round trip on each worker's compressed
    innovation ``c_i = C(target_i - g_i)``."""

    def __init__(self, codec: WireCodec, beta: float,
                 transport: Transport | None = None):
        self.codec = codec
        self.beta = beta
        self.transport = transport or LoopbackTransport()

    def init(self, num_workers: int, dim: int):
        from repro.core.error_feedback import EF21State

        z = jnp.zeros((num_workers, dim), jnp.float32)
        return EF21State(g_workers=z, g_server=jnp.zeros((dim,), jnp.float32),
                         momentum=z)

    def __call__(self, worker_grads: Array, rng, state):
        from repro.core.aggregators import AggregateOut
        from repro.core.error_feedback import EF21State

        del rng  # the EF21 compressors (Top-k / sign) are deterministic
        if state is None:
            raise ValueError("PackedEF21 needs an initialized EF21State")
        if self.beta < 1.0:
            mom = (1.0 - self.beta) * state.momentum + self.beta * worker_grads
            target = mom
        else:
            mom = state.momentum
            target = worker_grads

        innovations = target - state.g_workers
        m = innovations.shape[0]
        encoded = [self.codec.encode(innovations[i], None) for i in range(m)]
        delivered = self.transport.exchange(
            [e.packet.to_bytes() for e in encoded])
        packets = [Packet.from_bytes(b) for b in delivered]
        c = jnp.stack([jnp.asarray(self.codec.decode(p)) for p in packets])
        g_workers = state.g_workers + c
        g_server = state.g_server + jnp.mean(c, axis=0)
        bits = float(sum(self.codec.measured_bits(p) for p in packets))
        self.transport.broadcast(4 * self.codec.dim, m)
        return AggregateOut(g_server,
                            EF21State(g_workers, g_server, mom),
                            jnp.asarray(bits, jnp.float32))


def packed_aggregator(name: str, dim: int, *, transport: Transport | None = None,
                      k_fraction: float = 0.01, s: int = 1,
                      rtn_level: int = 4, qsgd_levels: int = 2,
                      momentum_beta: float = 0.1, fixed_levels: int = 24):
    """Build the packed-wire `Aggregator` for a registry name (the
    ``wire="packed"`` branch of `repro.core.aggregators.make_aggregator`)."""
    from repro.core.aggregators import Aggregator

    codec = make_codec(name, dim, k_fraction=k_fraction, s=s,
                       rtn_level=rtn_level, qsgd_levels=qsgd_levels,
                       fixed_levels=fixed_levels)
    multihost = is_multihost_transport(transport)
    if name in ("ef21", "ef21_sgdm", "signsgd_ef"):
        if multihost:
            raise NotImplementedError(
                f"{name!r} keeps per-worker innovation state on the server; "
                "the multihost wire does not replicate it yet — use a "
                "stateless method over tcp")
        beta = momentum_beta if name == "ef21_sgdm" else 1.0
        ef = PackedEF21(codec, beta, transport)
        return Aggregator(name, ef, init=ef.init)
    if multihost:
        return Aggregator(name, MultihostPackedAggregate(codec, transport))
    return Aggregator(name, PackedAggregate(codec, transport))
