"""Packed-wire aggregation: the paper's M-worker step with *real bytes*.

`make_aggregator(name, dim, wire="packed")` routes here: each worker's
gradient is encoded to a `Packet`, serialized, shipped through a `Transport`,
deserialized and decoded server-side, and the direction is the mean of the
*decoded* estimates.  Because every codec round-trip is value-exact, the
direction matches the abstract (`wire="abstract"`) path — now with measured
wire bits instead of asserted ones in `AggregateOut.bits`.

Only the byte framing itself lives on the host: by default every
aggregator here runs the COMPILED codec pipeline (`repro.comm.compiled`)
— one vmapped jitted encode for all M workers, one `device_get` of the
packed uint32 buffers, and one fused decode+mean — so the packed wire
tracks the fully-jitted path's step time while still shipping and
measuring real bytes (`BENCH_wire.json`; ``compiled=False`` restores the
original eager codecs for A-B runs).  Every aggregator implements the
unified stateful protocol (`init -> CommState`, packets in, CommState
out): `PackedEF21` threads the EF21/EF21-SGDM worker mirrors, and
`PackedAdaptiveMLMC` threads the EMA residual-norm ladders of the stateful
Alg.-3 family (`mlmc_adaptive_*`), shipping each worker's Lemma-3.4
probability explicitly in the packet header.

The `Multihost*` classes are the distributed realizations: when the
transport is a real multi-host one (`repro.comm.multihost`), each OS
process encodes only its own rank's message, rank 0 decodes + aggregates,
and the direction comes back over the wire — same math, same bytes, real
sockets.  `MultihostPackedEF21` closes the ROADMAP follow-up: rank 0
replicates every worker's decoded innovation into its ``g_workers`` mirror,
so stateful EF21 trains over tcp bit-for-bit equal to loopback.
"""

from __future__ import annotations

import struct
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.codec import WireCodec, make_codec
from repro.comm.multihost import is_multihost_transport
from repro.comm.packets import Packet
from repro.comm.transport import LoopbackTransport, Transport
from repro.obs import trace as obs
from repro.core.adaptive import ladder_ema_update, probs_from_ladder
from repro.core.error_feedback import ef21_targets
from repro.core.types import (
    CommState,
    adaptive_comm_state,
    ef21_comm_state,
    empty_comm_state,
)

Array = jax.Array


def _is_compiled(codec) -> bool:
    """The compiled pipeline (`repro.comm.compiled`) exposes batched
    encode + fused decode; a bare eager `WireCodec` does not."""
    return hasattr(codec, "encode_batch")


def _encode_round(codec, worker_grads: Array, keys,
                  probs=None) -> list[Packet]:
    """All M workers -> byte packets: ONE vmapped jitted encode + one
    device_get on the compiled pipeline, the legacy per-worker eager loop
    otherwise (same bytes either way — the byte-equality battery)."""
    if _is_compiled(codec):
        return codec.encode_batch(worker_grads, keys, probs=probs)
    m = worker_grads.shape[0]
    if probs is not None:
        return [codec.encode(worker_grads[i], keys[i],
                             probs=probs[i]).packet for i in range(m)]
    return [codec.encode(worker_grads[i], keys[i]).packet for i in range(m)]


def _decode_mean(codec, packets: list[Packet]) -> Array:
    """Decoded-estimate mean: one fused unpack+scatter+mean jit over the
    persistent staging buffers on the compiled pipeline."""
    if _is_compiled(codec):
        return codec.decode_mean(packets)
    return jnp.mean(jnp.stack([jnp.asarray(codec.decode(p))
                               for p in packets]), axis=0)


def _codec_impl(codec) -> str:
    return "compiled" if _is_compiled(codec) else "eager"


def _record_mlmc_draws(tel, codec, packets) -> None:
    """MLMC estimator telemetry: every shipped packet's sampled (level,
    p_l) straight from the wire header — the empirical side of the
    level-draw histogram.  The theoretical ladder is recorded once per
    method from ``compressor.static_probs()`` (the static Lemma-3.3
    distribution; the adaptive family overwrites it with its actual
    per-step Lemma-3.4 rows at each sampling point)."""
    name = getattr(codec, "name", "")
    if not name.startswith("mlmc"):
        return
    for p in packets:
        tel.mlmc.record_draw(name, p.header.level, p.header.prob)
    comp = getattr(codec, "compressor", None)
    if comp is not None and tel.mlmc.expected_probs(name) is None:
        tel.mlmc.record_expected(name, np.asarray(comp.static_probs()))


def _record_bias_proxy(tel, name: str, direction, worker_grads) -> None:
    """Running empirical-mean-vs-dense-gradient bias proxy (sampled: the
    dense mean costs one jnp reduction, so the disabled path never pays
    it and the enabled path pays it every ``sample_every`` rounds)."""
    if tel.should_sample(f"bias:{name}"):
        dense = np.asarray(jnp.mean(worker_grads, axis=0))
        tel.mlmc.record_bias(name, np.asarray(direction), dense)


class PackedAggregate:
    """Stateless packed-wire aggregator: encode -> ship -> decode -> mean.
    The CommState passes through unchanged.

    With a compiled codec (`make_compiled_codec`, the default wire), the
    per-worker Python loop is gone: one vmapped jitted encode emits every
    worker's packed buffers, one `device_get` lands them on the host for
    byte framing, and one fused jit decodes + means all M packets."""

    def __init__(self, codec: WireCodec, transport: Transport | None = None,
                 downlink: "Downlink | None" = None):
        self.codec = codec
        self.transport = transport or LoopbackTransport()
        self.downlink = downlink

    def init(self, num_workers: int, dim: int) -> CommState:
        del num_workers
        return empty_comm_state(dim if self.downlink is not None else 0)

    def __call__(self, worker_grads: Array, rng, state: CommState | None = None):
        from repro.core.aggregators import AggregateOut

        if state is None:
            state = self.init(*worker_grads.shape)
        tel = obs.active()
        name, impl = getattr(self.codec, "name", "?"), _codec_impl(self.codec)
        m = worker_grads.shape[0]
        keys = jax.random.split(rng, m)
        t0 = time.perf_counter() if tel.enabled else 0.0
        packets_out = _encode_round(self.codec, worker_grads, keys)
        if tel.enabled:
            tel.trace.complete("comm/encode", t0, codec=name, impl=impl)
            tel.observe("codec_encode_s", time.perf_counter() - t0,
                        codec=name, impl=impl)
            t0 = time.perf_counter()
        payloads = [p.to_bytes() for p in packets_out]
        if tel.enabled:
            tel.trace.complete("comm/serialize", t0, codec=name,
                               nbytes=sum(len(b) for b in payloads))
        delivered = self.transport.exchange(payloads)
        packets = [Packet.from_bytes(b) for b in delivered]
        t0 = time.perf_counter() if tel.enabled else 0.0
        direction = _decode_mean(self.codec, packets)
        if tel.enabled:
            tel.trace.complete("comm/decode_mean", t0, codec=name, impl=impl)
            tel.observe("codec_decode_s", time.perf_counter() - t0,
                        codec=name, impl=impl)
            _record_mlmc_draws(tel, self.codec, packets)
            _record_bias_proxy(tel, name, direction, worker_grads)
        bits = float(sum(self.codec.measured_bits(p) for p in packets))
        if self.downlink is not None:
            direction, state, dbits = _downlink_round(
                self.downlink, direction, state, rng, self.transport, m)
            state = state._replace(step=state.step + 1)
            bits += dbits
        else:
            # account the dense model-update broadcast on the downlink
            self.transport.broadcast(4 * self.codec.dim, m)
        return AggregateOut(direction, state, jnp.asarray(bits, jnp.float32))


class PackedAdaptiveMLMC:
    """The stateful Alg.-3 family on the byte wire: the per-worker EMA
    residual-norm ladders live in ``CommState.ladder_ema``, the updated EMA
    yields each worker's Lemma-3.4 distribution, and the sampled ``p_l``
    ships explicitly in the packet header (FLAG_EXPLICIT_PROB) so the
    server decodes from the packet alone.

    Per-worker math is computed row-by-row (not vmapped) so a multihost
    rank — which only ever sees its own row — replays the exact same f32
    ops and stays bitwise comparable (see `MultihostPackedAdaptive`)."""

    def __init__(self, codec, compressor, rho: float,
                 transport: Transport | None = None,
                 downlink: "Downlink | None" = None):
        self.codec = codec
        self.compressor = compressor
        self.rho = rho
        self.transport = transport or LoopbackTransport()
        self.downlink = downlink

    def init(self, num_workers: int, dim: int) -> CommState:
        return adaptive_comm_state(
            num_workers, self.compressor.num_levels,
            dim if self.downlink is not None else 0)

    def __call__(self, worker_grads: Array, rng, state: CommState | None = None):
        from repro.core.aggregators import AggregateOut

        m = worker_grads.shape[0]
        if state is None:
            state = self.init(m, worker_grads.shape[1])
        tel = obs.active()
        name, impl = getattr(self.codec, "name", "?"), _codec_impl(self.codec)
        keys = jax.random.split(rng, m)
        t0 = time.perf_counter() if tel.enabled else 0.0
        deltas = jnp.stack([self.compressor.residual_norms(worker_grads[i])
                            for i in range(m)])
        ema = ladder_ema_update(state.ladder_ema, deltas, self.rho, state.step)
        probs = probs_from_ladder(ema)
        packets_out = _encode_round(self.codec, worker_grads, keys,
                                    probs=probs)
        if tel.enabled:
            tel.trace.complete("comm/encode", t0, codec=name, impl=impl)
            tel.observe("codec_encode_s", time.perf_counter() - t0,
                        codec=name, impl=impl)
            # the EMA residual-norm ladder trajectory, every worker's row
            if tel.should_sample(f"ladder:{name}"):
                step = int(state.step)
                ema_np, probs_np = np.asarray(ema), np.asarray(probs)
                for i in range(m):
                    tel.mlmc.record_ladder(name, i, ema_np[i], step=step)
                # the adaptive family's ACTUAL Lemma-3.4 ladder (mean over
                # workers) is the expected distribution its draws follow
                tel.mlmc.record_expected(name, probs_np.mean(axis=0))
        delivered = self.transport.exchange(
            [p.to_bytes() for p in packets_out])
        packets = [Packet.from_bytes(b) for b in delivered]
        t0 = time.perf_counter() if tel.enabled else 0.0
        direction = _decode_mean(self.codec, packets)
        if tel.enabled:
            tel.trace.complete("comm/decode_mean", t0, codec=name, impl=impl)
            tel.observe("codec_decode_s", time.perf_counter() - t0,
                        codec=name, impl=impl)
            for p in packets:
                tel.mlmc.record_draw(name, p.header.level, p.header.prob)
            _record_bias_proxy(tel, name, direction, worker_grads)
        bits = float(sum(self.codec.measured_bits(p) for p in packets))
        new_state = state._replace(step=state.step + 1, ladder_ema=ema)
        if self.downlink is not None:
            direction, new_state, dbits = _downlink_round(
                self.downlink, direction, new_state, rng, self.transport, m)
            bits += dbits
        else:
            self.transport.broadcast(4 * self.codec.dim, m)
        return AggregateOut(direction, new_state,
                            jnp.asarray(bits, jnp.float32))


# ---------------------------------------------------------------------------
# multihost: rank-local encode, server-side decode, direction re-broadcast
# ---------------------------------------------------------------------------

#: the DIRECTION frame payload: magic, dim, measured bits, then dim f32
_DIR_MAGIC = b"RCD1"
_DIR_FMT = "<4sId"
_DIR_HEADER_BYTES = struct.calcsize(_DIR_FMT)    # 16


def pack_direction(direction: np.ndarray, bits: float) -> bytes:
    v = np.ascontiguousarray(np.asarray(direction), np.float32)
    return struct.pack(_DIR_FMT, _DIR_MAGIC, v.size, float(bits)) + v.tobytes()


def unpack_direction(raw: bytes, dim: int) -> tuple[np.ndarray, float]:
    if len(raw) < _DIR_HEADER_BYTES:
        raise ValueError(f"truncated direction blob: {len(raw)} bytes")
    magic, d, bits = struct.unpack_from(_DIR_FMT, raw, 0)
    if magic != _DIR_MAGIC:
        raise ValueError(f"bad direction magic {magic!r}")
    if d != dim or len(raw) != _DIR_HEADER_BYTES + 4 * d:
        raise ValueError(f"direction blob for dim {d} / {len(raw)} bytes, "
                         f"expected dim {dim}")
    return np.frombuffer(raw, np.float32, d, _DIR_HEADER_BYTES), bits


#: the DIRECTION_ENC frame payload (compressed downlink): same 16-byte
#: header shape as RCD1 (magic, dim, uplink bits) followed by ONE
#: serialized `Packet` the downlink codec decodes against the receiving
#: rank's DIANA shift.  Append-only next to RCD1: receivers dispatch on
#: the magic, old readers reject RCD2 loudly (bad magic), never silently.
_DIRE_MAGIC = b"RCD2"
_DIRE_FMT = "<4sId"
_DIRE_HEADER_BYTES = struct.calcsize(_DIRE_FMT)    # 16


def pack_encoded_direction(pkt_bytes: bytes, dim: int, bits: float) -> bytes:
    """Serialize one compressed-downlink blob: RCD2 header + packet bytes.
    ``bits`` carries the round's measured UPLINK bits (every rank returns
    the same `AggregateOut.bits`, so the server ships its sum along)."""
    return struct.pack(_DIRE_FMT, _DIRE_MAGIC, dim, float(bits)) + pkt_bytes


def unpack_encoded_direction(raw: bytes, dim: int) -> tuple[bytes, float]:
    """Inverse of `pack_encoded_direction` -> (packet bytes, uplink bits)."""
    if len(raw) < _DIRE_HEADER_BYTES:
        raise ValueError(f"truncated encoded-direction blob: {len(raw)} bytes")
    magic, d, bits = struct.unpack_from(_DIRE_FMT, raw, 0)
    if magic != _DIRE_MAGIC:
        raise ValueError(f"bad encoded-direction magic {magic!r}")
    if d != dim:
        raise ValueError(f"encoded direction for dim {d}, expected {dim}")
    return raw[_DIRE_HEADER_BYTES:], bits


#: the elastic DIRECTION payload (deadline partial aggregation): RCD1's
#: fields plus the world size and a per-rank participation mask byte each,
#: so every rank books identical ``wire/partial_round`` telemetry from the
#: same broadcast bytes.  Append-only next to RCD1/RCD2: receivers
#: dispatch on the magic, old readers reject RCD3 loudly, never silently.
_DIRP_MAGIC = b"RCD3"
_DIRP_FMT = "<4sIdB"
_DIRP_HEADER_BYTES = struct.calcsize(_DIRP_FMT)    # 17


def pack_partial_direction(direction: np.ndarray, bits: float,
                           mask: np.ndarray) -> bytes:
    """Serialize one elastic-round direction: RCD3 header, one
    participation byte per rank (1 = that rank's uplink made the
    deadline), then the dim f32 direction."""
    v = np.ascontiguousarray(np.asarray(direction), np.float32)
    m = np.ascontiguousarray(np.asarray(mask, bool))
    return (struct.pack(_DIRP_FMT, _DIRP_MAGIC, v.size, float(bits), m.size)
            + m.astype(np.uint8).tobytes() + v.tobytes())


def unpack_partial_direction(raw: bytes,
                             dim: int) -> tuple[np.ndarray, float,
                                                np.ndarray]:
    """Inverse of `pack_partial_direction` -> (direction, bits, mask)."""
    if len(raw) < _DIRP_HEADER_BYTES:
        raise ValueError(f"truncated partial-direction blob: "
                         f"{len(raw)} bytes")
    magic, d, bits, world = struct.unpack_from(_DIRP_FMT, raw, 0)
    if magic != _DIRP_MAGIC:
        raise ValueError(f"bad partial-direction magic {magic!r}")
    if d != dim or len(raw) != _DIRP_HEADER_BYTES + world + 4 * d:
        raise ValueError(f"partial-direction blob for dim {d} / world "
                         f"{world} / {len(raw)} bytes, expected dim {dim}")
    mask = np.frombuffer(raw, np.uint8, world,
                         _DIRP_HEADER_BYTES).astype(bool)
    vec = np.frombuffer(raw, np.float32, d, _DIRP_HEADER_BYTES + world)
    return vec, bits, mask


def _record_partial_round(tel, tp, mask: np.ndarray) -> None:
    """Book one elastic round's participation on THIS rank: an instant
    event when the round was partial, plus the participation-count
    histogram every round (server and workers read the same broadcast
    mask, so the books agree bitwise across the world)."""
    if not tel.enabled:
        return
    n = int(np.count_nonzero(mask))
    round_ = int(getattr(tp, "last_round", -1))
    if n < mask.size:
        tel.instant("wire/partial_round", cat="wire", pid=tp.rank,
                    round=round_, n_arrived=n, world=int(mask.size),
                    participants=[int(r) for r in np.flatnonzero(mask)])
    tel.observe("wire_participation", float(n), transport="tcp")


#: fold_in tag deriving the downlink draw key from the per-step rng —
#: distinct from the uplink's `jax.random.split` fan so the downlink
#: codec's stochasticity (if any) never correlates with a worker's draw
_DOWNLINK_FOLD = 0x0D0C


class Downlink:
    """DIANA-style compressed server->worker direction (the Shifted
    Compression Framework / "On Biased Compression" downlink).

    Every rank mirrors a shift vector ``h`` in ``CommState.shift``.  Per
    round the server encodes ``delta = direction - h`` with an ordinary
    wire codec, ships the packet, and EVERY rank (server included) applies

        direction~ = h + decode(packet)
        h         <- h + alpha * decode(packet)

    so params and shifts stay identical across ranks, and the shifted
    compression error contracts as the direction stabilizes.  The
    round-trip math is byte-for-byte the same on the in-process loopback
    aggregators and the tcp star (`Packet` serialization is lossless), so
    compressed-downlink tcp training equals loopback bit-for-bit."""

    def __init__(self, codec, alpha: float = 0.5):
        self.codec = codec
        self.alpha = float(alpha)
        self.dim = codec.dim
        self.name = getattr(codec, "name", "?")

    def key(self, rng):
        """The downlink draw key — identical derivation on every rank."""
        return jax.random.fold_in(rng, _DOWNLINK_FOLD)

    def encode(self, direction: Array, shift: Array, key):
        """Server side: -> (packet, decoded delta_hat, measured bits)."""
        delta = direction - shift
        pkt = self.codec.encode(delta, key).packet
        return pkt, self.decode(pkt), float(self.codec.measured_bits(pkt))

    def decode(self, pkt: Packet) -> Array:
        return jnp.asarray(self.codec.decode(pkt))

    def apply(self, shift: Array, delta_hat: Array) -> tuple[Array, Array]:
        """-> (direction~, new shift) — the same eager f32 ops everywhere."""
        return shift + delta_hat, shift + self.alpha * delta_hat


def _downlink_round(downlink, direction, state, rng, transport, world):
    """One loopback downlink round: encode against the shift, book the
    REAL blob size on the transport, return the decoded direction and the
    state with the advanced shift.  -> (direction~, state, downlink_bits)"""
    tel = obs.active()
    t0 = time.perf_counter() if tel.enabled else 0.0
    pkt, delta_hat, dbits = downlink.encode(direction, state.shift,
                                            downlink.key(rng))
    blob_len = _DIRE_HEADER_BYTES + len(pkt.to_bytes())
    if tel.enabled:
        tel.trace.complete("wire/downlink_encode", t0, codec=downlink.name,
                           nbytes=blob_len)
        tel.observe("downlink_encode_s", time.perf_counter() - t0,
                    codec=downlink.name)
    transport.broadcast(blob_len, world)
    direction, shift = downlink.apply(state.shift, delta_hat)
    return direction, state._replace(shift=shift), dbits


#: STATE frame payload: one rank's client-side CommState rows — the EMA
#: ladder row of `mlmc_adaptive_*` and the momentum row of `ef21_sgdm` —
#: gathered to rank 0 at checkpoint time (`Trainer.sync_comm_state`) so a
#: rank-0 checkpoint captures EVERY rank's client-side state, closing the
#: caveat documented on `MultihostPackedAdaptive` / `MultihostPackedEF21`.
_STATE_MAGIC = b"RCS1"
_STATE_FMT = "<4sBII"    # magic, rank, ladder length, momentum length
_STATE_HEADER_BYTES = struct.calcsize(_STATE_FMT)    # 13

#: RCS2 appends the rank's downlink-shift mirror to the row (append-only
#: next to RCS1: `unpack_comm_state_row` still reads RCS1 rows — a shift
#: of length 0 — so pre-downlink checkpoint gathers stay restorable)
_STATE2_MAGIC = b"RCS2"
_STATE2_FMT = "<4sBIII"  # magic, rank, ladder, momentum, shift lengths
_STATE2_HEADER_BYTES = struct.calcsize(_STATE2_FMT)    # 17


def pack_comm_state_row(state: CommState, rank: int) -> bytes:
    """Serialize rank's client-side rows of a `CommState` (raw f32 bit
    patterns, so a gathered row restores bitwise).  Rows are written in
    the RCS2 format (ladder + momentum + downlink shift)."""
    ladder = np.zeros((0,), np.float32)
    if getattr(state.ladder_ema, "ndim", 0) == 2 \
            and rank < state.ladder_ema.shape[0]:
        ladder = np.ascontiguousarray(np.asarray(state.ladder_ema[rank]),
                                      np.float32)
    momentum = np.zeros((0,), np.float32)
    if getattr(state.momentum, "ndim", 0) == 2 \
            and rank < state.momentum.shape[0]:
        momentum = np.ascontiguousarray(np.asarray(state.momentum[rank]),
                                        np.float32)
    shift = np.ascontiguousarray(np.asarray(state.shift), np.float32) \
        if getattr(state.shift, "ndim", 0) == 1 else np.zeros((0,), np.float32)
    return struct.pack(_STATE2_FMT, _STATE2_MAGIC, rank, ladder.size,
                       momentum.size, shift.size) + ladder.tobytes() + \
        momentum.tobytes() + shift.tobytes()


def unpack_comm_state_row(raw: bytes
                          ) -> tuple[int, np.ndarray, np.ndarray, np.ndarray]:
    """Inverse of `pack_comm_state_row`:
    (rank, ladder_row, momentum_row, shift) — any row may be empty
    (stateless / no-momentum / uplink-only methods).  Reads both the RCS2
    format and legacy RCS1 rows (no shift)."""
    if len(raw) < _STATE_HEADER_BYTES:
        raise ValueError(f"truncated STATE row: {len(raw)} bytes")
    magic = raw[:4]
    if magic == _STATE2_MAGIC:
        if len(raw) < _STATE2_HEADER_BYTES:
            raise ValueError(f"truncated STATE row: {len(raw)} bytes")
        _, rank, nl, nm, ns = struct.unpack_from(_STATE2_FMT, raw, 0)
        header = _STATE2_HEADER_BYTES
    elif magic == _STATE_MAGIC:
        _, rank, nl, nm = struct.unpack_from(_STATE_FMT, raw, 0)
        ns, header = 0, _STATE_HEADER_BYTES
    else:
        raise ValueError(f"bad STATE magic {magic!r}")
    if len(raw) != header + 4 * (nl + nm + ns):
        raise ValueError(f"STATE row of {len(raw)} bytes, expected "
                         f"{header + 4 * (nl + nm + ns)} "
                         f"(ladder {nl}, momentum {nm}, shift {ns})")
    ladder = np.frombuffer(raw, np.float32, nl, header)
    momentum = np.frombuffer(raw, np.float32, nm, header + 4 * nl)
    shift = np.frombuffer(raw, np.float32, ns, header + 4 * (nl + nm))
    return rank, ladder, momentum, shift


def fold_comm_state_rows(state: CommState, rows: list[bytes]) -> CommState:
    """Fold gathered STATE rows into a full `CommState` (rank 0's
    checkpoint view: its own mirrors plus every client's rows).  Shift
    rows are validated against rank 0's own mirror — the shift is
    replicated by construction, so a mismatching row is a desync bug,
    not data to fold."""
    ladder, momentum = state.ladder_ema, state.momentum
    for raw in rows:
        if not raw:
            # an elastic gather leaves None for a rank that was dead at
            # checkpoint time — its row simply keeps the restore default
            continue
        r, lad, mom, shf = unpack_comm_state_row(raw)
        if shf.size:
            own = np.asarray(state.shift)
            if shf.size != own.size:
                raise ValueError(
                    f"STATE shift row from rank {r} ({shf.size} dims) does "
                    f"not fit shift {own.shape}")
            if not np.array_equal(shf, own):
                raise ValueError(
                    f"STATE shift row from rank {r} diverged from the "
                    "server's mirror — downlink shifts must stay replicated")
        if lad.size:
            if getattr(ladder, "ndim", 0) != 2 or \
                    lad.size != ladder.shape[1] or r >= ladder.shape[0]:
                raise ValueError(
                    f"STATE ladder row from rank {r} ({lad.size} levels) "
                    f"does not fit ladder_ema {getattr(ladder, 'shape', ())}")
            ladder = ladder.at[r].set(jnp.asarray(lad))
        if mom.size:
            if getattr(momentum, "ndim", 0) != 2 or \
                    mom.size != momentum.shape[1] or r >= momentum.shape[0]:
                raise ValueError(
                    f"STATE momentum row from rank {r} ({mom.size} dims) "
                    f"does not fit momentum {getattr(momentum, 'shape', ())}")
            momentum = momentum.at[r].set(jnp.asarray(mom))
    return state._replace(ladder_ema=ladder, momentum=momentum)


def _require_multihost(transport, who: str):
    if not is_multihost_transport(transport):
        raise ValueError(f"{who} needs a multihost transport (rank/world + "
                         "broadcast_payload)")


def _require_one_worker(worker_grads: Array):
    if worker_grads.shape[0] != 1:
        raise ValueError(
            "a multihost rank hosts exactly one worker; got a stack of "
            f"{worker_grads.shape[0]} gradients (slice the global batch "
            "to this rank's shard)")


def _check_deadline(transport, deadline_ms, downlink=None):
    """Validate a per-aggregator ``deadline_ms`` against the transport:
    the round-tag protocol lives in the transport, so every rank must have
    been CONSTRUCTED elastic (``deadline_ms=`` on `make_tcp_transport`) —
    a per-aggregator deadline on a non-elastic transport would discard
    untagged frames at random.  The DIANA downlink shift assumes every
    rank applies every delta, so it never composes with deadlines."""
    elastic = bool(getattr(transport, "elastic", False))
    if deadline_ms is not None and not elastic:
        raise ValueError(
            "deadline_ms needs an elastic tcp transport — construct every "
            "rank's transport with deadline_ms=... (make_transport('tcp', "
            "..., deadline_ms=...)) so worker frames carry round tags")
    if elastic and downlink is not None:
        raise ValueError(
            "downlink compression does not compose with elastic deadline "
            "rounds: a rank that missed a round would desync its mirrored "
            "DIANA shift")


class MultihostPackedAggregate:
    """The socket-star realization of `PackedAggregate`: each OS process
    encodes ITS OWN worker's gradient, ships it to rank 0, and rank 0
    decodes all ``world`` packets, means them, and re-broadcasts the
    direction — no rank ever loops over the others' gradients.

    Bit-for-bit parity with the in-process loop: every rank draws the same
    per-step ``jax.random.split(rng, world)`` key fan and uses its own row,
    the server means the decoded estimates in rank order (exactly the
    worker order of `PackedAggregate`), and the direction crosses the wire
    as raw f32 bit patterns."""

    def __init__(self, codec: WireCodec, transport,
                 downlink: "Downlink | None" = None,
                 deadline_ms: float | None = None):
        _require_multihost(transport, "MultihostPackedAggregate")
        _check_deadline(transport, deadline_ms, downlink)
        self.codec = codec
        self.transport = transport
        self.downlink = downlink
        self.deadline_ms = deadline_ms

    def init(self, num_workers: int, dim: int) -> CommState:
        del num_workers
        return empty_comm_state(dim if self.downlink is not None else 0)

    def __call__(self, worker_grads: Array, rng, state: CommState | None = None):
        from repro.core.aggregators import AggregateOut

        if state is None:
            state = self.init(self.transport.world, worker_grads.shape[1])
        tp = self.transport
        _require_one_worker(worker_grads)
        tel = obs.active()
        keys = jax.random.split(rng, tp.world)
        t0 = time.perf_counter() if tel.enabled else 0.0
        enc = self.codec.encode(worker_grads[0], keys[tp.rank])
        if tel.enabled:
            tel.trace.complete("comm/encode", t0, pid=tp.rank,
                               codec=getattr(self.codec, "name", "?"),
                               impl=_codec_impl(self.codec))
            if tp.rank != 0:   # rank 0 records all draws in _serve_round
                _record_mlmc_draws(tel, self.codec, [enc.packet])
        dl = self.downlink
        direction, bits, shift = _serve_round(
            tp, self.codec, enc.packet.to_bytes(), downlink=dl,
            shift=state.shift if dl is not None else None,
            key=dl.key(rng) if dl is not None else None,
            deadline_ms=self.deadline_ms)
        if dl is not None:
            state = state._replace(step=state.step + 1, shift=shift)
        return AggregateOut(direction, state, jnp.asarray(bits, jnp.float32))


def _drain_decoding(tp, codec, local_payload: bytes, deadline_ms=None):
    """Server-side drain with AS-ARRIVAL decode: each uplink is parsed and
    its jitted decode DISPATCHED the moment its frame completes (jax
    dispatch is asynchronous), so unpack/scatter work overlaps the network
    wait for the remaining ranks instead of starting after the full drain.
    Returns (packets, decoded_rows|None) in rank order; an elastic
    deadline round leaves ``None`` in the slots that missed it."""
    world = tp.world
    packets: list = [None] * world
    rows: list = [None] * world
    compiled = hasattr(codec, "decode_device")

    def on_payload(r: int, raw: bytes) -> None:
        pkt = Packet.from_bytes(raw)
        packets[r] = pkt
        if compiled:
            rows[r] = codec.decode_device(pkt)

    if deadline_ms is not None:
        tp.exchange([local_payload], on_payload=on_payload,
                    deadline_ms=deadline_ms)
    else:
        tp.exchange([local_payload], on_payload=on_payload)
    return packets, (rows if compiled else None)


def _drain_containers(tp, plan, local_payload: bytes):
    """Server-side drain of RCBW multi-stream containers (the bucketed /
    policy uplink): each rank's container splits into per-bucket packets
    the moment its frame completes.  Returns ``arrived[b][r]`` — packets
    per bucket in rank order, the layout `WirePlan.decode_mean` expects."""
    from repro.comm.plan import unpack_bucket_payload

    world = tp.world
    per_rank: list = [None] * world

    def on_payload(r: int, raw: bytes) -> None:
        per_rank[r] = [Packet.from_bytes(p)
                       for p in unpack_bucket_payload(raw)]

    tp.exchange([local_payload], on_payload=on_payload)
    for r, parts in enumerate(per_rank):
        if parts is not None and len(parts) != plan.num_buckets:
            raise ValueError(
                f"rank {r} shipped {len(parts)} bucket packets, plan has "
                f"{plan.num_buckets}")
    return [[per_rank[r][b] for r in range(world)]
            for b in range(plan.num_buckets)]


def _serve_round(tp, codec, local_payload: bytes, *, downlink=None,
                 shift=None, key=None, plan=None,
                 deadline_ms=None) -> tuple[Array, float, Array | None]:
    """One multihost aggregation round: ship this rank's payload, decode +
    mean on rank 0, broadcast the direction.  Returns ``(direction, bits,
    new_shift)`` — bits (uplink + downlink where compressed) identical on
    every rank, ``new_shift`` None without a downlink.  EF21 does NOT
    route through here — its server must also fold the decoded innovations
    into the state mirror, so `MultihostPackedEF21` runs its own loop.

    Without a downlink the direction crosses as raw f32 bit patterns
    (`pack_direction`).  With one, rank 0 encodes ``direction - shift``
    through the downlink codec, ships the RCD2 blob on the DIRECTION_ENC
    frame, and every rank — server included — applies the DECODED delta
    against its mirrored shift, so the post-round direction and shift are
    identical (and bitwise equal to the loopback aggregators, which run
    the same round trip in-process).

    On an elastic transport the round may close at the deadline with only
    a subset of uplinks.  Rank 0 then computes the Horvitz-Thompson
    estimate — each arrived row weighted by its rank's inverse empirical
    participation frequency, summed, divided by the FULL world (see
    `repro.comm.elastic`) — and ships it with the participation mask on an
    RCD3 blob so every rank books identical ``wire/partial_round``
    telemetry.  When all weights are exactly 1 (every zero-fault round)
    the plain ``mean`` runs instead, bit-for-bit the loopback path."""
    tel = obs.active()
    elastic = bool(getattr(tp, "elastic", False))
    if elastic and (downlink is not None or plan is not None):
        raise ValueError(
            "elastic deadline rounds compose only with the plain direction "
            "broadcast: the DIANA downlink shift and the bucketed/policy "
            "containers both assume every rank contributes every round")
    if plan is not None:
        dim, name, impl = plan.dim, plan.name, "bucketed"
    else:
        dim = codec.dim
        name, impl = getattr(codec, "name", "?"), _codec_impl(codec)
    if tp.rank == 0:
        t0 = time.perf_counter() if tel.enabled else 0.0
        if plan is not None:
            arrived = _drain_containers(tp, plan, local_payload)
            direction = plan.decode_mean(arrived)
            bits = plan.measured_bits(arrived)
            if tel.enabled:
                tel.trace.complete("comm/serve_round", t0, pid=0, codec=name,
                                   impl=impl, world=tp.world)
                plan.record_segments(tel, arrived)
        else:
            packets, rows = _drain_decoding(tp, codec, local_payload,
                                            deadline_ms=deadline_ms)
            arrived = [r for r in range(tp.world) if packets[r] is not None]
            if rows is not None:
                stacked = jnp.stack([rows[r] for r in arrived])
            else:
                stacked = jnp.stack([jnp.asarray(codec.decode(packets[r]))
                                     for r in arrived])
            weights = None
            if elastic:
                weights = tp.membership.weights(arrived)
            if weights is None or (len(arrived) == tp.world
                                   and np.all(weights == 1.0)):
                direction = jnp.mean(stacked, axis=0)
            else:
                w = jnp.asarray(weights, stacked.dtype)
                direction = jnp.sum(stacked * w[:, None], axis=0) / tp.world
            if tel.enabled:
                tel.trace.complete("comm/serve_round", t0, pid=0, codec=name,
                                   impl=impl, world=tp.world,
                                   n_arrived=len(arrived))
                _record_mlmc_draws(tel, codec,
                                   [p for p in packets if p is not None])
            bits = float(sum(codec.measured_bits(packets[r])
                             for r in arrived))
        if downlink is None:
            if elastic:
                mask = np.zeros(tp.world, bool)
                mask[tp.last_participation] = True
                _record_partial_round(tel, tp, mask)
                tp.broadcast_payload(pack_partial_direction(
                    np.asarray(direction), bits, mask))
            else:
                tp.broadcast_payload(
                    pack_direction(np.asarray(direction), bits))
            return direction, bits, None
        t0 = time.perf_counter() if tel.enabled else 0.0
        pkt, delta_hat, dbits = downlink.encode(direction, shift, key)
        blob = pack_encoded_direction(pkt.to_bytes(), dim, bits)
        if tel.enabled:
            tel.trace.complete("wire/downlink_encode", t0, pid=0,
                               codec=downlink.name, nbytes=len(blob))
            tel.observe("downlink_encode_s", time.perf_counter() - t0,
                        codec=downlink.name)
        tp.broadcast_payload(blob, encoded=True)
        direction, new_shift = downlink.apply(shift, delta_hat)
        return direction, bits + dbits, new_shift
    tp.exchange([local_payload])
    raw = tp.broadcast_payload(None)
    if raw[:4] == _DIRP_MAGIC:
        vec, bits, mask = unpack_partial_direction(raw, dim)
        _record_partial_round(tel, tp, mask)
        return jnp.asarray(vec), bits, None
    if downlink is None:
        vec, bits = unpack_direction(raw, dim)
        return jnp.asarray(vec), bits, None
    pkt_bytes, bits = unpack_encoded_direction(raw, dim)
    pkt = Packet.from_bytes(pkt_bytes)
    delta_hat = downlink.decode(pkt)
    dbits = float(downlink.codec.measured_bits(pkt))
    direction, new_shift = downlink.apply(shift, delta_hat)
    return direction, bits + dbits, new_shift


class MultihostPackedAdaptive:
    """`PackedAdaptiveMLMC` over the socket star: each rank maintains ITS
    OWN row of the EMA ladder (it never sees the other workers' gradients),
    computes its Lemma-3.4 distribution locally, and ships the sampled
    ``p_l`` in the packet header — rank 0 needs no ladder at all to decode.
    Same f32 row ops as the in-process loop, so directions and bytes match
    loopback bit-for-bit.

    Checkpointing: rank 0 cannot reconstruct the other workers' ladders
    from the compressed segments (it only ever sees the sampled ``p_l``),
    so before saving, `Trainer.sync_comm_state` gathers every rank's
    (L,) EMA row over the dedicated STATE frame
    (`TcpStarTransport.gather_state` + `pack_comm_state_row`) and folds
    them into rank 0's ``ladder_ema`` — a rank-0 checkpoint then restores
    a tcp world bitwise (the restore-and-continue spawn test in
    ``tests/test_multihost.py``).  Without the sync a restored world's
    other rows restart at zero; unbiasedness is never affected (Lemma
    3.2), only the EMA warm-start."""

    def __init__(self, codec, compressor, rho: float, transport,
                 downlink: "Downlink | None" = None,
                 deadline_ms: float | None = None):
        _require_multihost(transport, "MultihostPackedAdaptive")
        _check_deadline(transport, deadline_ms, downlink)
        self.codec = codec
        self.compressor = compressor
        self.rho = rho
        self.transport = transport
        self.downlink = downlink
        self.deadline_ms = deadline_ms

    def init(self, num_workers: int, dim: int) -> CommState:
        return adaptive_comm_state(
            num_workers, self.compressor.num_levels,
            dim if self.downlink is not None else 0)

    def __call__(self, worker_grads: Array, rng, state: CommState | None = None):
        from repro.core.aggregators import AggregateOut

        tp = self.transport
        _require_one_worker(worker_grads)
        if state is None:
            state = self.init(tp.world, worker_grads.shape[1])
        tel = obs.active()
        keys = jax.random.split(rng, tp.world)
        r = tp.rank
        t0 = time.perf_counter() if tel.enabled else 0.0
        deltas = self.compressor.residual_norms(worker_grads[0])
        row = ladder_ema_update(state.ladder_ema[r], deltas, self.rho,
                                state.step)
        probs = probs_from_ladder(row)
        enc = self.codec.encode(worker_grads[0], keys[r], probs=probs)
        if tel.enabled:
            name = getattr(self.codec, "name", "?")
            tel.trace.complete("comm/encode", t0, pid=r, codec=name,
                               impl=_codec_impl(self.codec))
            if r != 0:   # rank 0 records every rank's draw in _serve_round
                tel.mlmc.record_draw(name, enc.packet.header.level,
                                     enc.packet.header.prob)
            if tel.should_sample(f"ladder:{name}:{r}"):
                tel.mlmc.record_ladder(name, r, np.asarray(row),
                                       step=int(state.step))
                tel.mlmc.record_expected(name, np.asarray(probs))
        dl = self.downlink
        direction, bits, shift = _serve_round(
            tp, self.codec, enc.packet.to_bytes(), downlink=dl,
            shift=state.shift if dl is not None else None,
            key=dl.key(rng) if dl is not None else None,
            deadline_ms=self.deadline_ms)
        new_state = state._replace(step=state.step + 1,
                                   ladder_ema=state.ladder_ema.at[r].set(row))
        if dl is not None:
            new_state = new_state._replace(shift=shift)
        return AggregateOut(direction, new_state,
                            jnp.asarray(bits, jnp.float32))


class PackedEF21:
    """EF21 / EF21-SGDM with the per-worker innovation on a packed wire.

    Replays `repro.core.error_feedback.EF21.step` with an
    encode -> ship -> decode round trip on each worker's compressed
    innovation ``c_i = C(target_i - g_i)``, threading the worker mirrors
    through `CommState`."""

    def __init__(self, codec: WireCodec, beta: float,
                 transport: Transport | None = None):
        self.codec = codec
        self.beta = beta
        self.transport = transport or LoopbackTransport()

    def init(self, num_workers: int, dim: int) -> CommState:
        return ef21_comm_state(num_workers, dim)

    def __call__(self, worker_grads: Array, rng, state: CommState | None = None):
        from repro.core.aggregators import AggregateOut

        del rng  # the EF21 compressors (Top-k / sign) are deterministic
        if state is None:
            state = self.init(*worker_grads.shape)
        tel = obs.active()
        name, impl = getattr(self.codec, "name", "?"), _codec_impl(self.codec)
        target, mom = ef21_targets(state, worker_grads, self.beta)
        innovations = target - state.g_workers
        m = innovations.shape[0]
        t0 = time.perf_counter() if tel.enabled else 0.0
        if _is_compiled(self.codec):
            packets_out = self.codec.encode_batch(innovations)
        else:
            packets_out = [self.codec.encode(innovations[i], None).packet
                           for i in range(m)]
        if tel.enabled:
            tel.trace.complete("comm/encode", t0, codec=name, impl=impl)
            tel.observe("codec_encode_s", time.perf_counter() - t0,
                        codec=name, impl=impl)
        delivered = self.transport.exchange(
            [p.to_bytes() for p in packets_out])
        packets = [Packet.from_bytes(b) for b in delivered]
        t0 = time.perf_counter() if tel.enabled else 0.0
        if _is_compiled(self.codec):
            c = self.codec.decode_stack(packets)
        else:
            c = jnp.stack([jnp.asarray(self.codec.decode(p))
                           for p in packets])
        g_workers = state.g_workers + c
        g_server = state.g_server + jnp.mean(c, axis=0)
        if tel.enabled:
            tel.trace.complete("comm/decode_fold", t0, codec=name, impl=impl)
            tel.observe("codec_decode_s", time.perf_counter() - t0,
                        codec=name, impl=impl)
            # innovation norms ||C(target_i - g_i)|| contract as the
            # mirrors converge — the EF21 health signal
            if tel.should_sample(f"innovation:{name}"):
                tel.mlmc.record_innovation(
                    name, np.asarray(jnp.linalg.norm(c, axis=1)),
                    step=int(state.step))
        bits = float(sum(self.codec.measured_bits(p) for p in packets))
        self.transport.broadcast(4 * self.codec.dim, m)
        new_state = state._replace(step=state.step + 1, g_workers=g_workers,
                                   g_server=g_server, momentum=mom)
        return AggregateOut(g_server, new_state,
                            jnp.asarray(bits, jnp.float32))


class MultihostPackedEF21:
    """EF21 / EF21-SGDM over the TCP star — the ROADMAP follow-up.

    Each rank compresses only ITS OWN innovation ``c_r = C(target_r - g_r)``
    (momentum and ``g_r`` are rank-local rows of the CommState).  Rank 0
    decodes every worker's innovation and REPLICATES them into its full
    ``(M, d)`` ``g_workers`` mirror — the server-side innovation-state
    replication that makes the aggregate ``g <- g + mean_i(c_i)``
    computable — then re-broadcasts the new direction ``g`` as raw f32 bit
    patterns, so training over tcp equals loopback bit-for-bit.

    Worker ranks update their own mirror row from their own decoded packet
    (value-exact, the identical bytes rank 0 decoded) and adopt the
    broadcast aggregate; rows of other workers stay at their initial zeros
    on non-server ranks (only rank 0 owns the full ``g_workers`` mirror —
    checkpoint on rank 0, like the launcher does).

    Checkpointing for ``beta < 1`` (EF21-SGDM): the MOMENTUM rows are
    client-side by construction — rank 0 cannot derive ``v_i`` from the
    compressed innovation ``c_i`` — so before saving,
    `Trainer.sync_comm_state` gathers every rank's momentum row over the
    STATE frame and folds them into rank 0's state, making the rank-0
    checkpoint complete (same mechanism as `MultihostPackedAdaptive`'s
    ladder rows).  Plain EF21 (``beta = 1``) has no momentum and its
    rank-0 state is complete without the sync."""

    def __init__(self, codec: WireCodec, beta: float, transport):
        _require_multihost(transport, "MultihostPackedEF21")
        if bool(getattr(transport, "elastic", False)):
            raise ValueError(
                "the EF21 family does not compose with an elastic "
                "(deadline_ms) transport: the server mirror g must fold "
                "EVERY rank's innovation every round")
        self.codec = codec
        self.beta = beta
        self.transport = transport

    def init(self, num_workers: int, dim: int) -> CommState:
        return ef21_comm_state(num_workers, dim)

    def __call__(self, worker_grads: Array, rng, state: CommState | None = None):
        from repro.core.aggregators import AggregateOut

        del rng
        tp = self.transport
        _require_one_worker(worker_grads)
        if state is None:
            state = self.init(tp.world, worker_grads.shape[1])
        r = tp.rank
        tel = obs.active()
        name, impl = getattr(self.codec, "name", "?"), _codec_impl(self.codec)
        own = state._replace(g_workers=state.g_workers[r:r + 1],
                             momentum=state.momentum[r:r + 1])
        target, mom_r = ef21_targets(own, worker_grads, self.beta)
        innovation = (target - own.g_workers)[0]
        t0 = time.perf_counter() if tel.enabled else 0.0
        enc = self.codec.encode(innovation, None)
        raw = enc.packet.to_bytes()
        if tel.enabled:
            tel.trace.complete("comm/encode", t0, pid=r, codec=name,
                               impl=impl)

        if tp.rank == 0:
            # server: decode ALL innovations -> replicate the worker mirror
            # (each uplink's decode dispatches as its frame completes)
            t0 = time.perf_counter() if tel.enabled else 0.0
            packets, rows = _drain_decoding(tp, self.codec, raw)
            if rows is not None:
                c = jnp.stack(rows)
            else:
                c = jnp.stack([jnp.asarray(self.codec.decode(p))
                               for p in packets])
            g_workers = state.g_workers + c
            g_server = state.g_server + jnp.mean(c, axis=0)
            if tel.enabled:
                tel.trace.complete("comm/serve_round", t0, pid=0, codec=name,
                                   impl=impl, world=tp.world)
                if tel.should_sample(f"innovation:{name}"):
                    tel.mlmc.record_innovation(
                        name, np.asarray(jnp.linalg.norm(c, axis=1)),
                        step=int(state.step))
            bits = float(sum(self.codec.measured_bits(p) for p in packets))
            tp.broadcast_payload(pack_direction(np.asarray(g_server), bits))
        else:
            tp.exchange([raw])
            # own row only: decode our own packet (the identical bytes the
            # server decoded, so the mirror row matches rank 0's bit-for-bit)
            c_r = jnp.asarray(self.codec.decode(Packet.from_bytes(raw)))
            g_workers = state.g_workers.at[r].add(c_r)
            vec, bits = unpack_direction(tp.broadcast_payload(None),
                                         self.codec.dim)
            g_server = jnp.asarray(vec)

        momentum = state.momentum.at[r].set(mom_r[0]) \
            if self.beta < 1.0 else state.momentum
        new_state = state._replace(step=state.step + 1, g_workers=g_workers,
                                   g_server=g_server, momentum=momentum)
        return AggregateOut(g_server, new_state,
                            jnp.asarray(bits, jnp.float32))


def _make_packed_codec(name: str, dim: int, compiled: bool | None,
                       codec_kw: dict):
    """One packed-wire codec: the per-(codec, direction) compiled defaults
    unless the caller forces a pipeline (shared by uplink, downlink, and
    the per-bucket `WirePlan` construction).  When the two directions'
    defaults disagree (e.g. mlmc_topk: compiled encode, eager decode) the
    result is a `repro.comm.compiled.HybridCodec`."""
    if compiled is None:
        from repro.comm.compiled import default_compiled

        enc_c = default_compiled(name, "encode")
        dec_c = default_compiled(name, "decode")
    else:
        enc_c = dec_c = bool(compiled)
    if enc_c and dec_c:
        from repro.comm.compiled import make_compiled_codec

        return make_compiled_codec(name, dim, **codec_kw)
    if enc_c or dec_c:
        from repro.comm.compiled import make_hybrid_codec

        return make_hybrid_codec(name, dim, encode_compiled=enc_c,
                                 **codec_kw)
    return make_codec(name, dim, **codec_kw)


def packed_aggregator(name: str, dim: int, *, transport: Transport | None = None,
                      k_fraction: float = 0.01, s: int = 1,
                      rtn_level: int = 4, qsgd_levels: int = 2,
                      momentum_beta: float = 0.1, fixed_levels: int = 24,
                      ema_rho: float = 0.25, compiled: bool | None = None,
                      downlink: str | None = None,
                      downlink_alpha: float = 0.5,
                      bucket_size: int | None = None,
                      policy=None,
                      deadline_ms: float | None = None):
    """Build the packed-wire `Aggregator` for a registry name (the
    ``wire="packed"`` branch of `repro.core.aggregators.make_aggregator`).

    ``compiled=None`` (default) picks the measured-faster pipeline per
    codec (`repro.comm.compiled.default_compiled`): the jit-compiled fast
    path for every codec except the EF21 family, whose compiled encode
    benchmarks slower than the eager one.  ``compiled=True`` forces the
    jit-compiled path — byte-identical packets, the per-worker eager op
    dispatch replaced by one vmapped encode, one device_get, and one
    fused decode+mean per step — and ``compiled=False`` forces the eager
    codecs (verification / A-B benchmarks).

    ``downlink`` names a registry codec for the server->worker direction
    (DIANA-style shift compression — see `Downlink`); ``bucket_size``
    carves the gradient into fixed-shape buckets encoded independently
    through a shared per-bucket `WirePlan`
    (`repro.comm.plan.BucketedPackedAggregate`), so the trainer can
    overlap per-bucket encodes with the remaining backward.

    ``policy`` (a `ResolvedPolicy`) replaces the single ``name`` codec
    with policy-driven (segment, codec) streams shipped as one RCBW
    container per worker (`repro.comm.plan.policy_packed_aggregator`);
    ``bucket_size`` composes by subdividing the segments."""
    from repro.core.aggregators import Aggregator

    codec_kw = dict(k_fraction=k_fraction, s=s, rtn_level=rtn_level,
                    qsgd_levels=qsgd_levels, fixed_levels=fixed_levels)
    elastic = bool(getattr(transport, "elastic", False))
    if elastic or deadline_ms is not None:
        _check_deadline(transport, deadline_ms, downlink)
        if policy is not None or bucket_size is not None:
            raise ValueError(
                "elastic deadline rounds do not compose with the "
                "bucketed/policy RCBW containers: a partial bucket round "
                "would leave the per-segment streams desynced across "
                "ranks")
        if name in ("ef21", "ef21_sgdm", "signsgd_ef"):
            raise ValueError(
                "the EF21 family does not compose with elastic deadline "
                "rounds: the server mirror g must fold EVERY rank's "
                "innovation every round, so a missed uplink desyncs the "
                "world")
    dl = None
    if downlink is not None:
        dl = Downlink(_make_packed_codec(downlink, dim, compiled, codec_kw),
                      downlink_alpha)
    if policy is not None:
        from repro.comm.plan import policy_packed_aggregator

        return policy_packed_aggregator(
            policy, dim, transport=transport, compiled=compiled,
            downlink=dl, codec_kw=codec_kw, bucket_size=bucket_size)
    if bucket_size is not None:
        from repro.comm.plan import bucketed_packed_aggregator

        return bucketed_packed_aggregator(
            name, dim, bucket_size=bucket_size, transport=transport,
            compiled=compiled, downlink=dl, codec_kw=codec_kw)
    codec = _make_packed_codec(name, dim, compiled, codec_kw)
    multihost = is_multihost_transport(transport)
    if name in ("ef21", "ef21_sgdm", "signsgd_ef"):
        if dl is not None:
            raise ValueError(
                "downlink compression does not compose with the EF21 "
                "family: its direction IS the server innovation state "
                "g, which every rank already reconstructs incrementally")
        beta = momentum_beta if name == "ef21_sgdm" else 1.0
        cls = MultihostPackedEF21 if multihost else PackedEF21
        ef = cls(codec, beta, transport)
        return Aggregator(name, ef, init=ef.init, stateful=True)
    if name in ("mlmc_adaptive_topk", "mlmc_adaptive_stopk",
                "mlmc_adaptive_rtn"):
        if multihost:
            ad = MultihostPackedAdaptive(codec, codec.compressor, ema_rho,
                                         transport, downlink=dl,
                                         deadline_ms=deadline_ms)
        else:
            ad = PackedAdaptiveMLMC(codec, codec.compressor, ema_rho,
                                    transport, downlink=dl)
        return Aggregator(name, ad, init=ad.init, stateful=True)
    if multihost:
        ag = MultihostPackedAggregate(codec, transport, downlink=dl,
                                      deadline_ms=deadline_ms)
    else:
        ag = PackedAggregate(codec, transport, downlink=dl)
    if dl is not None:
        return Aggregator(name, ag, init=ag.init, stateful=True)
    return Aggregator(name, ag)
