"""deepseek-v3-671b — [moe] MLA, 1 shared + 256 routed top-8, MTP. [arXiv:2412.19437]"""

from repro.configs.base import LayerSpec, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    cite="arXiv:2412.19437",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,     # MLA: logical kv heads = heads (latent-compressed)
    head_dim=128,
    d_ff=18432,           # dense-layer FFN width (first 3 layers)
    vocab_size=129280,
    prefix=(LayerSpec("mla", "dense"),) * 3,
    pattern=(LayerSpec("mla", "moe"),),
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=256, top_k=8, num_shared=1, d_ff_expert=2048),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    mtp_depth=1,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    fsdp=True,
    supports_long_context=False,  # full attention (MLA shrinks cache, but
                                  # long-ctx slots are reserved for SWA/SSM)
)
