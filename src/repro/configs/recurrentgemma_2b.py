"""recurrentgemma-2b — [hybrid] RG-LRU + local attn, 1 attn : 2 recurrent. [arXiv:2402.19427]"""

from repro.configs.base import LayerSpec, ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    cite="arXiv:2402.19427",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,       # MQA
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    # 26 = 2 recurrent prefix + 8 x (recurrent, recurrent, local-attn)
    prefix=(LayerSpec("rglru"),) * 2,
    pattern=(LayerSpec("rglru"), LayerSpec("rglru"), LayerSpec("swa")),
    swa_window=2048,
    rglru=RGLRUConfig(lru_width=2560, d_conv=4),
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    supports_long_context=True,   # recurrent state + windowed attention
)
