"""internvl2-76b — [vlm] InternViT + LLaMA3-70B-class LM backbone. [arXiv:2404.16821]

Per the assignment carve-out, the vision tower is a STUB: `input_specs()`
feeds precomputed, already-projected patch embeddings of shape
(batch, num_vision_tokens, d_model); this config is the language decoder
that consumes them."""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    cite="arXiv:2404.16821",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    pattern=(LayerSpec("attn"),),
    rope_theta=500_000.0,
    num_vision_tokens=256,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    fsdp=True,
    supports_long_context=False,  # full attention
)
