"""mamba2-370m — [ssm] attention-free SSD (state-space duality). [arXiv:2405.21060]"""

from repro.configs.base import LayerSpec, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    cite="arXiv:2405.21060",
    num_layers=48,
    d_model=1024,
    num_heads=1,        # attention-free; SSD heads derive from ssm config
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,             # no MLP blocks — Mamba2 blocks only
    vocab_size=50280,
    pattern=(LayerSpec("ssd"),),
    rope_style="none",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=256),
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    supports_long_context=True,   # O(1) recurrent state per token
)
