"""mixtral-8x22b — [moe] 8 experts top-2, GQA, SWA. [arXiv:2401.04088]"""

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    cite="arXiv:2401.04088",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    pattern=(LayerSpec("swa", "moe"),),
    swa_window=4096,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=8, top_k=2),
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    fsdp=True,
    supports_long_context=True,   # SWA decode: bounded window cache
)
