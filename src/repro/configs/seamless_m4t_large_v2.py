"""seamless-m4t-large-v2 — [audio] enc-dec, multimodal. [arXiv:2308.11596]

Per the assignment carve-out, the mel-spectrogram + conformer feature
frontend is a STUB: `input_specs()` feeds precomputed frame embeddings
(batch, source_len, d_model) to the transformer encoder; this config is the
encoder-decoder transformer backbone."""

from repro.configs.base import EncoderConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    cite="arXiv:2308.11596",
    num_layers=24,         # decoder layers; encoder below
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,       # full MHA
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    pattern=(LayerSpec("attn"),),
    rope_style="none",     # learned positions in the original; we use rope-free
    encoder=EncoderConfig(num_layers=24, d_model=1024, num_heads=16,
                          d_ff=8192, max_source_len=1024),
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    supports_long_context=False,  # full attention enc-dec
)
