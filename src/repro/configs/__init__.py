"""Config registry: the 10 assigned architectures + the paper-scale config.

``get_config(name)`` accepts the assignment ids (e.g. ``mixtral-8x22b``).
"""

from repro.configs.base import (
    DECODE_32K,
    INPUT_SHAPES,
    LONG_500K,
    PREFILL_32K,
    SHAPES_BY_NAME,
    TRAIN_4K,
    EncoderConfig,
    InputShape,
    LayerSpec,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
    reduce_for_smoke,
)
from repro.configs.chatglm3_6b import CONFIG as CHATGLM3_6B
from repro.configs.deepseek_v3_671b import CONFIG as DEEPSEEK_V3_671B
from repro.configs.gemma3_27b import CONFIG as GEMMA3_27B
from repro.configs.internvl2_76b import CONFIG as INTERNVL2_76B
from repro.configs.mamba2_370m import CONFIG as MAMBA2_370M
from repro.configs.mixtral_8x22b import CONFIG as MIXTRAL_8X22B
from repro.configs.paper_scale import CONFIG as PAPER_SCALE
from repro.configs.qwen25_3b import CONFIG as QWEN25_3B
from repro.configs.qwen3_4b import CONFIG as QWEN3_4B
from repro.configs.recurrentgemma_2b import CONFIG as RECURRENTGEMMA_2B
from repro.configs.seamless_m4t_large_v2 import CONFIG as SEAMLESS_M4T_LARGE_V2

ASSIGNED = (
    MIXTRAL_8X22B,
    MAMBA2_370M,
    DEEPSEEK_V3_671B,
    GEMMA3_27B,
    RECURRENTGEMMA_2B,
    INTERNVL2_76B,
    QWEN25_3B,
    QWEN3_4B,
    CHATGLM3_6B,
    SEAMLESS_M4T_LARGE_V2,
)

REGISTRY = {c.name: c for c in ASSIGNED + (PAPER_SCALE,)}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = [
    "ASSIGNED", "DECODE_32K", "EncoderConfig", "INPUT_SHAPES", "InputShape",
    "LONG_500K", "LayerSpec", "MLAConfig", "ModelConfig", "MoEConfig",
    "PREFILL_32K", "REGISTRY", "RGLRUConfig", "SHAPES_BY_NAME", "SSMConfig",
    "TRAIN_4K", "get_config", "reduce_for_smoke",
]
