"""qwen2.5-3b — [dense] GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B]"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    cite="hf:Qwen/Qwen2.5-0.5B",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    pattern=(LayerSpec("attn"),),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    supports_long_context=False,  # full attention
)
