"""chatglm3-6b — [dense] 2d-RoPE (half-dim rotary), GQA. [arXiv:2406.12793]"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    cite="arXiv:2406.12793",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    pattern=(LayerSpec("attn"),),
    rope_style="half",     # ChatGLM rotates only half of each head dim
    qkv_bias=True,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    supports_long_context=False,  # full attention
)
