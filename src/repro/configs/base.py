"""Model configuration schema + input shapes.

Every assigned architecture is described by one `ModelConfig`; layer structure
is a repeating `pattern` of (mixer, mlp) kinds with optional non-repeating
`prefix` layers, so heterogeneous stacks (gemma3 5:1 local:global,
recurrentgemma 1 attn : 2 recurrent, deepseek 3 dense + 58 MoE) lower to one
`lax.scan` over the repeated pattern plus a short unrolled prefix — keeping
HLO size O(pattern) instead of O(layers).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

MixerKind = Literal["attn", "swa", "mla", "ssd", "rglru"]
MlpKind = Literal["dense", "moe"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: MixerKind
    mlp: MlpKind = "dense"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8           # routed experts
    top_k: int = 2
    num_shared: int = 0            # shared (always-on) experts, deepseek-style
    d_ff_expert: int = 0           # 0 -> use cfg.d_ff
    router_noise: float = 0.0
    capacity_factor: float = 1.25  # used by the capacity-dropping variant
    aux_loss_weight: float = 0.01  # load-balance loss


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 256               # SSD chunk length
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0             # 0 -> d_model
    d_conv: int = 4
    c: float = 8.0                 # the RG-LRU `c` exponent scale


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Auxiliary encoder for enc-dec (audio) and the VLM vision stub."""
    num_layers: int = 24
    d_model: int = 1024
    num_heads: int = 16
    d_ff: int = 8192
    max_source_len: int = 1024     # frames / patches fed by the stub frontend


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    cite: str                      # source paper / model card
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # --- layer structure ---
    pattern: tuple[LayerSpec, ...] = (LayerSpec("attn"),)
    prefix: tuple[LayerSpec, ...] = ()      # unrolled leading layers
    # --- attention details ---
    rope_theta: float = 10_000.0
    rope_style: Literal["full", "half", "none"] = "full"  # half = chatglm 2d
    qkv_bias: bool = False
    qk_norm: bool = False
    swa_window: int = 4096
    softcap: float = 0.0           # gemma-style logit soft-capping (0 = off)
    # --- sub-configs ---
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    mla: MLAConfig | None = None
    encoder: EncoderConfig | None = None   # audio enc-dec
    # --- multimodal stubs ---
    num_vision_tokens: int = 0     # VLM: prepended patch embeddings
    # --- extras ---
    mtp_depth: int = 0             # deepseek multi-token-prediction layers
    tie_embeddings: bool = False
    # --- training / numerics ---
    param_dtype: str = "float32"
    activ_dtype: str = "float32"
    # --- distribution ---
    fsdp: bool = False             # shard stacked layer weights over `data`
    # --- long-context eligibility (sub-quadratic / SWA decode) ---
    supports_long_context: bool = False

    # ------------------------------------------------------------------

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_repeats(self) -> int:
        body = self.num_layers - len(self.prefix)
        if body % len(self.pattern):
            raise ValueError(
                f"{self.name}: {body} body layers not divisible by pattern "
                f"of length {len(self.pattern)}; adjust prefix")
        return body // len(self.pattern)

    @property
    def is_encdec(self) -> bool:
        return self.family == "audio"

    def layer_sequence(self) -> tuple[LayerSpec, ...]:
        return self.prefix + self.pattern * self.num_repeats

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND math."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd, h, kv = self.hd, self.num_heads, self.num_kv_heads
        total = v * d * (1 if self.tie_embeddings else 2)
        for spec in self.layer_sequence():
            if spec.mixer in ("attn", "swa"):
                total += d * (h * hd) * 2 + d * (kv * hd) * 2
            elif spec.mixer == "mla":
                m = self.mla
                total += (d * m.q_lora_rank
                          + m.q_lora_rank * h * (m.nope_head_dim + m.rope_head_dim)
                          + d * (m.kv_lora_rank + m.rope_head_dim)
                          + m.kv_lora_rank * h * (m.nope_head_dim + m.v_head_dim)
                          + h * m.v_head_dim * d)
            elif spec.mixer == "ssd":
                s = self.ssm
                din = s.expand * d
                total += d * (2 * din + 2 * s.n_groups * s.d_state
                              + din // s.head_dim) + din * d
            elif spec.mixer == "rglru":
                w = self.rglru.lru_width or d
                total += d * w * 2 + w * d + 2 * w
            if spec.mlp == "dense":
                total += 3 * d * f
            else:
                fe = self.moe.d_ff_expert or f
                n_e = self.moe.num_experts + self.moe.num_shared
                total += 3 * d * fe * n_e + d * self.moe.num_experts
            total += 2 * d  # norms
        if self.encoder is not None:
            e = self.encoder
            total += e.num_layers * (4 * e.d_model**2 + 3 * e.d_model * e.d_ff)
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE top-k) for 6*N_active*D FLOPs."""
        if self.moe is None:
            return self.param_count()
        fe = self.moe.d_ff_expert or self.d_ff
        d = self.d_model
        n_moe_layers = sum(1 for s in self.layer_sequence() if s.mlp == "moe")
        inactive = (self.moe.num_experts - self.moe.top_k) * 3 * d * fe
        return self.param_count() - n_moe_layers * inactive


# ---------------------------------------------------------------------------
# Assigned input shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Shrink a config to smoke-test size (<=2 pattern repeats, d_model<=256,
    <=4 experts, tiny vocab) while preserving the structural family."""
    d_model = min(cfg.d_model, 256)
    heads = max(2, min(cfg.num_heads, 4))
    kv = max(1, min(cfg.num_kv_heads, heads))
    hd = max(8, d_model // heads)
    changes: dict = dict(
        num_layers=len(cfg.prefix) + len(cfg.pattern),
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=hd,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        swa_window=min(cfg.swa_window, 16),
        num_vision_tokens=min(cfg.num_vision_tokens, 8),
        fsdp=False,
        param_dtype="float32",
        activ_dtype="float32",
    )
    if cfg.moe:
        fe = cfg.moe.d_ff_expert or cfg.d_ff
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            num_shared=min(cfg.moe.num_shared, 1),
            d_ff_expert=min(fe, 128) if cfg.moe.d_ff_expert else 0,
            # effectively dropless at smoke scale so decode-vs-forward
            # consistency isn't polluted by capacity drops
            capacity_factor=float(min(cfg.moe.num_experts, 4)))
    if cfg.ssm:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=min(cfg.ssm.d_state, 16), head_dim=16, chunk=8)
    if cfg.rglru:
        changes["rglru"] = dataclasses.replace(cfg.rglru, lru_width=d_model)
    if cfg.mla:
        changes["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=32,
                                   rope_head_dim=8, nope_head_dim=16,
                                   v_head_dim=16)
    if cfg.encoder:
        changes["encoder"] = EncoderConfig(num_layers=1, d_model=d_model,
                                           num_heads=heads, d_ff=256,
                                           max_source_len=16)
    if cfg.mtp_depth:
        changes["mtp_depth"] = 1
    return dataclasses.replace(cfg, **changes)
