"""gemma3-27b — [dense] 5:1 local:global attention, 128k ctx. [hf:google/gemma-3-1b-pt]"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    cite="hf:google/gemma-3-1b-pt",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    # 62 = 2 swa prefix + 10 x (5 swa + 1 global)
    prefix=(LayerSpec("swa"),) * 2,
    pattern=(LayerSpec("swa"),) * 5 + (LayerSpec("attn"),),
    swa_window=1024,
    rope_theta=1_000_000.0,
    qk_norm=True,
    softcap=0.0,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    fsdp=True,
    supports_long_context=True,   # SWA-dominant; global layers decode O(S)
)
