"""paper-scale — the paper's own experimental regime (§5).

The paper finetunes BERT-base (~110M params) on GLUE SST-2 and trains
ResNet18 (~11M) on CIFAR-10.  This config is a ~110M-parameter decoder
transformer used by the end-to-end example and the figure-reproduction
benchmarks as the stand-in workload for "a ~100M model trained with
MLMC-compressed distributed SGD"."""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="paper-scale",
    family="dense",
    cite="Zukerman et al., ICML 2025 §5 (BERT-base-scale stand-in)",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=32768,
    pattern=(LayerSpec("attn"),),
    param_dtype="float32",
    activ_dtype="float32",
    supports_long_context=False,
)
