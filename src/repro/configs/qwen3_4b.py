"""qwen3-4b — [dense] qk_norm, GQA. [hf:Qwen/Qwen3-8B]"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    cite="hf:Qwen/Qwen3-8B",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    pattern=(LayerSpec("attn"),),
    rope_theta=1_000_000.0,
    qk_norm=True,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    supports_long_context=False,  # full attention
)
