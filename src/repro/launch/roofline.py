"""Roofline analysis from compiled dry-run artifacts.

Per (arch × shape × mesh) we derive three per-chip time terms from the
compiled SPMD module (whose HLO is already per-partition):

    compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16, v5e-class)
    memory     = HLO_bytes / HBM_bw              (819 GB/s)
    collective = collective_bytes / ICI_bw       (~50 GB/s/link)

`cost_analysis()` supplies FLOPs and bytes-accessed; collective bytes are NOT
in cost_analysis, so we parse the optimized HLO text and sum the OUTPUT
shapes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (start-ops counted once, done-ops skipped).

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) sanity-checks how much of
the compiled compute is "useful" — catching remat recompute and dispatch
overheads.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

# --- hardware constants (TPU v5e-class target) ------------------------------
PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link per chip

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|f8e4m3fn|f8e5m2|f8e4m3|bf16|f16|f32|f64|s8|s16|"
                       r"s32|s64|u8|u16|u32|u64|c64|c128)\[([0-9,]*)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes per collective kind (per-chip, SPMD module)."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for kind in _COLLECTIVES:
            token = f" {kind}("
            start_token = f" {kind}-start("
            if token in stripped or start_token in stripped:
                # bytes of the op's OUTPUT: shapes appearing before the op name
                cut = stripped.find(start_token if start_token in stripped
                                    else token)
                head = stripped[:cut]
                for m in _SHAPE_RE.finditer(head):
                    out[kind] += _shape_bytes(m.group(1), m.group(2))
                break
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-chip HLO flops
    hbm_bytes: float             # per-chip bytes accessed
    coll_bytes: float            # per-chip collective output bytes
    coll_breakdown: dict[str, int]
    model_flops: float           # 6·N(_active)·D, per-chip share

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_fraction(self) -> float:
        return self.model_flops / max(self.flops, 1.0)

    def as_dict(self) -> dict[str, Any]:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_fraction": self.useful_fraction,
        }


def analyze(compiled, *, chips: int, model_flops_total: float) -> Roofline:
    """Build the roofline record from a compiled executable."""
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):  # older API returns [dict]
            cost = cost[0]
    except Exception:
        cost = {}
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    try:
        text = compiled.as_text()
    except Exception:
        text = ""
    coll = collective_bytes(text)
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops=model_flops_total / chips,
    )


def model_flops_for(cfg, shape) -> float:
    """6·N(_active)·D total FLOPs for the step's token volume.  Decode steps
    process one token per sequence; train includes the 3x backward factor
    (6ND already counts fwd+bwd for training; for inference use 2ND)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n * shape.global_batch
