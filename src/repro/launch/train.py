"""Training launcher.

Two modes:

* ``--mode sim`` (default; CPU-friendly): in-process M-worker simulation of
  MLMC-compressed parallel SGD (the paper's Alg. 1/2/3 + EF21 baselines) on
  a reduced architecture + synthetic LM data.  Produces loss-vs-bits
  telemetry and a checkpoint.
* ``--mode mesh``: builds the shard_map train step against the production
  mesh topology on whatever devices exist (use
  XLA_FLAGS=--xla_force_host_platform_device_count=N for CPU testing) and
  runs real sharded steps.

Sim mode can move its packed wire over real sockets: ``--transport tcp``
joins this process to a multi-host star (rank 0 aggregates; see
`repro.launch.multihost` for a one-command localhost world).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch paper-scale \
      --method mlmc_topk --steps 50 --workers 8
  PYTHONPATH=src python -m repro.launch.train --mode mesh --arch qwen2.5-3b \
      --smoke --mesh-shape 1,2,2 --steps 3 --method mlmc_fixed
  PYTHONPATH=src python -m repro.launch.train --wire packed --transport tcp \
      --rank 0 --world 2 --coordinator 127.0.0.1:37737 --steps 10
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="paper-scale")
    ap.add_argument("--mode", default="sim", choices=["sim", "mesh"])
    ap.add_argument("--method", default="mlmc_topk",
                    help="aggregator registry key; stateful methods "
                         "(ef21, ef21_sgdm, mlmc_adaptive_*) thread a "
                         "CommState through every step and checkpoint it")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--workers", type=int, default=8, help="sim-mode M")
    ap.add_argument("--k-fraction", type=float, default=0.01)
    ap.add_argument("--ema-rho", type=float, default=0.25,
                    help="ladder-EMA momentum of the stateful adaptive "
                         "MLMC family (1.0 = per-sample Lemma 3.4)")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch-per-worker", type=int, default=4)
    ap.add_argument("--wire", default="abstract",
                    choices=["abstract", "packed", "device"],
                    help="aggregation substrate: abstract in-memory "
                         "estimates, byte-exact host-side repro.comm "
                         "packets (sim only), or jit-native fixed-shape "
                         "device packets (sim + mesh)")
    ap.add_argument("--transport", default="loopback",
                    choices=["loopback", "parameter_server", "ring",
                             "hierarchical", "tcp"],
                    help="packed-wire transport: in-process cost-model "
                         "accounting, or 'tcp' for a real multi-host "
                         "socket star (measured bytes + wall-clock; pair "
                         "with --rank/--world/--coordinator)")
    ap.add_argument("--rank", type=int, default=0,
                    help="tcp: this process's rank (0 = server)")
    ap.add_argument("--world", type=int, default=0,
                    help="tcp: total ranks (defaults to --workers; one "
                         "rank hosts one worker)")
    ap.add_argument("--coordinator", default="127.0.0.1:37737",
                    help="tcp: host:port of rank 0's rendezvous socket")
    ap.add_argument("--rendezvous-timeout", type=float, default=60.0,
                    help="tcp: seconds to wait for all ranks to join")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="tcp: elastic mode — rank 0 closes each "
                         "aggregation round this many ms after the first "
                         "uplink frame lands, averaging whoever arrived "
                         "(inverse-participation reweighted so the mean "
                         "stays unbiased), tolerating dead ranks and "
                         "accepting mid-run REJOINs.  0 = classic "
                         "synchronous star (wait for everyone)")
    ap.add_argument("--downlink", default="",
                    help="compress the server->worker direction with this "
                         "registry codec (DIANA shift; packed + device "
                         "wires; empty = raw f32 broadcast)")
    ap.add_argument("--downlink-alpha", type=float, default=0.5,
                    help="shift learning rate of the downlink's DIANA "
                         "update h <- h + alpha * decode(delta)")
    ap.add_argument("--bucket-size", type=int, default=0,
                    help="carve the packed wire into fixed-shape buckets "
                         "of this many params (0 = one flat packet).  "
                         "In-process the buckets encode during backward; "
                         "over tcp they ship batched as one RCBW container "
                         "per rank")
    ap.add_argument("--policy", default="",
                    help="per-leaf codec policy: a preset name "
                         "(dense_small_tensors, dense_embed_norm, ...) or "
                         "a 'pattern=codec,pattern=codec' rule string "
                         "matched against param leaf paths/sizes "
                         "(repro.comm.policy).  Splits the gradient into "
                         "per-segment codec streams on every wire; "
                         "supersedes --method.  Over tcp the resolved "
                         "policy hash rides the HELLO handshake so "
                         "mismatched ranks fail fast at rendezvous")
    ap.add_argument("--smoke", action="store_true",
                    help="reduce the architecture to smoke size")
    ap.add_argument("--mesh-shape", default="1,2,2",
                    help="mesh-mode pod,data,model sizes")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--trace", default="",
                    help="record comm-stack telemetry (spans, wire "
                         "metrics, MLMC estimator telemetry) to this "
                         "JSONL event log (sim mode)")
    ap.add_argument("--trace-perfetto", default="",
                    help="additionally write the Chrome trace-event JSON "
                         "(open in https://ui.perfetto.dev or "
                         "chrome://tracing; one track per rank)")
    ap.add_argument("--trace-sample-every", type=int, default=10,
                    help="sampling period of the expensive estimator "
                         "metrics (ladder rows, innovation norms, bias "
                         "proxy); spans/counters are never sampled")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduce_for_smoke
    from repro.models import build_model
    from repro.optim import sgd

    cfg = get_config(args.arch)
    if args.smoke or args.mode == "sim":
        cfg = reduce_for_smoke(cfg)
    model = build_model(cfg)

    if args.mode == "sim":
        from repro.data import LMTask, lm_batches
        from repro.train import Trainer

        task = LMTask(vocab=cfg.vocab_size, seq=args.seq)
        if args.wire == "packed" and args.transport == "tcp":
            world = args.world or args.workers
            if args.workers != world:
                print(f"note: --workers overridden to --world {world} "
                      "(one tcp rank hosts one worker)")
                args.workers = world
        # every tcp rank draws this same global stream and slices its shard
        data = lm_batches(task, args.workers, args.batch_per_worker)
        params = model.init(jax.random.PRNGKey(0))

        def loss_fn(p, batch):
            return model.loss(p, batch, remat=False)[0]

        policy = None
        if args.policy:
            from repro.comm.policy import CodecPolicy

            # resolve HERE (against the real param tree) so the tcp HELLO
            # can carry the fingerprint before the Trainer exists
            policy = CodecPolicy.parse(args.policy).resolve(params)
        transport = None
        rank = 0
        if args.wire == "packed":
            from repro.comm import make_transport
            if args.transport == "tcp":
                rank = args.rank
                transport = make_transport(
                    "tcp", rank=rank, world=args.workers,
                    coordinator=args.coordinator,
                    timeout=args.rendezvous_timeout,
                    policy_hash=policy.hash if policy else None,
                    deadline_ms=args.deadline_ms or None)
            else:
                transport = make_transport(args.transport)
        elif args.transport != "loopback":
            print(f"note: --transport {args.transport} has no effect with "
                  f"--wire {args.wire} (only --wire packed ships host "
                  "bytes through a Transport)")
        telemetry = None
        if args.trace or args.trace_perfetto:
            from repro import obs

            telemetry = obs.Telemetry(
                rank=rank, sample_every=args.trace_sample_every)
        trainer = Trainer(loss_fn, params, num_workers=args.workers,
                          method=args.method, optimizer=sgd(args.lr),
                          k_fraction=args.k_fraction, ema_rho=args.ema_rho,
                          wire=args.wire, transport=transport,
                          downlink=args.downlink or None,
                          downlink_alpha=args.downlink_alpha,
                          bucket_size=args.bucket_size or None,
                          policy=policy, telemetry=telemetry)
        who = (f" rank={rank}/{args.workers}"
               if transport is not None and args.transport == "tcp" else "")
        pol = (f" policy={args.policy}({len(policy.segments)} segs)"
               if policy is not None else "")
        print(f"sim: {cfg.name} M={args.workers} method={args.method} "
              f"wire={args.wire}{who}{pol} dim={trainer.dim:,}")
        t0 = time.time()
        try:
            hist = trainer.fit(data, steps=args.steps, log_every=10)
        except Exception as exc:
            from repro.comm import ServerShutdown

            if not isinstance(exc, ServerShutdown):
                raise
            # elastic star: rank 0 said GOODBYE("shutdown") — a clean
            # end-of-run, not a network fault
            print(f"rank {rank}: server shut down cleanly after "
                  f"{transport.stats.rounds} rounds; exiting")
            if hasattr(transport, "close"):
                transport.close()
            return
        print(f"done in {time.time()-t0:.1f}s; final loss "
              f"{hist.loss[-1]:.4f}; total {hist.bits[-1]/1e9:.3f} Gbits")
        if transport is not None:
            st = transport.stats
            clock = (f"wall_time={st.wall_time_s*1e3:.2f} ms measured"
                     if args.transport == "tcp"
                     else f"sim_time={st.sim_time_s*1e3:.2f} ms")
            print(f"wire: {st.rounds} rounds, {st.bytes_up/1e6:.3f} MB up, "
                  f"{st.bytes_down/1e6:.3f} MB down, {clock} "
                  f"({args.transport})")
        if args.checkpoint:
            # STATE-frame collective: gather every rank's client-side
            # CommState rows to rank 0 so the bundle is complete (a no-op
            # off tcp); EVERY rank participates, then rank 0 writes
            trainer.sync_comm_state()
            if rank != 0:
                print("note: --checkpoint written by rank 0 only (params "
                      "are identical; this rank shipped its CommState rows "
                      "on the STATE frame, so the rank-0 bundle restores "
                      "the whole world)")
            else:
                # one bundle: params + opt_state + CommState, so stateful
                # runs (EF21 mirrors, adaptive EMA ladders) resume exactly
                trainer.save_checkpoint(
                    args.checkpoint, {"arch": cfg.name, "steps": args.steps})
                print(f"checkpoint -> {args.checkpoint}")
        if transport is not None and hasattr(transport, "close"):
            transport.close()
        if telemetry is not None:
            from repro import obs

            if args.trace:
                n = obs.export.write_jsonl(args.trace, telemetry)
                print(f"trace: {n} events -> {args.trace}")
            if args.trace_perfetto:
                n = obs.export.write_chrome_trace(
                    args.trace_perfetto, telemetry)
                print(f"trace: {n} trace events -> {args.trace_perfetto} "
                      "(open in https://ui.perfetto.dev)")
            bias = {m: e["bias_proxy"]
                    for m, e in telemetry.mlmc.summary().items()
                    if "bias_proxy" in e}
            if bias:
                print(f"bias proxy (||mean dir - mean dense||/||mean "
                      f"dense||): {bias}")
        return

    # --- mesh mode ---------------------------------------------------------
    if args.wire == "packed":
        raise SystemExit("--wire packed is host-side Python and applies to "
                         "sim mode only; use --wire device for packed "
                         "collective operands on the mesh")
    if args.transport != "loopback":
        print(f"note: --transport {args.transport} has no effect in mesh "
              "mode (collectives move device operands, not host packets)")
    from repro.configs.base import InputShape
    from repro.launch.mesh import make_mesh
    from repro.train import step as step_mod

    pp, dp, tp = (int(x) for x in args.mesh_shape.split(","))
    need = pp * dp * tp
    if jax.device_count() < need:
        raise SystemExit(
            f"need {need} devices, have {jax.device_count()} — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    if pp > 1:
        mesh = make_mesh((pp, dp, tp), ("pod", "data", "model"))
    else:
        mesh = make_mesh((dp, tp), ("data", "model"))
    gb = dp * pp * args.batch_per_worker
    shape = InputShape("cli", args.seq, gb, "train")
    opt = sgd(args.lr)
    fn, _, _ = step_mod.make_train_step(model, mesh, opt, shape=shape,
                                        method=args.method,
                                        k_fraction=args.k_fraction,
                                        wire=args.wire, ema_rho=args.ema_rho,
                                        policy=args.policy or None)
    comm_state, _ = step_mod.init_mesh_comm_state(
        model, mesh, method=args.method, k_fraction=args.k_fraction)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (gb, args.seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (gb, args.seq), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["vision"] = jnp.zeros((gb, cfg.num_vision_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["source"] = jnp.zeros(
            (gb, cfg.encoder.max_source_len, cfg.encoder.d_model))
    print(f"mesh: {cfg.name} {mesh.devices.shape} method={args.method} "
          f"wire={args.wire}")
    for t in range(args.steps):
        rng_t = jax.random.fold_in(key, t)
        if comm_state is not None:   # stateful method: thread the CommState
            params, opt_state, comm_state, metrics = fn(
                params, opt_state, comm_state, batch, rng_t)
        else:
            params, opt_state, metrics = fn(params, opt_state, batch, rng_t)
        print(f"  step {t} loss={float(metrics['loss']):.4f} "
              f"bits={float(metrics['bits']):.3e}")
    print("mesh training done")


if __name__ == "__main__":
    main()
