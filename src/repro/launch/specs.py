"""`input_specs()` — ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation: the dry-run lowers
`train_step` / `prefill_step` / `decode_step` against these.  The modality
carve-out lives here: VLM vision tokens and audio frames arrive as
precomputed embeddings of the right shape (the stub frontend).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models.model import Model
from repro.sharding.ctx import ShardCtx, unsharded

PyTree = Any


def _sds(shape, dtype, mesh=None, spec: P | None = None):
    sharding = None
    if mesh is not None and spec is not None:
        sharding = NamedSharding(mesh, spec)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _with_shardings(tree: PyTree, specs: PyTree, mesh) -> PyTree:
    def attach(x, s):
        return jax.ShapeDtypeStruct(x.shape, x.dtype,
                                    sharding=NamedSharding(mesh, s))
    return jax.tree.map(attach, tree, specs)


def batch_struct(cfg: ModelConfig, shape: InputShape, kind: str) -> dict:
    """Abstract batch (GLOBAL shapes, no shardings)."""
    b, s = shape.global_batch, shape.seq_len
    act = jnp.dtype(cfg.activ_dtype)
    out: dict = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.family == "vlm":
        out["vision"] = jax.ShapeDtypeStruct(
            (b, cfg.num_vision_tokens, cfg.d_model), act)
    if cfg.family == "audio":
        out["source"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder.max_source_len, cfg.encoder.d_model), act)
    return out


def abstract_caches(model: Model, shape: InputShape) -> PyTree:
    """GLOBAL cache shapes (unsharded ctx => tp-independent ring sizes)."""
    return jax.eval_shape(
        lambda: model.init_caches(shape.global_batch, shape.seq_len,
                                  unsharded()))


def rng_struct():
    return jax.eval_shape(lambda: jax.random.PRNGKey(0))


def input_specs(model: Model, shape: InputShape, mesh, kind: str,
                optimizer=None) -> tuple[PyTree, ...]:
    """Fully-sharded abstract inputs for one step function.

    kind='train'   -> (params, opt_state, batch, rng)
    kind='prefill' -> (params, batch)
    kind='decode'  -> (params, token, pos, caches[, enc_out])
    """
    import dataclasses

    from repro import perf
    from repro.launch.mesh import ctx_for_mesh, serve_ctx_for_mesh
    from repro.train import step as step_mod

    serve = kind in ("prefill", "decode")
    if serve and perf.enabled("serve_no_fsdp") and model.cfg.fsdp:
        # serving stores weights WITHOUT data-axis sharding (see perf.py)
        model = Model(dataclasses.replace(model.cfg, fsdp=False))
    ctx = (serve_ctx_for_mesh(mesh)
           if serve and perf.enabled("serve_tp_all") else ctx_for_mesh(mesh))
    cfg = model.cfg
    p_abs = model.abstract_params()
    p_specs = step_mod.model_param_specs(model, ctx)
    params = _with_shardings(p_abs, p_specs, mesh)
    b_axes = step_mod.batch_axes(shape.global_batch, ctx)

    if kind == "train":
        assert optimizer is not None
        o_abs = jax.eval_shape(optimizer.init, p_abs)
        o_specs = optimizer.state_specs(p_specs)
        opt = _with_shardings(o_abs, o_specs, mesh)
        batch = _with_shardings(
            batch_struct(cfg, shape, kind),
            step_mod.make_batch_specs(cfg, shape, ctx, kind), mesh)
        rng = _sds(rng_struct().shape, rng_struct().dtype, mesh, P())
        return params, opt, batch, rng

    if kind == "prefill":
        batch = _with_shardings(
            batch_struct(cfg, shape, kind),
            step_mod.make_batch_specs(cfg, shape, ctx, kind), mesh)
        return params, batch

    if kind == "decode":
        token = _sds((shape.global_batch,), jnp.int32, mesh, P(b_axes))
        pos = _sds((), jnp.int32, mesh, P())
        caches = _with_shardings(
            abstract_caches(model, shape),
            step_mod.cache_specs(cfg, ctx, shape.global_batch), mesh)
        if cfg.is_encdec:
            enc = _sds((shape.global_batch, cfg.encoder.max_source_len,
                        cfg.encoder.d_model), jnp.dtype(cfg.activ_dtype),
                       mesh, P(b_axes, None, None))
            return params, token, pos, caches, enc
        return params, token, pos, caches

    raise ValueError(kind)
