import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (device count is now locked at 512) ---

import argparse      # noqa: E402
import json          # noqa: E402
import pathlib       # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ASSIGNED, REGISTRY, SHAPES_BY_NAME  # noqa: E402
from repro.launch import roofline as rl                       # noqa: E402
from repro.launch.mesh import ctx_for_mesh, make_production_mesh  # noqa: E402
from repro.launch.specs import input_specs                    # noqa: E402
from repro.models import build_model                          # noqa: E402
from repro.optim import sgd                                   # noqa: E402
from repro.train import step as step_mod                      # noqa: E402

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
against the production mesh, print memory/cost analyses, and record the
roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh single --method mlmc_topk --out benchmarks/results

Roofline methodology: XLA's HloCostAnalysis counts a while-loop body ONCE,
so the production (scanned-over-layers) module under-reports flops/bytes/
collectives by ~the layer count.  We therefore compile THREE artifacts per
combo: the full scanned module (the lowering/compile proof + memory
analysis, since scan reuses body buffers) and 1-repeat / 2-repeat UNROLLED
variants whose cost analyses are exact; the full-depth cost is the linear
extrapolation  m(R) = m1 + (R-1) * (m2 - m1)  — still derived entirely from
compiled artifacts.

The FIRST two lines of this file force 512 host platform devices BEFORE any
jax import — do not move them.
"""

import dataclasses  # noqa: E402

RESULTS_DIR = pathlib.Path("benchmarks/results")


def scale_repeats(cfg, r: int):
    """Variant of cfg with r pattern repeats (and r encoder layers — for the
    audio arch both stacks have the same true repeat count, 24)."""
    changes: dict = {"num_layers": len(cfg.prefix) + r * len(cfg.pattern)}
    if cfg.encoder is not None:
        changes["encoder"] = dataclasses.replace(cfg.encoder, num_layers=r)
    return dataclasses.replace(cfg, **changes)


def _cost_of(compiled) -> dict:
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
    except Exception:
        cost = {}
    coll = rl.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "hbm_bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def _extrapolate(c1: dict, c2: dict, repeats: int) -> dict:
    def ext(a, b):
        return a + (repeats - 1) * max(b - a, 0.0)

    coll = {k: ext(c1["coll"][k], c2["coll"][k]) for k in c1["coll"]}
    return {"flops": ext(c1["flops"], c2["flops"]),
            "hbm_bytes": ext(c1["hbm_bytes"], c2["hbm_bytes"]),
            "coll": coll}


def combo_supported(cfg, shape) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("full-attention architecture without a sliding-window "
                       "variant: 500k decode is skipped per DESIGN.md "
                       "§Arch-applicability")
    return True, ""


def build_step(model, mesh, shape, method: str, k_fraction: float):
    """Returns (step_fn, abstract_args) for the shape's kind."""
    if shape.kind == "train":
        opt = sgd(3e-3)
        fn, _, _ = step_mod.make_train_step(model, mesh, opt, shape=shape,
                                            method=method,
                                            k_fraction=k_fraction)
        args = input_specs(model, shape, mesh, "train", optimizer=opt)
    elif shape.kind == "prefill":
        fn, _, _ = step_mod.make_prefill_step(model, mesh, shape=shape)
        args = input_specs(model, shape, mesh, "prefill")
    else:
        fn, _, _ = step_mod.make_decode_step(model, mesh, shape=shape)
        args = input_specs(model, shape, mesh, "decode")
    return fn, args


def run_one(arch: str, shape_name: str, multi_pod: bool, method: str,
            k_fraction: float, out_dir: pathlib.Path,
            save_hlo: bool = False) -> dict:
    from repro import perf

    cfg = REGISTRY[arch]
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "method": method, "opts": list(perf.active())}

    ok, reason = combo_supported(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    model = build_model(cfg)
    t0 = time.time()
    try:
        # 1. the production (scanned) module: the lowering/compile proof +
        #    memory analysis (scan reuses body buffers, so this is the
        #    realistic footprint)
        fn, args = build_step(model, mesh, shape, method, k_fraction)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        try:
            mem = compiled.memory_analysis()
            mem_rec = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes":
                    getattr(mem, "generated_code_size_in_bytes", None),
            }
        except Exception as e:  # CPU backend may not implement it
            mem_rec = {"error": str(e)}

        # 2. exact per-layer costs from 1-/2-repeat unrolled variants
        costs = []
        for r in (1, 2):
            vcfg = scale_repeats(cfg, r)
            vmodel = build_model(vcfg)
            vfn, vargs = build_step(vmodel, mesh, shape, method, k_fraction)
            costs.append(_cost_of(vfn.lower(*vargs).compile()))
        ext = _extrapolate(costs[0], costs[1], cfg.num_repeats)

        roof = rl.Roofline(
            flops=ext["flops"], hbm_bytes=ext["hbm_bytes"],
            coll_bytes=float(sum(ext["coll"].values())),
            coll_breakdown={k: int(v) for k, v in ext["coll"].items()},
            model_flops=rl.model_flops_for(cfg, shape) / chips)
        rec.update(status="ok", chips=chips, lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1), memory=mem_rec,
                   roofline=roof.as_dict(),
                   cost_r1=costs[0], cost_r2=costs[1],
                   cost_scanned=_cost_of(compiled))
        if save_hlo:
            (out_dir / f"hlo_{arch}_{shape_name}_{mesh_name}.txt").write_text(
                compiled.as_text())
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (see repro.configs.REGISTRY)")
    ap.add_argument("--shape", default="all",
                    help="train_4k|prefill_32k|decode_32k|long_500k|all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--method", default="mlmc_topk",
                    help="gradient aggregation: dense|mlmc_topk|mlmc_fixed")
    ap.add_argument("--k-fraction", type=float, default=0.001)
    ap.add_argument("--out", default=str(RESULTS_DIR))
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = ([c.name for c in ASSIGNED] if args.arch == "all"
             else [args.arch])
    shapes = (list(SHAPES_BY_NAME) if args.shape == "all" else [args.shape])
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                rec = run_one(arch, shape_name, multi_pod, args.method,
                              args.k_fraction, out_dir,
                              save_hlo=args.save_hlo)
                tag = (f"{arch}:{shape_name}:"
                       f"{'multi' if multi_pod else 'single'}:{args.method}")
                from repro import perf

                opt_tag = ("_" + "-".join(perf.active())
                           if perf.active() else "")
                fname = out_dir / (
                    f"dryrun_{arch}_{shape_name}_"
                    f"{'pod2x16x16' if multi_pod else 'pod16x16'}_"
                    f"{args.method}{opt_tag}.json")
                fname.write_text(json.dumps(rec, indent=1))
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"[OK]   {tag} compile={rec['compile_s']}s "
                          f"flops/chip={r['flops']:.3e} "
                          f"coll={r['coll_bytes']:.3e}B "
                          f"bottleneck={r['bottleneck']}", flush=True)
                elif rec["status"] == "skipped":
                    print(f"[SKIP] {tag}: {rec['reason'][:60]}", flush=True)
                else:
                    n_fail += 1
                    print(f"[FAIL] {tag}: {rec['error']}", flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} dry-run combinations failed")
    print("dry-run complete")


if __name__ == "__main__":
    main()
