"""Localhost multi-process launcher for the TCP packed wire.

Spawns ``--world`` OS processes of ``repro.launch.train --wire packed
--transport tcp`` (rank 0 = aggregation server), wires them to one free
coordinator port, and forwards everything after ``--`` to every rank.  Each
rank computes its own worker's gradient in its own process; the bytes
between them cross real localhost sockets and every rank's
`TransportStats` reports *measured* traffic and wall-clock.

For an actual multi-machine run, start one rank per machine by hand with
the same ``--coordinator host:port`` (see README "multi-host wire").

Example:
  PYTHONPATH=src python -m repro.launch.multihost --world 2 -- \
      --arch paper-scale --method mlmc_topk --steps 10
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys

from repro.comm.multihost import MAX_WORLD, pick_free_port


def _pop_flag(args: list[str], flag: str) -> tuple[str, list[str]]:
    """Remove ``flag value`` / ``flag=value`` from ``args``; return
    (value, remaining) — value is "" when the flag is absent."""
    out, value, i = [], "", 0
    while i < len(args):
        a = args[i]
        if a == flag and i + 1 < len(args):
            value = args[i + 1]
            i += 2
            continue
        if a.startswith(flag + "="):
            value = a.split("=", 1)[1]
            i += 1
            continue
        out.append(a)
        i += 1
    return value, out


def _rank_trace_path(base: str, rank: int) -> str:
    root, ext = os.path.splitext(base)
    return f"{root}.rank{rank}{ext or '.jsonl'}"


def _merge_traces(world: int, trace: str, perfetto: str) -> None:
    """Fold the per-rank JSONL logs into the user-requested artifacts:
    one merged, ts-sorted JSONL and/or one Chrome trace JSON with a
    Perfetto track per rank."""
    from repro.obs import export

    base = trace or perfetto
    per_rank = [_rank_trace_path(base, r) for r in range(world)]
    events = export.merge_events(
        *(export.read_jsonl(p) for p in per_rank if os.path.exists(p)))
    if trace:
        n = export.write_jsonl(trace, events)
        print(f"multihost: merged {n} events from {world} ranks -> {trace}")
    if perfetto:
        names = {r: f"rank {r}" + (" (server)" if r == 0 else "")
                 for r in range(world)}
        n = export.write_chrome_trace(perfetto, events, process_names=names)
        print(f"multihost: {n} trace events -> {perfetto} "
              "(open in https://ui.perfetto.dev)")


def launch_world(world: int, train_args: list[str], *,
                 coordinator: str | None = None) -> int:
    """Spawn ``world`` ranks of `repro.launch.train`; returns the first
    nonzero exit code (0 if all ranks succeeded).  A failing rank tears
    the remaining ones down rather than leaving them blocked on a dead
    socket.

    ``--trace``/``--trace-perfetto`` in the forwarded args are rewritten
    to per-rank JSONL logs (``out.rankR.jsonl``) and merged into the
    requested artifact(s) after all ranks exit 0 — the Perfetto view then
    shows one track per rank with the server's fan-in on track 0."""
    if not 2 <= world <= MAX_WORLD:
        raise ValueError(f"world must be in [2, {MAX_WORLD}], got {world}")
    reserved = {"--rank", "--world", "--coordinator", "--transport",
                "--wire", "--workers"}
    for arg in train_args:
        if arg.split("=", 1)[0] in reserved:
            raise ValueError(f"{arg!r} is set by the launcher; drop it from "
                             "the forwarded args")
    trace, train_args = _pop_flag(train_args, "--trace")
    perfetto, train_args = _pop_flag(train_args, "--trace-perfetto")
    coordinator = coordinator or f"127.0.0.1:{pick_free_port()}"
    env = dict(os.environ)
    # make `-m repro.launch.train` importable in the children no matter how
    # the launcher itself was started
    src = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    old = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src + (os.pathsep + old if old else "")
    procs = []
    try:
        for rank in range(world):
            cmd = [sys.executable, "-m", "repro.launch.train",
                   "--wire", "packed", "--transport", "tcp",
                   "--rank", str(rank), "--world", str(world),
                   "--coordinator", coordinator, *train_args]
            if trace or perfetto:
                cmd += ["--trace",
                        _rank_trace_path(trace or perfetto, rank)]
            procs.append(subprocess.Popen(cmd, env=env))
        rc = 0
        for p in procs:
            rc = rc or p.wait()
            if rc:
                break
        if rc == 0 and (trace or perfetto):
            _merge_traces(world, trace, perfetto)
        return rc
    finally:
        for p in procs:
            if p.poll() is None:
                p.terminate()
        for p in procs:
            if p.poll() is None:
                try:
                    p.wait(timeout=10)
                except subprocess.TimeoutExpired:
                    p.kill()


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--world", type=int, default=2,
                    help="number of ranks (= workers) to spawn")
    ap.add_argument("--coordinator", default="",
                    help="host:port override (default: a free local port)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="elastic mode: forward this aggregation deadline "
                         "to every rank (rank 0 averages whoever arrived "
                         "within the window; 0 = classic synchronous star)")
    ap.add_argument("train_args", nargs="*",
                    help="arguments after -- are forwarded to every "
                         "repro.launch.train rank")
    args = ap.parse_args()
    train_args = list(args.train_args)
    if args.deadline_ms:
        train_args += ["--deadline-ms", str(args.deadline_ms)]
    rc = launch_world(args.world, train_args,
                      coordinator=args.coordinator or None)
    if rc:
        raise SystemExit(rc)
    print(f"multihost: all {args.world} ranks finished")


if __name__ == "__main__":
    main()
