"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE importing
jax; smoke tests and benchmarks see the real single CPU device.
"""

from __future__ import annotations

import jax

from repro.sharding.ctx import ShardCtx


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary test meshes (e.g. (2, 4) on 8 forced host devices)."""
    return jax.make_mesh(shape, axes)


def ctx_for_mesh(mesh) -> ShardCtx:
    """Build the ShardCtx matching a mesh's axis names/sizes."""
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    return ShardCtx(
        model_axis="model" if "model" in names else None,
        data_axis="data" if "data" in names else None,
        pod_axis="pod" if "pod" in names else None,
        model_sizes=(sizes.get("model", 1),),
        tp=sizes.get("model", 1),
        dp=sizes.get("data", 1),
        pp=sizes.get("pod", 1),
    )


def serve_ctx_for_mesh(mesh) -> ShardCtx:
    """§Perf `serve_tp_all`: fuse the (data, model) axes into ONE 256-way
    model group for serving.  Decode batches are small and weights are huge,
    so data parallelism is the wrong axis assignment at serve time: fusing
    gives 16x more weight/cache sharding and removes the per-step FSDP
    all-gathers entirely (weights fit at 1/256 per chip)."""
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    axes = tuple(a for a in ("data", "model") if a in names)
    m_sizes = tuple(sizes[a] for a in axes)
    tp = 1
    for s in m_sizes:
        tp *= s
    return ShardCtx(
        model_axis=axes if len(axes) > 1 else (axes[0] if axes else None),
        data_axis=None,
        pod_axis="pod" if "pod" in names else None,
        model_sizes=m_sizes,
        tp=tp,
        dp=1,
        pp=sizes.get("pod", 1),
    )
