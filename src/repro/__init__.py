"""repro — Multilevel Monte Carlo gradient compression for distributed
training on TPU pods, in JAX.

Reproduction of: Zukerman, Hamoud & Levy, "Beyond Communication Overhead:
A Multilevel Monte Carlo Approach for Mitigating Compression Bias in
Distributed Learning", ICML 2025 — plus a production-grade multi-pod
training/serving substrate (10-architecture model zoo, manual TP/EP/FSDP
shard_map runtime, compressed gradient collectives, Pallas compression
kernels, roofline tooling, and the `repro.comm` wire subsystem: byte-exact
codecs, bit-pack kernels and cost-modeled transports for every compressor
family).
"""

__version__ = "1.0.0"
