"""Counters, gauges, histograms — plus the MLMC estimator telemetry.

Two halves:

* `MetricsRegistry` — generic labeled counters/gauges/histograms for the
  comm stack: wire bytes up/down per link, encode/decode latency per
  codec, step wall time.  Prometheus-flavoured naming, exported by
  `repro.obs.export.prometheus_text`.
* `MLMCTelemetry` — the paper-specific estimator metrics: per-step
  level-draw histograms vs the theoretical ``p_l`` ladder (Lemma 3.3 /
  3.4), adaptive EMA residual-norm trajectories, EF21 innovation norms,
  and a running empirical-mean-vs-dense-gradient bias proxy (the
  quantity Lemma 3.2 says converges to zero for MLMC and does NOT for
  plain biased compressors).

Everything here is pure host-side Python over numpy scalars/arrays — no
jax ops, so recording can never add a jit lowering (the retrace-guard
tests in ``tests/test_obs.py`` pin this down).  All containers are
thread-safe (the tcp server thread and the trainer thread both record)
and bounded (trajectory deques), so a long run cannot grow without
limit.
"""

from __future__ import annotations

import bisect
import threading
from collections import deque

import numpy as np

#: default histogram buckets for latencies in SECONDS (10us .. 10s)
DEFAULT_LATENCY_BUCKETS = (
    1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0, 3.0, 10.0)

#: default buckets for byte sizes (64B .. 256MB)
DEFAULT_BYTES_BUCKETS = tuple(float(64 * 4 ** i) for i in range(12))

#: bounded length of every trajectory deque (ladders, innovations, ...)
TRAJECTORY_MAXLEN = 4096


class Counter:
    """Monotone float counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def add(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    """Last-value-wins gauge."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram (cumulative counts at export time, like
    Prometheus; stored as per-bucket counts internally)."""

    __slots__ = ("bounds", "counts", "total", "n")

    def __init__(self, bounds=DEFAULT_LATENCY_BUCKETS):
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.total = 0.0
        self.n = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.total += v
        self.n += 1

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Thread-safe (name, labels) -> metric store.

    ``registry.counter("wire_bytes_up", transport="tcp").add(n)`` — the
    metric is created on first touch, like prometheus_client, so call
    sites never pre-declare anything."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[tuple, tuple[str, str, dict, object]] = {}

    def _get(self, kind: str, cls, name: str, labels: dict, *args):
        key = (kind, name, _label_key(labels))
        with self._lock:
            hit = self._metrics.get(key)
            if hit is None:
                hit = (kind, name, dict(labels), cls(*args))
                self._metrics[key] = hit
            return hit[3]

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", Gauge, name, labels)

    def histogram(self, name: str, *, buckets=DEFAULT_LATENCY_BUCKETS,
                  **labels) -> Histogram:
        return self._get("histogram", Histogram, name, labels, buckets)

    def snapshot(self) -> list[dict]:
        """Export view: one dict per metric, JSON-serializable."""
        with self._lock:
            items = list(self._metrics.values())
        out = []
        for kind, name, labels, m in items:
            d = {"kind": kind, "name": name, "labels": labels}
            if kind == "histogram":
                d.update(buckets=list(m.bounds), counts=list(m.counts),
                         sum=m.total, count=m.n)
            else:
                d["value"] = m.value
            out.append(d)
        return out


# ---------------------------------------------------------------------------
# MLMC estimator telemetry
# ---------------------------------------------------------------------------


class MLMCTelemetry:
    """Estimator-level telemetry for the paper's statistical claims.

    * ``record_draw`` — every shipped MLMC packet's sampled level (and the
      ``p_l`` it was drawn with); ``level_histogram`` folds these into the
      empirical level distribution to compare against the theoretical
      ladder (``record_expected``, from ``compressor.static_probs()`` or
      the adaptive per-sample distribution).
    * ``record_ladder`` — the Alg.-3 EMA residual-norm row of one worker
      (a trajectory deque per (method, worker)).
    * ``record_innovation`` — EF21 per-worker innovation norms
      ``||C(target_i - g_i)||`` (contracts as the mirrors converge).
    * ``record_bias`` — accumulates the shipped direction and the dense
      gradient mean; ``bias_proxy`` is the relative distance of their
      running means — the empirical-mean-vs-dense-gradient bias proxy
      (→ 0 for unbiased estimators by Lemma 3.2).
    """

    def __init__(self, maxlen: int = TRAJECTORY_MAXLEN):
        self._lock = threading.Lock()
        self._maxlen = maxlen
        self._draws: dict[str, dict[int, int]] = {}
        self._expected: dict[str, np.ndarray] = {}
        self._ladders: dict[tuple[str, int], deque] = {}
        self._innovations: dict[str, deque] = {}
        self._bias: dict[str, dict] = {}

    # -- level draws --------------------------------------------------------

    def record_draw(self, method: str, level: int, prob: float) -> None:
        with self._lock:
            hist = self._draws.setdefault(method, {})
            hist[int(level)] = hist.get(int(level), 0) + 1

    def record_expected(self, method: str, probs) -> None:
        p = np.asarray(probs, np.float64).ravel()
        s = p.sum()
        with self._lock:
            self._expected[method] = p / s if s > 0 else p

    def level_histogram(self, method: str) -> dict[int, float]:
        """Empirical level frequencies (1-based levels, sums to 1)."""
        with self._lock:
            hist = dict(self._draws.get(method, {}))
        n = sum(hist.values())
        return {lvl: c / n for lvl, c in sorted(hist.items())} if n else {}

    def draw_count(self, method: str) -> int:
        with self._lock:
            return sum(self._draws.get(method, {}).values())

    def expected_probs(self, method: str) -> np.ndarray | None:
        with self._lock:
            p = self._expected.get(method)
        return None if p is None else p.copy()

    # -- adaptive EMA ladder trajectories -----------------------------------

    def record_ladder(self, method: str, worker: int, row, step=None) -> None:
        row = np.asarray(row, np.float64).ravel().copy()
        with self._lock:
            dq = self._ladders.setdefault(
                (method, int(worker)), deque(maxlen=self._maxlen))
            dq.append((None if step is None else int(step), row))

    def ladder_trajectory(self, method: str,
                          worker: int) -> list[tuple[int | None, np.ndarray]]:
        with self._lock:
            return list(self._ladders.get((method, int(worker)), ()))

    # -- EF21 innovation norms ----------------------------------------------

    def record_innovation(self, method: str, norms, step=None) -> None:
        norms = np.asarray(norms, np.float64).ravel().copy()
        with self._lock:
            dq = self._innovations.setdefault(
                method, deque(maxlen=self._maxlen))
            dq.append((None if step is None else int(step), norms))

    def innovation_trajectory(self, method: str):
        with self._lock:
            return list(self._innovations.get(method, ()))

    # -- bias proxy ---------------------------------------------------------

    def record_bias(self, method: str, direction, dense_mean) -> None:
        d = np.asarray(direction, np.float64).ravel()
        g = np.asarray(dense_mean, np.float64).ravel()
        with self._lock:
            acc = self._bias.get(method)
            if acc is None or acc["dir"].shape != d.shape:
                acc = {"n": 0, "dir": np.zeros_like(d), "dense": np.zeros_like(g)}
                self._bias[method] = acc
            acc["n"] += 1
            acc["dir"] += d
            acc["dense"] += g

    def bias_proxy(self, method: str) -> float | None:
        """``||mean(direction) - mean(dense)|| / (||mean(dense)|| + eps)``
        over everything recorded so far; None before the first sample."""
        with self._lock:
            acc = self._bias.get(method)
            if acc is None or not acc["n"]:
                return None
            md = acc["dir"] / acc["n"]
            mg = acc["dense"] / acc["n"]
        return float(np.linalg.norm(md - mg) /
                     (np.linalg.norm(mg) + 1e-12))

    # -- export -------------------------------------------------------------

    def summary(self) -> dict:
        """JSON-serializable roll-up (trajectories: last entry + length)."""
        with self._lock:
            methods = set(self._draws) | set(self._expected) | \
                set(self._innovations) | set(self._bias) | \
                {m for (m, _w) in self._ladders}
            ladder_keys = list(self._ladders)
        out = {}
        for m in sorted(methods):
            entry: dict = {}
            hist = self.level_histogram(m)
            if hist:
                entry["level_histogram"] = {str(k): v for k, v in hist.items()}
                entry["draws"] = self.draw_count(m)
            exp = self.expected_probs(m)
            if exp is not None:
                entry["expected_probs"] = [float(x) for x in exp]
            bias = self.bias_proxy(m)
            if bias is not None:
                entry["bias_proxy"] = bias
            traj = self.innovation_trajectory(m)
            if traj:
                step, norms = traj[-1]
                entry["innovation_last"] = {
                    "step": step, "norms": [float(x) for x in norms],
                    "points": len(traj)}
            workers = sorted(w for (mm, w) in ladder_keys if mm == m)
            if workers:
                rows = {}
                for w in workers:
                    t = self.ladder_trajectory(m, w)
                    if t:
                        step, row = t[-1]
                        rows[str(w)] = {"step": step,
                                        "ema": [float(x) for x in row],
                                        "points": len(t)}
                entry["ladder_last"] = rows
            out[m] = entry
        return out
