"""Low-overhead span/event recorder + the `Telemetry` facade.

Design constraints (all pinned by ``tests/test_obs.py``):

* **No-op when disabled.**  The disabled path is a couple of attribute
  loads — ``active().span(...)`` returns one shared null context manager
  and touches no locks, no clocks, no dicts.
* **Zero effect on jit lowering.**  Recording is pure host Python over
  floats; the instrumented modules only ever call it OUTSIDE traced
  code, so enabling/disabling telemetry can never change what XLA sees
  (the PR-5 retrace-guard harness re-runs with telemetry on and off).
* **Thread-safe buffer.**  The tcp server reactor and the training loop
  record concurrently; events append under one lock into a bounded
  list (drops are counted, never silently lost).
* **Perfetto-ready timestamps.**  ``ts`` is ``epoch + perf_counter`` in
  microseconds: monotonic within a process, and approximately aligned
  ACROSS the ranks a localhost launcher spawns — which is what lines the
  per-rank tracks up so fan-in straggler skew is visible in one view.
  Durations are pure ``perf_counter`` differences.

Event dicts use the Chrome trace-event field names directly (``ph``,
``name``, ``cat``, ``ts``, ``dur``, ``pid``, ``tid``, ``args``) so the
JSONL log and the Perfetto export are the same objects —
``repro.obs.export`` only wraps/validates them.
"""

from __future__ import annotations

import threading
import time

from repro.obs.metrics import MetricsRegistry, MLMCTelemetry

#: hard cap on buffered events; beyond it events are counted as dropped
MAX_EVENTS = 1_000_000

#: default sampling period for the EXPENSIVE estimator metrics (ladder
#: rows, innovation norms, bias proxy); spans/counters are never sampled
DEFAULT_SAMPLE_EVERY = 10


class _NullSpan:
    """Shared do-nothing context manager for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: stamps ts on __enter__, emits on __exit__."""

    __slots__ = ("_rec", "name", "cat", "pid", "args", "_t0")

    def __init__(self, rec, name, cat, pid, args):
        self._rec = rec
        self.name = name
        self.cat = cat
        self.pid = pid
        self.args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        rec = self._rec
        t1 = time.perf_counter()
        ev = {"ph": "X", "name": self.name, "cat": self.cat,
              "ts": (rec._epoch_s + self._t0) * 1e6,
              "dur": (t1 - self._t0) * 1e6,
              "pid": rec.default_pid if self.pid is None else self.pid,
              "tid": rec._tid()}
        if self.args:
            ev["args"] = self.args
        rec._emit(ev)
        return False


class SpanRecorder:
    """Thread-safe bounded buffer of Chrome-trace-shaped events."""

    def __init__(self, enabled: bool = True, *, pid: int = 0,
                 max_events: int = MAX_EVENTS):
        self.enabled = enabled
        self.default_pid = pid
        self.max_events = max_events
        self.dropped = 0
        self._lock = threading.Lock()
        self._events: list[dict] = []
        # time.time() anchor: lines per-rank tracks up across processes
        self._epoch_s = time.time() - time.perf_counter()
        self._tids: dict[int, int] = {}

    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            tid = self._tids.get(ident)
            if tid is None:
                tid = self._tids[ident] = len(self._tids)
            return tid

    def now_us(self) -> float:
        """Current aligned timestamp in microseconds."""
        return (self._epoch_s + time.perf_counter()) * 1e6

    def _emit(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) < self.max_events:
                self._events.append(ev)
            else:
                self.dropped += 1

    def span(self, name: str, *, cat: str = "comm", pid: int | None = None,
             **args):
        """``with rec.span("encode", codec="topk"): ...`` — a complete
        ("X") event covering the block."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, pid, args)

    def complete(self, name: str, t0_perf: float, *, cat: str = "comm",
                 pid: int | None = None, **args) -> None:
        """Emit a complete ("X") event for a block that started at
        ``t0_perf`` (a ``time.perf_counter()`` stamp) and ends now — for
        call sites that time manually instead of nesting a ``with``."""
        if not self.enabled:
            return
        t1 = time.perf_counter()
        ev = {"ph": "X", "name": name, "cat": cat,
              "ts": (self._epoch_s + t0_perf) * 1e6,
              "dur": (t1 - t0_perf) * 1e6,
              "pid": self.default_pid if pid is None else pid,
              "tid": self._tid()}
        if args:
            ev["args"] = args
        self._emit(ev)

    def instant(self, name: str, *, cat: str = "comm",
                pid: int | None = None, ts: float | None = None, **args):
        """A point-in-time ("i") event — e.g. one rank's frame arrival."""
        if not self.enabled:
            return
        ev = {"ph": "i", "name": name, "cat": cat,
              "ts": self.now_us() if ts is None else ts,
              "pid": self.default_pid if pid is None else pid,
              "tid": self._tid(), "s": "t"}
        if args:
            ev["args"] = args
        self._emit(ev)

    def counter(self, name: str, value: float, *, cat: str = "comm",
                pid: int | None = None, series: str = "value"):
        """A Chrome counter ("C") sample — renders as a track in Perfetto."""
        if not self.enabled:
            return
        self._emit({"ph": "C", "name": name, "cat": cat, "ts": self.now_us(),
                    "pid": self.default_pid if pid is None else pid,
                    "tid": self._tid(), "args": {series: float(value)}})

    def events(self) -> list[dict]:
        """Snapshot copy of the buffered events."""
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0


class Telemetry:
    """One bundle of trace + metrics + MLMC telemetry.

    The instrumented modules reach the active bundle via `active()` —
    `Trainer(telemetry=...)` installs it — and guard every record with
    ``tel.enabled``, so a disabled bundle costs two attribute loads per
    site.  ``sample_every`` gates only the EXPENSIVE estimator metrics
    (ladder rows, innovation norms, bias proxy) through
    `should_sample`; spans, counters and level draws are always
    recorded when enabled."""

    def __init__(self, enabled: bool = True, *, rank: int = 0,
                 sample_every: int = DEFAULT_SAMPLE_EVERY,
                 max_events: int = MAX_EVENTS):
        self.enabled = enabled
        self.rank = rank
        self.sample_every = max(1, int(sample_every))
        self.trace = SpanRecorder(enabled, pid=rank, max_events=max_events)
        self.metrics = MetricsRegistry()
        self.mlmc = MLMCTelemetry()
        self._ticks: dict[str, int] = {}
        self._tick_lock = threading.Lock()

    # -- recording shortcuts -------------------------------------------------

    def span(self, name: str, **kw):
        if not self.enabled:
            return _NULL_SPAN
        return self.trace.span(name, **kw)

    def instant(self, name: str, **kw) -> None:
        if self.enabled:
            self.trace.instant(name, **kw)

    def count(self, name: str, value: float = 1.0, **labels) -> None:
        if self.enabled:
            self.metrics.counter(name, **labels).add(value)

    def observe(self, name: str, value: float, *, buckets=None,
                **labels) -> None:
        if self.enabled:
            kw = {} if buckets is None else {"buckets": buckets}
            self.metrics.histogram(name, **kw, **labels).observe(value)

    def gauge(self, name: str, value: float, **labels) -> None:
        if self.enabled:
            self.metrics.gauge(name, **labels).set(value)

    def should_sample(self, key: str) -> bool:
        """Every ``sample_every``-th call per key (first call included);
        always False when disabled — call sites skip their numpy/jnp work
        entirely on the disabled path."""
        if not self.enabled:
            return False
        with self._tick_lock:
            n = self._ticks.get(key, 0)
            self._ticks[key] = n + 1
        return n % self.sample_every == 0


#: the always-off bundle every module sees until something installs one
_DISABLED = Telemetry(enabled=False)
_active: Telemetry = _DISABLED


def active() -> Telemetry:
    """The currently installed `Telemetry` (a disabled singleton by
    default — callers need no None check)."""
    return _active


def install(telemetry: Telemetry | None) -> Telemetry:
    """Install ``telemetry`` as the process-wide active bundle (None
    restores the disabled default).  Returns the now-active bundle."""
    global _active
    _active = telemetry if telemetry is not None else _DISABLED
    return _active


def enabled() -> bool:
    return _active.enabled
