"""Exporters for recorded telemetry: JSONL, Chrome trace JSON, Prometheus.

* `write_jsonl` — one JSON object per line, each a Chrome-trace-shaped
  event (`repro.obs.trace` buffers them in that shape already); a final
  ``ph="M"`` metadata event named ``repro_summary`` carries the metric
  snapshot and the MLMC estimator roll-up.
* `chrome_trace` / `write_chrome_trace` — the Perfetto-viewable JSON
  (``{"traceEvents": [...]}``): one *process* track per rank (``pid`` =
  rank, labeled via ``process_name`` metadata), threads as sub-tracks,
  encode/serialize/socket/decode/aggregate spans as nested slices.
* `prometheus_text` — text-format dump of the `MetricsRegistry`.
* `validate_events` — checks events against the checked-in JSON schema
  (``trace_schema.json``, an append-only surface like the golden
  packets).  The validator is a deliberately tiny local subset of JSON
  Schema — the container must not need a jsonschema dependency.

The module doubles as a CLI (used by CI and the multihost launcher)::

    python -m repro.obs.export run.rank0.jsonl run.rank1.jsonl \
        --jsonl merged.jsonl --perfetto run.json --validate
"""

from __future__ import annotations

import argparse
import json
import os

#: the checked-in trace-event schema (append-only surface)
SCHEMA_PATH = os.path.join(os.path.dirname(__file__), "trace_schema.json")


def load_schema() -> dict:
    with open(SCHEMA_PATH, encoding="utf-8") as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# event assembly
# ---------------------------------------------------------------------------


def summary_event(telemetry) -> dict:
    """The trailing metadata event bundling metrics + MLMC telemetry."""
    return {"ph": "M", "name": "repro_summary", "cat": "meta",
            "ts": telemetry.trace.now_us(), "pid": telemetry.rank, "tid": 0,
            "args": {"metrics": telemetry.metrics.snapshot(),
                     "mlmc": telemetry.mlmc.summary(),
                     "dropped_events": telemetry.trace.dropped}}


def telemetry_events(telemetry) -> list[dict]:
    """All buffered events + the summary metadata event."""
    return telemetry.trace.events() + [summary_event(telemetry)]


# ---------------------------------------------------------------------------
# JSONL
# ---------------------------------------------------------------------------


def write_jsonl(path, events) -> int:
    """One event per line; accepts a `Telemetry` or an event list.
    Returns the number of events written."""
    if not isinstance(events, list):
        events = telemetry_events(events)
    with open(path, "w", encoding="utf-8") as f:
        for ev in events:
            f.write(json.dumps(ev, separators=(",", ":")) + "\n")
    return len(events)


def read_jsonl(path) -> list[dict]:
    out = []
    with open(path, encoding="utf-8") as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: not JSON: {e}") from e
    return out


def merge_events(*event_lists) -> list[dict]:
    """Concatenate per-rank event lists into one timeline (stable
    ts-sort; every event already carries its own pid = rank)."""
    merged = [ev for evs in event_lists for ev in evs]
    merged.sort(key=lambda ev: ev.get("ts", 0.0))
    return merged


# ---------------------------------------------------------------------------
# Chrome trace JSON (Perfetto / chrome://tracing)
# ---------------------------------------------------------------------------


def chrome_trace(events, *, process_names: dict[int, str] | None = None) -> dict:
    """Wrap events in the Chrome trace-event container, prepending
    ``process_name`` metadata so each rank renders as a named track."""
    if not isinstance(events, list):
        events = telemetry_events(events)
    pids = sorted({int(ev.get("pid", 0)) for ev in events})
    names = process_names or {}
    meta = [{"ph": "M", "name": "process_name", "pid": p, "tid": 0, "ts": 0,
             "args": {"name": names.get(p, f"rank {p}")}} for p in pids]
    meta += [{"ph": "M", "name": "process_sort_index", "pid": p, "tid": 0,
              "ts": 0, "args": {"sort_index": p}} for p in pids]
    return {"traceEvents": meta + list(events), "displayTimeUnit": "ms"}


def write_chrome_trace(path, events, *,
                       process_names: dict[int, str] | None = None) -> int:
    doc = chrome_trace(events, process_names=process_names)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, separators=(",", ":"))
    return len(doc["traceEvents"])


# ---------------------------------------------------------------------------
# Prometheus text format
# ---------------------------------------------------------------------------


def _prom_name(name: str) -> str:
    return "repro_" + "".join(c if c.isalnum() or c == "_" else "_"
                              for c in name)


def _prom_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def prometheus_text(metrics_or_telemetry) -> str:
    """Prometheus exposition-format dump of a `MetricsRegistry` snapshot
    (or the registry inside a `Telemetry`)."""
    snap = metrics_or_telemetry
    if hasattr(snap, "metrics"):
        snap = snap.metrics
    if hasattr(snap, "snapshot"):
        snap = snap.snapshot()
    lines, typed = [], set()
    for m in snap:
        name = _prom_name(m["name"])
        if name not in typed:
            lines.append(f"# TYPE {name} {m['kind']}")
            typed.add(name)
        if m["kind"] == "histogram":
            cum = 0
            for bound, c in zip(m["buckets"] + [float("inf")], m["counts"]):
                cum += c
                lb = dict(m["labels"], le=("+Inf" if bound == float("inf")
                                           else repr(bound)))
                lines.append(f"{name}_bucket{_prom_labels(lb)} {cum}")
            lines.append(f"{name}_sum{_prom_labels(m['labels'])} {m['sum']}")
            lines.append(f"{name}_count{_prom_labels(m['labels'])} "
                         f"{m['count']}")
        else:
            lines.append(f"{name}{_prom_labels(m['labels'])} {m['value']}")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# schema validation (tiny local JSON-Schema subset — no dependency)
# ---------------------------------------------------------------------------

_TYPES = {"object": dict, "array": list, "string": str, "boolean": bool,
          "number": (int, float), "integer": int}


def _check(value, schema: dict, path: str, errors: list[str]) -> None:
    t = schema.get("type")
    if t is not None:
        py = _TYPES[t]
        ok = isinstance(value, py) and not (
            t in ("number", "integer") and isinstance(value, bool))
        if t == "number":
            ok = ok or (isinstance(value, int) and not isinstance(value, bool))
        if not ok:
            errors.append(f"{path}: expected {t}, got "
                          f"{type(value).__name__}")
            return
    if "enum" in schema and value not in schema["enum"]:
        errors.append(f"{path}: {value!r} not in {schema['enum']}")
    if t == "object":
        for req in schema.get("required", ()):
            if req not in value:
                errors.append(f"{path}: missing required field {req!r}")
        for k, sub in schema.get("properties", {}).items():
            if k in value:
                _check(value[k], sub, f"{path}.{k}", errors)


def validate_events(events, schema: dict | None = None) -> list[str]:
    """Validate each event against the trace-event schema; returns the
    list of violations (empty = valid)."""
    schema = schema or load_schema()
    errors: list[str] = []
    for i, ev in enumerate(events):
        _check(ev, schema, f"event[{i}]", errors)
    return errors


# ---------------------------------------------------------------------------
# CLI — merge / validate / convert (used by CI and the multihost launcher)
# ---------------------------------------------------------------------------


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.export",
        description="merge, validate, and convert recorded JSONL traces")
    ap.add_argument("inputs", nargs="+", help="JSONL trace file(s)")
    ap.add_argument("--jsonl", default="", help="write merged JSONL here")
    ap.add_argument("--perfetto", default="",
                    help="write Chrome trace-event JSON here")
    ap.add_argument("--prometheus", default="",
                    help="write a Prometheus text dump of the summary "
                         "metrics here")
    ap.add_argument("--validate", action="store_true",
                    help="validate every event against the checked-in "
                         "schema (exit 1 on violation)")
    args = ap.parse_args(argv)
    events = merge_events(*[read_jsonl(p) for p in args.inputs])
    print(f"obs.export: {len(events)} events from {len(args.inputs)} file(s)")
    if args.validate:
        errors = validate_events(events)
        for e in errors[:20]:
            print(f"  SCHEMA {e}")
        if errors:
            raise SystemExit(f"obs.export: {len(errors)} schema violations")
        print("obs.export: schema OK")
    if args.jsonl:
        write_jsonl(args.jsonl, events)
        print(f"obs.export: wrote {args.jsonl}")
    if args.perfetto:
        n = write_chrome_trace(args.perfetto, events)
        print(f"obs.export: wrote {args.perfetto} ({n} trace events)")
    if args.prometheus:
        metrics = []
        for ev in events:
            if ev.get("ph") == "M" and ev.get("name") == "repro_summary":
                metrics.extend(ev.get("args", {}).get("metrics", []))
        with open(args.prometheus, "w", encoding="utf-8") as f:
            f.write(prometheus_text(metrics))
        print(f"obs.export: wrote {args.prometheus}")


if __name__ == "__main__":
    main()
