"""repro.obs — end-to-end comm-stack telemetry.

Spans + counters + MLMC estimator metrics, recorded host-side with zero
effect on jit lowering, exported as JSONL / Chrome trace (Perfetto) /
Prometheus text.

Typical use::

    from repro import obs

    tel = obs.Telemetry(rank=0)
    obs.install(tel)                    # Trainer(telemetry=tel) does this
    ...
    obs.export.write_jsonl("run.jsonl", tel)
    obs.export.write_chrome_trace("run.json", tel)

Instrumented call sites go through ``obs.active()`` — a disabled
singleton until something installs a bundle, so an uninstrumented run
pays two attribute loads per site and records nothing.
"""

from repro.obs import export
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    MLMCTelemetry,
)
from repro.obs.trace import (
    DEFAULT_SAMPLE_EVERY,
    SpanRecorder,
    Telemetry,
    active,
    enabled,
    install,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MLMCTelemetry",
    "SpanRecorder",
    "Telemetry",
    "DEFAULT_SAMPLE_EVERY",
    "active",
    "enabled",
    "install",
    "export",
]
