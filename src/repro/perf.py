"""Beyond-paper performance switches (§Perf hillclimbing).

Optimizations are opt-in via ``REPRO_OPT=name1,name2`` so the
paper-faithful baseline stays the default and every A/B in EXPERIMENTS.md
§Perf is a one-flag diff.  Flags are read at TRACE time — set the env var
before building/lowering a step function.

Available flags:
  grouped_decode     GQA decode attention without expanding the KV cache to
                     per-query-head (einsum over the group dim): cuts decode
                     cache reads by heads/kv_heads (2x gemma3, 6x mixtral).
  sparse_moe_gather  Low-occupancy MoE decode gathers only the routed
                     experts' weight slices (T*top_k < E) instead of running
                     the dense E-expert GEMM: cuts decode weight reads by
                     E/(T*top_k) (deepseek decode: 256 -> T*8).
  bf16_wire          MLMC-Top-k residual values cross the gather collective
                     in bf16 (indices stay int32): 8 -> 6 bytes/entry.
  serve_no_fsdp      prefill/decode keep weights replicated over the data
                     axes (FSDP is a TRAINING memory optimization — at serve
                     time it forces a full all-gather of every layer's
                     weights per decoded token).  Applicable when weights/tp
                     fit HBM (gemma3-27b: 3.4 GB/chip; NOT deepseek-671b).
  serve_tp_all       prefill/decode fuse the (data, model) mesh axes into ONE
                     model group (256-way TP/SP within a pod): weights shard
                     16x finer, caches shard 16x finer, the pod axis keeps
                     batch parallelism.  Requires num_heads % fused_tp == 0
                     (head-sharded attention) — demonstrated at reduced scale
                     in tests; the assigned archs cap at 128 heads so the
                     production-mesh §Perf runs use serve_no_fsdp instead.
"""

from __future__ import annotations

import os


def enabled(name: str) -> bool:
    flags = os.environ.get("REPRO_OPT", "")
    return name in {f.strip() for f in flags.split(",") if f.strip()}


def active() -> tuple[str, ...]:
    return tuple(sorted(
        f.strip() for f in os.environ.get("REPRO_OPT", "").split(",")
        if f.strip()))
