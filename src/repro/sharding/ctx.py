"""ShardCtx — the explicit parallel context threaded through all model code.

The whole runtime is ONE `jax.shard_map` over the production mesh; model code
never touches mesh globals.  Instead every layer receives a `ShardCtx` that
knows the axis names and (static) sizes, and exposes the collectives it is
allowed to use.  With the default `ShardCtx()` (all axes None / size 1) every
collective degenerates to the identity, so the exact same model code runs
unsharded on one CPU device for smoke tests.

Axis roles:
  * ``model``  — tensor / expert / sequence(-cache) parallelism (size tp)
  * ``data``   — data parallelism within a pod; also FSDP weight sharding
  * ``pod``    — data parallelism across pods (the slow hop; MLMC compression
                 always applies here)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    #: one axis name, or a TUPLE of axis names fused into one logical model
    #: group (serve_tp_all: both mesh axes become 256-way model parallelism)
    model_axis: str | tuple[str, ...] | None = None
    data_axis: str | None = None
    pod_axis: str | None = None
    #: per-axis sizes matching model_axis (int or tuple)
    model_sizes: tuple[int, ...] = ()
    tp: int = 1     # TOTAL size of the model group
    dp: int = 1     # size of data axis
    pp: int = 1     # size of pod axis  (pods, not pipeline)

    # ---- static helpers ----------------------------------------------------

    @property
    def dp_total(self) -> int:
        return self.dp * self.pp

    def data_axes(self) -> tuple[str, ...]:
        return tuple(a for a in (self.pod_axis, self.data_axis) if a)

    def model_axes(self) -> tuple[str, ...]:
        if self.model_axis is None:
            return ()
        if isinstance(self.model_axis, tuple):
            return self.model_axis
        return (self.model_axis,)

    # ---- indices ------------------------------------------------------------

    def model_index(self) -> Array:
        axes = self.model_axes()
        if not axes:
            return jnp.zeros((), jnp.int32)
        sizes = self.model_sizes or (self.tp,)
        idx = jnp.zeros((), jnp.int32)
        for a, s in zip(axes, sizes):
            idx = idx * s + lax.axis_index(a)
        return idx

    def data_index(self) -> Array:
        """Flat data-parallel worker index in [0, dp_total)."""
        idx = jnp.zeros((), jnp.int32)
        if self.pod_axis is not None:
            idx = idx + lax.axis_index(self.pod_axis) * self.dp
        if self.data_axis is not None:
            idx = idx + lax.axis_index(self.data_axis)
        return idx

    # ---- collectives (identity when the axis is absent) ---------------------

    def psum_model(self, x):
        axes = self.model_axes()
        return lax.psum(x, axes) if axes else x

    def pmax_model(self, x):
        axes = self.model_axes()
        return lax.pmax(x, axes) if axes else x

    def psum_data(self, x):
        axes = self.data_axes()
        return lax.psum(x, axes) if axes else x

    def pmean_data(self, x):
        axes = self.data_axes()
        return lax.pmean(x, axes) if axes else x

    def pmax_data(self, x):
        axes = self.data_axes()
        return lax.pmax(x, axes) if axes else x

    def psum_pod(self, x):
        return lax.psum(x, self.pod_axis) if self.pod_axis else x

    def all_gather_model(self, x, axis: int = 0, tiled: bool = True):
        for a in reversed(self.model_axes()):
            x = lax.all_gather(x, a, axis=axis, tiled=tiled)
        return x

    def all_gather_data(self, x, axis: int = 0, tiled: bool = True):
        """Gather over the within-pod data axis (FSDP weight gather)."""
        if self.data_axis is None:
            return x
        return lax.all_gather(x, self.data_axis, axis=axis, tiled=tiled)

    def all_gather_dp(self, x, axis: int = 0, tiled: bool = True):
        """Gather over ALL data-parallel axes (pod x data)."""
        for a in self.data_axes():
            x = lax.all_gather(x, a, axis=axis, tiled=tiled)
        return x

    def gather_data_stack(self, x):
        """Stacking all_gather over all data axes: (...,) -> (dp_total, ...).

        Worker order is pod-major (matches `data_index`).  This is the wire
        primitive of the compressed collectives: per-shard payloads — raw
        residual segments or the packed uint32 word buffers / f32 header
        lanes of a `repro.comm.device_wire.DevicePacket` — cross the mesh as
        one stacked operand, so the per-worker bytes ARE the operand bytes."""
        out = x[None]
        for a in reversed(self.data_axes()):
            out = lax.all_gather(out, a, axis=0, tiled=True)
        return out

    def ppermute_model(self, x, perm):
        if self.model_axis is None:
            return x
        return lax.ppermute(x, self.model_axis, perm)

    # ---- sequence-parallel cache helpers ------------------------------------

    def seq_shard_bounds(self, seq_len: int) -> tuple[Array, int]:
        """(start, size) of this model shard's slice of a length-``seq_len``
        sequence-sharded KV cache.  ``seq_len`` must divide by tp."""
        local = seq_len // self.tp
        return self.model_index() * local, local


def unsharded() -> ShardCtx:
    return ShardCtx()
