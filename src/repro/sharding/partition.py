"""Parameter partitioning rules.

Specs are derived from leaf *names* (the innermost dict key) — every layer
module registers its tensor-parallel dimension here.  Leaves living under
the scanned ``blocks`` subtree carry a leading stack (repeat) dimension, so
their sharded axes shift by one.

FSDP (``cfg.fsdp``): large leaves are additionally sharded over ``data`` on
the largest dimension that (a) is not the TP dim and (b) divides by dp.
The chosen axis is precomputed on GLOBAL shapes (``fsdp_axes``) and closed
over by the scan body, which all-gathers just-in-time (`fsdp_gather`).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.ctx import ShardCtx

PyTree = Any

# leaf name -> tensor-parallel axis (on the UNSTACKED shape); absent/None =>
# replicated over `model`
TP_AXIS: dict[str, int | None] = {
    # embeddings / head
    "embed": 0, "head": 1, "enc_embed": None,
    # attention
    "wq": 1, "wk": None, "wv": None, "wo": 0,
    "bq": 0, "bk": None, "bv": None,
    "q_norm": None, "k_norm": None,
    # MLA
    "w_dq": None, "w_uq": 1, "w_dkv": None, "kv_norm": None,
    "w_uk": 1, "w_uv": 1,
    # dense mlp
    "gate": 1, "up": 1, "down": 0,
    # moe
    "router": None, "w_gate": 2, "w_up": 2, "w_down": 1,
    # mamba2 / SSD
    "w_x": 1, "w_z": 1, "w_dt": 1, "w_b": None, "w_c": None,
    "conv_x": 1, "conv_b": None, "conv_c": None,
    "a_log": 0, "dt_bias": 0, "d_skip": 0, "gnorm": 0,
    # rg-lru
    "w_in": 1, "w_gate_branch": 1, "conv": 1, "w_a": 1, "lam": 0,
    "w_out": 0,
    # norms / misc
    "norm1": None, "norm2": None, "norm_cross": None, "final_norm": None,
    "mtp_proj": None,  # output is an activation (full d_model) — replicate
}

#: minimum leaf size to bother FSDP-sharding (small tensors stay replicated)
_FSDP_MIN_SIZE = 1 << 20


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def _is_stacked(path) -> bool:
    return any(isinstance(e, jax.tree_util.DictKey) and e.key == "blocks"
               for e in path)


def tp_axis(name: str) -> int | None:
    return TP_AXIS.get(name)


#: attention leaves that must replicate when num_heads % tp != 0 — the flat
#: feature dim may divide tp while still splitting mid-head, which is
#: semantically invalid (softmax is per-head).
_HEAD_SHARDED = frozenset({"wq", "wo", "bq", "w_uq", "w_uk", "w_uv"})


def replicate_set(cfg, tp: int) -> frozenset:
    """Leaf names forced to replicate for this (config, tp)."""
    if tp > 1 and cfg.num_heads % tp != 0:
        return _HEAD_SHARDED
    return frozenset()


def _fsdp_axis(shape: tuple[int, ...], tp_ax: int | None, dp: int,
               size: int) -> int | None:
    if dp <= 1 or size < _FSDP_MIN_SIZE:
        return None
    best = None
    for i, s in enumerate(shape):
        if i == tp_ax or s % dp != 0:
            continue
        if best is None or s > shape[best]:
            best = i
    return best


def param_specs(abstract_params: PyTree, *, dp: int, tp: int,
                fsdp: bool, data_axis: str = "data",
                model_axis: str = "model",
                replicate: frozenset = frozenset()) -> PyTree:
    """PartitionSpec pytree mirroring the params pytree (GLOBAL shapes)."""

    def spec(path, leaf):
        name = _leaf_name(path)
        stacked = _is_stacked(path)
        shape = tuple(leaf.shape)
        ushape = shape[1:] if stacked else shape
        tp_ax = (tp_axis(name)
                 if tp > 1 and name not in replicate else None)
        if tp_ax is not None and ushape[tp_ax] % tp != 0:
            tp_ax = None  # fall back to replication when not divisible
        fs_ax = (_fsdp_axis(ushape, tp_ax, dp, leaf.size)
                 if fsdp else None)
        axes: list[str | None] = [None] * len(ushape)
        if tp_ax is not None:
            axes[tp_ax] = model_axis
        if fs_ax is not None:
            axes[fs_ax] = data_axis
        if stacked:
            axes = [None] + axes
        return P(*axes)

    return jax.tree_util.tree_map_with_path(spec, abstract_params)


def fsdp_axes(abstract_params: PyTree, *, dp: int, tp: int, fsdp: bool,
              replicate: frozenset = frozenset()) -> PyTree:
    """Per-leaf FSDP axis (on the UNSTACKED/global layout) or -1.

    Computed on global shapes; the scan body uses it to all-gather leaves
    just-in-time.  Inside the scan the stack dim is already sliced away, so
    the recorded axis applies directly to the local leaf."""

    def ax(path, leaf):
        if not fsdp:
            return -1
        name = _leaf_name(path)
        stacked = _is_stacked(path)
        shape = tuple(leaf.shape)
        ushape = shape[1:] if stacked else shape
        tp_ax = (tp_axis(name)
                 if tp > 1 and name not in replicate else None)
        if tp_ax is not None and ushape[tp_ax] % tp != 0:
            tp_ax = None
        fs = _fsdp_axis(ushape, tp_ax, dp, leaf.size)
        return -1 if fs is None else fs

    return jax.tree_util.tree_map_with_path(ax, abstract_params)


def fsdp_gather(params: PyTree, axes: PyTree, ctx: ShardCtx) -> PyTree:
    """All-gather FSDP-sharded leaves over ``data`` (identity when axis<0).

    Called inside the scan body on UNSTACKED leaves; autodiff turns the
    gather into the matching reduce-scatter of the gradient."""
    if ctx.data_axis is None:
        return params

    def g(leaf, ax):
        if ax < 0:
            return leaf
        return ctx.all_gather_data(leaf, axis=int(ax), tiled=True)

    return jax.tree_util.tree_map(g, params, axes)


def shard_params_like(params: PyTree, specs: PyTree, mesh) -> PyTree:
    """Device-put global params according to specs (multi-device tests)."""
    from jax.sharding import NamedSharding

    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, specs)
