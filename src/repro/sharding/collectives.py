"""Compressed gradient collectives — the paper's Algorithms realized as
actual mesh communication inside shard_map.

On a TPU mesh there is no parameter server: "each machine sends its
compressed gradient to the server" (Alg. 2) becomes "each data shard feeds
its MLMC residual into a collective over the data axes".  Three schemes:

* ``dense``            — plain f32/bf16 psum (Alg. 1).  Operand bytes: 4d.
* ``mlmc_topk``        — each shard all-gathers only its residual segment
  (s values + s int32 indices) and scatter-adds locally.  Operand bytes on
  the wire: M·s·8  ≪  4d.  Levels are drawn INDEPENDENTLY per shard
  (fold_in of the data index) exactly as Alg. 2/3 prescribe.
* ``mlmc_fixed``       — the level-l bit-plane residual is a ternary tensor
  {-1,0,+1}: psum it as **int8** (exact for M ≤ 127) and rescale locally.
  Operand bytes: 1d (4x less than dense).  Constraints vs the paper, both
  documented in DESIGN.md: (a) the level draw is SHARED across shards (a
  common-random-numbers variant — unbiasedness is untouched, compression
  noise just stops averaging down in M), because a psum cannot apply
  per-shard scales; (b) the estimator is unbiased w.r.t. the 24-bit
  fixed-point grid value of the gradient (grid error ≤ 2^-24·max|g|).

Every function takes and returns a FLAT f32 vector (per-leaf plumbing lives
in `repro.train.step`) and also returns the idealized wire-bit count.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bits as bitcost
from repro.core.types import categorical
from repro.sharding.ctx import ShardCtx

Array = jax.Array


def _gather_axes(x: Array, ctx: ShardCtx) -> Array:
    """all_gather (stacking) over all data axes: (...,) -> (M, ...)."""
    axes = ctx.data_axes()
    out = x[None]
    for a in reversed(axes):
        out = lax.all_gather(out, a, axis=0, tiled=True)
    return out


def dense_allreduce(flat: Array, ctx: ShardCtx) -> tuple[Array, Array]:
    """Alg. 1: plain mean over the data axes."""
    mean = ctx.pmean_data(flat)
    bits = jnp.asarray(ctx.dp_total * bitcost.dense_bits(flat.shape[0]),
                       jnp.float32)
    return mean, bits


def mlmc_topk_allreduce(flat: Array, ctx: ShardCtx, rng: Array,
                        *, s: int) -> tuple[Array, Array]:
    """Adaptive MLMC s-Top-k (Alg. 3) with a sparse all-gather collective.

    One argsort serves both the Lemma-3.4 probabilities (segment norms of
    the sorted vector) and the residual extraction (ranks [(l-1)s, ls))."""
    d = flat.shape[0]
    s = min(s, d)
    L = math.ceil(d / s)
    pad = L * s - d

    rng = jax.random.fold_in(rng, ctx.data_index())  # independent levels
    order = jnp.argsort(-jnp.abs(flat))
    sorted_vals = flat[order]
    sv = jnp.pad(sorted_vals, (0, pad))
    so = jnp.pad(order, (0, pad), constant_values=d - 1)

    deltas = jnp.sqrt(jnp.sum(sv.reshape(L, s) ** 2, axis=-1))   # Lemma 3.4
    total = jnp.sum(deltas)
    probs = jnp.where(total > 1e-30, deltas / jnp.maximum(total, 1e-30),
                      jnp.full((L,), 1.0 / L))
    idx0 = categorical(rng, probs)                                # 0-based l-1
    p_l = jnp.maximum(probs[idx0], 1e-30)

    seg_vals = lax.dynamic_slice(sv, (idx0 * s,), (s,)) / p_l
    seg_idx = lax.dynamic_slice(so, (idx0 * s,), (s,))
    # zero padded tail entries (they carry index d-1; value must be 0)
    seg_vals = jnp.where(jnp.arange(s) + idx0 * s < d, seg_vals, 0.0)

    from repro import perf

    value_bits = 32
    if perf.enabled("bf16_wire"):
        # §Perf `bf16_wire`: residual values cross the gather in bf16
        # (8 -> 6 bytes/entry with the int32 index)
        seg_vals = seg_vals.astype(jnp.bfloat16)
        value_bits = 16
    g_vals = _gather_axes(seg_vals, ctx).reshape(-1)              # (M*s,)
    g_idx = _gather_axes(seg_idx, ctx).reshape(-1)
    dense = jnp.zeros((d,), flat.dtype).at[g_idx].add(
        g_vals.astype(flat.dtype))
    mean = dense / ctx.dp_total

    bits = jnp.asarray(
        ctx.dp_total * bitcost.topk_mlmc_bits(d, s, value_bits=value_bits),
        jnp.float32)
    return mean, bits


def mlmc_fixedpoint_allreduce(flat: Array, ctx: ShardCtx, rng: Array,
                              *, num_levels: int = 24
                              ) -> tuple[Array, Array]:
    """Fixed-point MLMC (Alg. 2, Lemma 3.3) with an int8 psum collective."""
    d = flat.shape[0]
    L = num_levels

    # shared scale (one scalar collective) + shared level draw (common rng)
    gmax = ctx.pmax_data(jnp.max(jnp.abs(flat)))
    gmax = jnp.maximum(gmax, 1e-30)
    probs = 2.0 ** -jnp.arange(1, L + 1, dtype=jnp.float32)
    probs = probs / jnp.sum(probs)
    idx0 = categorical(rng, probs)
    level = idx0 + 1
    p_l = probs[idx0]

    x = jnp.minimum(jnp.abs(flat) / gmax, 1.0 - 2.0 ** -24)
    bit = jnp.mod(jnp.floor(jnp.ldexp(x, level)), 2.0)
    tern = (jnp.sign(flat) * bit).astype(jnp.int8)

    summed = ctx.psum_data(tern)                                  # int8 wire
    scale = gmax * jnp.ldexp(1.0, -level) / (p_l * ctx.dp_total)
    mean = summed.astype(jnp.float32) * scale

    bits = jnp.asarray(
        ctx.dp_total * bitcost.fixed_point_mlmc_bits(d, L), jnp.float32)
    return mean, bits


AGG_METHODS = ("dense", "mlmc_topk", "mlmc_fixed")


def compressed_allreduce(flat: Array, ctx: ShardCtx, rng: Array,
                         method: str, *, k_fraction: float = 0.001,
                         min_segment: int = 8) -> tuple[Array, Array]:
    """Dispatch.  For mlmc_topk the per-leaf segment budget is
    ``s = max(min_segment, k_fraction * d)`` — one MLMC residual segment of
    roughly the Top-k budget the paper uses (k ∈ {0.001n .. 0.5n})."""
    if method == "dense":
        return dense_allreduce(flat, ctx)
    if method == "mlmc_topk":
        s = max(min_segment, int(round(k_fraction * flat.shape[0])))
        return mlmc_topk_allreduce(flat, ctx, rng, s=s)
    if method == "mlmc_fixed":
        return mlmc_fixedpoint_allreduce(flat, ctx, rng)
    raise ValueError(f"unknown aggregation method {method!r}")
